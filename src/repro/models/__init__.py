"""Model zoo: build any assigned architecture from its ArchConfig."""

from repro.configs.base import ArchConfig


def build_model(cfg: ArchConfig):
    """Family dispatch. All models expose the same surface:
    init / forward / loss / init_caches / prefill / decode_step."""
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import TransformerLM

        return TransformerLM(cfg)
    if cfg.family == "ssm":
        from repro.models.ssm_lm import Mamba2LM

        return Mamba2LM(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import HybridLM

        return HybridLM(cfg)
    if cfg.family == "audio":
        from repro.models.encdec import EncDecLM

        return EncDecLM(cfg)
    raise ValueError(f"unknown family: {cfg.family}")


__all__ = ["ArchConfig", "build_model"]
