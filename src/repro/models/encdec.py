"""Whisper-style encoder-decoder transformer [arXiv:2212.04356].

The modality frontend (log-mel spectrogram + 2x conv downsampling) is the
assignment's allowed stub: ``input_specs()`` provides precomputed frame
embeddings [B, T_enc, d]. Everything downstream — the bidirectional
encoder, the causal decoder with cross-attention, KV-cached serving — is
implemented fully.

Whisper conventions kept: LayerNorm (with biases), GELU MLP, attention
biases, sinusoidal positions (we use sinusoidal for the decoder too instead
of Whisper's learned table — noted in DESIGN.md), no RoPE.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    dense_init,
    embed_init,
    init_mlp,
    init_norm,
    sinusoidal_positions,
)
from repro.utils.sharding_ctx import shard_residual


def _init_xattn(key, d, n_heads, head_dim, dtype):
    return attn_mod.init_attention(key, d, n_heads, n_heads, head_dim, dtype,
                                   with_bias=True)


def _cross_kv(p, memory, n_heads, head_dim):
    B, T, _ = memory.shape
    k = (memory @ p["wk"] + p["bk"]).reshape(B, T, n_heads, head_dim)
    v = (memory @ p["wv"] + p["bv"]).reshape(B, T, n_heads, head_dim)
    return k, v


def _cross_attend(p, x, k, v, n_heads, head_dim):
    B, S, _ = x.shape
    q = (x @ p["wq"] + p["bq"]).reshape(B, S, n_heads, head_dim)
    out = attn_mod.attend_naive(q, k, v, attn_mod.mask_fn("bidirectional"))
    return out.reshape(B, S, n_heads * head_dim) @ p["wo"] + p["bo"]


def init_enc_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": init_norm(cfg.d_model, dtype, with_bias=True),
        "attn": attn_mod.init_attention(k1, cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.head_dim, dtype,
                                        with_bias=True),
        "ln2": init_norm(cfg.d_model, dtype, with_bias=True),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype, activation="gelu",
                        with_bias=True),
    }


def init_dec_block(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": init_norm(cfg.d_model, dtype, with_bias=True),
        "self_attn": attn_mod.init_attention(k1, cfg.d_model, cfg.n_heads,
                                             cfg.n_kv_heads, cfg.head_dim,
                                             dtype, with_bias=True),
        "ln_x": init_norm(cfg.d_model, dtype, with_bias=True),
        "cross_attn": _init_xattn(k2, cfg.d_model, cfg.n_heads, cfg.head_dim,
                                  dtype),
        "ln2": init_norm(cfg.d_model, dtype, with_bias=True),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype, activation="gelu",
                        with_bias=True),
    }


class EncDecLM(NamedTuple):
    cfg: ArchConfig

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        kenc, kdec, kemb = jax.random.split(key, 3)
        ekeys = jax.random.split(kenc, cfg.encoder_layers)
        dkeys = jax.random.split(kdec, cfg.n_layers)
        if cfg.scan_layers:
            enc = jax.vmap(lambda k: init_enc_block(k, cfg))(ekeys)
            dec = jax.vmap(lambda k: init_dec_block(k, cfg))(dkeys)
        else:
            enc = [init_enc_block(k, cfg) for k in ekeys]
            dec = [init_dec_block(k, cfg) for k in dkeys]
        return {
            "embed": embed_init(kemb, cfg.vocab_size, cfg.d_model, dtype),
            "encoder": enc,
            "enc_norm": init_norm(cfg.d_model, dtype, with_bias=True),
            "decoder": dec,
            "final_norm": init_norm(cfg.d_model, dtype, with_bias=True),
        }

    # -------------------------------------------------------------- encoder
    def encode(self, params, frames) -> jax.Array:
        cfg = self.cfg
        T = frames.shape[1]
        x = frames.astype(jnp.dtype(cfg.dtype))
        x = x + sinusoidal_positions(T, cfg.d_model, x.dtype)[None]

        def body(x, p):
            x = shard_residual(x)
            h = apply_norm(x, p["ln1"], "layernorm")
            h = attn_mod.attention(
                p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.head_dim, kind="bidirectional", use_rope=False,
                block_size=cfg.attn_block_size)
            x = x + h
            h = apply_norm(x, p["ln2"], "layernorm")
            return x + apply_mlp(h, p["mlp"], activation="gelu"), None

        if cfg.scan_layers:
            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, _ = jax.lax.scan(body_fn, x, params["encoder"])
        else:
            for p in params["encoder"]:
                x, _ = body(x, p)
        return apply_norm(x, params["enc_norm"], "layernorm")

    # -------------------------------------------------------------- decoder
    def _dec_embed(self, params, tokens, start_pos: int | jax.Array = 0):
        cfg = self.cfg
        x = params["embed"][tokens].astype(jnp.dtype(cfg.dtype))
        S = tokens.shape[1]
        pos_tab = sinusoidal_positions(S, cfg.d_model, x.dtype) \
            if isinstance(start_pos, int) and start_pos == 0 else None
        if pos_tab is not None:
            return x + pos_tab[None]
        # decode: single position start_pos
        inv = 1.0 / (10000.0 ** (jnp.arange(0, cfg.d_model, 2, jnp.float32)
                                 / cfg.d_model))
        ang = jnp.asarray(start_pos, jnp.float32) * inv
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]
        return x + pe.astype(x.dtype)

    def _dec_block_full(self, p, x, memory, cfg):
        x = shard_residual(x)
        h = apply_norm(x, p["ln1"], "layernorm")
        h = attn_mod.attention(
            p["self_attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, kind="full", use_rope=False,
            block_size=cfg.attn_block_size)
        x = x + h
        h = apply_norm(x, p["ln_x"], "layernorm")
        k, v = _cross_kv(p["cross_attn"], memory, cfg.n_heads, cfg.head_dim)
        x = x + _cross_attend(p["cross_attn"], h, k, v, cfg.n_heads, cfg.head_dim)
        h = apply_norm(x, p["ln2"], "layernorm")
        return x + apply_mlp(h, p["mlp"], activation="gelu")

    def forward(self, params, batch) -> jax.Array:
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        x = self._dec_embed(params, batch["tokens"])

        if cfg.scan_layers:
            def body(x, p):
                return self._dec_block_full(p, x, memory, cfg), None

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, _ = jax.lax.scan(body_fn, x, params["decoder"])
        else:
            for p in params["decoder"]:
                x = self._dec_block_full(p, x, memory, cfg)
        x = apply_norm(x, params["final_norm"], "layernorm")
        return x @ params["embed"].T  # whisper ties the output head

    def loss(self, params, batch) -> jax.Array:
        from repro.models.losses import chunked_ce

        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        x = self._dec_embed(params, batch["tokens"])
        if cfg.scan_layers:
            def body(x, p):
                return self._dec_block_full(p, x, memory, cfg), None

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, _ = jax.lax.scan(body_fn, x, params["decoder"])
        else:
            for p in params["decoder"]:
                x = self._dec_block_full(p, x, memory, cfg)
        x = apply_norm(x, params["final_norm"], "layernorm")
        return chunked_ce(x, params["embed"].T, batch["tokens"])

    # ---------------------------------------------------------------- serve
    def init_caches(self, batch: int, seq_len: int):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        one = lambda: {
            "self": attn_mod.init_cache(batch, seq_len, cfg.n_kv_heads,
                                        cfg.head_dim, dtype),
            "cross_k": jnp.zeros((batch, cfg.encoder_len, cfg.n_heads,
                                  cfg.head_dim), dtype),
            "cross_v": jnp.zeros((batch, cfg.encoder_len, cfg.n_heads,
                                  cfg.head_dim), dtype),
        }
        if cfg.scan_layers:
            return jax.tree.map(
                lambda *ls: jnp.stack(ls), *[one() for _ in range(cfg.n_layers)])
        return [one() for _ in range(cfg.n_layers)]

    def _dec_block_prefill(self, p, x, cache, memory, cfg):
        h = apply_norm(x, p["ln1"], "layernorm")
        h, self_c = attn_mod.prefill_attention(
            p["self_attn"], h, cache=cache["self"], n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, kind="full",
            use_rope=False, block_size=cfg.attn_block_size)
        x = x + h
        h = apply_norm(x, p["ln_x"], "layernorm")
        k, v = _cross_kv(p["cross_attn"], memory, cfg.n_heads, cfg.head_dim)
        x = x + _cross_attend(p["cross_attn"], h, k, v, cfg.n_heads, cfg.head_dim)
        h = apply_norm(x, p["ln2"], "layernorm")
        x = x + apply_mlp(h, p["mlp"], activation="gelu")
        return x, {"self": self_c, "cross_k": k, "cross_v": v}

    def _dec_block_decode(self, p, x1, cache, cfg):
        h = apply_norm(x1, p["ln1"], "layernorm")
        h, self_c = attn_mod.decode_attention(
            p["self_attn"], h, cache["self"], n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, kind="full",
            use_rope=False)
        x1 = x1 + h
        h = apply_norm(x1, p["ln_x"], "layernorm")
        x1 = x1 + _cross_attend(p["cross_attn"], h, cache["cross_k"],
                                cache["cross_v"], cfg.n_heads, cfg.head_dim)
        h = apply_norm(x1, p["ln2"], "layernorm")
        x1 = x1 + apply_mlp(h, p["mlp"], activation="gelu")
        return x1, {"self": self_c, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}

    def prefill(self, params, batch, caches):
        cfg = self.cfg
        memory = self.encode(params, batch["frames"])
        x = self._dec_embed(params, batch["tokens"])
        if cfg.scan_layers:
            def body(x, inp):
                p, cache = inp
                x, cache = self._dec_block_prefill(p, x, cache, memory, cfg)
                return x, cache

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, caches = jax.lax.scan(body_fn, x, (params["decoder"], caches))
        else:
            new = []
            for p, cache in zip(params["decoder"], caches):
                x, cache = self._dec_block_prefill(p, x, cache, memory, cfg)
                new.append(cache)
            caches = new
        x = apply_norm(x[:, -1:, :], params["final_norm"], "layernorm")
        return x @ params["embed"].T, caches

    def decode_step(self, params, token, caches):
        cfg = self.cfg
        # position = self-attn cache length (same for every layer)
        if cfg.scan_layers:
            length = caches["self"].length[0]
        else:
            length = caches[0]["self"].length
        x = self._dec_embed(params, token, start_pos=length)
        if cfg.scan_layers:
            def body(x, inp):
                p, cache = inp
                x, cache = self._dec_block_decode(p, x, cache, cfg)
                return x, cache

            x, caches = jax.lax.scan(body, x, (params["decoder"], caches))
        else:
            new = []
            for p, cache in zip(params["decoder"], caches):
                x, cache = self._dec_block_decode(p, x, cache, cfg)
                new.append(cache)
            caches = new
        x = apply_norm(x, params["final_norm"], "layernorm")
        return x @ params["embed"].T, caches
