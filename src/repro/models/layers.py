"""Shared neural-net building blocks (pure functions over param dicts).

No flax/haiku dependency: parameters are nested dicts of jnp arrays, each
module is an ``init_*`` + ``apply`` pair. This keeps pytrees transparent for
the federated algorithms (which treat the whole model as an optimization
variable) and for the sharding layer (which mirrors the dict structure with
PartitionSpecs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------- init
def dense_init(key, d_in: int, d_out: int, dtype, *, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# -------------------------------------------------------------------- norms
def rms_norm(x, weight, *, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x, weight, bias, *, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mean) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def init_norm(d: int, dtype, *, with_bias: bool = False):
    if with_bias:
        return {"weight": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    # rms_norm stores weight as a delta around 1 (gemma convention) so a
    # zeros-init is the identity transform.
    return {"weight": jnp.zeros((d,), dtype)}


def apply_norm(x, params, kind: str = "rmsnorm"):
    if kind == "layernorm":
        return layer_norm(x, params["weight"], params["bias"])
    return rms_norm(x, params["weight"])


# --------------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                      # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs      # [..., S, Dh/2]
    angles = angles[..., None, :]                                  # [..., S, 1, Dh/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int, dtype=jnp.float32):
    """Whisper-style fixed sinusoidal position embeddings [n_pos, d]."""
    inv = 1.0 / (10000.0 ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = jnp.arange(n_pos, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------- mlp
def init_mlp(key, d: int, d_ff: int, dtype, *, activation: str, with_bias: bool = False):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        p = {
            "gate": dense_init(k1, d, d_ff, dtype),
            "up": dense_init(k2, d, d_ff, dtype),
            "down": dense_init(k3, d_ff, d, dtype),
        }
    else:  # plain gelu (whisper)
        p = {"up": dense_init(k1, d, d_ff, dtype), "down": dense_init(k2, d_ff, d, dtype)}
    if with_bias:
        p["up_b"] = jnp.zeros((d_ff,), dtype)
        p["down_b"] = jnp.zeros((d,), dtype)
    return p


def apply_mlp(x, params, *, activation: str):
    if activation in ("swiglu", "geglu"):
        act = jax.nn.silu if activation == "swiglu" else lambda a: jax.nn.gelu(a, approximate=True)
        h = act(x @ params["gate"]) * (x @ params["up"])
        return h @ params["down"]
    h = x @ params["up"]
    if "up_b" in params:
        h = h + params["up_b"]
    h = jax.nn.gelu(h, approximate=True)
    out = h @ params["down"]
    if "down_b" in params:
        out = out + params["down_b"]
    return out
