"""Zamba2-style hybrid LM: Mamba2 backbone + ONE shared attention block.

The architecture alternates groups of ``shared_attn_every`` Mamba2 layers
with an application of a single *parameter-shared* attention(+MLP) block
[arXiv:2411.15242]. The shared block's parameters exist once; each
application at runtime gets its own KV cache. (Zamba2 additionally inserts
per-application LoRA adapters on the shared block; we share it verbatim and
note the simplification in DESIGN.md.)

Layer layout for n_layers = G * every + R:
    [every x mamba, shared-attn] * G  then  R trailing mamba layers.
Mamba groups are scanned ([G, every, ...] stacked params) so the lowered
HLO stays small at depth.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models.layers import apply_mlp, apply_norm, embed_init, init_mlp, init_norm
from repro.models.mamba2 import (
    apply_mamba_block,
    apply_mamba_block_decode,
    apply_mamba_block_prefill,
    init_mamba_block,
    init_ssm_cache,
)
from repro.models.transformer import apply_block, apply_block_decode, apply_block_prefill, init_block


def _layout(cfg: ArchConfig) -> tuple[int, int, int]:
    every = cfg.shared_attn_every
    groups = cfg.n_layers // every if every else 0
    rest = cfg.n_layers - groups * every
    return groups, every, rest


class HybridLM(NamedTuple):
    cfg: ArchConfig

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        groups, every, rest = _layout(cfg)
        kemb, kgrp, krest, kshared, khead = jax.random.split(key, 5)
        gkeys = jax.random.split(kgrp, max(groups * every, 1))
        if cfg.scan_layers and groups:
            stacked = jax.vmap(lambda k: init_mamba_block(k, cfg))(
                gkeys[: groups * every])
            grouped = jax.tree.map(
                lambda a: a.reshape((groups, every) + a.shape[1:]), stacked)
        else:
            grouped = [
                [init_mamba_block(gkeys[g * every + i], cfg) for i in range(every)]
                for g in range(groups)
            ]
        rkeys = jax.random.split(krest, max(rest, 1))
        return {
            "embed": embed_init(kemb, cfg.vocab_size, cfg.d_model, dtype),
            "groups": grouped,
            "shared_attn": init_block(kshared, cfg),   # attention + MLP block
            "rest": [init_mamba_block(rkeys[i], cfg) for i in range(rest)],
            "final_norm": init_norm(cfg.d_model, dtype),
            "lm_head": embed_init(khead, cfg.vocab_size, cfg.d_model, dtype).T,
        }

    def _embed(self, params, tokens):
        return params["embed"][tokens].astype(jnp.dtype(self.cfg.dtype))

    def _logits(self, params, x):
        x = apply_norm(x, params["final_norm"], self.cfg.norm)
        return x @ params["lm_head"]

    # ------------------------------------------------------------- training
    def _stack(self, params, x):
        cfg = self.cfg
        groups, every, rest = _layout(cfg)
        shared = params["shared_attn"]
        if cfg.scan_layers and groups:
            def group_body(x, gparams):
                def inner(x, p):
                    return apply_mamba_block(p, x, cfg), None

                x, _ = jax.lax.scan(inner, x, gparams)
                x, _ = apply_block(shared, x, cfg)
                return x, None

            body = jax.checkpoint(group_body) if cfg.remat else group_body
            x, _ = jax.lax.scan(body, x, params["groups"])
        else:
            for g in range(groups):
                for p in params["groups"][g]:
                    x = apply_mamba_block(p, x, cfg)
                x, _ = apply_block(shared, x, cfg)
        for p in params["rest"]:
            x = apply_mamba_block(p, x, cfg)
        return x

    def forward(self, params, batch) -> jax.Array:
        return self._logits(params, self._stack(params, self._embed(params, batch["tokens"])))

    def loss(self, params, batch) -> jax.Array:
        from repro.models.losses import chunked_ce

        x = self._stack(params, self._embed(params, batch["tokens"]))
        x = apply_norm(x, params["final_norm"], self.cfg.norm)
        return chunked_ce(x, params["lm_head"], batch["tokens"])

    # ---------------------------------------------------------------- serve
    def _attn_window_cap(self, seq_len: int) -> int:
        cfg = self.cfg
        # the shared attention block runs sliding-window in long-context
        # serving so the hybrid stays sub-quadratic (DESIGN.md §5).
        if cfg.attention == "sliding":
            return min(cfg.window, seq_len)
        return seq_len

    def init_caches(self, batch: int, seq_len: int):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        groups, every, rest = _layout(cfg)
        cap = self._attn_window_cap(seq_len)
        ssm_one = lambda: init_ssm_cache(batch, cfg, dtype)
        kv_one = lambda: attn_mod.init_cache(batch, cap, cfg.n_kv_heads,
                                             cfg.head_dim, dtype)
        if cfg.scan_layers and groups:
            ssm = jax.tree.map(lambda *ls: jnp.stack(ls),
                               *[ssm_one() for _ in range(groups * every)])
            ssm = jax.tree.map(
                lambda a: a.reshape((groups, every) + a.shape[1:]), ssm)
            kv = jax.tree.map(lambda *ls: jnp.stack(ls),
                              *[kv_one() for _ in range(groups)])
        else:
            ssm = [[ssm_one() for _ in range(every)] for _ in range(groups)]
            kv = [kv_one() for _ in range(groups)]
        rest_c = [ssm_one() for _ in range(rest)]
        return {"ssm": ssm, "kv": kv, "rest": rest_c}

    def prefill(self, params, batch, caches):
        cfg = self.cfg
        groups, every, rest = _layout(cfg)
        shared = params["shared_attn"]
        ring = cfg.attention == "sliding"
        x = self._embed(params, batch["tokens"])
        if cfg.scan_layers and groups:
            def group_body(x, inp):
                gparams, ssm_c, kv_c = inp

                def inner(x, pc):
                    p, c = pc
                    x, c = apply_mamba_block_prefill(p, x, c, cfg)
                    return x, c

                x, ssm_c = jax.lax.scan(inner, x, (gparams, ssm_c))
                x, kv_c = apply_block_prefill(shared, x, kv_c, cfg, ring=ring)
                return x, (ssm_c, kv_c)

            body = jax.checkpoint(group_body) if cfg.remat else group_body
            x, (ssm, kv) = jax.lax.scan(
                body, x, (params["groups"], caches["ssm"], caches["kv"]))
        else:
            ssm, kv = [], []
            for g in range(groups):
                gc = []
                for p, c in zip(params["groups"][g], caches["ssm"][g]):
                    x, c = apply_mamba_block_prefill(p, x, c, cfg)
                    gc.append(c)
                x, kvc = apply_block_prefill(shared, x, caches["kv"][g], cfg,
                                             ring=ring)
                ssm.append(gc)
                kv.append(kvc)
        rest_c = []
        for p, c in zip(params["rest"], caches["rest"]):
            x, c = apply_mamba_block_prefill(p, x, c, cfg)
            rest_c.append(c)
        caches = {"ssm": ssm, "kv": kv, "rest": rest_c}
        return self._logits(params, x[:, -1:, :]), caches

    def decode_step(self, params, token, caches):
        cfg = self.cfg
        groups, every, rest = _layout(cfg)
        shared = params["shared_attn"]
        ring = cfg.attention == "sliding"
        x = self._embed(params, token)
        if cfg.scan_layers and groups:
            def group_body(x, inp):
                gparams, ssm_c, kv_c = inp

                def inner(x, pc):
                    p, c = pc
                    x, c = apply_mamba_block_decode(p, x, c, cfg)
                    return x, c

                x, ssm_c = jax.lax.scan(inner, x, (gparams, ssm_c))
                x, kv_c = apply_block_decode(shared, x, kv_c, cfg, ring=ring)
                return x, (ssm_c, kv_c)

            x, (ssm, kv) = jax.lax.scan(
                group_body, x, (params["groups"], caches["ssm"], caches["kv"]))
        else:
            ssm, kv = [], []
            for g in range(groups):
                gc = []
                for p, c in zip(params["groups"][g], caches["ssm"][g]):
                    x, c = apply_mamba_block_decode(p, x, c, cfg)
                    gc.append(c)
                x, kvc = apply_block_decode(shared, x, caches["kv"][g], cfg,
                                            ring=ring)
                ssm.append(gc)
                kv.append(kvc)
        rest_c = []
        for p, c in zip(params["rest"], caches["rest"]):
            x, c = apply_mamba_block_decode(p, x, c, cfg)
            rest_c.append(c)
        caches = {"ssm": ssm, "kv": kv, "rest": rest_c}
        return self._logits(params, x), caches
