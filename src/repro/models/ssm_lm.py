"""Pure-SSM language model (mamba2-130m family): attention-free decoder."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_norm, embed_init, init_norm
from repro.models.mamba2 import (
    apply_mamba_block,
    apply_mamba_block_decode,
    apply_mamba_block_prefill,
    init_mamba_block,
    init_ssm_cache,
)


class Mamba2LM(NamedTuple):
    cfg: ArchConfig

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        kemb, klayers, khead = jax.random.split(key, 3)
        layer_keys = jax.random.split(klayers, cfg.n_layers)
        if cfg.scan_layers:
            layers = jax.vmap(lambda k: init_mamba_block(k, cfg))(layer_keys)
        else:
            layers = [init_mamba_block(k, cfg) for k in layer_keys]
        return {
            "embed": embed_init(kemb, cfg.vocab_size, cfg.d_model, dtype),
            "layers": layers,
            "final_norm": init_norm(cfg.d_model, dtype),
            "lm_head": embed_init(khead, cfg.vocab_size, cfg.d_model, dtype).T,
        }

    def _embed(self, params, tokens):
        return params["embed"][tokens].astype(jnp.dtype(self.cfg.dtype))

    def _logits(self, params, x):
        x = apply_norm(x, params["final_norm"], self.cfg.norm)
        return x @ params["lm_head"]

    def _stack(self, params, x):
        cfg = self.cfg
        if cfg.scan_layers:
            def body(x, p):
                return apply_mamba_block(p, x, cfg), None

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, _ = jax.lax.scan(body_fn, x, params["layers"])
        else:
            for p in params["layers"]:
                x = apply_mamba_block(p, x, cfg)
        return x

    def forward(self, params, batch) -> jax.Array:
        x = self._embed(params, batch["tokens"])
        return self._logits(params, self._stack(params, x))

    def loss(self, params, batch) -> jax.Array:
        from repro.models.losses import chunked_ce

        x = self._embed(params, batch["tokens"])
        x = apply_norm(self._stack(params, x), params["final_norm"], self.cfg.norm)
        return chunked_ce(x, params["lm_head"], batch["tokens"])

    # ---------------------------------------------------------------- serve
    def init_caches(self, batch: int, seq_len: int):
        cfg = self.cfg
        del seq_len  # SSM state is O(1) in sequence length
        dtype = jnp.dtype(cfg.dtype)
        one = lambda: init_ssm_cache(batch, cfg, dtype)
        if cfg.scan_layers:
            return jax.tree.map(
                lambda *ls: jnp.stack(ls), *[one() for _ in range(cfg.n_layers)])
        return [one() for _ in range(cfg.n_layers)]

    def prefill(self, params, batch, caches):
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        if cfg.scan_layers:
            def body(x, inp):
                p, cache = inp
                x, cache = apply_mamba_block_prefill(p, x, cache, cfg)
                return x, cache

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, caches = jax.lax.scan(body_fn, x, (params["layers"], caches))
        else:
            new = []
            for p, cache in zip(params["layers"], caches):
                x, cache = apply_mamba_block_prefill(p, x, cache, cfg)
                new.append(cache)
            caches = new
        return self._logits(params, x[:, -1:, :]), caches

    def decode_step(self, params, token, caches):
        cfg = self.cfg
        x = self._embed(params, token)
        if cfg.scan_layers:
            def body(x, inp):
                p, cache = inp
                x, cache = apply_mamba_block_decode(p, x, cache, cfg)
                return x, cache

            x, caches = jax.lax.scan(body, x, (params["layers"], caches))
        else:
            new = []
            for p, cache in zip(params["layers"], caches):
                x, cache = apply_mamba_block_decode(p, x, cache, cfg)
                new.append(cache)
            caches = new
        return self._logits(params, x), caches
