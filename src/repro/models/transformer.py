"""Decoder-only transformer LM covering the dense, moe and vlm families.

Layers are stacked ([L, ...] leaves) and applied with ``lax.scan`` (+
optional ``jax.checkpoint`` remat) so multi-B-parameter configs lower to a
compact HLO; the reduced smoke variants unroll in Python instead
(``scan_layers=False``).

The VLM family (llava-next) consumes stub-frontend image-patch embeddings:
the sequence layout is ``[n_modal image tokens][text tokens]`` and the LM
loss is applied on text positions only. The anyres tiling itself lives in
the (stubbed) vision tower; what this backbone implements is the token
interleave + the 60-layer language model that attends across both regions.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    embed_init,
    init_mlp,
    init_norm,
)
from repro.models.moe import apply_moe, init_moe
from repro.utils.sharding_ctx import shard_residual

MOE_AUX_COEF = 0.01


# ------------------------------------------------------------------- blocks
def init_block(key, cfg: ArchConfig):
    kattn, kmlp = jax.random.split(key)
    dtype = jnp.dtype(cfg.param_dtype)
    with_bias = cfg.norm == "layernorm"
    p = {
        "ln1": init_norm(cfg.d_model, dtype, with_bias=with_bias),
        "attn": attn.init_attention(
            key=kattn, d_model=cfg.d_model, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, dtype=dtype,
            qk_norm=cfg.qk_norm, with_bias=cfg.attn_bias),
        "ln2": init_norm(cfg.d_model, dtype, with_bias=with_bias),
    }
    if cfg.n_experts:
        p["moe"] = init_moe(kmlp, cfg.d_model, cfg.d_ff, cfg.n_experts, dtype,
                            shared_expert=cfg.moe_shared_expert,
                            activation=cfg.activation)
    else:
        p["mlp"] = init_mlp(kmlp, cfg.d_model, cfg.d_ff, dtype,
                            activation=cfg.activation, with_bias=cfg.mlp_bias)
    return p


def _apply_ffn(p, h, cfg: ArchConfig):
    if cfg.n_experts:
        out, aux = apply_moe(
            p["moe"], h, n_experts=cfg.n_experts, k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor, activation=cfg.activation,
            shared_expert=cfg.moe_shared_expert)
        return out, aux
    return apply_mlp(h, p["mlp"], activation=cfg.activation), jnp.zeros((), jnp.float32)


def apply_block(p, x, cfg: ArchConfig):
    """(x, aux) for one decoder block over a full sequence."""
    x = shard_residual(x)
    h = apply_norm(x, p["ln1"], cfg.norm)
    h = attn.attention(
        p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, kind=cfg.attention, window=cfg.window,
        chunk=cfg.chunk, rope_theta=cfg.rope_theta, use_rope=cfg.use_rope,
        block_size=cfg.attn_block_size, use_pallas=cfg.use_pallas_attention)
    x = x + h
    h = apply_norm(x, p["ln2"], cfg.norm)
    h, aux = _apply_ffn(p, h, cfg)
    return x + h, aux


def apply_block_decode(p, x1, cache, cfg: ArchConfig, *, ring: bool):
    h = apply_norm(x1, p["ln1"], cfg.norm)
    h, cache = attn.decode_attention(
        p["attn"], h, cache, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, kind=cfg.attention, window=cfg.window,
        chunk=cfg.chunk, rope_theta=cfg.rope_theta, use_rope=cfg.use_rope,
        ring=ring)
    x1 = x1 + h
    h = apply_norm(x1, p["ln2"], cfg.norm)
    h, _ = _apply_ffn(p, h, cfg)
    return x1 + h, cache


def apply_block_prefill(p, x, cache, cfg: ArchConfig, *, ring: bool):
    x = shard_residual(x)
    h = apply_norm(x, p["ln1"], cfg.norm)
    h, cache = attn.prefill_attention(
        p["attn"], h, cache=cache, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.head_dim, kind=cfg.attention,
        window=cfg.window, chunk=cfg.chunk, rope_theta=cfg.rope_theta,
        use_rope=cfg.use_rope, block_size=cfg.attn_block_size, ring=ring)
    x = x + h
    h = apply_norm(x, p["ln2"], cfg.norm)
    h, _ = _apply_ffn(p, h, cfg)
    return x + h, cache


# ---------------------------------------------------------------------- LM
class TransformerLM(NamedTuple):
    cfg: ArchConfig

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        kemb, klayers, khead = jax.random.split(key, 3)
        layer_keys = jax.random.split(klayers, cfg.n_layers)
        if cfg.scan_layers:
            layers = jax.vmap(lambda k: init_block(k, cfg))(layer_keys)
        else:
            layers = [init_block(k, cfg) for k in layer_keys]
        p = {
            "embed": embed_init(kemb, cfg.vocab_size, cfg.d_model, dtype),
            "layers": layers,
            "final_norm": init_norm(cfg.d_model, dtype,
                                    with_bias=cfg.norm == "layernorm"),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = embed_init(khead, cfg.vocab_size, cfg.d_model, dtype).T
        return p

    # -------------------------------------------------------------- forward
    def _embed(self, params, tokens, image_embeds=None):
        cfg = self.cfg
        x = params["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, x.dtype))
        if image_embeds is not None:
            x = jnp.concatenate([image_embeds.astype(x.dtype), x], axis=1)
        return x.astype(jnp.dtype(cfg.dtype))

    def _stack(self, params, x):
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        if cfg.scan_layers:
            def body(carry, p):
                x, aux = carry
                x, a = apply_block(p, x, cfg)
                return (x, aux + a), None

            body_fn = jax.checkpoint(body) if cfg.remat else body
            (x, aux_total), _ = jax.lax.scan(body_fn, (x, aux_total),
                                             params["layers"])
        else:
            for p in params["layers"]:
                x, a = apply_block(p, x, cfg)
                aux_total = aux_total + a
        return x, aux_total

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(x, params["final_norm"], cfg.norm)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return x @ head

    def forward(self, params, batch) -> jax.Array:
        """Full-sequence logits [B, S(+n_modal), V]."""
        x = self._embed(params, batch["tokens"], batch.get("image_embeds"))
        x, _ = self._stack(params, x)
        return self._logits(params, x)

    # ----------------------------------------------------------------- loss
    def loss(self, params, batch) -> jax.Array:
        """Next-token cross entropy (chunked; for VLM, text positions only)."""
        cfg = self.cfg
        from repro.models.losses import chunked_ce

        x = self._embed(params, batch["tokens"], batch.get("image_embeds"))
        x, aux = self._stack(params, x)
        x = apply_norm(x, params["final_norm"], cfg.norm)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        n_img = 0
        if batch.get("image_embeds") is not None:
            n_img = batch["image_embeds"].shape[1]
        ce = chunked_ce(x, head, batch["tokens"], prefix=n_img)
        return ce + MOE_AUX_COEF * aux

    # ---------------------------------------------------------------- serve
    def _ring(self) -> bool:
        # sliding windows and chunked-local both keep a bounded ring cache
        return self.cfg.attention in ("sliding", "chunked")

    def cache_capacity(self, seq_len: int) -> int:
        cfg = self.cfg
        if cfg.attention == "sliding":
            return min(cfg.window, seq_len)
        if cfg.attention == "chunked":
            return min(cfg.chunk, seq_len)
        return seq_len

    def init_caches(self, batch: int, seq_len: int):
        cfg = self.cfg
        cap = self.cache_capacity(seq_len)
        dtype = jnp.dtype(cfg.dtype)
        one = lambda: attn.init_cache(batch, cap, cfg.n_kv_heads, cfg.head_dim,
                                      dtype)
        if cfg.scan_layers:
            return jax.tree.map(
                lambda *ls: jnp.stack(ls), *[one() for _ in range(cfg.n_layers)])
        return [one() for _ in range(cfg.n_layers)]

    def prefill(self, params, batch, caches):
        """Run the prompt, returning (last-token logits, populated caches)."""
        cfg = self.cfg
        x = self._embed(params, batch["tokens"], batch.get("image_embeds"))
        ring = self._ring()
        if cfg.scan_layers:
            def body(x, inp):
                p, cache = inp
                x, cache = apply_block_prefill(p, x, cache, cfg, ring=ring)
                return x, cache

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, caches = jax.lax.scan(body_fn, x, (params["layers"], caches))
        else:
            new = []
            for p, cache in zip(params["layers"], caches):
                x, cache = apply_block_prefill(p, x, cache, cfg, ring=ring)
                new.append(cache)
            caches = new
        logits = self._logits(params, x[:, -1:, :])
        return logits, caches

    def decode_step(self, params, token, caches):
        """One decode step. token: [B, 1] int32 -> (logits [B,1,V], caches)."""
        cfg = self.cfg
        x = self._embed(params, token)
        ring = self._ring()
        if cfg.scan_layers:
            def body(x, inp):
                p, cache = inp
                x, cache = apply_block_decode(p, x, cache, cfg, ring=ring)
                return x, cache

            x, caches = jax.lax.scan(body, x, (params["layers"], caches))
        else:
            new = []
            for p, cache in zip(params["layers"], caches):
                x, cache = apply_block_decode(p, x, cache, cfg, ring=ring)
                new.append(cache)
            caches = new
        return self._logits(params, x), caches
