"""Memory-bounded language-model losses.

``chunked_ce`` never materializes the full [B, S, V] logits tensor: it scans
the sequence in chunks, projecting each chunk through the LM head and
computing its cross-entropy inside a remat'd scan body (backward recomputes
the chunk's logits). Per-chunk logits carry a vocab-sharded constraint
(sharding_ctx.shard_logits). At llama4-scout scale this replaces ~13 GB of
live f32 logits per device with ~0.4 GB per chunk.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.utils.sharding_ctx import shard_logits

CE_CHUNK = 512


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _guard(x, dtype_name: str):
    return x


def _guard_fwd(x, dtype_name):
    return x, None


def _guard_bwd(dtype_name, _, g):
    return (g.astype(dtype_name),)


_guard.defvjp(_guard_fwd, _guard_bwd)


def _grad_dtype_guard(x):
    """Identity forward; backward casts the cotangent to x's dtype.

    The CE loss upcasts to f32 at the very end of the graph, and JAX
    transpose rules propagate that f32 cotangent UNCHANGED through every
    residual add — so without this guard the whole backward pass (saved
    activation stacks, attention bwd, weight-grad accumulators) runs in
    f32: 2x the bytes of the bf16 forward. Verified on a minimal scan
    repro; see EXPERIMENTS.md §Dry-run.
    """
    return _guard(x, str(x.dtype))


def chunked_ce(x, head, tokens, *, prefix: int = 0, chunk: int = CE_CHUNK):
    """Mean next-token CE.

    x:      [B, S_total, d] final-norm hidden states
    head:   [d, V]
    tokens: [B, S_text] — x positions prefix..prefix+S_text-1 align with them
            (prefix = image-token count for VLMs, else 0).
    """
    B = x.shape[0]
    x = _grad_dtype_guard(x)
    preds = x[:, prefix:-1, :]              # predicts tokens[:, 1:]
    targets = tokens[:, 1:]
    n = targets.shape[1]
    c = min(chunk, n)
    pad = (-n) % c
    if pad:
        preds = jnp.pad(preds, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
    mask = (jnp.arange(n + pad) < n).astype(jnp.float32)
    nc = (n + pad) // c
    preds = preds.reshape(B, nc, c, -1).transpose(1, 0, 2, 3)
    targets = targets.reshape(B, nc, c).transpose(1, 0, 2)
    maskc = mask.reshape(nc, c)

    def body(acc, inp):
        x_c, t_c, m_c = inp                 # [B,c,d], [B,c], [c]
        logits = shard_logits((x_c @ head).astype(jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((logz - gold) * m_c[None, :]), None

    total, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                            (preds, targets, maskc))
    return total / (B * n)
