"""Attention: GQA/MQA/MHA with RoPE, qk-norm, full/sliding/chunked masks.

Three execution paths, chosen by context:

* ``attend_naive`` — materializes the [S, S] score matrix. Used for short
  sequences and as the oracle the blockwise path is tested against.
* ``attend_blockwise`` — flash-style streaming softmax over KV blocks
  (lax.scan, running max/denominator), so a 32k-token prefill never
  materializes a 32k x 32k matrix. Mask structure (causal / sliding window /
  chunked-local a la Llama-4 iRoPE) is applied per block from indices.
* ``attend_decode`` — single-query attention against a KV cache in grouped
  form (no KV-head repetition; queries reshaped to [B, 1, Hkv, G, Dh]), so
  the cache can be sequence-sharded over the `model` mesh axis and the
  softmax reductions lower to small all-reduces.

KV caches come in two flavors: full-length (``init_cache``) and ring-buffer
(``init_swa_cache``) whose size is just the attention window — the latter is
what makes `long_500k` decode O(window) for sliding-window architectures.
Keys are stored post-RoPE (absolute positions), so ring wraparound needs no
re-rotation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, rms_norm

NEG_INF = -1e30


# ------------------------------------------------------------------- params
def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, *, qk_norm: bool = False,
                   with_bias: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), dtype)
        p["k_norm"] = jnp.zeros((head_dim,), dtype)
    if with_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bo"] = jnp.zeros((d_model,), dtype)
    return p


def _project_qkv(params, x, n_heads, n_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv_heads, head_dim)
    v = v.reshape(B, S, n_kv_heads, head_dim)
    return q, k, v


def _qk_norm(params, q, k):
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    return q, k


# -------------------------------------------------------------------- masks
def mask_fn(kind: str, *, window: int = 0, chunk: int = 0):
    """Returns allowed(q_pos, k_pos) -> bool array, broadcasting over inputs."""

    def allowed(qp, kp):
        ok = kp <= qp  # causal
        if kind == "sliding":
            ok &= kp > qp - window
        elif kind == "chunked":
            ok &= (kp // chunk) == (qp // chunk)
        elif kind == "bidirectional":
            ok = jnp.ones_like(ok)
        return ok

    return allowed


# ------------------------------------------------------------- naive oracle
def attend_naive(q, k, v, allowed, *, q_positions=None, k_positions=None):
    """q [B,S,H,D], k/v [B,T,H,D] (heads already matched). Oracle path."""
    B, S, H, D = q.shape
    T = k.shape[1]
    qp = jnp.arange(S) if q_positions is None else q_positions
    kp = jnp.arange(T) if k_positions is None else k_positions
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / jnp.sqrt(D)
    mask = allowed(qp[:, None], kp[None, :])  # [S, T]
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", probs.astype(q.dtype), v)


# ------------------------------------------------------ blockwise (flash)
def attend_blockwise(q, k, v, allowed, *, block_size: int = 512):
    """Streaming-softmax attention, scanning KV blocks. Memory per step is
    O(S * block) instead of O(S^2). Matches attend_naive to float tolerance
    (property-tested in tests/test_attention.py).

    GQA is handled in GROUPED form — q reshaped to [B,S,Hkv,G,D], k/v kept
    at Hkv heads — so the KV stream is never materialized repeated to Hq
    heads (a 6x traffic/memory saving for 48q/8kv configs; EXPERIMENTS.md
    §Perf). Score/AV dots take bf16 inputs with f32 accumulation
    (preferred_element_type), so no f32 copy of K/V is ever created.
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    T = k.shape[1]
    nblk = -(-T // block_size)
    pad = nblk * block_size - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block_size, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block_size, Hkv, D).transpose(1, 0, 2, 3, 4)
    qg = q.reshape(B, S, Hkv, G, D)
    qpos = jnp.arange(S)

    def body(carry, inp):
        acc, m, denom = carry  # [B,S,Hkv,G,D] f32, [B,S,Hkv,G] x2
        blk_idx, kblk, vblk = inp
        kpos = blk_idx * block_size + jnp.arange(block_size)
        scores = jnp.einsum("bshgd,bthd->bshgt", qg, kblk,
                            preferred_element_type=jnp.float32)
        scores = scores / jnp.sqrt(D)
        ok = allowed(qpos[:, None], kpos[None, :]) & (kpos < T)[None, :]
        scores = jnp.where(ok[None, :, None, None, :], scores, NEG_INF)
        m_blk = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        # renormalize the running accumulator
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        acc = acc * alpha[..., None] + jnp.einsum(
            "bshgt,bthd->bshgd", p.astype(q.dtype), vblk,
            preferred_element_type=jnp.float32)
        denom = denom * alpha + jnp.sum(p, axis=-1)
        return (acc, m_new, denom), None

    acc0 = jnp.zeros((B, S, Hkv, G, D), jnp.float32)
    m0 = jnp.full((B, S, Hkv, G), NEG_INF, jnp.float32)
    d0 = jnp.zeros((B, S, Hkv, G), jnp.float32)
    # remat the per-block body: without it, scan AD saves the f32 score/prob
    # tensors of EVERY kv block as backward residuals (O(S * T) memory —
    # tens of GB at 4k x 4k training shapes); with it, backward recomputes
    # each block's scores from (q, kblk) for flash-attention-like memory.
    (acc, _, denom), _ = jax.lax.scan(
        jax.checkpoint(body), (acc0, m0, d0), (jnp.arange(nblk), kb, vb))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.reshape(B, S, Hq, D).astype(q.dtype)


# ------------------------------------------------------------ full attention
def attention(params, x, *, n_heads: int, n_kv_heads: int, head_dim: int,
              kind: str = "causal", window: int = 0, chunk: int = 0,
              rope_theta: float = 1e4, use_rope: bool = True,
              positions=None, block_size: int = 512,
              force_naive: bool = False, use_pallas: bool = False):
    """Training / prefill attention over a full sequence. Returns [B,S,d]."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    q, k = _qk_norm(params, q, k)
    if use_rope:
        pos = jnp.arange(S)[None, :] if positions is None else positions
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    allowed = mask_fn("causal" if kind == "full" else kind, window=window,
                      chunk=chunk)
    if use_pallas and not force_naive:
        from repro.kernels import ops as kops

        out = kops.flash_attention(
            q, k, v, kind=("causal" if kind == "full" else kind),
            window=window, chunk=chunk,
            q_blk=min(block_size, 256), kv_blk=min(block_size, 256))
        out = out.reshape(B, S, n_heads * head_dim) @ params["wo"]
        if "bo" in params:
            out = out + params["bo"]
        return out
    if force_naive or S <= 1024:
        # naive oracle path: repeat KV heads up to the query-head count
        groups = n_heads // n_kv_heads
        if groups > 1:
            k = jnp.repeat(k, groups, axis=2)
            v = jnp.repeat(v, groups, axis=2)
        out = attend_naive(q, k, v, allowed)
    else:
        # blockwise path handles GQA in grouped form (no KV repeat)
        out = attend_blockwise(q, k, v, allowed, block_size=block_size)
    out = out.reshape(B, S, n_heads * head_dim) @ params["wo"]
    if "bo" in params:
        out = out + params["bo"]
    return out


# ----------------------------------------------------------------- KV cache
class KVCache(NamedTuple):
    k: jax.Array          # [B, C, Hkv, D] (C = max len, or window for SWA)
    v: jax.Array          # [B, C, Hkv, D]
    pos: jax.Array        # [C] absolute position stored in each slot (-1 empty)
    length: jax.Array     # scalar: tokens seen so far


def init_cache(batch: int, capacity: int, n_kv_heads: int, head_dim: int,
               dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, capacity, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, capacity, n_kv_heads, head_dim), dtype),
        pos=jnp.full((capacity,), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32),
    )


def prefill_into_cache(cache: KVCache, k, v, *, ring: bool = False) -> KVCache:
    """Write a prefix [B, S, Hkv, D] (post-RoPE) into the cache.

    Non-ring: slots [0, S). Ring (cap < S possible): token at absolute
    position p lands in slot p % cap, so subsequent ring appends
    (slot = t % cap) always evict exactly the expired entry."""
    S = k.shape[1]
    cap = cache.k.shape[1]
    if ring and S > cap:
        k, v = k[:, -cap:], v[:, -cap:]
        kept_pos = jnp.arange(S - cap, S, dtype=jnp.int32)
        shift = S % cap  # kept[i] has pos S-cap+i -> slot (i + S%cap) % cap
        new_k = jnp.roll(k, shift, axis=1)
        new_v = jnp.roll(v, shift, axis=1)
        pos = jnp.roll(kept_pos, shift)
        return cache._replace(k=new_k, v=new_v, pos=pos,
                              length=jnp.asarray(S, jnp.int32))
    new_k = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0))
    pos = cache.pos.at[:S].set(jnp.arange(S, dtype=jnp.int32))
    return cache._replace(k=new_k, v=new_v, pos=pos,
                          length=jnp.asarray(S, jnp.int32))


def append_to_cache(cache: KVCache, k1, v1, *, ring: bool = False) -> KVCache:
    """Append one token's K/V [B, 1, Hkv, D]; ring caches wrap."""
    cap = cache.k.shape[1]
    t = cache.length
    slot = ((t % cap) if ring else jnp.minimum(t, cap - 1)).astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    new_k = jax.lax.dynamic_update_slice(cache.k, k1, (zero, slot, zero, zero))
    new_v = jax.lax.dynamic_update_slice(cache.v, v1, (zero, slot, zero, zero))
    pos = jax.lax.dynamic_update_slice(cache.pos, t[None].astype(jnp.int32), (slot,))
    return cache._replace(k=new_k, v=new_v, pos=pos, length=t + 1)


def attend_decode(q1, cache: KVCache, *, window: int = 0, chunk: int = 0,
                  kind: str = "full"):
    """One-token attention vs cache, grouped-query form (no KV repeat).

    q1: [B, Hq, D]. Returns [B, Hq, D]. The cache slot positions (absolute)
    drive masking, so full, sliding-window(ring) and chunked all share this
    path. Softmax reductions are over the (possibly `model`-sharded) cache
    slot axis.
    """
    B, Hq, D = q1.shape
    Hkv = cache.k.shape[2]
    G = Hq // Hkv
    qg = q1.reshape(B, Hkv, G, D)
    t = cache.length - 1  # absolute position of the query token
    scores = jnp.einsum("bhgd,bshd->bhgs", qg.astype(jnp.float32),
                        cache.k.astype(jnp.float32)) / jnp.sqrt(D)
    kp = cache.pos
    ok = (kp >= 0) & (kp <= t)
    if kind == "sliding":
        ok &= kp > t - window
    elif kind == "chunked":
        ok &= (kp // chunk) == (t // chunk)
    scores = jnp.where(ok[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs,
                     cache.v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(cache.k.dtype)


def decode_attention(params, x1, cache: KVCache, *, n_heads: int,
                     n_kv_heads: int, head_dim: int, kind: str = "full",
                     window: int = 0, chunk: int = 0, rope_theta: float = 1e4,
                     use_rope: bool = True, ring: bool = False):
    """Full decode step for one layer: project, rope at absolute position,
    append to cache, attend. x1: [B, 1, d]. Returns ([B, 1, d], new cache)."""
    B = x1.shape[0]
    q, k, v = _project_qkv(params, x1, n_heads, n_kv_heads, head_dim)
    q, k = _qk_norm(params, q, k)
    if use_rope:
        pos = cache.length[None, None].astype(jnp.int32)  # [1,1]
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    cache = append_to_cache(cache, k, v, ring=ring)
    out = attend_decode(q[:, 0], cache, window=window, chunk=chunk, kind=kind)
    out = out.reshape(B, 1, n_heads * head_dim) @ params["wo"]
    if "bo" in params:
        out = out + params["bo"]
    return out, cache


def prefill_attention(params, x, *, n_heads: int, n_kv_heads: int,
                      head_dim: int, cache: KVCache, kind: str = "full",
                      window: int = 0, chunk: int = 0, rope_theta: float = 1e4,
                      use_rope: bool = True, block_size: int = 512,
                      ring: bool = False):
    """Prefill: full-sequence attention AND populate the cache (post-RoPE)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    q, k = _qk_norm(params, q, k)
    if use_rope:
        pos = jnp.arange(S)[None, :]
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    cache = prefill_into_cache(cache, k, v, ring=ring)
    allowed = mask_fn("causal" if kind == "full" else kind, window=window,
                      chunk=chunk)
    if S <= 1024:
        groups = n_heads // n_kv_heads
        if groups > 1:
            k = jnp.repeat(k, groups, axis=2)
            v = jnp.repeat(v, groups, axis=2)
        out = attend_naive(q, k, v, allowed)
    else:
        out = attend_blockwise(q, k, v, allowed, block_size=block_size)
    out = out.reshape(B, S, n_heads * head_dim) @ params["wo"]
    if "bo" in params:
        out = out + params["bo"]
    return out, cache
