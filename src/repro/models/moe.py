"""Mixture-of-Experts block: top-k router + sort-based capacity dispatch.

Dispatch is *sort-based* (argsort by expert id, scatter into [E, C, d]
buffers, batched expert matmuls, gather back) rather than one-hot-einsum
based: the one-hot formulation costs O(T * E*C * d) FLOPs in dispatch alone,
which at 4k-sequence training shapes would exceed the expert FLOPs
themselves and corrupt the roofline. Here dispatch/gather are memory ops and
compute is exactly the active-expert matmuls: 3 * T * k * d * d_ff * 2 FLOPs
(gate/up/down with GLU), matching the 6*N_active*D MoE FLOPs model.

Expert weights are stacked [E, d, f]; on the production mesh E is sharded
over `model` when divisible (expert parallelism — scatter/gather lower to
all-to-all-style movement), otherwise the capacity axis is sharded.

Load-balance aux loss is the standard Switch-style mean(fraction * prob)
term, returned so the trainer can weight it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(key, d: int, d_ff: int, n_experts: int, dtype,
             *, shared_expert: bool, activation: str):
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, n_experts, dtype, scale=0.02),
        "gate": dense_init(ks[1], d, d_ff, dtype)[None].repeat(n_experts, 0)
        if activation in ("swiglu", "geglu") else None,
        "up": dense_init(ks[2], d, d_ff, dtype)[None].repeat(n_experts, 0),
        "down": dense_init(ks[3], d_ff, d, dtype)[None].repeat(n_experts, 0),
    }
    if p["gate"] is None:
        del p["gate"]
    if shared_expert:
        from repro.models.layers import init_mlp

        p["shared"] = init_mlp(ks[4], d, d_ff, dtype, activation=activation)
    return p


def apply_moe(params, x, *, n_experts: int, k: int, capacity_factor: float,
              activation: str, shared_expert: bool):
    """x: [B, S, d] -> ([B, S, d], aux_loss scalar).

    When the ambient sharding context requests token-sharded dispatch
    (granite's 40 experts don't divide a 16-way model axis, so the plain
    scatter makes GSPMD replicate + all-reduce the [E, C, d] buffers —
    ~116 GB/layer at prefill_32k), the token stream is reshaped so its
    SHARDED dimension (batch for serving, sequence for training) becomes a
    leading vmapped axis: every shard routes into its own local capacity
    buffer and no cross-device scatter traffic exists. Per-shard capacity
    (the standard per-device-capacity MoE semantics) replaces global
    capacity; tests cover equivalence in the drop-free regime.
    """
    from repro.utils.sharding_ctx import moe_shards

    B, S, d = x.shape
    shards = moe_shards()
    if shards is not None:
        nb, ns, spec = shards["nb"], shards["ns"], shards.get("spec")
        grid_axes = shards.get("axes")  # mesh axes of the token grid
        kw = dict(n_experts=n_experts, k=k, capacity_factor=capacity_factor,
                  activation=activation)
        ok = (B % nb == 0 and B >= nb and S % ns == 0 and S >= ns)
        if ok:
            n = nb * ns
            xs = (x.reshape(nb, B // nb, ns, S // ns, d)
                  .transpose(0, 2, 1, 3, 4)
                  .reshape(n, (B // nb) * (S // ns), d))
            if spec is not None:
                xs = jax.lax.with_sharding_constraint(xs, spec)
            # Gather-at-use: force-replicate the (small) expert weights for
            # this layer's dispatch so the per-shard expert matmul is fully
            # local. Without this GSPMD resolves the token-grid x f-shard
            # layout conflict by all-gathering the [grid, E, C, d] buffers
            # (64 GB/layer at granite prefill_32k) instead of the 0.2 GB
            # weights. Weights at rest stay sharded.
            import jax.sharding as jsh

            p_rep = dict(params)
            for w in ("gate", "up", "down"):
                if w in params:
                    p_rep[w] = jax.lax.with_sharding_constraint(
                        params[w], jsh.PartitionSpec(*(None,) * params[w].ndim))
            # spmd_axis_name pins the vmapped shard dim to the mesh axes of
            # the token grid, making every constraint inside _moe_tokens
            # (incl. the scatter outputs) shard-local by construction.
            out, aux = jax.vmap(
                lambda t: _moe_tokens(p_rep, t, shard_local=True, **kw),
                spmd_axis_name=grid_axes,
            )(xs)
            out = (out.reshape(nb, ns, B // nb, S // ns, d)
                   .transpose(0, 2, 1, 3, 4).reshape(B, S, d))
            if shared_expert and "shared" in params:
                from repro.models.layers import apply_mlp

                out = out + apply_mlp(x, params["shared"], activation=activation)
            return out, jnp.mean(aux)

    out, aux = _moe_tokens(params, x.reshape(B * S, d), n_experts=n_experts,
                           k=k, capacity_factor=capacity_factor,
                           activation=activation)
    if shared_expert and "shared" in params:
        from repro.models.layers import apply_mlp

        out = out + apply_mlp(x.reshape(B * S, d), params["shared"],
                              activation=activation)
    return out.reshape(B, S, d), aux


def _moe_tokens(params, xt, *, n_experts: int, k: int, capacity_factor: float,
                activation: str, shard_local: bool = False):
    """Core sort-based dispatch over a flat token stream xt: [T, d].
    shard_local=True (under the spmd_axis_name'd vmap) constrains the
    dispatch buffers to be unsharded WITHIN the shard."""
    T, d = xt.shape

    def local(a):
        if not shard_local:
            return a
        from jax.sharding import PartitionSpec

        return jax.lax.with_sharding_constraint(
            a, PartitionSpec(*(None,) * a.ndim))

    logits = xt @ params["router"]                       # [T, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topw, tope = jax.lax.top_k(probs, k)                 # [T, k]
    topw = topw / jnp.maximum(jnp.sum(topw, -1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ------------------------------------------------
    flat_e = tope.reshape(-1)                            # [T*k] expert ids
    flat_t = jnp.repeat(jnp.arange(T), k)                # [T*k] token ids
    flat_w = topw.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # slot within the expert's buffer = rank within its sorted run
    run_start = jnp.searchsorted(se, se, side="left")
    slot = jnp.arange(T * k) - run_start
    C = max(1, int(capacity_factor * T * k / n_experts))
    keep = slot < C
    slot = jnp.where(keep, slot, 0)

    buf = jnp.zeros((n_experts, C, d), xt.dtype)
    keep_x = local(jnp.where(keep[:, None], xt[st], jnp.zeros((), xt.dtype)))
    buf = local(buf.at[se, slot].add(keep_x.astype(xt.dtype)))

    # ---- expert computation (the only FLOPs) --------------------------------
    if "gate" in params:
        act = jax.nn.silu if activation == "swiglu" else (
            lambda a: jax.nn.gelu(a, approximate=True))
        h = act(jnp.einsum("ecd,edf->ecf", buf, params["gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", buf, params["up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, params["up"]),
                        approximate=True)
    y = jnp.einsum("ecf,efd->ecd", h, params["down"])    # [E, C, d]

    # ---- gather back + weighted combine ------------------------------------
    w_keep = jnp.where(keep, sw, 0.0).astype(xt.dtype)
    out_slots = local(local(y[se, slot]) * w_keep[:, None])
    out = local(jnp.zeros((T, d), xt.dtype).at[st].add(out_slots.astype(xt.dtype)))

    # ---- Switch-style load-balance loss -------------------------------------
    frac = jnp.mean(jax.nn.one_hot(tope[:, 0], n_experts, dtype=jnp.float32), 0)
    prob = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(frac * prob)

    return out, aux
