"""Mamba2 — SSD (state-space duality) blocks [arXiv:2405.21060].

The SSD computation is implemented twice:

* ``ssd_naive`` — the literal per-token recurrence
  ``h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t``, ``y_t = C_t h_t + D x_t``.
  O(S) sequential; the correctness oracle.
* ``ssd_chunked`` — the paper's chunked dual form: quadratic attention-like
  computation *within* chunks (MXU-friendly matmuls) + a ``lax.scan``
  recurrence *across* chunk states. This is the TPU adaptation of the SSD
  insight: the intra-chunk term is batched [Lc x Lc] matmuls that map onto
  the systolic array, and only the O(S/Lc) chunk-state recurrence is
  sequential.

Both are property-tested against each other across shapes/dtypes.
Decode is O(1) in sequence length: the carried state is [B, H, P, N] — this
is what makes `long_500k` a supported shape for the ssm/hybrid families.

Sharding: the head axis H is sharded over `model` when divisible, else the
head-dim P is (decided in launch/sharding.py); B/C projections are small and
replicated. B/C/x share a causal depthwise conv (kernel 4), as in the
reference implementation.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init, rms_norm

DEFAULT_CHUNK = 128


def ssm_dims(cfg: ArchConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    return d_inner, n_heads, cfg.ssm_headdim, cfg.ssm_state


# ------------------------------------------------------------------- params
def init_mamba_block(key, cfg: ArchConfig):
    d_inner, H, P, N = ssm_dims(cfg)
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 8)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "norm": jnp.zeros((cfg.d_model,), dtype),
        "wz": dense_init(ks[0], cfg.d_model, d_inner, dtype),
        "wx": dense_init(ks[1], cfg.d_model, d_inner, dtype),
        "wB": dense_init(ks[2], cfg.d_model, N, dtype),
        "wC": dense_init(ks[3], cfg.d_model, N, dtype),
        "wdt": dense_init(ks[4], cfg.d_model, H, dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "conv_w": (jax.random.normal(ks[5], (conv_ch, cfg.ssm_conv)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "out_norm": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[6], d_inner, cfg.d_model, dtype),
    }


# --------------------------------------------------------------------- conv
def causal_conv(x, w, b):
    """Depthwise causal conv. x: [B, S, C]; w: [C, K]."""
    K = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].transpose(2, 1, 0),  # [K, 1, C] -> OIW? use dim nums
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return out + b


def conv_step(x1, conv_state, w, b):
    """One-token conv using the carried last K-1 inputs.
    x1: [B, C]; conv_state: [B, K-1, C] -> (out [B, C], new state)."""
    window = jnp.concatenate([conv_state, x1[:, None, :]], axis=1)  # [B,K,C]
    out = jnp.einsum("bkc,ck->bc", window, w) + b
    return out, window[:, 1:]


# ---------------------------------------------------------------------- SSD
def ssd_naive(x, dt, A, Bm, Cm, *, h0=None):
    """Literal recurrence. x: [B,S,H,P], dt: [B,S,H], A: [H],
    Bm/Cm: [B,S,N]. Returns (y [B,S,H,P], h_final [B,H,P,N])."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    Af = jnp.asarray(A, jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(dtt * Af)[..., None, None]           # [B,H,1,1]
        inject = (dtt[..., None] * xt)[..., None] * bt[:, None, None, :]
        h = h * decay + inject                               # [B,H,P,N]
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int = DEFAULT_CHUNK, h0=None,
                use_kernel: bool = False):
    """Chunked dual form. Same signature/returns as ssd_naive.
    ``use_kernel`` computes the intra-chunk term with the Pallas kernel
    (kernels/ssd_intra.py) instead of the XLA einsums."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Lc = min(chunk, S)
    pad = (-S) % Lc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    Nc = Sp // Lc
    xf = x.reshape(Bsz, Nc, Lc, H, P).astype(jnp.float32)
    dtf = dt.reshape(Bsz, Nc, Lc, H).astype(jnp.float32)
    Bf = Bm.reshape(Bsz, Nc, Lc, N).astype(jnp.float32)
    Cf = Cm.reshape(Bsz, Nc, Lc, N).astype(jnp.float32)

    a = dtf * jnp.asarray(A, jnp.float32)         # [B,Nc,Lc,H] log-decay increments
    a_cs = jnp.cumsum(a, axis=2)                  # inclusive cumsum within chunk

    # ---- intra-chunk (quadratic, attention-like) ---------------------------
    # y_intra[i] = sum_{j<=i} (C_i . B_j) exp(a_cs[i] - a_cs[j]) dt[j] x[j]
    if use_kernel:
        from repro.kernels import ops as kops

        y_intra = kops.ssd_intra(xf, dtf, a_cs, Bf, Cf).astype(jnp.float32)
    else:
        cb = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)            # [B,Nc,Lc,Lc]
        seg = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]  # [B,Nc,i,j,H]
        causal = jnp.tril(jnp.ones((Lc, Lc), bool))[None, None, :, :, None]
        # mask BEFORE exp: acausal entries have seg > 0 and would overflow,
        # and where(mask, exp(seg), 0) still propagates 0*inf=NaN in the VJP.
        seg = jnp.where(causal, seg, -jnp.inf)
        w = cb[..., None] * jnp.exp(seg)                       # [B,Nc,i,j,H]
        y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w, dtf, xf)

    # ---- chunk states -------------------------------------------------------
    # state_c = sum_j B_j^T (dt_j x_j) exp(a_end - a_cs[j])   [B,Nc,H,P,N]
    decay_to_end = jnp.exp(a_cs[:, :, -1:, :] - a_cs)          # [B,Nc,Lc,H]
    states = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", dtf * decay_to_end, xf, Bf)

    # ---- inter-chunk recurrence over chunk states ---------------------------
    chunk_decay = jnp.exp(jnp.sum(a, axis=2))                  # [B,Nc,H]
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(h, inp):
        st, dc = inp                                           # [B,H,P,N], [B,H]
        h_out = h                                              # state BEFORE chunk
        h = h * dc[..., None, None] + st
        return h, h_out

    h_final, h_prevs = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                 # [B,Nc,H,P,N]

    # y_inter[i] = C_i . (exp(a_cs[i]) h_prev)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", Cf, jnp.exp(a_cs), h_prevs)

    y = (y_intra + y_inter).reshape(Bsz, Sp, H, P)[:, :S]
    return y.astype(x.dtype), h_final


# ------------------------------------------------------------------- block
class SSMCache(NamedTuple):
    conv: jax.Array    # [B, K-1, conv_ch]
    state: jax.Array   # [B, H, P, N] (f32)
    length: jax.Array


def init_ssm_cache(batch: int, cfg: ArchConfig, dtype) -> SSMCache:
    d_inner, H, P, N = ssm_dims(cfg)
    conv_ch = d_inner + 2 * N
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        state=jnp.zeros((batch, H, P, N), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )


def _ssm_inputs(p, u, cfg: ArchConfig):
    d_inner, H, P, N = ssm_dims(cfg)
    z = u @ p["wz"]
    xBC = jnp.concatenate([u @ p["wx"], u @ p["wB"], u @ p["wC"]], axis=-1)
    return z, xBC, (d_inner, H, P, N)


def apply_mamba_block(p, u, cfg: ArchConfig, *, naive: bool = False):
    """Full-sequence mamba2 block. u: [B, S, d] -> [B, S, d]."""
    from repro.utils.sharding_ctx import shard_residual

    u = shard_residual(u)
    B_, S, _ = u.shape
    h = rms_norm(u, p["norm"])
    z, xBC, (d_inner, H, P, N) = _ssm_inputs(p, h, cfg)
    xBC = jax.nn.silu(causal_conv(xBC, p["conv_w"], p["conv_b"]))
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(B_, S, H, P)
    dt = jax.nn.softplus((h @ p["wdt"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    if naive:
        y, _ = ssd_naive(x, dt, A, Bm, Cm)
    else:
        y, _ = ssd_chunked(x, dt, A, Bm, Cm, use_kernel=cfg.use_pallas_ssd)
    y = y + p["D"][None, None, :, None] * x
    y = y.reshape(B_, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    return u + y @ p["out_proj"]


def apply_mamba_block_prefill(p, u, cache: SSMCache, cfg: ArchConfig):
    """Full-sequence forward that also returns the carried SSM/conv state."""
    B_, S, _ = u.shape
    h = rms_norm(u, p["norm"])
    z, xBC, (d_inner, H, P, N) = _ssm_inputs(p, h, cfg)
    conv_tail = xBC[:, -(cfg.ssm_conv - 1):, :].astype(cache.conv.dtype)
    if S < cfg.ssm_conv - 1:  # degenerate tiny-seq case
        conv_tail = jnp.concatenate(
            [cache.conv[:, S:], xBC.astype(cache.conv.dtype)], axis=1)
    xBC = jax.nn.silu(causal_conv(xBC, p["conv_w"], p["conv_b"]))
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(B_, S, H, P)
    dt = jax.nn.softplus((h @ p["wdt"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h_final = ssd_chunked(x, dt, A, Bm, Cm, h0=cache.state)
    y = y + p["D"][None, None, :, None] * x
    y = y.reshape(B_, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    out = u + y @ p["out_proj"]
    new_cache = SSMCache(conv=conv_tail, state=h_final,
                         length=cache.length + S)
    return out, new_cache


def apply_mamba_block_decode(p, u1, cache: SSMCache, cfg: ArchConfig):
    """One-token step. u1: [B, 1, d]."""
    B_ = u1.shape[0]
    h = rms_norm(u1[:, 0], p["norm"])
    z = h @ p["wz"]
    xBC1 = jnp.concatenate([h @ p["wx"], h @ p["wB"], h @ p["wC"]], axis=-1)
    d_inner, H, P, N = ssm_dims(cfg)
    xBC, conv_state = conv_step(xBC1, cache.conv, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + N], axis=-1)
    x = x.reshape(B_, H, P)
    dt = jax.nn.softplus(h @ p["wdt"] + p["dt_bias"])      # [B, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32) * A)            # [B, H]
    inject = (dt[..., None] * x)[..., None] * Bm[:, None, None, :]
    state = cache.state * decay[..., None, None] + inject
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = (y + p["D"][None, :, None] * x).reshape(B_, d_inner).astype(u1.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"])
    out = u1[:, 0] + y @ p["out_proj"]
    return out[:, None, :], SSMCache(conv=conv_state, state=state,
                                     length=cache.length + 1)
