"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax.numpy as jnp


def fedcet_v(x, g, d, alpha: float):
    """The FedCET local-step triad: v = x - alpha*g - alpha*d.

    (== the paper's 2x(t) - x(t-1) - a grad(t) + a grad(t-1), via Lemma 1.)
    """
    return x - alpha * g - alpha * d


def fedcet_comm(d, m, m_bar, c: float, alpha: float, v=None):
    """The FedCET aggregation step, fused:
    d' = d + c (m - m_bar);  x' = v - c*alpha*(m - m_bar).

    ``m`` is the client's own WIRE message (post-compression) and ``v``
    the exact local vector the x-update starts from (``mctx``); without
    compression the two coincide, which is the ``v=None`` default."""
    if v is None:
        v = m
    delta = m - m_bar
    return d + c * delta, v - (c * alpha) * delta


def fedcet_round_tail(v, h, d, u, scale, w, den, *, c: float, alpha: float,
                      beta: float, bits: int):
    """The whole shift:q8 -> reduce -> FedCET pair round tail, one pass.

    The composed per-leaf seam (Shifted(StochasticQuant(bits)) transform +
    mean + ``server_aggregate``) computes, with ``h`` the shift memory and
    ``q`` the dithered fixed-point code of the residual ``v - h``::

        q     = clip(floor((v - h)/scale + u), -levels, levels)
        recon = h + q*scale                    # the wire message
        m_bar = sum_c(recon * w) / den         # (masked) client mean
        d'    = d + c*(recon - m_bar)
        x'    = v - c*alpha*(recon - m_bar)
        h'    = h + beta*q*scale               # the DIANA shift step

    Shapes: ``v``/``h``/``d`` are ``[clients, rows, lanes]``; ``u`` is the
    client-shared dither ``[rows, lanes]``; ``scale`` the per-leaf quant
    step broadcast to rows ``[rows, 1]``; ``w`` the client weights
    ``[clients, 1, 1]`` (ones, or the participation mask) and ``den`` the
    scalar weight sum (the masked-mean denominator). Expressions match
    compressors.StochasticQuant / Shifted and engine.masked_client_mean
    term for term, so the fused tail is bitwise-equivalent to the
    per-leaf transform stack. Returns ``(d', x', h')``."""
    levels = 2 ** (bits - 1) - 1
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.floor((v - h) * inv + u), -levels, levels)
    qs = q * scale
    recon = h + qs
    m_bar = jnp.sum(recon * w, axis=0, keepdims=True) / den
    delta = recon - m_bar
    return d + c * delta, v - (c * alpha) * delta, h + beta * qs


def ssd_intra(x, dt, a_cs, Bm, Cm):
    """SSD intra-chunk oracle. Shapes as kernels/ssd_intra.py:ssd_intra."""
    import jax

    cb = jnp.einsum("bcin,bcjn->bcij", Cm.astype(jnp.float32),
                    Bm.astype(jnp.float32))
    seg = (a_cs.astype(jnp.float32)[:, :, :, None, :]
           - a_cs.astype(jnp.float32)[:, :, None, :, :])   # [B,Nc,i,j,H]
    lc = x.shape[2]
    causal = jnp.tril(jnp.ones((lc, lc), bool))[None, None, :, :, None]
    seg = jnp.where(causal, seg, -jnp.inf)
    w = cb[..., None] * jnp.exp(seg)
    y = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", w, dt.astype(jnp.float32),
                   x.astype(jnp.float32))
    return y.astype(x.dtype)


def stochastic_quantize(a, u, scale, bits: int):
    """Dithered fixed-point quantize round-trip (kernels/quantize.py oracle).

    ``u ~ U[0,1)`` dither, ``scale`` = per-leaf step (max|a| / levels):
    ``out = scale * clip(floor(a/scale + u), -levels, levels)``; unbiased
    because ``E_u[floor(v + u)] = v``. ``scale == 0`` maps everything to 0.
    """
    levels = 2 ** (bits - 1) - 1
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    q = jnp.clip(jnp.floor(a * inv + u), -levels, levels)
    return q * scale


def segment_reduce(vals, slots: int):
    """Fixed-slot segment sum (kernels/gossip_reduce.py oracle): ``vals``
    is ``[n * slots, d]`` — node i's weighted neighbor contributions in
    rows ``i*slots .. (i+1)*slots`` (pad slots are zero) — reduced per
    node via ``jax.ops.segment_sum`` over ids ``[0,..0, 1,..1, ...]``."""
    import jax

    n = vals.shape[0] // slots
    seg = jnp.repeat(jnp.arange(n), slots)
    return jax.ops.segment_sum(vals, seg, num_segments=n)


def client_sketch(x, *, bins: int, lo: float, hi: float):
    """Per-client norm + log-histogram oracle (kernels/telemetry_reduce.py).

    ``x`` is the flattened client store ``[clients, D]`` (zero pad columns
    contribute 0). Returns ``(sq_norms [clients], hist [bins] int32)``
    where ``hist`` counts ``||x_i||`` into ``bins`` log10-uniform bins
    over ``[10^lo, 10^hi)`` — the binning formula is shared verbatim with
    ``core/telemetry.py:log_histogram`` (zeros land in bin 0, overflow
    clips into the edge bins)."""
    sq = jnp.sum(x * x, axis=1)
    v = jnp.sqrt(sq)
    logs = jnp.where(v > 0, jnp.log10(v), lo)
    idx = jnp.clip(jnp.floor((logs - lo) * (bins / (hi - lo))),
                   0, bins - 1).astype(jnp.int32)
    hist = jnp.zeros((bins,), jnp.int32).at[idx].add(1)
    return sq, hist


def topk_mask(x, k: int):
    """Magnitude top-k (per flattened leaf): keep the k largest |x|."""
    flat = x.reshape(-1)
    thresh = jnp.sort(jnp.abs(flat))[-k]
    return jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(x.shape)
