"""Pallas TPU kernel for the gossip fixed-slot segment reduce.

The sparse neighbor-exchange lowering (repro/core/topology.py: ``Mixing``
with ``lowering="sparse"``) turns the dense N x N gossip contraction into
a gather plus a PADDED segment reduce: every node owns exactly
``S = max_degree + 1`` weighted neighbor contributions (pad slots carry
weight 0), so the reduce is a fixed-stride sum — ``segment_sum`` whose
segments all have equal length S. That regularity is what makes it a
clean Pallas kernel: grid over (node blocks, lane blocks), each step
loads one ``(nb * S, db)`` tile of contributions, views it as
``(nb, S, db)`` and sums the slot axis — one HBM visit per edge
contribution (the memory-roofline floor for the reduce), no scatter, no
atomics, no segment-boundary bookkeeping.

Like the quantize kernel, all randomness/weighting happens OUTSIDE the
kernel (the caller gathers and weights the contributions), keeping the
kernel a pure function that is bit-comparable to its
``ref.py:segment_reduce`` oracle (``jax.ops.segment_sum`` over the same
fixed-slot ids) in interpret mode on CPU — tests/test_gossip_kernel.py.
On TPU it lowers through Mosaic next to the fedcet_update kernels.

Layout: ops.py pads the lane (coordinate) axis to a multiple of the
block width and the node count to a multiple of the node block, so every
BlockSpec tile is rectangular; zero-padded rows reduce to zero rows that
the wrapper slices off. The slot axis is NEVER padded — it is static
(the graph's max degree + 1), set by the neighbor tables.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

NODE_BLOCK = 8
LANE_BLOCK = 1024


def _seg_reduce_kernel(v_ref, o_ref, *, slots: int):
    v = v_ref[...]
    nb = v.shape[0] // slots
    o_ref[...] = jnp.sum(v.reshape(nb, slots, v.shape[1]), axis=1)


def segment_reduce_2d(vals, *, slots: int, node_block: int = NODE_BLOCK,
                      interpret: bool = True):
    """Fixed-slot segment sum: ``vals`` is ``[n * slots, d]`` (row
    ``i * slots + s`` = node i's slot-s contribution; pre-padded by
    ops.py so ``n % node_block == 0`` and ``d % lane block == 0``);
    returns the per-node sums ``[n, d]``."""
    rows, d = vals.shape
    assert rows % slots == 0, (rows, slots)
    n = rows // slots
    nb = min(node_block, n)
    db = min(LANE_BLOCK, d)
    grid = (pl.cdiv(n, nb), pl.cdiv(d, db))
    return pl.pallas_call(
        functools.partial(_seg_reduce_kernel, slots=slots),
        grid=grid,
        in_specs=[pl.BlockSpec((nb * slots, db), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((nb, db), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, d), vals.dtype),
        interpret=interpret,
    )(vals)
