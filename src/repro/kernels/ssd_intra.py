"""Pallas TPU kernel: Mamba2 SSD intra-chunk (quadratic) term.

The SSD dual form's hot spot is the per-chunk attention-like computation

    y[i] = sum_{j<=i} (C_i . B_j) * exp(a_cs[i] - a_cs[j]) * dt[j] * x[j]

(arXiv:2405.21060, "quadratic mode"). Per (batch, chunk, head) tile this is
two MXU matmuls — scores = C @ B^T [Lc, Lc] and y = (scores * decay * dt)
@ x [Lc, P] — plus a VPU decay mask. Grid = (B, n_chunks, H); block shapes
are the natural (Lc=128, N=128/64, P=64) tiles, all lane/sublane aligned.

VMEM per step: C,B [Lc,N] + x,y [Lc,P] + scores [Lc,Lc] f32 ~ 0.2 MiB —
far under budget, so the kernel is bandwidth-friendly and leaves room for a
future double-buffered multi-head variant.

Validated against the pure-jnp oracle (kernels/ref.py:ssd_intra) in
interpret mode; the inter-chunk recurrence stays in the XLA scan
(models/mamba2.ssd_chunked), which can consume this kernel via
``use_kernel=True`` on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(x_ref, dt_ref, acs_ref, b_ref, c_ref, o_ref):
    # blocks: x [Lc, P], dt [Lc], a_cs [Lc], B/C [Lc, N], o [Lc, P]
    cb = jnp.dot(c_ref[...].astype(jnp.float32),
                 b_ref[...].astype(jnp.float32).T)          # [Lc, Lc] MXU
    acs = acs_ref[...].astype(jnp.float32)                  # [Lc]
    seg = acs[:, None] - acs[None, :]                       # [Lc(i), Lc(j)]
    lc = seg.shape[0]
    causal = (jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (lc, lc), 1))
    seg = jnp.where(causal, seg, -jnp.inf)
    w = cb * jnp.exp(seg) * dt_ref[...].astype(jnp.float32)[None, :]
    y = jnp.dot(w, x_ref[...].astype(jnp.float32))          # [Lc, P] MXU
    o_ref[...] = y.astype(o_ref.dtype)


def ssd_intra(x, dt, a_cs, Bm, Cm, *, interpret: bool = True):
    """x: [B, Nc, Lc, H, P]; dt/a_cs: [B, Nc, Lc, H]; Bm/Cm: [B, Nc, Lc, N].
    Returns y_intra [B, Nc, Lc, H, P] (f32 accumulated, cast to x.dtype)."""
    Bsz, Nc, Lc, H, P = x.shape
    N = Bm.shape[-1]
    grid = (Bsz, Nc, H)
    return pl.pallas_call(
        _ssd_intra_kernel,
        grid=grid,
        in_specs=[
            # None block dims are squeezed away inside the kernel refs.
            pl.BlockSpec((None, None, Lc, None, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((None, None, Lc, None), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((None, None, Lc, None), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((None, None, Lc, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((None, None, Lc, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, Lc, None, P),
                               lambda b, c, h: (b, c, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, dt, a_cs, Bm, Cm)
