"""jit'd public wrappers around the Pallas kernels.

Handles arbitrary leaf shapes: flatten -> pad to a whole number of
(rows x 1024) lanes -> kernel -> unpad/reshape. On non-TPU backends the
kernels run in interpret mode (Python emulation of the kernel body), which
is how the CPU test suite validates them; on TPU they lower through Mosaic.

The FedCET hot-path entry points (``fedcet_v``, ``fedcet_comm``,
``fedcet_round_tail``) additionally take ``impl``:

* ``"auto"`` (default) — the Mosaic kernel on TPU; OFF-TPU the same math
  as plain XLA-compiled jnp (for the fused round tail: with explicit
  ``optimization_barrier`` materialization points replicating the
  kernel's staging — see ``fedcet_round_tail``). This is what the engine
  uses: interpret-mode Pallas re-emulates the grid in Python and is far
  too slow to EXECUTE a real round on CPU.
* ``"kernel"`` — force the pallas_call (interpret mode off-TPU); the
  kernel parity tests pin this against ``"ref"``.
* ``"ref"`` — force the kernels/ref.py oracle expression.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fedcet_update as K
from repro.kernels import ref as R


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _use_kernel(impl: str) -> bool:
    if impl == "auto":
        return jax.default_backend() == "tpu"
    if impl in ("kernel", "ref"):
        return impl == "kernel"
    raise ValueError(f"unknown impl {impl!r} (auto | kernel | ref)")


def _tile(a):
    n = a.size
    rows = -(-n // K.LANES)
    pad = rows * K.LANES - n
    flat = jnp.pad(a.reshape(-1), (0, pad))
    return flat.reshape(rows, K.LANES), n


def _untile(t, n, shape):
    return t.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("alpha", "impl"))
def fedcet_v(x, g, d, alpha: float, impl: str = "auto"):
    """Fused FedCET local-step triad (see kernels/ref.py:fedcet_v)."""
    if not _use_kernel(impl):
        return R.fedcet_v(x, g, d, alpha)
    t_x, n = _tile(x)
    t_g, _ = _tile(g)
    t_d, _ = _tile(d)
    out = K.fedcet_v_2d(t_x, t_g, t_d, alpha=alpha, interpret=_interpret())
    return _untile(out, n, x.shape)


@functools.partial(jax.jit, static_argnames=("kind", "window", "chunk",
                                              "q_blk", "kv_blk"))
def flash_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    chunk: int = 0, q_blk: int = 256, kv_blk: int = 256):
    """Grouped-GQA Pallas flash attention (see kernels/flash_attention.py)."""
    from repro.kernels import flash_attention as K3

    return K3.flash_attention(q, k, v, kind=kind, window=window, chunk=chunk,
                              q_blk=q_blk, kv_blk=kv_blk,
                              interpret=_interpret())


@jax.jit
def ssd_intra(x, dt, a_cs, Bm, Cm):
    """Pallas SSD intra-chunk term (see kernels/ssd_intra.py)."""
    from repro.kernels import ssd_intra as K2

    return K2.ssd_intra(x, dt, a_cs, Bm, Cm, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("bits",))
def stochastic_quantize(a, u, scale, bits: int):
    """Fused dithered-quantize round-trip (see kernels/quantize.py;
    oracle: kernels/ref.py:stochastic_quantize). ``u`` is the uniform
    dither (same shape as ``a``), ``scale`` the scalar per-leaf step."""
    from repro.kernels import quantize as KQ

    t_a, n = _tile(a)
    t_u, _ = _tile(u)
    t_s = jnp.asarray(scale, a.dtype).reshape(1, 1)  # scalar block, not a stream
    out = KQ.stochastic_quantize_2d(t_a, t_u, t_s, bits=bits,
                                    interpret=_interpret())
    return _untile(out, n, a.shape)


@functools.partial(jax.jit, static_argnames=("slots",))
def gossip_reduce(contrib, *, slots: int):
    """Fixed-slot gossip segment reduce (see kernels/gossip_reduce.py;
    oracle: kernels/ref.py:segment_reduce). ``contrib`` is the
    ``[n * slots, D]`` gathered-and-weighted neighbor contributions of
    the sparse exchange lowering (pad slots already zero-weighted);
    returns the per-node sums ``[n, D]``. Pads nodes to the node block
    and lanes to the lane block; zero pad rows reduce to zero rows that
    are sliced off."""
    from repro.kernels import gossip_reduce as KG

    rows, d = contrib.shape
    n = rows // slots
    nb = min(KG.NODE_BLOCK, n)
    db = min(KG.LANE_BLOCK, -(-d // 128) * 128)
    n_pad = -n % nb
    d_pad = -d % db
    t = jnp.pad(contrib, ((0, n_pad * slots), (0, d_pad)))
    out = KG.segment_reduce_2d(t, slots=slots, interpret=_interpret())
    return out[:n, :d]


@functools.partial(jax.jit, static_argnames=("bins", "lo", "hi", "k", "impl"))
def telemetry_sketch(data, *, bins: int, lo: float, hi: float, k: int,
                     impl: str = "auto"):
    """One-pass per-client distribution sketch over the packed client
    store (see kernels/telemetry_reduce.py; oracle:
    kernels/ref.py:client_sketch). ``data`` is ``[clients, ...]`` —
    typically the arena's ``[clients, rows, 1024]`` buffer, flattened
    per client here (zero pad entries contribute 0 to the norms).

    Returns ``(norms [clients], hist [bins] int32, top_vals [k],
    top_ids [k] int32)``: the per-client ``||x_i||``, their
    log10-histogram over ``[10^lo, 10^hi)`` and the k largest with their
    client indices. The top-k runs on the ``[clients]`` norms vector out
    here — next to a D-wide sweep it is free."""
    from repro.kernels import telemetry_reduce as KT

    n = data.shape[0]
    flat = data.reshape(n, -1)
    if _use_kernel(impl):
        cb = min(KT.CLIENT_BLOCK, n)
        db = min(KT.LANE_BLOCK, -(-flat.shape[1] // 128) * 128)
        t = jnp.pad(flat, ((0, -n % cb), (0, -flat.shape[1] % db)))
        sq, hist = KT.client_sketch_2d(t, bins=bins, lo=lo, hi=hi,
                                       n_valid=n, interpret=_interpret())
        sq, hist = sq[:n, 0], hist[0, :bins]
    else:
        sq, hist = R.client_sketch(flat, bins=bins, lo=lo, hi=hi)
    norms = jnp.sqrt(sq)
    tv, ti = jax.lax.top_k(norms, min(k, n))
    return norms, hist, tv, ti.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("c", "alpha", "impl"))
def fedcet_comm(d, m, m_bar, c: float, alpha: float, v=None,
                impl: str = "auto"):
    """Fused FedCET aggregation pair (see kernels/ref.py:fedcet_comm).

    ``m`` is the client's own WIRE message; pass ``v`` (the exact local
    vector, the engine's ``mctx``) when the message path is compressed —
    the drift delta comes from ``m`` while the x-update starts from
    ``v``. ``v=None`` keeps the uncompressed behavior (``v = m``)."""
    if not _use_kernel(impl):
        d_new, x_new = R.fedcet_comm(d, m, jnp.broadcast_to(m_bar, m.shape),
                                     c, alpha, v=v)
        return d_new, x_new
    t_d, n = _tile(d)
    t_m, _ = _tile(m)
    t_mb, _ = _tile(jnp.broadcast_to(m_bar, m.shape))
    if v is None:
        d_new, x_new = K.fedcet_comm_2d(t_d, t_m, t_mb, c=c, alpha=alpha,
                                        interpret=_interpret())
    else:
        t_v, _ = _tile(v)
        d_new, x_new = K.fedcet_comm4_2d(t_d, t_m, t_mb, t_v, c=c,
                                         alpha=alpha, interpret=_interpret())
    return _untile(d_new, n, d.shape), _untile(x_new, n, m.shape)


@functools.partial(jax.jit, static_argnames=("bits",))
def stochastic_quantize_rows(a, u, scale_rows, bits: int):
    """Row-wise-scale dithered-quantize round-trip over a pre-tiled
    ``[rows, 1024]`` arena buffer (see kernels/quantize.py
    ``stochastic_quantize_rows_2d``); ``scale_rows`` is ``[rows, 1]``."""
    from repro.kernels import quantize as KQ

    return KQ.stochastic_quantize_rows_2d(a, u, scale_rows, bits=bits,
                                          interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("c", "alpha", "beta", "bits", "impl"))
def fedcet_round_tail(v, h, d, u, scale, w, den, *, c: float, alpha: float,
                      beta: float, bits: int, impl: str = "auto"):
    """The fused shift-compressed FedCET round tail (oracle:
    kernels/ref.py:fedcet_round_tail): dithered-quantize the shifted
    residual, reconstruct the wire message, weighted-reduce it across
    clients and apply the paired ``(d', x')`` update plus the DIANA shift
    step — one kernel visit per element on TPU.

    Shapes: ``v``/``h``/``d`` [clients, rows, 1024]; ``u`` [rows, 1024];
    ``scale`` [rows, 1]; ``w`` [clients, 1]; ``den`` [1, 1].

    Off-TPU ``"auto"`` compiles the oracle expression with
    ``optimization_barrier`` at the kernel's two natural materialization
    points — the int8 quantizer codes and the client mean — pinning the
    two-pass schedule the Mosaic kernel implements (second pass re-reads
    1-byte codes). On CPU this lands AT the measured stream roofline
    (~39 B/elem model); XLA's per-leaf fusion reaches the same byte
    floor, so the CPU win is structural (a ~10x compiled-instruction
    collapse), not wall-clock — measured at 128 clients on the reduced
    fedlm-100m geometry, see benchmarks/fed_lm_bench.py."""
    if _use_kernel(impl):
        return K.fedcet_round_tail_3d(v, h, d, u, scale, w, den,
                                      c=c, alpha=alpha, beta=beta, bits=bits,
                                      interpret=_interpret())
    if impl == "ref":
        return R.fedcet_round_tail(v, h, d, u, scale, w[:, :, None],
                                   den[0, 0], c=c, alpha=alpha, beta=beta,
                                   bits=bits)
    bar = jax.lax.optimization_barrier
    levels = 2 ** (bits - 1) - 1
    code_t = jnp.int8 if bits <= 8 else jnp.int16
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    # pass 1: materialize the integral codes once, 1 byte/elem (exact:
    # floor lands on integers within +-levels, so the cast round-trips).
    q = bar(jnp.clip(jnp.floor((v - h) * inv + u), -levels,
                     levels).astype(code_t))
    qs = q.astype(v.dtype) * scale
    m_bar = bar(jnp.sum((h + qs) * w[:, :, None], axis=0, keepdims=True)
                / den[0, 0])
    # pass 2: one fused elementwise sweep reading q (i8), h, d, v.
    qs = q.astype(v.dtype) * scale
    delta = (h + qs) - m_bar
    return d + c * delta, v - (c * alpha) * delta, h + beta * qs
