"""jit'd public wrappers around the Pallas kernels.

Handles arbitrary leaf shapes: flatten -> pad to a whole number of
(rows x 1024) lanes -> kernel -> unpad/reshape. On non-TPU backends the
kernels run in interpret mode (Python emulation of the kernel body), which
is how the CPU test suite validates them; on TPU they lower through Mosaic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fedcet_update as K


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _tile(a):
    n = a.size
    rows = -(-n // K.LANES)
    pad = rows * K.LANES - n
    flat = jnp.pad(a.reshape(-1), (0, pad))
    return flat.reshape(rows, K.LANES), n


def _untile(t, n, shape):
    return t.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("alpha",))
def fedcet_v(x, g, d, alpha: float):
    """Fused FedCET local-step triad (see kernels/ref.py:fedcet_v)."""
    t_x, n = _tile(x)
    t_g, _ = _tile(g)
    t_d, _ = _tile(d)
    out = K.fedcet_v_2d(t_x, t_g, t_d, alpha=alpha, interpret=_interpret())
    return _untile(out, n, x.shape)


@functools.partial(jax.jit, static_argnames=("kind", "window", "chunk",
                                              "q_blk", "kv_blk"))
def flash_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    chunk: int = 0, q_blk: int = 256, kv_blk: int = 256):
    """Grouped-GQA Pallas flash attention (see kernels/flash_attention.py)."""
    from repro.kernels import flash_attention as K3

    return K3.flash_attention(q, k, v, kind=kind, window=window, chunk=chunk,
                              q_blk=q_blk, kv_blk=kv_blk,
                              interpret=_interpret())


@jax.jit
def ssd_intra(x, dt, a_cs, Bm, Cm):
    """Pallas SSD intra-chunk term (see kernels/ssd_intra.py)."""
    from repro.kernels import ssd_intra as K2

    return K2.ssd_intra(x, dt, a_cs, Bm, Cm, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("bits",))
def stochastic_quantize(a, u, scale, bits: int):
    """Fused dithered-quantize round-trip (see kernels/quantize.py;
    oracle: kernels/ref.py:stochastic_quantize). ``u`` is the uniform
    dither (same shape as ``a``), ``scale`` the scalar per-leaf step."""
    from repro.kernels import quantize as KQ

    t_a, n = _tile(a)
    t_u, _ = _tile(u)
    t_s = jnp.asarray(scale, a.dtype).reshape(1, 1)  # scalar block, not a stream
    out = KQ.stochastic_quantize_2d(t_a, t_u, t_s, bits=bits,
                                    interpret=_interpret())
    return _untile(out, n, a.shape)


@functools.partial(jax.jit, static_argnames=("slots",))
def gossip_reduce(contrib, *, slots: int):
    """Fixed-slot gossip segment reduce (see kernels/gossip_reduce.py;
    oracle: kernels/ref.py:segment_reduce). ``contrib`` is the
    ``[n * slots, D]`` gathered-and-weighted neighbor contributions of
    the sparse exchange lowering (pad slots already zero-weighted);
    returns the per-node sums ``[n, D]``. Pads nodes to the node block
    and lanes to the lane block; zero pad rows reduce to zero rows that
    are sliced off."""
    from repro.kernels import gossip_reduce as KG

    rows, d = contrib.shape
    n = rows // slots
    nb = min(KG.NODE_BLOCK, n)
    db = min(KG.LANE_BLOCK, -(-d // 128) * 128)
    n_pad = -n % nb
    d_pad = -d % db
    t = jnp.pad(contrib, ((0, n_pad * slots), (0, d_pad)))
    out = KG.segment_reduce_2d(t, slots=slots, interpret=_interpret())
    return out[:n, :d]


@functools.partial(jax.jit, static_argnames=("c", "alpha"))
def fedcet_comm(d, v, v_bar, c: float, alpha: float):
    """Fused FedCET aggregation pair (see kernels/ref.py:fedcet_comm)."""
    t_d, n = _tile(d)
    t_v, _ = _tile(v)
    t_vb, _ = _tile(jnp.broadcast_to(v_bar, v.shape))
    d_new, x_new = K.fedcet_comm_2d(t_d, t_v, t_vb, c=c, alpha=alpha,
                                    interpret=_interpret())
    return _untile(d_new, n, d.shape), _untile(x_new, n, v.shape)
