"""Pallas TPU kernel for the one-pass telemetry distribution sketch.

The distributional telemetry (core/telemetry.py: ``sketch_client_norms``)
needs, once per round, the per-client norms ``||x_i||`` over the FULL
``[N, rows, 1024]`` packed arena store plus their log-histogram — an
O(N * D) read that must not become three separate sweeps (norms, then
binning, then outliers) at N = 1e6. This kernel fuses norm accumulation
and histogram binning into ONE pass over the store: grid over
(client blocks, lane blocks) with the lane axis minor — TPU grid steps
run sequentially in row-major order, so each client block's partial
square-sums accumulate across its lane steps into a revisited ``[cb, 1]``
output block (the flash-attention accumulation pattern), and at the
block's LAST lane step the now-complete norms are binned into a single
revisited ``[1, bins]`` histogram block shared by every grid step. The
top-k outlier selection runs on the tiny ``[N]`` norms vector back in
ops.py (``jax.lax.top_k``) — fusing it into the sweep would buy nothing:
the norms output is 4 bytes per client against D * 4 read.

Binning is the shared verbatim formula (telemetry.log_histogram /
ref.client_sketch): ``idx = clip(floor((log10(v) - lo) * bins/(hi-lo)),
0, bins-1)``, zeros pinned to bin 0. The histogram one-hot uses a 2-D
``broadcasted_iota`` (TPU requires >=2-D iota) and masks padded client
rows via the static ``n_valid`` — zero pad LANES already contribute 0 to
the norms, but pad CLIENTS must not count in the histogram. The bin axis
is padded to a 128-lane multiple in the block; ops.py slices the logical
``[:bins]`` off.

Oracle: kernels/ref.py:client_sketch (bit-comparable in interpret mode —
tests/test_telemetry_dist.py); discipline as quantize/gossip_reduce.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
import jax.numpy as jnp

CLIENT_BLOCK = 8
LANE_BLOCK = 1024


def _sketch_kernel(x_ref, sq_ref, h_ref, *, bins: int, lo: float, hi: float,
                   n_valid: int, nj: int):
    i = pl.program_id(0)
    j = pl.program_id(1)
    x = x_ref[...]
    part = jnp.sum(x * x, axis=1, keepdims=True)            # [cb, 1]

    @pl.when(j == 0)
    def _init_sq():
        sq_ref[...] = jnp.zeros_like(sq_ref)

    sq_ref[...] += part

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init_hist():
        h_ref[...] = jnp.zeros_like(h_ref)

    @pl.when(j == nj - 1)
    def _bin():
        cb, bins_pad = x.shape[0], h_ref.shape[1]
        # broadcast the [cb, 1] norms to the full bin tile BEFORE the
        # transcendental: f64 log on a width-1 column crashes the XLA CPU
        # backend (interpret mode), and the [cb, bins] tile is the
        # natural register shape for the one-hot compare anyway.
        v = jnp.broadcast_to(jnp.sqrt(sq_ref[...]), (cb, bins_pad))
        logs = jnp.where(v > 0, jnp.log10(v), jnp.asarray(lo, v.dtype))
        idx = jnp.clip(jnp.floor((logs - lo) * (bins / (hi - lo))),
                       0, bins - 1).astype(jnp.int32)       # [cb, bins_pad]
        cols = jax.lax.broadcasted_iota(jnp.int32, (cb, bins_pad), 1)
        rows = jax.lax.broadcasted_iota(jnp.int32, (cb, bins_pad), 0)
        valid = rows + jnp.int32(i * cb) < jnp.int32(n_valid)
        hit = jnp.where(jnp.logical_and(cols == idx, valid),
                        jnp.int32(1), jnp.int32(0))
        h_ref[...] += jnp.sum(hit, axis=0, keepdims=True).astype(jnp.int32)


def client_sketch_2d(x, *, bins: int, lo: float, hi: float, n_valid: int,
                     client_block: int = CLIENT_BLOCK, interpret: bool = True):
    """Fused per-client square-norm + log-histogram over the flattened
    store ``x`` ``[n, d]`` (pre-padded by ops.py: ``n % client_block == 0``,
    ``d`` a lane-block multiple, pad entries zero). Returns
    ``(sq_norms [n, 1], hist [1, bins_pad] int32)`` with ``bins_pad`` the
    bin count padded to 128 lanes (logical bins first); only the first
    ``n_valid`` clients count in the histogram."""
    n, d = x.shape
    cb = min(client_block, n)
    db = min(LANE_BLOCK, d)
    bins_pad = -(-bins // 128) * 128
    grid = (pl.cdiv(n, cb), pl.cdiv(d, db))
    return pl.pallas_call(
        functools.partial(_sketch_kernel, bins=bins, lo=lo, hi=hi,
                          n_valid=n_valid, nj=grid[1]),
        grid=grid,
        in_specs=[pl.BlockSpec((cb, db), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((cb, 1), lambda i, j: (i, 0)),
                   pl.BlockSpec((1, bins_pad), lambda i, j: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, 1), x.dtype),
                   jax.ShapeDtypeStruct((1, bins_pad), jnp.int32)],
        interpret=interpret,
    )(x)
