"""Pallas TPU kernel: grouped-GQA flash attention (forward).

The canonical TPU online-softmax schedule: grid (batch, kv_head, q_block,
kv_block), with the kv_block axis innermost so the (m, l, acc) running
statistics live in VMEM scratch across kv iterations and each output block
is written once on the last kv step. GQA is handled in grouped form — q
blocks are [q_blk, G, D] tiles against [kv_blk, D] K/V tiles, so KV is
never repeated to the query-head count (the same 6x saving the XLA
blockwise path gets, here made explicit in the kernel's BlockSpecs).

Masking (causal / sliding window / chunked-local) is applied from global
q/k indices computed off the grid position — mask kinds are static kernel
parameters, so each variant compiles its own specialized kernel.

VMEM budget per step (q_blk=256, kv_blk=256, G<=8, D<=256, f32 scratch):
q 0.5-2 MiB + k/v 0.25-1 MiB + acc/l/m ~2 MiB — comfortably inside v5e's
~128 MiB. Validated in interpret mode against models/attention.attend_naive
across shapes, dtypes, group counts and mask kinds (tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  kind: str, window: int, chunk: int, q_blk: int,
                  kv_blk: int, seq_len: int, kv_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]                       # [q_blk, G, D]
    k = k_ref[...]                       # [kv_blk, D]
    v = v_ref[...]                       # [kv_blk, D]
    D = q.shape[-1]

    scores = jax.lax.dot_general(
        q, k, (((2,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # [q_blk, G, kv_blk]
    scores = scores / jnp.sqrt(jnp.asarray(D, jnp.float32))

    qpos = iq * q_blk + jax.lax.broadcasted_iota(
        jnp.int32, (q_blk, 1, kv_blk), 0)
    kpos = ik * kv_blk + jax.lax.broadcasted_iota(
        jnp.int32, (q_blk, 1, kv_blk), 2)
    ok = (kpos < kv_len) & (qpos < seq_len)
    if kind != "bidirectional":
        ok &= kpos <= qpos
    if kind == "sliding":
        ok &= kpos > qpos - window
    elif kind == "chunked":
        ok &= (kpos // chunk) == (qpos // chunk)
    scores = jnp.where(ok, scores, NEG_INF)

    m_prev = m_scr[...]                              # [q_blk, G]
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[..., None])           # [q_blk, G, kv_blk]
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # [q_blk, G, D]
    acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, kind: str = "causal", window: int = 0,
                    chunk: int = 0, q_blk: int = 256, kv_blk: int = 256,
                    interpret: bool = True):
    """q: [B, S, Hq, D]; k/v: [B, T, Hkv, D]. Returns [B, S, Hq, D]."""
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    q_blk = min(q_blk, S)
    kv_blk = min(kv_blk, T)
    nq = -(-S // q_blk)
    nk = -(-T // kv_blk)
    pad_q = nq * q_blk - S
    pad_k = nk * kv_blk - T
    qg = q.reshape(B, S, Hkv, G, D)
    if pad_q:
        qg = jnp.pad(qg, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    kernel = functools.partial(
        _flash_kernel, kind=kind, window=window, chunk=chunk, q_blk=q_blk,
        kv_blk=kv_blk, seq_len=S, kv_len=T)
    import jax.experimental.pallas.tpu as pltpu

    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((None, q_blk, None, G, D),
                         lambda b, h, iq, ik: (b, iq, h, 0, 0)),
            pl.BlockSpec((None, kv_blk, None, D),
                         lambda b, h, iq, ik: (b, ik, h, 0)),
            pl.BlockSpec((None, kv_blk, None, D),
                         lambda b, h, iq, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_blk, None, G, D),
                               lambda b, h, iq, ik: (b, iq, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(qg.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, G), jnp.float32),      # running max m
            pltpu.VMEM((q_blk, G), jnp.float32),      # running denom l
            pltpu.VMEM((q_blk, G, D), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(qg, k, v)
    if pad_q:
        out = out[:, :S]
    return out.reshape(B, S, Hq, D)
