"""Pallas TPU kernels for the FedCET update hot-path.

The FedCET local step applies ``v = x - alpha*g - alpha*d`` to EVERY
parameter of the model, tau times per communication round; the comm step
additionally applies the paired update ``(d', x') = (d + c*delta,
v - c*alpha*delta)``. On a multi-B-parameter model these streams are the
per-step HBM bottleneck of the algorithm (the paper's eq. (2)/(3) applied at
scale): 3 reads + 1 write per element for the triad, 3 reads + 2 writes for
the fused comm pair. Fusing them in one kernel visit per element is the
memory-roofline-optimal schedule.

Layout: inputs are reshaped by ops.py to [rows, 1024] — the minor dimension
is a multiple of the TPU lane width (128) and the row block (256) is a
multiple of the f32 sublane (8), so each BlockSpec tile is a
hardware-aligned (256, 1024) VMEM block (1 MiB for f32): 4 input tiles + 2
output tiles ~= 6 MiB of VMEM per step, comfortably inside the ~16 MiB
budget. Kernels are validated against kernels/ref.py in interpret mode
(CPU) across shapes and dtypes in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

ROW_BLOCK = 256
LANES = 1024


def _fedcet_v_kernel(x_ref, g_ref, d_ref, o_ref, *, alpha: float):
    x = x_ref[...]
    g = g_ref[...]
    d = d_ref[...]
    o_ref[...] = x - alpha * g - alpha * d


def fedcet_v_2d(x, g, d, *, alpha: float, interpret: bool = True):
    """x, g, d: [rows, LANES] (pre-tiled by ops.py)."""
    rows = x.shape[0]
    rb = min(ROW_BLOCK, rows)
    grid = (pl.cdiv(rows, rb),)
    spec = pl.BlockSpec((rb, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_fedcet_v_kernel, alpha=alpha),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, g, d)


def _fedcet_comm_kernel(d_ref, v_ref, vb_ref, d_out_ref, x_out_ref, *,
                        c: float, alpha: float):
    v = v_ref[...]
    delta = v - vb_ref[...]
    d_out_ref[...] = d_ref[...] + c * delta
    x_out_ref[...] = v - (c * alpha) * delta


def fedcet_comm_2d(d, v, v_bar, *, c: float, alpha: float,
                   interpret: bool = True):
    """Fused aggregation update; all operands [rows, LANES]."""
    rows = d.shape[0]
    rb = min(ROW_BLOCK, rows)
    grid = (pl.cdiv(rows, rb),)
    spec = pl.BlockSpec((rb, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_fedcet_comm_kernel, c=c, alpha=alpha),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(d.shape, d.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        interpret=interpret,
    )(d, v, v_bar)
