"""Pallas TPU kernels for the FedCET update hot-path.

The FedCET local step applies ``v = x - alpha*g - alpha*d`` to EVERY
parameter of the model, tau times per communication round; the comm step
additionally applies the paired update ``(d', x') = (d + c*delta,
v - c*alpha*delta)``. On a multi-B-parameter model these streams are the
per-step HBM bottleneck of the algorithm (the paper's eq. (2)/(3) applied at
scale): 3 reads + 1 write per element for the triad, 3 reads + 2 writes for
the fused comm pair. Fusing them in one kernel visit per element is the
memory-roofline-optimal schedule.

Layout: inputs are reshaped by ops.py to [rows, 1024] — the minor dimension
is a multiple of the TPU lane width (128) and the row block (256) is a
multiple of the f32 sublane (8), so each BlockSpec tile is a
hardware-aligned (256, 1024) VMEM block (1 MiB for f32): 4 input tiles + 2
output tiles ~= 6 MiB of VMEM per step, comfortably inside the ~16 MiB
budget. Kernels are validated against kernels/ref.py in interpret mode
(CPU) across shapes and dtypes in tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

ROW_BLOCK = 256
LANES = 1024


def _fedcet_v_kernel(x_ref, g_ref, d_ref, o_ref, *, alpha: float):
    x = x_ref[...]
    g = g_ref[...]
    d = d_ref[...]
    o_ref[...] = x - alpha * g - alpha * d


def fedcet_v_2d(x, g, d, *, alpha: float, interpret: bool = True):
    """x, g, d: [rows, LANES] (pre-tiled by ops.py)."""
    rows = x.shape[0]
    rb = min(ROW_BLOCK, rows)
    grid = (pl.cdiv(rows, rb),)
    spec = pl.BlockSpec((rb, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_fedcet_v_kernel, alpha=alpha),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, g, d)


def _fedcet_comm_kernel(d_ref, v_ref, vb_ref, d_out_ref, x_out_ref, *,
                        c: float, alpha: float):
    v = v_ref[...]
    delta = v - vb_ref[...]
    d_out_ref[...] = d_ref[...] + c * delta
    x_out_ref[...] = v - (c * alpha) * delta


def _fedcet_comm4_kernel(d_ref, m_ref, mb_ref, v_ref, d_out_ref, x_out_ref,
                         *, c: float, alpha: float):
    delta = m_ref[...] - mb_ref[...]
    d_out_ref[...] = d_ref[...] + c * delta
    x_out_ref[...] = v_ref[...] - (c * alpha) * delta


def fedcet_comm4_2d(d, m, m_bar, v, *, c: float, alpha: float,
                    interpret: bool = True):
    """The compressed-message aggregation pair (oracle:
    ref.fedcet_comm with ``v=``): delta comes from the WIRE message
    ``m`` while the x-update starts from the exact local ``v``.
    All operands [rows, LANES]."""
    rows = d.shape[0]
    rb = min(ROW_BLOCK, rows)
    grid = (pl.cdiv(rows, rb),)
    spec = pl.BlockSpec((rb, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_fedcet_comm4_kernel, c=c, alpha=alpha),
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(d.shape, d.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        interpret=interpret,
    )(d, m, m_bar, v)


def _round_tail_kernel(v_ref, h_ref, d_ref, u_ref, s_ref, w_ref, den_ref,
                       d_out_ref, x_out_ref, h_out_ref, *,
                       c: float, alpha: float, beta: float, levels: int):
    import jax.numpy as jnp

    v = v_ref[...]                      # [C, rb, LANES]
    h = h_ref[...]
    s = s_ref[...]                      # [rb, 1] per-leaf quant step
    inv = jnp.where(s > 0, 1.0 / s, 0.0)
    q = jnp.clip(jnp.floor((v - h) * inv + u_ref[...][None]),
                 -levels, levels)
    qs = q * s
    recon = h + qs
    w = w_ref[...][:, :, None]          # [C, 1, 1] client weights
    m_bar = jnp.sum(recon * w, axis=0, keepdims=True) / den_ref[0, 0]
    delta = recon - m_bar
    d_out_ref[...] = d_ref[...] + c * delta
    x_out_ref[...] = v - (c * alpha) * delta
    h_out_ref[...] = h + beta * qs


def fedcet_round_tail_3d(v, h, d, u, scale, w, den, *, c: float,
                         alpha: float, beta: float, bits: int,
                         interpret: bool = True):
    """The fused shift:q8 -> weighted reduce -> FedCET pair round tail
    (oracle: ref.fedcet_round_tail) — ONE kernel visit per element: the
    quantizer codes, the reconstructed wire message and the client mean
    all live in VMEM and never round-trip to HBM.

    ``v``/``h``/``d``: [clients, rows, LANES]; ``u``: [rows, LANES];
    ``scale``: [rows, 1]; ``w``: [clients, 1]; ``den``: [1, 1]. The grid
    tiles rows only — every client of a row block is resident so the
    cross-client reduction happens in-kernel; the row block shrinks with
    the client count to hold the ~6 resident [C, rb, LANES] f32 tiles
    within the ~16 MiB VMEM budget."""
    n_clients, rows, _ = v.shape
    # 6 live f32 tiles of [C, rb, LANES]: target <= ~2 MiB each.
    rb = max(1, min(rows, 512 // max(1, n_clients)))
    grid = (pl.cdiv(rows, rb),)
    cs = pl.BlockSpec((n_clients, rb, LANES), lambda i: (0, i, 0))
    rs = pl.BlockSpec((rb, LANES), lambda i: (i, 0))
    ss = pl.BlockSpec((rb, 1), lambda i: (i, 0))
    ws = pl.BlockSpec((n_clients, 1), lambda i: (0, 0))
    ds = pl.BlockSpec((1, 1), lambda i: (0, 0))
    sds = jax.ShapeDtypeStruct(v.shape, v.dtype)
    return pl.pallas_call(
        functools.partial(_round_tail_kernel, c=c, alpha=alpha, beta=beta,
                          levels=2 ** (bits - 1) - 1),
        grid=grid,
        in_specs=[cs, cs, cs, rs, ss, ws, ds],
        out_specs=[cs, cs, cs],
        out_shape=[sds, sds, sds],
        interpret=interpret,
    )(v, h, d, u, scale, w, den)


def fedcet_comm_2d(d, v, v_bar, *, c: float, alpha: float,
                   interpret: bool = True):
    """Fused aggregation update; all operands [rows, LANES]."""
    rows = d.shape[0]
    rb = min(ROW_BLOCK, rows)
    grid = (pl.cdiv(rows, rb),)
    spec = pl.BlockSpec((rb, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_fedcet_comm_kernel, c=c, alpha=alpha),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct(d.shape, d.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        interpret=interpret,
    )(d, v, v_bar)
