"""Pallas TPU kernels (validated in interpret mode on CPU; Mosaic on TPU).

  fedcet_update.py   fused FedCET local-step triad + aggregation pair
  flash_attention.py grouped-GQA online-softmax attention (causal /
                     sliding / chunked / bidirectional)
  ssd_intra.py       Mamba2 SSD intra-chunk (quadratic) term
  ops.py             jit'd public wrappers (tiling, backend dispatch)
  ref.py             pure-jnp oracles (the allclose targets)
"""
