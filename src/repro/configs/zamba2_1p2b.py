"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

38 Mamba2 layers with the single parameter-shared attention(+MLP) block
applied every 6 layers. The shared block is 32-head full attention
(kv=32, i.e. MHA) with d_ff=8192; in long-context serving it runs
sliding-window so the hybrid stays sub-quadratic (DESIGN.md §5).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,      # the shared attention block is MHA
    head_dim=64,
    d_ff=8192,          # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    shared_attn_every=6,
    attention="sliding",
    window=4096,
    activation="swiglu",
    citation="arXiv:2411.15242",
)
