"""minicpm-2b [dense] — llama-like arch trained with the WSD schedule
[arXiv:2404.06395]. The WSD (warmup-stable-decay) schedule itself lives in
repro.optim.schedules and is selected by the training driver for this arch."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,      # MHA
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    activation="swiglu",
    tie_embeddings=True,  # MiniCPM ties input/output embeddings
    citation="arXiv:2404.06395",
)
