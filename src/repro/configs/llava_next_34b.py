"""llava-next-34b [vlm] — anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

The vision tower (ViT/SigLIP + projector, anyres tile split) is the
assignment's allowed stub: input_specs() provides the anyres patch
embeddings [B, n_modal_tokens, d_model]; this config is the 60-layer
language backbone that interleaves and attends over them.
n_modal_tokens = 2880 ~= 5 anyres tiles x 576 patches/tile.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,       # GQA kv=8
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    modality="vision",
    n_modal_tokens=2880,
    activation="swiglu",
    rope_theta=1e6,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
