"""Registry of the assigned architectures (+ the paper's own workload).

Every entry cites its source. ``get_config(name)`` is what ``--arch <id>``
resolves through.
"""

from repro.configs.base import (
    INPUT_SHAPES,
    ArchConfig,
    FedScenario,
    ShapeConfig,
    supports_shape,
)


#: the 10 assigned architectures (fedlm-100m is a paper-side extra and is
#: not part of the dry-run / roofline matrix).
ASSIGNED = (
    "internlm2-20b", "zamba2-1.2b", "qwen3-1.7b", "minicpm-2b",
    "llava-next-34b", "llama4-scout-17b-a16e", "gemma-2b", "mamba2-130m",
    "granite-moe-3b-a800m", "whisper-small",
)


def _lazy():
    from repro.configs import (
        fedlm_100m,
        gemma_2b,
        granite_moe_3b_a800m,
        internlm2_20b,
        llama4_scout_17b_a16e,
        llava_next_34b,
        mamba2_130m,
        minicpm_2b,
        qwen3_1p7b,
        whisper_small,
        zamba2_1p2b,
    )

    return {
        m.CONFIG.name: m.CONFIG
        for m in (
            internlm2_20b, zamba2_1p2b, qwen3_1p7b, minicpm_2b, llava_next_34b,
            llama4_scout_17b_a16e, gemma_2b, mamba2_130m, granite_moe_3b_a800m,
            whisper_small, fedlm_100m,
        )
    }


_REGISTRY: dict[str, ArchConfig] | None = None


def registry() -> dict[str, ArchConfig]:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = _lazy()
    return _REGISTRY


def get_config(name: str) -> ArchConfig:
    reg = registry()
    if name not in reg:
        raise KeyError(f"unknown arch {name!r}; have {sorted(reg)}")
    return reg[name]


def list_archs() -> list[str]:
    return sorted(registry())


__all__ = [
    "ArchConfig",
    "FedScenario",
    "INPUT_SHAPES",
    "ShapeConfig",
    "get_config",
    "list_archs",
    "registry",
    "supports_shape",
]
