"""llama4-scout-17b-a16e [moe] — 16-expert top-1 MoE with a shared expert,
chunked local attention (iRoPE-style) [hf:meta-llama/Llama-4-Scout-17B-16E].

The chunked attention (8192-token chunks) is the sub-quadratic variant that
qualifies this arch for `long_500k` decode (DESIGN.md §5); "early fusion"
multimodality enters through the same stub-embedding path as the VLM family
but the assigned shapes here are text-token workloads.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,       # GQA kv=8
    head_dim=128,
    d_ff=8192,          # per expert
    vocab_size=202048,
    n_experts=16,
    experts_per_token=1,   # top-1 routing
    moe_shared_expert=True,
    attention="chunked",
    chunk=8192,
    activation="swiglu",
    rope_theta=5e5,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E",
)
