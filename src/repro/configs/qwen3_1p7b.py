"""qwen3-1.7b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B].

Sliding-window attention is enabled as the sub-quadratic variant that
qualifies this dense arch for the `long_500k` decode shape (DESIGN.md §5).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,       # GQA kv=8
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    attention="sliding",
    window=4096,
    activation="swiglu",
    rope_theta=1e6,
    citation="hf:Qwen/Qwen3-8B",
)
