"""mamba2-130m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,          # attention-free
    n_kv_heads=0,
    head_dim=None,
    d_ff=0,             # no MLP blocks in mamba2
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,       # d_inner = 1536 -> 24 SSD heads
    citation="arXiv:2405.21060",
)
