"""gemma-2b [dense] — GeGLU, head_dim=256, MQA [arXiv:2403.08295].

Sliding-window attention is enabled as the sub-quadratic variant that
qualifies this dense arch for the `long_500k` decode shape (DESIGN.md §5);
Gemma-1 itself is full-attention (the window matches Gemma-2's 4096).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,       # MQA
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    activation="geglu",
    embed_scale=True,   # gemma multiplies embeddings by sqrt(d_model)
    tie_embeddings=True,
    attention="sliding",
    window=4096,
    citation="arXiv:2403.08295",
)
