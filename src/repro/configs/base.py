"""Architecture + workload-shape config system.

Every assigned architecture gets one ``ArchConfig`` in its own module under
``repro.configs``; the registry maps ``--arch <id>`` to it. ``reduced()``
produces the CPU-smoke-test variant of the same family (<=2 layers,
d_model<=512, <=4 experts) as required by the assignment.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""
    head_dim: Optional[int] = None   # default d_model // n_heads

    # attention variants
    qk_norm: bool = False
    attention: str = "full"          # full | sliding | chunked
    window: int = 4096               # sliding-window size
    chunk: int = 8192                # chunked-local (iRoPE) chunk size
    rope_theta: float = 1e4
    use_rope: bool = True
    attn_bias: bool = False

    # mlp
    activation: str = "swiglu"       # swiglu | geglu | gelu
    mlp_bias: bool = False

    # norm / embeddings
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: multiply embeddings by sqrt(d)

    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    moe_shared_expert: bool = False
    capacity_factor: float = 1.25

    # ssm (mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4

    # hybrid (zamba2): one SHARED attention(+MLP) block applied every k layers
    shared_attn_every: int = 0

    # modality frontends (stubs): precomputed embeddings prepended/consumed
    modality: str = "text"           # text | vision | audio
    n_modal_tokens: int = 0          # vision: image-patch tokens per sample
    encoder_layers: int = 0          # audio: enc-dec encoder depth
    encoder_len: int = 1500          # audio: encoder frames

    # numerics / lowering
    dtype: str = "float32"           # activations
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    attn_block_size: int = 512
    #: use the Pallas kernels for attention / SSD (TPU target; interpret
    #: mode on CPU — enabled in tests/integration, off for XLA dry-runs
    #: since Pallas-TPU can't lower on the CPU host backend).
    use_pallas_attention: bool = False
    use_pallas_ssd: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.n_heads and self.n_kv_heads:
            assert self.n_heads % self.n_kv_heads == 0, (self.n_heads, self.n_kv_heads)

    # ------------------------------------------------------------- variants
    def reduced(self) -> "ArchConfig":
        """Smoke-test variant of the same family: 2 layers, d_model<=512,
        <=4 experts, small vocab/window — runs a train step on one CPU."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4) or 0
        kv = min(self.n_kv_heads, heads) if self.n_kv_heads else 0
        if heads and kv:
            kv = heads // max(1, heads // kv)  # keep a GQA ratio > 1 if it had one
        changes = dict(
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=(d // heads if heads else None),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            window=min(self.window, 64),
            chunk=min(self.chunk, 64),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_token=(min(self.experts_per_token, 2)
                               if self.experts_per_token else 0),
            # drop-free capacity so prefill/decode stay bit-consistent in the
            # smoke tests (production configs keep the real 1.25 and drop).
            capacity_factor=float(max(self.n_experts, 1)),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=16 if self.ssm_state else self.ssm_headdim,
            shared_attn_every=(1 if self.shared_attn_every else 0),
            n_modal_tokens=min(self.n_modal_tokens, 16),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_len=min(self.encoder_len, 32),
            scan_layers=False,
            remat=False,
        )
        return dataclasses.replace(self, **changes)

    def with_dtype(self, dtype: str, param_dtype: str | None = None) -> "ArchConfig":
        return dataclasses.replace(self, dtype=dtype,
                                   param_dtype=param_dtype or dtype)


@dataclasses.dataclass(frozen=True)
class FedScenario:
    """Launch-level federated-scenario knob: which compressor stack rides
    the uplink, what fraction of clients participates per round, and which
    delay model / stale-aggregation policy simulates asynchronous uplinks.

    ``compression`` is a spec string for
    :func:`repro.core.compressors.from_spec` — ``"none"``, ``"bf16"``,
    ``"topk:0.3"`` (per-client), ``"randk:0.25"``, ``"q8"``,
    ``"shift:q8"`` (DIANA-style shifted quantization), chains via ``+``
    (``"randk:0.5+q8"``), ``"ef:"`` prefix to force error feedback.
    ``error_feedback=None`` auto-wraps biased compressors only.
    ``compression_plan`` is the PER-LEAF alternative
    (:func:`repro.core.compressors.parse_plan`): first-match-wins
    ``pattern:spec`` rules over leaf paths, e.g.
    ``"embed*:q12,ln*:bf16,*:shift:q6"`` — mutually exclusive with
    ``compression``.

    ``delay`` is a spec string for :func:`repro.core.staleness.parse_delay`
    — ``"none"``, ``"fixed:2"`` (periodic uplink), ``"rr:1"`` (round-robin
    straggler), ``"geom:0.5"`` (Bernoulli arrivals) — with
    ``stale_policy`` one of ``"drop"`` / ``"last"`` / ``"poly:<a>"``.

    ``topology`` is a spec string for
    :func:`repro.core.topology.parse_topology` — ``"star"`` (the flat
    default), ``"hier:g8"`` / ``"hier:16x4"`` (edge-aggregator tree with
    per-hop comm accounting), ``"ring"`` / ``"torus"`` / ``"er:0.4"``
    (doubly-stochastic gossip mixing; ``"er:0.4:t"`` resamples the graph
    every round; a trailing ``":sparse"`` — ``"ring:sparse"``,
    ``"er:0.4:t:sparse"`` — selects the padded neighbor-exchange
    lowering, O(edges) instead of the dense N^2 contraction).
    ``tier_compression`` (hierarchies only) is a compressor spec applied
    to the interior edge->root tier uplinks (``"shift:q8"`` compresses
    the FULL uplink end to end), with per-hop bit-true accounting.

    ``cohort`` is a spec string (or int) for
    :func:`repro.core.engine.parse_cohort` — ``"none"`` (dense: every
    round touches all N client rows), ``256`` / ``"256"`` (uniformly
    sampled cohort of that size), ``"block:256"`` / ``"rr:256"``
    (contiguous-block / round-robin selectors), optional trailing
    ``":dense"`` to force the dense reference lowering. With a cohort
    the round's per-client work is O(cohort): the engine gathers the
    sampled rows from the server-side client-state store, runs the local
    scan on the cohort only, and scatters updates back.

    ``arena`` lowers the engine's stacked client store onto the packed
    parameter arena (:mod:`repro.core.arena`): the model pytree lives as
    one contiguous lane-aligned ``[clients, rows, 1024]`` buffer for the
    whole round and unpacks only at the gradient boundary. Composes with
    every knob above and is pinned <=1e-12-equivalent to the per-leaf
    lowering, so checkpoints and shardings stay flippable either way.

    ``telemetry`` attaches the in-trace round telemetry spec
    (:mod:`repro.core.telemetry`): per-round metric capture inside the
    jitted round (norms, compression error, invariant residual, consensus
    error, participation, staleness ages) with no host sync. ``False`` /
    ``"none"`` (the default) is a BITWISE no-op — the algorithm object is
    returned unchanged; any truthy value (``True``, a sink spec string, a
    ``Telemetry`` object) enables the default metric set.

    ``apply`` composes the scenario onto ANY engine algorithm — the same
    expression the simulation tests pin, now reachable from the production
    LM loop (`launch/train.py --compression ... --participation ...
    --delay ... --stale-policy ... --topology ... --tier-compression
    ... --cohort ... --arena ... --telemetry jsonl:path`)."""

    compression: str = "none"
    #: per-leaf compression plan — comma-separated ``pattern:spec`` rules
    #: for :func:`repro.core.compressors.parse_plan`, first-match-wins
    #: (``"embed*:q12,ln*:bf16,*:shift:q6"``; patterns glob slash-joined
    #: leaf paths or name flatten-order leaf indices), or a ready
    #: :class:`~repro.core.compressors.CompressionPlan` (e.g. from
    #: ``plan.allocate``). Mutually exclusive with ``compression`` — a
    #: plan IS the uplink compressor; ``error_feedback`` applies per rule.
    compression_plan: Any = "none"
    participation: float = 1.0
    delay: str = "none"
    stale_policy: str = "last"
    topology: str = "star"
    tier_compression: str = "none"
    error_feedback: bool | None = None
    cohort: int | str | None = "none"
    arena: bool = False
    telemetry: Any = False
    seed: int = 0

    def apply(self, algo):
        from repro.core.compressors import from_spec, parse_plan
        from repro.core.engine import (with_arena, with_cohort,
                                       with_compression, with_delay,
                                       with_participation, with_telemetry,
                                       with_topology)

        algo = with_arena(algo, self.arena)
        algo = with_topology(algo, self.topology, seed=self.seed,
                             tier_compression=self.tier_compression)
        algo = with_participation(algo, self.participation, seed=self.seed)
        comp = from_spec(self.compression)  # one normalizer for the grammar
        plan = parse_plan(self.compression_plan,
                          error_feedback=self.error_feedback)
        if comp is not None and plan is not None:
            raise ValueError(
                "pass EITHER compression= or compression_plan=, not both — "
                "a plan IS the uplink compressor (put a '*:<spec>' "
                "catch-all rule in the plan for the uniform part): "
                f"compression={self.compression!r}, "
                f"compression_plan={self.compression_plan!r}")
        if plan is not None:
            algo = with_compression(algo, compressor=plan, seed=self.seed)
        if comp is not None:
            algo = with_compression(algo, compressor=comp,
                                    error_feedback=self.error_feedback,
                                    seed=self.seed)
        algo = with_delay(algo, self.delay, policy=self.stale_policy,
                          seed=self.seed)
        # cohort last: it wraps the fully-composed spec so every transform
        # above runs inside the O(cohort) gathered round.
        algo = with_cohort(algo, self.cohort, seed=self.seed)
        # telemetry is an observer — attach after everything so captures
        # see the final composed round (exact no-op when disabled).
        return with_telemetry(algo, self.telemetry)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned workload shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def supports_shape(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Shape-coverage policy (documented in DESIGN.md §5)."""
    if shape.name == "long_500k":
        sub_quadratic = (
            cfg.family in ("ssm", "hybrid")
            or cfg.attention in ("sliding", "chunked")
        )
        if not sub_quadratic:
            return False, ("pure full-attention arch: 500k decode requires a "
                           "sub-quadratic attention variant (DESIGN.md §5)")
    return True, ""
