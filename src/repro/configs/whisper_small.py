"""whisper-small [audio] — encoder-decoder with conv frontend (stubbed)
[arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is the assignment's allowed
stub: input_specs() supplies 1500 precomputed frame embeddings per sample.
12 encoder + 12 decoder layers, MHA, LayerNorm/GELU/biases.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,          # decoder layers
    encoder_layers=12,
    encoder_len=1500,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,        # MHA
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    use_rope=False,       # sinusoidal positions
    attn_bias=True,
    mlp_bias=True,
    tie_embeddings=True,
    modality="audio",
    citation="arXiv:2212.04356",
)
