"""fedlm-100m — the paper-side end-to-end training config (not one of the 10
assigned archs): a ~100M-parameter dense LM used by examples/fed_train_lm.py
to demonstrate FedCET federated training at laptop-visible scale. The
reduced() variant of this config is what the CPU example actually steps."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="fedlm-100m",
    family="dense",
    n_layers=14,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=16384,
    activation="swiglu",
    scan_layers=True,
    remat=False,
    citation="(paper-side example config)",
)
