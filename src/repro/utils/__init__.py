from repro.utils.tree import (
    tree_add,
    tree_axpy,
    tree_bytes,
    tree_client_mean,
    tree_l2_norm,
    tree_num_params,
    tree_scale,
    tree_sub,
    tree_zeros_like,
)

__all__ = [
    "tree_add",
    "tree_axpy",
    "tree_bytes",
    "tree_client_mean",
    "tree_l2_norm",
    "tree_num_params",
    "tree_scale",
    "tree_sub",
    "tree_zeros_like",
]
