"""Ambient activation-sharding context.

Model code is mesh-agnostic; the launch drivers (dryrun/train/serve) enable
activation constraints for the production mesh via::

    with activation_sharding(residual=P(None, "model", None)):
        ... trace/lower the step ...

and the model blocks call ``shard_residual(x)`` on the residual stream at
layer boundaries. On CPU tests (no context) it is the identity. The default
production spec shards the SEQUENCE dimension over the `model` axis between
layers (Megatron-style sequence parallelism): with remat + scan-over-layers
the per-layer saved carry is the residual stream, so sequence-sharding it is
what keeps multi-B-parameter training inside HBM (see EXPERIMENTS.md
§Dry-run for the before/after).
"""

from __future__ import annotations

import contextlib
import threading

import jax

_state = threading.local()


@contextlib.contextmanager
def activation_sharding(residual=None, logits=None, moe_shards=None):
    """moe_shards: optional ('batch'|'seq', n_shards) enabling the
    locality-preserving token-sharded MoE dispatch (see models/moe.py)."""
    prev = (getattr(_state, "residual", None), getattr(_state, "logits", None),
            getattr(_state, "moe_shards", None))
    _state.residual = residual
    _state.logits = logits
    _state.moe_shards = moe_shards
    try:
        yield
    finally:
        _state.residual, _state.logits, _state.moe_shards = prev


def moe_shards():
    return getattr(_state, "moe_shards", None)


def shard_residual(x):
    spec = getattr(_state, "residual", None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def shard_logits(x):
    """Per-chunk CE logits: vocab over `model` (the residual constraint moves
    the model axis to seq, so un-constrained logits would replicate the
    vocab dim — 13 GB/device at llama4's 202k vocab)."""
    spec = getattr(_state, "logits", None)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)
