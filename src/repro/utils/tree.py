"""Pytree arithmetic helpers used across the federated algorithms.

All federated state in this framework is represented as *stacked* pytrees:
every leaf carries a leading ``clients`` axis, so a mean over clients is a
``jnp.mean(..., axis=0)`` on every leaf. Under ``pjit`` with the client axis
sharded over the ``("pod", "data")`` mesh axes, that mean lowers to the single
cross-client all-reduce that constitutes a FedCET communication round.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: s * x, a)


def tree_axpy(s, a, b):
    """``s * a + b`` leaf-wise."""
    return jax.tree.map(lambda x, y: s * x + y, a, b)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_client_mean(a, *, keepdims: bool = True):
    """Mean over the leading clients axis of every leaf.

    With ``keepdims=True`` the result broadcasts back against the stacked
    tree, which is the shape the parameter-server broadcast would produce.
    """
    return jax.tree.map(lambda x: jnp.mean(x, axis=0, keepdims=keepdims), a)


def tree_l2_norm(a) -> jax.Array:
    leaves = jax.tree.leaves(a)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_num_params(a) -> int:
    return sum(x.size for x in jax.tree.leaves(a))


def tree_bytes(a) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(a))
