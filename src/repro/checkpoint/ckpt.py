"""Checkpointing: pytree <-> .npz with structure-preserving keys.

Flat key encoding: each leaf path is joined with '/'. Dict/list/tuple/
NamedTuple containers are reconstructed from a JSON treedef sidecar stored
inside the same npz, so arbitrary algorithm states (FedCET's (x, d),
SCAFFOLD's controls, Adam moments) round-trip exactly. Steps are retained
round-robin (``keep`` most recent).
"""

from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_pytree(path: str, tree) -> None:
    leaves, treedef = _flatten(tree)
    payload = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    payload["treedef"] = np.frombuffer(
        json.dumps(str(treedef)).encode(), dtype=np.uint8)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp.npz"
    np.savez(tmp, **payload)
    os.replace(tmp, path)


def load_pytree(path: str, like):
    """Restore into the structure of ``like`` (whose treedef must match)."""
    with np.load(path) as z:
        n = sum(1 for k in z.files if k.startswith("leaf_"))
        leaves = [z[f"leaf_{i}"] for i in range(n)]
    ref_leaves, treedef = _flatten(like)
    assert treedef.num_leaves == len(leaves), (treedef.num_leaves, len(leaves))
    # Leaf count alone cannot detect a reordered state layout (e.g. a
    # checkpoint written by an older state structure) — that would restore
    # leaves transposed. Fail loudly on any shape mismatch instead.
    for i, (got, ref) in enumerate(zip(leaves, ref_leaves)):
        if tuple(got.shape) != tuple(np.shape(ref)):
            raise ValueError(
                f"checkpoint {path!r} is incompatible with the requested "
                f"state layout: leaf {i} has shape {tuple(got.shape)}, "
                f"expected {tuple(np.shape(ref))} (was it written by an "
                "older algorithm-state structure?)")
    return jax.tree.unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:09d}.npz")
    save_pytree(path, tree)
    steps = sorted(all_steps(ckpt_dir))
    for old in steps[:-keep]:
        os.remove(os.path.join(ckpt_dir, f"step_{old:09d}.npz"))
    return path


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for f in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)\.npz", f)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like, step: int | None = None):
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None, None
    path = os.path.join(ckpt_dir, f"step_{step:09d}.npz")
    return load_pytree(path, like), step
