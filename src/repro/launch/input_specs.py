"""Model-input construction: concrete batches (smoke tests / examples) and
ShapeDtypeStruct stand-ins (the multi-pod dry-run; no device allocation).

Batch layouts per family:
  text/moe/ssm/hybrid : {"tokens": [B, S] int32}
  vlm                 : + {"image_embeds": [B, n_modal_tokens, d] bf16/f32}
                          (stubbed anyres vision tower output)
  audio               : {"frames": [B, encoder_len, d]} (stubbed conv
                          frontend output) + {"tokens": [B, S] int32}

Training adds the clients axis outside these shapes: the federated
train_step consumes [tau, clients, B_local, ...] leaves.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def batch_shapes(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """{name: (shape, dtype)} for a single (non-federated) batch."""
    emb_dtype = jnp.dtype(cfg.dtype)
    shapes = {"tokens": ((batch, seq_len), jnp.int32)}
    if cfg.family == "vlm":
        shapes["image_embeds"] = ((batch, cfg.n_modal_tokens, cfg.d_model),
                                  emb_dtype)
    if cfg.family == "audio":
        shapes["frames"] = ((batch, cfg.encoder_len, cfg.d_model), emb_dtype)
    return shapes


def make_batch(cfg: ArchConfig, batch: int, seq_len: int, *, key=0) -> dict:
    """Concrete random batch (smoke tests, examples)."""
    if isinstance(key, int):
        key = jax.random.key(key)
    out = {}
    for name, (shape, dtype) in batch_shapes(cfg, batch, seq_len).items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(dtype, jnp.integer):
            out[name] = jax.random.randint(k, shape, 0, cfg.vocab_size, dtype)
        else:
            out[name] = (jax.random.normal(k, shape) * 0.02).astype(dtype)
    return out


def batch_specs(cfg: ArchConfig, batch: int, seq_len: int) -> dict:
    """ShapeDtypeStruct stand-ins for the dry-run (never allocated)."""
    return {
        name: jax.ShapeDtypeStruct(shape, dtype)
        for name, (shape, dtype) in batch_shapes(cfg, batch, seq_len).items()
    }


def fed_batch_specs(cfg: ArchConfig, tau: int, n_clients: int,
                    per_client_batch: int, seq_len: int) -> dict:
    """[tau, clients, ...] ShapeDtypeStructs for the federated train step."""
    return {
        name: jax.ShapeDtypeStruct((tau, n_clients) + shape, dtype)
        for name, (shape, dtype) in batch_shapes(
            cfg, per_client_batch, seq_len).items()
    }
