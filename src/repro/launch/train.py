"""Distributed federated training driver.

``build_train_step`` assembles the jitted FedCET communication round for a
given (arch, mesh): the paper's Algorithm 2 applied to the real model, with

  * clients laid out along the ("pod", "data") mesh axes (one model replica
    + one heterogeneous data shard per client),
  * each replica tensor-parallel over "model" (partition.py rules),
  * Megatron-style sequence-sharded residual activations,
  * the single FedCET vector aggregated by ONE cross-client all-reduce per
    tau gradient steps — the only collective crossing the pod boundary.

Also provides ``run_training`` — the end-to-end loop used by the examples
(single host: same code, 1x1 mesh semantics, no sharding constraints).

Run as a script for a production-launch entry point:
    python -m repro.launch.train --arch qwen3-1.7b --steps 100 ...
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES, ArchConfig, FedScenario
from repro.core.engine import EngineState, make_round_runner, scan_segments
from repro.core.fedcet import FedCET, FedCETState
from repro.core.staleness import DelayState
from repro.core.topology import TopoState
from repro.launch import input_specs as ispec
from repro.launch import partition
from repro.launch.mesh import client_axes, n_clients, tp_size
from repro.models import build_model
from repro.utils.sharding_ctx import activation_sharding


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    cfg: ArchConfig
    algo: Any  # FedCET, possibly wrapped by scenario transforms
    mesh: Any
    n_clients: int
    per_client_batch: int
    seq_len: int

    @property
    def client_axes(self) -> tuple[str, ...]:
        return client_axes(self.mesh)


def make_plan(arch: str, mesh, *, shape_name: str = "train_4k",
              tau: int = 2, alpha: float = 1e-3, c: float = 0.05,
              dtype: str = "bfloat16",
              scenario: FedScenario | None = None) -> TrainPlan:
    from repro.launch.overrides import distribution_for, train_mesh_view

    cfg = get_config(arch).with_dtype(dtype)
    shp = INPUT_SHAPES[shape_name]
    dist = distribution_for(arch)
    mesh = train_mesh_view(mesh, dist.fsdp)  # may split data -> (data, fsdp)
    nc = n_clients(mesh)
    assert shp.global_batch % nc == 0, (shp.global_batch, nc)
    algo = FedCET(alpha=alpha, c=c, tau=tau, n_clients=nc,
                  spmd_client_axes=client_axes(mesh))
    if scenario is not None:
        algo = scenario.apply(algo)
    return TrainPlan(cfg=cfg, algo=algo, mesh=mesh, n_clients=nc,
                     per_client_batch=shp.global_batch // nc,
                     seq_len=shp.seq_len)


def _fsdp(plan: TrainPlan) -> str | None:
    return "fsdp" if "fsdp" in plan.mesh.axis_names else None


def state_shardings(plan: TrainPlan, state_shapes):
    """Shardings for the algorithm state: x and d are stacked-client param
    trees; transform extras (error-feedback / shift memory) and the delay
    buffer are message-shaped — the same stacked layout as x — and shard
    identically (the buffer's ``[clients] int32`` age vector shards over
    the client axes); a stateful topology's ``TopoState`` is replicated —
    the scalar mixing round index, plus (for hierarchies with stateful
    tier compression) the small per-aggregator tier memory."""
    mesh, tp, ca = plan.mesh, tp_size(plan.mesh), plan.client_axes
    inner_shapes = (state_shapes.inner
                    if isinstance(state_shapes, EngineState) else state_shapes)
    tree_sh = lambda tree: partition.tree_shardings(  # noqa: E731
        tree, mesh, tp, ca, extra_axis=_fsdp(plan))
    inner_sh = FedCETState(x=tree_sh(inner_shapes.x), d=tree_sh(inner_shapes.d),
                           t=NamedSharding(mesh, P()))
    if not isinstance(state_shapes, EngineState):
        return inner_sh

    def extra_sh(e):
        if e is None:
            return None
        if isinstance(e, TopoState):
            return jax.tree.map(lambda _: NamedSharding(mesh, P()), e)
        return tree_sh(e)

    return EngineState(inner=inner_sh,
                       extras=tuple(extra_sh(e) for e in state_shapes.extras))


def abstract_state(plan: TrainPlan):
    """Shape-only algorithm state (no allocation) for AOT lowering:
    FedCETState, wrapped in EngineState when the plan's scenario attaches
    message transforms (extras shaped via ``eval_shape`` over each
    transform's ``init_extra`` on the message = x-shaped tree), a
    STATEFUL topology (a scalar ``TopoState`` round index, just before
    the delay slot) and/or a delay model (final extras slot = the server
    buffer: an x-shaped last-known message tree plus the ``[clients]
    int32`` age vector)."""
    model = build_model(plan.cfg)
    params = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    stack = lambda tree: jax.tree.map(
        lambda a: jax.ShapeDtypeStruct((plan.n_clients,) + a.shape, a.dtype), tree)
    inner = FedCETState(x=stack(params), d=stack(params),
                        t=jax.ShapeDtypeStruct((), jnp.int64))
    transforms = getattr(plan.algo, "transforms", ())
    delay = getattr(plan.algo, "delay", None)
    topo = getattr(plan.algo, "topology", None)
    topo_stateful = topo is not None and topo.stateful
    if not transforms and delay is None and not topo_stateful:
        return inner
    extras = tuple(jax.eval_shape(lambda t=t: t.init_extra(inner.x))
                   for t in transforms)
    if topo_stateful:
        # the scalar round index, plus — for hierarchies with stateful
        # tier compression — the per-tier memory shaped from the
        # (x-shaped) message tree, exactly as the engine inits it.
        extras = extras + (jax.eval_shape(lambda: topo.init_state(inner.x)),)
    if delay is not None:
        extras = extras + (DelayState(
            buf=inner.x,
            age=jax.ShapeDtypeStruct((plan.n_clients,), jnp.int32)),)
    return EngineState(inner=inner, extras=extras)


def build_round_fn(plan: TrainPlan) -> Callable:
    """The pure function jitted as the production train step."""
    model = build_model(plan.cfg)
    grad_fn = jax.grad(model.loss)
    algo = plan.algo

    def train_round(state: FedCETState, batches):
        return algo.round(grad_fn, state, batches)

    return train_round


def lower_train_step(plan: TrainPlan, *, donate: bool = True):
    """AOT lower + compile the FedCET round on the production mesh.

    ``donate`` aliases the state argument into the output so the stacked
    client store ((x, d), transform extras, delay buffers) updates in
    place instead of doubling peak memory at large N — essential once the
    cohort path scatters into an O(N)-row store. The dry-run path passes
    ``donate=False``: on the CPU backend, ``memory_analysis`` double-counts
    the aliased while-carry, so recorded numbers stay donation-free
    (EXPERIMENTS.md §Dry-run)."""
    mesh = plan.mesh
    state_shapes = abstract_state(plan)
    batch_shapes = ispec.fed_batch_specs(
        plan.cfg, plan.algo.tau, plan.n_clients, plan.per_client_batch,
        plan.seq_len)
    st_sh = state_shardings(plan, state_shapes)
    b_sh = partition.batch_shardings(
        batch_shapes, mesh,
        dim_axes=(None, plan.client_axes, _fsdp(plan)))
    fn = build_round_fn(plan)
    tp = tp_size(mesh)
    # token-sharded MoE dispatch when experts don't divide the model axis
    # (EXPERIMENTS.md §Perf iteration 1); per-client tokens are seq-sharded
    # over `model` (and batch over fsdp when present).
    moe = None
    if plan.cfg.n_experts and plan.cfg.n_experts % tp:
        fs = _fsdp(plan)
        nb = mesh.shape[fs] if fs else 1
        axes = (fs, "model") if fs else ("model",)
        moe = {"nb": nb, "ns": tp, "axes": axes,
               "spec": P(axes if len(axes) > 1 else axes[0], None, None)}
    with mesh:
        # per-client activations [B, S, d]: batch over fsdp (when present),
        # sequence over model (Megatron SP), d replicated.
        with activation_sharding(residual=P(_fsdp(plan), "model", None),
                                 logits=P(_fsdp(plan), None, "model"),
                                 moe_shards=moe):
            lowered = jax.jit(
                fn, in_shardings=(st_sh, b_sh), out_shardings=st_sh,
                donate_argnums=(0,) if donate else (),
            ).lower(state_shapes, batch_shapes)
    return lowered


# --------------------------------------------------------- single-host loop
def run_training(arch: str, *, steps: int = 100, tau: int = 2,
                 n_clients: int = 4, batch: int = 8, seq_len: int = 128,
                 alpha: float = 3e-3, c: float = 0.05, heterogeneity: float = 0.8,
                 reduced: bool = True, seed: int = 0,
                 compression: str = "none", compression_plan="none",
                 plan_adapt: float = 0.0, participation: float = 1.0,
                 delay: str = "none", stale_policy: str = "last",
                 topology: str = "star", tier_compression: str = "none",
                 cohort: int | str | None = "none", arena: bool = False,
                 telemetry: str | None = None, trace_rounds: str | None = None,
                 trace_dir: str = "profile_trace",
                 log_every: int = 10, ckpt_dir: str | None = None,
                 callback=None) -> dict:
    """End-to-end FedCET LM training on the host device(s). Returns metrics
    history. Used by examples/fed_train_lm.py.

    ``compression`` (a compressor spec — ``"randk:0.25"``, ``"shift:q8"``,
    ``"ef:topk:0.3+bf16"``, ...) or ``compression_plan`` (the PER-LEAF
    alternative: first-match-wins ``pattern:spec`` rules over leaf paths,
    ``"embed*:q12,ln*:bf16,*:shift:q6"``, or a ready
    ``CompressionPlan`` — e.g. from ``plan.allocate`` — billed exactly
    per leaf; ``plan_adapt > 1`` additionally tightens the plan one step
    each time the telemetry ``compress_err`` residual shrinks by that
    factor, re-jitting at the segment boundary with the carried state —
    requires ``telemetry``), ``participation``, ``delay`` /
    ``stale_policy`` (asynchronous rounds — ``"fixed:2"``, ``"rr:1"``,
    ``"geom:0.5"`` with ``drop``/``last``/``poly:a`` aggregation),
    ``topology`` (aggregation geometry — ``"hier:g8"`` edge-aggregator
    tree, ``"ring"``/``"torus"``/``"er:0.4"`` gossip mixing; a trailing
    ``":sparse"`` selects the O(edges) padded neighbor-exchange
    lowering) and ``tier_compression`` (hierarchies: re-compress the
    interior edge->root tier uplinks, e.g. ``"shift:q8"``) compose
    the corresponding engine transforms onto the FedCET spec, so the
    production LM loop runs any scenario the simulation tests pin; comm
    metering is bit-true from the resulting compressor stack, the delay
    model's uplink duty cycle, the sampling rate's downlink duty cycle,
    and the topology's per-hop traffic shape (compressed interior tiers
    included). ``cohort`` (``"none"`` | ``256`` | ``"block:256"`` |
    ``"rr:256"``) runs each round on a gathered fixed-size cohort of the
    client-state store — O(cohort) per-round work with only the cohort's
    uplink billed. ``arena`` packs the client store into the contiguous
    ``[clients, rows, 1024]`` parameter arena (unpacking only at the
    per-client gradient call) so the round tail streams one buffer
    instead of one per pytree leaf — numerically <=1e-12-equivalent.

    ``telemetry`` is a sink spec (``"jsonl:run.jsonl"``, ``"csv:m.csv"``,
    ``"stdout[:k]"``, ``"memory"``, comma-chained) — any non-empty spec
    attaches the in-trace telemetry transform (per-round norms, invariant
    residual, consensus error, staleness ages — captured inside the jitted
    scan, drained into the sinks per segment behind a run manifest).
    Adding ``hist[:bins[:lo:hi]]`` / ``topk[:k]`` parts to the same
    string turns on the population distribution sketches (per-client
    ``||d_i||``, drift, compression error and age log-histograms +
    quantiles + top-k outlier client ids, one O(N) pass over the full
    client store per round); ``leafstats`` adds the per-leaf
    msg_norm/compress_err breakdown as ``leaf_stats`` events. The drain
    also runs an online linear-rate estimator whose ``rho_hat`` rides
    each round event and WARNs on rate breaks naming the suspect axis.
    ``trace_rounds`` (``"a:b"`` or ``"a"``) brackets that round window
    with a ``jax.profiler`` trace written under ``trace_dir`` — segment
    boundaries are forced at the window edges so the trace covers exactly
    those rounds. Per-round stdout summary lines (round, loss, bits_up,
    active_clients) print for every ``log_every``-th round."""
    from repro.checkpoint.ckpt import save
    from repro.core import telemetry as tele
    from repro.core.comm import CommMeter, comm_bits_per_round, leaf_info_of
    from repro.data.synthetic import make_hetero_lm_dataset

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    scenario = FedScenario(compression=compression,
                           compression_plan=compression_plan,
                           participation=participation, delay=delay,
                           stale_policy=stale_policy, topology=topology,
                           tier_compression=tier_compression, cohort=cohort,
                           arena=arena, telemetry=telemetry or False,
                           seed=seed)
    algo = scenario.apply(FedCET(alpha=alpha, c=c, tau=tau, n_clients=n_clients))
    ds = make_hetero_lm_dataset(cfg.vocab_size, n_clients, seq_len, batch,
                                heterogeneity=heterogeneity, seed=seed)
    grad_fn = jax.grad(model.loss)

    def batches_for(r):
        toks = ds.sample_round(r, tau)  # [tau, C, B, S]
        return {"tokens": toks}

    state = algo.init(grad_fn, params, jax.tree.map(lambda b: b[0], batches_for(0)))

    # per-round mean client loss ON-DEVICE inside the scan (same expression
    # the old boundary-only eval computed on the segment's last round, so
    # logged history values are unchanged).
    def round_loss(s, b):
        b0 = jax.tree.map(lambda a: a[0], b)
        return jnp.mean(jax.vmap(model.loss)(algo.client_params(s), b0))

    # the shared multi-round scan driver: rounds between log/checkpoint
    # boundaries run as one jitted lax.scan segment. The carry is donated
    # so the client store ((x, d), extras, delay buffers) updates in
    # place — the loop below rebinds `state` each call, never reusing the
    # donated buffers.
    runner = make_round_runner(algo, grad_fn, metric_fn=round_loss,
                               metric_with_batch=True, donate=True)

    sinks = tele.parse_sinks(telemetry)
    tel_spec = getattr(algo, "telemetry", None)
    # passing the algo gives the monitor set a RateMonitor that names the
    # attached lossy axes when the measured linear rate breaks.
    monitors = tele.resolve_monitors(tel_spec, algo)
    leaf_info = leaf_info_of(params)
    leaf_names = None
    if tel_spec is not None and tel_spec.leaf_stats:
        # the canonical slash-joined names — the same vocabulary plan
        # globs match and per-leaf billing reports, so report.py can join
        # leaf_stats rows against the manifest's leaf_bits budget.
        leaf_names = [nm for nm, _ in leaf_info]
    trace = tele.TraceSession(tele.parse_trace_rounds(trace_rounds),
                              out_dir=trace_dir)
    trace_stops = set(trace.boundaries())

    def is_stop(r):
        return (r % log_every == 0 or r == steps - 1 or r in trace_stops
                or (ckpt_dir is not None and (r + 1) % 50 == 0))

    meter = CommMeter.for_params(params, algo=algo, n_clients=n_clients)
    per_round_bits = comm_bits_per_round(algo, meter.n_params, n_clients,
                                         leaf_info)
    adaptive = None
    if plan_adapt and plan_adapt > 1.0:
        from repro.core.compressors import AdaptivePlan, CompressionPlan

        plans = [t.compressor for t in algo.transforms
                 if isinstance(getattr(t, "compressor", None),
                               CompressionPlan)]
        if not plans:
            raise ValueError("plan_adapt needs a compression_plan attached")
        if telemetry is None:
            raise ValueError("plan_adapt reads the telemetry compress_err "
                             "residual; pass --telemetry")
        adaptive = AdaptivePlan(plan=plans[-1], factor=float(plan_adapt))

    def _swap_plan(a, plan):
        from repro.core.compressors import CompressionPlan

        ts = tuple(dataclasses.replace(t, compressor=plan)
                   if isinstance(getattr(t, "compressor", None),
                                 CompressionPlan) else t
                   for t in a.transforms)
        return dataclasses.replace(a, transforms=ts)
    # fallback when telemetry is off: the expected participant count (with
    # telemetry on, the line reports the exact in-trace count).
    expected_active = int(round(n_clients * min(participation, 1.0)))
    if sinks:
        tele.emit_event(sinks, tele.run_manifest(
            algo, n_params=meter.n_params,
            config={"arch": arch, "steps": steps, "tau": tau,
                    "n_clients": n_clients, "batch": batch,
                    "seq_len": seq_len, "compression": compression,
                    "compression_plan": str(compression_plan),
                    "plan_adapt": plan_adapt,
                    "participation": participation, "delay": delay,
                    "stale_policy": stale_policy, "topology": topology,
                    "tier_compression": tier_compression,
                    "cohort": str(cohort), "arena": arena, "seed": seed},
            monitors=monitors, leaf_info=leaf_info))
    history = {"round": [], "loss": [], "comm_bytes": []}
    for r, stop in scan_segments(0, steps, is_stop):
        ev = trace.maybe_start(r)
        if ev:
            tele.emit_event(sinks, ev)
        per_round = [batches_for(i) for i in range(r, stop + 1)]
        stacked = jax.tree.map(lambda *bs: jnp.stack(bs), *per_round)
        state, ys = runner(state, stacked)
        losses, tel_series = tele.split_metrics(algo, ys)
        ev = trace.maybe_stop(stop + 1)
        if ev:
            tele.emit_event(sinks, ev)
        if tel_series is not None and sinks:
            # the per-round loss rides the round events so the rate
            # estimator / report can read the LM convergence curve.
            tele.drain({**tel_series, "loss": losses}, sinks=sinks,
                       monitors=monitors, start_round=r, algo=algo,
                       n_params=meter.n_params, leaf_names=leaf_names,
                       leaf_bits=meter.leaf_bits)
        for _ in range(r, stop + 1):
            meter.tick_round(algo)
        if adaptive is not None and tel_series is not None \
                and "compress_err" in tel_series:
            new_plan = adaptive.update(
                float(jax.device_get(tel_series["compress_err"])[-1]))
            if new_plan is not None:
                # segment boundary: swap the tightened plan into the
                # attached transform and re-jit. Wrapper structure (and so
                # the extras pytree) is preserved, so the donated state
                # carries straight into the new runner.
                algo = _swap_plan(algo, new_plan)
                runner = make_round_runner(algo, grad_fn,
                                           metric_fn=round_loss,
                                           metric_with_batch=True,
                                           donate=True)
                meter = dataclasses.replace(
                    CommMeter.for_params(params, algo=algo,
                                         n_clients=n_clients),
                    rounds=meter.rounds, bytes_up=meter.bytes_up,
                    bytes_down=meter.bytes_down)
                per_round_bits = comm_bits_per_round(
                    algo, meter.n_params, n_clients, leaf_info)
                if sinks:
                    tele.emit_event(sinks, {
                        "event": "plan_adapt", "round": stop,
                        "bits_per_round": per_round_bits["up_bits"]})
        losses = jax.device_get(losses)
        active = None if tel_series is None else tel_series.get("participating")
        for i, rr in enumerate(range(r, stop + 1)):
            if rr % log_every == 0 or rr == steps - 1:
                a = expected_active if active is None else int(active[i])
                print(f"round {rr:5d}  loss {float(losses[i]):.4f}  "
                      f"bits_up {(rr + 1) * per_round_bits['up_bits']:.4g}  "
                      f"active_clients {a}")
        if stop % log_every == 0 or stop == steps - 1:
            loss = float(losses[-1])
            history["round"].append(stop)
            history["loss"].append(loss)
            history["comm_bytes"].append(meter.total)
            if callback:
                callback(stop, loss, meter.total)
        if ckpt_dir and (stop + 1) % 50 == 0:
            save(ckpt_dir, stop + 1, state)
    trace.close()
    tele.close_sinks(sinks)
    return history


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--alpha", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) architecture")
    ap.add_argument("--compression", default="none",
                    help="uplink compressor spec: none | bf16 | topk:0.3 | "
                         "randk:0.25 | q8 | shift:q8 | randk:0.5+q8 | ef:...")
    ap.add_argument("--compression-plan", default="none",
                    help="PER-LEAF uplink compression plan: comma-separated"
                         " first-match-wins pattern:spec rules over leaf "
                         "paths (glob or flatten-order leaf index), e.g. "
                         "'embed*:q12,ln*:bf16,*:shift:q6'; mutually "
                         "exclusive with --compression; billed exactly per "
                         "leaf (actual kept counts)")
    ap.add_argument("--plan-adapt", type=float, default=0.0,
                    help="adaptive plan schedule: tighten the plan one "
                         "step (quantizers -1 bit, sparsifiers k/2) each "
                         "time the telemetry compress_err residual shrinks"
                         " by this factor (> 1 enables; needs "
                         "--compression-plan and --telemetry)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="per-round Bernoulli client participation rate")
    ap.add_argument("--delay", default="none",
                    help="uplink delay model: none | fixed:2 | rr:1 | geom:0.5")
    ap.add_argument("--stale-policy", default="last",
                    help="stale-aggregation policy: drop | last | poly:1")
    ap.add_argument("--topology", default="star",
                    help="aggregation geometry: star | hier:g8 | hier:16x4 "
                         "| ring | torus | er:0.4 (gossip specs take a "
                         "trailing :sparse for the padded neighbor-exchange "
                         "lowering, e.g. ring:sparse, er:0.4:t:sparse)")
    ap.add_argument("--tier-compression", default="none",
                    help="hierarchies only: compressor spec for interior "
                         "edge->root tier uplinks (e.g. shift:q8)")
    ap.add_argument("--cohort", default="none",
                    help="cohort spec: none | 256 | block:256 | rr:256 "
                         "(optional trailing :dense forces the dense "
                         "reference lowering) — run each round on a "
                         "sampled fixed-size cohort, O(cohort) not O(N)")
    ap.add_argument("--arena", action="store_true",
                    help="pack the client store into the contiguous "
                         "[clients, rows, 1024] parameter arena (fused "
                         "round tail; <=1e-12-equivalent to per-leaf)")
    ap.add_argument("--telemetry", default=None,
                    help="telemetry sink spec: jsonl:<path> | csv:<path> | "
                         "stdout[:every] | memory (comma-chained). Any "
                         "non-empty spec enables in-trace round telemetry "
                         "+ invariant/rate monitors; add hist[:bins[:lo:hi]]"
                         " / topk:<k> parts for the per-client distribution"
                         " sketches and leafstats for the per-leaf "
                         "msg_norm/compress_err breakdown (e.g. "
                         "'jsonl:run.jsonl,hist:48,topk:4'); omitted = "
                         "bitwise no-op")
    ap.add_argument("--log-every", type=int, default=10,
                    help="print a per-round summary line (round, loss, "
                         "bits_up, active_clients) every k rounds")
    ap.add_argument("--trace-rounds", default=None,
                    help="profile round window 'a:b' (or 'a') with "
                         "jax.profiler — trace written under --trace-dir")
    ap.add_argument("--trace-dir", default="profile_trace")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)
    hist = run_training(
        args.arch, steps=args.steps, tau=args.tau, n_clients=args.clients,
        batch=args.batch, seq_len=args.seq_len, alpha=args.alpha,
        reduced=not args.full, ckpt_dir=args.ckpt_dir,
        compression=args.compression,
        compression_plan=args.compression_plan, plan_adapt=args.plan_adapt,
        participation=args.participation,
        delay=args.delay, stale_policy=args.stale_policy,
        topology=args.topology, tier_compression=args.tier_compression,
        cohort=args.cohort, arena=args.arena,
        telemetry=args.telemetry, trace_rounds=args.trace_rounds,
        trace_dir=args.trace_dir, log_every=args.log_every)
    print("final loss:", hist["loss"][-1])


if __name__ == "__main__":
    main()
