"""Parameter / input / cache PartitionSpec assignment.

Rules are name-based over the param-dict paths (the pytrees are plain
dicts, so the path is a readable module path like ``layers/attn/wq``), and
divisibility-aware: a dimension is only sharded over `model` when its size
divides the axis; otherwise the rule falls through to the next-best dim
(e.g. granite's 40 experts don't divide a 16-way model axis, so its expert
FFN shards the tiny d_ff instead). Megatron conventions throughout:
column-parallel in-projections, row-parallel out-projections, vocab-sharded
embeddings, expert-parallel MoE when divisible.

Stacked leading dims (scan-over-layers [L, ...], hybrid groups [G, every,
...], and the federated clients axis) are handled by right-aligning the rule
to the trailing logical dims and padding/prepending the rest.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _tp_if(n: int, tp: int):
    return "model" if n % tp == 0 and n >= tp else None


def _base_spec(path: tuple[str, ...], shape: tuple[int, ...], tp: int):
    """Spec for the TRAILING logical dims of one leaf. Returns a tuple whose
    length is the number of trailing dims it claims."""
    names = set(path)
    last = path[-1]
    in_moe = "moe" in names and "shared" not in names

    if last == "embed":
        return (_tp_if(shape[-2], tp), None)
    if last == "lm_head":
        return (None, _tp_if(shape[-1], tp))
    if last == "router":
        return (None, None)
    if in_moe and last in ("gate", "up"):
        e, _, f = shape[-3:]
        if e % tp == 0:
            return ("model", None, None)
        return (None, None, _tp_if(f, tp))
    if in_moe and last == "down":
        e, f, _ = shape[-3:]
        if e % tp == 0:
            return ("model", None, None)
        return (None, _tp_if(f, tp), None)
    if last in ("wq", "wk", "wv", "gate", "up", "wz", "wx"):
        return (None, _tp_if(shape[-1], tp))
    if last in ("wo", "out_proj", "down"):
        return (_tp_if(shape[-2], tp), None)
    if last == "conv_w":
        return (_tp_if(shape[-2], tp), None)
    # norms, biases, A_log, D, dt_bias, wB, wC, wdt, q_norm, ... -> replicated
    return ()


def _with_extra_axis(base: tuple, shape: tuple[int, ...], extra_axis: str,
                     extra_size: int) -> tuple:
    """ZeRO/2D-TP second weight axis: assign `extra_axis` to the first
    still-unsharded logical dim it divides."""
    if not base or extra_size <= 1:
        return base
    dims = shape[-len(base):]
    out = list(base)
    for i, (ax, dim) in enumerate(zip(base, dims)):
        if ax is None and dim % extra_size == 0 and dim >= extra_size:
            out[i] = extra_axis
            break
    return tuple(out)


def param_pspec(path: tuple[str, ...], leaf, tp: int,
                client_axes: tuple[str, ...] = (),
                extra_axis: str | None = None, extra_size: int = 1) -> P:
    base = _base_spec(path, leaf.shape, tp)
    if extra_axis:
        base = _with_extra_axis(base, leaf.shape, extra_axis, extra_size)
    n_pad = leaf.ndim - len(base) - (1 if client_axes else 0)
    if n_pad < 0:  # scalar-ish leaf under clients axis
        return P(*((client_axes,) if client_axes else ()))
    front = ((client_axes,) if client_axes else ())
    return P(*front, *(None,) * n_pad, *base)


def _path_names(kp) -> tuple[str, ...]:
    names = []
    for k in kp:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        elif hasattr(k, "name"):
            names.append(str(k.name))
    return tuple(names)


def tree_pspecs(tree, tp: int, client_axes: tuple[str, ...] = (),
                extra_axis: str | None = None, extra_size: int = 1):
    """PartitionSpec pytree mirroring ``tree`` (arrays or ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: param_pspec(_path_names(kp), leaf, tp, client_axes,
                                     extra_axis, extra_size),
        tree,
    )


def tree_shardings(tree, mesh: Mesh, tp: int, client_axes: tuple[str, ...] = (),
                   extra_axis: str | None = None):
    extra_size = mesh.shape[extra_axis] if extra_axis else 1
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        tree_pspecs(tree, tp, client_axes, extra_axis, extra_size),
        is_leaf=lambda x: isinstance(x, P),
    )


# --------------------------------------------------------------- serve side
def cache_pspec(path: tuple[str, ...], leaf, tp: int, dp, seq_axes) -> P:
    """KV/SSM cache sharding. dp = axis (tuple) for the batch dim or None;
    seq_axes = axes for the cache slot/seq dim (the long dim)."""
    last = path[-1]
    if last in ("k", "v"):           # [.., B, cap, Hkv, Dh]
        base = (dp, seq_axes, None, None)
    elif last in ("cross_k", "cross_v"):  # [.., B, T_enc, H, Dh]
        base = (dp, None, None, None)
    elif last == "conv":             # [.., B, K-1, ch]
        base = (dp, None, _tp_if(leaf.shape[-1], tp))
    elif last == "state":            # [.., B, H, P, N]
        h, p_dim = leaf.shape[-3], leaf.shape[-2]
        if h % tp == 0 and h >= tp:
            base = (dp, "model", None, None)
        elif p_dim % tp == 0 and p_dim >= tp:
            base = (dp, None, "model", None)
        else:
            base = (dp, None, None, None)
    elif last in ("pos", "length"):
        base = (None,) * leaf.ndim
        return P(*base[: leaf.ndim])
    else:
        base = (None,) * leaf.ndim
        return P(*base[: leaf.ndim])
    n_pad = leaf.ndim - len(base)
    return P(*(None,) * n_pad, *base)


def cache_shardings(caches, mesh: Mesh, *, batch: int):
    """Shardings for a cache pytree. Batch gets the client/data axes when it
    divides them; otherwise the sequence dim absorbs ALL mesh axes (the
    long_500k single-request layout)."""
    tp = mesh.shape["model"]
    from repro.launch.mesh import client_axes as _ca

    ca = _ca(mesh)
    dp_size = 1
    for a in ca:
        dp_size *= mesh.shape[a]
    if batch % dp_size == 0 and batch >= dp_size:
        dp, seq_axes = ca, "model"
    else:
        dp, seq_axes = None, ca + ("model",)

    def assign(kp, leaf):
        spec = cache_pspec(_path_names(kp), leaf, tp, dp, seq_axes)
        # never shard a dim the size doesn't divide
        fixed = []
        for ax, dim in zip(spec, leaf.shape):
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,) if ax else ()):
                size *= mesh.shape[a]
            fixed.append(ax if size and dim % size == 0 and dim >= size else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(assign, caches)


def batch_shardings(batch_tree, mesh: Mesh, *, dim_axes: tuple):
    """Input batches: ``dim_axes`` gives the axis (or axis tuple) for each
    leading dim; remaining dims are replicated.
    Train: dim_axes=(None, client_axes, fsdp_or_None) for [tau, C, B, ...];
    serve: dim_axes=(batch_axes,) for [B, ...]."""

    def assign(leaf):
        n_rest = leaf.ndim - len(dim_axes)
        return NamedSharding(mesh, P(*dim_axes, *(None,) * n_rest))

    return jax.tree.map(assign, batch_tree)
