import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) combination this lowers and
compiles the production step — the federated FedCET train round for
train_4k, serve prefill for prefill_32k, one-token cached serve_step for
decode_32k / long_500k — against 512 placeholder host devices, then records

  * compiled.memory_analysis()  (per-device bytes: proves it fits),
  * compiled.cost_analysis()    (raw XLA numbers, loop-undercount caveat),
  * the collective schedule parsed from the compiled HLO
    (loop-multiplier-corrected byte totals per collective kind),
  * the three roofline terms (analytic FLOPs/HBM model + parsed collectives)

into a JSON results file consumed by EXPERIMENTS.md and
benchmarks/roofline_table.py.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.json
  python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import time
import traceback


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            verbose: bool = True) -> dict:
    import jax

    from repro.configs import INPUT_SHAPES, get_config, supports_shape
    from repro.launch.mesh import make_production_mesh
    from repro.roofline.analysis import analyze_compiled
    from repro.roofline.flops import cost_for

    mesh_name = "2x16x16" if multi_pod else "16x16"
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch).with_dtype("bfloat16")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok"}

    ok, why = supports_shape(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape_name} x {mesh_name}: {why}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    t0 = time.time()
    if shape.kind == "train":
        from repro.launch.train import lower_train_step, make_plan

        plan = make_plan(arch, mesh, shape_name=shape_name)
        # donation off: CPU memory_analysis double-counts aliased carries,
        # and the dry-run's recorded numbers predate donation.
        lowered = lower_train_step(plan, donate=False)
    elif shape.kind == "prefill":
        from repro.launch.serve import lower_prefill

        lowered = lower_prefill(arch, mesh, shape_name=shape_name)
    else:
        from repro.launch.serve import lower_decode

        lowered = lower_decode(arch, mesh, shape_name=shape_name)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    raw_cost = compiled.cost_analysis()
    if isinstance(raw_cost, (list, tuple)):
        raw_cost = raw_cost[0] if raw_cost else {}
    hlo = compiled.as_text()
    cost = cost_for(cfg, shape, n_devices=n_devices)
    report = analyze_compiled(
        arch=arch, shape=shape_name, mesh_name=mesh_name,
        n_devices=n_devices, cost=cost, hlo_text=hlo, memory_stats=mem,
        raw_cost=raw_cost)

    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: args={mem.argument_size_in_bytes/1e9:.3f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.3f}GB "
              f"out={mem.output_size_in_bytes/1e9:.3f}GB per device")
        print(f"  cost_analysis:   flops={raw_cost.get('flops', 0):.3e} "
              f"(raw, loop bodies counted once)")
        print(f"  collectives:     {report.collective_detail['bytes_by_kind']}")
        print(f"  roofline terms:  compute={report.compute_s*1e3:.3f}ms "
              f"memory={report.memory_s*1e3:.3f}ms "
              f"collective={report.collective_s*1e3:.3f}ms "
              f"-> {report.bottleneck}-bound")

    rec.update(
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": int(mem.argument_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
        },
        roofline=report.as_dict(),
    )
    return rec


def merge_results(path: str, records: list[dict]) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    for r in records:
        data[f"{r['arch']}|{r['shape']}|{r['mesh']}"] = r
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1)
    os.replace(tmp, path)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) for the chosen mesh")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args(argv)

    from repro.configs import ASSIGNED, INPUT_SHAPES

    if args.all:
        combos = [(a, s) for a in ASSIGNED for s in INPUT_SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    records, failures = [], 0
    for arch, shape in combos:
        try:
            rec = run_one(arch, shape, multi_pod=args.multi_pod)
        except Exception as e:  # a failure here is a sharding bug: report it
            failures += 1
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            print(f"[dryrun] ERROR {arch} x {shape}: {e}")
        records.append(rec)
        merge_results(args.out, records)  # persist incrementally
    print(f"[dryrun] done: {len(records) - failures}/{len(records)} OK "
          f"-> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
