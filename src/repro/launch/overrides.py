"""Per-architecture distribution overrides.

The production device grid is fixed (16x16 per pod, 2x16x16 multi-pod), but
how the non-model axes are *interpreted* is a per-arch design decision:

* train: memory-heavy archs split the 16-way data axis into
  (clients x fsdp): each client's FedCET state (x, d — 4 bytes/param in
  bf16) additionally shards over `fsdp`, and the per-client batch also
  splits over `fsdp` (ZeRO-style: per-layer all-gather of weights inside
  the layer scan, gradient all-reduce over fsdp). llama4-scout's 109B total
  params (2 copies = 436 GB/client) simply cannot live on one client's 16
  model-shards of 16 GB HBM.

* serve: llama4-scout also needs weights sharded over BOTH non-batch axes
  (2D tensor parallelism: experts over `model`, d_ff over `data`), or
  13.6 GB/device of weights crowd out the KV cache.

Everything else keeps the plain layout: data=clients, model=TP.
"""

from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh

from repro.launch.mesh import _auto_axis_types


@dataclasses.dataclass(frozen=True)
class ArchDistribution:
    fsdp: int = 1            # train: data axis splits into (data/fsdp, fsdp)
    serve_wide: bool = False  # serve: also shard weights over the data axis


OVERRIDES: dict[str, ArchDistribution] = {
    "llama4-scout-17b-a16e": ArchDistribution(fsdp=4, serve_wide=True),
    "llava-next-34b": ArchDistribution(fsdp=2),
}


def distribution_for(arch: str) -> ArchDistribution:
    return OVERRIDES.get(arch, ArchDistribution())


def train_mesh_view(mesh: Mesh, fsdp: int) -> Mesh:
    """Reinterpret the production device grid with an fsdp axis split out of
    the data axis: (pod?, data, model) -> (pod?, data/fsdp, fsdp, model)."""
    if fsdp == 1:
        return mesh
    names = mesh.axis_names
    assert "data" in names and mesh.shape["data"] % fsdp == 0
    new_shape, new_names = [], []
    for n in names:
        if n == "data":
            new_shape += [mesh.shape["data"] // fsdp, fsdp]
            new_names += ["data", "fsdp"]
        else:
            new_shape.append(mesh.shape[n])
            new_names.append(n)
    dev = np.asarray(mesh.devices).reshape(new_shape)
    return Mesh(dev, tuple(new_names), **_auto_axis_types(len(new_names)))
