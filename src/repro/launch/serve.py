"""Distributed serving driver: batched prefill + KV-cached decode.

Layouts (decided in partition.cache_shardings):
  * prefill_32k / decode_32k — request batch over the ("pod","data") axes,
    KV-cache sequence (or SSM heads) over "model";
  * long_500k — batch=1: the cache sequence dim absorbs ALL mesh axes
    (ring-buffer window for sliding/chunked attention, O(1) state for SSM).

``lower_prefill`` / ``lower_decode`` AOT-lower the steps for the dry-run;
``generate`` is the runnable single-host loop used by examples/serve_lm.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES, ArchConfig
from repro.launch import input_specs as ispec
from repro.launch import partition
from repro.launch.mesh import client_axes, tp_size
from repro.models import build_model
from repro.utils.sharding_ctx import activation_sharding


def _serve_cfg(arch: str, dtype: str = "bfloat16") -> ArchConfig:
    return get_config(arch).with_dtype(dtype)


def _batch_axes(mesh, batch: int):
    ca = client_axes(mesh)
    size = 1
    for a in ca:
        size *= mesh.shape[a]
    return ca if batch % size == 0 and batch >= size else None


def abstract_serve_state(cfg: ArchConfig, batch: int, seq_len: int):
    model = build_model(cfg)
    params = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    # VLM caches must also hold the image-token prefix.
    cap = seq_len + (cfg.n_modal_tokens if cfg.family == "vlm" else 0)
    caches = jax.eval_shape(lambda: model.init_caches(batch, cap))
    return model, params, caches


def lower_decode(arch: str, mesh, *, shape_name: str = "decode_32k",
                 dtype: str = "bfloat16"):
    """One-token serve_step with a seq_len-deep cache (the decode shapes)."""
    from repro.launch.overrides import distribution_for

    cfg = _serve_cfg(arch, dtype)
    shp = INPUT_SHAPES[shape_name]
    model, params, caches = abstract_serve_state(cfg, shp.global_batch,
                                                 shp.seq_len)
    tp = tp_size(mesh)
    wide = "data" if distribution_for(arch).serve_wide else None
    p_sh = partition.tree_shardings(params, mesh, tp, extra_axis=wide)
    c_sh = partition.cache_shardings(caches, mesh, batch=shp.global_batch)
    ba = _batch_axes(mesh, shp.global_batch)
    tok_sh = NamedSharding(mesh, P(ba, None))
    token = jax.ShapeDtypeStruct((shp.global_batch, 1), jnp.int32)

    def decode_step(params, token, caches):
        return model.decode_step(params, token, caches)

    # decode processes one token per request: the token-sharded dispatch's
    # per-layer weight gather would dominate (measured: 18 -> 241 ms
    # regression), so decode keeps the plain dispatch.
    with mesh:
        with activation_sharding(residual=P(None, None, "model")):
            # decode residual is [B, 1, d]: shard d_model (seq dim is 1)
            lowered = jax.jit(
                decode_step, in_shardings=(p_sh, tok_sh, c_sh),
            ).lower(params, token, caches)
    return lowered


def _moe_ctx(cfg: ArchConfig, mesh, batch: int, *, seq_sharded: bool):
    """Token-sharded MoE dispatch when experts don't divide the model axis —
    see models/moe.py and EXPERIMENTS.md §Perf iteration 1. Serving tokens
    are sharded over BOTH the data axes (batch) and, at prefill, the model
    axis (sequence), so the dispatch vmaps over the full device grid."""
    tp = tp_size(mesh)
    if not (cfg.n_experts and cfg.n_experts % tp):
        return None
    ca = client_axes(mesh)
    dp = 1
    for a in ca:
        dp *= mesh.shape[a]
    if not (batch % dp == 0 and batch >= dp):
        return None
    ns = tp if seq_sharded else 1
    grid_axes = (ca + ("model",)) if seq_sharded else ca
    return {"nb": dp, "ns": ns, "axes": grid_axes,
            "spec": P(grid_axes if len(grid_axes) > 1 else grid_axes[0],
                      None, None)}


def lower_prefill(arch: str, mesh, *, shape_name: str = "prefill_32k",
                  dtype: str = "bfloat16"):
    """Full-prompt prefill populating the cache (the prefill shapes)."""
    from repro.launch.overrides import distribution_for

    cfg = _serve_cfg(arch, dtype)
    shp = INPUT_SHAPES[shape_name]
    model, params, caches = abstract_serve_state(cfg, shp.global_batch,
                                                 shp.seq_len)
    tp = tp_size(mesh)
    wide = "data" if distribution_for(arch).serve_wide else None
    p_sh = partition.tree_shardings(params, mesh, tp, extra_axis=wide)
    c_sh = partition.cache_shardings(caches, mesh, batch=shp.global_batch)
    batch_specs = ispec.batch_specs(cfg, shp.global_batch, shp.seq_len)
    ba = _batch_axes(mesh, shp.global_batch)
    b_sh = partition.batch_shardings(batch_specs, mesh, dim_axes=(ba,))

    def prefill(params, batch, caches):
        return model.prefill(params, batch, caches)

    moe = _moe_ctx(cfg, mesh, shp.global_batch, seq_sharded=True)
    with mesh:
        with activation_sharding(residual=P(None, "model", None),
                                 logits=P(None, None, "model"),
                                 moe_shards=moe):
            lowered = jax.jit(
                prefill, in_shardings=(p_sh, b_sh, c_sh),
            ).lower(params, batch_specs, caches)
    return lowered


# ------------------------------------------------------- single-host loop
def generate(arch: str, *, prompt_len: int = 32, gen_len: int = 32,
             batch: int = 2, reduced: bool = True, seed: int = 0,
             greedy: bool = True):
    """Runnable generation loop (examples/serve_lm.py)."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    batch_data = ispec.make_batch(cfg, batch, prompt_len, key=seed + 1)
    total = prompt_len + gen_len
    extra = cfg.n_modal_tokens if cfg.family == "vlm" else 0
    caches = model.init_caches(batch, total + extra)
    logits, caches = jax.jit(model.prefill)(params, batch_data, caches)
    decode = jax.jit(model.decode_step)
    toks = []
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    key = jax.random.key(seed + 2)
    for _ in range(gen_len):
        toks.append(tok)
        logits, caches = decode(params, tok, caches)
        if greedy:
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        else:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, logits[:, -1, :])[:, None].astype(jnp.int32)
    return jnp.concatenate(toks, axis=1)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--full", action="store_true")
    a = ap.parse_args()
    out = generate(a.arch, prompt_len=a.prompt_len, gen_len=a.gen_len,
                   batch=a.batch, reduced=not a.full)
    print("generated token ids:")
    print(out)
