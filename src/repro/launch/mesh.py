"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis crosses the DCN boundary; FedCET's single aggregated vector is
the only collective that traverses it, once per tau local steps.

Functions (not module-level constants) so importing never touches jax
device state; the dry-run process sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE importing jax.
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

try:  # AxisType landed in jax 0.5; older jax defaults every axis to Auto
    from jax.sharding import AxisType

    def _auto_axis_types(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # pragma: no cover - depends on installed jax
    def _auto_axis_types(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; run "
            "under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes, **_auto_axis_types(len(axes)))


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for sharding unit tests (subprocesses with 4-8 fake devs)."""
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev, axes, **_auto_axis_types(len(axes)))


def client_axes(mesh: Mesh) -> tuple[str, ...]:
    """The mesh axes that enumerate federated clients (model/fsdp excluded)."""
    return tuple(a for a in mesh.axis_names if a not in ("model", "fsdp"))


def n_clients(mesh: Mesh) -> int:
    out = 1
    for a in client_axes(mesh):
        out *= mesh.shape[a]
    return out


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]
