from repro.roofline.analysis import RooflineReport, analyze_compiled
from repro.roofline.constants import HBM_BW, ICI_BW, PEAK_FLOPS

__all__ = ["HBM_BW", "ICI_BW", "PEAK_FLOPS", "RooflineReport", "analyze_compiled"]
