"""Collective-traffic extraction from compiled (post-SPMD) HLO text.

``cost_analysis()`` counts while-loop bodies ONCE (verified empirically in
this container), so naive parsing undercounts anything inside
scan-over-layers. We therefore:

 1. split the HLO module into computations,
 2. record every collective op (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute) with its result-shape bytes,
 3. build the computation call graph (body= / condition= / to_apply= /
    branch_computations / calls),
 4. propagate execution multipliers: a while body executes `trip` times,
    where trip is recovered from the largest integer constant in the loop's
    condition computation (exact for lax.scan's counted loops; logged so a
    mis-parse is visible).

Bytes convention: result-shape bytes of the op (documented proxy for link
traffic; the ring-algorithm factor 2(n-1)/n for all-reduce is applied in
analysis.py when converting to seconds).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# computation header: "%name (params...) -> type {" — params may contain
# nested parens (tuple types), so match only the leading name and require
# an arrow + opening brace on the line.
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_CALLSITE_RE = re.compile(
    r"(?:body|condition|to_apply|branch_computations|called_computations)="
    r"({[^}]*}|%?[\w\.\-]+)")
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Sum of byte sizes of every shaped tensor in a type string
    (handles tuples like (f32[8,128], f32[8,128]))."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes: int
    computation: str
    multiplier: int = 1

    @property
    def effective_bytes(self) -> int:
        return self.bytes * self.multiplier


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Computation definitions start at column 0 ("%name (" or "ENTRY");
    their (possibly line-wrapped) header runs until the opening "{", and the
    body is the indented lines until the column-0 "}"."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        if line.startswith("}"):
            cur = None
            continue
        if line and not line[0].isspace():
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None and line.strip():
            comps[cur].append(line.strip())
    return comps


def parse_collectives(hlo: str) -> list[CollectiveOp]:
    comps = _split_computations(hlo)

    # --- call graph + while bodies ------------------------------------------
    callees: dict[str, set[str]] = defaultdict(set)
    while_links: list[tuple[str, str, str]] = []  # (caller, body, cond)
    for name, lines in comps.items():
        for ln in lines:
            body = re.search(r"body=%?([\w\.\-]+)", ln)
            cond = re.search(r"condition=%?([\w\.\-]+)", ln)
            if body and cond:
                while_links.append((name, body.group(1), cond.group(1)))
            for m in _CALLSITE_RE.finditer(ln):
                blob = m.group(1).strip("{}")
                for callee in re.split(r",\s*", blob):
                    if callee:
                        callees[name].add(callee.lstrip("%"))

    # --- trip counts from condition computations ----------------------------
    def trip_count(cond_name: str) -> int:
        lines = comps.get(cond_name, [])
        consts = []
        for ln in lines:
            for m in re.finditer(r"constant\((\d+)\)", ln):
                consts.append(int(m.group(1)))
        return max(consts) if consts else 1

    body_trip = {body: trip_count(cond) for _, body, cond in while_links}

    # --- multipliers by propagation over the call graph ---------------------
    mult: dict[str, int] = defaultdict(lambda: 1)

    def visit(name: str, m: int, seen: frozenset):
        if name in seen:
            return
        mult[name] = max(mult[name], m)
        child_seen = seen | {name}
        for callee in callees.get(name, ()):  # nested loops multiply
            child_m = m * body_trip.get(callee, 1)
            visit(callee, child_m, child_seen)

    entry = next((n for n in comps if "main" in n), None)
    roots = [entry] if entry else list(comps)
    for r in roots:
        visit(r, 1, frozenset())
    # computations not reached from entry (rare) keep multiplier 1

    # --- collect collectives -------------------------------------------------
    out: list[CollectiveOp] = []
    for name, lines in comps.items():
        for ln in lines:
            for kind in COLLECTIVES:
                # match "= TYPE kind(" to avoid e.g. all-reduce-start dupes
                if re.search(rf"=\s*[^=]*\b{kind}(?:-start)?\(", ln):
                    ty = ln.split("=", 1)[1]
                    ty = ty.split(kind)[0]
                    b = shape_bytes(ty)
                    if b:
                        out.append(CollectiveOp(kind=kind, bytes=b,
                                                computation=name,
                                                multiplier=mult[name]))
                    break
    return out


def collective_summary(hlo: str) -> dict:
    ops = parse_collectives(hlo)
    by_kind: dict[str, int] = defaultdict(int)
    count: dict[str, int] = defaultdict(int)
    for op in ops:
        by_kind[op.kind] += op.effective_bytes
        count[op.kind] += op.multiplier
    return {
        "bytes_by_kind": dict(by_kind),
        "count_by_kind": dict(count),
        "total_bytes": int(sum(by_kind.values())),
        "n_sites": len(ops),
    }
