"""TPU v5e hardware constants (per chip), per the assignment."""

PEAK_FLOPS = 197e12   # bf16 FLOP/s
HBM_BW = 819e9        # bytes/s
ICI_BW = 50e9         # bytes/s per link
CHIPS_PER_POD = 256
VMEM_BYTES = 128 * 1024 * 1024  # ~128 MiB v5e vector memory
HBM_BYTES = 16 * 1024**3
