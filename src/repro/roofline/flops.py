"""Analytic compute/memory cost model per (arch x shape).

Why analytic: XLA's ``cost_analysis()`` counts while-loop bodies ONCE
(verified in this container — a scan of 8 matmuls reports 1 matmul of
FLOPs), and everything perf-relevant here lives inside scans
(layers, attention KV blocks, SSD chunks, FedCET local steps). So the
roofline compute/memory terms come from explicit formulas derived from the
config, while the dry-run's compiled artifact supplies the per-device
memory footprint (memory_analysis) and the collective traffic (HLO parse
with loop multipliers). Raw cost_analysis numbers are recorded alongside
for reference.

Conventions (documented in EXPERIMENTS.md):
  * matmul FLOPs = 2mnk; training = 4x forward for the scanned blocks
    (fwd + 2x bwd + 1x remat recompute), 3x for the un-remat'd LM head.
  * the baseline blockwise attention computes ALL KV blocks then masks, so
    its attention context is S (not S/2 causal / w sliding) — the waste is
    part of the BASELINE and is one of the hillclimb levers.
  * MODEL_FLOPS follows the assignment: 6*N*D (train) / 2*N*D (inference),
    N = active params, D = tokens processed per step.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class StepCost:
    flops_per_device: float          # analytic compiled-work estimate
    hbm_bytes_per_device: float      # analytic HBM traffic estimate
    model_flops_total: float         # 6*N_active*D (or 2*N*D inference)
    n_params: int
    n_active_params: int
    detail: dict


# ------------------------------------------------------------ param counts
def param_counts(cfg: ArchConfig) -> tuple[int, int]:
    """(total, active-per-token) parameter counts, exact from eval_shape."""
    import jax

    from repro.models import build_model

    model = build_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k), jax.random.key(0))
    leaves = jax.tree_util.tree_flatten_with_path(shapes)[0]
    total = sum(l.size for _, l in leaves)
    if not cfg.n_experts:
        return total, total
    expert = 0
    for kp, leaf in leaves:
        names = [getattr(k, "key", "") for k in kp]
        # routed experts only: the shared expert (".../moe/shared/...") is
        # always active and must not be discounted.
        if ("moe" in names and "shared" not in names
                and str(names[-1]) in ("gate", "up", "down")):
            expert += leaf.size
    active = total - expert + int(expert * cfg.experts_per_token / cfg.n_experts)
    return total, active


# ------------------------------------------------------- per-token forward
def _attn_ctx(cfg: ArchConfig, S: int, *, decode: bool) -> int:
    """Effective KV length each query attends over in the BASELINE impl."""
    if decode:
        if cfg.attention == "sliding":
            return min(cfg.window, S)
        if cfg.attention == "chunked":
            return min(cfg.chunk, S)
        return S
    # baseline blockwise visits every KV block (masking, not skipping)
    return S


def _dense_block_flops_per_token(cfg: ArchConfig, ctx: int) -> float:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * d * (hq * dh) * 2 + 2 * d * (hkv * dh) * 2  # wq+wo, wk+wv
    attn = 2 * hq * dh * ctx * 2                           # scores + AV
    if cfg.n_experts:
        k = cfg.experts_per_token
        mlp = 6 * d * cfg.d_ff * k + 2 * d * cfg.n_experts
        if cfg.moe_shared_expert:
            mlp += 6 * d * cfg.d_ff
    else:
        n_mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
        mlp = 2 * d * cfg.d_ff * n_mats
    return proj + attn + mlp


def _mamba_block_flops_per_token(cfg: ArchConfig, chunk: int = 128) -> float:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    h = d_in // cfg.ssm_headdim
    p = cfg.ssm_headdim
    n = cfg.ssm_state
    proj = 2 * d * (2 * d_in + 2 * n + h) + 2 * d_in * d
    conv = 2 * cfg.ssm_conv * (d_in + 2 * n)
    lc = chunk
    ssd = 2 * n * lc + 2 * lc * h * p + 4 * n * h * p  # cb + intra + states/inter
    return proj + conv + ssd


def _per_token_forward_flops(cfg: ArchConfig, ctx: int) -> float:
    """Per-token forward FLOPs through all blocks (no embed/head)."""
    if cfg.family == "ssm":
        return cfg.n_layers * _mamba_block_flops_per_token(cfg)
    if cfg.family == "hybrid":
        every = cfg.shared_attn_every or cfg.n_layers + 1
        n_attn = cfg.n_layers // every
        return (cfg.n_layers * _mamba_block_flops_per_token(cfg)
                + n_attn * _dense_block_flops_per_token(cfg, ctx))
    if cfg.family == "audio":
        # decoder blocks + cross attention against encoder_len
        dec = _dense_block_flops_per_token(cfg, ctx)
        d, hq, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
        cross = 2 * d * (hq * dh) * 2 + 2 * hq * dh * cfg.encoder_len * 2
        return cfg.n_layers * (dec + cross)
    return cfg.n_layers * _dense_block_flops_per_token(cfg, ctx)


def _head_flops_per_token(cfg: ArchConfig) -> float:
    return 2 * cfg.d_model * cfg.vocab_size


def _encoder_flops(cfg: ArchConfig, batch: int) -> float:
    if cfg.family != "audio":
        return 0.0
    t = cfg.encoder_len
    per_tok = cfg.encoder_layers * _dense_block_flops_per_token(
        dataclasses.replace(cfg, n_experts=0, activation="gelu"), t)
    return per_tok * t * batch


# ------------------------------------------------------------- step costs
def train_cost(cfg: ArchConfig, shape: ShapeConfig, *, n_devices: int,
               tau: int = 2) -> StepCost:
    n_total, n_active = param_counts(cfg)
    S = shape.seq_len
    tokens = shape.global_batch * S          # per local step
    extra = cfg.n_modal_tokens if cfg.family == "vlm" else 0
    tokens_with_modal = shape.global_batch * (S + extra)

    fwd_blocks = _per_token_forward_flops(cfg, _attn_ctx(cfg, S + extra, decode=False))
    fwd = fwd_blocks * tokens_with_modal + _head_flops_per_token(cfg) * tokens_with_modal
    fwd += _encoder_flops(cfg, shape.global_batch)
    step = (4.0 * (fwd - _head_flops_per_token(cfg) * tokens_with_modal)
            + 3.0 * _head_flops_per_token(cfg) * tokens_with_modal)
    total = step * tau                       # tau local steps per round
    model_flops = 6.0 * n_active * tokens * tau

    # HBM traffic: FedCET state streams (x, d read; v written; grads) are
    # ~7 param-passes per local step + layer-boundary activations + logits.
    param_bytes = n_total * 2  # bf16
    act_bytes = (cfg.n_layers * tokens_with_modal * cfg.d_model * 2) * 4
    logit_bytes = tokens_with_modal * cfg.vocab_size * 2 * 3
    hbm = tau * (7.0 * param_bytes + act_bytes + logit_bytes)
    return StepCost(
        flops_per_device=total / n_devices,
        hbm_bytes_per_device=hbm / n_devices,
        model_flops_total=model_flops,
        n_params=n_total, n_active_params=n_active,
        detail={"fwd_flops": fwd, "tokens_per_local_step": tokens,
                "tau": tau, "param_bytes": param_bytes},
    )


def prefill_cost(cfg: ArchConfig, shape: ShapeConfig, *, n_devices: int) -> StepCost:
    n_total, n_active = param_counts(cfg)
    S = shape.seq_len
    extra = cfg.n_modal_tokens if cfg.family == "vlm" else 0
    tokens = shape.global_batch * (S + extra)
    fwd = (_per_token_forward_flops(cfg, _attn_ctx(cfg, S + extra, decode=False))
           * tokens + _head_flops_per_token(cfg) * shape.global_batch)
    fwd += _encoder_flops(cfg, shape.global_batch)
    model_flops = 2.0 * n_active * tokens
    param_bytes = n_total * 2
    kv_token_bytes = _cache_bytes_per_token(cfg)
    hbm = param_bytes + tokens * kv_token_bytes + \
        cfg.n_layers * tokens * cfg.d_model * 2 * 2
    return StepCost(
        flops_per_device=fwd / n_devices,
        hbm_bytes_per_device=hbm / n_devices,
        model_flops_total=model_flops,
        n_params=n_total, n_active_params=n_active,
        detail={"tokens": tokens},
    )


def _cache_bytes_per_token(cfg: ArchConfig) -> float:
    if cfg.family == "ssm":
        return 0.0  # O(1) state
    n_attn = cfg.n_layers
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // (cfg.shared_attn_every or cfg.n_layers + 1)
    return n_attn * 2 * cfg.n_kv_heads * cfg.head_dim * 2  # k+v bf16


def decode_cost(cfg: ArchConfig, shape: ShapeConfig, *, n_devices: int) -> StepCost:
    n_total, n_active = param_counts(cfg)
    B = shape.global_batch
    ctx = _attn_ctx(cfg, shape.seq_len, decode=True)
    fwd = (_per_token_forward_flops(cfg, ctx) + _head_flops_per_token(cfg)) * B
    model_flops = 2.0 * n_active * B
    param_bytes = n_total * 2
    # decode HBM: weights once + the live cache window read per step
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        h = d_in // cfg.ssm_headdim
        cache_read = cfg.n_layers * B * h * cfg.ssm_headdim * cfg.ssm_state * 4 * 2
    else:
        cache_read = B * ctx * _cache_bytes_per_token(cfg)
        if cfg.family == "hybrid":
            d_in = cfg.ssm_expand * cfg.d_model
            h = d_in // cfg.ssm_headdim
            cache_read += cfg.n_layers * B * h * cfg.ssm_headdim * cfg.ssm_state * 4 * 2
    hbm = param_bytes + cache_read
    return StepCost(
        flops_per_device=fwd / n_devices,
        hbm_bytes_per_device=hbm / n_devices,
        model_flops_total=model_flops,
        n_params=n_total, n_active_params=n_active,
        detail={"ctx": ctx, "cache_read_bytes": cache_read},
    )


def cost_for(cfg: ArchConfig, shape: ShapeConfig, *, n_devices: int,
             tau: int = 2) -> StepCost:
    if shape.kind == "train":
        return train_cost(cfg, shape, n_devices=n_devices, tau=tau)
    if shape.kind == "prefill":
        return prefill_cost(cfg, shape, n_devices=n_devices)
    return decode_cost(cfg, shape, n_devices=n_devices)
