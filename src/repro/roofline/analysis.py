"""Three-term roofline assembly (compute / memory / collective).

    compute term    = FLOPs / (chips x 197 TFLOP/s)
    memory term     = HBM bytes / (chips x 819 GB/s)
    collective term = collective bytes / (chips x 50 GB/s ICI)

FLOPs and HBM bytes come from the analytic cost model (roofline/flops.py —
see its docstring for why not cost_analysis), collective bytes from the
compiled HLO (roofline/hlo_parse.py, loop-multiplier-corrected, ring factor
2(n-1)/n applied to all-reduce). The dominant term is the bottleneck the
§Perf loop iterates on.
"""

from __future__ import annotations

import dataclasses

from repro.roofline import constants as C
from repro.roofline.flops import StepCost
from repro.roofline.hlo_parse import collective_summary


@dataclasses.dataclass(frozen=True)
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    analytic_flops_total: float
    flops_ratio: float            # MODEL_FLOPS / analytic total FLOPs
    collective_bytes: int
    collective_detail: dict
    memory_per_device_bytes: int  # from compiled.memory_analysis()
    raw_cost_analysis: dict
    bottleneck: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _ring_factor(kind: str, n: int) -> float:
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n
    if kind in ("all-gather", "reduce-scatter"):
        return (n - 1) / n
    return 1.0


def analyze_compiled(*, arch: str, shape: str, mesh_name: str, n_devices: int,
                     cost: StepCost, hlo_text: str, memory_stats,
                     raw_cost: dict | None) -> RooflineReport:
    summary = collective_summary(hlo_text)
    # link-traffic seconds: bytes already per-module; collectives in the HLO
    # are per-device-program ops, so their shape bytes are per-device moves.
    coll_s = 0.0
    for kind, b in summary["bytes_by_kind"].items():
        coll_s += b * _ring_factor(kind, n_devices) / C.ICI_BW
    compute_s = cost.flops_per_device / C.PEAK_FLOPS
    memory_s = cost.hbm_bytes_per_device / C.HBM_BW
    analytic_total = cost.flops_per_device * n_devices
    ratio = (cost.model_flops_total / analytic_total) if analytic_total else 0.0
    mem_bytes = 0
    if memory_stats is not None:
        mem_bytes = int(memory_stats.argument_size_in_bytes
                        + memory_stats.temp_size_in_bytes
                        + memory_stats.output_size_in_bytes)
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        model_flops=cost.model_flops_total,
        analytic_flops_total=analytic_total,
        flops_ratio=ratio,
        collective_bytes=summary["total_bytes"],
        collective_detail=summary,
        memory_per_device_bytes=mem_bytes,
        raw_cost_analysis={k: float(v) for k, v in (raw_cost or {}).items()
                           if k in ("flops", "bytes accessed")},
        bottleneck=max(terms, key=terms.get),
    )
