"""NIDS [Li, Shi & Yan, 2019] — the decentralized optimizer FedCET
descends from, as an engine spec.

NIDS (Network-InDependent Step-size) iterates, per node i over a gossip
graph with doubly-stochastic mixing matrix W:

    x(k+1) = W~ [ 2 x(k) - x(k-1) - alpha (grad(k) - grad(k-1)) ],
    W~ = (I + W) / 2,

i.e. EXACTLY FedCET's 2-point extrapolation message (Algorithm 2 /
``FedCETLiteral``) pushed through a LAZY mixing step instead of the star
mean. This spec closes the loop to the paper's origin: ``message`` is the
literal extrapolation ``m = 2x - x_prev - alpha (g - g_prev)``, and
``server_aggregate`` applies the lazy half-step ``x <- (m + m_bar) / 2``
— so with :func:`repro.core.engine.with_topology` supplying
``m_bar = (W m)_i``, the update is ``((I + W)/2) m``: NIDS proper.

Correctness structure (the same telescoping FedCET inherits): W being
COLUMN-stochastic makes the client mean of ``x`` evolve exactly like the
centralized extrapolation, and the warm-up ``x(-1) = x(-2) - alpha
g(x(-2))`` pins the conserved quantity ``mean(x(k)) - mean(x(k-1)) +
alpha mean(g(k-1))`` to ZERO — so any fixed point has zero mean
gradient: NIDS converges to the EXACT optimum for every connected graph,
at a rate governed by the spectral gap of W (measured against
star-FedCET in benchmarks/topology_sweep.py).

Under the default (star) topology ``m_bar`` is the global mean and the
update degenerates to lazy centralized averaging — identical to
``FedCETLiteral`` with ``c * alpha = 1/2`` (pinned <= 1e-12 in
tests/test_topology.py, which is the lineage proof in executable form).

Communication: ONE n-vector per client per round each way under the
star topology (the mixed result must reach every client, exactly like
FedCETLiteral's broadcast). Under a gossip topology there is no server
and no broadcast — the exchange is billed as per-edge uplink messages —
which the Mixing topology expresses itself (``broadcast_mult() == 0``
zeroes the downlink), so the spec declares the star cost and lets the
attached topology reshape it. ``tau`` defaults to 1 (NIDS mixes every
step); ``tau > 1`` runs pure extrapolated local steps between mixings,
the same generalization FedCET makes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import replicate
from repro.core.engine import RoundEngine


class NIDSState(NamedTuple):
    x_curr: Any  # stacked [clients, ...] x(k)
    x_prev: Any  # x(k-1)
    g_prev: Any  # grad f(x(k-1))
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class NIDS(RoundEngine):
    alpha: float
    n_clients: int
    tau: int = 1
    name: str = "nids"
    vectors_up: int = 1
    vectors_down: int = 1  # star broadcast; gossip topologies zero it

    def init_warmup(self, gf, x0, init_batch):
        """x(-1) = x(-2) - alpha grad(x(-2)), then one aggregating step —
        the initialization that zeroes the conserved mean-gradient term
        (identical to FedCET's warm-up block; Lemma 1 lineage)."""
        x_m2 = replicate(x0, self.n_clients)
        g_m2 = gf(x_m2, init_batch)
        x_m1 = jax.tree.map(lambda x, g: x - self.alpha * g, x_m2, g_m2)
        return NIDSState(x_curr=x_m1, x_prev=x_m2, g_prev=g_m2,
                         t=jnp.asarray(-1)), True

    def _extrapolate(self, gf, state, batch):
        """m = 2 x(k) - x(k-1) - alpha (grad(k) - grad(k-1))."""
        a = self.alpha
        g = gf(state.x_curr, batch)
        m = jax.tree.map(
            lambda xc, xp, gc, gp: 2.0 * xc - xp - a * gc + a * gp,
            state.x_curr, state.x_prev, g, state.g_prev,
        )
        return m, g

    def local_step(self, gf, state, batch, rctx):
        m, g = self._extrapolate(gf, state, batch)
        return NIDSState(x_curr=m, x_prev=state.x_curr, g_prev=g,
                         t=state.t + 1)

    def message(self, gf, state, batch, rctx):
        """The transmitted vector is the extrapolation m; mctx carries the
        EXACT (m, grad) pair — a gossip node knows its own m exactly, so
        under an attached compressor only the neighbors' copies are
        compressed (the CHOCO-SGD convention)."""
        m, g = self._extrapolate(gf, state, batch)
        return m, (m, g)

    def server_aggregate(self, state, msg, msg_bar, mctx, rctx):
        """The lazy mixing half-step x <- (m + m_bar)/2: with a gossip
        topology supplying m_bar = (W m)_i this is ((I+W)/2) m — NIDS."""
        m_exact, g = mctx
        x_next = jax.tree.map(lambda mm, mb: 0.5 * (mm + mb), m_exact, msg_bar)
        return NIDSState(x_curr=x_next, x_prev=state.x_curr, g_prev=g,
                         t=state.t + 1)

    def client_params(self, state):
        return self._inner(state).x_curr
