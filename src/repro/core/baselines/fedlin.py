"""FedTrack [30] / FedLin [18] — gradient-tracking baselines, as engine specs.

Both start every round from the shared global model x_bar and run tau
corrected local steps

    y <- y - alpha * (grad_i(y) - g_i + g_bar),   g_i = grad_i(x_bar),

where g_bar = mean_i g_i is the *incrementally aggregated* global gradient.
The server then averages the endpoints. This guarantees exact linear
convergence under heterogeneity, at the cost of TWO n-dimensional vectors
each way per round (g_i up + endpoint up; x_bar down + g_bar down). In
engine terms the round-start gradient exchange is ``begin_round`` (it uses
the engine-provided aggregator, so client sampling masks it consistently);
the endpoint model is the message.

FedLin additionally sparsifies the *round-start uplink gradient* with top-k
+ error feedback (client-side memory). This is FedLin's own scheme, kept in
the spec — the generic ``with_compression`` transform applies to the
endpoint message instead. ``k_frac = 1.0`` recovers FedTrack exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import replicate
from repro.core.comm import sparsified_up_frac, topk_sparsify
from repro.core.engine import RoundEngine
from repro.utils.tree import tree_zeros_like


class FedLinState(NamedTuple):
    x: Any        # global model (replicated across the stacked axis)
    memory: Any   # per-client error-feedback memory (zeros when k_frac=1)
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class FedLin(RoundEngine):
    alpha: float
    tau: int
    n_clients: int
    k_frac: float = 1.0  # fraction of gradient entries transmitted (top-k)
    name: str = "fedlin"
    vectors_up: int = 2
    vectors_down: int = 2

    @property
    def up_frac(self) -> float:
        """The TWO up vectors compress independently: the round-start
        gradient through FedLin's own top-k (k_frac), the endpoint message
        through any attached engine transforms."""
        g_frac = sparsified_up_frac(self.k_frac) if self.k_frac < 1.0 else 1.0
        return (g_frac + super().up_frac) / 2.0

    @property
    def bits_per_coord(self) -> float:
        """Bit-true counterpart of ``up_frac``: the sparsified round-start
        gradient costs ``k_frac * (32 + 32)`` bits/coord (f32 values +
        int32 indices); the endpoint message pays the attached transforms."""
        g_bits = 32.0 * (sparsified_up_frac(self.k_frac)
                         if self.k_frac < 1.0 else 1.0)
        return (g_bits + self._transforms_bits(32.0)) / 2.0

    @property
    def cohort_compatible(self) -> bool:
        """FedLin's own top-k sparsifies ACROSS the stacked client axis
        (``topk_sparsify`` over the full uplink-gradient leaf) — that
        selection is population-global, so the spec rejects cohort
        execution unless it is dense (``k_frac=1`` = FedTrack)."""
        return self.k_frac >= 1.0

    def init_warmup(self, gf, x0, init_batch):
        del gf, init_batch
        x = replicate(x0, self.n_clients)
        return FedLinState(x=x, memory=tree_zeros_like(x), t=jnp.asarray(0)), False

    def _compress_up(self, g, memory):
        """Top-k sparsification with error feedback on the uplink gradient."""
        if self.k_frac >= 1.0:
            return g, memory
        g_eff = jax.tree.map(jnp.add, g, memory)
        g_sparse = jax.tree.map(lambda a: topk_sparsify(a, self.k_frac), g_eff)
        memory = jax.tree.map(jnp.subtract, g_eff, g_sparse)
        return g_sparse, memory

    def begin_round(self, gf, state, first_batch, agg):
        """Round-start exchange: each client evaluates grad at the shared
        point, (optionally sparsified) uplinks it, server means, downlinks."""
        g_i = gf(state.x, first_batch)
        g_i_tx, memory = self._compress_up(g_i, state.memory)
        g_bar = agg(g_i_tx)
        return state._replace(memory=memory), (g_i_tx, g_bar)

    def _tracked_step(self, gf, state, batch, rctx):
        g_i_tx, g_bar = rctx
        g = gf(state.x, batch)
        return jax.tree.map(
            lambda yy, gg, gi, gb: yy - self.alpha * (gg - gi + gb),
            state.x, g, g_i_tx, g_bar,
        )

    def local_step(self, gf, state, batch, rctx):
        return state._replace(x=self._tracked_step(gf, state, batch, rctx))

    def message(self, gf, state, batch, rctx):
        """The tau-th corrected step folds into the endpoint message."""
        return self._tracked_step(gf, state, batch, rctx), None

    def server_aggregate(self, state, msg, msg_bar, mctx, rctx):
        x_new = jax.tree.map(lambda mb, mm: jnp.broadcast_to(mb, mm.shape),
                             msg_bar, msg)
        return FedLinState(x=x_new, memory=state.memory, t=state.t + self.tau)


def FedTrack(alpha: float, tau: int, n_clients: int) -> FedLin:
    """FedTrack = FedLin without sparsification (k_frac = 1)."""
    return FedLin(alpha=alpha, tau=tau, n_clients=n_clients, k_frac=1.0,
                  name="fedtrack")
