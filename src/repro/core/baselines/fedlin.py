"""FedTrack [30] / FedLin [18] — gradient-tracking federated baselines.

Both start every round from the shared global model x_bar and run tau
corrected local steps

    y <- y - alpha * (grad_i(y) - g_i + g_bar),   g_i = grad_i(x_bar),

where g_bar = mean_i g_i is the *incrementally aggregated* global gradient.
The server then averages the endpoints. This guarantees exact linear
convergence under heterogeneity, at the cost of TWO n-dimensional vectors
each way per round (g_i up + endpoint up; x_bar down + g_bar down).

FedLin additionally sparsifies the *uplink gradient* with top-k + error
feedback (client-side memory), trading rounds for bytes. ``k_frac = 1.0``
recovers FedTrack exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import GradFn, replicate, vmap_grads
from repro.core.comm import topk_sparsify
from repro.utils.tree import tree_client_mean, tree_zeros_like


class FedLinState(NamedTuple):
    x: Any        # global model (replicated across the stacked axis)
    memory: Any   # per-client error-feedback memory (zeros when k_frac=1)
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class FedLin:
    alpha: float
    tau: int
    n_clients: int
    k_frac: float = 1.0  # fraction of gradient entries transmitted (top-k)
    name: str = "fedlin"
    vectors_up: int = 2
    vectors_down: int = 2

    def init(self, grad_fn: GradFn, x0, init_batch) -> FedLinState:
        del grad_fn, init_batch
        x = replicate(x0, self.n_clients)
        return FedLinState(x=x, memory=tree_zeros_like(x), t=jnp.asarray(0))

    def _compress_up(self, g, memory):
        """Top-k sparsification with error feedback on the uplink gradient."""
        if self.k_frac >= 1.0:
            return g, memory
        g_eff = jax.tree.map(jnp.add, g, memory)
        g_sparse = jax.tree.map(lambda a: topk_sparsify(a, self.k_frac), g_eff)
        memory = jax.tree.map(jnp.subtract, g_eff, g_sparse)
        return g_sparse, memory

    def round(self, grad_fn: GradFn, state: FedLinState, batches) -> FedLinState:
        gf = vmap_grads(grad_fn)
        a = self.alpha

        # Round-start exchange: each client evaluates grad at the shared
        # point, (optionally sparsified) uplinks it, server means, downlinks.
        b0 = jax.tree.map(lambda b: b[0], batches)
        g_i = gf(state.x, b0)
        g_i_tx, memory = self._compress_up(g_i, state.memory)
        g_bar = tree_client_mean(g_i_tx)

        def body(y, b):
            g = gf(y, b)
            y = jax.tree.map(
                lambda yy, gg, gi, gb: yy - a * (gg - gi + gb),
                y, g, g_i_tx, g_bar,
            )
            return y, None

        y, _ = jax.lax.scan(body, state.x, batches)
        y_bar = tree_client_mean(y)
        x_new = jax.tree.map(lambda yb, yy: jnp.broadcast_to(yb, yy.shape), y_bar, y)
        return FedLinState(x=x_new, memory=memory, t=state.t + self.tau)

    def global_params(self, state: FedLinState):
        return tree_client_mean(state.x, keepdims=False)


def FedTrack(alpha: float, tau: int, n_clients: int) -> FedLin:
    """FedTrack = FedLin without sparsification (k_frac = 1)."""
    return dataclasses.replace(
        FedLin(alpha=alpha, tau=tau, n_clients=n_clients, k_frac=1.0),
        name="fedtrack",
    )
