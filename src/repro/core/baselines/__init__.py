from repro.core.baselines.fedavg import FedAvg
from repro.core.baselines.feddyn import FedDyn
from repro.core.baselines.fedlin import FedLin, FedTrack
from repro.core.baselines.fedprox import FedProx
from repro.core.baselines.nids import NIDS
from repro.core.baselines.scaffold import Scaffold

__all__ = ["FedAvg", "FedDyn", "FedLin", "FedProx", "FedTrack", "NIDS",
           "Scaffold"]
