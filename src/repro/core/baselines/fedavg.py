"""FedAvg [4] — the canonical federated learning baseline.

tau local SGD steps per client, then the server averages the models. One
n-dimensional vector up + one down per round — same communication as FedCET —
but under heterogeneous data it exhibits *client drift*: with a constant
learning rate the iterates stall at a nonzero distance from x*
(the motivating failure FedCET fixes; validated in tests/test_baselines.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import GradFn, replicate, vmap_grads
from repro.utils.tree import tree_client_mean


class FedAvgState(NamedTuple):
    x: Any  # stacked [clients, ...]
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class FedAvg:
    alpha: float
    tau: int
    n_clients: int
    name: str = "fedavg"
    vectors_up: int = 1
    vectors_down: int = 1

    def init(self, grad_fn: GradFn, x0, init_batch) -> FedAvgState:
        del grad_fn, init_batch
        return FedAvgState(x=replicate(x0, self.n_clients), t=jnp.asarray(0))

    def round(self, grad_fn: GradFn, state: FedAvgState, batches) -> FedAvgState:
        gf = vmap_grads(grad_fn)

        def body(x, b):
            g = gf(x, b)
            return jax.tree.map(lambda xx, gg: xx - self.alpha * gg, x, g), None

        x, _ = jax.lax.scan(body, state.x, batches)
        x_bar = tree_client_mean(x)
        x = jax.tree.map(lambda xb, xx: jnp.broadcast_to(xb, xx.shape), x_bar, x)
        return FedAvgState(x=x, t=state.t + self.tau)

    def global_params(self, state: FedAvgState):
        return tree_client_mean(state.x, keepdims=False)
