"""FedAvg [4] — the canonical federated learning baseline, as an engine spec.

tau local SGD steps per client, then the server averages the models. One
n-dimensional vector up + one down per round — same communication as FedCET —
but under heterogeneous data it exhibits *client drift*: with a constant
learning rate the iterates stall at a nonzero distance from x*
(the motivating failure FedCET fixes; validated in tests/test_baselines.py).

The transmitted message is the post-local-steps model itself; the server
aggregate broadcasts its (participating-clients) mean.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import replicate
from repro.core.engine import RoundEngine


class FedAvgState(NamedTuple):
    x: Any  # stacked [clients, ...]
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class FedAvg(RoundEngine):
    alpha: float
    tau: int
    n_clients: int
    name: str = "fedavg"
    vectors_up: int = 1
    vectors_down: int = 1

    def init_warmup(self, gf, x0, init_batch):
        del gf, init_batch
        return FedAvgState(x=replicate(x0, self.n_clients), t=jnp.asarray(0)), False

    def _sgd(self, gf, x, batch):
        g = gf(x, batch)
        return jax.tree.map(lambda xx, gg: xx - self.alpha * gg, x, g)

    def local_step(self, gf, state, batch, rctx):
        return FedAvgState(x=self._sgd(gf, state.x, batch), t=state.t)

    def message(self, gf, state, batch, rctx):
        """The tau-th local step folds into the message computation."""
        return self._sgd(gf, state.x, batch), None

    def server_aggregate(self, state, msg, msg_bar, mctx, rctx):
        x = jax.tree.map(lambda mb, mm: jnp.broadcast_to(mb, mm.shape),
                         msg_bar, msg)
        return FedAvgState(x=x, t=state.t + self.tau)
