"""SCAFFOLD [26] — stochastic controlled averaging, as an engine spec.

Clients carry a control variate c_i, the server carries c; local steps use
the corrected gradient grad_i - c_i + c. We implement full participation with
option II control updates (the variant the paper's experiments use for the
comparison: alpha_g = 1, alpha_l = 1/(81 tau L)).

Communication per round per client: model delta AND control delta up; global
model AND global control down — TWO n-dimensional vectors each way, i.e.
double FedCET's traffic (Remark 2). In engine terms the message is the
two-tree pytree ``{"dy": y - x, "dc": c_i+ - c_i}``; ``begin_round`` stashes
the round-start model so the deltas and option-II update have their anchor
after the local scan has advanced ``x``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import replicate
from repro.core.engine import RoundEngine
from repro.utils.tree import tree_zeros_like


class ScaffoldState(NamedTuple):
    x: Any       # server model, replicated across the stacked axis
    c_i: Any     # stacked per-client control variates
    c: Any       # server control variate (replicated)
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class Scaffold(RoundEngine):
    alpha_l: float
    tau: int
    n_clients: int
    alpha_g: float = 1.0
    name: str = "scaffold"
    vectors_up: int = 2
    vectors_down: int = 2

    def init_warmup(self, gf, x0, init_batch):
        del gf, init_batch
        x = replicate(x0, self.n_clients)
        return ScaffoldState(x=x, c_i=tree_zeros_like(x), c=tree_zeros_like(x),
                             t=jnp.asarray(0)), False

    def begin_round(self, gf, state, first_batch, agg):
        del gf, first_batch, agg
        return state, state.x  # rctx = round-start model x

    def _corrected_step(self, gf, state, batch):
        g = gf(state.x, batch)
        return jax.tree.map(
            lambda yy, gg, ci, cc: yy - self.alpha_l * (gg - ci + cc),
            state.x, g, state.c_i, state.c,
        )

    def local_step(self, gf, state, batch, rctx):
        return state._replace(x=self._corrected_step(gf, state, batch))

    def message(self, gf, state, batch, rctx):
        x0 = rctx
        y = self._corrected_step(gf, state, batch)
        # Option II: c_i+ = c_i - c + (x - y_i) / (tau * alpha_l)
        c_i_new = jax.tree.map(
            lambda ci, cc, xx, yy: ci - cc + (xx - yy) / (self.tau * self.alpha_l),
            state.c_i, state.c, x0, y,
        )
        msg = {"dy": jax.tree.map(jnp.subtract, y, x0),
               "dc": jax.tree.map(jnp.subtract, c_i_new, state.c_i)}
        return msg, c_i_new

    def server_aggregate(self, state, msg, msg_bar, mctx, rctx):
        x0, c_i_new = rctx, mctx
        x_new = jax.tree.map(lambda xx, d: xx + self.alpha_g * d,
                             x0, msg_bar["dy"])
        c_new = jax.tree.map(jnp.add, state.c, msg_bar["dc"])
        return ScaffoldState(x=x_new, c_i=c_i_new, c=c_new,
                             t=state.t + self.tau)
