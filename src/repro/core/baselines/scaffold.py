"""SCAFFOLD [26] — stochastic controlled averaging.

Clients carry a control variate c_i, the server carries c; local steps use
the corrected gradient grad_i - c_i + c. We implement full participation with
option II control updates (the variant the paper's experiments use for the
comparison: alpha_g = 1, alpha_l = 1/(81 tau L)).

Communication per round per client: model delta AND control delta up; global
model AND global control down — TWO n-dimensional vectors each way, i.e.
double FedCET's traffic (Remark 2).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import GradFn, replicate, vmap_grads
from repro.utils.tree import tree_client_mean, tree_zeros_like


class ScaffoldState(NamedTuple):
    x: Any       # server model, replicated across the stacked axis
    c_i: Any     # stacked per-client control variates
    c: Any       # server control variate (replicated)
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class Scaffold:
    alpha_l: float
    tau: int
    n_clients: int
    alpha_g: float = 1.0
    name: str = "scaffold"
    vectors_up: int = 2
    vectors_down: int = 2

    def init(self, grad_fn: GradFn, x0, init_batch) -> ScaffoldState:
        del grad_fn, init_batch
        x = replicate(x0, self.n_clients)
        return ScaffoldState(x=x, c_i=tree_zeros_like(x), c=tree_zeros_like(x),
                             t=jnp.asarray(0))

    def round(self, grad_fn: GradFn, state: ScaffoldState, batches) -> ScaffoldState:
        gf = vmap_grads(grad_fn)
        a = self.alpha_l

        def body(y, b):
            g = gf(y, b)
            y = jax.tree.map(
                lambda yy, gg, ci, cc: yy - a * (gg - ci + cc),
                y, g, state.c_i, state.c,
            )
            return y, None

        y, _ = jax.lax.scan(body, state.x, batches)

        # Option II: c_i+ = c_i - c + (x - y_i) / (tau * alpha_l)
        c_i_new = jax.tree.map(
            lambda ci, cc, xx, yy: ci - cc + (xx - yy) / (self.tau * a),
            state.c_i, state.c, state.x, y,
        )
        # Server aggregation (full participation): x += alpha_g * mean(dy),
        # c += mean(dc). Means over the stacked clients axis == the two
        # uplink vectors; the broadcast back == the two downlink vectors.
        dy_bar = tree_client_mean(jax.tree.map(jnp.subtract, y, state.x))
        dc_bar = tree_client_mean(jax.tree.map(jnp.subtract, c_i_new, state.c_i))
        x_new = jax.tree.map(lambda xx, d: xx + self.alpha_g * d, state.x, dy_bar)
        c_new = jax.tree.map(jnp.add, state.c, dc_bar)
        return ScaffoldState(x=x_new, c_i=c_i_new, c=c_new, t=state.t + self.tau)

    def global_params(self, state: ScaffoldState):
        return tree_client_mean(state.x, keepdims=False)
