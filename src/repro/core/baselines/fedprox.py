"""FedProx [Li et al., MLSys 2020] — proximal local SGD, as an engine spec.

Each round starts from the shared global model ``x0`` (the round-start
anchor, carried as ``rctx``); every local step minimizes the PROXIMAL
surrogate ``f_i(x) + (mu/2) ||x - x0||^2``:

    x <- x - alpha * (grad_i(x) + mu * (x - x0)).

The transmitted message is the post-local-steps model (FedAvg-style); the
server broadcasts the (participating-clients) mean. One n-vector each way —
the same communication as FedCET/FedAvg. ``mu = 0`` recovers FedAvg's
iterates exactly (pinned in tests/test_baselines.py).

This spec is the proof-of-inheritance for the transform stack: ~40 lines of
algorithm math, and ``with_delay`` x ``with_compression`` x
``with_participation`` all compose onto it with no algorithm-side code
(tests/test_staleness.py runs the full triple stack).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import replicate
from repro.core.engine import RoundEngine


class FedProxState(NamedTuple):
    x: Any  # stacked [clients, ...]
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class FedProx(RoundEngine):
    alpha: float
    mu_prox: float
    tau: int
    n_clients: int
    name: str = "fedprox"
    vectors_up: int = 1
    vectors_down: int = 1

    def init_warmup(self, gf, x0, init_batch):
        del gf, init_batch
        return FedProxState(x=replicate(x0, self.n_clients), t=jnp.asarray(0)), False

    def begin_round(self, gf, state, first_batch, agg):
        """rctx = the round-start model (the proximal anchor x0; equals the
        broadcast global model, since server_aggregate replicates it)."""
        del gf, first_batch, agg
        return state, state.x

    def _prox_step(self, gf, x, batch, x0):
        g = gf(x, batch)
        return jax.tree.map(
            lambda xx, gg, aa: xx - self.alpha * (gg + self.mu_prox * (xx - aa)),
            x, g, x0)

    def local_step(self, gf, state, batch, rctx):
        return FedProxState(x=self._prox_step(gf, state.x, batch, rctx),
                            t=state.t)

    def message(self, gf, state, batch, rctx):
        """The tau-th proximal step folds into the message computation."""
        return self._prox_step(gf, state.x, batch, rctx), None

    def server_aggregate(self, state, msg, msg_bar, mctx, rctx):
        x = jax.tree.map(lambda mb, mm: jnp.broadcast_to(mb, mm.shape),
                         msg_bar, msg)
        return FedProxState(x=x, t=state.t + self.tau)
