"""FedDyn [Acar et al., ICLR 2021] — dynamic regularization, as an engine
spec.

Each client carries a dual variable ``lam_i`` (its running estimate of
the local gradient at the consensus optimum) and minimizes the DYNAMIC
surrogate ``f_i(x) - <lam_i, x> + (a/2) ||x - x_t||^2`` with ``tau``
gradient steps from the round-start anchor ``x_t``:

    x <- x - alpha (grad_i(x) - lam_i + a (x - x_t)),

then updates the dual from the transmitted endpoint ``y_i``:

    lam_i <- lam_i - a (y_i - x_t).

The server tracks ``h = mean_i(lam_i)`` incrementally from the SAME
aggregate the model update uses and de-biases the broadcast:

    h <- h - a (y_bar - x_t),        x_{t+1} = y_bar - h / a.

At the fixed point ``lam_i = grad_i(x*)`` the dynamic gradient vanishes
for every client simultaneously, so — like FedCET and SCAFFOLD, unlike
FedAvg — FedDyn converges EXACTLY under heterogeneous data with a
constant step size, while transmitting the same single n-vector each way
as FedAvg/FedCET. It is the remaining drift-corrected one-vector
baseline from the paper's comparison family.

This spec is the second inheritance proof after FedProx: ~45 lines of
algorithm math, and the compression x participation stack composes onto
it with no algorithm-side code (the exact-convergence test in
tests/test_baselines.py runs shift:q8 x 80% sampling on the
heterogeneous-Hessian problem where FedAvg provably floors). ``h`` is
replicated server state: under client sampling absent clients keep their
frozen replica, the documented simulation semantics for replicated-state
baselines (present-only downlink — see ARCHITECTURE.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import replicate
from repro.core.engine import RoundEngine
from repro.utils.tree import tree_zeros_like


class FedDynState(NamedTuple):
    x: Any       # stacked [clients, ...] model parameters
    lam: Any     # stacked per-client dual variables (-> grad_i(x*))
    h: Any       # server de-bias state (replicated; -> 0 at the optimum)
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class FedDyn(RoundEngine):
    alpha: float
    a_dyn: float
    tau: int
    n_clients: int
    name: str = "feddyn"
    vectors_up: int = 1
    vectors_down: int = 1

    def init_warmup(self, gf, x0, init_batch):
        del gf, init_batch
        x = replicate(x0, self.n_clients)
        return FedDynState(x=x, lam=tree_zeros_like(x), h=tree_zeros_like(x),
                           t=jnp.asarray(0)), False

    def begin_round(self, gf, state, first_batch, agg):
        """rctx = the round-start model (the proximal anchor x_t)."""
        del gf, first_batch, agg
        return state, state.x

    def _dyn_step(self, gf, state, batch, x0):
        g = gf(state.x, batch)
        return jax.tree.map(
            lambda xx, gg, ll, aa:
                xx - self.alpha * (gg - ll + self.a_dyn * (xx - aa)),
            state.x, g, state.lam, x0)

    def local_step(self, gf, state, batch, rctx):
        return state._replace(x=self._dyn_step(gf, state, batch, rctx))

    def message(self, gf, state, batch, rctx):
        """The tau-th dynamic step folds into the endpoint message."""
        return self._dyn_step(gf, state, batch, rctx), None

    def server_aggregate(self, state, msg, msg_bar, mctx, rctx):
        """``lam_i`` updates from the client's own TRANSMITTED endpoint
        (``msg``, post-compression) and ``h`` from the aggregate of the
        same wire data — the FedCET/Lemma-2 discipline: both sides of the
        ``h = mean_i(lam_i)`` invariant see identical messages, so it
        survives any (even biased) compressor exactly. Updating ``lam``
        from the exact endpoint instead lets ``h - mean(lam)`` random-walk
        with the per-round compression error of the mean (measured floor
        ~4e-3 under shift:q8 vs ~2e-14 with the wire-consistent update)."""
        x0 = rctx
        lam_new = jax.tree.map(
            lambda ll, yy, aa: ll - self.a_dyn * (yy - aa),
            state.lam, msg, x0)
        h_new = jax.tree.map(
            lambda hh, mb, aa: hh - self.a_dyn * (mb - aa),
            state.h, msg_bar, x0)
        x_next = jax.tree.map(
            lambda mb, hh: jnp.broadcast_to(mb, hh.shape) - hh / self.a_dyn,
            msg_bar, h_new)
        return FedDynState(x=x_next, lam=lam_new, h=h_new,
                           t=state.t + self.tau)
