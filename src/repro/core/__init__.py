"""Core: the paper's contribution (FedCET) and its comparison baselines."""

from repro.core.api import FederatedAlgorithm, comm_bytes_per_round, replicate, vmap_grads
from repro.core.baselines import FedAvg, FedLin, FedTrack, Scaffold
from repro.core.comm import CommMeter, quantize_bf16, topk_sparsify
from repro.core.fedcet import FedCET, FedCETLiteral, max_weight_c
from repro.core.fedcet_compressed import FedCETCompressed
from repro.core.participation import FedCETPartial
from repro.core.lr_search import (
    alpha0_upper_bound,
    contraction_factors,
    lr_search,
    lr_search_validated,
    remark1_inequalities,
)

__all__ = [
    "FedAvg",
    "FedCET",
    "FedCETCompressed",
    "FedCETLiteral",
    "FedCETPartial",
    "FedLin",
    "FedTrack",
    "FederatedAlgorithm",
    "CommMeter",
    "Scaffold",
    "alpha0_upper_bound",
    "comm_bytes_per_round",
    "contraction_factors",
    "lr_search",
    "lr_search_validated",
    "max_weight_c",
    "quantize_bf16",
    "replicate",
    "remark1_inequalities",
    "topk_sparsify",
    "vmap_grads",
]
