"""Core: the paper's contribution (FedCET), its comparison baselines, and
the unified round engine + message transforms they all run on."""

from repro.core.api import FederatedAlgorithm, comm_bytes_per_round, replicate, vmap_grads
from repro.core.baselines import FedAvg, FedLin, FedProx, FedTrack, Scaffold
from repro.core.comm import (
    CommMeter,
    bits_per_coord_of,
    comm_bits_per_round,
    quantize_bf16,
    topk_sparsify,
)
from repro.core.compressors import (
    Bf16,
    Chain,
    Compressor,
    ErrorFeedback,
    RandK,
    StochasticQuant,
    TopK,
    from_spec,
)
from repro.core.engine import (
    ClientSampling,
    EngineState,
    ErrorFeedbackCompression,
    MessageCompression,
    RoundEngine,
    make_round_runner,
    masked_client_mean,
    participation_mask,
    run_rounds,
    with_compression,
    with_delay,
    with_participation,
)
from repro.core.staleness import (
    DelayState,
    StalenessConfig,
    StalePolicy,
    parse_delay,
    parse_policy,
)
from repro.core.fedcet import FedCET, FedCETLiteral, max_weight_c
from repro.core.fedcet_compressed import FedCETCompressed
from repro.core.participation import FedCETPartial
from repro.core.lr_search import (
    alpha0_upper_bound,
    contraction_factors,
    lr_search,
    lr_search_validated,
    remark1_inequalities,
)

__all__ = [
    "Bf16",
    "Chain",
    "ClientSampling",
    "CommMeter",
    "Compressor",
    "DelayState",
    "EngineState",
    "ErrorFeedback",
    "ErrorFeedbackCompression",
    "FedAvg",
    "FedCET",
    "FedCETCompressed",
    "FedCETLiteral",
    "FedCETPartial",
    "FedLin",
    "FedProx",
    "FedTrack",
    "FederatedAlgorithm",
    "MessageCompression",
    "RandK",
    "RoundEngine",
    "Scaffold",
    "StalePolicy",
    "StalenessConfig",
    "StochasticQuant",
    "TopK",
    "alpha0_upper_bound",
    "bits_per_coord_of",
    "comm_bits_per_round",
    "comm_bytes_per_round",
    "contraction_factors",
    "from_spec",
    "lr_search",
    "lr_search_validated",
    "make_round_runner",
    "masked_client_mean",
    "max_weight_c",
    "parse_delay",
    "parse_policy",
    "participation_mask",
    "quantize_bf16",
    "replicate",
    "remark1_inequalities",
    "run_rounds",
    "topk_sparsify",
    "vmap_grads",
    "with_compression",
    "with_delay",
    "with_participation",
]
