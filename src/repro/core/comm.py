"""Communication accounting and compression operators.

The paper's headline claim (Remark 2) is a *communication-volume* one:
FedCET moves ONE n-dimensional vector per client per round where SCAFFOLD /
FedTrack / FedLin move two. This module provides

* :class:`CommMeter` — declarative accounting per round from the
  algorithm's ``vectors_up`` / ``vectors_down`` and the model size. Since
  the compressor subsystem the meter is BIT-TRUE: construct it with
  ``for_params(params, algo=...)`` and it derives per-coordinate wire bits
  from the algorithm's attached compressor stack (``bits_per_coord``) — the
  old ``itemsize=4`` path silently overcounted bf16/quantized uplinks and
  has been removed from ``for_params`` (it raises with a migration hint;
  the direct constructor keeps the fixed-width legacy mode for explicit
  opt-in). With a ``with_delay`` model attached the uplink is
  additionally scaled by the transmit duty cycle (``transmit_frac``):
  buffered rounds where a client does not transmit count zero uplink bits.
  With client sampling attached the DOWNLINK scales by ``receive_frac``
  (present-only downlink: absent clients keep frozen replicas, no phantom
  broadcasts), and an attached topology contributes its per-hop traffic
  shape (:func:`comm_hops_per_round`: gossip edges on the client hop —
  identical for the dense and sparse lowerings — and aggregator-tier
  messages for hierarchies: upward hops pay the tier compressor's width
  when ``tier_compression`` is attached, downward re-broadcasts stay
  dense f32);
* ``topk_sparsify`` — magnitude top-k with the complement zeroed (FedLin's
  uplink sparsifier; the ``TopK(per_client=False)`` legacy flatten in
  repro/core/compressors.py is this exact function);
* ``quantize_bf16`` — the :class:`~repro.core.compressors.Bf16` round-trip.

The first-class compressor objects (TopK, RandK, StochasticQuant, Bf16,
Chain, ErrorFeedback) live in :mod:`repro.core.compressors`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_num_params


def topk_sparsify(a: jax.Array, k_frac: float) -> jax.Array:
    """Keep the top ``round(k_frac * size)`` (min 1) entries of |a| (per
    leaf), zeroing the rest. Shape-preserving; differentiable a.e. (we only
    use it on gradients, never through it)."""
    if k_frac >= 1.0:
        return a
    flat = a.reshape(-1)
    k = max(1, int(round(k_frac * flat.size)))
    # threshold = k-th largest magnitude; ties keep >= threshold entries.
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    return jnp.where(mask, flat, 0.0).reshape(a.shape)


def quantize_bf16(a: jax.Array) -> jax.Array:
    """Round-trip through bfloat16 — models a half-width transmitted vector."""
    return a.astype(jnp.bfloat16).astype(a.dtype)


def leaf_name(path) -> str:
    """Canonical slash-joined leaf name for a jax key path — the naming
    contract shared by :class:`~repro.core.compressors.CompressionPlan`
    globs, telemetry ``leaf_stats`` labels and per-leaf billing (e.g.
    ``('embed', 'w') -> "embed/w"``, list positions render as digits)."""
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            parts.append(str(k.name))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:  # FlattenedIndexKey and friends
            parts.append(str(getattr(k, "key", k)).strip(".[]'\""))
    return "/".join(parts)


def leaf_info_of(params) -> list:
    """The message leaf decomposition ``[(name, n_coords), ...]`` of a
    model pytree, in flatten order (== ``ArenaLayout.row_segments`` leaf
    order — arena runs unpack to exactly this tree). This is the shared
    vocabulary between plans, billing and telemetry: names feed plan
    globs, sizes feed the exact ``wire_bits`` rounding."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    return [(leaf_name(p), int(leaf.size)) for p, leaf in flat]


def message_leaf_bits_of(algo, leaf_info) -> list | None:
    """Per-leaf exact uplink wire bits for one client's one UP vector, or
    None when the algorithm cannot bill per-leaf (no ``message_leaf_bits``
    hook, or internal compression the engine cannot decompose — FedLin)."""
    fn = getattr(algo, "message_leaf_bits", None)
    return None if fn is None else fn(leaf_info)


def bits_per_coord_of(algo) -> float:
    """Bit-true uplink width (bits per model coordinate per UP vector) an
    algorithm declares; falls back to ``32 * up_frac`` for objects that
    predate the compressor subsystem."""
    bits = getattr(algo, "bits_per_coord", None)
    if bits is not None:
        return float(bits)
    return 32.0 * float(getattr(algo, "up_frac", 1.0))


def transmit_frac_of(algo) -> float:
    """Uplink duty cycle: the expected fraction of rounds a client's
    message actually lands at the server. Folds the attached delay model
    (``with_delay`` — buffered rounds transmit ZERO uplink bits, the
    server reuses its last-known copy) AND the client-sampling rate
    (``with_participation`` — absent clients cannot deliver; the engine
    ANDs the arrival mask with the presence mask, and the independent
    schedules multiply in expectation). 1.0 for synchronous
    full-participation algorithms; downlink broadcasts stay dense."""
    return float(getattr(algo, "transmit_frac", 1.0))


def receive_frac_of(algo) -> float:
    """Downlink duty cycle: the expected fraction of rounds a client
    RECEIVES the broadcast. Present-only downlink: under client sampling
    absent clients keep frozen replicas instead of receiving phantom
    broadcasts, so the meter bills downlink at the participation rate
    (1.0 for full participation; delay models do not reduce downlink —
    stale-but-present clients still apply the buffered-mean update)."""
    return float(getattr(algo, "receive_frac", 1.0))


def topology_of(algo):
    """The algorithm's aggregation topology, or None for the flat star."""
    return getattr(algo, "topology", None)


def tier_bits_of(topo) -> float:
    """Wire bits per coordinate on UPWARD aggregator-tier hops: 32.0
    dense f32, or the hierarchy's ``tier_compression`` width when one is
    attached (repro/core/topology.py `Tier recompression`). Downward
    tier re-broadcasts always stay dense f32."""
    return float(getattr(topo, "tier_bits_per_coord", 32.0))


def comm_hops_per_round(algo, n_params: int, n_clients: int = 1,
                        leaf_info=None) -> list:
    """Per-hop EXPECTED uplink traffic for one round, as dicts of
    ``{hop, messages, bits}``. The client (first) hop pays the compressor
    stack's wire width x the transmit duty cycle — once per message,
    where a gossip topology sends one message per directed graph edge
    (IDENTICAL for the dense and sparse lowerings — the same edges are
    exchanged either way) and star/hierarchical send one per client.
    Aggregator-tier hops (edge->root re-transmissions in a hierarchy)
    carry dense f32 partial aggregates unless the hierarchy attaches a
    ``tier_compression`` — then each upward tier message pays that
    compressor's wire width instead (:func:`tier_bits_of`).

    Pass ``leaf_info`` (see :func:`leaf_info_of`) to bill the client hop
    EXACTLY per leaf: actual sparsifier kept counts (``max(1, round(k *
    n))`` — tiny leaves cost more than the fraction declares) and
    per-leaf :class:`~repro.core.compressors.CompressionPlan` rules,
    falling back to the fractional ``n_params * bits_per_coord`` when the
    algorithm cannot decompose per leaf."""
    topo = topology_of(algo)
    up_mult = topo.client_up_mult(n_clients) if topo is not None else 1.0
    msg_bits = float(n_params) * bits_per_coord_of(algo)
    if leaf_info is not None:
        lb = message_leaf_bits_of(algo, leaf_info)
        if lb is not None:
            msg_bits = float(sum(lb))
    hops = [{
        "hop": "client",
        "messages": n_clients * up_mult,
        "bits": (algo.vectors_up * msg_bits * n_clients * up_mult
                 * transmit_frac_of(algo)),
    }]
    for label, msgs in (topo.aggregator_hops(n_clients) if topo else ()):
        hops.append({"hop": label, "messages": msgs,
                     "bits": algo.vectors_up * n_params * msgs
                     * tier_bits_of(topo)})
    return hops


@dataclasses.dataclass
class CommMeter:
    """Accumulates transmitted bytes across rounds for one algorithm.

    Two modes:

    * **bit-true** (``bits_up`` set — use ``for_params(params, algo=...)``):
      per-vector cost is ``n_params * bits_up / 8`` bytes, with ``bits_up``
      derived from the algorithm's compressor stack. Compression is already
      folded in — ``tick`` must NOT also be given ``up_frac`` (raises, to
      catch double counting).
    * **legacy** (``bits_up`` None): dense ``itemsize`` bytes per
      coordinate scaled by an explicit ``up_frac`` per tick. Reachable
      only through the direct constructor — the ``itemsize`` kwarg of
      ``for_params`` now raises (it was silently wrong for bf16/quantized
      uplinks: a 4-byte default regardless of what the compressor put on
      the wire)."""

    n_params: int
    itemsize: int = 4
    n_clients: int = 1
    bits_up: float | None = None
    bits_down: float | None = None
    #: uplink duty cycle: expected fraction of rounds a client's uplink
    #: lands (``with_delay`` algorithms transmit ZERO uplink bits on
    #: buffered rounds; the server reuses its last-known copy).
    up_duty: float = 1.0
    #: downlink duty cycle: present-only downlink — under client sampling
    #: absent clients keep frozen replicas and are NOT billed a broadcast.
    down_duty: float = 1.0
    #: topology traffic shape (repro/core/topology.py): first-hop uplink
    #: messages per client (gossip degree), downlink client-hop multiplier
    #: (0 = no broadcast at all), and aggregator-tier messages per vector
    #: (edge->root re-transmissions — upward hops pay ``tier_bits_up``
    #: bits/coord, the tier compressor's width when one is attached;
    #: downward tier re-broadcasts stay dense f32).
    up_mult: float = 1.0
    down_mult: float = 1.0
    agg_msgs: float = 0.0
    tier_bits_up: float = 32.0
    #: exact per-leaf uplink wire bits for one client's one UP vector, in
    #: leaf flatten order (``for_params`` fills this whenever the algorithm
    #: can bill per leaf). When set, ``bits_up == sum(leaf_bits)/n_params``
    #: — the exact size-weighted width, actual kept counts and per-leaf
    #: plan rules included.
    leaf_bits: tuple | None = None
    rounds: int = 0
    bytes_up: int = 0
    bytes_down: int = 0

    @classmethod
    def for_params(cls, params, *, algo=None, itemsize: int | None = None,
                   n_clients: int = 1) -> "CommMeter":
        """Meter for one parameter pytree. Pass ``algo=`` for bit-true
        accounting from its compressor stack, its delay model's uplink
        duty cycle, its sampling rate's downlink duty cycle, and its
        topology's per-hop traffic shape; ``itemsize`` is REMOVED and
        raises with a migration hint."""
        if itemsize is not None:
            raise ValueError(
                "CommMeter.for_params(itemsize=...) was removed: it "
                "assumed a fixed dense width and miscounted compressed "
                "uplinks. Migrate to CommMeter.for_params(params, "
                "algo=algo, n_clients=n) for bit-true accounting from the "
                "algorithm's compressor stack (or construct "
                "CommMeter(n_params=..., itemsize=...) directly if you "
                "really want a fixed width).")
        if algo is not None:
            topo = topology_of(algo)
            n_params = tree_num_params(params)
            lb = message_leaf_bits_of(algo, leaf_info_of(params))
            bits_up = (sum(lb) / float(n_params) if lb
                       else bits_per_coord_of(algo))
            return cls(n_params=n_params, n_clients=n_clients,
                       bits_up=bits_up,
                       leaf_bits=tuple(lb) if lb else None,
                       bits_down=32.0 * float(getattr(algo, "down_frac", 1.0)),
                       up_duty=transmit_frac_of(algo),
                       down_duty=receive_frac_of(algo),
                       up_mult=(topo.client_up_mult(n_clients)
                                if topo is not None else 1.0),
                       down_mult=(topo.broadcast_mult(n_clients)
                                  if topo is not None else 1.0),
                       agg_msgs=float(sum(m for _, m in
                                          topo.aggregator_hops(n_clients))
                                      if topo is not None else 0.0),
                       tier_bits_up=(tier_bits_of(topo)
                                     if topo is not None else 32.0))
        return cls(n_params=tree_num_params(params),
                   itemsize=4 if itemsize is None else itemsize,
                   n_clients=n_clients)

    def tick(self, vectors_up: int, vectors_down: int, *,
             up_frac: float | None = None, down_frac: float = 1.0) -> None:
        """Record one communication round. In legacy mode ``up_frac`` < 1
        models sparsified uplinks; in bit-true mode the compressed width is
        already baked into ``bits_up`` and passing ``up_frac`` raises.
        Aggregator-tier hops (hierarchical topologies) are billed dense
        f32 in BOTH directions — the tree is traversed up and down."""
        self.rounds += 1
        if self.bits_up is not None:
            if up_frac is not None:
                raise ValueError(
                    "bit-true CommMeter already folds compression into "
                    "bits_up; passing up_frac would double-count")
            per_coord = self.n_params * self.n_clients
            bits_down = 32.0 if self.bits_down is None else self.bits_down
            agg_bits_up = self.agg_msgs * self.n_params * self.tier_bits_up
            agg_bits_down = self.agg_msgs * self.n_params * 32.0
            self.bytes_up += int(vectors_up * (per_coord * self.up_mult
                                               * self.bits_up * self.up_duty
                                               + agg_bits_up) / 8.0)
            self.bytes_down += int(vectors_down * (per_coord * self.down_mult
                                                   * bits_down * down_frac
                                                   * self.down_duty
                                                   + agg_bits_down) / 8.0)
            return
        per_vec = self.n_params * self.itemsize * self.n_clients
        self.bytes_up += int(vectors_up * per_vec
                             * (1.0 if up_frac is None else up_frac))
        self.bytes_down += int(vectors_down * per_vec * down_frac)

    def tick_round(self, algo) -> None:
        """Record one round for ``algo`` using the right mode automatically
        (the call sites in FedTrainer / launch.train)."""
        if self.bits_up is not None:
            self.tick(algo.vectors_up, algo.vectors_down)
        else:
            self.tick(algo.vectors_up, algo.vectors_down,
                      up_frac=getattr(algo, "up_frac", 1.0))

    @property
    def total(self) -> int:
        return self.bytes_up + self.bytes_down


def comm_bits_per_round(algo, n_params: int, n_clients: int = 1,
                        leaf_info=None) -> dict:
    """Bit-true EXPECTED wire bits per communication round (the Remark 2
    accounting with the compressor stack, the delay model's uplink duty
    cycle, the sampling rate's downlink duty cycle, and the topology's
    per-hop traffic folded in; downlink stays dense f32). ``up_bits``
    sums all uplink hops (see :func:`comm_hops_per_round` — interior
    tier hops pay the tier compressor's width when one is attached); the
    hierarchy's downward tier re-broadcasts mirror the upward hops but
    always stay dense f32 (tier recompression is an UPLINK mechanism).
    ``leaf_info`` upgrades the client hop to exact per-leaf billing
    (actual kept counts + per-leaf plan rules) — see
    :func:`comm_hops_per_round`."""
    topo = topology_of(algo)
    up = sum(h["bits"] for h in
             comm_hops_per_round(algo, n_params, n_clients, leaf_info))
    down_mult = topo.broadcast_mult(n_clients) if topo is not None else 1.0
    agg_msgs = (sum(m for _, m in topo.aggregator_hops(n_clients))
                if topo is not None else 0)
    down = algo.vectors_down * n_params * (
        n_clients * down_mult * 32.0 * receive_frac_of(algo)
        + agg_msgs * 32.0)
    return {"up_bits": up, "down_bits": down, "total_bits": up + down}


def sparsified_up_frac(k_frac: float) -> float:
    """Effective uplink fraction for top-k: values + int32 indices."""
    if k_frac >= 1.0:
        return 1.0
    return 2.0 * k_frac
