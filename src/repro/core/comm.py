"""Communication accounting and compression operators.

The paper's headline claim (Remark 2) is a *communication-volume* one:
FedCET moves ONE n-dimensional vector per client per round where SCAFFOLD /
FedTrack / FedLin move two. This module provides

* :class:`CommMeter` — declarative byte accounting per round from the
  algorithm's ``vectors_up`` / ``vectors_down`` and the model size;
* ``topk_sparsify`` — magnitude top-k with the complement zeroed (FedLin's
  uplink sparsifier; also reusable for beyond-paper FedCET compression);
* ``quantize_bf16`` / error-feedback helpers — a beyond-paper option that
  halves FedCET's remaining traffic again (recorded separately in
  EXPERIMENTS.md; the paper itself transmits full-precision vectors).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_num_params


def topk_sparsify(a: jax.Array, k_frac: float) -> jax.Array:
    """Keep the top ``ceil(k_frac * size)`` entries of |a| (per leaf),
    zeroing the rest. Shape-preserving; differentiable a.e. (we only use it
    on gradients, never through it)."""
    if k_frac >= 1.0:
        return a
    flat = a.reshape(-1)
    k = max(1, int(round(k_frac * flat.size)))
    # threshold = k-th largest magnitude; ties keep >= threshold entries.
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    return jnp.where(mask, flat, 0.0).reshape(a.shape)


def quantize_bf16(a: jax.Array) -> jax.Array:
    """Round-trip through bfloat16 — models a half-width transmitted vector."""
    return a.astype(jnp.bfloat16).astype(a.dtype)


@dataclasses.dataclass
class CommMeter:
    """Accumulates transmitted bytes across rounds for one algorithm."""

    n_params: int
    itemsize: int = 4
    n_clients: int = 1
    rounds: int = 0
    bytes_up: int = 0
    bytes_down: int = 0

    @classmethod
    def for_params(cls, params, *, itemsize: int = 4, n_clients: int = 1) -> "CommMeter":
        return cls(n_params=tree_num_params(params), itemsize=itemsize,
                   n_clients=n_clients)

    def tick(self, vectors_up: int, vectors_down: int, *,
             up_frac: float = 1.0, down_frac: float = 1.0) -> None:
        """Record one communication round. ``up_frac`` < 1 models sparsified
        uplinks (top-k indices+values ~= 2 * k_frac of dense payload)."""
        per_vec = self.n_params * self.itemsize * self.n_clients
        self.rounds += 1
        self.bytes_up += int(vectors_up * per_vec * up_frac)
        self.bytes_down += int(vectors_down * per_vec * down_frac)

    @property
    def total(self) -> int:
        return self.bytes_up + self.bytes_down


def sparsified_up_frac(k_frac: float) -> float:
    """Effective uplink fraction for top-k: values + int32 indices."""
    if k_frac >= 1.0:
        return 1.0
    return 2.0 * k_frac
