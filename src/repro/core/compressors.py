"""First-class message compressors for the federated round engine.

The paper's headline is communication volume (Remark 2: ONE n-vector per
client per round); this module owns what happens to that vector on the wire.
A :class:`Compressor` is a stateless ``compress(key, leaf) -> leaf`` object
attached to an engine algorithm through ``with_compression(...,
compressor=...)`` (repro/core/engine.py); client-side error feedback is an
explicit :class:`ErrorFeedback` wrapper whose memory rides in ``EngineState``
like any other transform extra.

Conventions (shared with the whole repo):

* message leaves are STACKED ``[clients, ...]`` pytrees — axis 0 is the
  client axis. Per-client compressors (``TopK(per_client=True)``) operate
  row-wise; ``per_client=False`` keeps the seed's legacy flatten, where
  top-k competes ACROSS clients (needed for seed-equivalence).
* stochastic compressors receive a per-round PRNG key derived from the
  engine state's step counter (never reused across rounds — the same fix
  PR 1 applied to participation masks) and use randomness that is
  SYNCHRONIZED across clients: one mask / one dither per round, shared by
  every client and the server. This buys two things:

  - :class:`RandK` transmits VALUES ONLY (the server regenerates the mask
    from the shared round seed), so its wire cost is ``32 * k_frac`` bits
    per coordinate — no index traffic;
  - FedCET's fixed point survives exactly. The aggregation update depends
    only on ``msg_i - msg_bar``; with a shared-randomness compressor ``C``,
    clients at consensus (``v_i = x*`` for all ``i``) transmit identical
    messages, so ``msg_i - msg_bar = 0`` and the optimum stays a fixed
    point pathwise. Unbiasedness (``E[C(v)] = v``) keeps the drift update
    mean-zero along the trajectory. Together these remove the stochastic
    error floor PR 1 measured for biased compressors under random
    participation (pinned in tests/test_engine.py).

Accounting contract (the "bit-true" side of the abstraction): every
compressor declares

* ``keep_frac``   — fraction of coordinates surviving (1.0 for quantizers);
* ``index_bits``  — position bits per KEPT coordinate (32 for TopK's int32
  indices, 0 for seed-synchronized RandK);
* ``value_bits``  — transmitted width of kept values (``None`` = leave the
  incoming width unchanged — sparsifiers pass values through);
* ``bits_per_coord`` — exact wire bits per ORIGINAL (dense f32) coordinate,
  derived from the above; ``up_frac = bits_per_coord / 32``.

:class:`Chain` composes stages left-to-right and accounts exactly: value
width is the NARROWEST any stage puts on the wire (first-narrowest-wins —
a later, wider quantizer re-encodes already-narrow values and cannot widen
the payload), index bits accumulate per stage at that stage's survival
fraction. Per-leaf scalar overheads (one f32 scale per leaf for
:class:`StochasticQuant`) are O(1) per tensor and excluded.

Fractional accounting (``bits_per_coord``) is an n -> infinity statement;
the ACTUAL kept count of a sparsifier is ``max(1, round(k_frac * n))`` per
leaf, so tiny leaves (biases, layernorm scales) transmit more than the
declared fraction. ``wire_bits(n)`` is the exact per-leaf cost with that
rounding applied — ``CommMeter``/``comm_bits_per_round`` bill it when
given the leaf decomposition (repro/core/comm.py:leaf_info_of).

:class:`CompressionPlan` maps leaf paths (globs over ``embed/w``-style
slash-joined names, or flatten-order leaf indices — the same order as
``ArenaLayout.row_segments``) to per-leaf compressor specs, with a greedy
bit-budget allocator (``plan.allocate``) and an adaptive tightening hook
(:class:`AdaptivePlan`). A plan IS a Compressor: it rides the same
``MessageCompression`` transform, and a plan mapping every leaf to one
spec is bitwise-identical to the uniform path (same ``fold_in(key, i)``
per-leaf subkey enumeration, same per-leaf stateful-wrapper math).

``from_spec`` parses the launch-config grammar (configs/base.py):
``"topk:0.3"``, ``"randk:0.25"``, ``"q8"``, ``"nat"`` (natural /
exponent-only quantization), ``"bf16"``, chained with ``+``
(``"topk:0.3+bf16"``), with an optional ``"ef:"`` (error feedback) or
``"shift:"`` (DIANA-style shifted compression — see :class:`Shifted`)
prefix around the whole chain.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.arena import Arena, pack, pack_rows, unpack
from repro.core.comm import quantize_bf16, topk_sparsify

__all__ = [
    "AdaptivePlan",
    "Bf16",
    "Chain",
    "CompressionPlan",
    "Compressor",
    "ErrorFeedback",
    "Identity",
    "NaturalQuant",
    "RandK",
    "Shifted",
    "StochasticQuant",
    "TopK",
    "as_compressor",
    "auto_wrap",
    "from_spec",
    "parse_plan",
    "stack_wire_bits",
]


def _coord_shape(leaf) -> tuple:
    """The per-client coordinate space of a stacked leaf: axis 0 is ALWAYS
    the client axis (a ``(n_clients,)`` leaf is a stacked scalar parameter
    with coordinate space ``()`` — never a per-client draw axis, which
    would break the synchronized-randomness invariant)."""
    return tuple(leaf.shape[1:])


def _is_arena(x) -> bool:
    return isinstance(x, Arena)


def _has_arena(tree) -> bool:
    return any(map(_is_arena, jax.tree.leaves(tree, is_leaf=_is_arena)))


def _k_of(k_frac: float, n: int) -> int:
    return max(1, int(round(k_frac * n)))


@dataclasses.dataclass(frozen=True)
class Compressor:
    """Base: a stateless per-leaf transform with declared wire cost.

    Subclasses implement ``compress(key, leaf)`` (``key`` is ``None`` for
    deterministic compressors — ``requires_key`` gates whether the engine
    derives one) and override the accounting class attributes."""

    #: does compress() consume a PRNG key (stochastic compressor)?
    requires_key = False
    #: is E[compress(v)] = v over the key distribution?
    unbiased = False
    #: does apply() carry per-client memory in `extra` (ErrorFeedback /
    #: Shifted)? Stateful wrappers cannot nest inside another stateful
    #: wrapper or a Chain — there is one `extra` slot per transform.
    stateful = False

    # ------------------------------------------------------------ accounting
    @property
    def keep_frac(self) -> float:
        return 1.0

    @property
    def index_bits(self) -> float:
        return 0.0

    @property
    def value_bits(self) -> float | None:
        """Transmitted width of kept values; None = unchanged (passthrough)."""
        return None

    @property
    def bits_per_coord(self) -> float:
        """Exact wire bits per original dense-f32 coordinate."""
        return self.keep_frac * ((self.value_bits or 32.0) + self.index_bits)

    @property
    def up_frac(self) -> float:
        """Uplink fraction vs a dense f32 payload (bit-true)."""
        return self.bits_per_coord / 32.0

    @property
    def omega(self) -> float:
        """Variance parameter of an unbiased compressor
        (``E|C(x) - x|^2 <= omega |x|^2``); 0.0 for (near-)deterministic
        ones. Drives :class:`Shifted`'s stable step ``beta = 1/(1+omega)``."""
        return 0.0

    def wire_bits(self, n: int) -> float:
        """EXACT uplink wire bits one client pays for one leaf of ``n``
        coordinates — the actual-kept-count analogue of
        ``n * bits_per_coord``. Sparsifying stages keep
        ``max(1, round(k_frac * n))`` coordinates (the same rounding
        ``compress`` applies), so tiny leaves bill their real cost; the
        drift vs the fractional declaration is at most one coordinate's
        worth of bits per sparsifying stage per leaf (pinned in
        tests/test_comm.py)."""
        return _stages_wire_bits(_wire_stages(self), n)

    # -------------------------------------------------------------- compute
    def compress(self, key, leaf):
        raise NotImplementedError

    # ---------------------------------------------- pytree-level application
    def init_extra(self, msg_shapes):
        """Per-client carried state (None for stateless compressors)."""
        del msg_shapes
        return None

    def apply(self, key, msg, extra):
        """Compress a message pytree; distinct subkey per leaf. Arena-
        packed messages (core/arena.py) route through ``apply_arena``."""
        if _has_arena(msg):
            return self.apply_arena(key, msg, extra)
        leaves, treedef = jax.tree.flatten(msg)
        out = [
            self.compress(
                jax.random.fold_in(key, i) if self.requires_key else None, leaf)
            for i, leaf in enumerate(leaves)
        ]
        return jax.tree.unflatten(treedef, out), extra

    def apply_arena(self, key, msg, extra):
        """Compress an arena-packed message. The generic path unpacks each
        Arena back to its stacked per-leaf tree, applies the normal
        per-leaf compression and repacks — the unpacked tree flattens in
        the arena's own layout order, so per-leaf subkeys, quantizer
        scales and dither draws are IDENTICAL to the per-leaf engine
        (which is what pins arena runs <= 1e-12 against per-leaf runs for
        every compressor, including the pad-unsafe sparsifiers).
        Compressors whose math is expressible over packed rows override
        this with a native single-launch version (StochasticQuant)."""
        unpacked = jax.tree.map(lambda a: unpack(a) if _is_arena(a) else a,
                                msg, is_leaf=_is_arena)
        out, extra = self.apply(key, unpacked, extra)
        out = jax.tree.map(
            lambda a, o: pack(o, a.layout) if _is_arena(a) else o,
            msg, out, is_leaf=_is_arena)
        return out, extra


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    """Exact no-op (useful as a from_spec result and a Chain unit)."""

    def compress(self, key, leaf):
        del key
        return leaf


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Magnitude top-k sparsification (biased — pair with ErrorFeedback).

    ``per_client=True`` keeps the top ``round(k_frac * n)`` entries (min 1,
    matching the seed's ``topk_sparsify`` rounding) of each client's OWN
    row — the realistic federation semantics. ``False`` reproduces the
    seed's flatten, where clients compete for the global top-k of the
    stacked leaf (kept bit-identical for seed equivalence)."""

    k_frac: float
    per_client: bool = True

    @property
    def keep_frac(self) -> float:
        return min(self.k_frac, 1.0)

    @property
    def index_bits(self) -> float:
        return 32.0 if self.keep_frac < 1.0 else 0.0

    def compress(self, key, leaf):
        del key
        if self.k_frac >= 1.0:
            return leaf
        if not self.per_client:
            return topk_sparsify(leaf, self.k_frac)
        rows = leaf.reshape(leaf.shape[0], -1)  # axis 0 = clients, always
        k = _k_of(self.k_frac, rows.shape[1])
        thresh = jax.lax.top_k(jnp.abs(rows), k)[0][:, -1:]
        kept = jnp.where(jnp.abs(rows) >= thresh, rows, 0.0)
        return kept.reshape(leaf.shape)


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Uniform random-k sparsification, rescaled by ``n/k`` — UNBIASED.

    Draws one exact-k coordinate mask per round per leaf from the shared
    round key (all clients + the server regenerate it, so no index bits
    travel) and rescales kept entries so ``E[compress(v)] = v``."""

    k_frac: float

    requires_key = True
    unbiased = True

    @property
    def keep_frac(self) -> float:
        return min(self.k_frac, 1.0)

    @property
    def omega(self) -> float:
        """Classic rand-k variance: E|C(x) - x|^2 = (n/k - 1) |x|^2."""
        return max(1.0 / self.keep_frac - 1.0, 0.0)

    def compress(self, key, leaf):
        if self.k_frac >= 1.0:
            return leaf
        shape = _coord_shape(leaf)
        n = math.prod(shape)
        k = _k_of(self.k_frac, n)
        # exact-k uniform subset: keep the k largest of n iid uniform scores
        scores = jax.random.uniform(key, (n,))
        thresh = jax.lax.top_k(scores, k)[0][-1]
        mask = (scores >= thresh).reshape(shape)
        scale = jnp.asarray(n / k, leaf.dtype)
        return jnp.where(mask, leaf * scale, 0.0)


@dataclasses.dataclass(frozen=True)
class StochasticQuant(Compressor):
    """Dithered fixed-point quantization to ``bits`` — UNBIASED.

    Per leaf: ``s = max|leaf| / L`` with ``L = 2^(bits-1) - 1`` (one shared
    scale across clients, so consensus messages quantize identically), then
    stochastic rounding via a shared uniform dither ``u ~ U[0,1)``:
    ``q = clip(floor(leaf/s + u), -L, L)``; the round-trip transmits
    ``q * s``. ``E_u[floor(v + u)] = v`` makes the round-trip unbiased.

    ``per_client_dither=True`` draws an INDEPENDENT dither per client row
    (non-seed-synchronized — a federation whose clients cannot share a
    round seed). Still unbiased and the same wire bits, but it gives up
    the synchronized-randomness consequence documented at module top:
    clients at consensus no longer transmit identical messages, so
    FedCET's fixed point only holds in expectation, not pathwise (scale
    stays shared/deterministic either way: it is max|leaf| over the whole
    stacked leaf).

    ``use_kernel=True`` routes the round-trip through the Pallas kernel
    (kernels/quantize.py — interpret mode off-TPU); the default pure-jnp
    path is the same math as the kernel's ref.py oracle."""

    bits: int = 8
    use_kernel: bool = False
    per_client_dither: bool = False

    requires_key = True
    unbiased = True

    def __post_init__(self):
        assert 2 <= self.bits <= 16, self.bits

    @property
    def value_bits(self) -> float:
        return float(self.bits)

    def compress(self, key, leaf):
        levels = 2 ** (self.bits - 1) - 1
        ct = leaf.dtype if leaf.dtype in (jnp.float32, jnp.float64) \
            else jnp.float32
        a = leaf.astype(ct)
        scale = jnp.max(jnp.abs(a)) / levels
        if self.per_client_dither:
            u = jax.random.uniform(key, leaf.shape, dtype=ct)
        else:
            u = jnp.broadcast_to(
                jax.random.uniform(key, _coord_shape(leaf), dtype=ct), a.shape)
        if self.use_kernel:
            from repro.kernels import ops as kops

            return kops.stochastic_quantize(a, u, scale,
                                            self.bits).astype(leaf.dtype)
        inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
        q = jnp.clip(jnp.floor(a * inv + u), -levels, levels)
        return (q * scale).astype(leaf.dtype)

    def apply_arena(self, key, msg, extra):
        """Native packed-rows quantization: ONE launch for the whole
        pytree instead of a scale/dither/floor chain per leaf.

        Bitwise-equivalent to the per-leaf path: the per-leaf scale
        ``max|leaf|/levels`` becomes a segment-max over the leaf's rows
        (pads are zero, max is exact), the per-leaf dithers are drawn
        from the SAME ``fold_in(key, i)`` enumeration (flatten order ==
        layout order) at the same coordinate shapes and packed next to
        the data (pad dither 0 keeps pads at exactly 0 through
        ``floor``), and the elementwise expression is identical."""
        if (not isinstance(msg, Arena) or msg.data.ndim != 3
                or msg.layout.dtype not in (jnp.float32, jnp.float64)):
            return super().apply_arena(key, msg, extra)
        lo, a = msg.layout, msg.data
        levels = 2 ** (self.bits - 1) - 1
        seg = jnp.asarray(lo.row_segments())
        row_max = jnp.max(jnp.abs(a), axis=(0, 2))                  # [rows]
        leaf_max = jax.ops.segment_max(row_max, seg,
                                       num_segments=len(lo.shapes))
        scale = (leaf_max / levels)[seg][:, None]                   # [rows, 1]
        keys = [jax.random.fold_in(key, i) for i in range(len(lo.shapes))]
        if self.per_client_dither:
            lead = a.shape[0]
            u = pack_rows([jax.random.uniform(k, (lead,) + shp, dtype=a.dtype)
                           for k, shp in zip(keys, lo.shapes)], lo, lead=lead)
        else:
            u = pack_rows([jax.random.uniform(k, shp, dtype=a.dtype)
                           for k, shp in zip(keys, lo.shapes)], lo)
        if self.use_kernel:
            from repro.kernels import ops as kops

            lead, rows, lanes = a.shape
            out = kops.stochastic_quantize_rows(
                a.reshape(lead * rows, lanes),
                jnp.broadcast_to(u, a.shape).reshape(lead * rows, lanes),
                jnp.broadcast_to(scale, (lead, rows, 1)).reshape(-1, 1),
                self.bits).reshape(a.shape)
            return Arena(out, lo), extra
        inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
        q = jnp.clip(jnp.floor(a * inv + u), -levels, levels)
        return Arena(q * scale, lo), extra


@dataclasses.dataclass(frozen=True)
class NaturalQuant(Compressor):
    """Natural (exponent-only) compression [Horvath et al., 2019] —
    UNBIASED. Each value keeps its sign and is stochastically rounded to
    one of the two nearest powers of two: for ``2^a <= |v| < 2^(a+1)``,
    transmit ``2^(a+1)`` with probability ``|v|/2^a - 1`` and ``2^a``
    otherwise, so ``E[C(v)] = v`` per coordinate. The mantissa never
    rides the wire: a sign bit plus an 8-bit exponent field (the full f32
    exponent range) is 9 bits/coordinate, with NO shared scale to
    synchronize — unlike :class:`StochasticQuant` there is no per-leaf
    max to agree on, which is what makes natural compression compose
    freely with sparsifiers in practice. Relative variance is bounded by
    construction: ``omega = 1/8``, independent of dimension.

    The rounding dither is shared across clients (one draw per
    coordinate per round, broadcast over the client axis), preserving the
    synchronized-randomness invariant: clients at consensus transmit
    identical messages."""

    requires_key = True
    unbiased = True

    @property
    def value_bits(self) -> float:
        return 9.0  # sign + 8-bit exponent; mantissa dropped

    @property
    def omega(self) -> float:
        """E|C(x) - x|^2 <= (1/8) |x|^2 (Horvath et al., Thm. 7)."""
        return 0.125

    def compress(self, key, leaf):
        ct = leaf.dtype if leaf.dtype in (jnp.float32, jnp.float64) \
            else jnp.float32
        a = leaf.astype(ct)
        mag = jnp.abs(a)
        e = jnp.floor(jnp.log2(jnp.where(mag > 0, mag, 1.0)))
        # ldexp, not exp2: XLA lowers exp2 to exp(x ln 2), which is off by
        # an ulp — the wire value must be an EXACT power of two (that is
        # the whole point: only the exponent is transmitted).
        low = jnp.ldexp(jnp.ones_like(a), e.astype(jnp.int32))
        # clip guards the floor(log2) edge at exact powers of two, where
        # float rounding could leave p infinitesimally outside [0, 1).
        p_up = jnp.clip(mag / low - 1.0, 0.0, 1.0)
        u = jnp.broadcast_to(
            jax.random.uniform(key, _coord_shape(leaf), dtype=ct), a.shape)
        out = jnp.sign(a) * low * jnp.where(u < p_up, 2.0, 1.0)
        return jnp.where(mag > 0, out, 0.0).astype(leaf.dtype)


@dataclasses.dataclass(frozen=True)
class Bf16(Compressor):
    """bfloat16 round-trip (deterministic nearest-even rounding — biased)."""

    @property
    def value_bits(self) -> float:
        return 16.0

    def compress(self, key, leaf):
        del key
        return quantize_bf16(leaf)


@dataclasses.dataclass(frozen=True)
class Chain(Compressor):
    """Left-to-right composition: ``Chain((a, b))`` transmits ``b(a(v))``.

    Accounting is exact: the value width is the narrowest any stage sets
    (first-narrowest-wins); index bits accumulate per sparsifying stage,
    weighted by the survival fraction at that stage (e.g. ``TopK(0.3) +
    Bf16`` costs ``0.3 * (16 + 32)`` bits/coordinate — bf16 values, int32
    indices)."""

    stages: tuple

    def __post_init__(self):
        if any(s.stateful for s in self.stages):
            raise ValueError("stateful wrappers (ErrorFeedback/Shifted) go "
                             "AROUND a chain, not inside it")

    @property
    def requires_key(self):  # type: ignore[override]
        return any(s.requires_key for s in self.stages)

    @property
    def unbiased(self):  # type: ignore[override]
        return all(s.unbiased for s in self.stages) and bool(self.stages)

    @property
    def keep_frac(self) -> float:
        return math.prod(s.keep_frac for s in self.stages)

    @property
    def omega(self) -> float:
        """Independent unbiased stages compose as 1+w = prod_i (1+w_i)."""
        return math.prod(1.0 + s.omega for s in self.stages) - 1.0

    @property
    def index_bits(self) -> float:
        """Position bits per FINALLY-kept coordinate: each sparsifying
        stage pays its indices at that stage's survival fraction, then the
        total is normalized by the end-to-end keep fraction so the base
        ``keep_frac * (value + index)`` formula reproduces the exact sum
        (this also lets stacked engine transforms compose chains of
        chains without losing index bits)."""
        keep, idx = 1.0, 0.0
        for s in self.stages:
            keep *= s.keep_frac
            idx += keep * s.index_bits
        return idx / keep if keep > 0 else 0.0

    @property
    def value_bits(self) -> float | None:
        """First-narrowest-wins: once a stage has narrowed the payload to
        ``b`` bits, a LATER wider stage re-encodes those values but cannot
        put more information back on the wire — ``q8 + bf16`` transmits
        8-bit payloads in a 16-bit container at best, and the honest wire
        cost is the 8 bits of content. (The old scan billed the LAST
        quantizer's width, silently over-billing such chains 2x.)"""
        vb = None
        for s in self.stages:
            if s.value_bits is not None:
                vb = s.value_bits if vb is None else min(vb, s.value_bits)
        return vb

    def compress(self, key, leaf):
        for i, s in enumerate(self.stages):
            sub = (jax.random.fold_in(key, i)
                   if (s.requires_key and key is not None) else None)
            leaf = s.compress(sub, leaf)
        return leaf


@dataclasses.dataclass(frozen=True)
class ErrorFeedback(Compressor):
    """Client-side error feedback around any inner compressor:
    ``e += msg; tx = C(e); e -= tx`` — the compression error is re-injected
    next round instead of lost. The per-client memory ``e`` is transform
    extra state riding in ``EngineState`` (checkpointed with the run).

    Meant for BIASED inner compressors (TopK/Bf16). Wrapping an unbiased
    stochastic compressor reintroduces a feedback limit cycle (the floor
    PR 1 measured for top-k+EF), so ``with_compression``'s auto mode only
    applies EF when the inner compressor is biased."""

    inner: Compressor

    stateful = True

    def __post_init__(self):
        if self.inner.stateful:
            raise ValueError("cannot nest stateful wrappers: "
                             f"ErrorFeedback({type(self.inner).__name__})")

    @property
    def requires_key(self):  # type: ignore[override]
        return self.inner.requires_key

    @property
    def keep_frac(self) -> float:
        return self.inner.keep_frac

    @property
    def index_bits(self) -> float:
        return self.inner.index_bits

    @property
    def value_bits(self) -> float | None:
        return self.inner.value_bits

    @property
    def bits_per_coord(self) -> float:
        return self.inner.bits_per_coord

    def compress(self, key, leaf):
        raise TypeError("ErrorFeedback is stateful; use apply(), not compress()")

    def init_extra(self, msg_shapes):
        return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), msg_shapes)

    def apply(self, key, msg, extra):
        carried = jax.tree.map(jnp.add, extra, msg)
        tx, _ = self.inner.apply(key, carried, None)
        return tx, jax.tree.map(jnp.subtract, carried, tx)


@dataclasses.dataclass(frozen=True)
class Shifted(Compressor):
    """DIANA-style shifted compression (the compression-meets-control-variate
    structure of Mishchenko et al. / the composite-FL line in PAPERS.md):
    compress the RESIDUAL against a per-client shift ``h`` that both ends
    track from transmitted data only::

        q  = C(msg - h)        (transmitted payload)
        tx = h + q             (server-side reconstruction, enters the mean)
        h' = h + beta * q

    Because :class:`StochasticQuant` scales to ``max|input|``, quantizing
    the residual makes the quantization step SHRINK as clients converge —
    this removes the small re-excitation floor that plain dithered
    quantization sustains under random participation (measured in
    tests/test_engine.py) while keeping the same wire bits as ``inner``.
    The shift memory rides in ``EngineState`` and freezes for absent
    clients, mirroring the server's view (``h`` only advances on rounds the
    client transmits)."""

    inner: Compressor
    #: shift step; None = the DIANA-stable ``1/(1 + inner.omega)`` (1.0 for
    #: quantizers, ``k_frac`` for rand-k — beta=1 over a high-variance
    #: compressor makes the shift recursion diverge).
    beta: float | None = None

    stateful = True

    def __post_init__(self):
        if self.inner.stateful:
            raise ValueError("cannot nest stateful wrappers: "
                             f"Shifted({type(self.inner).__name__})")

    @property
    def step(self) -> float:
        return 1.0 / (1.0 + self.inner.omega) if self.beta is None else self.beta

    @property
    def requires_key(self):  # type: ignore[override]
        return self.inner.requires_key

    @property
    def unbiased(self):  # type: ignore[override]
        return self.inner.unbiased

    @property
    def keep_frac(self) -> float:
        return self.inner.keep_frac

    @property
    def index_bits(self) -> float:
        return self.inner.index_bits

    @property
    def value_bits(self) -> float | None:
        return self.inner.value_bits

    @property
    def bits_per_coord(self) -> float:
        return self.inner.bits_per_coord

    def compress(self, key, leaf):
        raise TypeError("Shifted is stateful; use apply(), not compress()")

    def init_extra(self, msg_shapes):
        return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), msg_shapes)

    def apply(self, key, msg, extra):
        resid = jax.tree.map(jnp.subtract, msg, extra)
        q, _ = self.inner.apply(key, resid, None)
        recon = jax.tree.map(jnp.add, extra, q)
        b = self.step
        shift = jax.tree.map(lambda h, qq: h + b * qq, extra, q)
        return recon, shift


# -------------------------------------------------- exact per-leaf wire bits
def _wire_stages(comp: Compressor) -> list:
    """The billable stage list of a compressor stack: stateful wrappers
    bill their inner compressor (EF/shift memories never ride the wire),
    chains flatten to their stages."""
    while isinstance(comp, (ErrorFeedback, Shifted)):
        comp = comp.inner
    return list(comp.stages) if isinstance(comp, Chain) else [comp]


def _stages_wire_bits(stages, n: int) -> float:
    """Exact wire bits for one leaf of ``n`` coords through a stage list:
    the Chain accounting model with the ACTUAL kept count
    ``max(1, round(cum_keep * n))`` in place of the fraction, and
    first-narrowest-wins value width. Each sparsifying stage pays its
    index bits at the survival count after that stage."""
    frac, kept, idx, value = 1.0, float(n), 0.0, None
    for s in stages:
        kf = s.keep_frac
        if kf < 1.0:
            frac *= kf
            kept = float(_k_of(frac, n))
        idx += kept * s.index_bits
        vb = s.value_bits
        if vb is not None:
            value = vb if value is None else min(value, vb)
    return kept * (32.0 if value is None else value) + idx


def stack_wire_bits(stack, index: int, name: str, n: int) -> float:
    """Exact wire bits one client pays for leaf ``(index, name)`` of ``n``
    coords through a TRANSFORM stack (one compressor per attached engine
    transform, applied left-to-right). Plans resolve to their per-leaf
    rule first; ``None`` entries (passthrough) bill nothing extra. This is
    the one composition rule both the per-leaf and arena lowerings bill
    through, so they agree by construction."""
    stages: list = []
    for comp in stack:
        if isinstance(comp, CompressionPlan):
            comp = comp.resolve(index, name)
        if comp is None:
            continue
        stages.extend(_wire_stages(comp))
    return _stages_wire_bits(stages, n)


# --------------------------------------------------------- per-leaf planning
def _match_leaf(name: str, pattern: str) -> bool:
    """Glob match against the slash-joined leaf path or any one of its
    components (so ``embed*`` matches ``embed/w`` and ``ln*`` matches
    ``layers_0/ln1/scale``)."""
    import fnmatch

    return (fnmatch.fnmatchcase(name, pattern)
            or any(fnmatch.fnmatchcase(part, pattern)
                   for part in name.split("/")))


@dataclasses.dataclass(frozen=True)
class CompressionPlan(Compressor):
    """Per-leaf compression policy: an ordered ``(pattern, compressor)``
    rule list resolved FIRST-MATCH-WINS against each message leaf.

    Patterns are globs over the slash-joined leaf path (``embed/w``,
    ``layers_0/attn/wq`` — the names :func:`repro.core.comm.leaf_info_of`
    derives) matched against the full path or any single component, or
    all-digit strings naming a flatten-order leaf index (the same order as
    ``ArenaLayout.row_segments`` segments). Unmatched leaves fall through
    to ``default`` (``None`` = dense f32 passthrough).

    A plan is itself a :class:`Compressor` and rides the engine's
    ``MessageCompression`` transform unchanged. Leaf ``i`` is compressed
    with subkey ``fold_in(key, i)`` — exactly the enumeration the uniform
    per-tree path uses — and stateful rule wrappers (:class:`Shifted` /
    :class:`ErrorFeedback`) run leaf-wise against a message-shaped memory
    tree, so a plan mapping EVERY leaf to one spec is bitwise-identical to
    uniform ``with_compression`` with that spec, and checkpoints
    interchange between the two (pinned in tests/test_comp_plan.py).
    Arena-packed messages unpack, apply per-leaf, and repack (flatten
    order == layout order), so both lowerings compress AND bill
    identically.

    ``leaves`` optionally binds the leaf decomposition ``((name, n), ...)``
    so the scalar accounting properties (``bits_per_coord`` et al.) are
    exact; unbound plans estimate from their catch-all rule. Billing
    through ``CommMeter.for_params`` / ``comm_bits_per_round(...,
    leaf_info=)`` is always exact — it carries the decomposition."""

    rules: tuple = ()
    default: Compressor | None = None
    #: optional bound leaf decomposition ((name, n_coords), ...) for exact
    #: scalar accounting; attach via ``bind``/``allocate``.
    leaves: tuple | None = None

    def __post_init__(self):
        for pat, comp in self.rules:
            if comp is not None and isinstance(comp, CompressionPlan):
                raise ValueError("plans cannot nest inside plans")
        if self.default is not None and self.default.stateful:
            raise ValueError("the default rule must be stateless; name the "
                             "leaves a stateful wrapper should cover (a "
                             "'*' catch-all rule may be stateful)")

    # ------------------------------------------------------------ resolution
    def resolve(self, index: int, name: str) -> Compressor | None:
        """The compressor for leaf ``(index, name)``: first matching rule,
        else ``default``, else None (dense passthrough)."""
        for pat, comp in self.rules:
            if pat.isdigit():
                if int(pat) == index:
                    return comp
            elif _match_leaf(name, pat):
                return comp
        return self.default

    def _rule_comps(self):
        comps = [c for _, c in self.rules if c is not None]
        if self.default is not None:
            comps.append(self.default)
        return comps

    # ------------------------------------------------------------ accounting
    @property
    def stateful(self):  # type: ignore[override]
        return any(c.stateful for c in self._rule_comps())

    @property
    def requires_key(self):  # type: ignore[override]
        return any(c.requires_key for c in self._rule_comps())

    @property
    def unbiased(self):  # type: ignore[override]
        return all(c.unbiased for c in self._rule_comps())

    @property
    def omega(self) -> float:
        return max((c.omega for c in self._rule_comps()), default=0.0)

    @property
    def keep_frac(self):  # type: ignore[override]
        """None on purpose: a plan has no single keep fraction — the
        engine's ``_transforms_bits`` falls through to ``bits_per_coord``
        and per-leaf billing uses ``wire_bits``/``stack_wire_bits``."""
        return None

    @property
    def index_bits(self):  # type: ignore[override]
        return None

    @property
    def value_bits(self) -> float | None:
        return None

    @property
    def bits_per_coord(self) -> float:
        """Size-weighted average wire bits per coordinate. EXACT when the
        plan is bound to a leaf decomposition (``bind``/``allocate``);
        otherwise estimated from the catch-all rule (32.0 if none)."""
        if self.leaves:
            total = sum(n for _, n in self.leaves)
            return sum(self.tree_wire_bits(self.leaves)) / float(total)
        for pat, comp in self.rules:
            if pat == "*":
                return 32.0 if comp is None else comp.bits_per_coord
        return 32.0 if self.default is None else self.default.bits_per_coord

    def leaf_wire_bits(self, index: int, name: str, n: int) -> float:
        comp = self.resolve(index, name)
        return float(n) * 32.0 if comp is None else comp.wire_bits(n)

    def tree_wire_bits(self, leaf_info) -> list:
        """Exact per-leaf wire bits for a ``[(name, n), ...]`` leaf
        decomposition (one client, one up-vector)."""
        return [self.leaf_wire_bits(i, nm, int(n))
                for i, (nm, n) in enumerate(leaf_info)]

    def bind(self, leaf_info) -> "CompressionPlan":
        """Attach the leaf decomposition so scalar accounting is exact."""
        info = tuple((str(nm), int(n)) for nm, n in leaf_info)
        return dataclasses.replace(self, leaves=info)

    # -------------------------------------------------------------- compute
    def compress(self, key, leaf):
        raise TypeError("CompressionPlan is a whole-tree policy; "
                        "use apply(), not compress()")

    def init_extra(self, msg_shapes):
        """One message-shaped memory tree when ANY rule is stateful (the
        same structure the uniform Shifted/ErrorFeedback wrappers carry —
        what makes plan and uniform checkpoints interchange); leaves whose
        rule is stateless keep zeros there untouched."""
        if not self.stateful:
            return None
        return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                            msg_shapes)

    def _apply_leaf(self, comp, sub, leaf, e):
        """One leaf through its resolved rule. Stateful wrappers run
        leaf-wise with EXACTLY the uniform wrappers' math and key gating
        (the inner compressor of leaf i sees the same ``fold_in(key, i)``
        subkey the uniform path derives)."""
        if comp is None:
            return leaf, e
        if isinstance(comp, ErrorFeedback):
            carried = e + leaf
            tx = comp.inner.compress(
                sub if comp.inner.requires_key else None, carried)
            return tx, carried - tx
        if isinstance(comp, Shifted):
            resid = leaf - e
            q = comp.inner.compress(
                sub if comp.inner.requires_key else None, resid)
            return e + q, e + comp.step * q
        return comp.compress(sub if comp.requires_key else None, leaf), e

    def apply(self, key, msg, extra):
        if _has_arena(msg):
            return self.apply_arena(key, msg, extra)
        flat, treedef = jax.tree_util.tree_flatten_with_path(msg)
        from repro.core.comm import leaf_name

        names = [leaf_name(p) for p, _ in flat]
        e_leaves = (jax.tree.leaves(extra) if extra is not None
                    else [None] * len(flat))
        out, new_e = [], []
        for i, ((_, leaf), e) in enumerate(zip(flat, e_leaves)):
            comp = self.resolve(i, names[i])
            sub = (jax.random.fold_in(key, i)
                   if key is not None and comp is not None
                   and comp.requires_key else None)
            o, ne = self._apply_leaf(comp, sub, leaf, e)
            out.append(o)
            new_e.append(ne)
        out = jax.tree.unflatten(treedef, out)
        if extra is None:
            return out, None
        return out, jax.tree.unflatten(treedef, new_e)

    def apply_arena(self, key, msg, extra):
        """Unpack message AND memory, apply per-leaf, repack both — the
        unpacked tree flattens in the arena's layout order, so rule
        resolution, per-leaf subkeys and wrapper memories are IDENTICAL
        to the per-leaf lowering."""
        unpack_tree = lambda t: jax.tree.map(  # noqa: E731
            lambda a: unpack(a) if _is_arena(a) else a, t, is_leaf=_is_arena)
        repack_tree = lambda like, t: jax.tree.map(  # noqa: E731
            lambda a, o: pack(o, a.layout) if _is_arena(a) else o,
            like, t, is_leaf=_is_arena)
        out, new_e = self.apply(key, unpack_tree(msg),
                                unpack_tree(extra) if extra is not None
                                else None)
        out = repack_tree(msg, out)
        if extra is None:
            return out, None
        return out, repack_tree(extra, new_e)

    # ------------------------------------------------------------- allocator
    def allocate(self, budget_bits_per_round: float, *, leaves,
                 sensitivity="rms", grads=None, wrap: str | None = "shift",
                 min_bits: int = 2, max_bits: int = 12) -> "CompressionPlan":
        """Greedy bit-budget allocation: pick per-leaf quantizer widths (or
        a ``k_frac`` when the budget is below the all-``min_bits`` floor)
        meeting a TOTAL uplink budget of ``budget_bits_per_round`` bits per
        client per round, and return the resulting bound plan.

        ``leaves`` is the message/params pytree (or a ``[(name, n)]``
        decomposition). ``sensitivity`` weighs leaves: ``"rms"`` (per-leaf
        root-mean-square of ``leaves``' values), ``"absmax"`` (per-leaf
        ``max|x|`` — the grid scale StochasticQuant actually uses, so the
        model-matched choice for quantizer plans), ``"grad_norm"``
        (per-leaf ``|g|/sqrt(n)`` of the ``grads`` pytree), an explicit
        per-leaf sequence, or None (uniform). Dithered quantization at ``b`` bits
        has mean-square error ``~ n * s^2 * 4^-b``, so the marginal value
        of one more bit on leaf ``i`` is ``~ s_i^2 * 4^-b_i`` per
        coordinate while its cost is flat — the allocator water-fills by
        repeatedly granting +1 bit to the leaf with the highest
        ``s_i^2 * 4^-b_i`` that still fits. ``wrap`` wraps every per-leaf
        quantizer (``"shift"`` default — the DIANA shift that removes the
        quantization floor; ``"ef"``; None = bare)."""
        if isinstance(leaves, (list, tuple)) and leaves \
                and isinstance(leaves[0], (list, tuple)) \
                and len(leaves[0]) == 2 and isinstance(leaves[0][1], int):
            info = [(str(nm), int(n)) for nm, n in leaves]
            values = None
        else:
            from repro.core.comm import leaf_info_of

            info = leaf_info_of(leaves)
            values = jax.tree.leaves(leaves)
        if sensitivity is None or sensitivity == "uniform":
            s = [1.0] * len(info)
        elif isinstance(sensitivity, str):
            if sensitivity == "rms":
                if values is None:
                    raise ValueError("sensitivity='rms' needs the actual "
                                     "leaf arrays, not a (name, n) list")
                s = [float(jnp.sqrt(jnp.mean(jnp.square(v.astype(
                    jnp.float32))))) for v in values]
            elif sensitivity == "absmax":
                # the scale StochasticQuant actually quantizes against —
                # its per-coordinate error is ~ max|x|^2 * 4^-b, so this
                # is the model-matched weighting for quantizer plans.
                if values is None:
                    raise ValueError("sensitivity='absmax' needs the "
                                     "actual leaf arrays")
                s = [float(jnp.max(jnp.abs(v.astype(jnp.float32))))
                     for v in values]
            elif sensitivity == "grad_norm":
                if grads is None:
                    raise ValueError("sensitivity='grad_norm' needs grads=")
                gl = jax.tree.leaves(grads)
                s = [float(jnp.linalg.norm(g.astype(jnp.float32).ravel())
                           / math.sqrt(max(g.size, 1))) for g in gl]
            else:
                raise ValueError(f"unknown sensitivity {sensitivity!r} "
                                 "(rms | absmax | grad_norm | sequence "
                                 "| None)")
        else:
            s = [float(v) for v in sensitivity]
        if len(s) != len(info):
            raise ValueError(f"sensitivity has {len(s)} entries for "
                             f"{len(info)} leaves")
        max_bits = min(max_bits, 16)
        floor_cost = sum(n for _, n in info) * min_bits
        mk_wrap = {"shift": Shifted, "ef": ErrorFeedback,
                   None: lambda c: c, "none": lambda c: c}[wrap]
        if budget_bits_per_round < floor_cost:
            # below the all-min_bits floor: trade coordinates, not width —
            # one shared k_frac scales the whole message into budget.
            k = max(budget_bits_per_round / float(floor_cost), 1.0 / 64.0)
            rules = tuple(
                (nm, mk_wrap(Chain((RandK(k), StochasticQuant(min_bits)))))
                for nm, _ in info)
            return CompressionPlan(rules=rules, leaves=tuple(info))
        import heapq

        bits = [min_bits] * len(info)
        spend = budget_bits_per_round - floor_cost
        heap = [(-(s[i] ** 2 * 4.0 ** -bits[i]), i)
                for i in range(len(info)) if s[i] > 0.0]
        heapq.heapify(heap)
        while heap:
            _, i = heapq.heappop(heap)
            n_i = info[i][1]
            if bits[i] >= max_bits or n_i > spend:
                continue  # this leaf is done; cheaper leaves may still fit
            bits[i] += 1
            spend -= n_i
            heapq.heappush(heap, (-(s[i] ** 2 * 4.0 ** -bits[i]), i))
        rules = tuple((nm, mk_wrap(StochasticQuant(bits[i])))
                      for i, (nm, _) in enumerate(info))
        return CompressionPlan(rules=rules, leaves=tuple(info))

    def tightened(self, *, bits_step: int = 1, k_scale: float = 0.5,
                  min_bits: int = 2, min_k: float = 1.0 / 64.0
                  ) -> "CompressionPlan":
        """One adaptive-schedule step: every quantizer drops ``bits_step``
        bits (floor ``min_bits``) and every sparsifier scales its
        ``k_frac`` by ``k_scale`` (floor ``min_k``) — spend less wire as
        residuals shrink. Wrapper structure (and therefore the carried
        memory's shape) is preserved, so the tightened plan swaps into a
        live run without touching ``EngineState``."""
        def t(c):
            if c is None:
                return None
            if isinstance(c, (ErrorFeedback, Shifted)):
                return dataclasses.replace(c, inner=t(c.inner))
            if isinstance(c, Chain):
                return Chain(tuple(t(stg) for stg in c.stages))
            if isinstance(c, StochasticQuant):
                return dataclasses.replace(
                    c, bits=max(min_bits, c.bits - bits_step))
            if isinstance(c, (TopK, RandK)):
                return dataclasses.replace(
                    c, k_frac=max(min_k, c.k_frac * k_scale))
            return c

        return dataclasses.replace(
            self, rules=tuple((p, t(c)) for p, c in self.rules),
            default=t(self.default))


@dataclasses.dataclass
class AdaptivePlan:
    """Telemetry-driven plan schedule: call ``update(compress_err)`` with
    the per-round compression residual; each time the residual has shrunk
    by ``factor`` since the last tightening, the plan tightens one step
    (``CompressionPlan.tightened``) and the NEW plan is returned (else
    None). The caller re-attaches it via ``with_compression`` and rebuilds
    its round runner — extras shapes are preserved, so the live
    ``EngineState`` carries over unchanged."""

    plan: CompressionPlan
    factor: float = 10.0
    min_bits: int = 2
    ref_err: float | None = None

    def update(self, compress_err: float) -> CompressionPlan | None:
        err = float(compress_err)
        if not math.isfinite(err) or err <= 0.0:
            return None
        if self.ref_err is None:
            self.ref_err = err
            return None
        if err * self.factor <= self.ref_err:
            self.plan = self.plan.tightened(min_bits=self.min_bits)
            self.ref_err = err
            return self.plan
        return None


def parse_plan(spec, *, error_feedback: bool | None = None
               ) -> CompressionPlan | None:
    """Parse the launch-config plan grammar: comma-separated
    ``pattern:compressor-spec`` rules, first-match-wins, e.g.
    ``"embed*:q12,ln*:bf16,*:shift:q6"``. The pattern is everything before
    the FIRST colon (a glob over slash-joined leaf paths, or an all-digit
    leaf index); the rest is a full ``from_spec`` compressor spec
    (``shift:``/``ef:`` prefixes and ``+`` chains included).
    ``pattern:none`` pins matched leaves to dense passthrough. Each rule's
    compressor goes through the same :func:`auto_wrap` error-feedback
    policy as the uniform path, which is what keeps an all-one-spec plan
    bitwise-equal to uniform ``with_compression``."""
    if spec is None or isinstance(spec, CompressionPlan):
        return spec
    if not isinstance(spec, str):
        raise TypeError(f"not a compression plan: {spec!r}")
    s = spec.strip()
    if s.lower() in ("", "none", "off"):
        return None
    rules = []
    for part in s.split(","):
        part = part.strip()
        if not part:
            continue
        pat, sep, cspec = part.partition(":")
        pat = pat.strip()
        if not sep or not pat or not cspec.strip():
            raise ValueError(
                f"bad plan rule {part!r} (want 'pattern:spec', e.g. "
                "'embed*:q12' or '*:shift:q8'); full grammar: "
                "'embed*:q12,ln*:bf16,*:shift:q6'")
        rules.append((pat, auto_wrap(from_spec(cspec.strip()),
                                     error_feedback)))
    return CompressionPlan(rules=tuple(rules))


# ------------------------------------------------------------------ parsing
def _parse_stage(tok: str) -> Compressor:
    name, _, arg = tok.partition(":")
    name = name.strip().lower()
    if name == "topk":
        return TopK(float(arg), per_client=True)
    if name == "topk_global":
        return TopK(float(arg), per_client=False)
    if name == "randk":
        return RandK(float(arg))
    if name in ("quant", "q"):
        return StochasticQuant(bits=int(arg))
    if name.startswith("q") and name[1:].isdigit():
        return StochasticQuant(bits=int(name[1:]))
    if name.startswith("pq") and name[2:].isdigit():  # per-client dither
        return StochasticQuant(bits=int(name[2:]), per_client_dither=True)
    if name == "nat":
        return NaturalQuant()
    if name == "bf16":
        return Bf16()
    raise ValueError(f"unknown compressor spec {tok!r} (try topk:0.3, "
                     "topk_global:0.3, randk:0.25, q8, pq8, nat, bf16, "
                     "ef:..., a+b)")


def from_spec(spec: str | Compressor | None) -> Compressor | None:
    """Parse a launch-config compression spec into a Compressor (or None).

    Grammar: ``none`` | stage (``+`` stage)* with an optional ``ef:`` or
    ``shift:`` prefix (error feedback / DIANA shift around the whole chain).
    Stages: ``topk:<frac>`` (per-client), ``topk_global:<frac>`` (legacy
    cross-client), ``randk:<frac>``, ``q<bits>``/``quant:<bits>``,
    ``pq<bits>`` (per-client — non-synchronized — dither), ``bf16``.
    Examples: ``"randk:0.25"``, ``"ef:topk:0.3+bf16"``, ``"shift:q8"``."""
    if spec is None or isinstance(spec, Compressor):
        return spec
    s = spec.strip().lower()
    if s in ("", "none", "off"):
        return None
    wrap = None
    if s.startswith("ef:"):
        wrap, s = ErrorFeedback, s[3:]
    elif s.startswith("shift:"):
        wrap, s = Shifted, s[6:]
    stages = tuple(_parse_stage(tok) for tok in s.split("+") if tok.strip())
    if not stages:
        raise ValueError(f"empty compressor spec {spec!r} (a bare ef:/shift: "
                         "prefix would wrap a no-op in model-size memory)")
    comp: Compressor = stages[0] if len(stages) == 1 else Chain(stages)
    return wrap(comp) if wrap else comp


def auto_wrap(comp: Compressor | None,
              error_feedback: bool | None = None) -> Compressor | None:
    """The default error-feedback policy, shared by the engine's
    ``with_compression`` and hierarchical tier recompression
    (repro/core/topology.py): wrap BIASED STATELESS compressors in
    :class:`ErrorFeedback` (EF around an unbiased compressor reintroduces
    a feedback limit cycle; stateful wrappers already own their extra
    slot), leave everything else bare. Pass ``error_feedback=True/False``
    to force either way; ``None`` passes through."""
    if comp is None:
        return None
    ef = ((not comp.unbiased and not comp.stateful)
          if error_feedback is None else error_feedback)
    if ef and not isinstance(comp, ErrorFeedback):
        comp = ErrorFeedback(comp)  # raises if comp is stateful
    return comp


def as_compressor(obj: Any) -> Compressor:
    """Coerce a Compressor or spec string; reject None/unknown types."""
    comp = from_spec(obj)
    if not isinstance(comp, Compressor):
        raise TypeError(f"not a compressor: {obj!r}")
    return comp
