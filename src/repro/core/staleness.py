"""Staleness: asynchronous (delayed-uplink) federated rounds.

The paper's round model is fully synchronous — every client's message
arrives in the round it was computed. Real federations have stragglers and
delayed uplinks. This module simulates them INSIDE the jitted round loop on
the engine's message/aggregate seam (the same seam ``with_compression`` /
``with_participation`` ride): clients always compute their round, but a
per-client *delay model* decides on which rounds each client's uplink
actually lands at the server. The server keeps a **last-known message
buffer** per client (:class:`DelayState`: the most recent successfully
transmitted — post-compression — wire message, plus its integer age in
rounds), and a pluggable *stale-aggregation policy* decides how buffered
messages enter the server mean.

Delay models (``parse_delay`` grammar — the ``FedScenario(delay=...)`` /
``--delay`` knob):

* ``fixed:k`` — periodic uplink: EVERY client's message lands only on
  rounds ``r % (k+1) == 0``, so between arrivals the server's copy ages
  ``1..k``. ``fixed:0`` is the synchronous engine (exact no-op: the
  factory returns the algorithm object unchanged).
* ``rr:k`` — deterministic round-robin straggler: at round ``r`` the ``k``
  clients ``{r, .., r+k-1} mod N`` miss the round; each client goes stale
  for ``k`` consecutive rounds per cycle of ``N`` (max age ``k``).
  ``rr:0`` is an exact no-op.
* ``geom:p`` — each client's uplink lands independently with probability
  ``p`` per round (inter-arrival times geometric, mean ``1/p``; expected
  age ``(1-p)/p``). Drawn from the step counter via a domain-separated
  PRNG stream (same restart-stable schedule discipline as the
  participation-mask and compression keys). ``geom:1`` is an exact no-op.

Stale-aggregation policies (``parse_policy``):

* ``drop`` — aggregate FRESH arrivals only (present-clients mean, exactly
  the participation-mask machinery); clients whose message did not land
  take the *local continuation* instead of the aggregation update — the
  tau-th step applied as a pure local step (``local_step`` on the comm
  batch), so they keep training and their transform/drift state freezes.
  On rounds where NOTHING lands (``fixed:k`` between arrivals) the server
  skips the aggregation entirely and every client continues locally.
* ``last`` — the server averages the full buffer (fresh messages where
  they landed, last-known copies elsewhere) uniformly; every client
  applies the update using the server's copy of its OWN message (the
  buffered one — clients keep what they last transmitted). Uniform
  weights keep FedCET's redistributive invariant ``sum_i d_i = 0`` exact
  under staleness: the drift updates sum over the buffer deviations from
  the buffer mean.
* ``poly:a`` — staleness-discounted weights ``w_i = (1+age_i)^(-a)``
  (normalized) over the buffer; ``poly:0`` degenerates to ``last``. The
  weighted mean intentionally breaks the unweighted mean-zero structure —
  whether FedCET's invariant survives is a *measured* question
  (benchmarks/staleness_sweep.py).

All policies are weighted buffer means (:func:`weighted_client_mean`), so
when every client is fresh every round they all reduce to the plain
cross-client mean and the attached machinery is a bit-identical no-op on
the algorithm state (pinned in tests/test_staleness.py).

The buffer is SERVER state: it updates (and ages) every round regardless
of client participation, is checkpointed with the run inside
``EngineState`` extras, and is seeded at ``init`` with each client's
would-be first message so early stale rounds never average zeros.
Composition with the other transforms is defined once in the engine
(repro/core/engine.py ``_comm_step``): compression runs first (the buffer
holds wire messages; stale clients' error-feedback / shift memory reverts
— they did not transmit), participation masks freshness (an absent client
cannot deliver) while its buffer keeps aging.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "DelayState",
    "FixedDelay",
    "GeometricDelay",
    "RoundRobinStraggler",
    "StalePolicy",
    "StalenessConfig",
    "parse_delay",
    "parse_policy",
    "weighted_client_mean",
]

#: domain-separation tag folded into geometric-delay keys so the freshness
#: stream never collides with the participation-mask (bare seed) or
#: compression (0x7A11A5 + index) schedules at the shared default seed=0.
_DELAY_KEY_TAG = 0x57A1E


class DelayState(NamedTuple):
    """The server-side message buffer riding in ``EngineState`` extras.

    ``buf`` mirrors the (post-transform) message pytree — stacked
    ``[clients, ...]`` leaves holding each client's last successfully
    transmitted wire message; ``age`` is ``[clients] int32``, the number of
    rounds since that client's last arrival (0 = landed this round)."""

    buf: Any
    age: jax.Array


def weighted_client_mean(tree, w: jax.Array):
    """Weighted mean over the leading clients axis with weights ``w``
    (normalized here; an all-zero ``w`` yields zeros — callers only hit
    that when no client applies the result). Reduces to the plain client
    mean for any uniform positive ``w``. The zero-sum guard must not
    clamp small positive sums (``poly:a`` weights can sum below 1 for
    very stale buffers — clamping would silently shrink the mean)."""
    s = jnp.sum(w)
    denom = jnp.where(s > 0, s, 1.0)

    def mean_leaf(a):
        wb = w.reshape((-1,) + (1,) * (a.ndim - 1)).astype(a.dtype)
        return jnp.sum(a * wb, axis=0, keepdims=True) / denom.astype(a.dtype)

    return jax.tree.map(mean_leaf, tree)


# ------------------------------------------------------------- delay models
@dataclasses.dataclass(frozen=True)
class FixedDelay:
    """Periodic uplink: all clients land every ``k+1`` rounds (age cycles
    ``0..k``). ``k=0`` = synchronous."""

    k: int

    requires_key = False

    @property
    def identity(self) -> bool:
        return self.k <= 0

    @property
    def max_age(self) -> int:
        return max(self.k, 0)

    def fresh(self, key, round_index: jax.Array, n_clients: int) -> jax.Array:
        del key
        hit = (round_index % (self.k + 1)) == 0
        return jnp.broadcast_to(hit, (n_clients,))

    def transmit_frac(self, n_clients: int) -> float:
        del n_clients
        return 1.0 / (self.k + 1)


@dataclasses.dataclass(frozen=True)
class RoundRobinStraggler:
    """Deterministic rotating stragglers: at round ``r`` the ``k`` clients
    ``(r + j) mod N`` (``j < k``) miss the round. Each client is stale for
    ``k`` consecutive rounds per ``N``-round cycle (max age ``k``)."""

    k: int

    requires_key = False

    @property
    def identity(self) -> bool:
        return self.k <= 0

    @property
    def max_age(self) -> int:
        return max(self.k, 0)

    def fresh(self, key, round_index: jax.Array, n_clients: int) -> jax.Array:
        del key
        idx = jnp.arange(n_clients)
        return ((idx - round_index) % n_clients) >= self.k

    def transmit_frac(self, n_clients: int) -> float:
        return max(n_clients - self.k, 0) / n_clients


@dataclasses.dataclass(frozen=True)
class GeometricDelay:
    """Independent per-client Bernoulli(``p``) arrival per round —
    geometric inter-arrival times with mean ``1/p``, expected staleness
    ``(1-p)/p``. ``p=1`` = synchronous."""

    p: float

    requires_key = True

    def __post_init__(self):
        assert 0.0 < self.p <= 1.0, self.p

    @property
    def identity(self) -> bool:
        return self.p >= 1.0

    def fresh(self, key, round_index: jax.Array, n_clients: int) -> jax.Array:
        del round_index  # already folded into the key by StalenessConfig
        return jax.random.bernoulli(key, self.p, (n_clients,))

    def transmit_frac(self, n_clients: int) -> float:
        del n_clients
        return self.p


# ----------------------------------------------------------------- policies
@dataclasses.dataclass(frozen=True)
class StalePolicy:
    """Stale-robust aggregation over the server buffer.

    ``kind`` selects the weight rule over (age, fresh); ``apply_stale``
    says whether clients with no fresh arrival still apply the aggregation
    update (using their buffered own message) or take the local
    continuation instead (``drop``)."""

    kind: str            # "drop" | "last" | "poly"
    a: float = 0.0       # poly discount exponent

    @property
    def apply_stale(self) -> bool:
        return self.kind != "drop"

    def weights(self, age: jax.Array, fresh: jax.Array) -> jax.Array:
        # canonical float width (f64 under x64): f32 weights would leave a
        # ~1e-8 non-cancellation in the weighted mean even when all ages
        # are equal, flooring otherwise-exact f64 convergence runs.
        ft = jax.dtypes.canonicalize_dtype(jnp.float64)
        if self.kind == "drop":
            return fresh.astype(ft)
        if self.kind == "last":
            return jnp.ones_like(age, dtype=ft)
        if self.kind == "poly":
            return (1.0 + age.astype(ft)) ** (-self.a)
        raise ValueError(f"unknown stale policy kind {self.kind!r}")


def parse_policy(spec: "str | StalePolicy") -> StalePolicy:
    """``drop`` | ``last`` | ``poly:<a>`` (``poly:0`` == ``last`` weights)."""
    if isinstance(spec, StalePolicy):
        return spec
    s = spec.strip().lower()
    name, _, arg = s.partition(":")
    if name == "drop":
        return StalePolicy("drop")
    if name == "last":
        return StalePolicy("last")
    if name == "poly":
        return StalePolicy("poly", a=float(arg) if arg else 1.0)
    raise ValueError(f"unknown stale policy {spec!r} (try drop, last, poly:1)")


def parse_delay(spec):
    """Parse a delay-model spec; returns ``None`` for synchronous specs
    (``none``/``off``/``fixed:0``/``rr:0``/``geom:1``), so ``with_delay``
    can be an exact no-op at the identity settings, like the other
    transform factories."""
    if spec is None:
        return None
    if isinstance(spec, (FixedDelay, RoundRobinStraggler, GeometricDelay)):
        return None if spec.identity else spec
    s = str(spec).strip().lower()
    if s in ("", "none", "off", "sync"):
        return None
    name, _, arg = s.partition(":")
    if name == "fixed":
        model = FixedDelay(int(arg))
    elif name == "rr":
        model = RoundRobinStraggler(int(arg))
    elif name == "geom":
        model = GeometricDelay(float(arg))
    else:
        raise ValueError(
            f"unknown delay spec {spec!r} (try fixed:2, rr:1, geom:0.5)")
    return None if model.identity else model


# ------------------------------------------------------------ configuration
@dataclasses.dataclass(frozen=True)
class StalenessConfig:
    """The engine-level staleness knob (``RoundEngine.delay``): a delay
    model + a stale-aggregation policy + the PRNG seed for stochastic
    schedules. Frozen/hashable so it is jit-static like the rest of the
    algorithm spec."""

    model: Any
    policy: StalePolicy = StalePolicy("last")
    seed: int = 0

    def fresh_mask(self, step, tau: int, n_clients: int) -> jax.Array:
        """[n_clients] bool arrival mask for the round entered at step
        counter ``step`` (the engine advances ``t`` by exactly ``tau`` per
        round, so ``step // tau`` is the round index). Stochastic models
        key off the raw step through a domain-separated stream —
        deterministic under restart, never shared with the participation
        or compression schedules."""
        r = jnp.asarray(step, jnp.int32) // tau
        key = None
        if getattr(self.model, "requires_key", False):
            key = jax.random.fold_in(jax.random.key(self.seed), _DELAY_KEY_TAG)
            key = jax.random.fold_in(key, jnp.asarray(step, jnp.int32))
        return self.model.fresh(key, r, n_clients)

    def transmit_frac(self, n_clients: int) -> float:
        """Expected fraction of rounds on which a client's uplink lands —
        the duty cycle CommMeter folds into uplink bytes (buffered rounds
        transmit ZERO uplink bits)."""
        return float(self.model.transmit_frac(n_clients))
