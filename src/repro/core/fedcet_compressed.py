"""FedCET-C — beyond-paper: compressed-uplink FedCET with error feedback.

The paper reduces per-round traffic to ONE n-vector each way (Remark 2).
This extension compresses that single uplink vector further — bf16
quantization and/or magnitude top-k sparsification — with client-side
error-feedback memory so the compression error is re-injected the next
round rather than lost:

    e_i      <- e_i + v_i            (accumulate into feedback memory)
    v_i^c    = C(e_i)                 (compressed transmitted message)
    e_i      <- e_i - v_i^c           (remainder carried forward)
    v_bar    = mean_i v_i^c           (server aggregate, broadcast)
    d_i'     = d_i + c (v_i^c - v_bar)
    x_i'     = v_i - c*a*(v_i^c - v_bar)

Note the drift update uses the client's own COMPRESSED message so that
d_i' - d_i remains mean-zero across clients (sum_i (v_i^c - v_bar) = 0),
preserving the fixed-point structure of Lemma 2. The x-update applies the
correction to the client's exact local vector v_i.

Since the unified round engine this is no longer a separate algorithm:
:func:`FedCETCompressed` is sugar for composing the generic
``with_compression`` message transform (repro/core/engine.py) onto the
plain FedCET spec — the recursion above falls out of FedCET's
``server_aggregate`` receiving the transformed message as ``msg`` and the
exact local vector as ``mctx``. The same transform composes onto any other
engine algorithm, and stacks with ``with_participation``.

The paper has no compression variant (FedLin compresses a gradient in a
2-vector scheme); this is recorded as a beyond-paper result in
EXPERIMENTS.md §Perf: with top-30% + error feedback, uplink bytes drop to
~0.6 of FedCET's (~0.3 of SCAFFOLD's) while exact convergence is preserved
empirically (tests/test_fedcet_compressed.py).
"""

from __future__ import annotations

from repro.core.engine import ErrorFeedbackCompression, RoundEngine, with_compression
from repro.core.fedcet import FedCET

__all__ = ["ErrorFeedbackCompression", "FedCETCompressed"]


def FedCETCompressed(alpha: float, c: float, tau: int, n_clients: int,
                     k_frac: float = 1.0, quantize: bool = False,
                     error_feedback: bool | None = None,
                     compressor=None, seed: int = 0,
                     name: str = "fedcet_c", **engine_kw) -> RoundEngine:
    """Compressed-uplink FedCET: ``with_compression`` over the FedCET spec.

    ``k_frac=1.0, quantize=False`` (and no ``compressor``) is an exact
    no-op — the returned algorithm IS plain FedCET (bit-identical
    iterates). ``compressor=`` takes any first-class compressor object or
    spec string (``"randk:0.25"``, ``"ef:topk:0.3+bf16"``, ``"q8"``) from
    :mod:`repro.core.compressors`; ``error_feedback=None`` auto-wraps
    biased compressors only (the legacy ``k_frac``/``quantize`` path always
    defaults to feedback on, exactly as before)."""
    base = FedCET(alpha=alpha, c=c, tau=tau, n_clients=n_clients, name=name,
                  **engine_kw)
    return with_compression(base, k_frac=k_frac, quantize=quantize,
                            error_feedback=error_feedback,
                            compressor=compressor, seed=seed)
