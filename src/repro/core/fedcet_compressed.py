"""FedCET-C — beyond-paper: compressed-uplink FedCET with error feedback.

The paper reduces per-round traffic to ONE n-vector each way (Remark 2).
This extension compresses that single uplink vector further — bf16
quantization and/or magnitude top-k sparsification — with client-side
error-feedback memory so the compression error is re-injected the next
round rather than lost:

    e_i      <- e_i + v_i            (accumulate into feedback memory)
    v_i^c    = C(e_i)                 (compressed transmitted message)
    e_i      <- e_i - v_i^c           (remainder carried forward)
    v_bar    = mean_i v_i^c           (server aggregate, broadcast)
    d_i'     = d_i + c (v_i^c - v_bar)
    x_i'     = v_i - c*a*(v_i^c - v_bar)

Note the drift update uses the client's own COMPRESSED message so that
d_i' - d_i remains mean-zero across clients (sum_i (v_i^c - v_bar) = 0),
preserving the fixed-point structure of Lemma 2. The x-update applies the
correction to the client's exact local vector v_i.

The paper has no compression variant (FedLin compresses a gradient in a
2-vector scheme); this is recorded as a beyond-paper result in
EXPERIMENTS.md §Perf: with top-30% + error feedback, uplink bytes drop to
~0.6 of FedCET's (~0.3 of SCAFFOLD's) while exact convergence is preserved
empirically (tests/test_fedcet_compressed.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import GradFn, replicate, vmap_grads
from repro.core.comm import quantize_bf16, sparsified_up_frac, topk_sparsify
from repro.utils.tree import tree_client_mean, tree_zeros_like


class FedCETCState(NamedTuple):
    x: Any
    d: Any
    e: Any  # error-feedback memory (same shape as x)
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class FedCETCompressed:
    alpha: float
    c: float
    tau: int
    n_clients: int
    k_frac: float = 1.0          # top-k fraction (1.0 = dense)
    quantize: bool = False       # bf16 the transmitted vector
    name: str = "fedcet_c"
    vectors_up: int = 1
    vectors_down: int = 1
    spmd_client_axes: tuple = ()

    @property
    def up_frac(self) -> float:
        """Effective uplink fraction vs a dense f32 vector."""
        frac = sparsified_up_frac(self.k_frac)
        if self.quantize:
            frac *= 0.5
        return min(frac, 1.0 if not self.quantize else 0.5) if self.k_frac < 1.0 \
            else (0.5 if self.quantize else 1.0)

    def _compress(self, a: jax.Array) -> jax.Array:
        out = a
        if self.k_frac < 1.0:
            out = topk_sparsify(out, self.k_frac)
        if self.quantize:
            out = quantize_bf16(out)
        return out

    def init(self, grad_fn: GradFn, x0, init_batch) -> FedCETCState:
        gf = vmap_grads(grad_fn, spmd_axis_name=(self.spmd_client_axes or None))
        x_m2 = replicate(x0, self.n_clients)
        g_m2 = gf(x_m2, init_batch)
        x_m1 = jax.tree.map(lambda x, g: x - self.alpha * g, x_m2, g_m2)
        state = FedCETCState(x=x_m1, d=tree_zeros_like(x_m1),
                             e=tree_zeros_like(x_m1), t=jnp.asarray(-1))
        return self._comm_step(gf, state, init_batch)

    def _v(self, x, g, d):
        a = self.alpha
        return jax.tree.map(lambda xx, gg, dd: xx - a * gg - a * dd, x, g, d)

    def _local_step(self, gf, state: FedCETCState, batch) -> FedCETCState:
        g = gf(state.x, batch)
        v = self._v(state.x, g, state.d)
        return FedCETCState(x=v, d=state.d, e=state.e, t=state.t + 1)

    def _comm_step(self, gf, state: FedCETCState, batch) -> FedCETCState:
        g = gf(state.x, batch)
        v = self._v(state.x, g, state.d)
        # error-feedback compression of the single transmitted vector
        e_plus_v = jax.tree.map(jnp.add, state.e, v)
        v_tx = jax.tree.map(self._compress, e_plus_v)
        e_new = jax.tree.map(jnp.subtract, e_plus_v, v_tx)
        v_bar = tree_client_mean(v_tx)
        ca = self.c * self.alpha
        d_next = jax.tree.map(lambda dd, vt, vb: dd + self.c * (vt - vb),
                              state.d, v_tx, v_bar)
        x_next = jax.tree.map(lambda vv, vt, vb: vv - ca * (vt - vb),
                              v, v_tx, v_bar)
        return FedCETCState(x=x_next, d=d_next, e=e_new, t=state.t + 1)

    def round(self, grad_fn: GradFn, state: FedCETCState, batches) -> FedCETCState:
        gf = vmap_grads(grad_fn, spmd_axis_name=(self.spmd_client_axes or None))
        if self.tau > 1:
            local_b = jax.tree.map(lambda b: b[: self.tau - 1], batches)

            def body(s, b):
                return self._local_step(gf, s, b), None

            state, _ = jax.lax.scan(body, state, local_b)
        last_b = jax.tree.map(lambda b: b[self.tau - 1], batches)
        return self._comm_step(gf, state, last_b)

    def global_params(self, state: FedCETCState):
        return tree_client_mean(state.x, keepdims=False)
