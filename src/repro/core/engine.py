"""The unified federated round engine.

Every algorithm in this repo shares the paper's round structure (Remark 2):
``tau - 1`` pure-local steps, then exactly ONE aggregating step in which each
client transmits a message, the server reduces it, and clients apply the
result. Before this module existed that structure was hand-rolled seven times
(FedCET, FedCETLiteral, FedCETPartial, FedCETCompressed, FedAvg, SCAFFOLD,
FedLin); now :class:`RoundEngine` owns it once and each algorithm is a slim
*spec* — a frozen dataclass subclass declaring five hooks:

* ``init_warmup(gf, x0, init_batch) -> (state, run_init_comm_step)`` —
  build the pre-round state from replicated initial parameters (FedCET's
  warm-up block additionally requests one aggregating step);
* ``begin_round(gf, state, first_batch, agg) -> (state, rctx)`` — optional
  round-start exchange (FedLin's gradient uplink); ``rctx`` is closed over
  by the local scan and the aggregating step;
* ``local_step(gf, state, batch, rctx) -> state`` — one pure-local step;
* ``message(gf, state, batch, rctx) -> (msg, mctx)`` — the transmitted
  pytree at the aggregating step (FedCET: the single vector ``v``;
  SCAFFOLD: the ``{dy, dc}`` pair). ``mctx`` carries client-local values the
  aggregation needs but the network never sees (FedCET's exact ``v``);
* ``server_aggregate(state, msg, msg_bar, mctx, rctx) -> state`` — apply
  the reduced message. ``msg`` is the client's own message AFTER transforms
  (see below), ``msg_bar`` the aggregate over (participating) clients.

The engine owns everything else: the ``vmap_grads`` lift with
``spmd_client_axes``, batch slicing (leaves ``[tau, clients, ...]``), the
``lax.scan`` over the tau-1 local steps (the aggregation stays OUTSIDE the
scan so the cross-pod all-reduce appears exactly once per round in the HLO),
message transforms, and client sampling.

Message transforms & composition
--------------------------------
:func:`with_compression` and :func:`with_participation` wrap ANY engine
algorithm without forking its round body, and compose in either order::

    algo = with_compression(with_participation(FedCET(...), 0.5), k_frac=0.3)
    algo = with_compression(algo2, compressor="randk:0.25")  # unbiased

* ``with_compression`` inserts a :class:`repro.core.compressors.Compressor`
  stack into the message path (the legacy ``k_frac=``/``quantize=`` kwargs
  are sugar for the seed's cross-client top-k + bf16 chain under error
  feedback: ``e += msg; tx = C(e); e -= tx``). Transform state such as the
  per-client feedback memory rides along in an :class:`EngineState` wrapper;
  stochastic compressors draw a fresh PRNG key per round from the state's
  step counter (via :class:`MessageCompression`). Crucially the spec's
  ``server_aggregate`` receives the client's own COMPRESSED message as
  ``msg`` — FedCET's drift update ``d += c (msg - msg_bar)`` therefore stays
  mean-zero across clients (``sum_i (tx_i - mean tx) = 0``), preserving the
  Lemma 2 fixed-point structure; the exact local vector needed for the
  x-update travels in ``mctx``.
* ``with_participation`` draws a Bernoulli client mask per round
  (deterministic from the state's step counter, which the engine advances by
  exactly ``tau`` per round), replaces the aggregation mean with a
  present-clients-only mean, and freezes absent clients — every state leaf
  with a leading ``n_clients`` axis reverts to its pre-round value, so
  absent clients neither compute nor transmit, and redistributive invariants
  (``sum_i d_i = 0``) survive sampling.
* ``with_delay`` simulates ASYNCHRONOUS rounds (delayed uplinks) on the
  same seam: a per-client delay model decides which uplinks land each
  round, the server keeps a last-known message buffer
  (:class:`repro.core.staleness.DelayState`, riding in ``EngineState``
  extras like transform memory), and a stale-aggregation policy
  (``drop`` / ``last`` / ``poly:a``) folds buffered messages into the
  server mean. Delay applies AFTER compression (the buffer holds wire
  messages) and composes with participation (absent clients cannot
  deliver; their buffer entry keeps aging). See staleness.py.
* ``with_topology`` replaces the flat all-to-one reduction itself:
  hierarchical edge-aggregator trees (per-hop comm accounting, root
  ingress of ``g`` messages instead of ``n_clients``) or doubly-stochastic
  gossip mixing (per-client neighborhood means — no server at all; the
  NIDS lineage FedCET descends from). Every reduction is a WEIGHTED one,
  fed the same weight vector the star engine uses (uniform / the
  participation mask / the stale policy's weights), so topology composes
  with all three transforms above with no algorithm-side code. Stateful
  topologies (per-round resampled graphs) ride a
  :class:`repro.core.topology.TopoState` in ``EngineState`` extras, just
  before the delay buffer. See topology.py.
* ``with_cohort`` makes per-round WORK O(cohort) instead of O(N): the full
  per-client state (FedCET's ``d_i``, SCAFFOLD's ``c_i``, error-feedback /
  shift memory, the delay buffer) stays server-side as the sharded
  client-state store, and each round the engine gathers the sampled
  cohort's rows into a fixed-shape ``[cohort, ...]`` batch, runs
  ``begin_round`` / the local scan / ``message`` on the cohort only, and
  scatters the updated rows back — all inside the jitted round step
  (static shapes, checkpoint/resume-stable; the cohort index is derived
  from the step counter through a domain-separated PRNG stream). See
  `Cohort execution` below.

Cohort execution
----------------
:class:`CohortSpec` splits the round into two phases. Phase A is the
per-client compute (``begin_round``, the tau-1 local scan, ``message``) —
row-wise vmapped work whose per-row values are independent of the batch
size, so running it on the gathered ``[cohort, ...]`` rows (the default
``lowering="gather"``) or on the full ``[N, ...]`` store and gathering the
results afterwards (``lowering="dense"``, the O(N) reference the
equivalence tests pin against) yields identical cohort rows. Phase B is
everything cross-client — message transforms, the delay buffer update, the
weighted reduction, ``server_aggregate``, the participation freeze — and
ALWAYS runs on cohort-sized arrays in BOTH lowerings, so the two lowerings
agree bitwise and cross-client compressors (shared-scale quantizers,
cross-client top-k) are simply defined OVER THE COHORT. Composition:
``with_participation`` becomes a Bernoulli mask over the cohort slots
(absent members freeze, exactly the dense discipline), ``with_delay``
buffers index by GLOBAL client id (non-sampled clients' buffered messages
keep aging; ``fresh_mask`` is evaluated at global ids so rr/fixed
schedules are client-stable), hierarchical topologies reduce the cohort
through :meth:`~repro.core.topology.Topology.reduce_cohort` (first-tier
segment ids gathered at the cohort's global ids, so every edge aggregator
still sees exactly its own members), and CommMeter bills uplink AND
present-only downlink at the ``cohort/N`` duty cycle. Gossip mixing has no
server to sample a cohort — ``with_cohort`` rejects it — and FedLin's
spec-internal cross-client top-k (``k_frac < 1``) is rejected via
``cohort_compatible``. The store scatter is ``x.at[idx].set(rows)`` on
every ``[N, ...]`` leaf: donate the round carry
(``make_round_runner(..., donate=True)``, the launch default) so XLA
updates the store in place instead of copying O(N) state per round —
benchmarks/cohort_scaling.py pins round time ~flat in N at fixed cohort.

All five factories are EXACT no-ops at their identity settings
(``rate >= 1.0``; ``k_frac >= 1.0 and not quantize``; delay ``fixed:0`` /
``rr:0`` / ``geom:1`` / ``none``; topology ``star``; cohort ``none`` /
``0`` / ``size >= n_clients``): they return the algorithm object
unchanged.

The shared multi-round driver
-----------------------------
:func:`run_rounds` / :func:`make_round_runner` scan ``algo.round`` over K
rounds with an optional per-round metric hook. ``simulate_quadratic``,
``FedTrainer.fit`` and ``launch.train.run_training`` all consume it — one
lowered while-loop whether the payload is the paper's 60-dim quadratic or a
sharded multi-B-parameter LM.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import telemetry as tele
from repro.core.api import GradFn, vmap_grads
from repro.core.comm import sparsified_up_frac
from repro.core.staleness import (
    DelayState,
    StalenessConfig,
    parse_delay,
    parse_policy,
    weighted_client_mean,
)
from repro.core.topology import TopoState, parse_topology
from repro.utils.tree import tree_client_mean


class EngineState(NamedTuple):
    """Algorithm state plus per-transform extra state (e.g. error-feedback
    memory), plus — when a STATEFUL topology is attached — its
    :class:`repro.core.topology.TopoState` (the mixing round index), plus
    — when ``with_delay`` is attached — the server's last-known message
    buffer as the FINAL extras slot
    (:class:`repro.core.staleness.DelayState`). Only used when at least one
    transform, a stateful topology or a delay model is attached; bare
    algorithms keep their bare spec state, so existing checkpoints and
    sharding specs are unaffected."""

    inner: Any
    extras: tuple


# --------------------------------------------------------------------- masks
def participation_mask(key, n_clients: int, rate: float) -> jax.Array:
    """Bernoulli(rate) participation mask, guaranteed non-empty: if no client
    draws in, one uniformly random client is forced in. The Bernoulli draw
    and the fallback index use independent subkeys."""
    k_draw, k_fallback = jax.random.split(key)
    m = jax.random.bernoulli(k_draw, rate, (n_clients,))
    first = jax.nn.one_hot(jax.random.randint(k_fallback, (), 0, n_clients),
                           n_clients, dtype=bool)
    return jnp.where(jnp.any(m), m, first)


def masked_client_mean(tree, mask: jax.Array, *, keepdims: bool = True):
    """Mean over the leading clients axis restricted to ``mask``-selected
    clients (the server average under partial participation)."""
    denom = jnp.maximum(jnp.sum(mask.astype(jnp.int32)), 1)

    def mean_leaf(a):
        mb = mask.reshape((-1,) + (1,) * (a.ndim - 1)).astype(a.dtype)
        return jnp.sum(a * mb, axis=0, keepdims=keepdims) / denom.astype(a.dtype)

    return jax.tree.map(mean_leaf, tree)


def select_clients(new, old, mask: jax.Array, n_clients: int):
    """Per-client select between two same-structure pytrees: leaves with a
    leading ``n_clients`` axis take ``new`` where the mask is set and ``old``
    elsewhere; all other leaves (global scalars like the step counter) take
    ``new`` unconditionally."""

    def sel(n, o):
        if getattr(n, "ndim", 0) >= 1 and n.shape[0] == n_clients:
            mb = mask.reshape((-1,) + (1,) * (n.ndim - 1))
            return jnp.where(mb, n, o)
        return n

    return jax.tree.map(sel, new, old)


# --------------------------------------------------------------------- cohort
#: domain-separation tag folded into cohort-selection keys so the cohort
#: stream never collides with the participation (bare seed), compression
#: (0x7A11A5 + index), delay (0x57A1E) or topology (0x70_70 / 0x71_E5)
#: schedules at the default seed=0.
_COHORT_KEY_TAG = 0xC0_807


def gather_clients(tree, idx: jax.Array, n_clients: int):
    """Gather the ``idx`` rows of every per-client leaf (leading
    ``n_clients`` axis) of the client-state store; leaves without the
    client axis (global scalars like the step counter, ``[1, ...]``
    broadcast means) pass through unchanged."""

    def g(a):
        if getattr(a, "ndim", 0) >= 1 and a.shape[0] == n_clients:
            return a[idx]
        return a

    return jax.tree.map(g, tree)


def scatter_clients(store, rows, idx: jax.Array, n_clients: int):
    """Scatter updated cohort ``rows`` back into the client-state store:
    per-client store leaves take ``store.at[idx].set(row)``; all other
    leaves (global scalars) take the cohort's value unconditionally —
    the mirror of :func:`select_clients`'s convention."""

    def s(o, r):
        if getattr(o, "ndim", 0) >= 1 and o.shape[0] == n_clients:
            return o.at[idx].set(r)
        return r

    return jax.tree.map(s, store, rows)


@dataclasses.dataclass(frozen=True)
class CohortSpec:
    """Per-round cohort selection for O(cohort) round execution.

    ``selector`` picks which ``size`` global client ids train each round
    (all derived from the round-entry step counter, so the schedule is
    deterministic and checkpoint/resume-stable):

    * ``"uniform"`` — a uniformly random size-subset without replacement
      (``jax.random.permutation`` — O(N log N) selection work per round,
      O(cohort) everything else);
    * ``"block"`` — a contiguous block at a random offset (O(cohort)
      selection — the default for the scaling benchmark);
    * ``"rr"`` — round-robin blocks ``[r*size, (r+1)*size) mod N``
      (deterministic, key-free — every client trains once per N/size
      rounds).

    ``lowering`` selects the execution strategy: ``"gather"`` (gather the
    cohort rows, run phase A on ``[size, ...]`` — the O(cohort) path) or
    ``"dense"`` (run phase A on the full ``[N, ...]`` store and gather the
    results — the O(N) reference both benchmarks and equivalence tests
    compare against; phase B is cohort-sized either way, so the two agree
    bitwise)."""

    size: int
    selector: str = "uniform"
    seed: int = 0
    lowering: str = "gather"

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"cohort size must be >= 1: {self.size}")
        if self.selector not in ("uniform", "block", "rr"):
            raise ValueError(f"unknown cohort selector {self.selector!r} "
                             "(uniform | block | rr)")
        if self.lowering not in ("gather", "dense"):
            raise ValueError(f"unknown cohort lowering {self.lowering!r} "
                             "(gather | dense)")

    def indices(self, step, tau: int, n_clients: int) -> jax.Array:
        """The round's sorted-free ``[size] int32`` global client ids,
        keyed by the round-entry step counter ``step`` (advanced by
        exactly ``tau`` per round — restart-stable)."""
        m = self.size
        if self.selector == "rr":
            r = jnp.asarray(step, jnp.int32) // tau
            return (r * m + jnp.arange(m, dtype=jnp.int32)) % n_clients
        key = jax.random.fold_in(jax.random.key(self.seed), _COHORT_KEY_TAG)
        key = jax.random.fold_in(key, jnp.asarray(step, jnp.int32))
        if self.selector == "block":
            off = jax.random.randint(key, (), 0, n_clients, dtype=jnp.int32)
            return (off + jnp.arange(m, dtype=jnp.int32)) % n_clients
        return jax.random.permutation(key, n_clients)[:m].astype(jnp.int32)


def parse_cohort(spec):
    """Parse a cohort spec; returns ``None`` for identity specs (``None`` /
    ``"none"`` / ``"off"`` / ``"full"`` / ``0``) so ``with_cohort`` can be
    an exact no-op, like every other transform factory.

    Grammar: an int, ``"256"``, ``"uniform:256"``, ``"block:256"``,
    ``"rr:256"``, with an optional trailing ``":dense"`` / ``":gather"``
    lowering selector (``"block:256:dense"``)."""
    if spec is None or isinstance(spec, CohortSpec):
        return spec
    if isinstance(spec, int):
        return CohortSpec(size=spec) if spec > 0 else None
    s = str(spec).strip().lower()
    if s in ("", "none", "off", "full", "0"):
        return None
    parts = s.split(":")
    lowering = "gather"
    if parts[-1] in ("gather", "dense"):
        lowering = parts.pop()
    if len(parts) == 1:
        selector, size = "uniform", parts[0]
    elif len(parts) == 2:
        selector, size = parts
    else:
        raise ValueError(f"bad cohort spec {spec!r} "
                         "(try 256, block:256, rr:256, block:256:dense)")
    try:
        size_i = int(size)
    except ValueError:
        raise ValueError(f"bad cohort size in spec {spec!r}: {size!r}")
    if size_i <= 0:
        return None
    return CohortSpec(size=size_i, selector=selector, lowering=lowering)


# ---------------------------------------------------------------- transforms
#: domain-separation tag folded into compression keys so they never collide
#: with the participation-mask key schedule (both default to seed=0).
_COMPRESS_KEY_TAG = 0x7A11A5


@dataclasses.dataclass(frozen=True)
class MessageCompression:
    """Message transform adapting a :class:`repro.core.compressors.Compressor`
    (possibly ``ErrorFeedback``-wrapped) into the engine's message path.

    Owns the per-round PRNG schedule for stochastic compressors: the key is
    ``fold_in(fold_in(key(seed), TAG), step)`` where ``step`` is the state's
    step counter at round entry (advanced by exactly ``tau`` per round, -1
    at the warm-up aggregation) — a fresh key every round, deterministic
    under restart, never shared with the participation mask schedule.
    Randomness is synchronized across clients (see compressors.py: this is
    what makes unbiased compressors preserve the FedCET fixed point and
    lets RandK skip index traffic)."""

    compressor: Any
    seed: int = 0
    #: position in the algorithm's transform stack, folded into the key so
    #: two stacked stochastic transforms at the same (default) seed never
    #: replay each other's randomness (which would de-unbias them).
    index: int = 0

    @property
    def up_frac(self) -> float:
        return self.compressor.up_frac

    @property
    def bits_per_coord(self) -> float:
        return self.compressor.bits_per_coord

    @property
    def keep_frac(self) -> float:
        return self.compressor.keep_frac

    @property
    def index_bits(self) -> float:
        return self.compressor.index_bits

    @property
    def value_bits(self) -> float | None:
        return self.compressor.value_bits

    @property
    def unbiased(self) -> bool:
        return getattr(self.compressor, "unbiased", False)

    def init_extra(self, msg_shapes):
        return self.compressor.init_extra(msg_shapes)

    def apply(self, msg, extra, step):
        key = None
        if self.compressor.requires_key:
            key = jax.random.fold_in(
                jax.random.key(self.seed), _COMPRESS_KEY_TAG + self.index)
            key = jax.random.fold_in(key, jnp.asarray(step, jnp.int32))
        return self.compressor.apply(key, msg, extra)


@dataclasses.dataclass(frozen=True)
class ErrorFeedbackCompression:
    """Legacy message transform (the seed's scheme, kept as construction
    sugar with its exact semantics): cross-client top-k sparsification
    and/or bf16 quantization with optional client-side error feedback.

    Since the compressor subsystem this is a thin shim over
    ``ErrorFeedback(Chain((TopK(k_frac, per_client=False), Bf16())))`` —
    the compress path is bit-identical to the seed (seed-equivalence tests
    pin it to <= 1e-12). ``up_frac`` keeps the seed's APPROXIMATE accounting
    ("bf16 halves whatever remains") for backward compatibility;
    ``bits_per_coord`` reports the bit-true cost (bf16 halves VALUES only —
    top-k index traffic stays int32), which is what ``CommMeter`` now
    meters. New code should pass ``with_compression(..., compressor=...)``
    objects instead."""

    k_frac: float = 1.0
    quantize: bool = False
    error_feedback: bool = True

    @property
    def up_frac(self) -> float:
        """Effective uplink fraction vs a dense f32 payload (top-k transmits
        values + int32 indices; bf16 halves whatever remains)."""
        frac = sparsified_up_frac(self.k_frac)
        if self.quantize:
            frac = min(0.5 * frac, 0.5)
        return min(frac, 1.0)

    def _compressor(self):
        from repro.core.compressors import (
            Bf16, Chain, ErrorFeedback, Identity, TopK)

        stages = []
        if self.k_frac < 1.0:
            stages.append(TopK(self.k_frac, per_client=False))
        if self.quantize:
            stages.append(Bf16())
        comp = (stages[0] if len(stages) == 1
                else Chain(tuple(stages)) if stages else Identity())
        return ErrorFeedback(comp) if self.error_feedback else comp

    @property
    def bits_per_coord(self) -> float:
        return self._compressor().bits_per_coord

    @property
    def keep_frac(self) -> float:
        return self._compressor().keep_frac

    @property
    def index_bits(self) -> float:
        return self._compressor().index_bits

    @property
    def value_bits(self) -> float | None:
        return self._compressor().value_bits

    def init_extra(self, msg_shapes):
        """Feedback memory, shaped like the message (from ``eval_shape``)."""
        return self._compressor().init_extra(msg_shapes)

    def apply(self, msg, extra, step):
        del step  # deterministic stack
        return self._compressor().apply(None, msg, extra)


@dataclasses.dataclass(frozen=True)
class ClientSampling:
    """Per-round Bernoulli client participation policy."""

    rate: float
    seed: int = 0


# --------------------------------------------------------------------- engine
@dataclasses.dataclass(frozen=True)
class RoundEngine:
    """Shared round driver; algorithms subclass this and implement the spec
    hooks (``init_warmup``, ``local_step``, ``message``,
    ``server_aggregate``, optionally ``begin_round`` / ``client_params``).

    Subclasses must declare ``name``, ``tau``, ``n_clients``, ``vectors_up``
    and ``vectors_down`` fields (the FederatedAlgorithm protocol), and their
    state must be a pytree whose per-client leaves carry a leading
    ``n_clients`` axis plus a scalar step counter ``t`` that the engine-run
    round advances by exactly ``tau``."""

    transforms: tuple = dataclasses.field(default=(), kw_only=True)
    sampling: ClientSampling | None = dataclasses.field(default=None, kw_only=True)
    #: asynchronous-round simulation (delay model + buffer + stale policy);
    #: attach via ``with_delay`` — see repro/core/staleness.py.
    delay: StalenessConfig | None = dataclasses.field(default=None, kw_only=True)
    #: aggregation geometry (hierarchical tiers / gossip mixing); attach via
    #: ``with_topology`` — see repro/core/topology.py. None = the flat star.
    topology: Any | None = dataclasses.field(default=None, kw_only=True)
    #: O(cohort) round execution (gather/scatter on the sharded client-state
    #: store); attach via ``with_cohort``. None = every client trains.
    cohort: CohortSpec | None = dataclasses.field(default=None, kw_only=True)
    #: pack the model pytree into the contiguous [rows, 1024] parameter
    #: arena (core/arena.py): state/message leaves become single packed
    #: buffers, unpacked only at the model-apply (gradient) boundary;
    #: attach via ``with_arena``. The whole engine seam is
    #: representation-transparent (Arena is a pytree node), so every
    #: transform/axis above composes unchanged.
    arena: bool = dataclasses.field(default=False, kw_only=True)
    #: in-trace telemetry spec (core/telemetry.py): when attached, the
    #: round captures per-round scalars (gradient/message norms,
    #: compression error, participation, staleness ages; the runner adds
    #: the invariant residual and consensus error from the post-round
    #: state) onto the runner's tape with no host sync. None (the
    #: default) is a BITWISE no-op: every capture site is guarded on this
    #: field, so the disabled round traces the identical jaxpr.
    telemetry: Any | None = dataclasses.field(default=None, kw_only=True)
    #: mesh axes carrying the client dimension (production launcher only).
    spmd_client_axes: tuple = dataclasses.field(default=(), kw_only=True)

    # ------------------------------------------------------------ spec hooks
    def init_warmup(self, gf, x0, init_batch):
        raise NotImplementedError

    def begin_round(self, gf, state, first_batch, agg):
        """Optional round-start exchange; returns (state, round context)."""
        del gf, first_batch, agg
        return state, None

    def local_step(self, gf, state, batch, rctx):
        raise NotImplementedError

    def message(self, gf, state, batch, rctx):
        raise NotImplementedError

    def server_aggregate(self, state, msg, msg_bar, mctx, rctx):
        raise NotImplementedError

    def _fused_tail(self, inner, msg, mctx, extras, step, mask):
        """Optional whole-round-tail fusion hook, consulted by
        ``_comm_step`` on plain synchronous arena rounds (no topology, no
        delay). A spec that can execute transform -> reduce ->
        ``server_aggregate`` as one fused pass over its packed message
        returns ``(new_inner, new_extras)``; ``None`` falls through to
        the generic seam. FedCET implements it for the shift-quantized
        uplink via the kernels/ops.py ``fedcet_round_tail`` kernel."""
        del inner, msg, mctx, extras, step, mask
        return None

    def client_params(self, state):
        """Stacked [clients, ...] model parameters (default: ``state.x``),
        unpacked from the parameter arena when the state carries one."""
        x = self._inner(state).x
        from repro.core.arena import Arena, unpack

        return unpack(x) if isinstance(x, Arena) else x

    def global_params(self, state):
        p = tree_client_mean(self.client_params(state), keepdims=False)
        from repro.core.arena import Arena, unpack

        return unpack(p) if isinstance(p, Arena) else p

    # ------------------------------------------------------------ accounting
    @property
    def up_frac(self) -> float:
        """Effective uplink bytes fraction after message transforms."""
        frac = 1.0
        for t in self.transforms:
            frac *= getattr(t, "up_frac", 1.0)
        return frac

    def _transforms_bits(self, bits: float = 32.0) -> float:
        """Fold the attached transforms' bit-true cost onto a dense width.

        Stacked transforms compose like Chain stages — via their
        (keep_frac, index_bits, value_bits) triple, NOT by multiplying
        total fractions (that would wrongly scale a sparsifier's int32
        index bits by a later quantizer's value fraction: top-k 30% then
        q8 is 0.3*(8+32)=12 bits/coord, not 32*0.6*0.25)."""
        keep, idx, value = 1.0, 0.0, bits
        for t in self.transforms:
            kf = getattr(t, "keep_frac", None)
            if kf is None:  # unknown transform: coarse fractional fallback
                per = getattr(t, "bits_per_coord", None)
                per = 32.0 * getattr(t, "up_frac", 1.0) if per is None else per
                value *= per / 32.0
                continue
            keep *= kf
            idx += keep * t.index_bits
            vb = t.value_bits
            if vb is not None:
                # first-narrowest-wins, mirroring Chain.value_bits: a later
                # wider stage cannot put information back on the wire.
                value = min(value, vb)
        return keep * value + idx

    @property
    def bits_per_coord(self) -> float:
        """Bit-true average wire bits per model coordinate per UP vector,
        derived from the attached compressor stack (32.0 when dense).
        Specs with internal compression (FedLin's round-start top-k)
        override this alongside ``up_frac``."""
        return self._transforms_bits(32.0)

    def message_leaf_bits(self, leaf_info):
        """EXACT per-leaf uplink wire bits for one client's one UP vector,
        given the message leaf decomposition ``[(name, n_coords), ...]``
        (see repro/core/comm.py:leaf_info_of) — the actual-kept-count,
        per-leaf-plan-aware refinement of ``n * bits_per_coord``.

        Returns ``None`` when per-leaf accounting does not apply: a spec
        that overrides ``bits_per_coord`` bills internal compression the
        engine cannot decompose (FedLin), and an unknown transform without
        a compressor has no stage algebra to walk. Never inspects the
        arena: the decomposition comes from the unpacked params either
        way, which is what makes arena and per-leaf lowerings bill
        identically (pinned in benchmarks/comm_table.py)."""
        if type(self).bits_per_coord is not RoundEngine.bits_per_coord:
            return None
        stack = []
        for t in self.transforms:
            comp = getattr(t, "compressor", None)
            if comp is None and hasattr(t, "_compressor"):
                comp = t._compressor()
            if comp is None:
                return None
            stack.append(comp)
        from repro.core.compressors import stack_wire_bits

        return [stack_wire_bits(stack, i, nm, int(n))
                for i, (nm, n) in enumerate(leaf_info)]

    @property
    def down_frac(self) -> float:
        return 1.0

    @property
    def transmit_frac(self) -> float:
        """Expected fraction of rounds a client's uplink actually lands
        (1.0 synchronous). Buffered rounds transmit zero uplink bits —
        CommMeter folds this duty cycle into bytes_up. With client
        sampling attached the effective arrival mask is ``fresh AND
        present`` (an absent client cannot deliver), and the two schedules
        are independent PRNG streams, so the expectations multiply.
        (The participation factor ignores the non-empty-mask fallback's
        tiny upward correction at very low rates.) With a cohort attached
        only its ``size/N`` slice of clients computes at all — non-sampled
        clients transmit ZERO uplink bits, so the duty cycle multiplies
        by the cohort fraction."""
        frac = self._cohort_frac
        if self.sampling is not None:
            frac *= min(self.sampling.rate, 1.0)
        if self.delay is not None:
            frac *= self.delay.transmit_frac(self.n_clients)
        return frac

    @property
    def receive_frac(self) -> float:
        """Expected fraction of rounds a client RECEIVES the downlink
        broadcast (1.0 synchronous). Under client sampling the server
        broadcasts to PRESENT clients only — absent clients keep their
        frozen replica instead of receiving a phantom broadcast, so
        CommMeter bills downlink bytes at the participation rate. Delay
        models do not reduce downlink: stale-but-present clients still
        apply the (buffered-mean) update, which still has to reach them.
        A cohort is present-only downlink taken to its O(cohort)
        conclusion: only the sampled ``size/N`` slice receives anything,
        so the cohort fraction multiplies here too."""
        frac = self._cohort_frac
        if self.sampling is not None:
            frac *= min(self.sampling.rate, 1.0)
        return frac

    @property
    def _cohort_frac(self) -> float:
        return (self.cohort.size / self.n_clients
                if self.cohort is not None else 1.0)

    @property
    def cohort_compatible(self) -> bool:
        """Whether this spec's own math is cohort-safe: True unless the
        spec performs a CROSS-CLIENT computation outside the engine's
        phase-B seam (FedLin's internal cross-client top-k overrides
        this). Engine-level transforms need no flag — they always run on
        the gathered cohort rows."""
        return True

    # ------------------------------------------------------- state wrapping
    @property
    def _topo_stateful(self) -> bool:
        return self.topology is not None and self.topology.stateful

    @property
    def _wrapped(self) -> bool:
        return (bool(self.transforms) or self.delay is not None
                or self._topo_stateful)

    def _wrap(self, inner, extras, tstate=None, dstate=None):
        if not self._wrapped:
            return inner
        extras = tuple(extras)
        if self._topo_stateful:
            extras += (tstate,)
        if self.delay is not None:
            extras += (dstate,)
        return EngineState(inner, extras)

    def _split(self, state):
        """-> (inner, transform extras, TopoState | None, DelayState | None).

        Extras layout: per-transform slots first, then the stateful
        topology's TopoState (when attached), then the delay buffer as the
        FINAL slot (when attached)."""
        if not self._wrapped:
            return state, (), None, None
        extras, tstate, dstate = state.extras, None, None
        if self.delay is not None:
            extras, dstate = extras[:-1], extras[-1]
        if self._topo_stateful:
            extras, tstate = extras[:-1], extras[-1]
        return state.inner, extras, tstate, dstate

    def _inner(self, state):
        return state.inner if self._wrapped else state

    # ------------------------------------------------------------- plumbing
    def _grad(self, grad_fn: GradFn) -> GradFn:
        gf = vmap_grads(grad_fn, spmd_axis_name=(self.spmd_client_axes or None))
        if self.arena:
            from repro.core.arena import Arena, pack, unpack

            base = gf

            # the model-apply boundary: the loss sees the real pytree, the
            # engine sees the arena. The unpack is pure slicing — XLA fuses
            # it into the gradient consumers (measured: unpack+grads costs
            # ~the grads alone); the repack is the one real crossing per
            # call. (Returning RAW grads and folding the pack into the
            # spec's first consumer was tried and is SLOWER: outside the
            # grad closure the unpacked x/d slices materialize as copies
            # instead of fusing, so the per-leaf triad + concat streams the
            # model twice more than pack-then-fused-triad. Keep the pack
            # here.)
            def arena_gf(x, batch):
                if not isinstance(x, Arena):
                    return base(x, batch)
                return pack(base(unpack(x), batch), x.layout)

            gf = arena_gf
        if self.telemetry is None:
            return gf

        inner_gf = gf

        # the capture is a no-op outside the runner's tape and inside the
        # muted tau-1 local scan; an Arena gradient's zero pads make the
        # packed norm equal the per-leaf norm.
        def recording_gf(x, batch):
            g = inner_gf(x, batch)
            if tele.collecting():
                tele.capture("grad_norm", tele.mean_client_norm(g))
            return g

        return recording_gf

    def _msg_shapes(self, gf, inner, init_batch):
        """Abstract (eval_shape) wire-message tree of the current state —
        shapes transform extras and stateful-topology tier memory."""
        def msg_of(s, b):
            s2, rctx = self.begin_round(gf, s, b, tree_client_mean)
            return self.message(gf, s2, b, rctx)[0]

        return jax.eval_shape(msg_of, inner, init_batch)

    def _init_extras(self, msg_shapes) -> tuple:
        """Per-transform extra state, shaped from the (abstract) message."""
        return tuple(t.init_extra(msg_shapes) for t in self.transforms)

    def _comm_step(self, gf, inner, extras, batch, rctx, agg, step,
                   tstate=None, dstate=None, fresh=None, mask=None):
        """The single aggregating step: message -> transforms -> [staleness
        buffer] -> reduce -> apply. The only place a cross-client collective
        fires. ``step`` is the state's step counter at round entry —
        stochastic transforms derive their per-round PRNG key from it
        (never reused across rounds; stack multiple stochastic transforms
        with distinct seeds). With a topology attached, the reduction goes
        through ``reduce_and_advance`` — the one place topology state
        (resampled-graph index, tier-compression memory) moves — under
        the ``mask``-derived weights (uniform, or the participation
        mask; the delay path derives its own stale-policy weights
        instead).

        With ``dstate``/``fresh`` set (a ``with_delay`` round), the wire
        message lands in the server buffer only where ``fresh`` is true,
        the stale policy turns buffer + ages into the aggregation mean,
        and stale clients either apply the update with their BUFFERED own
        message (``last``/``poly`` — the copy both ends kept) or take the
        tau-th step as a pure local continuation (``drop``). Stale clients
        never transmitted, so their transform memory (error feedback /
        shift) reverts to its pre-round value. Returns
        ``(inner, extras, dstate, tx)`` — ``tx`` is the post-transform
        wire message (``init`` seeds the buffer from it)."""
        msg, mctx = self.message(gf, inner, batch, rctx)
        # observer-only telemetry: rec is False when telemetry is detached
        # (bitwise no-op) or no tape is active (init / direct round calls).
        rec = self.telemetry is not None and tele.collecting()
        if rec:
            tele.capture("msg_norm", tele.mean_client_norm(msg))
            if self.telemetry.leaf_stats:
                tele.capture("leaf_msg_norm", tele.leaf_client_norms(msg))
        if (dstate is None and self.delay is None and self.topology is None
                and self.arena):
            fused = self._fused_tail(inner, msg, mctx, extras, step, mask)
            if fused is not None:
                inner, new_extras = fused
                return inner, tuple(new_extras), tstate, None, None
        raw = msg
        new_extras = []
        for t, e in zip(self.transforms, extras):
            msg, e = t.apply(msg, e, step)
            new_extras.append(e)
        if rec and self.transforms:
            diff = jax.tree.map(lambda a, b: a - b, msg, raw)
            tele.capture("compress_err", tele.mean_client_norm(diff))
            if self.telemetry.wants_sketch("compress_err"):
                tele.capture("compress_err_clients",
                             jnp.sqrt(tele.client_sq_norms(diff)))
            if self.telemetry.leaf_stats:
                tele.capture("leaf_compress_err",
                             tele.leaf_client_norms(diff))

        if dstate is None:  # synchronous path (and always: init)
            if self.topology is not None:
                msg_bar, tstate_next = self.topology.reduce_and_advance(
                    msg, self._topo_weights(mask), tstate)
            else:
                msg_bar, tstate_next = agg(msg), None
            inner = self.server_aggregate(inner, msg, msg_bar, mctx, rctx)
            return inner, tuple(new_extras), tstate_next, None, msg

        # fresh arrivals replace the buffered copy and reset its age; the
        # buffer is server state — it updates and ages every round.
        buf = select_clients(msg, dstate.buf, fresh, self.n_clients)
        age = jnp.where(fresh, 0, dstate.age + 1).astype(dstate.age.dtype)
        if rec:
            tele.capture("fresh_count", jnp.sum(fresh.astype(jnp.int32)))
            tele.capture("age_min", jnp.min(age))
            tele.capture("age_mean", jnp.mean(age.astype(jnp.float32)))
            tele.capture("age_max", jnp.max(age))
        w = self.delay.policy.weights(age, fresh)
        # the stale policy's weights feed the TOPOLOGY's reduction (the
        # same weighted seam as the synchronous path), so hierarchical /
        # gossip aggregation composes with staleness with no extra code.
        if self.topology is not None:
            msg_bar, tstate_next = self.topology.reduce_and_advance(
                buf, w, tstate)
        else:
            msg_bar, tstate_next = weighted_client_mean(buf, w), None
        # each client's own-message slot is what the server attributed to
        # it: the fresh wire message where it landed, the buffer elsewhere.
        agg_inner = self.server_aggregate(inner, buf, msg_bar, mctx, rctx)
        if not self.delay.policy.apply_stale:
            # drop: no-arrival clients take the tau-th step as a pure local
            # step instead of the aggregation update (XLA CSEs the repeated
            # gradient evaluation at the same point).
            local = self.local_step(gf, inner, batch, rctx)
            agg_inner = select_clients(agg_inner, local, fresh, self.n_clients)
        new_extras = tuple(
            select_clients(ne, e, fresh, self.n_clients)
            for ne, e in zip(new_extras, extras))
        return (agg_inner, new_extras, tstate_next,
                DelayState(buf=buf, age=age), msg)

    def _would_transmit(self, gf, inner, extras, batch):
        """The wire message the current state WOULD transmit (begin_round
        context and transform-memory updates discarded) — seeds the delay
        buffer for specs whose warm-up runs no init aggregation."""
        st, rctx = self.begin_round(gf, inner, batch, tree_client_mean)
        msg, _ = self.message(gf, st, batch, rctx)
        for t, e in zip(self.transforms, extras):
            msg, _ = t.apply(msg, e, inner.t)
        return msg

    def _topo_weights(self, mask, n: int | None = None):
        """The per-client weight vector a topology reduces under on
        non-delayed rounds: uniform, or the participation mask. ``n``
        overrides the vector length (cohort rounds reduce over the
        cohort slots, not the full population)."""
        ft = jax.dtypes.canonicalize_dtype(jnp.float64)
        return (mask.astype(ft) if mask is not None
                else jnp.ones((n if n is not None else self.n_clients,), ft))

    def _aggregator(self, mask, tstate):
        """The round's READ-ONLY cross-client reduction (fed to
        ``begin_round`` — e.g. FedLin's gradient exchange): the attached
        topology's weighted reduce (uniform weights, or the participation
        mask as weights; topology state frozen — only the aggregating
        step advances it), else the star mean / masked mean the engine
        always used."""
        if self.topology is not None:
            w = self._topo_weights(mask)
            return lambda tr: self.topology.reduce(tr, w, tstate)
        if mask is not None:
            return lambda tr: masked_client_mean(tr, mask)
        return tree_client_mean

    def _cohort_aggregator(self, mask, idx, tstate):
        """The cohort round's READ-ONLY reduction over the gathered
        ``[cohort, ...]`` rows: the topology's cohort reduce (fed the
        cohort's GLOBAL ids so hierarchies route each member to its own
        edge aggregator) or the weighted cohort mean."""
        w = self._topo_weights(mask, self.cohort.size)
        if self.topology is not None:
            return lambda tr: self.topology.reduce_cohort(
                tr, w, idx, self.n_clients, tstate)
        return lambda tr: weighted_client_mean(tr, w)

    # -------------------------------------------------------------- protocol
    def init(self, grad_fn: GradFn, x0, init_batch):
        """Replicate-and-warm-up, plus one aggregating step if the spec's
        warm-up requests it. Client sampling and delay never apply at init
        (matching the full-participation synchronous initialization of the
        paper) but the TOPOLOGY does — it is the physical network, so a
        warm-up aggregation already flows through the tree / gossip graph.
        The delay buffer is seeded with each client's (would-be) init-time
        wire message, age 0 — so early stale rounds average real messages,
        never zeros."""
        gf = self._grad(grad_fn)
        if self.arena:
            from repro.core.arena import Arena, pack

            if not isinstance(x0, Arena):
                x0 = pack(x0)
            # from here on EVERY state/message tree the spec builds from
            # x0 (replicate, zeros_like, eval_shape, transform extras,
            # the delay buffer) is arena-valued by construction.
        inner, run_comm = self.init_warmup(gf, x0, init_batch)
        topo_shapes = (self.topology is not None
                       and self.topology.needs_msg_shapes)
        msg_shapes = (self._msg_shapes(gf, inner, init_batch)
                      if (self.transforms or topo_shapes) else None)
        extras = self._init_extras(msg_shapes)
        tstate = None
        if self.topology is not None:
            tstate = self.topology.init_state(msg_shapes if topo_shapes
                                              else None)
        tx = None
        if run_comm:
            inner, extras, tstate, _, tx = self._comm_step(
                gf, inner, extras, init_batch, rctx=None,
                agg=self._aggregator(None, tstate), step=inner.t,
                tstate=tstate)
        dstate = None
        if self.delay is not None:
            if tx is None:
                tx = self._would_transmit(gf, inner, extras, init_batch)
            dstate = DelayState(
                buf=tx, age=jnp.zeros((self.n_clients,), jnp.int32))
        return self._wrap(inner, extras, tstate, dstate)

    def round(self, grad_fn: GradFn, state, batches):
        """One communication round: optional round-start exchange, tau-1
        local steps under ``lax.scan``, one aggregating step.

        ``batches`` leaves have leading ``[tau, clients, ...]`` axes. The
        scan keeps the lowered HLO small for multi-B parameter models; the
        aggregation sits OUTSIDE the scan so the cross-pod all-reduce
        appears exactly once per round in the HLO.

        With a cohort attached the round dispatches to
        :meth:`_cohort_round` — same state layout, same hooks, O(cohort)
        work."""
        if self.cohort is not None:
            return self._cohort_round(grad_fn, state, batches)
        gf = self._grad(grad_fn)
        inner, extras, tstate, dstate = self._split(state)

        step0 = inner.t  # round-entry counter: keys masks AND compressors
        mask = None
        if self.sampling is not None:
            key = jax.random.fold_in(jax.random.key(self.sampling.seed),
                                     jnp.asarray(inner.t, jnp.int32))
            mask = participation_mask(key, self.n_clients, self.sampling.rate)
        agg = self._aggregator(mask, tstate)
        fresh = None
        if self.delay is not None:
            fresh = self.delay.fresh_mask(step0, self.tau, self.n_clients)
            if mask is not None:
                fresh = jnp.logical_and(fresh, mask)  # absent can't deliver
        if self.telemetry is not None and tele.collecting():
            tele.capture("participating",
                         jnp.sum(mask.astype(jnp.int32)) if mask is not None
                         else jnp.asarray(self.n_clients, jnp.int32))
        frozen_inner, frozen_extras = inner, extras

        first_b = jax.tree.map(lambda b: b[0], batches)
        inner, rctx = self.begin_round(gf, inner, first_b, agg)

        if self.tau > 1:
            local_b = jax.tree.map(lambda b: b[: self.tau - 1], batches)

            def body(s, b):
                return self.local_step(gf, s, b, rctx), None

            # muted: a capture inside the scan body would leak inner-scan
            # tracers onto the round-level telemetry tape.
            with tele.muted():
                inner, _ = jax.lax.scan(body, inner, local_b)

        last_b = jax.tree.map(lambda b: b[self.tau - 1], batches)
        inner, extras, tstate, dstate, _ = self._comm_step(
            gf, inner, extras, last_b, rctx, agg, step=step0,
            tstate=tstate, dstate=dstate, fresh=fresh, mask=mask)

        if mask is not None:
            # absent clients keep their pre-round state entirely; the delay
            # buffer and the topology round index are SERVER/NETWORK state
            # and are never reverted — an absent client's last-known
            # message simply keeps aging.
            inner = select_clients(inner, frozen_inner, mask, self.n_clients)
            extras = tuple(select_clients(e, fe, mask, self.n_clients)
                           for e, fe in zip(extras, frozen_extras))
        return self._wrap(inner, extras, tstate, dstate)

    def _cohort_round(self, grad_fn: GradFn, state, batches):
        """One O(cohort) communication round (see the module docstring's
        `Cohort execution`): select the cohort's global ids, gather their
        rows from the client-state store, run phase A (per-client compute)
        on the cohort, run phase B (all cross-client work) on cohort-sized
        arrays, scatter the updated rows back. Non-cohort clients are
        untouched except for server-side aging of their delay-buffer
        entries — exactly how the dense engine treats absent clients."""
        gf = self._grad(grad_fn)
        inner, extras, tstate, dstate = self._split(state)
        N, m, tau = self.n_clients, self.cohort.size, self.tau

        step0 = inner.t  # round-entry counter: keys cohort, masks, dither
        idx = self.cohort.indices(step0, tau, N)
        mask = None
        if self.sampling is not None:
            # Bernoulli participation WITHIN the cohort: a sampled-but-
            # absent member freezes, like any absent client in dense mode.
            key = jax.random.fold_in(jax.random.key(self.sampling.seed),
                                     jnp.asarray(step0, jnp.int32))
            mask = participation_mask(key, m, self.sampling.rate)
        fresh = None
        if self.delay is not None:
            # delay schedules key off GLOBAL client ids (an rr straggler
            # stays the same physical client whichever round samples it).
            fresh = self.delay.fresh_mask(step0, tau, N)[idx]
            if mask is not None:
                fresh = jnp.logical_and(fresh, mask)
        agg = self._cohort_aggregator(mask, idx, tstate)

        frozen_inner = gather_clients(inner, idx, N)  # pre-round rows
        extras_c = tuple(gather_clients(e, idx, N) for e in extras)

        # ---- phase A: per-client compute (begin_round -> scan -> message)
        if self.cohort.lowering == "dense":
            # O(N) reference lowering: every client computes, only the
            # cohort's rows feed phase B. Row-wise vmapped compute is
            # batch-size independent, so the gathered results match the
            # gather lowering bitwise.
            dense_agg = lambda tr: agg(gather_clients(tr, idx, N))  # noqa: E731
            first_b = jax.tree.map(lambda b: b[0], batches)
            st, rctx = self.begin_round(gf, inner, first_b, dense_agg)
            if tau > 1:
                local_b = jax.tree.map(lambda b: b[: tau - 1], batches)
                with tele.muted():
                    st, _ = jax.lax.scan(
                        lambda s, b: (self.local_step(gf, s, b, rctx), None),
                        st, local_b)
            last_b = jax.tree.map(lambda b: b[tau - 1], batches)
            msg, mctx = self.message(gf, st, last_b, rctx)
            inner_c = gather_clients(st, idx, N)
            msg_c = gather_clients(msg, idx, N)
            mctx_c = gather_clients(mctx, idx, N)
            rctx_c = gather_clients(rctx, idx, N)
            last_b_c = gather_clients(last_b, idx, N)
        else:
            inner_c = gather_clients(inner, idx, N)
            batches_c = jax.tree.map(
                lambda b: (b[:, idx] if getattr(b, "ndim", 0) >= 2
                           and b.shape[1] == N else b), batches)
            first_b = jax.tree.map(lambda b: b[0], batches_c)
            inner_c, rctx_c = self.begin_round(gf, inner_c, first_b, agg)
            if tau > 1:
                local_b = jax.tree.map(lambda b: b[: tau - 1], batches_c)
                with tele.muted():
                    inner_c, _ = jax.lax.scan(
                        lambda s, b: (self.local_step(gf, s, b, rctx_c),
                                      None),
                        inner_c, local_b)
            last_b_c = jax.tree.map(lambda b: b[tau - 1], batches_c)
            msg_c, mctx_c = self.message(gf, inner_c, last_b_c, rctx_c)

        # ---- phase B: transforms -> [buffer] -> reduce -> apply, all on
        # cohort-sized arrays in BOTH lowerings (shared code = bitwise
        # lowering equivalence; cross-client ops are per-cohort by design).
        rec = self.telemetry is not None and tele.collecting()
        if rec:
            tele.capture("msg_norm", tele.mean_client_norm(msg_c))
            tele.capture("participating",
                         jnp.sum(mask.astype(jnp.int32)) if mask is not None
                         else jnp.asarray(m, jnp.int32))
            if self.telemetry.leaf_stats:
                tele.capture("leaf_msg_norm", tele.leaf_client_norms(msg_c))
        tx_c = msg_c
        new_extras_c = []
        for t, e in zip(self.transforms, extras_c):
            tx_c, e = t.apply(tx_c, e, step0)
            new_extras_c.append(e)
        new_extras_c = tuple(new_extras_c)
        if rec and self.transforms:
            diff_c = jax.tree.map(lambda a, b: a - b, tx_c, msg_c)
            tele.capture("compress_err", tele.mean_client_norm(diff_c))
            if self.telemetry.wants_sketch("compress_err"):
                # cohort-sized wire data — finalize translates top-k slots
                # to GLOBAL client ids through the captured cohort index.
                tele.capture("compress_err_clients",
                             jnp.sqrt(tele.client_sq_norms(diff_c)))
                tele.capture("cohort_ids", idx.astype(jnp.int32))
            if self.telemetry.leaf_stats:
                tele.capture("leaf_compress_err",
                             tele.leaf_client_norms(diff_c))

        if dstate is None:
            if self.topology is not None:
                msg_bar, tstate = self.topology.reduce_cohort_and_advance(
                    tx_c, self._topo_weights(mask, m), idx, N, tstate)
            else:
                msg_bar = weighted_client_mean(
                    tx_c, self._topo_weights(mask, m))
            inner_c = self.server_aggregate(inner_c, tx_c, msg_bar,
                                            mctx_c, rctx_c)
            dstate_next = None
        else:
            buf_c = gather_clients(dstate.buf, idx, N)
            buf_c = select_clients(tx_c, buf_c, fresh, m)
            age_c = jnp.where(fresh, 0, dstate.age[idx] + 1
                              ).astype(dstate.age.dtype)
            w = self.delay.policy.weights(age_c, fresh)
            if self.topology is not None:
                msg_bar, tstate = self.topology.reduce_cohort_and_advance(
                    buf_c, w, idx, N, tstate)
            else:
                msg_bar = weighted_client_mean(buf_c, w)
            agg_inner_c = self.server_aggregate(inner_c, buf_c, msg_bar,
                                                mctx_c, rctx_c)
            if not self.delay.policy.apply_stale:
                local = self.local_step(gf, inner_c, last_b_c, rctx_c)
                agg_inner_c = select_clients(agg_inner_c, local, fresh, m)
            inner_c = agg_inner_c
            new_extras_c = tuple(select_clients(ne, e, fresh, m)
                                 for ne, e in zip(new_extras_c, extras_c))
            # the buffer is server state: every non-cohort entry keeps
            # aging (its owner could not deliver), cohort entries land.
            dstate_next = DelayState(
                buf=jax.tree.map(
                    lambda o, r: (o.at[idx].set(r)
                                  if getattr(o, "ndim", 0) >= 1
                                  and o.shape[0] == N else r),
                    dstate.buf, buf_c),
                age=(dstate.age + 1).astype(dstate.age.dtype
                                            ).at[idx].set(age_c))
            if rec:
                # cohort arrivals; ages summarize the FULL server buffer
                # (non-cohort entries keep aging — the system-wide view).
                tele.capture("fresh_count",
                             jnp.sum(fresh.astype(jnp.int32)))
                tele.capture("age_min", jnp.min(dstate_next.age))
                tele.capture("age_mean",
                             jnp.mean(dstate_next.age.astype(jnp.float32)))
                tele.capture("age_max", jnp.max(dstate_next.age))

        if mask is not None:
            # absent cohort members keep their pre-round rows entirely
            # (the dense engine's participation freeze, per-cohort).
            inner_c = select_clients(inner_c, frozen_inner, mask, m)
            new_extras_c = tuple(select_clients(e, fe, mask, m)
                                 for e, fe in zip(new_extras_c, extras_c))

        # ---- scatter the cohort rows back into the client-state store
        inner_next = scatter_clients(inner, inner_c, idx, N)
        extras_next = tuple(scatter_clients(e, ec, idx, N)
                            for e, ec in zip(extras, new_extras_c))
        return self._wrap(inner_next, extras_next, tstate, dstate_next)


# ------------------------------------------------------- transform factories
def with_participation(algo: RoundEngine, rate: float, seed: int = 0) -> RoundEngine:
    """Per-round Bernoulli client sampling for ANY engine algorithm.
    ``rate >= 1.0`` is an exact no-op (returns ``algo`` unchanged)."""
    if rate >= 1.0:
        return algo
    return dataclasses.replace(algo, sampling=ClientSampling(rate=rate, seed=seed))


def with_compression(algo: RoundEngine, *, k_frac: float = 1.0,
                     quantize: bool = False,
                     error_feedback: bool | None = None,
                     compressor=None, seed: int = 0) -> RoundEngine:
    """Compressed uplink for ANY engine algorithm's message path.

    Two entry forms:

    * ``compressor=`` — a :class:`repro.core.compressors.Compressor` object
      or spec string (``"randk:0.25"``, ``"topk:0.3+bf16"``, ``"q8"``, ...).
      ``error_feedback=None`` (the default) wraps BIASED compressors in
      :class:`~repro.core.compressors.ErrorFeedback` and leaves unbiased
      ones bare (EF around an unbiased compressor reintroduces a feedback
      limit cycle); pass True/False to force. ``seed`` keys the per-round
      randomness of stochastic compressors.
    * legacy ``k_frac=`` / ``quantize=`` — the seed's cross-client top-k +
      bf16 error-feedback scheme, bit-identical to the original
      (``error_feedback=None`` means True here). ``k_frac >= 1.0 and not
      quantize`` is an exact no-op (returns ``algo`` unchanged).

    Transforms stack: the last one attached compresses the output of the
    previous one."""
    if compressor is not None:
        if k_frac < 1.0 or quantize:
            raise ValueError(
                "pass EITHER compressor= or the legacy k_frac=/quantize= "
                "kwargs, not both (the legacy pair would be silently "
                f"ignored): compressor={compressor!r}, k_frac={k_frac}, "
                f"quantize={quantize}")
        from repro.core.compressors import (CompressionPlan, auto_wrap,
                                            from_spec)

        comp = from_spec(compressor)
        if comp is None:  # the "none" spec — exact no-op, like k_frac=1.0
            return algo
        # auto mode: EF around biased STATELESS compressors only — wrapping
        # a Shifted/ErrorFeedback would clobber its extra slot. Plans own
        # their per-RULE error-feedback policy (parse_plan applies the same
        # auto_wrap rule-wise), so the whole-tree wrap must not double up.
        if not isinstance(comp, CompressionPlan):
            comp = auto_wrap(comp, error_feedback)
        t = MessageCompression(comp, seed=seed, index=len(algo.transforms))
        return dataclasses.replace(algo, transforms=algo.transforms + (t,))
    if k_frac >= 1.0 and not quantize:
        return algo
    t = ErrorFeedbackCompression(
        k_frac=k_frac, quantize=quantize,
        error_feedback=True if error_feedback is None else error_feedback)
    return dataclasses.replace(algo, transforms=algo.transforms + (t,))


def with_delay(algo: RoundEngine, delay, *, policy="last",
               seed: int = 0) -> RoundEngine:
    """Asynchronous rounds for ANY engine algorithm: simulate delayed
    uplinks with a server-side last-known message buffer and a
    stale-aggregation policy (see repro/core/staleness.py).

    ``delay`` is a spec string (``"fixed:2"``, ``"rr:1"``, ``"geom:0.5"``)
    or a delay-model object; ``policy`` is ``"drop"`` / ``"last"`` /
    ``"poly:<a>"`` (or a :class:`~repro.core.staleness.StalePolicy`);
    ``seed`` keys stochastic schedules (domain-separated from the
    participation and compression streams). Identity delays (``"none"``,
    ``"fixed:0"``, ``"rr:0"``, ``"geom:1"``) are exact no-ops — the
    algorithm object is returned unchanged, for every policy.

    Delay applies at the aggregation seam AFTER any compression transforms
    (the buffer holds wire messages), so composition with
    ``with_compression`` / ``with_participation`` is order-independent."""
    model = parse_delay(delay)
    if model is None:
        return algo
    if algo.delay is not None:
        raise ValueError("algorithm already has a delay model attached "
                         f"({algo.delay!r}); stacked delays are undefined")
    cfg = StalenessConfig(model=model, policy=parse_policy(policy), seed=seed)
    return dataclasses.replace(algo, delay=cfg)


def with_topology(algo: RoundEngine, topology, *, seed: int = 0,
                  tier_compression=None) -> RoundEngine:
    """Non-star aggregation geometry for ANY engine algorithm: hierarchical
    (edge-aggregator tree) or gossip (doubly-stochastic mixing) reduction
    at the aggregation seam (see repro/core/topology.py).

    ``topology`` is a spec string (``"hier:g8"``, ``"hier:16x4"``,
    ``"ring"``, ``"torus"``, ``"er:0.4"``, ``"er:0.4:t"`` for a per-round
    resampled graph; gossip specs take a trailing ``":sparse"`` selecting
    the padded neighbor-exchange lowering) or a
    :class:`~repro.core.topology.Topology` object; ``seed`` keys
    stochastic graph draws and tier-compression dither (domain-separated
    from the participation / compression / delay streams).
    ``tier_compression`` (hierarchies only) re-compresses interior
    aggregator-tier uplinks with any compressor spec — see topology.py's
    `Tier recompression`. Star specs (``"star"`` / ``"none"`` / a
    :class:`~repro.core.topology.Star` object) are exact no-ops — the
    algorithm object is returned unchanged.

    The topology applies wherever the engine reduces across clients — the
    aggregating step, FedLin's round-start gradient exchange, and the
    warm-up aggregation at ``init`` — and receives the SAME per-client
    weight vector the star engine uses (uniform, the participation mask,
    or the stale policy's weights), so it composes with
    ``with_compression`` / ``with_participation`` / ``with_delay`` in any
    factory order."""
    topo = parse_topology(topology, algo.n_clients, seed=seed,
                          tier_compression=tier_compression)
    if topo is None:
        return algo
    if algo.topology is not None:
        raise ValueError("algorithm already has a topology attached "
                         f"({algo.topology!r}); stacked topologies are "
                         "undefined")
    if algo.cohort is not None and not topo.supports_cohort:
        raise ValueError(
            f"topology {topo!r} does not support cohort execution (gossip "
            "mixing has no server to sample a cohort — every node exchanges "
            "with its neighbors every round)")
    return dataclasses.replace(algo, topology=topo)


def with_cohort(algo: RoundEngine, cohort, *, seed: int = 0) -> RoundEngine:
    """O(cohort) round execution for ANY engine algorithm: keep the
    per-client state server-side and run each round on a gathered
    fixed-shape cohort only (see the module docstring's `Cohort
    execution`).

    ``cohort`` is a size (int), a spec string (``"256"``,
    ``"block:256"``, ``"rr:256"``, optional trailing ``":dense"`` for the
    O(N) reference lowering) or a :class:`CohortSpec`; ``seed`` keys the
    stochastic selectors (domain-separated from every other engine
    stream). Identity specs (``None`` / ``"none"`` / ``0`` / ``size >=
    n_clients`` — the whole population trains anyway) are exact no-ops:
    the algorithm object is returned unchanged.

    Composition: attach the cohort LAST (after compression /
    participation / delay / topology) — the factory validates the
    already-attached axes. Gossip mixing topologies and specs whose own
    math crosses clients outside the engine seam (``cohort_compatible``
    False — FedLin with ``k_frac < 1``) are rejected."""
    spec = cohort if isinstance(cohort, CohortSpec) else parse_cohort(cohort)
    if spec is not None and not isinstance(cohort, CohortSpec):
        spec = dataclasses.replace(spec, seed=seed)
    if spec is None or spec.size >= algo.n_clients:
        if spec is not None and spec.size > algo.n_clients:
            raise ValueError(f"cohort size {spec.size} exceeds "
                             f"n_clients={algo.n_clients}")
        return algo
    if algo.cohort is not None:
        raise ValueError("algorithm already has a cohort attached "
                         f"({algo.cohort!r}); stacked cohorts are undefined")
    if not algo.cohort_compatible:
        raise ValueError(
            f"{algo.name} is not cohort-compatible: its spec performs a "
            "cross-client computation outside the engine's aggregation "
            "seam (FedLin's internal cross-client top-k needs the full "
            "population — use k_frac=1.0 / FedTrack, or move compression "
            "to with_compression)")
    if algo.topology is not None and not algo.topology.supports_cohort:
        raise ValueError(
            f"topology {algo.topology!r} does not support cohort execution "
            "(gossip mixing has no server to sample a cohort)")
    return dataclasses.replace(algo, cohort=spec)


def with_arena(algo: RoundEngine, enable: bool = True) -> RoundEngine:
    """Packed-parameter-arena execution for ANY engine algorithm: ``init``
    flattens the model pytree once into the contiguous lane-aligned
    ``[rows, 1024]`` buffer of core/arena.py, and every state / message /
    transform-memory tree stays packed for the life of the run — the
    per-leaf tree.map seam becomes a handful of whole-model array ops,
    unpacked only at the gradient boundary. Composes with every other
    factory in any order (the Arena is a pytree node, so compression /
    participation / delay / topology / cohort code paths are untouched),
    and is pinned <= 1e-12-equivalent to the per-leaf representation
    (tests/test_arena.py). ``enable=False`` is an exact no-op. Checkpoints
    flip between representations via ``core.arena.adapt_state``."""
    if not enable:
        return algo
    return dataclasses.replace(algo, arena=True)


def with_telemetry(algo: RoundEngine, telemetry=True) -> RoundEngine:
    """In-trace round telemetry for ANY engine algorithm (see
    repro/core/telemetry.py): the round captures per-round scalar metrics
    (gradient/message norms, compression error, participation, staleness
    ages, the ``sum_i d_i`` invariant residual, the consensus error) onto
    the runner's scan — no host sync, no extra algorithm state
    (checkpoints unaffected).

    ``telemetry`` is ``True`` / a :class:`~repro.core.telemetry.Telemetry`
    spec / any truthy spec string; disabled specs (``None`` / ``False`` /
    ``"none"`` / ``"off"``) are exact no-ops — the algorithm object is
    returned unchanged, so telemetry OFF is bitwise identical to the
    un-instrumented engine (pinned in tests/test_telemetry.py)."""
    spec = tele.parse_telemetry(telemetry)
    if spec is None:
        return algo
    return dataclasses.replace(algo, telemetry=spec)


# --------------------------------------------------------- multi-round driver
def make_round_runner(algo, grad_fn: GradFn, *, metric_fn=None,
                      repeat: bool = False, metric_with_batch: bool = False,
                      donate: bool = False):
    """Build the jitted K-round scan over ``algo.round``.

    * ``repeat=False`` (default): the returned ``run(state, batches)`` scans
      over stacked per-round batches (leaves ``[rounds, tau, clients, ...]``).
    * ``repeat=True``: ``run(state, batches, rounds)`` replays the SAME
      per-round batch pytree (leaves ``[tau, clients, ...]``) for ``rounds``
      rounds — the full-batch simulation mode.

    ``metric_fn(state) -> pytree`` is evaluated after every round and stacked
    into the second return value; with ``metric_with_batch=True`` it is
    called as ``metric_fn(state, round_batches)`` instead (the per-round
    ``[tau, clients, ...]`` pytree) — this is how ``FedTrainer.fit`` keeps
    its eval-loss series on-device inside the scan. Keep ONE runner per
    training loop: jit caching is per function instance.

    ``donate=True`` donates the state argument (``donate_argnums=(0,)``)
    so the carry aliases in/out — for a cohort algorithm the scatter back
    into the ``[N, ...]`` client-state store then updates IN PLACE instead
    of copying O(N) state per call, which is what keeps round time
    O(cohort) and peak memory ~1x the store. The caller must rebind
    (``state = run(state, ...)``) and never touch the donated value again
    — callers that re-read the input state afterwards (e.g.
    ``simulate_quadratic``'s err(state0)) must keep the default.

    With telemetry attached (``with_telemetry``) each round's body runs
    under a :func:`repro.core.telemetry.collect` tape and the stacked ys
    become ``{"metric": ..., "telemetry": {...}}`` — split them with
    :func:`repro.core.telemetry.split_metrics`. Without telemetry the ys
    structure (and the traced jaxpr) is exactly the pre-telemetry one."""
    def _metric(s, b):
        if metric_fn is None:
            return None
        return metric_fn(s, b) if metric_with_batch else metric_fn(s)

    tel = getattr(algo, "telemetry", None)

    def _round(s, b):
        if tel is None:
            return algo.round(grad_fn, s, b), None
        with tele.collect() as tape:
            s = algo.round(grad_fn, s, b)
        return s, tel.finalize(tape, algo, s)

    def _ys(s, b, tl):
        m = _metric(s, b)
        return m if tel is None else {"metric": m, "telemetry": tl}

    donate_kw = {"donate_argnums": (0,)} if donate else {}
    if repeat:
        def run(state, batches, rounds):
            def body(s, _):
                s, tl = _round(s, batches)
                return s, _ys(s, batches, tl)

            return jax.lax.scan(body, state, None, length=rounds)

        return jax.jit(run, static_argnums=2, **donate_kw)

    def run(state, batches):
        def body(s, b):
            s, tl = _round(s, b)
            return s, _ys(s, b, tl)

        return jax.lax.scan(body, state, batches)

    return jax.jit(run, **donate_kw)


def scan_segments(start: int, total: int, is_boundary, *, max_rounds: int = 32):
    """Yield ``(first, last)`` round indices for jitted scan segments.

    Each segment ends at the next boundary round (inclusive — the round
    after which the caller wants to eval/checkpoint/log) or after
    ``max_rounds``, whichever comes first; the cap bounds the memory spent
    on stacked per-round batches. Shared by ``FedTrainer.fit`` and
    ``launch.train.run_training``."""
    r = start
    while r < total:
        cap = min(total - 1, r + max_rounds - 1)
        stop = next((s for s in range(r, cap) if is_boundary(s)), cap)
        yield r, stop
        r = stop + 1


def run_rounds(algo, grad_fn: GradFn, state, batches, *, rounds: int | None = None,
               metric_fn=None):
    """Run K communication rounds through one ``lax.scan`` (the shared
    driver behind ``simulate_quadratic`` and ``FedTrainer.fit``).

    With ``rounds=None``, ``batches`` leaves are ``[rounds, tau, clients,
    ...]`` stacks and the round count is their leading axis; with
    ``rounds=K``, ``batches`` is a single per-round pytree (leaves
    ``[tau, clients, ...]``) replayed every round. Returns
    ``(final_state, stacked_metrics)`` (metrics ``None`` without a hook)."""
    if rounds is not None:
        return make_round_runner(algo, grad_fn, metric_fn=metric_fn,
                                 repeat=True)(state, batches, rounds)
    return make_round_runner(algo, grad_fn, metric_fn=metric_fn)(state, batches)
