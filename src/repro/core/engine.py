"""The unified federated round engine.

Every algorithm in this repo shares the paper's round structure (Remark 2):
``tau - 1`` pure-local steps, then exactly ONE aggregating step in which each
client transmits a message, the server reduces it, and clients apply the
result. Before this module existed that structure was hand-rolled seven times
(FedCET, FedCETLiteral, FedCETPartial, FedCETCompressed, FedAvg, SCAFFOLD,
FedLin); now :class:`RoundEngine` owns it once and each algorithm is a slim
*spec* — a frozen dataclass subclass declaring five hooks:

* ``init_warmup(gf, x0, init_batch) -> (state, run_init_comm_step)`` —
  build the pre-round state from replicated initial parameters (FedCET's
  warm-up block additionally requests one aggregating step);
* ``begin_round(gf, state, first_batch, agg) -> (state, rctx)`` — optional
  round-start exchange (FedLin's gradient uplink); ``rctx`` is closed over
  by the local scan and the aggregating step;
* ``local_step(gf, state, batch, rctx) -> state`` — one pure-local step;
* ``message(gf, state, batch, rctx) -> (msg, mctx)`` — the transmitted
  pytree at the aggregating step (FedCET: the single vector ``v``;
  SCAFFOLD: the ``{dy, dc}`` pair). ``mctx`` carries client-local values the
  aggregation needs but the network never sees (FedCET's exact ``v``);
* ``server_aggregate(state, msg, msg_bar, mctx, rctx) -> state`` — apply
  the reduced message. ``msg`` is the client's own message AFTER transforms
  (see below), ``msg_bar`` the aggregate over (participating) clients.

The engine owns everything else: the ``vmap_grads`` lift with
``spmd_client_axes``, batch slicing (leaves ``[tau, clients, ...]``), the
``lax.scan`` over the tau-1 local steps (the aggregation stays OUTSIDE the
scan so the cross-pod all-reduce appears exactly once per round in the HLO),
message transforms, and client sampling.

Message transforms & composition
--------------------------------
:func:`with_compression` and :func:`with_participation` wrap ANY engine
algorithm without forking its round body, and compose in either order::

    algo = with_compression(with_participation(FedCET(...), 0.5), k_frac=0.3)
    algo = with_compression(algo2, compressor="randk:0.25")  # unbiased

* ``with_compression`` inserts a :class:`repro.core.compressors.Compressor`
  stack into the message path (the legacy ``k_frac=``/``quantize=`` kwargs
  are sugar for the seed's cross-client top-k + bf16 chain under error
  feedback: ``e += msg; tx = C(e); e -= tx``). Transform state such as the
  per-client feedback memory rides along in an :class:`EngineState` wrapper;
  stochastic compressors draw a fresh PRNG key per round from the state's
  step counter (via :class:`MessageCompression`). Crucially the spec's
  ``server_aggregate`` receives the client's own COMPRESSED message as
  ``msg`` — FedCET's drift update ``d += c (msg - msg_bar)`` therefore stays
  mean-zero across clients (``sum_i (tx_i - mean tx) = 0``), preserving the
  Lemma 2 fixed-point structure; the exact local vector needed for the
  x-update travels in ``mctx``.
* ``with_participation`` draws a Bernoulli client mask per round
  (deterministic from the state's step counter, which the engine advances by
  exactly ``tau`` per round), replaces the aggregation mean with a
  present-clients-only mean, and freezes absent clients — every state leaf
  with a leading ``n_clients`` axis reverts to its pre-round value, so
  absent clients neither compute nor transmit, and redistributive invariants
  (``sum_i d_i = 0``) survive sampling.

Both factories are EXACT no-ops at their identity settings
(``rate >= 1.0``; ``k_frac >= 1.0 and not quantize``): they return the
algorithm object unchanged.

The shared multi-round driver
-----------------------------
:func:`run_rounds` / :func:`make_round_runner` scan ``algo.round`` over K
rounds with an optional per-round metric hook. ``simulate_quadratic``,
``FedTrainer.fit`` and ``launch.train.run_training`` all consume it — one
lowered while-loop whether the payload is the paper's 60-dim quadratic or a
sharded multi-B-parameter LM.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import GradFn, vmap_grads
from repro.core.comm import sparsified_up_frac
from repro.utils.tree import tree_client_mean


class EngineState(NamedTuple):
    """Algorithm state plus per-transform extra state (e.g. error-feedback
    memory). Only used when at least one message transform is attached;
    transform-free algorithms keep their bare spec state, so existing
    checkpoints and sharding specs are unaffected."""

    inner: Any
    extras: tuple


# --------------------------------------------------------------------- masks
def participation_mask(key, n_clients: int, rate: float) -> jax.Array:
    """Bernoulli(rate) participation mask, guaranteed non-empty: if no client
    draws in, one uniformly random client is forced in. The Bernoulli draw
    and the fallback index use independent subkeys."""
    k_draw, k_fallback = jax.random.split(key)
    m = jax.random.bernoulli(k_draw, rate, (n_clients,))
    first = jax.nn.one_hot(jax.random.randint(k_fallback, (), 0, n_clients),
                           n_clients, dtype=bool)
    return jnp.where(jnp.any(m), m, first)


def masked_client_mean(tree, mask: jax.Array, *, keepdims: bool = True):
    """Mean over the leading clients axis restricted to ``mask``-selected
    clients (the server average under partial participation)."""
    denom = jnp.maximum(jnp.sum(mask.astype(jnp.int32)), 1)

    def mean_leaf(a):
        mb = mask.reshape((-1,) + (1,) * (a.ndim - 1)).astype(a.dtype)
        return jnp.sum(a * mb, axis=0, keepdims=keepdims) / denom.astype(a.dtype)

    return jax.tree.map(mean_leaf, tree)


def select_clients(new, old, mask: jax.Array, n_clients: int):
    """Per-client select between two same-structure pytrees: leaves with a
    leading ``n_clients`` axis take ``new`` where the mask is set and ``old``
    elsewhere; all other leaves (global scalars like the step counter) take
    ``new`` unconditionally."""

    def sel(n, o):
        if getattr(n, "ndim", 0) >= 1 and n.shape[0] == n_clients:
            mb = mask.reshape((-1,) + (1,) * (n.ndim - 1))
            return jnp.where(mb, n, o)
        return n

    return jax.tree.map(sel, new, old)


# ---------------------------------------------------------------- transforms
#: domain-separation tag folded into compression keys so they never collide
#: with the participation-mask key schedule (both default to seed=0).
_COMPRESS_KEY_TAG = 0x7A11A5


@dataclasses.dataclass(frozen=True)
class MessageCompression:
    """Message transform adapting a :class:`repro.core.compressors.Compressor`
    (possibly ``ErrorFeedback``-wrapped) into the engine's message path.

    Owns the per-round PRNG schedule for stochastic compressors: the key is
    ``fold_in(fold_in(key(seed), TAG), step)`` where ``step`` is the state's
    step counter at round entry (advanced by exactly ``tau`` per round, -1
    at the warm-up aggregation) — a fresh key every round, deterministic
    under restart, never shared with the participation mask schedule.
    Randomness is synchronized across clients (see compressors.py: this is
    what makes unbiased compressors preserve the FedCET fixed point and
    lets RandK skip index traffic)."""

    compressor: Any
    seed: int = 0
    #: position in the algorithm's transform stack, folded into the key so
    #: two stacked stochastic transforms at the same (default) seed never
    #: replay each other's randomness (which would de-unbias them).
    index: int = 0

    @property
    def up_frac(self) -> float:
        return self.compressor.up_frac

    @property
    def bits_per_coord(self) -> float:
        return self.compressor.bits_per_coord

    @property
    def keep_frac(self) -> float:
        return self.compressor.keep_frac

    @property
    def index_bits(self) -> float:
        return self.compressor.index_bits

    @property
    def value_bits(self) -> float | None:
        return self.compressor.value_bits

    @property
    def unbiased(self) -> bool:
        return getattr(self.compressor, "unbiased", False)

    def init_extra(self, msg_shapes):
        return self.compressor.init_extra(msg_shapes)

    def apply(self, msg, extra, step):
        key = None
        if self.compressor.requires_key:
            key = jax.random.fold_in(
                jax.random.key(self.seed), _COMPRESS_KEY_TAG + self.index)
            key = jax.random.fold_in(key, jnp.asarray(step, jnp.int32))
        return self.compressor.apply(key, msg, extra)


@dataclasses.dataclass(frozen=True)
class ErrorFeedbackCompression:
    """Legacy message transform (the seed's scheme, kept as construction
    sugar with its exact semantics): cross-client top-k sparsification
    and/or bf16 quantization with optional client-side error feedback.

    Since the compressor subsystem this is a thin shim over
    ``ErrorFeedback(Chain((TopK(k_frac, per_client=False), Bf16())))`` —
    the compress path is bit-identical to the seed (seed-equivalence tests
    pin it to <= 1e-12). ``up_frac`` keeps the seed's APPROXIMATE accounting
    ("bf16 halves whatever remains") for backward compatibility;
    ``bits_per_coord`` reports the bit-true cost (bf16 halves VALUES only —
    top-k index traffic stays int32), which is what ``CommMeter`` now
    meters. New code should pass ``with_compression(..., compressor=...)``
    objects instead."""

    k_frac: float = 1.0
    quantize: bool = False
    error_feedback: bool = True

    @property
    def up_frac(self) -> float:
        """Effective uplink fraction vs a dense f32 payload (top-k transmits
        values + int32 indices; bf16 halves whatever remains)."""
        frac = sparsified_up_frac(self.k_frac)
        if self.quantize:
            frac = min(0.5 * frac, 0.5)
        return min(frac, 1.0)

    def _compressor(self):
        from repro.core.compressors import (
            Bf16, Chain, ErrorFeedback, Identity, TopK)

        stages = []
        if self.k_frac < 1.0:
            stages.append(TopK(self.k_frac, per_client=False))
        if self.quantize:
            stages.append(Bf16())
        comp = (stages[0] if len(stages) == 1
                else Chain(tuple(stages)) if stages else Identity())
        return ErrorFeedback(comp) if self.error_feedback else comp

    @property
    def bits_per_coord(self) -> float:
        return self._compressor().bits_per_coord

    @property
    def keep_frac(self) -> float:
        return self._compressor().keep_frac

    @property
    def index_bits(self) -> float:
        return self._compressor().index_bits

    @property
    def value_bits(self) -> float | None:
        return self._compressor().value_bits

    def init_extra(self, msg_shapes):
        """Feedback memory, shaped like the message (from ``eval_shape``)."""
        return self._compressor().init_extra(msg_shapes)

    def apply(self, msg, extra, step):
        del step  # deterministic stack
        return self._compressor().apply(None, msg, extra)


@dataclasses.dataclass(frozen=True)
class ClientSampling:
    """Per-round Bernoulli client participation policy."""

    rate: float
    seed: int = 0


# --------------------------------------------------------------------- engine
@dataclasses.dataclass(frozen=True)
class RoundEngine:
    """Shared round driver; algorithms subclass this and implement the spec
    hooks (``init_warmup``, ``local_step``, ``message``,
    ``server_aggregate``, optionally ``begin_round`` / ``client_params``).

    Subclasses must declare ``name``, ``tau``, ``n_clients``, ``vectors_up``
    and ``vectors_down`` fields (the FederatedAlgorithm protocol), and their
    state must be a pytree whose per-client leaves carry a leading
    ``n_clients`` axis plus a scalar step counter ``t`` that the engine-run
    round advances by exactly ``tau``."""

    transforms: tuple = dataclasses.field(default=(), kw_only=True)
    sampling: ClientSampling | None = dataclasses.field(default=None, kw_only=True)
    #: mesh axes carrying the client dimension (production launcher only).
    spmd_client_axes: tuple = dataclasses.field(default=(), kw_only=True)

    # ------------------------------------------------------------ spec hooks
    def init_warmup(self, gf, x0, init_batch):
        raise NotImplementedError

    def begin_round(self, gf, state, first_batch, agg):
        """Optional round-start exchange; returns (state, round context)."""
        del gf, first_batch, agg
        return state, None

    def local_step(self, gf, state, batch, rctx):
        raise NotImplementedError

    def message(self, gf, state, batch, rctx):
        raise NotImplementedError

    def server_aggregate(self, state, msg, msg_bar, mctx, rctx):
        raise NotImplementedError

    def client_params(self, state):
        """Stacked [clients, ...] model parameters (default: ``state.x``)."""
        return self._inner(state).x

    def global_params(self, state):
        return tree_client_mean(self.client_params(state), keepdims=False)

    # ------------------------------------------------------------ accounting
    @property
    def up_frac(self) -> float:
        """Effective uplink bytes fraction after message transforms."""
        frac = 1.0
        for t in self.transforms:
            frac *= getattr(t, "up_frac", 1.0)
        return frac

    def _transforms_bits(self, bits: float = 32.0) -> float:
        """Fold the attached transforms' bit-true cost onto a dense width.

        Stacked transforms compose like Chain stages — via their
        (keep_frac, index_bits, value_bits) triple, NOT by multiplying
        total fractions (that would wrongly scale a sparsifier's int32
        index bits by a later quantizer's value fraction: top-k 30% then
        q8 is 0.3*(8+32)=12 bits/coord, not 32*0.6*0.25)."""
        keep, idx, value = 1.0, 0.0, bits
        for t in self.transforms:
            kf = getattr(t, "keep_frac", None)
            if kf is None:  # unknown transform: coarse fractional fallback
                per = getattr(t, "bits_per_coord", None)
                per = 32.0 * getattr(t, "up_frac", 1.0) if per is None else per
                value *= per / 32.0
                continue
            keep *= kf
            idx += keep * t.index_bits
            vb = t.value_bits
            if vb is not None:
                value = vb
        return keep * value + idx

    @property
    def bits_per_coord(self) -> float:
        """Bit-true average wire bits per model coordinate per UP vector,
        derived from the attached compressor stack (32.0 when dense).
        Specs with internal compression (FedLin's round-start top-k)
        override this alongside ``up_frac``."""
        return self._transforms_bits(32.0)

    @property
    def down_frac(self) -> float:
        return 1.0

    # ------------------------------------------------------- state wrapping
    def _wrap(self, inner, extras):
        return EngineState(inner, tuple(extras)) if self.transforms else inner

    def _split(self, state):
        if self.transforms:
            return state.inner, state.extras
        return state, ()

    def _inner(self, state):
        return state.inner if self.transforms else state

    # ------------------------------------------------------------- plumbing
    def _grad(self, grad_fn: GradFn) -> GradFn:
        return vmap_grads(grad_fn, spmd_axis_name=(self.spmd_client_axes or None))

    def _init_extras(self, gf, inner, init_batch) -> tuple:
        """Per-transform extra state, shaped from the (abstract) message."""
        if not self.transforms:
            return ()

        def msg_of(s, b):
            s2, rctx = self.begin_round(gf, s, b, tree_client_mean)
            return self.message(gf, s2, b, rctx)[0]

        msg_shapes = jax.eval_shape(msg_of, inner, init_batch)
        return tuple(t.init_extra(msg_shapes) for t in self.transforms)

    def _comm_step(self, gf, inner, extras, batch, rctx, agg, step):
        """The single aggregating step: message -> transforms -> reduce ->
        apply. The only place a cross-client collective fires. ``step`` is
        the state's step counter at round entry — stochastic transforms
        derive their per-round PRNG key from it (never reused across
        rounds; stack multiple stochastic transforms with distinct seeds)."""
        msg, mctx = self.message(gf, inner, batch, rctx)
        new_extras = []
        for t, e in zip(self.transforms, extras):
            msg, e = t.apply(msg, e, step)
            new_extras.append(e)
        msg_bar = agg(msg)
        inner = self.server_aggregate(inner, msg, msg_bar, mctx, rctx)
        return inner, tuple(new_extras)

    # -------------------------------------------------------------- protocol
    def init(self, grad_fn: GradFn, x0, init_batch):
        """Replicate-and-warm-up, plus one aggregating step if the spec's
        warm-up requests it. Client sampling never applies at init (matching
        the full-participation initialization of the paper)."""
        gf = self._grad(grad_fn)
        inner, run_comm = self.init_warmup(gf, x0, init_batch)
        extras = self._init_extras(gf, inner, init_batch)
        if run_comm:
            inner, extras = self._comm_step(gf, inner, extras, init_batch,
                                            rctx=None, agg=tree_client_mean,
                                            step=inner.t)
        return self._wrap(inner, extras)

    def round(self, grad_fn: GradFn, state, batches):
        """One communication round: optional round-start exchange, tau-1
        local steps under ``lax.scan``, one aggregating step.

        ``batches`` leaves have leading ``[tau, clients, ...]`` axes. The
        scan keeps the lowered HLO small for multi-B parameter models; the
        aggregation sits OUTSIDE the scan so the cross-pod all-reduce
        appears exactly once per round in the HLO."""
        gf = self._grad(grad_fn)
        inner, extras = self._split(state)

        step0 = inner.t  # round-entry counter: keys masks AND compressors
        mask = None
        agg = tree_client_mean
        if self.sampling is not None:
            key = jax.random.fold_in(jax.random.key(self.sampling.seed),
                                     jnp.asarray(inner.t, jnp.int32))
            mask = participation_mask(key, self.n_clients, self.sampling.rate)
            agg = lambda tr: masked_client_mean(tr, mask)  # noqa: E731
        frozen_inner, frozen_extras = inner, extras

        first_b = jax.tree.map(lambda b: b[0], batches)
        inner, rctx = self.begin_round(gf, inner, first_b, agg)

        if self.tau > 1:
            local_b = jax.tree.map(lambda b: b[: self.tau - 1], batches)

            def body(s, b):
                return self.local_step(gf, s, b, rctx), None

            inner, _ = jax.lax.scan(body, inner, local_b)

        last_b = jax.tree.map(lambda b: b[self.tau - 1], batches)
        inner, extras = self._comm_step(gf, inner, extras, last_b, rctx, agg,
                                        step=step0)

        if mask is not None:
            # absent clients keep their pre-round state entirely
            inner = select_clients(inner, frozen_inner, mask, self.n_clients)
            extras = tuple(select_clients(e, fe, mask, self.n_clients)
                           for e, fe in zip(extras, frozen_extras))
        return self._wrap(inner, extras)


# ------------------------------------------------------- transform factories
def with_participation(algo: RoundEngine, rate: float, seed: int = 0) -> RoundEngine:
    """Per-round Bernoulli client sampling for ANY engine algorithm.
    ``rate >= 1.0`` is an exact no-op (returns ``algo`` unchanged)."""
    if rate >= 1.0:
        return algo
    return dataclasses.replace(algo, sampling=ClientSampling(rate=rate, seed=seed))


def with_compression(algo: RoundEngine, *, k_frac: float = 1.0,
                     quantize: bool = False,
                     error_feedback: bool | None = None,
                     compressor=None, seed: int = 0) -> RoundEngine:
    """Compressed uplink for ANY engine algorithm's message path.

    Two entry forms:

    * ``compressor=`` — a :class:`repro.core.compressors.Compressor` object
      or spec string (``"randk:0.25"``, ``"topk:0.3+bf16"``, ``"q8"``, ...).
      ``error_feedback=None`` (the default) wraps BIASED compressors in
      :class:`~repro.core.compressors.ErrorFeedback` and leaves unbiased
      ones bare (EF around an unbiased compressor reintroduces a feedback
      limit cycle); pass True/False to force. ``seed`` keys the per-round
      randomness of stochastic compressors.
    * legacy ``k_frac=`` / ``quantize=`` — the seed's cross-client top-k +
      bf16 error-feedback scheme, bit-identical to the original
      (``error_feedback=None`` means True here). ``k_frac >= 1.0 and not
      quantize`` is an exact no-op (returns ``algo`` unchanged).

    Transforms stack: the last one attached compresses the output of the
    previous one."""
    if compressor is not None:
        if k_frac < 1.0 or quantize:
            raise ValueError(
                "pass EITHER compressor= or the legacy k_frac=/quantize= "
                "kwargs, not both (the legacy pair would be silently "
                f"ignored): compressor={compressor!r}, k_frac={k_frac}, "
                f"quantize={quantize}")
        from repro.core.compressors import ErrorFeedback, from_spec

        comp = from_spec(compressor)
        if comp is None:  # the "none" spec — exact no-op, like k_frac=1.0
            return algo
        # auto mode: EF around biased STATELESS compressors only — wrapping
        # a Shifted/ErrorFeedback would clobber its extra slot.
        ef = ((not comp.unbiased and not comp.stateful)
              if error_feedback is None else error_feedback)
        if ef and not isinstance(comp, ErrorFeedback):
            comp = ErrorFeedback(comp)  # raises if comp is stateful
        t = MessageCompression(comp, seed=seed, index=len(algo.transforms))
        return dataclasses.replace(algo, transforms=algo.transforms + (t,))
    if k_frac >= 1.0 and not quantize:
        return algo
    t = ErrorFeedbackCompression(
        k_frac=k_frac, quantize=quantize,
        error_feedback=True if error_feedback is None else error_feedback)
    return dataclasses.replace(algo, transforms=algo.transforms + (t,))


# --------------------------------------------------------- multi-round driver
def make_round_runner(algo, grad_fn: GradFn, *, metric_fn=None, repeat: bool = False):
    """Build the jitted K-round scan over ``algo.round``.

    * ``repeat=False`` (default): the returned ``run(state, batches)`` scans
      over stacked per-round batches (leaves ``[rounds, tau, clients, ...]``).
    * ``repeat=True``: ``run(state, batches, rounds)`` replays the SAME
      per-round batch pytree (leaves ``[tau, clients, ...]``) for ``rounds``
      rounds — the full-batch simulation mode.

    ``metric_fn(state) -> pytree`` is evaluated after every round and stacked
    into the second return value. Keep ONE runner per training loop: jit
    caching is per function instance."""
    if repeat:
        def run(state, batches, rounds):
            def body(s, _):
                s = algo.round(grad_fn, s, batches)
                return s, (metric_fn(s) if metric_fn is not None else None)

            return jax.lax.scan(body, state, None, length=rounds)

        return jax.jit(run, static_argnums=2)

    def run(state, batches):
        def body(s, b):
            s = algo.round(grad_fn, s, b)
            return s, (metric_fn(s) if metric_fn is not None else None)

        return jax.lax.scan(body, state, batches)

    return jax.jit(run)


def scan_segments(start: int, total: int, is_boundary, *, max_rounds: int = 32):
    """Yield ``(first, last)`` round indices for jitted scan segments.

    Each segment ends at the next boundary round (inclusive — the round
    after which the caller wants to eval/checkpoint/log) or after
    ``max_rounds``, whichever comes first; the cap bounds the memory spent
    on stacked per-round batches. Shared by ``FedTrainer.fit`` and
    ``launch.train.run_training``."""
    r = start
    while r < total:
        cap = min(total - 1, r + max_rounds - 1)
        stop = next((s for s in range(r, cap) if is_boundary(s)), cap)
        yield r, stop
        r = stop + 1


def run_rounds(algo, grad_fn: GradFn, state, batches, *, rounds: int | None = None,
               metric_fn=None):
    """Run K communication rounds through one ``lax.scan`` (the shared
    driver behind ``simulate_quadratic`` and ``FedTrainer.fit``).

    With ``rounds=None``, ``batches`` leaves are ``[rounds, tau, clients,
    ...]`` stacks and the round count is their leading axis; with
    ``rounds=K``, ``batches`` is a single per-round pytree (leaves
    ``[tau, clients, ...]``) replayed every round. Returns
    ``(final_state, stacked_metrics)`` (metrics ``None`` without a hook)."""
    if rounds is not None:
        return make_round_runner(algo, grad_fn, metric_fn=metric_fn,
                                 repeat=True)(state, batches, rounds)
    return make_round_runner(algo, grad_fn, metric_fn=metric_fn)(state, batches)
