"""Packed parameter arena: the model pytree as ONE lane-aligned buffer.

The engine's message/aggregate seam is element-wise over the whole model
(compress -> reduce -> FedCET ``(d', x')`` pair). Executed per leaf it is
dozens of small XLA ops per round — many dispatches on TPU, and once the
per-client arrays outgrow cache it re-streams every intermediate from
HBM/DRAM. The arena flattens the pytree ONCE into a contiguous
``[rows, LANES]`` f32 buffer (LANES = 1024, the Pallas kernels' lane
tiling) so the whole seam is a handful of big array ops — and, with
``FedCET(use_fused_kernel=True)``, a single fused kernel visit per
element (kernels/fedcet_update.py ``fedcet_round_tail``).

Layout: leaves are flattened in ``jax.tree.flatten`` order, each padded
up to a whole number of 1024-lane rows (pad values are ZERO and every
seam operation preserves zero pads — add/sub of zero is zero, the
dither rows are zero-padded so ``floor(0 + 0) = 0``, and reductions are
per-leaf via the static row->leaf segment map). The static
:class:`ArenaLayout` records the treedef, per-leaf shapes and row
extents; it is hashable (jit-static) and rides as pytree aux data, so an
:class:`Arena` is itself a pytree whose single leaf is ``data``:

* ``data.ndim == 2`` — ``[rows, LANES]``: one model (e.g. the global
  mean);
* ``data.ndim == 3`` — ``[lead, rows, LANES]``: a stacked
  ``[clients, ...]`` tree (the repo-wide client-axis convention; axis 0
  keeps meaning clients, so ``gather/scatter/select_clients``,
  ``tree_client_mean`` and participation masking work on arenas
  unchanged).

Pack/unpack happen only at the model-apply boundary (the engine wraps
the vmapped grad fn) and at checkpoint adaptation
(:func:`adapt_state` — flips a per-leaf checkpoint into an arena run
and back, so the ``--arena`` knob stays flippable mid-sweep).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "LANES",
    "Arena",
    "ArenaLayout",
    "adapt_state",
    "pack",
    "pack_rows",
    "unpack",
]

#: lane width of one arena row — matches kernels/fedcet_update.py LANES.
LANES = 1024


def _rows_of(shape: tuple) -> int:
    return max(1, -(-math.prod(shape) // LANES))


@dataclasses.dataclass(frozen=True)
class ArenaLayout:
    """Static (hashable) description of how a pytree maps onto the arena."""

    treedef: Any
    shapes: tuple  # per-leaf MODEL shapes (no client axis), flatten order
    dtype: Any     # the single float dtype every leaf shares
    rows_per_leaf: tuple

    @classmethod
    def for_tree(cls, tree) -> "ArenaLayout":
        """Layout for a MODEL pytree (leaves carry no client axis)."""
        leaves, treedef = jax.tree.flatten(tree)
        if not leaves:
            raise ValueError("cannot build an arena layout for an empty tree")
        dtypes = {jnp.asarray(l).dtype for l in leaves}
        if len(dtypes) != 1:
            raise ValueError(
                "arena requires a homogeneous leaf dtype (mixed dtypes would "
                f"change per-leaf rounding): {sorted(map(str, dtypes))}")
        (dtype,) = dtypes
        if not jnp.issubdtype(dtype, jnp.floating):
            raise ValueError(f"arena leaves must be floating, got {dtype}")
        shapes = tuple(tuple(jnp.shape(l)) for l in leaves)
        return cls(treedef=treedef, shapes=shapes, dtype=dtype,
                   rows_per_leaf=tuple(_rows_of(s) for s in shapes))

    @property
    def rows(self) -> int:
        return sum(self.rows_per_leaf)

    @property
    def num_params(self) -> int:
        return sum(math.prod(s) for s in self.shapes)

    def row_segments(self) -> np.ndarray:
        """Static row -> leaf-index map ``[rows]`` (int32) for per-leaf
        segment reductions (quantizer scales) over the packed buffer."""
        return np.repeat(np.arange(len(self.shapes), dtype=np.int32),
                         self.rows_per_leaf)

    def leaf_sizes(self) -> tuple:
        """Per-leaf coordinate counts in flatten order — the segment index
        of ``row_segments`` IS the leaf index a
        :class:`~repro.core.compressors.CompressionPlan` digit rule names,
        and these sizes are the ``n`` its exact ``wire_bits`` rounding
        bills (same order as ``repro.core.comm.leaf_info_of`` on the
        unpacked tree)."""
        return tuple(math.prod(s) for s in self.shapes)


class Arena:
    """A pytree whose leaves live packed in one ``[..., rows, LANES]``
    buffer. Registered as a pytree node (child: ``data``; aux: layout),
    so ``jax.tree.map`` arithmetic, ``eval_shape``, donation, sharding
    and checkpointing all treat it as a single big leaf."""

    __slots__ = ("data", "layout")

    def __init__(self, data, layout: ArenaLayout):
        self.data = data
        self.layout = layout

    def __repr__(self):
        return (f"Arena(shape={tuple(jnp.shape(self.data))}, "
                f"leaves={len(self.layout.shapes)}, "
                f"params={self.layout.num_params})")


jax.tree_util.register_pytree_node(
    Arena,
    lambda a: ((a.data,), a.layout),
    lambda layout, children: Arena(children[0], layout),
)


def _lead_of(leaf_shape: tuple, model_shape: tuple) -> int | None:
    """None for an unstacked (model-shaped) leaf, else the stack size."""
    if tuple(leaf_shape) == tuple(model_shape):
        return None
    if tuple(leaf_shape[1:]) == tuple(model_shape):
        return int(leaf_shape[0])
    raise ValueError(f"leaf shape {leaf_shape} matches neither the model "
                     f"shape {model_shape} nor a stacked [lead, ...] of it")


def pack(tree, layout: ArenaLayout | None = None) -> Arena:
    """Flatten ``tree`` (model-shaped, or stacked ``[lead, ...]``) into an
    :class:`Arena`. Padding is zero; pure reshape/pad/concat — bitwise."""
    if layout is None:
        layout = ArenaLayout.for_tree(tree)
    leaves = jax.tree.leaves(tree)
    if len(leaves) != len(layout.shapes):
        raise ValueError(f"tree has {len(leaves)} leaves, layout expects "
                         f"{len(layout.shapes)}")
    leads = {_lead_of(jnp.shape(l), s)
             for l, s in zip(leaves, layout.shapes)}
    if len(leads) != 1:
        raise ValueError(f"inconsistent leading axes across leaves: {leads}")
    (lead,) = leads
    return Arena(pack_rows(leaves, layout, lead=lead), layout)


def pack_rows(leaves, layout: ArenaLayout, lead: int | None = None):
    """Pack a list of per-leaf arrays (layout order; model-shaped, or
    ``[lead, ...]``-stacked when ``lead`` is given) into a raw
    ``[(lead,) rows, LANES]`` buffer — the dither-packing path, which
    needs rows without the Arena wrapper.

    Single-materialization schedule: leaves and their zero pads are
    interleaved into ONE flat concatenate (zeros are broadcast constants),
    so the packed buffer is written once — a per-leaf ``jnp.pad`` followed
    by a concat would stream the model an extra time, which is the
    dominant crossing cost of the arena round at DRAM-resident sizes."""
    parts = []
    dtype = layout.dtype
    for leaf, shape, nr in zip(leaves, layout.shapes, layout.rows_per_leaf):
        n = math.prod(shape)
        flat = jnp.reshape(leaf, (n,) if lead is None else (lead, n))
        parts.append(flat)
        if nr * LANES != n:
            pad_shape = ((nr * LANES - n,) if lead is None
                         else (lead, nr * LANES - n))
            parts.append(jnp.zeros(pad_shape, dtype))
    flat = jnp.concatenate(parts, axis=-1)
    shape = (layout.rows, LANES)
    return jnp.reshape(flat, shape if lead is None else (lead,) + shape)


def unpack(arena: Arena):
    """Invert :func:`pack`: slice each leaf's rows back out and reshape.
    ``data.ndim == 2`` yields the model tree; 3 yields a stacked
    ``[lead, ...]`` tree. Bitwise (pads dropped, no arithmetic)."""
    lo, data = arena.layout, arena.data
    if data.ndim not in (2, 3):
        raise ValueError(f"arena data must be [lead?, rows, {LANES}], got "
                         f"shape {tuple(data.shape)}")
    lead = None if data.ndim == 2 else data.shape[0]
    out, off = [], 0
    for shape, nr in zip(lo.shapes, lo.rows_per_leaf):
        n = math.prod(shape)
        if lead is None:
            a = jnp.reshape(data[off:off + nr], (nr * LANES,))[:n]
            out.append(jnp.reshape(a, shape))
        else:
            a = jnp.reshape(data[:, off:off + nr], (lead, nr * LANES))[:, :n]
            out.append(jnp.reshape(a, (lead,) + shape))
        off += nr
    return jax.tree.unflatten(lo.treedef, out)


def adapt_state(src, like):
    """Structurally adapt a checkpointed engine state between the per-leaf
    and arena representations: wherever ``like`` carries an :class:`Arena`
    and ``src`` carries the corresponding subtree (or vice versa), pack /
    unpack; everything else is recursed field-by-field. Keeps checkpoints
    knob-flippable: a per-leaf run restores into an ``--arena`` run and
    back with bitwise-identical leaf values."""
    if isinstance(like, Arena):
        if isinstance(src, Arena):
            return src
        return pack(src, like.layout)
    if isinstance(src, Arena):
        return unpack(src)
    # namedtuples (EngineState / FedCETState / DelayState / TopoState ...)
    if isinstance(like, tuple) and hasattr(like, "_fields"):
        return type(like)(*(adapt_state(s, l) for s, l in zip(src, like)))
    if isinstance(like, tuple):
        return tuple(adapt_state(s, l) for s, l in zip(src, like))
    if isinstance(like, list):
        return [adapt_state(s, l) for s, l in zip(src, like)]
    if isinstance(like, dict):
        return {k: adapt_state(src[k], like[k]) for k in like}
    return src
