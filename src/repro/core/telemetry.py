"""In-trace telemetry: per-round metrics, invariant monitors, event sinks.

The engine's round body runs inside one jitted ``lax.scan`` over K rounds —
a host callback per round would serialize the scan, and re-running the
round outside jit to measure it would double the work. This module instead
captures scalars *while the round is being traced*:

* :func:`capture` writes a named scalar onto the active **tape** — a
  trace-time collector the round runner opens around ``algo.round`` via
  :func:`collect`. Outside a tape (direct ``algo.round`` calls, ``init``,
  ``eval_shape``) and inside :func:`muted` regions (the engine mutes the
  tau-1 local ``lax.scan`` — a capture there would leak inner-scan tracers
  into the round-level tape) it is a no-op, so instrumented code needs no
  caller-side discipline.
* :meth:`Telemetry.finalize` turns tape + post-round state into the round's
  metric dict — tape scalars plus state-derived series: FedCET's
  ``sum_i d_i = 0`` invariant residual (Lemma 2 — the quantity PR 3/PR 5
  measured drifting under poly staleness / tier recompression, now live)
  and the consensus error ``max_i ||x_i - x_bar||`` (the gossip-descent
  quantity). The dict becomes the scan's stacked ys: metrics stay
  on-device for the whole segment, ZERO host syncs inside the scan.
* :func:`drain` device-gets the stacked series ONCE per segment and feeds
  per-round events (plus :class:`Monitor` WARN events and static per-round
  bit accounting from :func:`repro.core.comm.comm_bits_per_round`) into
  pluggable sinks: :class:`JsonlSink` (one JSON object per line, manifest
  first), :class:`CsvSink`, :class:`StdoutSink`, :class:`MemorySink`.

Telemetry disabled (``algo.telemetry is None``) must be a BITWISE no-op:
the engine guards every capture on the attached spec, so the disabled
round traces the exact same jaxpr as before this module existed —
tests/test_telemetry.py pins 0.0 divergence across the composed-scenario
matrix.

Profiling hooks live here too: :class:`TraceSession` brackets a
``--trace-rounds a:b`` window with ``jax.profiler`` trace capture, and
:func:`instruction_count` counts optimized-HLO instructions (reusing
``roofline/hlo_parse``'s computation splitter) so benchmarks can report
the instrumentation's compiled footprint.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import subprocess
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# ------------------------------------------------------------------ the tape
#: stack of active trace-time collectors (nested collect()s shadow like
#: dynamic scope) and a mute depth counter. Trace-time only — never part of
#: traced state, so it adds no jaxpr inputs and costs nothing when empty.
_TAPES: list[dict] = []
_MUTE: int = 0


def collecting() -> bool:
    """True when a tape is active and not muted — the engine's guard for
    building capture ops at all (disabled telemetry traces zero extra ops)."""
    return bool(_TAPES) and _MUTE == 0


def capture(name: str, value) -> None:
    """Record a named scalar on the active tape (no-op without one).
    Repeated captures of the same name within a round keep the LAST value
    (e.g. ``grad_norm`` at the aggregating step, not a begin_round probe)."""
    if collecting():
        _TAPES[-1][name] = value


@contextlib.contextmanager
def collect():
    """Open a tape around a traced region; yields the dict of captured
    tracers (valid within the same trace — the caller folds them into its
    outputs before the trace ends)."""
    tape: dict = {}
    _TAPES.append(tape)
    try:
        yield tape
    finally:
        _TAPES.pop()


@contextlib.contextmanager
def muted():
    """Suppress captures while tracing an inner ``lax.scan`` body (whose
    tracers must not escape onto the round-level tape)."""
    global _MUTE
    _MUTE += 1
    try:
        yield
    finally:
        _MUTE -= 1


# ----------------------------------------------------------- metric helpers
def client_sq_norms(tree):
    """``[clients]`` squared L2 norms: per-client sum of squares over every
    leaf's non-leading axes (leaves carry a leading clients axis; an Arena
    leaf's zero pads contribute nothing, so packed == per-leaf)."""
    tot = None
    for a in jax.tree.leaves(tree):
        s = jnp.sum(jnp.square(a), axis=tuple(range(1, a.ndim)))
        tot = s if tot is None else tot + s
    return tot


def mean_client_norm(tree):
    """Mean over clients of the per-client L2 norm."""
    return jnp.mean(jnp.sqrt(client_sq_norms(tree)))


def _tree_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(a)) for a in jax.tree.leaves(tree)))


# ------------------------------------------------------------------ monitors
@dataclasses.dataclass(frozen=True)
class Monitor:
    """Declarative per-round alert: WARN when ``metric`` crosses ``bound``
    (``mode="max"``: value > bound; ``"min"``: value < bound). ``axis``
    names the scenario axis the violation implicates — the WARN event
    carries it so a drifting invariant points at its cause."""

    metric: str
    bound: float
    mode: str = "max"
    axis: str = ""

    def violated(self, value) -> bool:
        v = float(value)
        return v > self.bound if self.mode == "max" else v < self.bound


#: the PR 3 pinned boundary as a live check: FedCET's redistributive drift
#: updates keep sum_i d_i = 0 exactly (Lemma 2) under every exact scenario
#: (fixed:k delay included — uniform ages make poly discounting uniform);
#: non-uniform stale-policy weights (poly:a with rr/geom ages) and tier
#: recompression break the redistribution. The residual is RELATIVE
#: (||mean_i d_i|| / mean_i ||d_i||): exact scenarios sit at accumulation
#: noise (~1e-13 in f64), the pinned drift scenarios reach O(1e-2..1).
INVARIANT_MONITOR = Monitor(
    metric="invariant_residual", bound=1e-6, mode="max",
    axis="stale_policy (poly:a discounting with non-uniform ages) or "
         "tier_compression — non-uniform aggregation weights break the "
         "sum_i d_i = 0 redistribution (Lemma 2)")


# ------------------------------------------------------------- the spec
@dataclasses.dataclass(frozen=True)
class Telemetry:
    """The telemetry spec attached to an engine algorithm
    (``with_telemetry`` / ``FedScenario(telemetry=...)``). Hashable and
    stateless — it adds NO algorithm state (checkpoints are unaffected)
    and selects which metrics the runner stacks and which monitors the
    drain evaluates.

    ``metrics="auto"`` keeps everything captured plus the state-derived
    series; a tuple restricts to those names (unavailable names are
    silently absent — e.g. no ``age_*`` without a delay model).
    ``monitors="auto"`` evaluates :data:`INVARIANT_MONITOR` on algorithms
    that expose the drift state; a tuple of :class:`Monitor` overrides."""

    metrics: tuple | str = "auto"
    monitors: tuple | str = "auto"

    def finalize(self, tape: dict, algo, state) -> dict:
        """Tape + post-round state -> the round's metric dict (still
        traced values; becomes the scan's stacked ys)."""
        out = dict(tape)
        inner = algo._inner(state)
        d = getattr(inner, "d", None)
        if d is not None:
            num = _tree_norm(jax.tree.map(lambda a: jnp.mean(a, axis=0), d))
            den = mean_client_norm(d)
            out["invariant_residual"] = num / jnp.maximum(
                den, jnp.asarray(1e-30, den.dtype))
        x = getattr(inner, "x", None)
        if x is None:
            x = getattr(inner, "x_curr", None)
        if x is not None:
            dev = jax.tree.map(
                lambda a: a - jnp.mean(a, axis=0, keepdims=True), x)
            out["consensus_err"] = jnp.sqrt(jnp.max(client_sq_norms(dev)))
        if self.metrics != "auto":
            out = {k: out[k] for k in self.metrics if k in out}
        return out


def parse_telemetry(spec) -> Telemetry | None:
    """Normalize a telemetry knob: ``None`` / ``False`` / ``"none"`` /
    ``"off"`` / ``""`` -> None (disabled — the factory returns the
    algorithm unchanged); a :class:`Telemetry` passes through; any other
    truthy value (``True``, a sink spec string) -> the default spec."""
    if spec is None or spec is False:
        return None
    if isinstance(spec, Telemetry):
        return spec
    if isinstance(spec, str) and spec.strip().lower() in (
            "", "none", "off", "0", "false"):
        return None
    return Telemetry()


def resolve_monitors(telemetry: Telemetry | None) -> tuple:
    if telemetry is None:
        return ()
    if telemetry.monitors == "auto":
        return (INVARIANT_MONITOR,)
    return tuple(telemetry.monitors)


def split_metrics(algo, ys):
    """Split a round runner's stacked ys into ``(metrics, telemetry)`` —
    the runner nests them only when the algorithm has telemetry attached,
    so un-instrumented callers see the exact pre-telemetry structure."""
    if getattr(algo, "telemetry", None) is None or ys is None:
        return ys, None
    return ys["metric"], ys["telemetry"]


# --------------------------------------------------------------------- sinks
def _scalar(v):
    a = np.asarray(v)
    if a.dtype.kind == "b":
        return bool(a)
    if a.dtype.kind in "iu":
        return int(a)
    return float(a)


class MemorySink:
    """Collects events in a list (tests / programmatic consumers)."""

    def __init__(self):
        self.events: list[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON object per line; the run manifest is the first event."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")

    def emit(self, event: dict) -> None:
        self._f.write(json.dumps(event) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class CsvSink:
    """Round events as CSV; columns fixed by the first round event
    (non-round events are skipped — JSONL is the full stream)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")
        self._keys: list[str] | None = None

    def emit(self, event: dict) -> None:
        if event.get("event") != "round":
            return
        if self._keys is None:
            self._keys = [k for k in event if k != "event"]
            self._f.write(",".join(self._keys) + "\n")
        self._f.write(",".join(str(event.get(k, "")) for k in self._keys)
                      + "\n")

    def close(self) -> None:
        self._f.close()


class StdoutSink:
    """Human-readable summary lines; round lines gated by ``every``."""

    def __init__(self, every: int = 1):
        self.every = max(int(every), 1)

    @staticmethod
    def _fmt(v):
        return f"{v:.6g}" if isinstance(v, float) else str(v)

    def emit(self, event: dict) -> None:
        kind = event.get("event")
        if kind == "round":
            if event.get("round", 0) % self.every:
                return
            body = "  ".join(f"{k}={self._fmt(v)}" for k, v in event.items()
                             if k not in ("event", "round"))
            print(f"[telemetry] round {event.get('round', 0):5d}  {body}")
        elif kind == "monitor":
            print(f"[telemetry] WARN round {event.get('round')}: "
                  f"{event.get('metric')}={self._fmt(event.get('value'))} "
                  f"{'>' if event.get('mode', 'max') == 'max' else '<'} "
                  f"{event.get('bound')}  (axis: {event.get('axis', '')})")
        elif kind == "manifest":
            print(f"[telemetry] run algo={event.get('algo')} "
                  f"n_clients={event.get('n_clients')} tau={event.get('tau')} "
                  f"commit={event.get('commit')}")
        elif kind == "profile":
            print(f"[telemetry] profiler {event.get('action')} at round "
                  f"{event.get('round')} -> {event.get('dir')}")

    def close(self) -> None:
        pass


def parse_sinks(spec) -> list:
    """Sink spec grammar (the ``--telemetry`` CLI knob): comma-separated
    ``jsonl:<path>`` | ``csv:<path>`` | ``stdout[:every]`` | ``memory``.
    Sink objects / lists pass through; None -> []."""
    if spec is None or spec is True:
        return []
    if not isinstance(spec, str):
        return list(spec) if isinstance(spec, (list, tuple)) else [spec]
    sinks = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        kind, _, arg = part.partition(":")
        kind = kind.lower()
        if kind == "jsonl":
            sinks.append(JsonlSink(arg or "telemetry.jsonl"))
        elif kind == "csv":
            sinks.append(CsvSink(arg or "telemetry.csv"))
        elif kind == "stdout":
            sinks.append(StdoutSink(every=int(arg) if arg else 1))
        elif kind in ("memory", "mem"):
            sinks.append(MemorySink())
        else:
            raise ValueError(f"unknown telemetry sink {part!r} "
                             "(jsonl:<path> | csv:<path> | stdout[:k] | "
                             "memory)")
    return sinks


def emit_event(sinks, event: dict) -> None:
    for s in sinks:
        s.emit(event)


def close_sinks(sinks) -> None:
    for s in sinks:
        s.close()


# ----------------------------------------------------------- manifest/drain
def _git_commit() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None
    except OSError:
        return None


def run_manifest(algo, *, n_params: int | None = None, config: dict | None = None,
                 monitors: tuple = (), extra: dict | None = None) -> dict:
    """The run's first event: what ran, where, and what one round costs on
    the wire (the ``comm_hops_per_round`` per-hop contract + totals)."""
    tel = getattr(algo, "telemetry", None)
    ev = {
        "event": "manifest", "schema": 1,
        "algo": getattr(algo, "name", type(algo).__name__),
        "n_clients": getattr(algo, "n_clients", None),
        "tau": getattr(algo, "tau", None),
        "commit": _git_commit(),
        "mesh": {"backend": jax.default_backend(),
                 "n_devices": jax.device_count()},
        "metrics": (list(tel.metrics)
                    if tel is not None and tel.metrics != "auto" else "auto"),
        "monitors": [dataclasses.asdict(m) for m in monitors],
        "config": dict(config or {}),
    }
    if n_params:
        from repro.core.comm import comm_bits_per_round, comm_hops_per_round

        nc = getattr(algo, "n_clients", 1)
        ev["bits_per_round"] = comm_bits_per_round(algo, n_params, nc)
        ev["hops"] = comm_hops_per_round(algo, n_params, nc)
    if extra:
        ev.update(extra)
    return ev


def drain(series: dict | None, *, sinks=(), monitors=(), start_round: int = 0,
          static: dict | None = None, algo=None,
          n_params: int | None = None) -> list:
    """Device-get the stacked per-round telemetry pytree ONCE and emit one
    ``round`` event per round into the sinks, evaluating ``monitors``
    against each (violations emit a structured WARN event right after
    their round). ``static`` merges constant per-round fields; passing
    ``algo``/``n_params`` derives the bit-true ``bits_up``/``bits_down``
    per round from the comm accounting. Returns the emitted events."""
    events: list[dict] = []
    if not series:
        return events
    host = {k: np.asarray(jax.device_get(v)) for k, v in series.items()}
    n = len(next(iter(host.values())))
    stat = dict(static or {})
    if algo is not None and n_params:
        from repro.core.comm import comm_bits_per_round

        bits = comm_bits_per_round(algo, n_params,
                                   getattr(algo, "n_clients", 1))
        stat.setdefault("bits_up", bits["up_bits"])
        stat.setdefault("bits_down", bits["down_bits"])
    for i in range(n):
        ev = {"event": "round", "round": int(start_round + i)}
        for k, v in host.items():
            ev[k] = _scalar(v[i])
        ev.update(stat)
        events.append(ev)
        emit_event(sinks, ev)
        for m in monitors:
            if m.metric in ev and m.violated(ev[m.metric]):
                warn = {"event": "monitor", "level": "WARN",
                        "metric": m.metric, "round": ev["round"],
                        "value": ev[m.metric], "bound": m.bound,
                        "mode": m.mode, "axis": m.axis}
                events.append(warn)
                emit_event(sinks, warn)
    return events


def write_csv_rows(path: str, rows: list[dict]) -> None:
    """The trainer's CSV contract, verbatim (``FedTrainer._write_csv``
    routes through this so the bytes stay identical): header from the
    first row's keys, ``str()``-formatted values."""
    if not rows:
        return
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    keys = list(rows[0])
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for row in rows:
            f.write(",".join(str(row[k]) for k in keys) + "\n")


# ----------------------------------------------------------------- profiling
def parse_trace_rounds(spec) -> tuple[int, int] | None:
    """``"a:b"`` -> the half-open round window [a, b) to trace; ``"a"``
    traces the single round a. None/empty -> no tracing."""
    if spec is None or spec == "":
        return None
    if isinstance(spec, tuple):
        lo, hi = spec
    else:
        a, _, b = str(spec).partition(":")
        lo = int(a)
        hi = int(b) if b else lo + 1
    if hi <= lo or lo < 0:
        raise ValueError(f"bad --trace-rounds window {spec!r} (want a:b "
                         "with 0 <= a < b)")
    return lo, hi


@dataclasses.dataclass
class TraceSession:
    """Brackets a ``--trace-rounds a:b`` window with ``jax.profiler``
    trace capture. The caller forces scan-segment boundaries at the
    window edges (:meth:`boundaries`) and calls :meth:`maybe_start` before
    / :meth:`maybe_stop` after each segment; both return a ``profile``
    event for the sinks when they act."""

    window: tuple[int, int] | None
    out_dir: str = "profile_trace"
    active: bool = False

    def boundaries(self) -> tuple:
        """Round indices that must END a scan segment so the traced
        segment starts/stops exactly at the window edges."""
        if self.window is None:
            return ()
        return tuple(b for b in (self.window[0] - 1, self.window[1] - 1)
                     if b >= 0)

    def maybe_start(self, first_round: int) -> dict | None:
        if (self.window is None or self.active
                or not (self.window[0] <= first_round < self.window[1])):
            return None
        jax.profiler.start_trace(self.out_dir)
        self.active = True
        return {"event": "profile", "action": "start_trace",
                "round": first_round, "dir": self.out_dir}

    def maybe_stop(self, next_round: int) -> dict | None:
        if not self.active or next_round < self.window[1]:
            return None
        jax.profiler.stop_trace()
        self.active = False
        return {"event": "profile", "action": "stop_trace",
                "round": next_round, "dir": self.out_dir}

    def close(self) -> None:
        if self.active:
            jax.profiler.stop_trace()
            self.active = False


def instruction_count(lowered_or_text) -> int:
    """Instruction count of an optimized HLO module (a ``jit(...).lower()``
    result or its compiled text), via ``roofline/hlo_parse``'s computation
    splitter — one count per "name = op(...)" line across all
    computations. Benchmarks use it to report telemetry's compiled
    footprint next to its wall-clock cost."""
    txt = lowered_or_text
    if not isinstance(txt, str):
        txt = lowered_or_text.compile().as_text()
    from repro.roofline.hlo_parse import _split_computations

    return sum(1 for lines in _split_computations(txt).values()
               for ln in lines if " = " in ln)
