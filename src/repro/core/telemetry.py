"""In-trace telemetry: per-round metrics, invariant monitors, event sinks.

The engine's round body runs inside one jitted ``lax.scan`` over K rounds —
a host callback per round would serialize the scan, and re-running the
round outside jit to measure it would double the work. This module instead
captures scalars *while the round is being traced*:

* :func:`capture` writes a named scalar onto the active **tape** — a
  trace-time collector the round runner opens around ``algo.round`` via
  :func:`collect`. Outside a tape (direct ``algo.round`` calls, ``init``,
  ``eval_shape``) and inside :func:`muted` regions (the engine mutes the
  tau-1 local ``lax.scan`` — a capture there would leak inner-scan tracers
  into the round-level tape) it is a no-op, so instrumented code needs no
  caller-side discipline.
* :meth:`Telemetry.finalize` turns tape + post-round state into the round's
  metric dict — tape scalars plus state-derived series: FedCET's
  ``sum_i d_i = 0`` invariant residual (Lemma 2 — the quantity PR 3/PR 5
  measured drifting under poly staleness / tier recompression, now live)
  and the consensus error ``max_i ||x_i - x_bar||`` (the gossip-descent
  quantity). The dict becomes the scan's stacked ys: metrics stay
  on-device for the whole segment, ZERO host syncs inside the scan.
* :func:`drain` device-gets the stacked series ONCE per segment and feeds
  per-round events (plus :class:`Monitor` WARN events and static per-round
  bit accounting from :func:`repro.core.comm.comm_bits_per_round`) into
  pluggable sinks: :class:`JsonlSink` (one JSON object per line, manifest
  first), :class:`CsvSink`, :class:`StdoutSink`, :class:`MemorySink`.

Beyond scalars, the spec can request **distribution sketches**
(``Telemetry(sketches="auto")`` / the ``--telemetry hist:...`` grammar):
fixed-bin log-histograms, p50/p90/p99/max quantiles and top-k
outlier-client ids of the per-client ``||d_i||``, the drift
``||x_i - x_bar||``, the per-client compression error and the staleness
ages — vector-valued captures that ride the scan ys next to the scalars.
Sketches are computed in :meth:`Telemetry.finalize` from the post-round
state, so under cohort mode they read the FULL ``[N, ...]`` client store
in one O(N) pass (the scalars above see only the cohort) and are
identical between the gather and dense cohort lowerings. On a packed
parameter arena the norm+histogram reduction routes through the fused
Pallas kernel (``kernels/telemetry_reduce.py`` via
``kernels/ops.py:telemetry_sketch``). ``leaf_stats=True`` adds the
per-leaf msg-norm / compression-error breakdown (the bit-budget
allocator's future input) via the arena's row->leaf segment map,
drained as ``leaf_stats`` events.

At drain time :class:`RateMonitor` fits the **online linear-rate
estimator** rho_hat — a windowed least-squares slope of ``log(residual)``
vs round — annotating round events and emitting a ``rate_break`` WARN
(naming the scenario axis) when a series that was contracting stalls
above the numerical floor: the PR 3 (rr:2 + poly:1) and PR 5 (tier
shift:q8) error floors become live detections from one run's JSONL
alone (:func:`replay_jsonl`).

Telemetry disabled (``algo.telemetry is None``) must be a BITWISE no-op:
the engine guards every capture on the attached spec, so the disabled
round traces the exact same jaxpr as before this module existed —
tests/test_telemetry.py pins 0.0 divergence across the composed-scenario
matrix.

Profiling hooks live here too: :class:`TraceSession` brackets a
``--trace-rounds a:b`` window with ``jax.profiler`` trace capture, and
:func:`instruction_count` counts optimized-HLO instructions (reusing
``roofline/hlo_parse``'s computation splitter) so benchmarks can report
the instrumentation's compiled footprint.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
import subprocess
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# ------------------------------------------------------------------ the tape
#: stack of active trace-time collectors (nested collect()s shadow like
#: dynamic scope) and a mute depth counter. Trace-time only — never part of
#: traced state, so it adds no jaxpr inputs and costs nothing when empty.
_TAPES: list[dict] = []
_MUTE: int = 0


def collecting() -> bool:
    """True when a tape is active and not muted — the engine's guard for
    building capture ops at all (disabled telemetry traces zero extra ops)."""
    return bool(_TAPES) and _MUTE == 0


def capture(name: str, value) -> None:
    """Record a named scalar on the active tape (no-op without one).
    Repeated captures of the same name within a round keep the LAST value
    (e.g. ``grad_norm`` at the aggregating step, not a begin_round probe)."""
    if collecting():
        _TAPES[-1][name] = value


@contextlib.contextmanager
def collect():
    """Open a tape around a traced region; yields the dict of captured
    tracers (valid within the same trace — the caller folds them into its
    outputs before the trace ends)."""
    tape: dict = {}
    _TAPES.append(tape)
    try:
        yield tape
    finally:
        _TAPES.pop()


@contextlib.contextmanager
def muted():
    """Suppress captures while tracing an inner ``lax.scan`` body (whose
    tracers must not escape onto the round-level tape)."""
    global _MUTE
    _MUTE += 1
    try:
        yield
    finally:
        _MUTE -= 1


# ----------------------------------------------------------- metric helpers
def client_sq_norms(tree):
    """``[clients]`` squared L2 norms: per-client sum of squares over every
    leaf's non-leading axes (leaves carry a leading clients axis; an Arena
    leaf's zero pads contribute nothing, so packed == per-leaf)."""
    tot = None
    for a in jax.tree.leaves(tree):
        s = jnp.sum(jnp.square(a), axis=tuple(range(1, a.ndim)))
        tot = s if tot is None else tot + s
    return tot


def mean_client_norm(tree):
    """Mean over clients of the per-client L2 norm."""
    return jnp.mean(jnp.sqrt(client_sq_norms(tree)))


def _tree_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(a)) for a in jax.tree.leaves(tree)))


# ------------------------------------------------------ distribution sketches
#: the state-derived per-client distributions ``sketches="auto"`` tracks
#: (each is silently absent when its source state is — e.g. no ``age_*``
#: without a delay model, no ``compress_err_*`` without transforms).
SKETCH_SOURCES = ("d_norm", "drift", "compress_err", "age")


def log_histogram(vals, bins: int, lo: float, hi: float):
    """``[bins]`` int32 counts of ``vals`` (non-negative) over log10-spaced
    bins covering ``[10^lo, 10^hi)``; zeros and underflow clip into bin 0,
    overflow into the last bin. The binning expression is shared verbatim
    with ``kernels/ref.py:client_sketch`` and the Pallas
    ``telemetry_reduce`` kernel (their parity contract)."""
    logs = jnp.where(vals > 0, jnp.log10(vals), lo)
    idx = jnp.clip(jnp.floor((logs - lo) * (bins / (hi - lo))),
                   0, bins - 1).astype(jnp.int32)
    return jnp.zeros((bins,), jnp.int32).at[idx].add(1)


def _finish_sketch(name, vals, hist, spec, ids=None,
                   top=None) -> dict:
    """Quantiles + top-k around a per-client value vector whose histogram
    is already computed; ``ids`` maps local (cohort-slot) indices back to
    global client ids, ``top`` passes kernel-computed top-k through."""
    q = jnp.quantile(vals, jnp.asarray([0.5, 0.9, 0.99], vals.dtype))
    if top is None:
        top = jax.lax.top_k(vals, min(spec.topk, vals.shape[0]))
    tv, ti = top
    ti = ti.astype(jnp.int32)
    if ids is not None:
        ti = ids[ti]
    return {f"{name}_hist": hist,
            f"{name}_p50": q[0], f"{name}_p90": q[1], f"{name}_p99": q[2],
            f"{name}_max": jnp.max(vals),
            f"{name}_top_vals": tv, f"{name}_top_ids": ti}


def sketch_values(name, vals, spec, ids=None) -> dict:
    """Distribution sketch of a per-client ``[n]`` value vector: log-bin
    histogram, p50/p90/p99/max and the top-k outlier (value, client-id)
    pairs — all still traced (they ride the scan ys)."""
    if not jnp.issubdtype(vals.dtype, jnp.floating):
        vals = vals.astype(jnp.float32)
    hist = log_histogram(vals, spec.hist_bins, spec.hist_lo, spec.hist_hi)
    return _finish_sketch(name, vals, hist, spec, ids=ids)


def sketch_client_norms(name, tree, spec, ids=None) -> dict:
    """Sketch the per-client L2 norms of a ``[clients, ...]`` state tree.
    A packed-arena tree takes the fused one-pass Pallas norm+histogram
    reduction (``kernels/ops.py:telemetry_sketch`` — the Mosaic kernel on
    TPU, the same-math XLA expression elsewhere); any other pytree takes
    the generic ``client_sq_norms`` path. Both bin identically."""
    from repro.core.arena import Arena

    if isinstance(tree, Arena) and tree.data.ndim == 3:
        from repro.kernels import ops

        norms, hist, tv, ti = ops.telemetry_sketch(
            tree.data, bins=spec.hist_bins, lo=spec.hist_lo,
            hi=spec.hist_hi, k=min(spec.topk, tree.data.shape[0]))
        return _finish_sketch(name, norms, hist, spec, ids=ids,
                              top=(tv, ti))
    return sketch_values(name, jnp.sqrt(client_sq_norms(tree)), spec,
                         ids=ids)


def leaf_client_norms(tree):
    """``[n_leaves]`` mean-client L2 norm per MODEL leaf — the per-leaf
    breakdown of ``msg_norm`` / ``compress_err`` (``leaf_stats`` events;
    the input a per-leaf bit-budget allocator would consume). On an arena
    the reduction runs over the packed buffer through the static
    row->leaf segment map; on a plain pytree it is the per-leaf norm
    stack. Arena zero pads contribute nothing, so packed ~= per-leaf."""
    from repro.core.arena import Arena

    if isinstance(tree, Arena):
        seg = jnp.asarray(tree.layout.row_segments())
        n_leaves = len(tree.layout.shapes)
        row_sq = jnp.sum(jnp.square(tree.data), axis=-1)
        if row_sq.ndim == 1:
            row_sq = row_sq[None, :]
        per = jax.ops.segment_sum(row_sq.T, seg,
                                  num_segments=n_leaves)  # [leaves, clients]
        return jnp.mean(jnp.sqrt(per), axis=1)
    return jnp.stack([
        jnp.mean(jnp.sqrt(jnp.sum(jnp.square(a),
                                  axis=tuple(range(1, a.ndim)))))
        for a in jax.tree.leaves(tree)])


# ------------------------------------------------------------------ monitors
@dataclasses.dataclass(frozen=True)
class Monitor:
    """Declarative per-round alert: WARN when ``metric`` crosses ``bound``
    (``mode="max"``: value > bound; ``"min"``: value < bound). ``axis``
    names the scenario axis the violation implicates — the WARN event
    carries it so a drifting invariant points at its cause."""

    metric: str
    bound: float
    mode: str = "max"
    axis: str = ""

    def violated(self, value) -> bool:
        v = float(value)
        return v > self.bound if self.mode == "max" else v < self.bound


#: the PR 3 pinned boundary as a live check: FedCET's redistributive drift
#: updates keep sum_i d_i = 0 exactly (Lemma 2) under every exact scenario
#: (fixed:k delay included — uniform ages make poly discounting uniform);
#: non-uniform stale-policy weights (poly:a with rr/geom ages) and tier
#: recompression break the redistribution. The residual is RELATIVE
#: (||mean_i d_i|| / mean_i ||d_i||): exact scenarios sit at accumulation
#: noise (~1e-13 in f64), the pinned drift scenarios reach O(1e-2..1).
INVARIANT_MONITOR = Monitor(
    metric="invariant_residual", bound=1e-6, mode="max",
    axis="stale_policy (poly:a discounting with non-uniform ages) or "
         "tier_compression — non-uniform aggregation weights break the "
         "sum_i d_i = 0 redistribution (Lemma 2)")


# ------------------------------------------------------ linear-rate estimator
def fit_rate(rounds, values) -> float:
    """Windowed log-residual regression: the least-squares slope of
    ``ln(value)`` against round index, returned as the per-round
    contraction factor ``rho_hat = exp(slope)`` — the paper's linear rate
    as a measured number (``rho_hat < 1``: still converging linearly;
    ``>= 1``: stalled or diverging)."""
    r = np.asarray(rounds, dtype=float)
    v = np.log(np.asarray(values, dtype=float))
    r = r - r.mean()
    denom = float(np.sum(r * r)) or 1.0
    return float(math.exp(float(np.sum(r * (v - v.mean()))) / denom))


def rate_axis(algo) -> str:
    """The scenario axes attached to ``algo`` that can break the paper's
    linear rate — what a :class:`RateMonitor` WARN names as the suspects
    (mirroring the measured boundaries: PR 3 stale-policy discounting,
    PR 5 tier recompression, biased compression)."""
    parts = []
    delay = getattr(algo, "delay", None)
    if delay is not None:
        parts.append("stale_policy (poly:a discounting under non-uniform "
                     "delay ages floors FedCET — the PR 3 boundary)")
    topo = getattr(algo, "topology", None)
    if topo is not None and getattr(topo, "tier_compression", None) is not None:
        parts.append("tier_compression (interior-hop recompression lacks "
                     "wire-consistency — the PR 5 freeze)")
    if getattr(algo, "transforms", ()):
        parts.append("compression (a biased compressor without error "
                     "feedback keeps an error floor)")
    return " or ".join(parts) or "no lossy axis attached"


@dataclasses.dataclass
class RateMonitor:
    """Online linear-rate estimator + rate-break alert, evaluated at drain
    time over the streamed round events (stateful across a run's drain
    segments — :func:`resolve_monitors` builds a fresh one per run).

    Each round it appends ``(round, metric)`` and fits
    :func:`fit_rate` over the trailing ``window`` points, annotating the
    round event with ``rho_hat``. A **rate break** fires when a series
    that had established linear convergence (best windowed estimate
    ``<= ref_rho``) stalls (``rho_hat >= stall_rho``) while still far
    above the numerical floor (``value > floor`` — so the healthy f64
    noise plateau of an exact run never alerts). The WARN event carries
    ``kind="rate_break"`` and ``axis`` — the scenario axes under
    suspicion (:func:`rate_axis`).

    ``metric`` defaults to ``"err"`` — a residual-type series the caller
    merges into the drained round events (``simulate_quadratic``'s
    distance-to-optimum; anything that decays to ZERO under exact
    scenarios). Non-residual series (e.g. an LM loss with a nonzero
    irreducible floor) would false-alarm at convergence; rounds without
    the metric are simply skipped, so attaching the monitor to a run
    that never emits it is harmless."""

    metric: str = "err"
    window: int = 12
    stall_rho: float = 0.99
    ref_rho: float = 0.97
    floor: float = 1e-10
    cooldown: int = 10
    axis: str = ""

    def __post_init__(self):
        self._rounds: list[int] = []
        self._values: list[float] = []
        self._best: float | None = None
        self._last_warn: int | None = None

    def observe(self, ev: dict) -> dict | None:
        """Feed one round event (annotates it with ``rho_hat`` in place);
        returns the rate-break WARN event when one fires, else None."""
        v = ev.get(self.metric)
        if not isinstance(v, (int, float)) or isinstance(v, bool) or v <= 0:
            return None
        r = int(ev.get("round", len(self._rounds)))
        self._rounds.append(r)
        self._values.append(float(v))
        if len(self._rounds) < self.window:
            return None
        rho = fit_rate(self._rounds[-self.window:],
                       self._values[-self.window:])
        ev["rho_hat"] = rho
        self._best = rho if self._best is None else min(self._best, rho)
        if (rho >= self.stall_rho and self._best <= self.ref_rho
                and v > self.floor
                and (self._last_warn is None
                     or r - self._last_warn >= self.cooldown)):
            self._last_warn = r
            return {"event": "monitor", "kind": "rate_break",
                    "level": "WARN", "metric": self.metric, "round": r,
                    "value": float(v), "rho_hat": rho,
                    "rho_ref": self._best, "axis": self.axis}
        return None


def replay_jsonl(path: str, monitors) -> list[dict]:
    """Re-run a monitor set over a finished run's JSONL file ALONE — no
    re-simulation: stream its round events through threshold
    :class:`Monitor` checks and :class:`RateMonitor` observers exactly as
    a live drain would, returning the WARN events. This is how the
    pinned scenario boundaries are reproduced post hoc from one run's
    log (benchmarks/telemetry_bench.py, benchmarks/report.py)."""
    warns: list[dict] = []
    with open(path) as f:
        for line in f:
            ev = json.loads(line)
            if ev.get("event") != "round":
                continue
            for m in monitors:
                if hasattr(m, "observe"):
                    w = m.observe(ev)
                    if w:
                        warns.append(w)
                    continue
                v = ev.get(m.metric)
                if (isinstance(v, (int, float))
                        and not isinstance(v, bool) and m.violated(v)):
                    warns.append({"event": "monitor", "level": "WARN",
                                  "metric": m.metric, "round": ev["round"],
                                  "value": v, "bound": m.bound,
                                  "mode": m.mode, "axis": m.axis})
    return warns


# ------------------------------------------------------------- the spec
@dataclasses.dataclass(frozen=True)
class Telemetry:
    """The telemetry spec attached to an engine algorithm
    (``with_telemetry`` / ``FedScenario(telemetry=...)``). Hashable and
    stateless — it adds NO algorithm state (checkpoints are unaffected)
    and selects which metrics the runner stacks and which monitors the
    drain evaluates.

    ``metrics="auto"`` keeps everything captured plus the state-derived
    series; a tuple restricts to those names (unavailable names are
    silently absent — e.g. no ``age_*`` without a delay model).
    ``monitors="auto"`` evaluates :data:`INVARIANT_MONITOR` on algorithms
    that expose the drift state (plus a :class:`RateMonitor` when
    :func:`resolve_monitors` is given the algorithm); a tuple of
    :class:`Monitor` overrides.

    ``sketches`` turns on the population-scale distribution sketches:
    ``False`` (default — scalar telemetry only, the pre-sketch stream),
    ``"auto"`` / ``True`` (every source in :data:`SKETCH_SOURCES` whose
    state exists) or a tuple of source names. Each source ``s`` adds
    ``s_hist`` (``[hist_bins]`` int32 log-histogram over
    ``[10^hist_lo, 10^hist_hi)``), ``s_p50``/``s_p90``/``s_p99``/
    ``s_max`` and the ``[topk]`` outlier pairs ``s_top_vals`` /
    ``s_top_ids`` (GLOBAL client ids, also under cohort mode).
    ``leaf_stats=True`` adds the per-leaf ``leaf_msg_norm`` /
    ``leaf_compress_err`` vectors (drained as ``leaf_stats`` events)."""

    metrics: tuple | str = "auto"
    monitors: tuple | str = "auto"
    sketches: tuple | str | bool = False
    hist_bins: int = 48
    hist_lo: float = -12.0
    hist_hi: float = 4.0
    topk: int = 4
    leaf_stats: bool = False

    def wants_sketch(self, name: str) -> bool:
        """Whether the spec sketches source ``name`` — the engine's guard
        for building the per-client capture ops at all."""
        if not self.sketches:
            return False
        if self.sketches is True or self.sketches == "auto":
            return True
        return name in self.sketches

    def finalize(self, tape: dict, algo, state) -> dict:
        """Tape + post-round state -> the round's metric dict (still
        traced values; becomes the scan's stacked ys). Sketches read the
        post-round state, which is the FULL ``[N, ...]`` client store in
        both cohort lowerings — the one O(N) pass per round."""
        out = dict(tape)
        # raw per-client seam captures feed sketches only — never emitted.
        cohort_ids = out.pop("cohort_ids", None)
        err_clients = out.pop("compress_err_clients", None)
        inner = algo._inner(state)
        d = getattr(inner, "d", None)
        if d is not None:
            num = _tree_norm(jax.tree.map(lambda a: jnp.mean(a, axis=0), d))
            den = mean_client_norm(d)
            out["invariant_residual"] = num / jnp.maximum(
                den, jnp.asarray(1e-30, den.dtype))
        x = getattr(inner, "x", None)
        if x is None:
            x = getattr(inner, "x_curr", None)
        dev = None
        if x is not None:
            dev = jax.tree.map(
                lambda a: a - jnp.mean(a, axis=0, keepdims=True), x)
            out["consensus_err"] = jnp.sqrt(jnp.max(client_sq_norms(dev)))
        if self.sketches:
            if d is not None and self.wants_sketch("d_norm"):
                out.update(sketch_client_norms("d_norm", d, self))
            if dev is not None and self.wants_sketch("drift"):
                out.update(sketch_client_norms("drift", dev, self))
            if err_clients is not None and self.wants_sketch("compress_err"):
                out.update(sketch_values("compress_err", err_clients, self,
                                         ids=cohort_ids))
            if self.wants_sketch("age"):
                split = getattr(algo, "_split", None)
                dstate = split(state)[3] if split is not None else None
                if dstate is not None:
                    out.update(sketch_values(
                        "age", dstate.age.astype(jnp.float32), self))
        if self.metrics != "auto":
            out = {k: out[k] for k in self.metrics if k in out}
        return out


#: spec-string parts that configure the SPEC rather than name a sink —
#: ``parse_telemetry`` consumes them, ``parse_sinks`` skips them, so one
#: ``--telemetry`` string drives both (``"jsonl:run.jsonl,hist:48"``).
_SPEC_PART_KINDS = ("hist", "topk", "leafstats", "leaf_stats")


def _spec_overrides(spec: str) -> dict:
    """Telemetry-field overrides encoded in a sink-spec string:
    ``hist[:bins[:lo:hi]]`` (log10 bin range) and ``topk[:k]`` turn the
    distribution sketches on, ``leafstats`` the per-leaf breakdown."""
    ov: dict = {}
    for part in spec.split(","):
        kind, _, arg = part.strip().partition(":")
        kind = kind.lower()
        if kind == "hist":
            ov["sketches"] = "auto"
            sub = [s for s in arg.split(":") if s]
            if sub:
                ov["hist_bins"] = int(sub[0])
            if len(sub) >= 3:
                ov["hist_lo"], ov["hist_hi"] = float(sub[1]), float(sub[2])
        elif kind == "topk":
            ov["sketches"] = "auto"
            if arg:
                ov["topk"] = int(arg)
        elif kind in ("leafstats", "leaf_stats"):
            ov["leaf_stats"] = True
    return ov


def parse_telemetry(spec) -> Telemetry | None:
    """Normalize a telemetry knob: ``None`` / ``False`` / ``"none"`` /
    ``"off"`` / ``""`` -> None (disabled — the factory returns the
    algorithm unchanged); a :class:`Telemetry` passes through; any other
    truthy value (``True``, a sink spec string) -> the default spec, with
    ``hist``/``topk``/``leafstats`` parts of a spec string turning the
    distribution sketches on (see :func:`_spec_overrides`)."""
    if spec is None or spec is False:
        return None
    if isinstance(spec, Telemetry):
        return spec
    if isinstance(spec, str):
        if spec.strip().lower() in ("", "none", "off", "0", "false"):
            return None
        return Telemetry(**_spec_overrides(spec))
    return Telemetry()


def resolve_monitors(telemetry: Telemetry | None, algo=None) -> tuple:
    """The drain-time monitor set for a spec: explicit tuples pass
    through; ``"auto"`` is the invariant monitor plus — when the
    algorithm is given, so the WARN can name its attached lossy axes —
    a fresh (stateful) :class:`RateMonitor` on the residual series."""
    if telemetry is None:
        return ()
    if telemetry.monitors == "auto":
        if algo is None:
            return (INVARIANT_MONITOR,)
        return (INVARIANT_MONITOR, RateMonitor(axis=rate_axis(algo)))
    return tuple(telemetry.monitors)


def split_metrics(algo, ys):
    """Split a round runner's stacked ys into ``(metrics, telemetry)`` —
    the runner nests them only when the algorithm has telemetry attached,
    so un-instrumented callers see the exact pre-telemetry structure."""
    if getattr(algo, "telemetry", None) is None or ys is None:
        return ys, None
    return ys["metric"], ys["telemetry"]


# --------------------------------------------------------------------- sinks
def _scalar(v):
    a = np.asarray(v)
    if a.dtype.kind == "b":
        return bool(a)
    if a.dtype.kind in "iu":
        return int(a)
    return float(a)


def _jsonable(v):
    """Host value -> JSON-serializable event value: native scalar, or a
    list for the 1-D sketch vectors (histogram bins, top-k ids)."""
    a = np.asarray(v)
    if a.ndim == 0:
        return _scalar(a)
    if a.ndim == 1:
        return [_scalar(x) for x in a]
    raise ValueError("telemetry events carry scalars or 1-D vectors, got "
                     f"shape {a.shape}")


class MemorySink:
    """Collects events in a list (tests / programmatic consumers)."""

    def __init__(self):
        self.events: list[dict] = []

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON object per line; the run manifest is the first event."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")

    def emit(self, event: dict) -> None:
        self._f.write(json.dumps(event) + "\n")
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class CsvSink:
    """Round events as CSV; columns fixed by the first round event
    (non-round events are skipped — JSONL is the full stream).

    Vector-valued metrics (the distribution sketches: ``*_hist`` bins,
    ``*_top_ids``/``*_top_vals``) are flattened into stable indexed
    columns ``name.0 .. name.{k-1}`` — the column set stays fixed because
    sketch shapes are static (``hist_bins``/``topk`` are spec fields).
    Anything deeper than 1-D is rejected with a pointer at the JSONL
    sink, never silently stringified into an unparseable cell."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")
        self._keys: list[str] | None = None

    @staticmethod
    def _flatten(event: dict) -> dict:
        flat = {}
        for k, v in event.items():
            if k == "event":
                continue
            if isinstance(v, (list, tuple)):
                if any(isinstance(x, (list, tuple)) for x in v):
                    raise ValueError(
                        f"CsvSink cannot flatten nested vector metric {k!r}"
                        " — route this stream to a jsonl:<path> sink")
                for i, x in enumerate(v):
                    flat[f"{k}.{i}"] = x
            else:
                flat[k] = v
        return flat

    def emit(self, event: dict) -> None:
        if event.get("event") != "round":
            return
        flat = self._flatten(event)
        if self._keys is None:
            self._keys = list(flat)
            self._f.write(",".join(self._keys) + "\n")
        self._f.write(",".join(str(flat.get(k, "")) for k in self._keys)
                      + "\n")

    def close(self) -> None:
        self._f.close()


class StdoutSink:
    """Human-readable summary lines; round lines gated by ``every``."""

    def __init__(self, every: int = 1):
        self.every = max(int(every), 1)

    @staticmethod
    def _fmt(v):
        return f"{v:.6g}" if isinstance(v, float) else str(v)

    def emit(self, event: dict) -> None:
        kind = event.get("event")
        if kind == "round":
            if event.get("round", 0) % self.every:
                return
            # sketch vectors stay in jsonl/csv — a 48-bin histogram per
            # line would drown the summary.
            body = "  ".join(f"{k}={self._fmt(v)}" for k, v in event.items()
                             if k not in ("event", "round")
                             and not isinstance(v, (list, tuple)))
            print(f"[telemetry] round {event.get('round', 0):5d}  {body}")
        elif kind == "monitor" and event.get("kind") == "rate_break":
            print(f"[telemetry] WARN round {event.get('round')}: rate break "
                  f"on {event.get('metric')} — rho_hat="
                  f"{self._fmt(event.get('rho_hat'))} after established "
                  f"{self._fmt(event.get('rho_ref'))} at value "
                  f"{self._fmt(event.get('value'))}  "
                  f"(axis: {event.get('axis', '')})")
        elif kind == "monitor":
            print(f"[telemetry] WARN round {event.get('round')}: "
                  f"{event.get('metric')}={self._fmt(event.get('value'))} "
                  f"{'>' if event.get('mode', 'max') == 'max' else '<'} "
                  f"{event.get('bound')}  (axis: {event.get('axis', '')})")
        elif kind == "manifest":
            print(f"[telemetry] run algo={event.get('algo')} "
                  f"n_clients={event.get('n_clients')} tau={event.get('tau')} "
                  f"commit={event.get('commit')}")
        elif kind == "profile":
            print(f"[telemetry] profiler {event.get('action')} at round "
                  f"{event.get('round')} -> {event.get('dir')}")

    def close(self) -> None:
        pass


def parse_sinks(spec) -> list:
    """Sink spec grammar (the ``--telemetry`` CLI knob): comma-separated
    ``jsonl:<path>`` | ``csv:<path>`` | ``stdout[:every]`` | ``memory``.
    Spec-configuring parts (``hist``/``topk``/``leafstats`` — consumed by
    :func:`parse_telemetry`) are skipped so one string drives both.
    Sink objects / lists pass through; None -> []."""
    if spec is None or spec is True:
        return []
    if not isinstance(spec, str):
        return list(spec) if isinstance(spec, (list, tuple)) else [spec]
    sinks = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        kind, _, arg = part.partition(":")
        kind = kind.lower()
        if kind in _SPEC_PART_KINDS:
            continue
        if kind == "jsonl":
            sinks.append(JsonlSink(arg or "telemetry.jsonl"))
        elif kind == "csv":
            sinks.append(CsvSink(arg or "telemetry.csv"))
        elif kind == "stdout":
            sinks.append(StdoutSink(every=int(arg) if arg else 1))
        elif kind in ("memory", "mem"):
            sinks.append(MemorySink())
        else:
            raise ValueError(f"unknown telemetry sink {part!r} "
                             "(jsonl:<path> | csv:<path> | stdout[:k] | "
                             "memory)")
    return sinks


def emit_event(sinks, event: dict) -> None:
    for s in sinks:
        s.emit(event)


def close_sinks(sinks) -> None:
    for s in sinks:
        s.close()


# ----------------------------------------------------------- manifest/drain
def _git_commit() -> str | None:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], capture_output=True,
            text=True, cwd=os.path.dirname(os.path.abspath(__file__)))
        return out.stdout.strip() or None
    except OSError:
        return None


def run_manifest(algo, *, n_params: int | None = None, config: dict | None = None,
                 monitors: tuple = (), extra: dict | None = None,
                 leaf_info=None) -> dict:
    """The run's first event: what ran, where, and what one round costs on
    the wire (the ``comm_hops_per_round`` per-hop contract + totals).
    ``leaf_info`` (``repro.core.comm.leaf_info_of``) upgrades billing to
    exact per-leaf wire bits and records the per-leaf budget breakdown
    (``leaf_names`` / ``leaf_bits``) for report.py's budget-vs-leaf
    view."""
    tel = getattr(algo, "telemetry", None)
    ev = {
        "event": "manifest", "schema": 1,
        "algo": getattr(algo, "name", type(algo).__name__),
        "n_clients": getattr(algo, "n_clients", None),
        "tau": getattr(algo, "tau", None),
        "commit": _git_commit(),
        "mesh": {"backend": jax.default_backend(),
                 "n_devices": jax.device_count()},
        "metrics": (list(tel.metrics)
                    if tel is not None and tel.metrics != "auto" else "auto"),
        "monitors": [dataclasses.asdict(m) for m in monitors],
        "config": dict(config or {}),
    }
    if n_params:
        from repro.core.comm import (comm_bits_per_round,
                                     comm_hops_per_round,
                                     message_leaf_bits_of)

        nc = getattr(algo, "n_clients", 1)
        ev["bits_per_round"] = comm_bits_per_round(algo, n_params, nc,
                                                   leaf_info)
        ev["hops"] = comm_hops_per_round(algo, n_params, nc, leaf_info)
        if leaf_info is not None:
            lb = message_leaf_bits_of(algo, leaf_info)
            if lb is not None:
                ev["leaf_names"] = [nm for nm, _ in leaf_info]
                ev["leaf_sizes"] = [int(n) for _, n in leaf_info]
                ev["leaf_bits"] = [float(b) for b in lb]
    if extra:
        ev.update(extra)
    return ev


def drain(series: dict | None, *, sinks=(), monitors=(), start_round: int = 0,
          static: dict | None = None, algo=None,
          n_params: int | None = None, leaf_names=None,
          leaf_bits=None) -> list:
    """Device-get the stacked per-round telemetry pytree ONCE and emit one
    ``round`` event per round into the sinks, evaluating ``monitors``
    against each (violations emit a structured WARN event right after
    their round). ``static`` merges constant per-round fields; passing
    ``algo``/``n_params`` derives the bit-true ``bits_up``/``bits_down``
    per round from the comm accounting. Returns the emitted events.

    Vector-valued series (the distribution sketches) land in the round
    event as JSON lists; ``leaf_*`` series split off into a per-round
    ``leaf_stats`` event (``leaf_names`` labels its entries — and
    ``leaf_bits``, the exact per-leaf wire bits from the comm accounting,
    rides along as ``bits`` — on the first round of the segment). Observer monitors (:class:`RateMonitor` —
    anything with ``.observe``) see and annotate each round event BEFORE
    it is emitted, so ``rho_hat`` rides the stream; threshold
    :class:`Monitor` checks skip vector values."""
    events: list[dict] = []
    if not series:
        return events
    host = {k: np.asarray(jax.device_get(v)) for k, v in series.items()}
    n = len(next(iter(host.values())))
    stat = dict(static or {})
    if algo is not None and n_params:
        from repro.core.comm import comm_bits_per_round

        bits = comm_bits_per_round(algo, n_params,
                                   getattr(algo, "n_clients", 1))
        stat.setdefault("bits_up", bits["up_bits"])
        stat.setdefault("bits_down", bits["down_bits"])
    leaf_keys = [k for k in host if k.startswith("leaf_")]
    observers = [m for m in monitors if hasattr(m, "observe")]
    checks = [m for m in monitors if not hasattr(m, "observe")]
    for i in range(n):
        ev = {"event": "round", "round": int(start_round + i)}
        for k, v in host.items():
            if k in leaf_keys:
                continue
            ev[k] = _jsonable(v[i])
        ev.update(stat)
        rate_warns = [w for w in (m.observe(ev) for m in observers) if w]
        events.append(ev)
        emit_event(sinks, ev)
        if leaf_keys:
            lev = {"event": "leaf_stats", "round": ev["round"]}
            if leaf_names is not None and i == 0:
                lev["names"] = list(leaf_names)
            if leaf_bits is not None and i == 0:
                lev["bits"] = [float(b) for b in leaf_bits]
            for k in leaf_keys:
                lev[k[len("leaf_"):]] = _jsonable(host[k][i])
            events.append(lev)
            emit_event(sinks, lev)
        for m in checks:
            v = ev.get(m.metric)
            if (isinstance(v, (int, float)) and not isinstance(v, bool)
                    and m.violated(v)):
                warn = {"event": "monitor", "level": "WARN",
                        "metric": m.metric, "round": ev["round"],
                        "value": v, "bound": m.bound,
                        "mode": m.mode, "axis": m.axis}
                events.append(warn)
                emit_event(sinks, warn)
        for w in rate_warns:
            events.append(w)
            emit_event(sinks, w)
    return events


def write_csv_rows(path: str, rows: list[dict]) -> None:
    """The trainer's CSV contract, verbatim (``FedTrainer._write_csv``
    routes through this so the bytes stay identical): header from the
    first row's keys, ``str()``-formatted values."""
    if not rows:
        return
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    keys = list(rows[0])
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for row in rows:
            f.write(",".join(str(row[k]) for k in keys) + "\n")


# ----------------------------------------------------------------- profiling
def parse_trace_rounds(spec) -> tuple[int, int] | None:
    """``"a:b"`` -> the half-open round window [a, b) to trace; ``"a"``
    traces the single round a. None/empty -> no tracing."""
    if spec is None or spec == "":
        return None
    if isinstance(spec, tuple):
        lo, hi = spec
    else:
        a, _, b = str(spec).partition(":")
        lo = int(a)
        hi = int(b) if b else lo + 1
    if hi <= lo or lo < 0:
        raise ValueError(f"bad --trace-rounds window {spec!r} (want a:b "
                         "with 0 <= a < b)")
    return lo, hi


@dataclasses.dataclass
class TraceSession:
    """Brackets a ``--trace-rounds a:b`` window with ``jax.profiler``
    trace capture. The caller forces scan-segment boundaries at the
    window edges (:meth:`boundaries`) and calls :meth:`maybe_start` before
    / :meth:`maybe_stop` after each segment; both return a ``profile``
    event for the sinks when they act."""

    window: tuple[int, int] | None
    out_dir: str = "profile_trace"
    active: bool = False

    def boundaries(self) -> tuple:
        """Round indices that must END a scan segment so the traced
        segment starts/stops exactly at the window edges."""
        if self.window is None:
            return ()
        return tuple(b for b in (self.window[0] - 1, self.window[1] - 1)
                     if b >= 0)

    def maybe_start(self, first_round: int) -> dict | None:
        if (self.window is None or self.active
                or not (self.window[0] <= first_round < self.window[1])):
            return None
        jax.profiler.start_trace(self.out_dir)
        self.active = True
        return {"event": "profile", "action": "start_trace",
                "round": first_round, "dir": self.out_dir}

    def maybe_stop(self, next_round: int) -> dict | None:
        if not self.active or next_round < self.window[1]:
            return None
        jax.profiler.stop_trace()
        self.active = False
        return {"event": "profile", "action": "stop_trace",
                "round": next_round, "dir": self.out_dir}

    def close(self) -> None:
        if self.active:
            jax.profiler.stop_trace()
            self.active = False


def instruction_count(lowered_or_text) -> int:
    """Instruction count of an optimized HLO module (a ``jit(...).lower()``
    result or its compiled text), via ``roofline/hlo_parse``'s computation
    splitter — one count per "name = op(...)" line across all
    computations. Benchmarks use it to report telemetry's compiled
    footprint next to its wall-clock cost."""
    txt = lowered_or_text
    if not isinstance(txt, str):
        txt = lowered_or_text.compile().as_text()
    from repro.roofline.hlo_parse import _split_computations

    return sum(1 for lines in _split_computations(txt).values()
               for ln in lines if " = " in ln)
