"""Algorithm 1 — learning-rate search for FedCET.

Implemented verbatim from the paper, plus a validated variant that searches
directly against the convergence inequalities (16) of Remark 1 and reports
the resulting contraction factors (rho_1, rho_2) of Corollary 1.
"""

from __future__ import annotations

import dataclasses
import math


def _growth(tau: int) -> float:
    """(1 + 2/tau)^(2 tau - 2) — the local-drift amplification constant."""
    return (1.0 + 2.0 / tau) ** (2 * tau - 2)


def alpha0_upper_bound(mu: float, L: float, tau: int) -> float:
    """Initial learning-rate bound from Algorithm 1 / Remark 1:

    alpha_0 < min{ 1/(2 tau L),
                   mu^2 / (2 tau (1+2/tau)^(2tau-2) L^3),
                   mu  / (5 tau (1+2/tau)^(2tau-2) L^2) }.
    """
    g = _growth(tau)
    return min(
        1.0 / (2.0 * tau * L),
        mu**2 / (2.0 * tau * g * L**3),
        mu / (5.0 * tau * g * L**2),
    )


def _alg1_predicates(alpha: float, mu: float, L: float, tau: int) -> tuple[float, float]:
    """The two while-loop expressions of Algorithm 1 (search continues while
    both are > 0)."""
    g = _growth(tau)
    p1 = 1.0 - tau * mu * alpha + tau * L**2 * (tau * alpha - 2.0 / mu) * g * alpha
    p2 = (1.0 - tau * L * alpha) * tau * mu * alpha \
        + tau**3 * L**4 * (tau * alpha - 2.0 / mu) * g * alpha**3
    return p1, p2


def lr_search(mu: float, L: float, tau: int, *, h_frac: float = 1e-3,
              alpha0_frac: float = 0.999) -> float:
    """Algorithm 1, exactly as printed.

    ``h = h_frac * alpha_0`` (the paper's experiments use h = 0.001 alpha_0).
    Starts from ``alpha_0 = alpha0_frac * upper_bound`` (any value strictly
    below the bound is admissible) and grows alpha by h while both predicates
    hold, returning the last alpha that satisfied them.
    """
    if not (0 < mu <= L):
        raise ValueError(f"need 0 < mu <= L, got mu={mu}, L={L}")
    if tau < 1:
        raise ValueError(f"tau must be a positive integer, got {tau}")
    alpha0 = alpha0_frac * alpha0_upper_bound(mu, L, tau)
    h = h_frac * alpha0
    alpha = alpha0
    # Termination is guaranteed: at alpha = 2/(tau L) the predicates fail
    # (Corollary 1, part (ii)), so the loop runs at most O(1/h_frac) steps.
    max_iters = int(math.ceil((2.0 / (tau * L) - alpha0) / h)) + 2
    for _ in range(max_iters):
        p1, p2 = _alg1_predicates(alpha, mu, L, tau)
        if not (p1 > 0.0 and p2 > 0.0):
            break
        alpha += h
    return alpha - h


def remark1_inequalities(alpha: float, mu: float, L: float, tau: int) -> tuple[float, float]:
    """LHS - RHS of the two inequalities in (16); both must be > 0."""
    g = _growth(tau)
    lhs = 1.0 - tau * mu * alpha
    rhs1 = (
        1.0
        + L * mu * tau**2 * alpha**2
        + (2.0 * tau**3 / mu) * g * L**4 * alpha**3
        - 2.0 * tau * mu * alpha
        - tau**4 * g * L**4 * alpha**4
    )
    rhs2 = (2.0 / (tau * mu * alpha) - 1.0) * tau**2 * g * L**2 * alpha**2
    return lhs - rhs1, lhs - rhs2


@dataclasses.dataclass(frozen=True)
class ContractionFactors:
    alpha: float
    c: float
    rho1: float
    rho2: float

    @property
    def rho(self) -> float:
        return max(self.rho1, self.rho2)

    @property
    def converges(self) -> bool:
        return 0.0 < self.rho < 1.0


def contraction_factors(alpha: float, mu: float, L: float, tau: int,
                        n_clients: int) -> ContractionFactors:
    """rho_1, rho_2 from the proof of Corollary 1.

    M = c^{-1} (I - 11^T/N)^\\dagger - alpha I restricted to range(I - 11^T/N)
    has lambda_max(M) = 1/c - alpha (the pseudo-inverse of the centering
    projector is itself, eigenvalue 1 on that range).
    """
    g = _growth(tau)
    b2 = tau**2 * g
    c = mu / (2.0 * mu * alpha + 8.0)
    tma = tau * mu * alpha
    rho1 = (1.0 - (2.0 - tau * alpha * L) * tma
            + (2.0 / tma - 1.0) * b2 * tau**2 * alpha**4 * L**4) / (1.0 - tma)
    lam = 1.0 / c - alpha
    rho2 = (lam + (2.0 / tma - 1.0) * b2 * alpha**2 * L**2 * tau * alpha) / (
        lam + (1.0 - tma) * tau * alpha)
    return ContractionFactors(alpha=alpha, c=c, rho1=rho1, rho2=rho2)


def lr_search_validated(mu: float, L: float, tau: int, *, h_frac: float = 1e-3,
                        alpha0_frac: float = 0.999) -> float:
    """Variant searching directly against (16): returns the largest alpha on
    the search grid for which BOTH Remark-1 inequalities hold strictly."""
    alpha0 = alpha0_frac * alpha0_upper_bound(mu, L, tau)
    h = h_frac * alpha0
    alpha = alpha0
    max_iters = int(math.ceil((2.0 / (tau * L) - alpha0) / h)) + 2
    for _ in range(max_iters):
        d1, d2 = remark1_inequalities(alpha, mu, L, tau)
        if not (d1 > 0.0 and d2 > 0.0):
            break
        alpha += h
    return alpha - h
