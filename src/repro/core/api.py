"""Federated-algorithm API: the consumer-facing protocol.

Every algorithm in this framework (FedCET and the baselines it is compared
against in the paper: FedAvg, SCAFFOLD, FedTrack, FedLin) presents the same
functional interface so drivers, benchmarks and the distributed launcher can
swap them via config:

* state is a *stacked* pytree — every per-client leaf has a leading
  ``clients`` axis (plus a scalar step counter ``t``);
* ``init(grad_fn, x0, init_batch)`` builds per-client state from a single
  set of initial parameters (replicated, then algorithm-specific warm-up);
* ``round(grad_fn, state, batches)`` runs one *communication round*:
  ``tau`` local gradient steps plus exactly one aggregation. ``batches`` is a
  pytree whose leaves have leading axes ``[tau, clients, ...]`` (full-batch
  callers simply broadcast the same batch ``tau`` times);
* communication cost is exposed *declaratively* via ``vectors_up`` /
  ``vectors_down`` (number of n-dimensional vectors moved per client per
  round) and the transform-aware ``up_frac``, so the benchmark harness can
  account bytes without tracing.

Algorithms do NOT hand-roll ``init``/``round``: they are slim specs —
``init_warmup`` / ``local_step`` / ``message`` / ``server_aggregate`` (and
optionally ``begin_round``) — on top of :class:`repro.core.engine.RoundEngine`,
which owns the round structure once: batch slicing, the ``vmap_grads`` lift,
the ``lax.scan`` over the tau-1 local steps, the single aggregating step,
message transforms (``with_compression``), client sampling
(``with_participation``), delayed uplinks (``with_delay``) and the
aggregation geometry (``with_topology`` — hierarchical tiers / gossip
mixing). See engine.py's module docstring and ARCHITECTURE.md for the
decomposition and the transform-composition rules.
Multi-round execution likewise goes through one shared scan-based driver,
``engine.run_rounds``, consumed by ``core/simulate.py``, ``fed/trainer.py``
and ``launch/train.py`` alike.

``grad_fn(params, batch) -> grads`` takes a SINGLE client's parameters; the
engine vmaps it over the client axis. Under ``pjit`` the vmapped axis is
sharded over the client mesh axes, and the aggregation's ``tree_client_mean``
lowers to the only collective that crosses the pod boundary.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Protocol, runtime_checkable

import jax

GradFn = Callable[[Any, Any], Any]  # (params, batch) -> grads, single client
AlgState = Any


@runtime_checkable
class FederatedAlgorithm(Protocol):
    """Structural interface shared by FedCET and all baselines."""

    name: str
    tau: int
    #: n-dimensional vectors transmitted per client per round (client->server).
    vectors_up: int
    #: n-dimensional vectors transmitted per client per round (server->client).
    vectors_down: int

    def init(self, grad_fn: GradFn, x0, init_batch) -> AlgState: ...

    def round(self, grad_fn: GradFn, state: AlgState, batches) -> AlgState: ...

    def global_params(self, state: AlgState): ...


def vmap_grads(grad_fn: GradFn, spmd_axis_name=None) -> GradFn:
    """Lift a single-client grad_fn to stacked [clients, ...] pytrees.

    ``spmd_axis_name`` (the mesh axes carrying the client dimension, e.g.
    ("pod", "data")) lets GSPMD pin the vmapped axis for every sharding
    decision inside the per-client computation — used by the production
    launcher; simulation callers leave it None."""
    return jax.vmap(grad_fn, in_axes=(0, 0), spmd_axis_name=spmd_axis_name)


def replicate(x0, n_clients: int):
    """Stack a single parameter pytree into [n_clients, ...]."""
    import jax.numpy as jnp

    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_clients,) + a.shape), x0)


def comm_bytes_per_round(algo: FederatedAlgorithm, n_params: int,
                         itemsize: int = 4, n_clients: int = 1) -> dict:
    """Bytes moved per communication round (Remark 2 accounting)."""
    up = algo.vectors_up * n_params * itemsize * n_clients
    down = algo.vectors_down * n_params * itemsize * n_clients
    return {"up": up, "down": down, "total": up + down}


@dataclasses.dataclass(frozen=True)
class RoundMetrics:
    """Optional per-round diagnostics emitted by drivers."""

    round_index: int
    error_to_opt: float | None = None
    grad_norm: float | None = None
    bytes_up: int = 0
    bytes_down: int = 0
