"""FedCET — the paper's contribution (Algorithm 2), as engine specs.

Two equivalent implementations are provided, both thin
:class:`repro.core.engine.RoundEngine` specs (the engine owns the round
structure — local scan, message transforms, aggregation):

* :class:`FedCET` — the production form, using the ``(d, x)`` recursion of
  Lemma 1. It carries TWO persistent model-sized states per client
  (``x`` and the drift variable ``d``) plus one transient gradient:

      v      = x - alpha * grad - alpha * d        # transmitted at comm rounds
      d_next = d + c * (v - mean_clients(v))       # comm round only
      x_next = v - c * alpha * (v - mean_clients(v))   (comm) / v (local)

  ``d`` converges to ``-grad_i(x*)`` — it absorbs exactly the gradient
  heterogeneity that makes FedAvg drift — yet is never transmitted. Only the
  single vector ``v`` crosses the network, which is the paper's headline:
  half the communication of SCAFFOLD / FedTrack / FedLin. Under message
  compression the drift update uses the client's own compressed message
  (``msg`` in ``server_aggregate``) so ``sum_i d_i = 0`` is preserved
  (Lemma 2), while the x-update corrects the exact local vector ``v``
  carried in ``mctx``.

* :class:`FedCETLiteral` — the 2-point extrapolation form exactly as printed
  in Algorithm 2 (states ``x(t), x(t-1)`` and gradients at both). Used as a
  reference oracle: tests assert both forms produce identical iterates
  (Lemma 1), which numerically validates the paper's reformulation. (The two
  forms coincide only for the UNtransformed message path — the literal form
  has no separate exact-local-vector carry, so compose transforms with
  :class:`FedCET`, not with the literal oracle.)

A communication round = ``tau - 1`` pure-local steps followed by one
aggregating step, matching Algorithm 2's ``(t+1) mod tau == 0`` schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import replicate
from repro.core.engine import RoundEngine
from repro.utils.tree import tree_zeros_like


class FedCETState(NamedTuple):
    x: Any  # stacked [clients, ...] model parameters
    d: Any  # stacked [clients, ...] drift-correction variable (Lemma 1)
    t: jax.Array  # global iteration counter (drives sampling keys)


@dataclasses.dataclass(frozen=True)
class FedCET(RoundEngine):
    """FedCET in the memory-efficient (d, x) form of Lemma 1."""

    alpha: float
    c: float
    tau: int
    n_clients: int
    name: str = "fedcet"
    vectors_up: int = 1  # Remark 2: ONE n-dim vector per client per round
    vectors_down: int = 1
    #: fuse the local-step triad with the Pallas kernel (TPU target;
    #: interpret-mode on CPU). Off by default — XLA fuses this fine; the
    #: kernel exists for the perf phase and is validated against ref.py.
    use_fused_kernel: bool = False

    def init_warmup(self, gf, x0, init_batch):
        """Paper's warm-up: x(-1) = x(-2) - a*grad(x(-2)), d(-1) = 0, then
        one aggregating step (run by the engine) produces (d(0), x(0)) —
        exactly the initialization block above Algorithm 2 in (d, x) form."""
        x_m2 = replicate(x0, self.n_clients)
        g_m2 = gf(x_m2, init_batch)
        x_m1 = jax.tree.map(lambda x, g: x - self.alpha * g, x_m2, g_m2)
        return FedCETState(x=x_m1, d=tree_zeros_like(x_m1), t=jnp.asarray(-1)), True

    def _v(self, x, g, d):
        """The single transmitted vector v = x - a*g - a*d (== the paper's
        2x(t) - x(t-1) - a*grad(t) + a*grad(t-1), see Lemma 1)."""
        if self.use_fused_kernel:
            from repro.kernels import ops as kops

            return jax.tree.map(
                lambda xx, gg, dd: kops.fedcet_v(xx, gg, dd, self.alpha), x, g, d
            )
        a = self.alpha
        return jax.tree.map(lambda xx, gg, dd: xx - a * gg - a * dd, x, g, d)

    def local_step(self, gf, state, batch, rctx):
        """Eq. (3): pure extrapolated local training, d frozen."""
        g = gf(state.x, batch)
        v = self._v(state.x, g, state.d)
        return FedCETState(x=v, d=state.d, t=state.t + 1)

    def message(self, gf, state, batch, rctx):
        """The single uplink vector v; also carried as mctx so the x-update
        stays exact when a transform compresses the transmitted copy."""
        g = gf(state.x, batch)
        v = self._v(state.x, g, state.d)
        return v, v

    def server_aggregate(self, state, msg, msg_bar, mctx, rctx):
        """Eq. (2): the aggregating step. ``msg`` is the client's own
        (possibly compressed) transmitted vector, ``mctx`` the exact v.
        With ``use_fused_kernel`` the paired update runs through the
        kernels/ops.py ``fedcet_comm`` pair kernel — one visit per
        element for BOTH outputs instead of two tree.map streams."""
        if self.use_fused_kernel:
            from repro.kernels import ops as kops

            d_leaves, treedef = jax.tree.flatten(state.d)
            pairs = [
                kops.fedcet_comm(dd, mm, mb, self.c, self.alpha,
                                 v=(None if vv is mm else vv))
                for dd, mm, mb, vv in zip(
                    d_leaves, jax.tree.leaves(msg), jax.tree.leaves(msg_bar),
                    jax.tree.leaves(mctx))
            ]
            d_next = jax.tree.unflatten(treedef, [p[0] for p in pairs])
            x_next = jax.tree.unflatten(treedef, [p[1] for p in pairs])
            return FedCETState(x=x_next, d=d_next, t=state.t + 1)
        ca = self.c * self.alpha
        d_next = jax.tree.map(lambda dd, mm, mb: dd + self.c * (mm - mb),
                              state.d, msg, msg_bar)
        x_next = jax.tree.map(lambda vv, mm, mb: vv - ca * (mm - mb),
                              mctx, msg, msg_bar)
        return FedCETState(x=x_next, d=d_next, t=state.t + 1)

    def _fused_tail(self, inner, msg, mctx, extras, step, mask):
        """The fully fused arena round tail (engine hook; see
        kernels/ops.py:fedcet_round_tail): when the transform stack is
        exactly one shift-quantized compression over a packed arena
        message, the dequantize + weighted reduce + paired ``(d', x')``
        update + DIANA shift step collapse into ONE kernel visit per
        element — the quantizer codes, reconstructed wire message and
        client mean never round-trip through HBM. Replicates the generic
        seam's PRNG schedule and masked-mean expressions term for term
        (pinned <= 1e-12 in tests/test_arena.py); any non-matching
        configuration returns None and takes the generic path."""
        if not self.use_fused_kernel or len(self.transforms) != 1:
            return None
        from repro.core.arena import Arena
        from repro.core.compressors import Shifted, StochasticQuant
        from repro.core.engine import _COMPRESS_KEY_TAG, MessageCompression

        t = self.transforms[0]
        if not isinstance(t, MessageCompression):
            return None
        comp = t.compressor
        if not (isinstance(comp, Shifted)
                and isinstance(comp.inner, StochasticQuant)
                and not comp.inner.per_client_dither):
            return None
        h = extras[0]
        if not (isinstance(msg, Arena) and isinstance(h, Arena)
                and msg.data.ndim == 3
                and msg.layout.dtype in (jnp.float32, jnp.float64)):
            return None
        from repro.core.arena import pack_rows
        from repro.kernels import ops as kops

        lo, va, ha, da = msg.layout, msg.data, h.data, inner.d.data
        ft = va.dtype
        quant = comp.inner
        levels = 2 ** (quant.bits - 1) - 1
        # the per-leaf quantizer scale of the shifted RESIDUAL: segment-max
        # over the leaf's rows (exact — the same max as per-leaf).
        seg = jnp.asarray(lo.row_segments())
        row_max = jnp.max(jnp.abs(va - ha), axis=(0, 2))
        leaf_max = jax.ops.segment_max(row_max, seg,
                                       num_segments=len(lo.shapes))
        scale = (leaf_max / levels)[seg][:, None]
        # MessageCompression's round key, then the per-leaf dither draws
        # in flatten (== layout) order — bit-identical to the generic path.
        key = jax.random.fold_in(jax.random.key(t.seed),
                                 _COMPRESS_KEY_TAG + t.index)
        key = jax.random.fold_in(key, jnp.asarray(step, jnp.int32))
        u = pack_rows([jax.random.uniform(jax.random.fold_in(key, i), shp,
                                          dtype=ft)
                       for i, shp in enumerate(lo.shapes)], lo)
        n = va.shape[0]
        if mask is None:
            w = jnp.ones((n, 1), ft)
            den = jnp.full((1, 1), n, ft)
        else:  # the exact masked_client_mean expressions
            w = mask.astype(ft).reshape(n, 1)
            den = jnp.maximum(jnp.sum(mask.astype(jnp.int32)),
                              1).astype(ft).reshape(1, 1)
        d2, x2, h2 = kops.fedcet_round_tail(
            va, ha, da, u, scale, w, den, c=self.c, alpha=self.alpha,
            beta=comp.step, bits=quant.bits)
        inner = FedCETState(x=Arena(x2, lo), d=Arena(d2, lo), t=inner.t + 1)
        return inner, (Arena(h2, lo),)


class FedCETLiteralState(NamedTuple):
    x_curr: Any  # x(t)
    x_prev: Any  # x(t-1)
    g_prev: Any  # grad f(x(t-1))
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class FedCETLiteral(RoundEngine):
    """Algorithm 2 exactly as printed (3 persistent states). Reference only."""

    alpha: float
    c: float
    tau: int
    n_clients: int
    name: str = "fedcet_literal"
    vectors_up: int = 1
    vectors_down: int = 1

    def init_warmup(self, gf, x0, init_batch):
        x_m2 = replicate(x0, self.n_clients)
        g_m2 = gf(x_m2, init_batch)
        x_m1 = jax.tree.map(lambda x, g: x - self.alpha * g, x_m2, g_m2)
        return FedCETLiteralState(x_curr=x_m1, x_prev=x_m2, g_prev=g_m2,
                                  t=jnp.asarray(-1)), True

    def _extrapolate(self, gf, state, batch):
        """2x(t) - x(t-1) - a grad(t) + a grad(t-1), and grad(t) for carry."""
        a = self.alpha
        g = gf(state.x_curr, batch)
        m = jax.tree.map(
            lambda xc, xp, gc, gp: 2.0 * xc - xp - a * gc + a * gp,
            state.x_curr, state.x_prev, g, state.g_prev,
        )
        return m, g

    def local_step(self, gf, state, batch, rctx):
        m, g = self._extrapolate(gf, state, batch)
        return FedCETLiteralState(x_curr=m, x_prev=state.x_curr, g_prev=g,
                                  t=state.t + 1)

    def message(self, gf, state, batch, rctx):
        m, g = self._extrapolate(gf, state, batch)
        return m, g

    def server_aggregate(self, state, msg, msg_bar, mctx, rctx):
        ca = self.c * self.alpha
        x_next = jax.tree.map(lambda mm, mb: ca * mb + (1.0 - ca) * mm,
                              msg, msg_bar)
        return FedCETLiteralState(x_curr=x_next, x_prev=state.x_curr,
                                  g_prev=mctx, t=state.t + 1)

    def client_params(self, state):
        return self._inner(state).x_curr


def max_weight_c(mu: float, alpha: float) -> float:
    """Largest admissible weight parameter: c = mu / (2 mu alpha + 8)."""
    return mu / (2.0 * mu * alpha + 8.0)
