"""FedCET — the paper's contribution (Algorithm 2).

Two equivalent implementations are provided:

* :class:`FedCET` — the production form, using the ``(d, x)`` recursion of
  Lemma 1. It carries TWO persistent model-sized states per client
  (``x`` and the drift variable ``d``) plus one transient gradient:

      v      = x - alpha * grad - alpha * d        # transmitted at comm rounds
      d_next = d + c * (v - mean_clients(v))       # comm round only
      x_next = v - c * alpha * (v - mean_clients(v))   (comm) / v (local)

  ``d`` converges to ``-grad_i(x*)`` — it absorbs exactly the gradient
  heterogeneity that makes FedAvg drift — yet is never transmitted. Only the
  single vector ``v`` crosses the network, which is the paper's headline:
  half the communication of SCAFFOLD / FedTrack / FedLin.

* :class:`FedCETLiteral` — the 2-point extrapolation form exactly as printed
  in Algorithm 2 (states ``x(t), x(t-1)`` and gradients at both). Used as a
  reference oracle: tests assert both forms produce identical iterates
  (Lemma 1), which numerically validates the paper's reformulation.

A communication round = ``tau - 1`` pure-local steps followed by one
aggregating step, matching Algorithm 2's ``(t+1) mod tau == 0`` schedule.
The aggregation is implemented as a leaf-wise mean over the stacked clients
axis; under ``pjit`` with that axis sharded over ``("pod", "data")`` it is
the only cross-pod collective, fired once per ``tau`` gradient steps.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.api import GradFn, replicate, vmap_grads
from repro.utils.tree import tree_client_mean, tree_zeros_like


class FedCETState(NamedTuple):
    x: Any  # stacked [clients, ...] model parameters
    d: Any  # stacked [clients, ...] drift-correction variable (Lemma 1)
    t: jax.Array  # global iteration counter (informational)


@dataclasses.dataclass(frozen=True)
class FedCET:
    """FedCET in the memory-efficient (d, x) form of Lemma 1."""

    alpha: float
    c: float
    tau: int
    n_clients: int
    name: str = "fedcet"
    vectors_up: int = 1  # Remark 2: ONE n-dim vector per client per round
    vectors_down: int = 1
    #: fuse the local-step triad with the Pallas kernel (TPU target;
    #: interpret-mode on CPU). Off by default — XLA fuses this fine; the
    #: kernel exists for the perf phase and is validated against ref.py.
    use_fused_kernel: bool = False
    #: mesh axes carrying the client dimension (production launcher only).
    spmd_client_axes: tuple = ()

    # ------------------------------------------------------------------ init
    def init(self, grad_fn: GradFn, x0, init_batch) -> FedCETState:
        """Paper's warm-up: x(-1) = x(-2) - a*grad(x(-2)), d(-1) = 0, then one
        aggregating step produces (d(0), x(0)). This is exactly the
        initialization block above Algorithm 2, rewritten in (d, x) form."""
        gf = vmap_grads(grad_fn, spmd_axis_name=(self.spmd_client_axes or None))
        x_m2 = replicate(x0, self.n_clients)
        g_m2 = gf(x_m2, init_batch)
        x_m1 = jax.tree.map(lambda x, g: x - self.alpha * g, x_m2, g_m2)
        d_m1 = tree_zeros_like(x_m1)
        state = FedCETState(x=x_m1, d=d_m1, t=jnp.asarray(-1))
        return self._comm_step(gf, state, init_batch)

    # ----------------------------------------------------------------- steps
    def _v(self, x, g, d):
        """The single transmitted vector v = x - a*g - a*d (== the paper's
        2x(t) - x(t-1) - a*grad(t) + a*grad(t-1), see Lemma 1)."""
        if self.use_fused_kernel:
            from repro.kernels import ops as kops

            return jax.tree.map(
                lambda xx, gg, dd: kops.fedcet_v(xx, gg, dd, self.alpha), x, g, d
            )
        a = self.alpha
        return jax.tree.map(lambda xx, gg, dd: xx - a * gg - a * dd, x, g, d)

    def _local_step(self, gf, state: FedCETState, batch) -> FedCETState:
        """Eq. (3): pure extrapolated local training, d frozen."""
        g = gf(state.x, batch)
        v = self._v(state.x, g, state.d)
        return FedCETState(x=v, d=state.d, t=state.t + 1)

    def _comm_step(self, gf, state: FedCETState, batch) -> FedCETState:
        """Eq. (2): the aggregating step. mean over clients == server
        aggregate + broadcast; the only cross-client collective."""
        g = gf(state.x, batch)
        v = self._v(state.x, g, state.d)
        v_bar = tree_client_mean(v)
        ca = self.c * self.alpha
        d_next = jax.tree.map(lambda dd, vv, vb: dd + self.c * (vv - vb), state.d, v, v_bar)
        x_next = jax.tree.map(lambda vv, vb: vv - ca * (vv - vb), v, v_bar)
        return FedCETState(x=x_next, d=d_next, t=state.t + 1)

    # ----------------------------------------------------------------- round
    def round(self, grad_fn: GradFn, state: FedCETState, batches) -> FedCETState:
        """One communication round: (tau-1) local steps + 1 comm step.

        ``batches`` leaves have leading [tau, clients, ...]. The local steps
        run under ``lax.scan`` so the lowered HLO stays small for multi-B
        parameter models; the aggregation sits OUTSIDE the scan so the
        cross-pod all-reduce appears exactly once per round in the HLO.
        """
        gf = vmap_grads(grad_fn, spmd_axis_name=(self.spmd_client_axes or None))
        if self.tau > 1:
            local_b = jax.tree.map(lambda b: b[: self.tau - 1], batches)

            def body(s, b):
                return self._local_step(gf, s, b), None

            state, _ = jax.lax.scan(body, state, local_b)
        last_b = jax.tree.map(lambda b: b[self.tau - 1], batches)
        return self._comm_step(gf, state, last_b)

    def global_params(self, state: FedCETState):
        return tree_client_mean(state.x, keepdims=False)


class FedCETLiteralState(NamedTuple):
    x_curr: Any  # x(t)
    x_prev: Any  # x(t-1)
    g_prev: Any  # grad f(x(t-1))
    t: jax.Array


@dataclasses.dataclass(frozen=True)
class FedCETLiteral:
    """Algorithm 2 exactly as printed (3 persistent states). Reference only."""

    alpha: float
    c: float
    tau: int
    n_clients: int
    name: str = "fedcet_literal"
    vectors_up: int = 1
    vectors_down: int = 1
    spmd_client_axes: tuple = ()

    def init(self, grad_fn: GradFn, x0, init_batch) -> FedCETLiteralState:
        gf = vmap_grads(grad_fn, spmd_axis_name=(self.spmd_client_axes or None))
        x_m2 = replicate(x0, self.n_clients)
        g_m2 = gf(x_m2, init_batch)
        x_m1 = jax.tree.map(lambda x, g: x - self.alpha * g, x_m2, g_m2)
        state = FedCETLiteralState(x_curr=x_m1, x_prev=x_m2, g_prev=g_m2,
                                   t=jnp.asarray(-1))
        return self._step(gf, state, init_batch, comm=True)

    def _message(self, gf, state, batch):
        """2x(t) - x(t-1) - a grad(t) + a grad(t-1), and grad(t) for carry."""
        a = self.alpha
        g = gf(state.x_curr, batch)
        m = jax.tree.map(
            lambda xc, xp, gc, gp: 2.0 * xc - xp - a * gc + a * gp,
            state.x_curr, state.x_prev, g, state.g_prev,
        )
        return m, g

    def _step(self, gf, state, batch, *, comm: bool) -> FedCETLiteralState:
        m, g = self._message(gf, state, batch)
        if comm:
            m_bar = tree_client_mean(m)
            ca = self.c * self.alpha
            x_next = jax.tree.map(lambda mm, mb: ca * mb + (1.0 - ca) * mm, m, m_bar)
        else:
            x_next = m
        return FedCETLiteralState(x_curr=x_next, x_prev=state.x_curr, g_prev=g,
                                  t=state.t + 1)

    def round(self, grad_fn: GradFn, state, batches) -> FedCETLiteralState:
        gf = vmap_grads(grad_fn, spmd_axis_name=(self.spmd_client_axes or None))
        for s in range(self.tau - 1):  # reference impl: clarity over scan
            b = jax.tree.map(lambda x: x[s], batches)
            state = self._step(gf, state, b, comm=False)
        b = jax.tree.map(lambda x: x[self.tau - 1], batches)
        return self._step(gf, state, b, comm=True)

    def global_params(self, state):
        return tree_client_mean(state.x_curr, keepdims=False)


def max_weight_c(mu: float, alpha: float) -> float:
    """Largest admissible weight parameter: c = mu / (2 mu alpha + 8)."""
    return mu / (2.0 * mu * alpha + 8.0)
