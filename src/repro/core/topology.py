"""Topology: WHERE the aggregation happens — star, hierarchical, gossip.

The paper's round model (and everything in this repo up to now) is the
degenerate STAR topology: one server, flat all-to-one aggregation — every
client's message crosses the network to a single root, which is the
scaling bottleneck once "clients" means millions of edge devices. FedCET
itself descends from the DECENTRALIZED optimizer NIDS, where there is no
server at all: each node mixes with its graph neighbors through a
doubly-stochastic matrix. This module makes the aggregation geometry a
first-class scenario axis on the engine's message/aggregate seam (the
same seam ``with_compression`` / ``with_participation`` / ``with_delay``
ride):

* :class:`Star` — the flat all-to-one mean, exactly today's engine. The
  ``with_topology`` factory returns the algorithm object UNCHANGED for
  star specs (the identity-shortcut discipline every transform factory
  follows); attaching the ``Star`` machinery explicitly is pinned
  trajectory-identical (<= 1e-12) to the bare engine in
  tests/test_topology.py.
* :class:`Hierarchical` — 2-or-more-level tree aggregation: EDGE
  aggregators each take a contiguous block of clients, compute the
  weighted partial mean of their block, and forward ONE message up the
  tree; the root combines tier aggregates into the global mean. The
  value is numerically the star mean up to float reassociation (the
  grouped sums associate differently — measured ~1e-14 trajectory
  drift, NOT bit-identical), but the traffic shape changes completely:
  the root ingests ``groups[-1]`` messages instead of ``n_clients``
  (the production scaling story), and comm accounting bills each hop
  separately — see `Per-hop accounting` below. With
  ``tier_compression=`` set, the partial means themselves are
  RE-COMPRESSED at every interior hop (see `Tier recompression`).
* :class:`Mixing` — no server: client i receives the W-weighted
  neighborhood mean ``sum_j W_ij m_j`` of a doubly-stochastic gossip
  matrix (ring, torus, Erdős–Rényi; Metropolis–Hastings weights). The
  aggregate is PER-CLIENT (a stacked ``[clients, ...]`` tree, not a
  broadcast ``[1, ...]`` mean); every engine spec already broadcasts
  ``msg_bar`` leaf-wise, so the same ``server_aggregate`` math runs
  decentralized unchanged. Column-stochasticity is what preserves
  FedCET's redistributive invariant: ``sum_i (m_i - (W m)_i) = 0``, so
  the drift updates stay mean-zero under gossip. Composed with the
  :class:`repro.core.baselines.nids.NIDS` spec this implements NIDS
  proper — closing the loop to the paper's origin.

Sparse exchange lowering
------------------------
The dense ``Mixing`` path materializes the full N x N matrix and pays an
``N^2 x D`` contraction per leaf per round — fine for the paper's N=10
simulator, simulator-only on a production mesh where W is a bounded-degree
graph (ring degree 2, torus degree 4) and all but ``E = sum_i deg_i``
entries are zero. ``lowering="sparse"`` (spec suffix ``:sparse``, e.g.
``ring:sparse`` / ``er:0.4:t:sparse``) lowers the SAME aggregation to a
padded neighbor-index exchange:

* each node owns a static-width table of ``S = max_degree + 1`` slots
  (slot 0 = itself with the Metropolis diagonal weight, then its
  neighbors; pad slots carry weight 0 and a self-index, so they gather
  safely and contribute exactly 0);
* the reduce is a gather of the S neighbor rows, a weight multiply, and a
  fixed-slot segment sum (``jax.ops.segment_sum``, or the Pallas
  segment-reduce kernel in kernels/gossip_reduce.py behind
  ``use_kernel=True`` — interpret mode off-TPU, mirroring
  ``StochasticQuant``) — ``O(E x D)`` instead of ``O(N^2 x D)``
  (pinned at N in {64, 256, 1024} by benchmarks/gossip_scaling.py);
* per-round resampled Erdős–Rényi graphs rebuild the neighbor tables
  INSIDE the traced round from the same :class:`TopoState`-keyed
  domain-separated stream as the dense matrix, so sparse and dense
  resampled runs agree round-by-round and across checkpoint resume.

The lowering is a pure implementation change: dense and sparse
trajectories agree <= 1e-12 on every connected family (the
dense-equivalence harness in tests/test_topology.py) and the comm
accounting is IDENTICAL — one message per directed edge either way.
``max_degree=0`` (auto) sizes the table from the actual graph; an
explicit cap that a static graph overflows raises at construction, and
resampled graphs (whose degree is unbounded below n-1) reject any
explicit cap below ``n - 1``.

Tier recompression
------------------
``Hierarchical(tier_compression=<Compressor>)`` (launch knob
``--tier-compression shift:q8``) applies a compressor round-trip to each
interior tier's transmitted partial means, so the uplink is compressed
END TO END: clients send their (compressed) wire messages to edge
aggregators, and edge->root hops now carry e.g. 8-bit shifted-quantized
partial means instead of dense f32. Mechanics:

* stochastic tier compressors derive their per-round key from the
  :class:`TopoState` round index through a domain-separated stream
  (``_TIER_KEY_TAG`` + tier index) — deterministic, restart-stable, one
  dither per (round, tier) shared by every reduce in that round (both
  ends of the tier link see the same quantizer);
* stateful wrappers (``shift:`` / ``ef:``) keep their per-aggregator
  memory in ``TopoState.tier`` (a tuple of per-tier extras riding
  EngineState extras — checkpointed, sharded replicated); the memory
  advances exactly once per aggregation (``reduce_and_advance``), while
  read-only reduces (FedLin's round-start exchange) see it frozen;
* per-hop accounting bills interior UPLINK hops at the tier compressor's
  ``bits_per_coord`` (``tier_bits_per_coord``); the downward tier
  re-broadcasts stay dense f32 — see repro/core/comm.py.

Weighted reduction contract
---------------------------
A topology reduces a stacked ``[clients, ...]`` tree under per-client
weights ``w`` (``reduce(tree, w, tstate)``): uniform weights for plain
rounds, the participation mask under client sampling, and the stale
policy's ``(age, fresh)`` weights under ``with_delay`` — the SAME weight
vector the star engine feeds ``weighted_client_mean``, so every topology
composes with every transform with no algorithm-side code. Star and
Hierarchical return the ``[1, ...]`` weighted mean (hierarchical
grouping of a weighted mean is exact regrouping — same value, different
association); Mixing row-renormalizes ``W * w`` so absent/stale
neighbors drop out of each node's neighborhood mean. The engine's
aggregating step calls ``reduce_and_advance`` (reduce + state advance in
one step — the only place topology state moves); everything else uses
the read-only ``reduce``.

Topology state
--------------
Topologies that evolve per round (an Erdős–Rényi graph resampled every
aggregation, keyed by a domain-separated PRNG stream) — and hierarchies
whose tier compressor is stochastic or stateful — carry a
:class:`TopoState` (the mixing round index, plus the optional tier
memory) in the ``EngineState`` extras slot, just before ``DelayState``
— checkpointed with the run, restart-stable, threaded through the AOT
``abstract_state`` / ``state_shardings`` path in launch/train.py.
Static topologies are stateless frozen dataclasses like every other
engine knob.

Per-hop accounting
------------------
A topology declares its traffic shape instead of letting the meter
assume ``n_clients`` flat uplinks:

* ``client_up_mult(n)`` — uplink messages per client on the FIRST hop
  (1 for star/hierarchical; the node degree for gossip, where a client
  transmits its wire message to each neighbor — identical for the dense
  and sparse lowerings, which exchange the same directed edges);
* ``aggregator_hops(n)`` — ``(label, messages)`` per aggregator tier
  (edge->root re-transmissions). Upward tier messages carry
  ``tier_bits_per_coord`` bits per coordinate (32.0 dense f32 unless
  ``tier_compression`` is set); the downward tier re-broadcasts stay
  dense f32;
* ``broadcast_mult(n)`` — downlink client-hop multiplier (0 for gossip:
  there is no broadcast; the exchange is billed as uplink edges).

``CommMeter.for_params(algo=...)`` and ``comm_bits_per_round`` /
``comm_hops_per_round`` (repro/core/comm.py) fold these in, so
``hier:g8`` shows the root ingesting 8 messages while the client tier
still pays the compressed wire width x the delay duty cycle.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.compressors import auto_wrap, from_spec as compressor_from_spec
from repro.core.staleness import weighted_client_mean

__all__ = [
    "Hierarchical",
    "Mixing",
    "Star",
    "TopoState",
    "Topology",
    "parse_topology",
]

#: domain-separation tag folded into resampled-graph keys so the topology
#: stream never collides with the participation (bare seed), compression
#: (0x7A11A5 + index) or delay (0x57A1E) schedules at the default seed=0.
_TOPO_KEY_TAG = 0x70_70

#: domain-separation tag (+ tier index) for hierarchical tier-compression
#: dither keys — never collides with the graph-resampling stream above or
#: the engine-side transform streams at the default seed=0.
_TIER_KEY_TAG = 0x71_E5

#: widest neighbor table the sparse lowering unrolls slot-by-slot (fused
#: gather+fma per slot); wider tables (resampled graphs capped at n-1)
#: fall back to one gather + segment_sum to keep the traced graph small.
_UNROLL_SLOTS = 32


class TopoState(NamedTuple):
    """Per-run topology state riding in ``EngineState`` extras (just
    before the delay buffer when both are attached): the aggregation
    round index ``k`` that keys time-varying mixing matrices and tier
    compression dither, plus — for hierarchies whose ``tier_compression``
    is stateful (``shift:`` / ``ef:`` wrappers) — the per-tier compressor
    memory ``tier`` (a tuple of per-aggregator trees). Checkpointed,
    restart-stable; ``tier=None`` flattens away, so states saved before
    tier recompression existed round-trip unchanged."""

    k: jax.Array  # int32 aggregation counter (init included)
    tier: Any = None  # per-tier compressor memory (Hierarchical only)


# ------------------------------------------------------------------ protocol
@dataclasses.dataclass(frozen=True)
class Topology:
    """Base: a weighted cross-client reduction with a declared traffic
    shape. Subclasses implement ``reduce`` and override the accounting
    hooks; stateful topologies also override ``init_state`` /
    ``reduce_and_advance``."""

    #: does this topology carry a TopoState in EngineState extras?
    stateful = False
    #: does ``init_state`` need the (abstract) message tree to shape its
    #: state (hierarchies with stateful tier compression)?
    needs_msg_shapes = False
    #: can this topology reduce a GATHERED cohort (``with_cohort``)? True
    #: for server-rooted geometries (star, hierarchical — the reduction is
    #: a weighted mean, well-defined over any client subset); False for
    #: gossip mixing, where every node exchanges with its neighbors every
    #: round and there is no server to sample a cohort.
    supports_cohort = False

    # --------------------------------------------------------------- state
    def init_state(self, msg_shapes=None) -> TopoState | None:
        del msg_shapes
        return TopoState(k=jnp.zeros((), jnp.int32)) if self.stateful else None

    def advance(self, tstate: TopoState | None) -> TopoState | None:
        if not self.stateful:
            return None
        return TopoState(k=tstate.k + 1, tier=tstate.tier)

    # -------------------------------------------------------------- compute
    def reduce(self, tree, w: jax.Array, tstate: TopoState | None = None):
        """Aggregate a stacked ``[clients, ...]`` tree under per-client
        weights ``w`` — ``[1, ...]`` (star/hierarchical mean) or
        ``[clients, ...]`` (per-client gossip neighborhood means).
        READ-ONLY: topology state (graph schedule, tier memory) is used
        but never advanced — the engine's aggregating step goes through
        :meth:`reduce_and_advance` instead."""
        raise NotImplementedError

    def reduce_and_advance(self, tree, w: jax.Array,
                           tstate: TopoState | None = None):
        """The aggregating-step entry point: reduce AND advance the
        topology state in one step (stateful tier compressors update
        their memory from the partial means they just transmitted).
        Returns ``(aggregate, next_tstate)``."""
        return self.reduce(tree, w, tstate), self.advance(tstate)

    def reduce_cohort(self, tree, w: jax.Array, idx: jax.Array,
                      n_clients: int, tstate: TopoState | None = None):
        """Reduce a GATHERED ``[cohort, ...]`` tree under cohort-slot
        weights ``w``; ``idx`` carries the cohort's GLOBAL client ids (a
        hierarchy routes each member to the edge aggregator its global id
        belongs to). READ-ONLY, like :meth:`reduce`. Only topologies with
        ``supports_cohort`` implement this."""
        raise NotImplementedError(
            f"{type(self).__name__} does not support cohort execution")

    def reduce_cohort_and_advance(self, tree, w: jax.Array, idx: jax.Array,
                                  n_clients: int,
                                  tstate: TopoState | None = None):
        """Cohort counterpart of :meth:`reduce_and_advance`."""
        return (self.reduce_cohort(tree, w, idx, n_clients, tstate),
                self.advance(tstate))

    # ----------------------------------------------------------- accounting
    def client_up_mult(self, n_clients: int) -> float:
        """Uplink messages per client on the first hop (gossip: degree)."""
        del n_clients
        return 1.0

    def aggregator_hops(self, n_clients: int) -> tuple:
        """``(label, messages)`` per aggregator tier above the clients."""
        del n_clients
        return ()

    @property
    def tier_bits_per_coord(self) -> float:
        """Wire bits per coordinate on UPWARD aggregator-tier hops (32.0
        dense f32; the tier compressor's width when one is attached)."""
        return 32.0

    def broadcast_mult(self, n_clients: int) -> float:
        """Downlink client-hop multiplier (0 = no broadcast at all)."""
        del n_clients
        return 1.0

    def validate(self, n_clients: int) -> None:
        """Raise if the topology cannot serve ``n_clients`` nodes."""
        del n_clients


# ---------------------------------------------------------------------- star
@dataclasses.dataclass(frozen=True)
class Star(Topology):
    """Flat all-to-one aggregation — the engine's native geometry, kept
    as an explicit object so tests can attach the topology MACHINERY and
    pin it trajectory-identical to the bare engine. ``with_topology``
    never attaches it (star specs are identity shortcuts)."""

    supports_cohort = True

    def reduce(self, tree, w, tstate=None):
        del tstate
        return weighted_client_mean(tree, w)

    def reduce_cohort(self, tree, w, idx, n_clients, tstate=None):
        """The star reduces any client subset identically: the weighted
        mean over whoever transmitted."""
        del idx, n_clients, tstate
        return weighted_client_mean(tree, w)


# -------------------------------------------------------------- hierarchical
@dataclasses.dataclass(frozen=True)
class Hierarchical(Topology):
    """Tree aggregation: ``groups = (g1, g2, ...)`` aggregators per tier,
    clients in contiguous near-equal blocks. ``(8,)`` is the 2-level
    edge+root production shape (8 edge aggregators, root ingests 8
    messages); ``(16, 4)`` adds a mid tier. Each tier forwards weighted
    partial means with their weight sums, so the root value equals the
    star weighted mean exactly up to float reassociation — whether
    FedCET's exactness survives the regrouped arithmetic (it does,
    ~1e-14, even under a shift:q8 client uplink) is pinned in
    benchmarks/topology_sweep.py.

    ``tier_compression`` re-compresses each interior tier's transmitted
    partial means (the edge->root hop) with any
    :class:`repro.core.compressors.Compressor`; stochastic compressors
    key their dither from the :class:`TopoState` round index and
    stateful wrappers (``shift:`` / ``ef:``) keep per-tier,
    per-aggregator memory in ``TopoState.tier`` — see the module
    docstring's `Tier recompression` section."""

    groups: tuple
    tier_compression: Any = None
    seed: int = 0

    def __post_init__(self):
        g = (self.groups,) if isinstance(self.groups, int) else tuple(self.groups)
        object.__setattr__(self, "groups", g)
        if not g or any(int(x) < 1 for x in g):
            raise ValueError(f"need >= 1 aggregator per tier: {g}")
        if any(b >= a for a, b in zip(g, g[1:])):
            raise ValueError(f"tier sizes must strictly decrease: {g}")
        if self.tier_compression is not None and not (
                hasattr(self.tier_compression, "apply")
                and hasattr(self.tier_compression, "bits_per_coord")):
            raise ValueError(
                "tier_compression must be a repro.core.compressors."
                f"Compressor (got {self.tier_compression!r}); pass spec "
                "strings through parse_topology / with_topology")

    def validate(self, n_clients: int) -> None:
        if self.groups[0] > n_clients:
            raise ValueError(
                f"hierarchical tier of {self.groups[0]} aggregators over "
                f"only {n_clients} clients (want fan-in > 1)")

    # ---------------------------------------------------------------- state
    @property
    def stateful(self) -> bool:  # type: ignore[override]
        c = self.tier_compression
        return c is not None and (c.stateful or c.requires_key)

    @property
    def needs_msg_shapes(self) -> bool:  # type: ignore[override]
        return self.tier_compression is not None and self.tier_compression.stateful

    def _tiers(self, n: int) -> list:
        return [g for g in self.groups if g < n]  # degenerate tiers drop out

    def init_state(self, msg_shapes=None) -> TopoState | None:
        if not self.stateful:
            return None
        tier = None
        if self.needs_msg_shapes:
            if msg_shapes is None:
                raise ValueError(
                    "stateful tier compression needs the message shapes to "
                    "size its per-tier memory — the engine passes them at "
                    "init; direct callers can use jax.eval_shape")
            n = jax.tree.leaves(msg_shapes)[0].shape[0]
            mem = []
            for g in self._tiers(n):
                shapes_g = jax.tree.map(
                    lambda sd, _g=g: jax.ShapeDtypeStruct(
                        (_g,) + tuple(sd.shape[1:]), sd.dtype), msg_shapes)
                mem.append(self.tier_compression.init_extra(shapes_g))
            tier = tuple(mem)
        return TopoState(k=jnp.zeros((), jnp.int32), tier=tier)

    # -------------------------------------------------------------- compute
    @staticmethod
    def _segments(n_in: int, n_out: int) -> jax.Array:
        """Contiguous near-equal block assignment ``[n_in] -> n_out``."""
        return jnp.asarray([i * n_out // n_in for i in range(n_in)], jnp.int32)

    def _tier_key(self, t_i: int, k):
        key = jax.random.fold_in(jax.random.key(self.seed),
                                 _TIER_KEY_TAG + t_i)
        return jax.random.fold_in(key, jnp.asarray(k, jnp.int32))

    def _reduce_impl(self, tree, w, tstate, seg0=None, n_total=None):
        """Shared tier walk; returns ``(aggregate, new tier memory)`` —
        the caller decides whether the memory update is kept
        (``reduce_and_advance``) or discarded (read-only ``reduce``).

        ``seg0``/``n_total`` are the cohort entry point: ``tree``/``w``
        are cohort rows, ``seg0`` maps each row to its GLOBAL first-tier
        aggregator (the static segment table gathered at the cohort's
        global ids), and the tier structure is sized from ``n_total`` —
        so tier shapes (and the per-tier compressor memory) are identical
        whether the full population or a cohort feeds the tree, and edge
        aggregators with no cohort member contribute zero weight (the
        existing ``wsum > 0`` guard)."""
        n = n_total if n_total is not None else w.shape[0]
        comp = self.tier_compression
        k = tstate.k if tstate is not None else jnp.zeros((), jnp.int32)
        vals, wt, cur = tree, w, n
        new_mem = []
        for t_i, g in enumerate(self._tiers(n)):
            ids = (seg0 if t_i == 0 and seg0 is not None
                   else self._segments(cur, g))
            wsum = jax.ops.segment_sum(wt, ids, num_segments=g)
            denom = jnp.where(wsum > 0, wsum, 1.0)

            def pmean(a, _ids=ids, _wt=wt, _den=denom, _g=g):
                wb = _wt.astype(a.dtype).reshape((-1,) + (1,) * (a.ndim - 1))
                sums = jax.ops.segment_sum(a * wb, _ids, num_segments=_g)
                db = _den.astype(a.dtype).reshape((-1,) + (1,) * (a.ndim - 1))
                # the edge aggregator transmits its PARTIAL MEAN (one
                # message regardless of block size) + the weight mass.
                return sums / db

            vals = jax.tree.map(pmean, vals)
            if comp is not None:
                key = self._tier_key(t_i, k) if comp.requires_key else None
                extra = None
                if comp.stateful:
                    extra = (tstate.tier[t_i]
                             if tstate is not None and tstate.tier is not None
                             else jax.tree.map(jnp.zeros_like, vals))
                vals, extra = comp.apply(key, vals, extra)
                new_mem.append(extra)
            wt, cur = wsum, g

        def final(a):
            wb = wt.astype(a.dtype).reshape((-1,) + (1,) * (a.ndim - 1))
            total = jnp.sum(wt).astype(a.dtype)
            denom = jnp.where(total > 0, total, jnp.ones((), a.dtype))
            return jnp.sum(a * wb, axis=0, keepdims=True) / denom

        return jax.tree.map(final, vals), tuple(new_mem)

    def reduce(self, tree, w, tstate=None):
        return self._reduce_impl(tree, w, tstate)[0]

    def _advanced(self, tstate, mem):
        if not self.stateful:
            return None
        k = tstate.k if tstate is not None else jnp.zeros((), jnp.int32)
        tier = mem if self.needs_msg_shapes else (
            tstate.tier if tstate is not None else None)
        return TopoState(k=k + 1, tier=tier)

    def reduce_and_advance(self, tree, w, tstate=None):
        out, mem = self._reduce_impl(tree, w, tstate)
        return out, self._advanced(tstate, mem)

    # -------------------------------------------------------------- cohort
    supports_cohort = True

    def _seg0(self, idx, n_clients: int):
        """Each cohort member's GLOBAL first-tier aggregator id: the
        static full-population segment table gathered at the cohort's
        (traced) global ids."""
        tiers = self._tiers(n_clients)
        if not tiers:
            return None
        return self._segments(n_clients, tiers[0])[idx]

    def reduce_cohort(self, tree, w, idx, n_clients, tstate=None):
        return self._reduce_impl(tree, w, tstate,
                                 seg0=self._seg0(idx, n_clients),
                                 n_total=n_clients)[0]

    def reduce_cohort_and_advance(self, tree, w, idx, n_clients,
                                  tstate=None):
        out, mem = self._reduce_impl(tree, w, tstate,
                                     seg0=self._seg0(idx, n_clients),
                                     n_total=n_clients)
        return out, self._advanced(tstate, mem)

    # ----------------------------------------------------------- accounting
    def aggregator_hops(self, n_clients: int) -> tuple:
        tiers = self._tiers(n_clients)
        return tuple(
            (f"tier{i + 1}->" + ("root" if i == len(tiers) - 1
                                 else f"tier{i + 2}"), int(g))
            for i, g in enumerate(tiers))

    @property
    def tier_bits_per_coord(self) -> float:  # type: ignore[override]
        if self.tier_compression is None:
            return 32.0
        return float(self.tier_compression.bits_per_coord)


# -------------------------------------------------------------------- mixing
def _metropolis(n: int, edges: set) -> list:
    """Doubly-stochastic Metropolis–Hastings weights for an undirected
    graph: ``W_ij = 1 / (1 + max(d_i, d_j))`` on edges, diagonal absorbs
    the slack. Symmetric, nonnegative, rows and columns sum to 1."""
    deg = [0] * n
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    W = [[0.0] * n for _ in range(n)]
    for i, j in edges:
        wij = 1.0 / (1.0 + max(deg[i], deg[j]))
        W[i][j] = W[j][i] = wij
    for i in range(n):
        W[i][i] = 1.0 - sum(W[i])
    return W


@dataclasses.dataclass(frozen=True)
class Mixing(Topology):
    """Gossip aggregation through a doubly-stochastic matrix ``W``:
    client i receives ``sum_j W_ij w_j m_j / sum_j W_ij w_j`` — its
    weight-renormalized neighborhood mean — instead of the global mean.
    Build with :meth:`ring` / :meth:`torus` / :meth:`erdos_renyi`, or
    pass any doubly-stochastic ``w`` (nested tuples, so the spec stays
    hashable/jit-static like every engine knob).

    ``resample=True`` (Erdős–Rényi only) redraws the graph at every
    aggregation from a domain-separated PRNG stream keyed by the
    :class:`TopoState` round index — the stateful-topology path.

    ``lowering="sparse"`` replaces the dense N x N contraction with the
    padded neighbor-index exchange (gather + fixed-slot segment sum; the
    Pallas kernel behind ``use_kernel=True``) — same aggregation,
    O(E x D) cost; see the module docstring. ``max_degree=0`` sizes the
    table automatically (static graphs: the actual max degree; resampled
    graphs: ``n - 1``, the only cap that can contain every draw)."""

    w: tuple | None = None
    n: int = 0
    graph: str = "custom"
    p: float = 0.0
    seed: int = 0
    resample: bool = False
    lowering: str = "dense"
    max_degree: int = 0
    use_kernel: bool = False

    def __post_init__(self):
        if self.w is not None:
            object.__setattr__(self, "w", tuple(tuple(float(x) for x in r)
                                                for r in self.w))
            object.__setattr__(self, "n", len(self.w))
        if self.w is None and not self.resample:
            raise ValueError("Mixing needs a matrix (w=) or resample=True")
        if self.resample and not (0.0 < self.p <= 1.0):
            raise ValueError(f"resampled Erdos-Renyi needs 0 < p <= 1: {self.p}")
        if self.lowering not in ("dense", "sparse"):
            raise ValueError(f"unknown mixing lowering {self.lowering!r} "
                             "(dense | sparse)")
        if self.max_degree:
            if self.w is not None and self.max_degree < self._max_degree():
                raise ValueError(
                    f"max_degree={self.max_degree} overflows: the "
                    f"{self.graph} graph has a node of degree "
                    f"{self._max_degree()} (use max_degree=0 for auto)")
            if self.resample and self.max_degree < self.n - 1:
                raise ValueError(
                    "a resampled Erdos-Renyi graph can draw any degree up "
                    f"to n-1={self.n - 1}; max_degree={self.max_degree} "
                    "cannot bound it (use max_degree=0 for auto)")

    # ------------------------------------------------------------- builders
    @classmethod
    def ring(cls, n: int) -> "Mixing":
        if n < 2:
            raise ValueError(f"ring needs >= 2 nodes: {n}")
        edges = {(min(i, (i + 1) % n), max(i, (i + 1) % n)) for i in range(n)}
        return cls(w=tuple(map(tuple, _metropolis(n, edges))), graph="ring")

    @classmethod
    def torus(cls, n: int | None = None, shape: tuple | None = None) -> "Mixing":
        """2-D periodic grid; ``shape=(rows, cols)`` or the most-square
        factorization of ``n`` (prime ``n`` degenerates to a ring and is
        rejected — ask for ``ring`` explicitly)."""
        if shape is None:
            r = max(d for d in range(1, int(math.isqrt(n)) + 1) if n % d == 0)
            shape = (r, n // r)
        rows, cols = shape
        if n is not None and rows * cols != n:
            raise ValueError(f"torus shape {shape} has {rows * cols} nodes "
                             f"but n={n} was requested")
        if min(rows, cols) < 2:
            raise ValueError(
                f"torus needs both dims >= 2, got {shape} (use ring)")
        n = rows * cols
        edges = set()
        for i in range(rows):
            for j in range(cols):
                a = i * cols + j
                for b in (i * cols + (j + 1) % cols, ((i + 1) % rows) * cols + j):
                    if a != b:
                        edges.add((min(a, b), max(a, b)))
        return cls(w=tuple(map(tuple, _metropolis(n, edges))),
                   graph=f"torus{rows}x{cols}")

    @classmethod
    def erdos_renyi(cls, n: int, p: float, seed: int = 0,
                    resample: bool = False) -> "Mixing":
        """G(n, p) with Metropolis weights. ``resample=False`` samples
        ONE graph here (host-side, from ``seed``) and fixes it;
        ``resample=True`` defers sampling into the traced round, redrawn
        per aggregation (the TopoState-keyed stream)."""
        if resample:
            return cls(w=None, n=n, graph="er", p=p, seed=seed, resample=True)
        import numpy as np

        rng = np.random.default_rng(seed)
        edges = {(i, j) for i in range(n) for j in range(i + 1, n)
                 if rng.random() < p}
        return cls(w=tuple(map(tuple, _metropolis(n, edges))),
                   graph="er", p=p, seed=seed)

    # ---------------------------------------------------------------- state
    @property
    def stateful(self) -> bool:  # type: ignore[override]
        return self.resample

    # -------------------------------------------------------------- compute
    def _max_degree(self) -> int:
        """Actual max node degree of a static graph (off-diagonal support)."""
        return max(sum(1 for j, x in enumerate(row) if j != i and x != 0.0)
                   for i, row in enumerate(self.w))

    def _matrix(self, tstate, n: int, dtype):
        if not self.resample:
            return jnp.asarray(self.w, dtype=dtype)
        key = jax.random.fold_in(jax.random.key(self.seed), _TOPO_KEY_TAG)
        key = jax.random.fold_in(key, tstate.k)
        upper = jnp.triu(jax.random.bernoulli(key, self.p, (n, n)), k=1)
        adj = jnp.logical_or(upper, upper.T)
        deg = jnp.sum(adj, axis=1)
        mw = 1.0 / (1.0 + jnp.maximum(deg[:, None], deg[None, :]).astype(dtype))
        W = jnp.where(adj, mw, 0.0)
        return W + jnp.diag(1.0 - jnp.sum(W, axis=1))

    def _static_tables(self):
        """Padded neighbor tables from the fixed matrix, host-side: slot 0
        is the node itself (the Metropolis diagonal), then its neighbors;
        pad slots carry weight 0 and a self index (a safe gather the zero
        weight masks out)."""
        import numpy as np

        n = self.n
        W = np.asarray(self.w, dtype=np.float64)
        nbrs = [[j for j in range(n) if j != i and W[i, j] != 0.0]
                for i in range(n)]
        dmax = self.max_degree or max((len(v) for v in nbrs), default=0)
        idx = np.tile(np.arange(n, dtype=np.int32)[:, None], (1, dmax + 1))
        wgt = np.zeros((n, dmax + 1))
        for i, v in enumerate(nbrs):
            wgt[i, 0] = W[i, i]
            for s, j in enumerate(v):
                idx[i, s + 1] = j
                wgt[i, s + 1] = W[i, j]
        return idx, wgt

    def _resampled_tables(self, tstate, n: int, dtype):
        """Rebuild the padded neighbor tables INSIDE the traced round from
        the same TopoState-keyed stream as the dense ``_matrix`` — the
        table build is O(n^2) per round but independent of the model
        dimension, so the per-leaf exchange stays O(E x D)."""
        key = jax.random.fold_in(jax.random.key(self.seed), _TOPO_KEY_TAG)
        key = jax.random.fold_in(key, tstate.k)
        upper = jnp.triu(jax.random.bernoulli(key, self.p, (n, n)), k=1)
        adj = jnp.logical_or(upper, upper.T)
        deg = jnp.sum(adj, axis=1)
        # a node has at most n-1 neighbors: caps above that (a uniform cap
        # shared across graphs of varying n) just clamp to the full table.
        cap = min(self.max_degree or n - 1, n - 1)
        # stable argsort floats neighbor columns first (ascending id),
        # giving each row its neighbor list in the first `deg[i]` slots.
        order = jnp.argsort(~adj, axis=1, stable=True)[:, :cap]
        valid = jnp.arange(cap)[None, :] < deg[:, None]
        nd = jnp.maximum(deg[:, None], deg[order])
        wn = jnp.where(valid, 1.0 / (1.0 + nd.astype(dtype)), 0.0)
        selfw = 1.0 - jnp.sum(wn, axis=1)
        me = jnp.arange(n, dtype=order.dtype)[:, None]
        idx = jnp.concatenate([me, jnp.where(valid, order, me)], axis=1)
        wgt = jnp.concatenate([selfw[:, None], wn], axis=1)
        return idx, wgt

    def _reduce_sparse(self, tree, w, tstate):
        n = w.shape[0]
        if self.resample:
            idx, wgt = self._resampled_tables(tstate, n, w.dtype)
        else:
            idx_np, wgt_np = self._static_tables()
            idx = jnp.asarray(idx_np, jnp.int32)
            wgt = jnp.asarray(wgt_np, w.dtype)
        slots = idx.shape[1]
        wn = wgt * w[idx]                        # [n, S]: W_ij * w_j
        denom = jnp.sum(wn, axis=1)
        denom = jnp.where(denom > 0, denom, 1.0)

        def mean_leaf(a):
            wnl = wn.astype(a.dtype)
            flat = a.reshape(n, -1)
            if self.use_kernel:
                from repro.kernels import ops as kops

                contrib = flat[idx.reshape(-1)] * wnl.reshape(-1, 1)
                out = kops.gossip_reduce(contrib, slots=slots)
            elif slots <= _UNROLL_SLOTS:
                # the fixed-slot segment reduction, unrolled over the S
                # slots so XLA fuses each row gather with its fma instead
                # of materializing the [n*S, D] edge tensor and paying a
                # scatter (measured ~25x faster on CPU at N=1024; same
                # sum — pinned against jax.ops.segment_sum and the Pallas
                # kernel in tests/test_gossip_kernel.py).
                out = wnl[:, 0:1] * flat[idx[:, 0]]
                for s in range(1, slots):
                    out = out + wnl[:, s:s + 1] * flat[idx[:, s]]
            else:
                # wide tables (resampled graphs capped at n-1): keep the
                # graph small with one gather + one segment_sum.
                contrib = flat[idx.reshape(-1)] * wnl.reshape(-1, 1)
                seg = jnp.repeat(jnp.arange(n), slots)
                out = jax.ops.segment_sum(contrib, seg, num_segments=n,
                                          indices_are_sorted=True)
            out = out / denom.astype(a.dtype)[:, None]
            return out.reshape(a.shape)

        return jax.tree.map(mean_leaf, tree)

    def reduce(self, tree, w, tstate=None):
        n = w.shape[0]
        if self.w is not None and self.n != n:
            raise ValueError(f"mixing matrix is {self.n}x{self.n}, "
                             f"state has {n} clients")
        if self.lowering == "sparse":
            return self._reduce_sparse(tree, w, tstate)

        def mean_leaf(a):
            W = self._matrix(tstate, n, a.dtype)
            Ww = W * w.astype(a.dtype)[None, :]       # row i: W_ij * w_j
            denom = jnp.sum(Ww, axis=1)
            denom = jnp.where(denom > 0, denom, 1.0)
            flat = a.reshape(n, -1)
            out = (Ww @ flat) / denom[:, None]
            return out.reshape(a.shape)

        return jax.tree.map(mean_leaf, tree)

    # ----------------------------------------------------------- accounting
    def _directed_edges(self, n: int) -> float:
        if self.resample:
            return n * (n - 1) * self.p  # expected
        return sum(1 for i, row in enumerate(self.w)
                   for j, x in enumerate(row) if i != j and x != 0.0)

    def client_up_mult(self, n_clients: int) -> float:
        """Gossip clients transmit their wire message to each neighbor:
        the first (and only) hop carries one message per directed edge —
        the same edges whichever lowering executes the exchange."""
        return self._directed_edges(n_clients) / n_clients

    def broadcast_mult(self, n_clients: int) -> float:
        return 0.0  # no server, no broadcast — the exchange is the uplink

    def validate(self, n_clients: int) -> None:
        if self.n and self.n != n_clients:
            raise ValueError(f"{self.graph} mixing is over {self.n} nodes but "
                             f"the algorithm has {n_clients} clients")

    # ------------------------------------------------------------- analysis
    @property
    def spectral_gap(self) -> float | None:
        """``1 - |lambda_2(W)|`` — the consensus rate driver (1.0 = one-shot
        averaging, -> 0 = disconnected). None for resampled graphs (no
        single matrix to analyze)."""
        if self.w is None:
            return None
        import numpy as np

        lam = np.sort(np.abs(np.linalg.eigvalsh(np.asarray(self.w))))
        return float(1.0 - lam[-2])


# ------------------------------------------------------------------- parsing
def _parse_tier_compression(tier_compression):
    """Normalize a tier-compression spec (string / Compressor / None) with
    the engine's default error-feedback policy (auto-EF around biased
    stateless compressors; ``shift:`` / ``ef:`` prefixes pass through)."""
    comp = compressor_from_spec(tier_compression)
    return auto_wrap(comp)


def parse_topology(spec, n_clients: int, seed: int = 0,
                   tier_compression=None):
    """Parse a topology spec; returns ``None`` for star specs (``star`` /
    ``none`` / ``""``) so ``with_topology`` can be an exact no-op at the
    identity setting, like every other transform factory.

    Grammar: ``star`` | ``hier:g8`` / ``hier:8`` / ``hier:16x4`` (tree
    tiers, coarsest last) | ``ring`` | ``torus`` / ``torus:2x5`` |
    ``er:0.4`` (one fixed G(n,p) graph) | ``er:0.4:t`` (resampled every
    round — the stateful path). Gossip specs take a trailing
    ``:sparse`` (``ring:sparse``, ``torus:2x5:sparse``,
    ``er:0.4:t:sparse``) selecting the padded neighbor-exchange
    lowering. ``tier_compression`` (a compressor spec string or object;
    hierarchies only) re-compresses interior tier uplinks."""
    tier = _parse_tier_compression(tier_compression)

    def _check_tier(topo):
        if tier is not None and not isinstance(topo, Hierarchical):
            raise ValueError(
                "tier_compression re-compresses hierarchical aggregator "
                f"tiers; topology {spec!r} has none (gossip edges carry "
                "the client compressor's wire message already)")

    if spec is None:
        _check_tier(None)
        return None
    if isinstance(spec, Topology):
        if isinstance(spec, Star):
            _check_tier(None)
            return None
        _check_tier(spec)
        if tier is not None:
            spec = dataclasses.replace(spec, tier_compression=tier, seed=seed)
        spec.validate(n_clients)
        return spec
    s = str(spec).strip().lower()
    if s in ("", "star", "none", "off"):
        _check_tier(None)
        return None
    lowering = "dense"
    parts = s.split(":")
    if parts[-1] in ("sparse", "dense"):
        lowering, parts = parts[-1], parts[:-1]
        s = ":".join(parts)
    name, _, arg = s.partition(":")
    if name == "hier":
        arg = arg.lstrip("g")
        try:
            groups = tuple(int(tok) for tok in arg.split("x") if tok)
        except ValueError:
            groups = ()
        if not groups:
            raise ValueError(f"bad hierarchical spec {spec!r} "
                             "(try hier:g8 or hier:16x4)")
        topo = Hierarchical(groups, tier_compression=tier, seed=seed)
    elif name == "ring":
        topo = Mixing.ring(n_clients)
    elif name == "torus":
        shape = None
        if arg:
            r, _, c = arg.partition("x")
            shape = (int(r), int(c))
            if shape[0] * shape[1] != n_clients:
                raise ValueError(f"torus {shape} has {shape[0] * shape[1]} "
                                 f"nodes but the algorithm has {n_clients}")
        topo = Mixing.torus(n_clients, shape=shape)
    elif name == "er":
        p, _, flag = arg.partition(":")
        topo = Mixing.erdos_renyi(n_clients, float(p), seed=seed,
                                  resample=flag in ("t", "resample"))
    else:
        raise ValueError(f"unknown topology spec {spec!r} "
                         "(try star, hier:g8, ring, ring:sparse, torus, "
                         "er:0.4)")
    if lowering == "sparse":
        if not isinstance(topo, Mixing):
            raise ValueError(f"the :sparse lowering applies to gossip "
                             f"(ring/torus/er) topologies, not {spec!r}")
        topo = dataclasses.replace(topo, lowering="sparse")
    _check_tier(topo)
    topo.validate(n_clients)
    return topo
