"""Topology: WHERE the aggregation happens — star, hierarchical, gossip.

The paper's round model (and everything in this repo up to now) is the
degenerate STAR topology: one server, flat all-to-one aggregation — every
client's message crosses the network to a single root, which is the
scaling bottleneck once "clients" means millions of edge devices. FedCET
itself descends from the DECENTRALIZED optimizer NIDS, where there is no
server at all: each node mixes with its graph neighbors through a
doubly-stochastic matrix. This module makes the aggregation geometry a
first-class scenario axis on the engine's message/aggregate seam (the
same seam ``with_compression`` / ``with_participation`` / ``with_delay``
ride):

* :class:`Star` — the flat all-to-one mean, exactly today's engine. The
  ``with_topology`` factory returns the algorithm object UNCHANGED for
  star specs (the identity-shortcut discipline every transform factory
  follows); attaching the ``Star`` machinery explicitly is pinned
  trajectory-identical (<= 1e-12) to the bare engine in
  tests/test_topology.py.
* :class:`Hierarchical` — 2-or-more-level tree aggregation: EDGE
  aggregators each take a contiguous block of clients, compute the
  weighted partial mean of their block, and forward ONE message up the
  tree; the root combines tier aggregates into the global mean. The
  value is numerically the star mean up to float reassociation (the
  grouped sums associate differently — measured ~1e-14 trajectory
  drift, NOT bit-identical), but the traffic shape changes completely:
  the root ingests ``groups[-1]`` messages instead of ``n_clients``
  (the production scaling story), and comm accounting bills each hop
  separately — see `Per-hop accounting` below.
* :class:`Mixing` — no server: client i receives the W-weighted
  neighborhood mean ``sum_j W_ij m_j`` of a doubly-stochastic gossip
  matrix (ring, torus, Erdős–Rényi; Metropolis–Hastings weights). The
  aggregate is PER-CLIENT (a stacked ``[clients, ...]`` tree, not a
  broadcast ``[1, ...]`` mean); every engine spec already broadcasts
  ``msg_bar`` leaf-wise, so the same ``server_aggregate`` math runs
  decentralized unchanged. Column-stochasticity is what preserves
  FedCET's redistributive invariant: ``sum_i (m_i - (W m)_i) = 0``, so
  the drift updates stay mean-zero under gossip. Composed with the
  :class:`repro.core.baselines.nids.NIDS` spec this implements NIDS
  proper — closing the loop to the paper's origin.

Weighted reduction contract
---------------------------
A topology reduces a stacked ``[clients, ...]`` tree under per-client
weights ``w`` (``reduce(tree, w, tstate)``): uniform weights for plain
rounds, the participation mask under client sampling, and the stale
policy's ``(age, fresh)`` weights under ``with_delay`` — the SAME weight
vector the star engine feeds ``weighted_client_mean``, so every topology
composes with every transform with no algorithm-side code. Star and
Hierarchical return the ``[1, ...]`` weighted mean (hierarchical
grouping of a weighted mean is exact regrouping — same value, different
association); Mixing row-renormalizes ``W * w`` so absent/stale
neighbors drop out of each node's neighborhood mean.

Topology state
--------------
Topologies that evolve per round (an Erdős–Rényi graph resampled every
aggregation, keyed by a domain-separated PRNG stream) carry a
:class:`TopoState` (the mixing round index) in the ``EngineState``
extras slot, just before ``DelayState`` — checkpointed with the run,
restart-stable, threaded through the AOT ``abstract_state`` /
``state_shardings`` path in launch/train.py. Static topologies are
stateless frozen dataclasses like every other engine knob.

Per-hop accounting
------------------
A topology declares its traffic shape instead of letting the meter
assume ``n_clients`` flat uplinks:

* ``client_up_mult(n)`` — uplink messages per client on the FIRST hop
  (1 for star/hierarchical; the node degree for gossip, where a client
  transmits its wire message to each neighbor);
* ``aggregator_hops(n)`` — ``(label, messages)`` per aggregator tier
  (edge->root re-transmissions). These carry DENSE f32 partial
  aggregates: the client-side compressor stack applies to the
  client->edge hop only (re-compressing partial means at interior tiers
  is future work, noted in ARCHITECTURE.md);
* ``broadcast_mult(n)`` — downlink client-hop multiplier (0 for gossip:
  there is no broadcast; the exchange is billed as uplink edges).

``CommMeter.for_params(algo=...)`` and ``comm_bits_per_round`` /
``comm_hops_per_round`` (repro/core/comm.py) fold these in, so
``hier:g8`` shows the root ingesting 8 messages while the client tier
still pays the compressed wire width x the delay duty cycle.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.staleness import weighted_client_mean

__all__ = [
    "Hierarchical",
    "Mixing",
    "Star",
    "TopoState",
    "Topology",
    "parse_topology",
]

#: domain-separation tag folded into resampled-graph keys so the topology
#: stream never collides with the participation (bare seed), compression
#: (0x7A11A5 + index) or delay (0x57A1E) schedules at the default seed=0.
_TOPO_KEY_TAG = 0x70_70


class TopoState(NamedTuple):
    """Per-run topology state riding in ``EngineState`` extras (just
    before the delay buffer when both are attached): the aggregation
    round index ``k`` that keys time-varying mixing matrices. Scalar,
    checkpointed, restart-stable."""

    k: jax.Array  # int32 aggregation counter (init included)


# ------------------------------------------------------------------ protocol
@dataclasses.dataclass(frozen=True)
class Topology:
    """Base: a weighted cross-client reduction with a declared traffic
    shape. Subclasses implement ``reduce`` and override the accounting
    hooks; stateful topologies also override ``init_state``/``advance``."""

    #: does this topology carry a TopoState in EngineState extras?
    stateful = False

    # --------------------------------------------------------------- state
    def init_state(self) -> TopoState | None:
        return TopoState(k=jnp.zeros((), jnp.int32)) if self.stateful else None

    def advance(self, tstate: TopoState | None) -> TopoState | None:
        return TopoState(k=tstate.k + 1) if self.stateful else None

    # -------------------------------------------------------------- compute
    def reduce(self, tree, w: jax.Array, tstate: TopoState | None = None):
        """Aggregate a stacked ``[clients, ...]`` tree under per-client
        weights ``w`` — ``[1, ...]`` (star/hierarchical mean) or
        ``[clients, ...]`` (per-client gossip neighborhood means)."""
        raise NotImplementedError

    # ----------------------------------------------------------- accounting
    def client_up_mult(self, n_clients: int) -> float:
        """Uplink messages per client on the first hop (gossip: degree)."""
        del n_clients
        return 1.0

    def aggregator_hops(self, n_clients: int) -> tuple:
        """``(label, messages)`` per aggregator tier above the clients."""
        del n_clients
        return ()

    def broadcast_mult(self, n_clients: int) -> float:
        """Downlink client-hop multiplier (0 = no broadcast at all)."""
        del n_clients
        return 1.0

    def validate(self, n_clients: int) -> None:
        """Raise if the topology cannot serve ``n_clients`` nodes."""
        del n_clients


# ---------------------------------------------------------------------- star
@dataclasses.dataclass(frozen=True)
class Star(Topology):
    """Flat all-to-one aggregation — the engine's native geometry, kept
    as an explicit object so tests can attach the topology MACHINERY and
    pin it trajectory-identical to the bare engine. ``with_topology``
    never attaches it (star specs are identity shortcuts)."""

    def reduce(self, tree, w, tstate=None):
        del tstate
        return weighted_client_mean(tree, w)


# -------------------------------------------------------------- hierarchical
@dataclasses.dataclass(frozen=True)
class Hierarchical(Topology):
    """Tree aggregation: ``groups = (g1, g2, ...)`` aggregators per tier,
    clients in contiguous near-equal blocks. ``(8,)`` is the 2-level
    edge+root production shape (8 edge aggregators, root ingests 8
    messages); ``(16, 4)`` adds a mid tier. Each tier forwards weighted
    partial means with their weight sums, so the root value equals the
    star weighted mean exactly up to float reassociation — whether
    FedCET's exactness survives the regrouped arithmetic (it does,
    ~1e-14, even under a shift:q8 client uplink) is pinned in
    benchmarks/topology_sweep.py."""

    groups: tuple

    def __post_init__(self):
        g = (self.groups,) if isinstance(self.groups, int) else tuple(self.groups)
        object.__setattr__(self, "groups", g)
        if not g or any(int(x) < 1 for x in g):
            raise ValueError(f"need >= 1 aggregator per tier: {g}")
        if any(b >= a for a, b in zip(g, g[1:])):
            raise ValueError(f"tier sizes must strictly decrease: {g}")

    def validate(self, n_clients: int) -> None:
        if self.groups[0] > n_clients:
            raise ValueError(
                f"hierarchical tier of {self.groups[0]} aggregators over "
                f"only {n_clients} clients (want fan-in > 1)")

    @staticmethod
    def _segments(n_in: int, n_out: int) -> jax.Array:
        """Contiguous near-equal block assignment ``[n_in] -> n_out``."""
        return jnp.asarray([i * n_out // n_in for i in range(n_in)], jnp.int32)

    def reduce(self, tree, w, tstate=None):
        del tstate
        n = w.shape[0]
        tiers = [g for g in self.groups if g < n]  # degenerate tiers drop out

        def mean_leaf(a):
            vals = a
            wt = w.astype(a.dtype)
            cur = n
            for g in tiers:
                ids = self._segments(cur, g)
                wb = wt.reshape((-1,) + (1,) * (vals.ndim - 1))
                sums = jax.ops.segment_sum(vals * wb, ids, num_segments=g)
                wsum = jax.ops.segment_sum(wt, ids, num_segments=g)
                denom = jnp.where(wsum > 0, wsum, 1.0)
                # the edge aggregator transmits its PARTIAL MEAN (one
                # message regardless of block size) + the weight mass.
                vals = sums / denom.reshape((-1,) + (1,) * (vals.ndim - 1))
                wt, cur = wsum, g
            wb = wt.reshape((-1,) + (1,) * (vals.ndim - 1))
            total = jnp.sum(wt)
            denom = jnp.where(total > 0, total, jnp.ones((), a.dtype))
            return jnp.sum(vals * wb, axis=0, keepdims=True) / denom

        return jax.tree.map(mean_leaf, tree)

    def aggregator_hops(self, n_clients: int) -> tuple:
        tiers = [g for g in self.groups if g < n_clients]
        return tuple(
            (f"tier{i + 1}->" + ("root" if i == len(tiers) - 1
                                 else f"tier{i + 2}"), int(g))
            for i, g in enumerate(tiers))


# -------------------------------------------------------------------- mixing
def _metropolis(n: int, edges: set) -> list:
    """Doubly-stochastic Metropolis–Hastings weights for an undirected
    graph: ``W_ij = 1 / (1 + max(d_i, d_j))`` on edges, diagonal absorbs
    the slack. Symmetric, nonnegative, rows and columns sum to 1."""
    deg = [0] * n
    for i, j in edges:
        deg[i] += 1
        deg[j] += 1
    W = [[0.0] * n for _ in range(n)]
    for i, j in edges:
        wij = 1.0 / (1.0 + max(deg[i], deg[j]))
        W[i][j] = W[j][i] = wij
    for i in range(n):
        W[i][i] = 1.0 - sum(W[i])
    return W


@dataclasses.dataclass(frozen=True)
class Mixing(Topology):
    """Gossip aggregation through a doubly-stochastic matrix ``W``:
    client i receives ``sum_j W_ij w_j m_j / sum_j W_ij w_j`` — its
    weight-renormalized neighborhood mean — instead of the global mean.
    Build with :meth:`ring` / :meth:`torus` / :meth:`erdos_renyi`, or
    pass any doubly-stochastic ``w`` (nested tuples, so the spec stays
    hashable/jit-static like every engine knob).

    ``resample=True`` (Erdős–Rényi only) redraws the graph at every
    aggregation from a domain-separated PRNG stream keyed by the
    :class:`TopoState` round index — the stateful-topology path."""

    w: tuple | None = None
    n: int = 0
    graph: str = "custom"
    p: float = 0.0
    seed: int = 0
    resample: bool = False

    def __post_init__(self):
        if self.w is not None:
            object.__setattr__(self, "w", tuple(tuple(float(x) for x in r)
                                                for r in self.w))
            object.__setattr__(self, "n", len(self.w))
        if self.w is None and not self.resample:
            raise ValueError("Mixing needs a matrix (w=) or resample=True")
        if self.resample and not (0.0 < self.p <= 1.0):
            raise ValueError(f"resampled Erdos-Renyi needs 0 < p <= 1: {self.p}")

    # ------------------------------------------------------------- builders
    @classmethod
    def ring(cls, n: int) -> "Mixing":
        if n < 2:
            raise ValueError(f"ring needs >= 2 nodes: {n}")
        edges = {(min(i, (i + 1) % n), max(i, (i + 1) % n)) for i in range(n)}
        return cls(w=tuple(map(tuple, _metropolis(n, edges))), graph="ring")

    @classmethod
    def torus(cls, n: int | None = None, shape: tuple | None = None) -> "Mixing":
        """2-D periodic grid; ``shape=(rows, cols)`` or the most-square
        factorization of ``n`` (prime ``n`` degenerates to a ring and is
        rejected — ask for ``ring`` explicitly)."""
        if shape is None:
            r = max(d for d in range(1, int(math.isqrt(n)) + 1) if n % d == 0)
            shape = (r, n // r)
        rows, cols = shape
        if min(rows, cols) < 2:
            raise ValueError(
                f"torus needs both dims >= 2, got {shape} (use ring)")
        n = rows * cols
        edges = set()
        for i in range(rows):
            for j in range(cols):
                a = i * cols + j
                for b in (i * cols + (j + 1) % cols, ((i + 1) % rows) * cols + j):
                    if a != b:
                        edges.add((min(a, b), max(a, b)))
        return cls(w=tuple(map(tuple, _metropolis(n, edges))),
                   graph=f"torus{rows}x{cols}")

    @classmethod
    def erdos_renyi(cls, n: int, p: float, seed: int = 0,
                    resample: bool = False) -> "Mixing":
        """G(n, p) with Metropolis weights. ``resample=False`` samples
        ONE graph here (host-side, from ``seed``) and fixes it;
        ``resample=True`` defers sampling into the traced round, redrawn
        per aggregation (the TopoState-keyed stream)."""
        if resample:
            return cls(w=None, n=n, graph="er", p=p, seed=seed, resample=True)
        import numpy as np

        rng = np.random.default_rng(seed)
        edges = {(i, j) for i in range(n) for j in range(i + 1, n)
                 if rng.random() < p}
        return cls(w=tuple(map(tuple, _metropolis(n, edges))),
                   graph="er", p=p, seed=seed)

    # ---------------------------------------------------------------- state
    @property
    def stateful(self) -> bool:  # type: ignore[override]
        return self.resample

    # -------------------------------------------------------------- compute
    def _matrix(self, tstate, n: int, dtype):
        if not self.resample:
            return jnp.asarray(self.w, dtype=dtype)
        key = jax.random.fold_in(jax.random.key(self.seed), _TOPO_KEY_TAG)
        key = jax.random.fold_in(key, tstate.k)
        upper = jnp.triu(jax.random.bernoulli(key, self.p, (n, n)), k=1)
        adj = jnp.logical_or(upper, upper.T)
        deg = jnp.sum(adj, axis=1)
        mw = 1.0 / (1.0 + jnp.maximum(deg[:, None], deg[None, :]).astype(dtype))
        W = jnp.where(adj, mw, 0.0)
        return W + jnp.diag(1.0 - jnp.sum(W, axis=1))

    def reduce(self, tree, w, tstate=None):
        n = w.shape[0]
        if self.w is not None and self.n != n:
            raise ValueError(f"mixing matrix is {self.n}x{self.n}, "
                             f"state has {n} clients")

        def mean_leaf(a):
            W = self._matrix(tstate, n, a.dtype)
            Ww = W * w.astype(a.dtype)[None, :]       # row i: W_ij * w_j
            denom = jnp.sum(Ww, axis=1)
            denom = jnp.where(denom > 0, denom, 1.0)
            flat = a.reshape(n, -1)
            out = (Ww @ flat) / denom[:, None]
            return out.reshape(a.shape)

        return jax.tree.map(mean_leaf, tree)

    # ----------------------------------------------------------- accounting
    def _directed_edges(self, n: int) -> float:
        if self.resample:
            return n * (n - 1) * self.p  # expected
        return sum(1 for i, row in enumerate(self.w)
                   for j, x in enumerate(row) if i != j and x != 0.0)

    def client_up_mult(self, n_clients: int) -> float:
        """Gossip clients transmit their wire message to each neighbor:
        the first (and only) hop carries one message per directed edge."""
        return self._directed_edges(n_clients) / n_clients

    def broadcast_mult(self, n_clients: int) -> float:
        return 0.0  # no server, no broadcast — the exchange is the uplink

    def validate(self, n_clients: int) -> None:
        if self.n and self.n != n_clients:
            raise ValueError(f"{self.graph} mixing is over {self.n} nodes but "
                             f"the algorithm has {n_clients} clients")

    # ------------------------------------------------------------- analysis
    @property
    def spectral_gap(self) -> float | None:
        """``1 - |lambda_2(W)|`` — the consensus rate driver (1.0 = one-shot
        averaging, -> 0 = disconnected). None for resampled graphs (no
        single matrix to analyze)."""
        if self.w is None:
            return None
        import numpy as np

        lam = np.sort(np.abs(np.linalg.eigvalsh(np.asarray(self.w))))
        return float(1.0 - lam[-2])


# ------------------------------------------------------------------- parsing
def parse_topology(spec, n_clients: int, seed: int = 0):
    """Parse a topology spec; returns ``None`` for star specs (``star`` /
    ``none`` / ``""``) so ``with_topology`` can be an exact no-op at the
    identity setting, like every other transform factory.

    Grammar: ``star`` | ``hier:g8`` / ``hier:8`` / ``hier:16x4`` (tree
    tiers, coarsest last) | ``ring`` | ``torus`` / ``torus:2x5`` |
    ``er:0.4`` (one fixed G(n,p) graph) | ``er:0.4:t`` (resampled every
    round — the stateful path)."""
    if spec is None:
        return None
    if isinstance(spec, Topology):
        if isinstance(spec, Star):
            return None
        spec.validate(n_clients)
        return spec
    s = str(spec).strip().lower()
    if s in ("", "star", "none", "off"):
        return None
    name, _, arg = s.partition(":")
    if name == "hier":
        arg = arg.lstrip("g")
        try:
            groups = tuple(int(tok) for tok in arg.split("x") if tok)
        except ValueError:
            groups = ()
        if not groups:
            raise ValueError(f"bad hierarchical spec {spec!r} "
                             "(try hier:g8 or hier:16x4)")
        topo = Hierarchical(groups)
    elif name == "ring":
        topo = Mixing.ring(n_clients)
    elif name == "torus":
        shape = None
        if arg:
            r, _, c = arg.partition("x")
            shape = (int(r), int(c))
            if shape[0] * shape[1] != n_clients:
                raise ValueError(f"torus {shape} has {shape[0] * shape[1]} "
                                 f"nodes but the algorithm has {n_clients}")
        topo = Mixing.torus(n_clients, shape=shape)
    elif name == "er":
        p, _, flag = arg.partition(":")
        topo = Mixing.erdos_renyi(n_clients, float(p), seed=seed,
                                  resample=flag in ("t", "resample"))
    else:
        raise ValueError(f"unknown topology spec {spec!r} "
                         "(try star, hier:g8, ring, torus, er:0.4)")
    topo.validate(n_clients)
    return topo
