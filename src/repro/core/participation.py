"""Partial client participation (beyond-paper).

The paper assumes full participation (every client contributes to every
aggregation). Real federations sample clients. This module adds
participation-masked rounds for FedCET:

* a participation mask m in {0,1}^N is drawn per round (deterministic from
  the round index);
* absent clients freeze (no local steps, no state change) — they neither
  compute nor transmit;
* the server averages v over PRESENT clients only, and only present
  clients apply the aggregation update. The drift updates of present
  clients use deviations from the present-mean, so sum_i d_i stays zero
  across the federation (the Lemma-2 fixed-point structure is preserved;
  `tests/test_participation.py` checks the invariant under random masks).

Empirically (tests): with participation >= 0.5 on the paper's problem the
iterates still converge linearly to the exact optimum, at proportionally
lower bytes/round; very low participation slows convergence but does not
bias it. The paper's theory does not cover this regime — the tests document
measured behavior, not a claimed guarantee.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.api import GradFn, vmap_grads
from repro.core.fedcet import FedCET, FedCETState


def participation_mask(key, n_clients: int, rate: float) -> jax.Array:
    """At least one client participates; expected fraction = rate."""
    m = jax.random.bernoulli(key, rate, (n_clients,))
    # guarantee non-empty participation: force client argmax(uniform) in
    first = jax.nn.one_hot(jax.random.randint(key, (), 0, n_clients),
                           n_clients, dtype=bool)
    return jnp.where(jnp.any(m), m, first)


@dataclasses.dataclass(frozen=True)
class FedCETPartial(FedCET):
    """FedCET with per-round client sampling."""

    participation: float = 1.0
    seed: int = 0
    name: str = "fedcet_partial"

    def _masked_mean(self, tree, mask):
        w = mask.astype(jnp.float32)
        denom = jnp.maximum(jnp.sum(w), 1.0)

        def mean_leaf(a):
            wb = w.reshape((-1,) + (1,) * (a.ndim - 1)).astype(a.dtype)
            return jnp.sum(a * wb, axis=0, keepdims=True) / denom.astype(a.dtype)

        return jax.tree.map(mean_leaf, tree)

    def _apply_masked(self, new, old, mask):
        def sel(n, o):
            mb = mask.reshape((-1,) + (1,) * (n.ndim - 1))
            return jnp.where(mb, n, o)

        return jax.tree.map(sel, new, old)

    def round(self, grad_fn: GradFn, state: FedCETState, batches) -> FedCETState:
        gf = vmap_grads(grad_fn, spmd_axis_name=(self.spmd_client_axes or None))
        # per-round mask derived from the iteration counter in the state
        key = jax.random.fold_in(jax.random.key(self.seed),
                                 jnp.asarray(state.t, jnp.int32))
        mask = participation_mask(key, self.n_clients, self.participation)

        frozen = state
        # local steps (computed for all, applied to present clients only —
        # in a real deployment absent clients simply don't run; here the
        # masking keeps the computation jit-static)
        if self.tau > 1:
            local_b = jax.tree.map(lambda b: b[: self.tau - 1], batches)

            def body(s, b):
                return self._local_step(gf, s, b), None

            state, _ = jax.lax.scan(body, state, local_b)
        last_b = jax.tree.map(lambda b: b[self.tau - 1], batches)
        g = gf(state.x, last_b)
        v = self._v(state.x, g, state.d)
        v_bar = self._masked_mean(jax.tree.map(
            lambda a, m=mask: a * m.reshape((-1,) + (1,) * (a.ndim - 1)).astype(a.dtype), v), mask)
        ca = self.c * self.alpha
        d_next = jax.tree.map(lambda dd, vv, vb: dd + self.c * (vv - vb),
                              state.d, v, v_bar)
        x_next = jax.tree.map(lambda vv, vb: vv - ca * (vv - vb), v, v_bar)
        new = FedCETState(x=x_next, d=d_next, t=state.t + self.tau)
        # absent clients keep their pre-round state entirely
        return FedCETState(
            x=self._apply_masked(new.x, frozen.x, mask),
            d=self._apply_masked(new.d, frozen.d, mask),
            t=new.t,
        )
