"""Partial client participation (beyond-paper).

The paper assumes full participation (every client contributes to every
aggregation). Real federations sample clients. Since the unified round
engine this is a generic composition — ``with_participation`` (in
repro/core/engine.py) wraps ANY engine algorithm:

* a participation mask m in {0,1}^N is drawn per round (deterministic from
  the state's step counter, which the engine advances by exactly tau per
  round; the Bernoulli draw and the non-empty fallback use independent
  subkeys);
* absent clients freeze (no local steps, no state change) — they neither
  compute nor transmit;
* the server averages the message over PRESENT clients only, and only
  present clients apply the aggregation update. For FedCET the drift
  updates of present clients use deviations from the present-mean, so
  sum_i d_i stays zero across the federation (the Lemma-2 fixed-point
  structure is preserved; `tests/test_participation.py` checks the
  invariant under random masks).

:func:`FedCETPartial` remains as construction sugar for the FedCET case.

Empirically (tests): with participation >= 0.5 on the paper's problem the
iterates still converge linearly to the exact optimum, at proportionally
lower bytes/round; very low participation slows convergence but does not
bias it. The paper's theory does not cover this regime — the tests document
measured behavior, not a claimed guarantee.
"""

from __future__ import annotations

from repro.core.engine import (
    ClientSampling,
    RoundEngine,
    masked_client_mean,
    participation_mask,
    select_clients,
    with_participation,
)
from repro.core.fedcet import FedCET

__all__ = [
    "ClientSampling",
    "FedCETPartial",
    "masked_client_mean",
    "participation_mask",
    "select_clients",
    "with_participation",
]


def FedCETPartial(alpha: float, c: float, tau: int, n_clients: int,
                  participation: float = 1.0, seed: int = 0,
                  name: str = "fedcet_partial", **engine_kw) -> RoundEngine:
    """FedCET with per-round client sampling: ``with_participation`` over
    the FedCET spec. ``participation=1.0`` is an exact no-op — the returned
    algorithm IS plain FedCET."""
    base = FedCET(alpha=alpha, c=c, tau=tau, n_clients=n_clients, name=name,
                  **engine_kw)
    return with_participation(base, participation, seed=seed)
