"""Sequential (single-host) federated simulation driver.

Runs any FederatedAlgorithm against the paper's quadratic problem (or any
(grad_fn, batches) pair) for K communication rounds through the shared
``engine.run_rounds`` scan — so the CPU repro of Fig. 1 runs in
milliseconds, and the identical ``algo.round`` is what the distributed
launcher jits onto the production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.engine import run_rounds
from repro.core.telemetry import split_metrics
from repro.data.quadratic import QuadraticProblem


@dataclasses.dataclass(frozen=True)
class SimResult:
    errors: jax.Array        # [rounds+1] e(k) = ||mean_i x_i(k tau) - x*||
    state: Any               # final algorithm state
    bytes_per_round: int     # per the algorithm's declared vectors
    #: stacked per-round telemetry series (dict of [rounds] arrays) when
    #: the algorithm has ``with_telemetry`` attached, else None. Feed it
    #: to ``repro.core.telemetry.drain`` for sink/monitor processing.
    telemetry: Any = None

    @property
    def final_error(self) -> float:
        return float(self.errors[-1])


def simulate_quadratic(algo, problem: QuadraticProblem, rounds: int,
                       *, x0: jax.Array | None = None) -> SimResult:
    """Reproduces the paper's §IV protocol: full-batch gradients, error
    measured as e(k) = || (1/N) sum_i x_i(k tau) - x* ||."""
    if x0 is None:
        x0 = jnp.zeros((problem.dim,), dtype=problem.b.dtype)
    grad_fn = jax.grad(problem.client_loss)
    batches = problem.stacked_batches(algo.tau)
    init_batch = jax.tree.map(lambda b: b[0], batches)
    x_star = problem.x_star

    state0 = algo.init(grad_fn, x0, init_batch)

    def err(state) -> jax.Array:
        return jnp.linalg.norm(algo.global_params(state) - x_star)

    final_state, ys = run_rounds(algo, grad_fn, state0, batches,
                                 rounds=rounds, metric_fn=err)
    errs, telemetry = split_metrics(algo, ys)
    errors = jnp.concatenate([err(state0)[None], errs])
    n_bytes = (algo.vectors_up + algo.vectors_down) * problem.dim * 4 * problem.n_clients
    return SimResult(errors=errors, state=final_state, bytes_per_round=n_bytes,
                     telemetry=telemetry)


def paper_fig1_algorithms(problem: QuadraticProblem, tau: int = 2):
    """The four algorithms of Fig. 1 (+ FedAvg as the drift illustration),
    with the exact learning-rate rules the paper prescribes."""
    from repro.core.baselines import FedAvg, FedTrack, Scaffold
    from repro.core.fedcet import FedCET, max_weight_c
    from repro.core.lr_search import lr_search

    mu, L, n = problem.mu, problem.L, problem.n_clients
    alpha = lr_search(mu, L, tau)  # Algorithm 1, h = 0.001 * alpha_0
    return {
        "fedcet": FedCET(alpha=alpha, c=max_weight_c(mu, alpha), tau=tau, n_clients=n),
        "fedtrack": FedTrack(alpha=1.0 / (18.0 * tau * L), tau=tau, n_clients=n),
        "scaffold": Scaffold(alpha_l=1.0 / (81.0 * tau * L), alpha_g=1.0, tau=tau,
                             n_clients=n),
        "fedavg": FedAvg(alpha=1.0 / (2.0 * tau * L), tau=tau, n_clients=n),
    }
