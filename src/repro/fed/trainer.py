"""FedTrainer — the production training harness around FederatedAlgorithm.

Responsibilities a real deployment needs beyond the algorithm step:

* round orchestration with a pluggable data source (round -> batches),
  running through the shared scan driver (``engine.make_round_runner``):
  rounds between eval/checkpoint boundaries execute as ONE jitted
  ``lax.scan`` segment rather than a python-level round loop,
* periodic evaluation: global-model loss AND per-client local losses (the
  heterogeneity gap — mean local minus global — is the practical drift
  diagnostic). In the default (train-batch) mode the losses are computed
  INSIDE the round scan via the runner's per-round metric hook, so a
  segment never leaves the device between eval boundaries — ``fit`` pulls
  one metric row per boundary; a held-out ``eval_batch_for`` falls back to
  the out-of-scan evaluator,
* checkpoint/resume of the FULL algorithm state (round counter and any
  transform state such as error-feedback / shift memory included),
* BIT-TRUE communication metering via the algorithm's declared vector
  counts and its compressor stack's ``bits_per_coord`` (a bf16 uplink
  meters 16 bits/coordinate, ``randk:0.25`` meters 8 — the old fixed
  ``itemsize`` bytes silently overcounted compressed uplinks), plus the
  delay model's uplink duty cycle, the sampling rate's PRESENT-ONLY
  downlink duty, and the topology's per-hop traffic shape (hierarchical
  tier messages; gossip edges, no broadcast),
* CSV metrics logging.

Works with any engine algorithm (FedCET — plain, compressed, sampled,
delayed and/or re-topologized via the ``with_*`` factories — FedAvg,
SCAFFOLD, FedTrack, FedLin, FedProx, FedDyn, NIDS) and any model
exposing ``loss(params, batch)``.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import restore, save
from repro.core.comm import CommMeter
from repro.core.engine import make_round_runner, scan_segments


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    rounds: int = 100
    eval_every: int = 25
    ckpt_every: int = 0              # 0 = no checkpoints
    ckpt_dir: str | None = None
    ckpt_keep: int = 3
    log_csv: str | None = None
    #: DEPRECATED: fixed transmitted element width (bytes). None (default)
    #: meters bit-true from the algorithm's compressor stack; setting a
    #: value forces the legacy dense-itemsize accounting.
    itemsize: int | None = None
    #: upper bound on rounds per jitted scan segment — bounds the memory
    #: spent on stacked per-round batches when eval/ckpt are sparse or off.
    max_scan_rounds: int = 32


class FedTrainer:
    def __init__(self, algo, loss_fn: Callable, cfg: TrainerConfig):
        self.algo = algo
        self.loss_fn = loss_fn
        self.cfg = cfg
        self.grad_fn = jax.grad(loss_fn)
        # ONE runner per mode for the whole fit: jit caches a compilation
        # per distinct segment length, so steady-state segments never
        # retrace.
        self._runner = make_round_runner(algo, self.grad_fn)

        def _scan_metrics(state, batches):
            """Per-round eval losses ON-DEVICE inside the scan (same math
            as ``evaluate``: first tau-slice of that round's batches)."""
            b = jax.tree.map(lambda a: a[0], batches)
            local = jax.vmap(loss_fn)(algo.client_params(state), b)
            glob = jnp.mean(jax.vmap(loss_fn, in_axes=(None, 0))(
                algo.global_params(state), b))
            return {"loss_global": glob, "loss_local_mean": jnp.mean(local)}

        self._metric_runner = make_round_runner(
            algo, self.grad_fn, metric_fn=_scan_metrics,
            metric_with_batch=True)
        self._eval_clients = jax.jit(
            lambda xs, b: jax.vmap(loss_fn)(xs, b))
        self._eval_global = jax.jit(
            lambda x, b: jnp.mean(jax.vmap(loss_fn, in_axes=(None, 0))(x, b)))
        self.history: list[dict] = []

    # ------------------------------------------------------------ lifecycle
    def init_state(self, params, init_batch):
        return self.algo.init(self.grad_fn, params, init_batch)

    def maybe_resume(self, state):
        """Resume from the newest checkpoint if one exists."""
        if not self.cfg.ckpt_dir:
            return state, 0
        restored, step = restore(self.cfg.ckpt_dir, state)
        if restored is None:
            return state, 0
        return restored, step

    # ------------------------------------------------------------ schedule
    def _eval_at(self, r: int) -> bool:
        return bool(self.cfg.eval_every) and (
            r % self.cfg.eval_every == 0 or r == self.cfg.rounds - 1)

    def _ckpt_at(self, r: int) -> bool:
        return bool(self.cfg.ckpt_every and self.cfg.ckpt_dir
                    and (r + 1) % self.cfg.ckpt_every == 0)

    # ------------------------------------------------------------ main loop
    def fit(self, state, batches_for: Callable[[int], Any],
            eval_batch_for: Callable[[int], Any] | None = None,
            start_round: int = 0, callback=None):
        params1 = jax.tree.map(lambda a: a[0], self.algo.client_params(state))
        if self.cfg.itemsize is None:
            meter = CommMeter.for_params(params1, algo=self.algo,
                                         n_clients=self.algo.n_clients)
        else:  # legacy fixed-width accounting (deprecated)
            meter = CommMeter.for_params(params1, itemsize=self.cfg.itemsize,
                                         n_clients=self.algo.n_clients)
        t0 = time.time()
        # train-batch eval rides the scan's metric hook (no host round-trip
        # inside a segment); a held-out eval fn needs the out-of-scan path.
        scan_eval = bool(self.cfg.eval_every) and eval_batch_for is None
        runner = self._metric_runner if scan_eval else self._runner
        for r, stop in scan_segments(
                start_round, self.cfg.rounds,
                lambda s: self._eval_at(s) or self._ckpt_at(s),
                max_rounds=self.cfg.max_scan_rounds):
            stacked = jax.tree.map(
                lambda *bs: jnp.stack(bs),
                *[batches_for(i) for i in range(r, stop + 1)])
            state, metrics = runner(state, stacked)
            for _ in range(r, stop + 1):
                meter.tick_round(self.algo)
            if self._eval_at(stop):
                if scan_eval:  # the segment's last round == stop
                    glob = float(metrics["loss_global"][-1])
                    loc = float(metrics["loss_local_mean"][-1])
                    row = {"loss_global": glob, "loss_local_mean": loc,
                           "heterogeneity_gap": loc - glob}
                else:
                    row = self.evaluate(state, eval_batch_for(stop))
                row.update(round=stop, comm_bytes=meter.total,
                           wall_s=round(time.time() - t0, 2))
                self.history.append(row)
                if callback:
                    callback(row)
            if self._ckpt_at(stop):
                save(self.cfg.ckpt_dir, stop + 1, state, keep=self.cfg.ckpt_keep)
        if self.cfg.log_csv:
            self._write_csv()
        return state

    # ----------------------------------------------------------------- eval
    def evaluate(self, state, batches) -> dict:
        """batches: [tau, clients, ...] — evaluation uses the first slice."""
        b = jax.tree.map(lambda a: a[0], batches)
        local = self._eval_clients(self.algo.client_params(state), b)
        global_params = self.algo.global_params(state)
        glob = self._eval_global(global_params, b)
        return {
            "loss_global": float(glob),
            "loss_local_mean": float(jnp.mean(local)),
            "heterogeneity_gap": float(jnp.mean(local) - glob),
        }

    def _write_csv(self):
        if not self.history:
            return
        os.makedirs(os.path.dirname(self.cfg.log_csv) or ".", exist_ok=True)
        keys = list(self.history[0])
        with open(self.cfg.log_csv, "w") as f:
            f.write(",".join(keys) + "\n")
            for row in self.history:
                f.write(",".join(str(row[k]) for k in keys) + "\n")
