"""FedTrainer — the production training harness around FederatedAlgorithm.

Responsibilities a real deployment needs beyond the algorithm step:

* round orchestration with a pluggable data source (round -> batches),
  running through the shared scan driver (``engine.make_round_runner``):
  rounds between eval/checkpoint boundaries execute as ONE jitted
  ``lax.scan`` segment rather than a python-level round loop,
* periodic evaluation: global-model loss AND per-client local losses (the
  heterogeneity gap — mean local minus global — is the practical drift
  diagnostic). In the default (train-batch) mode the losses are computed
  INSIDE the round scan via the runner's per-round metric hook, so a
  segment never leaves the device between eval boundaries — ``fit`` pulls
  one metric row per boundary; a held-out ``eval_batch_for`` falls back to
  the out-of-scan evaluator,
* checkpoint/resume of the FULL algorithm state (round counter and any
  transform state such as error-feedback / shift memory included),
* BIT-TRUE communication metering via the algorithm's declared vector
  counts and its compressor stack's ``bits_per_coord`` (a bf16 uplink
  meters 16 bits/coordinate, ``randk:0.25`` meters 8 — the old fixed
  ``itemsize`` bytes silently overcounted compressed uplinks), plus the
  delay model's uplink duty cycle, the sampling rate's PRESENT-ONLY
  downlink duty, and the topology's per-hop traffic shape (hierarchical
  tier messages; gossip edges, no broadcast),
* CSV metrics logging (through the telemetry module's CSV-row writer —
  same bytes as the trainer always wrote), and — when the algorithm has
  ``with_telemetry`` attached and the trainer is given ``sinks=`` — the
  in-trace per-round telemetry stream: each scan segment's stacked series
  drains into the sinks (JSONL manifest + round events, monitor WARNs)
  with zero host syncs inside the segment.

Works with any engine algorithm (FedCET — plain, compressed, sampled,
delayed and/or re-topologized via the ``with_*`` factories — FedAvg,
SCAFFOLD, FedTrack, FedLin, FedProx, FedDyn, NIDS) and any model
exposing ``loss(params, batch)``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import restore, save
from repro.core import telemetry as tele
from repro.core.comm import CommMeter
from repro.core.engine import make_round_runner, scan_segments


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    rounds: int = 100
    eval_every: int = 25
    ckpt_every: int = 0              # 0 = no checkpoints
    ckpt_dir: str | None = None
    ckpt_keep: int = 3
    log_csv: str | None = None
    #: REMOVED: the legacy fixed transmitted element width (bytes). Must
    #: stay None — ``CommMeter.for_params(itemsize=...)`` now raises with
    #: a migration hint; the bit-true ``algo=`` accounting is always used.
    itemsize: int | None = None
    #: upper bound on rounds per jitted scan segment — bounds the memory
    #: spent on stacked per-round batches when eval/ckpt are sparse or off.
    max_scan_rounds: int = 32


class FedTrainer:
    def __init__(self, algo, loss_fn: Callable, cfg: TrainerConfig,
                 sinks=None):
        self.algo = algo
        self.loss_fn = loss_fn
        self.cfg = cfg
        #: telemetry event sinks (a ``parse_sinks`` spec string, a list of
        #: sink objects, or None). Round telemetry flows into them when
        #: the algorithm has ``with_telemetry`` attached.
        self.sinks = tele.parse_sinks(sinks)
        self.monitors = tele.resolve_monitors(getattr(algo, "telemetry",
                                                      None))
        self.grad_fn = jax.grad(loss_fn)
        # ONE runner per mode for the whole fit: jit caches a compilation
        # per distinct segment length, so steady-state segments never
        # retrace.
        self._runner = make_round_runner(algo, self.grad_fn)

        def _scan_metrics(state, batches):
            """Per-round eval losses ON-DEVICE inside the scan (same math
            as ``evaluate``: first tau-slice of that round's batches)."""
            b = jax.tree.map(lambda a: a[0], batches)
            local = jax.vmap(loss_fn)(algo.client_params(state), b)
            glob = jnp.mean(jax.vmap(loss_fn, in_axes=(None, 0))(
                algo.global_params(state), b))
            return {"loss_global": glob, "loss_local_mean": jnp.mean(local)}

        self._metric_runner = make_round_runner(
            algo, self.grad_fn, metric_fn=_scan_metrics,
            metric_with_batch=True)
        self._eval_clients = jax.jit(
            lambda xs, b: jax.vmap(loss_fn)(xs, b))
        self._eval_global = jax.jit(
            lambda x, b: jnp.mean(jax.vmap(loss_fn, in_axes=(None, 0))(x, b)))
        self.history: list[dict] = []

    # ------------------------------------------------------------ lifecycle
    def init_state(self, params, init_batch):
        return self.algo.init(self.grad_fn, params, init_batch)

    def maybe_resume(self, state):
        """Resume from the newest checkpoint if one exists."""
        if not self.cfg.ckpt_dir:
            return state, 0
        restored, step = restore(self.cfg.ckpt_dir, state)
        if restored is None:
            return state, 0
        return restored, step

    # ------------------------------------------------------------ schedule
    def _eval_at(self, r: int) -> bool:
        return bool(self.cfg.eval_every) and (
            r % self.cfg.eval_every == 0 or r == self.cfg.rounds - 1)

    def _ckpt_at(self, r: int) -> bool:
        return bool(self.cfg.ckpt_every and self.cfg.ckpt_dir
                    and (r + 1) % self.cfg.ckpt_every == 0)

    # ------------------------------------------------------------ main loop
    def fit(self, state, batches_for: Callable[[int], Any],
            eval_batch_for: Callable[[int], Any] | None = None,
            start_round: int = 0, callback=None):
        params1 = jax.tree.map(lambda a: a[0], self.algo.client_params(state))
        if self.cfg.itemsize is None:
            meter = CommMeter.for_params(params1, algo=self.algo,
                                         n_clients=self.algo.n_clients)
        else:  # removed legacy path: for_params raises a migration hint
            meter = CommMeter.for_params(params1, itemsize=self.cfg.itemsize,
                                         n_clients=self.algo.n_clients)
        if self.sinks:
            tele.emit_event(self.sinks, tele.run_manifest(
                self.algo, n_params=meter.n_params,
                config={"rounds": self.cfg.rounds,
                        "eval_every": self.cfg.eval_every},
                monitors=self.monitors))
        t0 = time.time()
        # train-batch eval rides the scan's metric hook (no host round-trip
        # inside a segment); a held-out eval fn needs the out-of-scan path.
        scan_eval = bool(self.cfg.eval_every) and eval_batch_for is None
        runner = self._metric_runner if scan_eval else self._runner
        for r, stop in scan_segments(
                start_round, self.cfg.rounds,
                lambda s: self._eval_at(s) or self._ckpt_at(s),
                max_rounds=self.cfg.max_scan_rounds):
            stacked = jax.tree.map(
                lambda *bs: jnp.stack(bs),
                *[batches_for(i) for i in range(r, stop + 1)])
            state, ys = runner(state, stacked)
            metrics, tel_series = tele.split_metrics(self.algo, ys)
            if tel_series is not None and self.sinks:
                tele.drain(tel_series, sinks=self.sinks,
                           monitors=self.monitors, start_round=r,
                           algo=self.algo, n_params=meter.n_params)
            for _ in range(r, stop + 1):
                meter.tick_round(self.algo)
            if self._eval_at(stop):
                if scan_eval:  # the segment's last round == stop
                    glob = float(metrics["loss_global"][-1])
                    loc = float(metrics["loss_local_mean"][-1])
                    row = {"loss_global": glob, "loss_local_mean": loc,
                           "heterogeneity_gap": loc - glob}
                else:
                    row = self.evaluate(state, eval_batch_for(stop))
                row.update(round=stop, comm_bytes=meter.total,
                           wall_s=round(time.time() - t0, 2))
                self.history.append(row)
                if callback:
                    callback(row)
            if self._ckpt_at(stop):
                save(self.cfg.ckpt_dir, stop + 1, state, keep=self.cfg.ckpt_keep)
        if self.cfg.log_csv:
            self._write_csv()
        tele.close_sinks(self.sinks)
        return state

    # ----------------------------------------------------------------- eval
    def evaluate(self, state, batches) -> dict:
        """batches: [tau, clients, ...] — evaluation uses the first slice."""
        b = jax.tree.map(lambda a: a[0], batches)
        local = self._eval_clients(self.algo.client_params(state), b)
        global_params = self.algo.global_params(state)
        glob = self._eval_global(global_params, b)
        return {
            "loss_global": float(glob),
            "loss_local_mean": float(jnp.mean(local)),
            "heterogeneity_gap": float(jnp.mean(local) - glob),
        }

    def _write_csv(self):
        # the telemetry module's CSV-row writer replicates the trainer's
        # historical format exactly (header from the first row's keys,
        # str()-formatted values) — output bytes are unchanged.
        tele.write_csv_rows(self.cfg.log_csv, self.history)
