from repro.fed.trainer import FedTrainer, TrainerConfig

__all__ = ["FedTrainer", "TrainerConfig"]
