"""Minimal optimizer library (no optax dependency).

FedCET itself is a GD-type method whose update rule lives in repro.core;
these optimizers serve the baselines and the centralized/local-Adam training
examples. API: ``init(params) -> state``, ``update(grads, state, params, lr)
-> (new_params, new_state)``. States are pytrees, so they compose with the
stacked-client layout and pjit sharding unchanged.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Sgd:
    momentum: float = 0.0

    def init(self, params):
        if self.momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(self, grads, state, params, lr):
        if self.momentum == 0.0:
            new = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new, state
        vel = jax.tree.map(lambda v, g: self.momentum * v + g, state, grads)
        new = jax.tree.map(lambda p, v: p - lr * v, params, vel)
        return new, vel


@dataclasses.dataclass(frozen=True)
class Adam:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params):
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1.0 - self.b1 ** t.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** t.astype(jnp.float32)

        def upd(p, m, v):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

        new = jax.tree.map(upd, params, m, v)
        return new, {"m": m, "v": v, "t": t}
