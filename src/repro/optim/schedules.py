"""Learning-rate schedules, including WSD (warmup-stable-decay).

WSD is MiniCPM's schedule [arXiv:2404.06395]: linear warmup -> long stable
plateau -> short (exponential/linear) decay. The minicpm-2b arch config
selects it via the training driver.
"""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine(lr: float, total_steps: int, warmup: int = 0, min_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * warm * cos

    return f


def wsd(lr: float, total_steps: int, *, warmup_frac: float = 0.01,
        decay_frac: float = 0.1, min_frac: float = 0.01):
    """Warmup-Stable-Decay: the final `decay_frac` of training decays
    exponentially from lr to min_frac * lr."""
    warmup = max(1, int(warmup_frac * total_steps))
    decay_start = int((1.0 - decay_frac) * total_steps)

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / warmup, 1.0)
        decay_prog = jnp.clip((step - decay_start) / max(total_steps - decay_start, 1),
                              0.0, 1.0)
        decay = jnp.power(min_frac, decay_prog)  # exp decay to min_frac * lr
        return lr * warm * decay

    return f
