from repro.optim.optimizers import Adam, Sgd
from repro.optim.schedules import constant, cosine, wsd

__all__ = ["Adam", "Sgd", "constant", "cosine", "wsd"]
