"""The paper's numerical-evaluation problem (Section IV, Eq. 17).

Distributed estimation: client i holds n_i noisy measurements b_ij of a
parameter x, with measurement matrix M_i and regularizer r_i = 1:

    f_i(x) = (1/n_i) sum_j ||M_i x - b_ij||^2 + ||x||^2.

The paper's experiment fixes M_i = I (so mu = L = 4 and the optimum has the
closed form x* = (1/2) mean_ij b_ij). We additionally support *diagonal*
per-client M_i = diag(m_i): that variant has heterogeneous client Hessians
(2 diag(m_i^2) + 2I), which is the regime where FedAvg's client drift is
provably nonzero — with identical Hessians (the paper's M_i = I case)
periodic averaging is exact for quadratics and FedAvg does not drift, which
is precisely why the paper's Fig. 1 compares only against exact-convergence
methods. Both variants expose closed-form x* for exactness tests.

Each client's batch is the pytree {"b": [n_i, n], "m": [n]} so the vmapped
grad_fn sees everything client-local in one leaf structure.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuadraticProblem:
    b: jax.Array          # [N, n_i, n] measurements
    m: jax.Array          # [N, n] diagonal measurement matrices

    @property
    def n_clients(self) -> int:
        return self.b.shape[0]

    @property
    def dim(self) -> int:
        return self.b.shape[-1]

    @property
    def mu(self) -> float:
        """Global strong-convexity constant: min_i lambda_min(2 m_i^2 + 2)."""
        return float(2.0 * jnp.min(self.m**2) + 2.0)

    @property
    def L(self) -> float:
        return float(2.0 * jnp.max(self.m**2) + 2.0)

    @property
    def x_star(self) -> jax.Array:
        """grad f = mean_i [2 m_i^2 x - 2 m_i mean_j b_ij + 2x] = 0."""
        m2 = jnp.mean(self.m**2, axis=0)                    # [n]
        mb = jnp.mean(self.m * jnp.mean(self.b, axis=1), axis=0)  # [n]
        return mb / (m2 + 1.0)

    def client_loss(self, x: jax.Array, batch) -> jax.Array:
        """f_i for a single client; batch = {"b": [n_i, n], "m": [n]}."""
        residual = batch["m"][None, :] * x[None, :] - batch["b"]
        return jnp.mean(jnp.sum(residual**2, axis=-1)) + jnp.sum(x**2)

    def client_grad(self, x: jax.Array, batch) -> jax.Array:
        """Closed form 2 m^2 x - 2 m mean_j b_ij + 2x (cross-checks jax.grad)."""
        m = batch["m"]
        return 2.0 * m**2 * x - 2.0 * m * jnp.mean(batch["b"], axis=0) + 2.0 * x

    def global_loss(self, x: jax.Array) -> jax.Array:
        batches = {"b": self.b, "m": self.m}
        return jnp.mean(jax.vmap(self.client_loss, in_axes=(None, 0))(x, batches))

    def stacked_batches(self, tau: int):
        """Full-batch training: every local step sees the whole local set.
        Leading axes [tau, N, ...] as the round API expects."""
        return {
            "b": jnp.broadcast_to(self.b[None], (tau,) + self.b.shape),
            "m": jnp.broadcast_to(self.m[None], (tau,) + self.m.shape),
        }


def make_quadratic_problem(key: jax.Array | int = 0, *, n_clients: int = 10,
                           n_measurements: int = 10, dim: int = 60,
                           spread: float = 10.0) -> QuadraticProblem:
    """Paper settings: N=10 clients, n_i=10 measurements, n=60,
    b_ij ~ U[-10, 10], M_i = I (so mu = L = 4)."""
    if isinstance(key, int):
        key = jax.random.key(key)
    dtype = jax.dtypes.canonicalize_dtype(jnp.float64)  # f64 iff x64 enabled
    b = jax.random.uniform(key, (n_clients, n_measurements, dim),
                           minval=-spread, maxval=spread, dtype=dtype)
    m = jnp.ones((n_clients, dim), dtype=dtype)
    return QuadraticProblem(b=b, m=m)


def make_hetero_hessian_problem(key: jax.Array | int = 0, *, n_clients: int = 10,
                                n_measurements: int = 10, dim: int = 60,
                                spread: float = 10.0,
                                m_low: float = 0.5,
                                m_high: float = 1.5) -> QuadraticProblem:
    """Heterogeneous-Hessian variant: M_i = diag(m_i), m_i ~ U[m_low, m_high].
    Exhibits genuine FedAvg client drift (used by tests/test_baselines.py)."""
    if isinstance(key, int):
        key = jax.random.key(key)
    kb, km = jax.random.split(key)
    dtype = jax.dtypes.canonicalize_dtype(jnp.float64)  # f64 iff x64 enabled
    b = jax.random.uniform(kb, (n_clients, n_measurements, dim),
                           minval=-spread, maxval=spread, dtype=dtype)
    m = jax.random.uniform(km, (n_clients, dim), minval=m_low, maxval=m_high,
                           dtype=dtype)
    return QuadraticProblem(b=b, m=m)
