from repro.data.quadratic import (
    QuadraticProblem,
    make_hetero_hessian_problem,
    make_quadratic_problem,
)
from repro.data.synthetic import HeteroLMDataset, make_hetero_lm_dataset

__all__ = [
    "HeteroLMDataset",
    "QuadraticProblem",
    "make_hetero_hessian_problem",
    "make_hetero_lm_dataset",
    "make_quadratic_problem",
]
