"""Synthetic heterogeneous language-model data pipeline.

Federated LM training needs per-client token streams whose *distributions
differ* across clients (the non-IID setting the paper targets). We synthesize
this with per-client Markov chains over the vocabulary: each client draws a
client-specific transition kernel by mixing a shared base kernel with a
client-unique one, with mixing weight controlled by ``heterogeneity``
(0 = IID across clients, 1 = fully disjoint unigram/bigram statistics).

The pipeline is deterministic given a seed, infinite (stateless indexing by
round/step), and emits batches shaped ``[tau, clients, batch, seq]`` — the
exact leading layout the FederatedAlgorithm.round API consumes. Everything is
pure JAX so the batch synthesis can itself be jitted and sharded along the
client axis on the production mesh.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class HeteroLMDataset:
    vocab_size: int
    n_clients: int
    seq_len: int
    batch_size: int          # per-client
    heterogeneity: float     # in [0, 1]
    seed: int = 0

    def _client_logits(self) -> jax.Array:
        """[clients, vocab] per-client unigram logit tables."""
        base = jax.random.normal(jax.random.key(self.seed), (self.vocab_size,))
        uniq = jax.random.normal(
            jax.random.key(self.seed + 1), (self.n_clients, self.vocab_size)
        )
        h = self.heterogeneity
        return (1.0 - h) * base[None, :] + h * 2.0 * uniq

    def sample_round(self, round_index: int, tau: int) -> jax.Array:
        """Tokens [tau, clients, batch, seq] for one communication round.

        First-order structure: token t+1 is correlated with token t through a
        shift of the client's logit table, giving each client learnable but
        distinct statistics.
        """
        logits = self._client_logits()  # [C, V]
        key = jax.random.fold_in(jax.random.key(self.seed + 2), round_index)

        def sample_client(ckey, clogits):
            ks = jax.random.split(ckey, tau * self.batch_size)

            def sample_seq(k):
                def step(tok, kk):
                    shifted = jnp.roll(clogits, tok)
                    nxt = jax.random.categorical(kk, shifted + clogits)
                    return nxt, nxt

                k0, krest = k, jax.random.split(k, self.seq_len)
                first = jax.random.categorical(k0, clogits)
                _, toks = jax.lax.scan(step, first, krest)
                return jnp.concatenate([first[None], toks[:-1]])

            toks = jax.vmap(sample_seq)(ks)  # [tau*batch, seq]
            return toks.reshape(tau, self.batch_size, self.seq_len)

        ckeys = jax.random.split(key, self.n_clients)
        toks = jax.vmap(sample_client)(ckeys, logits)  # [C, tau, B, S]
        return jnp.transpose(toks, (1, 0, 2, 3)).astype(jnp.int32)

    def client_unigram_divergence(self) -> jax.Array:
        """Mean pairwise total-variation distance between client unigram
        distributions — the heterogeneity diagnostic used in tests."""
        p = jax.nn.softmax(self._client_logits(), axis=-1)  # [C, V]
        tv = 0.5 * jnp.sum(jnp.abs(p[:, None, :] - p[None, :, :]), axis=-1)
        c = self.n_clients
        off = jnp.sum(tv) / (c * (c - 1)) if c > 1 else jnp.asarray(0.0)
        return off


def make_hetero_lm_dataset(vocab_size: int, n_clients: int, seq_len: int,
                           batch_size: int, *, heterogeneity: float = 0.8,
                           seed: int = 0) -> HeteroLMDataset:
    return HeteroLMDataset(vocab_size=vocab_size, n_clients=n_clients,
                           seq_len=seq_len, batch_size=batch_size,
                           heterogeneity=heterogeneity, seed=seed)
