"""FedTrainer: orchestration, eval metrics, checkpoint/resume determinism."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import FedCET
from repro.data.synthetic import make_hetero_lm_dataset
from repro.fed import FedTrainer, TrainerConfig
from repro.models import build_model


def _setup(tmp=None, rounds=6, ckpt_every=0):
    cfg = get_config("fedlm-100m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_clients, tau, B, S = 3, 2, 2, 32
    algo = FedCET(alpha=3e-3, c=0.05, tau=tau, n_clients=n_clients)
    ds = make_hetero_lm_dataset(cfg.vocab_size, n_clients, S, B, seed=1)
    batches_for = lambda r: {"tokens": ds.sample_round(r, tau)}
    tc = TrainerConfig(rounds=rounds, eval_every=2, ckpt_every=ckpt_every,
                       ckpt_dir=tmp, log_csv=None)
    trainer = FedTrainer(algo, model.loss, tc)
    state = trainer.init_state(params, jax.tree.map(lambda b: b[0],
                                                    batches_for(0)))
    return trainer, state, batches_for


def test_training_reduces_loss_and_logs():
    trainer, state, batches_for = _setup(rounds=20)
    # fixed held-out batch so the eval series is comparable across rounds
    eval_b = batches_for(10_001)
    state = trainer.fit(state, batches_for, eval_batch_for=lambda r: eval_b)
    assert trainer.history, "eval rows must be recorded"
    losses = [h["loss_global"] for h in trainer.history]
    assert losses[-1] < losses[0]
    for h in trainer.history:
        assert np.isfinite(h["loss_global"])
        assert np.isfinite(h["heterogeneity_gap"])
        assert h["comm_bytes"] > 0


def test_checkpoint_resume_is_deterministic(tmp_path):
    d = str(tmp_path / "ck")
    # run 1: 6 rounds straight
    trainer, state, batches_for = _setup(rounds=6)
    final_a = trainer.fit(state, batches_for)
    # run 2: 3 rounds + checkpoint, then resume for the remaining 3
    trainer_b, state_b, _ = _setup(tmp=d, rounds=3, ckpt_every=3)
    mid = trainer_b.fit(state_b, batches_for)
    trainer_c, state_c, _ = _setup(tmp=d, rounds=6, ckpt_every=0)
    resumed, start = trainer_c.maybe_resume(state_c)
    assert start == 3
    final_b = trainer_c.fit(resumed, batches_for, start_round=start)
    for a, b in zip(jax.tree.leaves(final_a.x), jax.tree.leaves(final_b.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_heterogeneity_gap_positive_on_noniid():
    """On non-IID shards, mean local loss at client optima-drifted params
    should be <= global-model loss on own shard... the gap is finite and
    the metric plumbing works."""
    trainer, state, batches_for = _setup(rounds=4)
    state = trainer.fit(state, batches_for)
    gaps = [h["heterogeneity_gap"] for h in trainer.history]
    assert all(np.isfinite(g) for g in gaps)
