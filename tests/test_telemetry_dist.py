"""Distributional telemetry + online rate estimation (PR 9).

The contracts: (1) sketches OFF stays the PR 8 BITWISE no-op even with
the sketch machinery present; (2) the population sketches read the full
``[N, ...]`` client store, so the cohort gather lowering and the dense
reference lowering produce IDENTICAL sketches; (3) the Pallas
``telemetry_reduce`` kernel matches its jnp oracle on arena-packed
stores including zero-pad rows and ragged client counts; (4) the rate
estimator recovers rho on synthetic geometric series and reproduces the
PR 3 staleness boundary (rr:2 + poly:1 rate break naming the axis,
fixed:2 + poly:1 silent) live from one run's drain and post hoc from its
JSONL alone; (5) the sinks handle vector-valued events explicitly.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedScenario
from repro.core import (
    CsvSink,
    FedCET,
    MemorySink,
    RateMonitor,
    Telemetry,
    drain,
    fit_rate,
    max_weight_c,
    parse_sinks,
    parse_telemetry,
    rate_axis,
    replay_jsonl,
    resolve_monitors,
    split_metrics,
    with_delay,
    with_telemetry,
)
from repro.core.lr_search import lr_search
from repro.core.simulate import simulate_quadratic
from repro.core.telemetry import SKETCH_SOURCES, log_histogram
from repro.data.quadratic import make_quadratic_problem
from repro.kernels import ops
from repro.kernels import ref as R

jax.config.update("jax_enable_x64", True)

ROUNDS = 6
SKETCH_SPEC = Telemetry(sketches="auto", topk=3, leaf_stats=True)
COMPOSED = dict(compression="shift:q8", participation=0.8, delay="fixed:2",
                stale_policy="poly:1", cohort="block:4", arena=True)


def _problem(n_clients=8, dim=24, **kw):
    return make_quadratic_problem(0, n_clients=n_clients, dim=dim, **kw)


def _fedcet(problem, tau=2):
    alpha = lr_search(problem.mu, problem.L, tau)
    return FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=tau,
                  n_clients=problem.n_clients)


def _assert_bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        diff = np.abs(x.astype(np.float64) - y.astype(np.float64)).max() \
            if x.size else 0.0
        assert diff == 0.0, f"max abs diff {diff} != 0.0"


def _sketch_keys(series):
    return [k for k in series
            if any(k.startswith(s + "_") for s in SKETCH_SOURCES)]


# ------------------------------------------------------ bitwise no-op
def test_sketches_off_is_bitwise_noop():
    """With the sketch machinery present in the codebase, a telemetry-OFF
    run and a full-sketch run still agree at EXACTLY 0.0 state diff on
    the fully composed scenario — sketches only observe."""
    problem = _problem()
    off = FedScenario(telemetry=False, **COMPOSED).apply(_fedcet(problem))
    on = FedScenario(telemetry=SKETCH_SPEC, **COMPOSED).apply(_fedcet(problem))
    res_off = simulate_quadratic(off, problem, rounds=ROUNDS)
    res_on = simulate_quadratic(on, problem, rounds=ROUNDS)
    _assert_bitwise_equal(res_off.state, res_on.state)
    _assert_bitwise_equal(res_off.errors, res_on.errors)
    assert _sketch_keys(res_on.telemetry), "sketches did not materialize"


# ----------------------------------------------------- sketch content
def test_sketch_series_shapes_and_invariants():
    problem = _problem()
    algo = FedScenario(telemetry=SKETCH_SPEC, **COMPOSED).apply(
        _fedcet(problem))
    res = simulate_quadratic(algo, problem, rounds=ROUNDS)
    tel = res.telemetry
    n, cohort = problem.n_clients, 4
    for src, count in [("d_norm", n), ("drift", n), ("age", n),
                       ("compress_err", cohort)]:
        hist = np.asarray(tel[f"{src}_hist"])
        assert hist.shape == (ROUNDS, SKETCH_SPEC.hist_bins)
        # every client lands in exactly one bin (cohort-sized for the
        # wire-data sketch — compression error exists only for senders)
        assert (hist.sum(axis=1) == count).all(), (src, hist.sum(axis=1))
        p50 = np.asarray(tel[f"{src}_p50"])
        p90 = np.asarray(tel[f"{src}_p90"])
        p99 = np.asarray(tel[f"{src}_p99"])
        mx = np.asarray(tel[f"{src}_max"])
        assert (p50 <= p90 + 1e-12).all() and (p90 <= p99 + 1e-12).all()
        assert (p99 <= mx + 1e-12).all()
        tv = np.asarray(tel[f"{src}_top_vals"])
        ti = np.asarray(tel[f"{src}_top_ids"])
        assert tv.shape == (ROUNDS, SKETCH_SPEC.topk) == ti.shape
        assert tv[:, 0] == pytest.approx(np.asarray(mx), abs=1e-12)
        assert ti.min() >= 0 and ti.max() < n  # GLOBAL ids under cohorts
    # per-leaf breakdown rides as leaf_ vectors (1 leaf: the quadratic x)
    assert np.asarray(tel["leaf_msg_norm"]).shape == (ROUNDS, 1)
    assert np.asarray(tel["leaf_compress_err"]).shape == (ROUNDS, 1)


def test_histogram_matches_shared_binning_formula():
    spec = Telemetry(sketches="auto")
    vals = jnp.asarray([0.0, 1e-13, 3e-7, 0.5, 2.0, 9e3, 1e9])
    hist = np.asarray(log_histogram(vals, spec.hist_bins, spec.hist_lo,
                                    spec.hist_hi))
    assert hist.sum() == vals.shape[0]
    # zeros pin to bin 0; overflow clips into the top bin
    assert hist[0] >= 1 and hist[-1] >= 1


# ------------------------------------------- cohort vs dense lowering
def test_cohort_and_dense_lowerings_sketch_identically():
    """Sketches read the post-round store, which both cohort lowerings
    produce bitwise-equal — so every sketch series must agree exactly
    (integer histograms / ids) or <=1e-12 (float quantiles)."""
    problem = _problem()
    res_g = simulate_quadratic(
        FedScenario(telemetry=SKETCH_SPEC, **COMPOSED).apply(
            _fedcet(problem)), problem, rounds=ROUNDS)
    res_d = simulate_quadratic(
        FedScenario(telemetry=SKETCH_SPEC,
                    **{**COMPOSED, "cohort": "block:4:dense"}).apply(
            _fedcet(problem)), problem, rounds=ROUNDS)
    keys = _sketch_keys(res_g.telemetry)
    assert keys and set(keys) == set(_sketch_keys(res_d.telemetry))
    for k in keys:
        a = np.asarray(res_g.telemetry[k])
        b = np.asarray(res_d.telemetry[k])
        if a.dtype.kind in "iu":
            assert (a == b).all(), k
        else:
            assert np.abs(a - b).max() <= 1e-12, (
                k, np.abs(a - b).max())


# --------------------------------------------------- kernel vs oracle
@pytest.mark.parametrize("n_clients", [8, 13])
def test_telemetry_reduce_kernel_matches_ref(n_clients):
    """Pallas kernel (interpret mode) vs the jnp oracle on an arena-style
    ``[N, rows, 1024]`` store with zero-pad tail entries and a client
    count that does not divide the client block."""
    rng = np.random.default_rng(0)
    rows, lanes = 3, 1024
    data = rng.normal(size=(n_clients, rows, lanes)) \
        * np.logspace(-6, 2, n_clients)[:, None, None]
    data[:, -1, 512:] = 0.0  # arena zero padding
    data = jnp.asarray(data)
    kw = dict(bins=48, lo=-12.0, hi=4.0, k=4)
    nk, hk, tvk, tik = ops.telemetry_sketch(data, impl="kernel", **kw)
    nr, hr, tvr, tir = ops.telemetry_sketch(data, impl="ref", **kw)
    assert float(jnp.max(jnp.abs(nk - nr))) <= 1e-12
    assert bool(jnp.all(hk == hr)) and int(hk.sum()) == n_clients
    assert bool(jnp.all(tik == tir))
    assert float(jnp.max(jnp.abs(tvk - tvr))) <= 1e-12


def test_telemetry_reduce_ref_oracle_is_exact():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(6, 40)))
    sq, hist = R.client_sketch(x, bins=32, lo=-12.0, hi=4.0)
    np.testing.assert_allclose(np.asarray(sq),
                               np.asarray(jnp.sum(x * x, axis=1)),
                               rtol=0, atol=0)
    expect = np.asarray(log_histogram(jnp.sqrt(jnp.sum(x * x, axis=1)),
                                      32, -12.0, 4.0))
    assert (np.asarray(hist) == expect).all()


# ----------------------------------------------------- rate estimator
def test_fit_rate_recovers_rho_on_geometric_series():
    for rho in (0.5, 0.9, 0.99):
        r = np.arange(40)
        v = 3.7 * rho ** r
        assert fit_rate(r, v) == pytest.approx(rho, rel=1e-9)


def test_rate_monitor_fires_on_synthetic_stall():
    """A geometric decay that flatlines: the windowed rho_hat crosses 1
    after linear convergence was established -> exactly one rate-break
    WARN (cooldown suppresses repeats within its horizon)."""
    m = RateMonitor(axis="synthetic-axis")
    vals = [0.8 ** r for r in range(30)] + [0.8 ** 30] * 25
    events = drain({"err": np.asarray(vals)}, monitors=(m,))
    warns = [e for e in events if e.get("kind") == "rate_break"]
    assert warns and warns[0]["axis"] == "synthetic-axis"
    assert warns[0]["rho_hat"] >= m.stall_rho
    assert warns[0]["round"] >= 30
    # rho_hat rides the round events from the moment the window fills
    annotated = [e for e in events
                 if e["event"] == "round" and "rho_hat" in e]
    assert len(annotated) >= len(vals) - m.window
    assert annotated[0]["rho_hat"] == pytest.approx(0.8, rel=1e-6)


def test_rate_monitor_silent_on_clean_contraction():
    m = RateMonitor()
    vals = [0.9 ** r for r in range(60)]
    events = drain({"err": np.asarray(vals)}, monitors=(m,))
    assert not [e for e in events if e.get("kind") == "rate_break"]


def _boundary_run(delay_spec, path):
    problem = _problem()
    algo = with_telemetry(
        with_delay(_fedcet(problem), delay_spec, policy="poly:1"), True)
    monitors = (RateMonitor(axis=rate_axis(algo)),)
    res = simulate_quadratic(algo, problem, rounds=48)
    sinks = parse_sinks(f"jsonl:{path}")
    events = drain({**res.telemetry, "err": np.asarray(res.errors)[1:]},
                   sinks=sinks, monitors=monitors, algo=algo,
                   n_params=problem.dim)
    for s in sinks:
        s.close()
    return [e for e in events if e.get("kind") == "rate_break"]


def test_rate_monitor_reproduces_staleness_boundary(tmp_path):
    """The PR 3 boundary as a LIVE rate-break detection: rr:2 + poly:1
    floors FedCET (non-uniform ages break Lemma 2) -> rate break naming
    stale_policy; fixed:2 + poly:1 stays exact -> silent. And the same
    detection replays from the finished JSONL alone."""
    silent = _boundary_run("fixed:2", str(tmp_path / "fixed2.jsonl"))
    assert not silent, silent[:1]
    breaks = _boundary_run("rr:2", str(tmp_path / "rr2.jsonl"))
    assert breaks, "no rate break on rr:2 + poly:1"
    assert "stale_policy" in breaks[0]["axis"]
    assert breaks[0]["rho_hat"] >= 0.99
    # post hoc, from the file alone — no re-simulation
    replayed = [w for w in replay_jsonl(str(tmp_path / "rr2.jsonl"),
                                        (RateMonitor(),))
                if w.get("kind") == "rate_break"]
    assert replayed and replayed[0]["round"] == breaks[0]["round"]
    again = [w for w in replay_jsonl(str(tmp_path / "fixed2.jsonl"),
                                     (RateMonitor(),))
             if w.get("kind") == "rate_break"]
    assert not again


def test_rate_axis_names_lossy_axes():
    problem = _problem()
    base = _fedcet(problem)
    assert "no lossy axis" in rate_axis(base)
    assert "stale_policy" in rate_axis(
        with_delay(base, "rr:2", policy="poly:1"))


def test_resolve_monitors_adds_rate_monitor_with_algo():
    problem = _problem()
    algo = with_telemetry(_fedcet(problem), True)
    plain = resolve_monitors(algo.telemetry)
    withalgo = resolve_monitors(algo.telemetry, algo)
    assert not any(isinstance(m, RateMonitor) for m in plain)
    rms = [m for m in withalgo if isinstance(m, RateMonitor)]
    assert len(rms) == 1


# ------------------------------------------------------------- sinks
def test_csv_sink_flattens_vector_metrics(tmp_path):
    path = str(tmp_path / "m.csv")
    sink = CsvSink(path)
    sink.emit({"event": "round", "round": 0, "loss": 1.5,
               "d_norm_hist": [1, 2, 3], "d_norm_p50": 0.5})
    sink.emit({"event": "round", "round": 1, "loss": 1.2,
               "d_norm_hist": [0, 4, 2], "d_norm_p50": 0.4})
    sink.close()
    lines = open(path).read().strip().split("\n")
    header = lines[0].split(",")
    assert "d_norm_hist.0" in header and "d_norm_hist.2" in header
    assert "d_norm_p50" in header
    row = dict(zip(header, lines[2].split(",")))
    assert row["d_norm_hist.1"] == "4"


def test_csv_sink_rejects_nested_vectors():
    sink = CsvSink("/dev/null")
    with pytest.raises(ValueError, match="jsonl"):
        sink.emit({"event": "round", "round": 0, "bad": [[1, 2], [3, 4]]})
    sink.close()


def test_jsonl_round_events_carry_vectors(tmp_path):
    path = str(tmp_path / "r.jsonl")
    sinks = parse_sinks(f"jsonl:{path}")
    drain({"loss": np.asarray([1.0, 0.5]),
           "d_norm_hist": np.asarray([[1, 2], [3, 4]], np.int32)},
          sinks=sinks)
    for s in sinks:
        s.close()
    evs = [json.loads(line) for line in open(path)]
    assert evs[0]["d_norm_hist"] == [1, 2]
    assert evs[1]["d_norm_hist"] == [3, 4]


def test_drain_splits_leaf_series_into_leaf_stats_events():
    sink = MemorySink()
    drain({"loss": np.asarray([1.0, 0.5]),
           "leaf_msg_norm": np.asarray([[1.0, 2.0], [3.0, 4.0]]),
           "leaf_compress_err": np.asarray([[0.1, 0.2], [0.3, 0.4]])},
          sinks=[sink], leaf_names=["embed", "head"])
    rounds = [e for e in sink.events if e["event"] == "round"]
    leaves = [e for e in sink.events if e["event"] == "leaf_stats"]
    assert len(rounds) == len(leaves) == 2
    assert "leaf_msg_norm" not in rounds[0]
    assert leaves[0]["names"] == ["embed", "head"]  # first event only
    assert "names" not in leaves[1]
    assert leaves[1]["msg_norm"] == [3.0, 4.0]
    assert leaves[0]["compress_err"] == [0.1, 0.2]


# ----------------------------------------------------------- parsing
def test_parse_telemetry_sketch_grammar():
    spec = parse_telemetry("jsonl:r.jsonl,hist:32:-10:2,topk:6,leafstats")
    assert spec.sketches == "auto" and spec.hist_bins == 32
    assert spec.hist_lo == -10.0 and spec.hist_hi == 2.0
    assert spec.topk == 6 and spec.leaf_stats
    bare = parse_telemetry("jsonl:r.jsonl")
    assert bare.sketches is False and not bare.leaf_stats
    assert parse_telemetry("hist").sketches == "auto"


def test_parse_sinks_skips_spec_parts(tmp_path):
    sinks = parse_sinks(f"jsonl:{tmp_path}/a.jsonl,hist:48,topk:4,leafstats")
    assert len(sinks) == 1
    for s in sinks:
        s.close()
    with pytest.raises(ValueError, match="unknown telemetry sink"):
        parse_sinks("histogram:48")


def test_wants_sketch_selection():
    assert Telemetry(sketches="auto").wants_sketch("d_norm")
    assert not Telemetry(sketches=False).wants_sketch("d_norm")
    only = Telemetry(sketches=("drift",))
    assert only.wants_sketch("drift") and not only.wants_sketch("d_norm")


def test_metrics_filter_applies_to_sketches():
    problem = _problem()
    spec = Telemetry(sketches="auto", metrics=("d_norm_hist", "d_norm_p99"))
    algo = FedScenario(telemetry=spec, **COMPOSED).apply(_fedcet(problem))
    res = simulate_quadratic(algo, problem, rounds=2)
    assert set(res.telemetry) == {"d_norm_hist", "d_norm_p99"}
