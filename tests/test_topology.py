"""The topology subsystem (repro/core/topology.py + engine with_topology).

Pins, in order:

* star specs are EXACT no-ops (the factory returns the algorithm object
  unchanged) and the attached ``Star`` machinery is trajectory-identical
  (<= 1e-12) to the bare engine for FedCET, FedAvg, SCAFFOLD and FedLin —
  bare AND composed with compression + participation;
* the spec grammar, mixing-matrix structure (doubly stochastic,
  Metropolis weights, spectral gap) and the weighted-reduce contract
  (hierarchical == star up to reassociation, for uniform, masked and
  zero-group weights; gossip rows renormalize);
* the NIDS lineage: the NIDS spec under the star topology IS
  ``FedCETLiteral`` with ``c * alpha = 1/2`` (<= 1e-12), and NIDS over
  ring / torus / Erdős–Rényi gossip converges to the EXACT optimum —
  FedCET's origin recovered as a ~70-line engine spec + a mixing matrix;
* measured convergence: FedCET stays exact (~1e-14) under 2-level
  hierarchical aggregation — alone, with a shift:q8 8-bit uplink, with
  client sampling, and with rr:2 staleness (full sweep in
  benchmarks/topology_sweep.py) — and under ring gossip;
* determinism and checkpoint/resume: a per-round resampled
  Erdős–Rényi graph (the stateful-topology path) draws the same schedule
  across runs, and the ``TopoState`` round index rides ``EngineState``
  extras through save/restore, also when composed with ``with_delay``
  (TopoState just before the final DelayState slot);
* per-hop comm accounting: the hierarchy's root ingests ``g`` messages
  (billed dense f32 per tier) while the client tier pays the compressed
  wire width x the duty cycle; gossip bills one message per directed
  edge and NO downlink broadcast; present-only downlink bills the
  broadcast at the participation rate;
* THE DENSE-EQUIVALENCE HARNESS for the sparse exchange lowering: the
  padded neighbor-index exchange (``ring:sparse`` / ``torus:sparse`` /
  ``er:p[:t]:sparse``) is <= 1e-12 against the dense N x N contraction
  on FedCET and NIDS — bare, composed with shift:q8 x 0.8 participation
  x fixed:2 delay in EVERY factory order, and round-by-round on the
  per-round resampled graph (whose neighbor tables rebuild from the
  TopoState stream, surviving checkpoint resume mid-sweep);
* tier recompression: ``hier`` with ``tier_compression=`` compresses the
  interior edge->root partial means — exact per-hop accounting (8-bit
  tiers, dense downward re-broadcasts), shift memory riding TopoState
  through checkpoint/resume, and the measured convergence boundary:
  FedAvg stays EXACT under shift:q8 tiers (memoryless mean) while
  FedCET freezes at a ~quantizer-resolution offset — the tier hop's
  transmission error integrates into ``sum_i d_i`` (no wire-consistency
  at interior hops) and permanently displaces the Lemma 2 fixed point;
* Mixing grammar/validation gaps surfaced by the lowering: torus
  ``shape`` vs ``n`` mismatch, max-degree overflow on a dense
  Erdős–Rényi draw, resampled graphs rejecting explicit degree caps,
  unknown lowering names, tier compression on non-hierarchies.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    NIDS,
    CommMeter,
    EngineState,
    FedAvg,
    FedCET,
    FedCETLiteral,
    FedLin,
    Hierarchical,
    Mixing,
    Scaffold,
    Star,
    TopoState,
    comm_bits_per_round,
    comm_hops_per_round,
    max_weight_c,
    parse_topology,
    run_rounds,
    with_compression,
    with_delay,
    with_participation,
    with_topology,
)
from repro.core.lr_search import lr_search
from repro.core.simulate import simulate_quadratic
from repro.core.staleness import DelayState
from repro.data.quadratic import make_quadratic_problem

jax.config.update("jax_enable_x64", True)

TAU = 2
_TOL = dict(rtol=1e-12, atol=1e-12)
N = 10  # the paper problem's client count


@pytest.fixture(scope="module")
def problem():
    return make_quadratic_problem(0)


def _fedcet(problem, tau=TAU):
    alpha = lr_search(problem.mu, problem.L, tau)
    return FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=tau,
                  n_clients=problem.n_clients)


def _all_algos(problem):
    n, L = problem.n_clients, problem.L
    return {
        "fedcet": _fedcet(problem),
        "fedavg": FedAvg(alpha=1.0 / (2 * TAU * L), tau=TAU, n_clients=n),
        "scaffold": Scaffold(alpha_l=1.0 / (81 * TAU * L), tau=TAU, n_clients=n),
        "fedlin": FedLin(alpha=1.0 / (18 * TAU * L), tau=TAU, n_clients=n,
                         k_frac=0.3),
    }


# ------------------------------------------------------------ exact no-ops
def test_star_specs_are_exact_noops(problem):
    algo = _fedcet(problem)
    for spec in ("star", "none", "", None, Star()):
        assert with_topology(algo, spec) is algo


def test_star_machinery_seed_equivalent_all_algorithms(problem):
    """The Star object attached EXPLICITLY (bypassing the factory's
    identity shortcut) runs the full weighted-reduce machinery and must
    reproduce the bare engine <= 1e-12 on every algorithm — including
    FedLin, whose round-start gradient exchange also flows through the
    topology's aggregator."""
    for name, algo in _all_algos(problem).items():
        ref = simulate_quadratic(algo, problem, rounds=12)
        res = simulate_quadratic(dataclasses.replace(algo, topology=Star()),
                                 problem, rounds=12)
        np.testing.assert_allclose(np.asarray(res.errors),
                                   np.asarray(ref.errors), **_TOL,
                                   err_msg=name)


def test_star_machinery_noop_composed_with_transforms(problem):
    """Star equivalence must survive composition: the topology's weighted
    reduce receives the participation mask as weights and must match the
    masked mean path bit-for-bit-ish (<= 1e-12)."""
    base = with_compression(with_participation(_fedcet(problem), 0.7, seed=5),
                            compressor="shift:q8")
    ref = simulate_quadratic(base, problem, rounds=30)
    res = simulate_quadratic(dataclasses.replace(base, topology=Star()),
                             problem, rounds=30)
    np.testing.assert_allclose(np.asarray(res.errors),
                               np.asarray(ref.errors), **_TOL)


def test_stacked_topology_raises(problem):
    algo = with_topology(_fedcet(problem), "hier:g5")
    with pytest.raises(ValueError, match="already has a topology"):
        with_topology(algo, "ring")


# ------------------------------------------------------------------ grammar
def test_parse_topology_grammar():
    assert parse_topology("star", N) is None
    assert parse_topology(None, N) is None
    assert parse_topology("hier:g5", N) == Hierarchical((5,))
    assert parse_topology("hier:5", N) == Hierarchical((5,))
    assert parse_topology("hier:5x2", N) == Hierarchical((5, 2))
    assert parse_topology("ring", N).graph == "ring"
    assert parse_topology("torus", N).graph == "torus2x5"
    assert parse_topology("torus:2x5", N).graph == "torus2x5"
    er = parse_topology("er:0.4", N)
    assert er.graph == "er" and er.p == 0.4 and not er.resample
    ert = parse_topology("er:0.4:t", N)
    assert ert.resample and ert.stateful and ert.n == N
    with pytest.raises(ValueError, match="unknown topology"):
        parse_topology("tree:3", N)
    with pytest.raises(ValueError, match="bad hierarchical"):
        parse_topology("hier:", N)
    with pytest.raises(ValueError, match="strictly decrease"):
        parse_topology("hier:2x5", N)
    with pytest.raises(ValueError, match="torus"):
        parse_topology("torus:3x5", N)
    with pytest.raises(ValueError, match="nodes"):
        parse_topology(Mixing.ring(8), N)  # 8-node matrix, 10 clients


def test_mixing_matrices_doubly_stochastic():
    for topo in (Mixing.ring(N), Mixing.torus(N), Mixing.erdos_renyi(N, 0.5),
                 Mixing.torus(12, shape=(3, 4))):
        W = np.asarray(topo.w)
        np.testing.assert_allclose(W, W.T, atol=0)
        np.testing.assert_allclose(W.sum(axis=0), 1.0, atol=1e-12)
        np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-12)
        assert (W >= 0).all()
        assert 0.0 < topo.spectral_gap <= 1.0
    # denser graphs mix faster: ER(0.8) gap > ring gap at N=10
    assert Mixing.erdos_renyi(N, 0.9, seed=1).spectral_gap \
        > Mixing.ring(N).spectral_gap


# ------------------------------------------------------- weighted reduction
def test_hierarchical_reduce_matches_star_weighted_mean():
    """Grouped two-stage (and three-stage) weighted means are exact
    regroupings of the flat weighted mean — same value up to float
    reassociation — including non-uniform weights, non-divisible group
    sizes and groups whose weight mass is entirely zero."""
    key = jax.random.key(0)
    tree = {"a": jax.random.normal(key, (N, 7)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (N,))}
    star = Star()
    for w in (jnp.ones((N,)),
              jax.random.uniform(jax.random.fold_in(key, 2), (N,)),
              jnp.asarray([0.0, 0.0, 1, 1, 1, 0, 1, 1, 1, 1.0])):  # group 0 dead
        ref = star.reduce(tree, w)
        for groups in ((5,), (3,), (4, 2), (7,)):
            out = Hierarchical(groups).reduce(tree, w)
            np.testing.assert_allclose(
                np.asarray(out["a"]), np.asarray(ref["a"]), rtol=1e-12,
                err_msg=str(groups))
            np.testing.assert_allclose(
                np.asarray(out["b"]), np.asarray(ref["b"]), rtol=1e-12)


def test_mixing_reduce_neighborhood_means():
    """Gossip reduce returns PER-CLIENT rows: W-weighted neighborhood
    means, renormalized over the surviving weights when some clients are
    masked out."""
    topo = Mixing.ring(4)
    tree = {"v": jnp.asarray([[1.0], [2.0], [3.0], [4.0]])}
    out = topo.reduce(tree, jnp.ones((4,)))["v"]
    assert out.shape == (4, 1)
    W = np.asarray(topo.w)
    np.testing.assert_allclose(np.asarray(out)[:, 0],
                               W @ np.array([1, 2, 3, 4.0]), rtol=1e-12)
    # mask client 0 out: each row renormalizes over its remaining neighbors
    w = jnp.asarray([0.0, 1.0, 1.0, 1.0])
    out = np.asarray(topo.reduce(tree, w)["v"])[:, 0]
    Wm = W * np.array([0, 1, 1, 1.0])
    np.testing.assert_allclose(out, (Wm @ np.array([1, 2, 3, 4.0]))
                               / Wm.sum(axis=1), rtol=1e-12)
    # column-stochasticity preserves the uniform-weight client mean:
    # mean_i (W m)_i == mean_i m_i — the invariant FedCET's drift needs
    full = topo.reduce(tree, jnp.ones((4,)))["v"]
    np.testing.assert_allclose(float(jnp.mean(full)), 2.5, rtol=1e-12)


# ------------------------------------------------------------- NIDS lineage
def test_nids_star_is_fedcet_literal_lineage(problem):
    """The lineage proof in executable form: under the star topology the
    NIDS spec's lazy half-step ``x <- (m + m_bar)/2`` is FedCETLiteral's
    aggregation with ``c * alpha = 1/2`` — identical trajectories."""
    alpha = 1.0 / problem.L
    nids = NIDS(alpha=alpha, n_clients=problem.n_clients)
    literal = FedCETLiteral(alpha=alpha, c=0.5 / alpha, tau=1,
                            n_clients=problem.n_clients)
    r_n = simulate_quadratic(nids, problem, rounds=150)
    r_l = simulate_quadratic(literal, problem, rounds=150)
    np.testing.assert_allclose(np.asarray(r_n.errors),
                               np.asarray(r_l.errors), **_TOL)


def test_nids_gossip_converges_exactly(problem):
    """NIDS proper: the decentralized optimizer FedCET descends from,
    over actual gossip graphs — exact linear convergence to the global
    optimum for every connected doubly-stochastic topology (measured
    ~5e-15 at 2000 rounds; the rate-vs-spectral-gap sweep is pinned in
    benchmarks/topology_sweep.py)."""
    nids = NIDS(alpha=1.0 / problem.L, n_clients=problem.n_clients)
    for spec in ("ring", "torus", "er:0.5"):
        algo = with_topology(nids, spec)
        res = simulate_quadratic(algo, problem, rounds=2000)
        assert res.final_error < 1e-9, (spec, res.final_error)


# ------------------------------------------- measured convergence boundaries
def test_fedcet_exact_under_hierarchical_aggregation(problem):
    """THE tentpole result: FedCET's exact linear convergence SURVIVES
    multi-hop aggregation — 2-level (and 3-level) hierarchical trees are
    exact regroupings of the mean, so the fixed-point structure is
    untouched (~3e-15), including with a shift:q8 8-bit uplink, client
    sampling, and rr:2 staleness riding the same weighted reduce."""
    base = _fedcet(problem)
    for spec in ("hier:g5", "hier:4x2"):
        hier = with_topology(base, spec)
        assert simulate_quadratic(hier, problem, rounds=800).final_error \
            < 1e-9, spec
    hier = with_topology(base, "hier:g5")
    stacks = {
        "shift:q8": with_compression(hier, compressor="shift:q8"),
        "part": with_participation(hier, 0.8, seed=3),
        "q8+part": with_compression(with_participation(hier, 0.8, seed=3),
                                    compressor="shift:q8"),
        "rr2:last": with_delay(hier, "rr:2", policy="last"),
    }
    for name, algo in stacks.items():
        res = simulate_quadratic(algo, problem, rounds=1200)
        assert res.final_error < 1e-9, (name, res.final_error)


def test_fedcet_exact_under_ring_gossip(problem):
    """Beyond the paper: FedCET's aggregating step run through a
    doubly-stochastic RING instead of the server mean still converges
    exactly — column-stochasticity keeps ``sum_i d_i = 0``."""
    algo = with_topology(_fedcet(problem), "ring")
    res = simulate_quadratic(algo, problem, rounds=1200)
    assert res.final_error < 1e-9, res.final_error
    d_mean = np.asarray(jnp.mean(res.state.d, axis=0))
    np.testing.assert_allclose(d_mean, 0.0, atol=1e-10)


def test_hierarchical_trajectory_tracks_star(problem):
    """Short-horizon check that hierarchy is pure reassociation: 12
    rounds stay within 1e-12 of the flat star trajectory."""
    ref = simulate_quadratic(_fedcet(problem), problem, rounds=12)
    res = simulate_quadratic(with_topology(_fedcet(problem), "hier:g5"),
                             problem, rounds=12)
    np.testing.assert_allclose(np.asarray(res.errors),
                               np.asarray(ref.errors), **_TOL)


# ------------------------------------------------------------- determinism
def test_resampled_graph_deterministic_across_runs(problem):
    """er:p:t redraws the graph every aggregation from the TopoState
    round index through a domain-separated stream — same seed, same
    schedule, bit-equal error curves across independent runs."""
    algo = with_topology(_fedcet(problem), "er:0.5:t", seed=11)
    r1 = simulate_quadratic(algo, problem, rounds=40)
    r2 = simulate_quadratic(algo, problem, rounds=40)
    np.testing.assert_array_equal(np.asarray(r1.errors), np.asarray(r2.errors))
    assert isinstance(r1.state, EngineState)
    assert isinstance(r1.state.extras[-1], TopoState)
    # init ran one warm-up aggregation + 40 rounds
    assert int(r1.state.extras[-1].k) == 41


def test_topology_seed_varies_resampled_schedule(problem):
    algo_a = with_topology(_fedcet(problem), "er:0.5:t", seed=0)
    algo_b = with_topology(_fedcet(problem), "er:0.5:t", seed=1)
    ra = simulate_quadratic(algo_a, problem, rounds=40)
    rb = simulate_quadratic(algo_b, problem, rounds=40)
    assert (np.asarray(ra.errors) != np.asarray(rb.errors)).any()


@pytest.mark.parametrize("delayed", [False, True])
def test_checkpoint_resume_reproduces_topo_state(problem, delayed, tmp_path):
    """Save/restore mid-sweep: the TopoState round index rides in
    EngineState (just before the DelayState slot when with_delay is also
    attached), round-trips the npz checkpoint exactly, and the resumed
    run continues bit-compatibly with the uninterrupted one."""
    from repro.checkpoint.ckpt import load_pytree, save_pytree

    algo = with_topology(_fedcet(problem), "er:0.6:t", seed=3)
    if delayed:
        algo = with_delay(algo, "rr:2", policy="last")
    gf = jax.grad(problem.client_loss)
    batches = problem.stacked_batches(TAU)
    init_b = jax.tree.map(lambda b: b[0], batches)
    x0 = jnp.zeros((problem.dim,), problem.b.dtype)
    state0 = algo.init(gf, x0, init_b)
    tstate = state0.extras[-2] if delayed else state0.extras[-1]
    assert isinstance(tstate, TopoState) and int(tstate.k) == 1
    if delayed:
        assert isinstance(state0.extras[-1], DelayState)

    full, _ = run_rounds(algo, gf, state0, batches, rounds=8)
    half, _ = run_rounds(algo, gf, state0, batches, rounds=4)
    path = str(tmp_path / "mid.npz")
    save_pytree(path, half)
    back = load_pytree(path, half)
    for a, b in zip(jax.tree.leaves(half), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    resumed, _ = run_rounds(algo, gf, back, batches, rounds=4)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **_TOL)


def test_abstract_state_matches_topology_extras():
    """The AOT lowering path: abstract_state inserts the TopoState slot
    (scalar int32) for a stateful topology, before the DelayState slot."""
    from repro.configs.base import FedScenario
    from repro.launch.mesh import make_test_mesh
    from repro.launch.train import abstract_state, make_plan, state_shardings

    mesh = make_test_mesh((1, 1))  # single-host CPU mesh
    plan = make_plan("qwen3-1.7b", mesh,
                     scenario=FedScenario(topology="er:0.5:t", delay="rr:1"))
    shapes = abstract_state(plan)
    assert isinstance(shapes, EngineState)
    assert isinstance(shapes.extras[-2], TopoState)
    assert shapes.extras[-2].k.shape == ()
    assert isinstance(shapes.extras[-1], DelayState)
    sh = state_shardings(plan, shapes)
    assert isinstance(sh.extras[-2], TopoState)


# -------------------------------------------------------- per-hop accounting
def test_hierarchical_per_hop_accounting(problem):
    """Root ingress shrinks from N to g messages; the client hop pays the
    compressed width x duty, aggregator tiers re-transmit dense f32 (both
    directions), and CommMeter agrees with comm_bits_per_round."""
    n, dim = problem.n_clients, problem.dim
    base = _fedcet(problem)
    hier = with_topology(with_compression(base, compressor="shift:q8"),
                         "hier:g5")
    hops = comm_hops_per_round(hier, dim, n)
    assert [h["hop"] for h in hops] == ["client", "tier1->root"]
    assert hops[0]["messages"] == n and hops[1]["messages"] == 5
    assert hops[0]["bits"] == dim * n * 8.0          # q8 wire width
    assert hops[1]["bits"] == dim * 5 * 32.0         # dense partial means
    bits = comm_bits_per_round(hier, dim, n)
    assert bits["up_bits"] == hops[0]["bits"] + hops[1]["bits"]
    assert bits["down_bits"] == dim * (n + 5) * 32.0
    params = {"w": jnp.zeros((dim,))}
    m = CommMeter.for_params(params, algo=hier, n_clients=n)
    m.tick_round(hier)
    assert m.bytes_up == int(bits["up_bits"] / 8)
    assert m.bytes_down == int(bits["down_bits"] / 8)
    # 3-level tree: both tiers appear
    deep = with_topology(base, "hier:4x2")
    assert [h["messages"] for h in comm_hops_per_round(deep, dim, n)] \
        == [n, 4, 2]


def test_mixing_accounting_edges_no_broadcast(problem):
    """Gossip bills one message per directed edge on the (only) uplink
    hop and NO broadcast downlink; the expected-edge count drives the
    resampled variant."""
    n, dim = problem.n_clients, problem.dim
    ring = with_topology(_fedcet(problem), "ring")
    assert ring.topology.client_up_mult(n) == 2.0  # ring degree
    bits = comm_bits_per_round(ring, dim, n)
    assert bits["up_bits"] == dim * n * 2 * 32.0
    assert bits["down_bits"] == 0.0
    ert = with_topology(_fedcet(problem), "er:0.4:t")
    assert ert.topology.client_up_mult(n) == pytest.approx((n - 1) * 0.4)


def test_present_only_downlink_duty(problem):
    """Present-only downlink: absent clients keep frozen replicas instead
    of receiving phantom broadcasts, so downlink is billed at the
    participation rate — for FedCET and the replicated-state baselines
    alike; delay models leave downlink dense."""
    n, dim = problem.n_clients, problem.dim
    base = _fedcet(problem)
    assert base.receive_frac == 1.0
    assert with_delay(base, "fixed:2").receive_frac == 1.0
    part = with_participation(base, 0.8, seed=0)
    assert part.receive_frac == pytest.approx(0.8)
    scaffold = with_participation(
        Scaffold(alpha_l=0.01, tau=TAU, n_clients=n), 0.5)
    assert scaffold.receive_frac == pytest.approx(0.5)
    bits = comm_bits_per_round(part, dim, n)
    assert bits["down_bits"] == pytest.approx(dim * n * 32.0 * 0.8)
    params = {"w": jnp.zeros((dim,))}
    m = CommMeter.for_params(params, algo=part, n_clients=n)
    m.tick_round(part)
    assert m.bytes_down == int(dim * n * 32.0 * 0.8 / 8)
    sync = CommMeter.for_params(params, algo=base, n_clients=n)
    sync.tick_round(base)
    assert sync.bytes_down == int(dim * n * 32.0 / 8)


# ------------------------------------------------- sparse exchange lowering
def _state_allclose(a, b, **tol):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **tol)


def test_sparse_lowering_matches_dense_all_families(problem):
    """THE dense-equivalence harness: the padded neighbor-exchange
    lowering is the SAME aggregation as the dense N x N contraction —
    trajectories AND final states <= 1e-12 on FedCET and NIDS for every
    connected graph family."""
    algos = {"fedcet": _fedcet(problem),
             "nids": NIDS(alpha=1.0 / problem.L, n_clients=problem.n_clients)}
    for name, algo in algos.items():
        for spec in ("ring", "torus", "er:0.5"):
            ref = simulate_quadratic(with_topology(algo, spec), problem,
                                     rounds=15)
            res = simulate_quadratic(with_topology(algo, spec + ":sparse"),
                                     problem, rounds=15)
            np.testing.assert_allclose(np.asarray(res.errors),
                                       np.asarray(ref.errors), **_TOL,
                                       err_msg=f"{name}/{spec}")
            _state_allclose(res.state, ref.state, **_TOL)


def test_sparse_lowering_composed_every_factory_order(problem):
    """ring:sparse under shift:q8 x 0.8 participation x fixed:2 delay,
    attached in EVERY factory order: all 24 orders build the SAME
    composed algorithm object (the transform slots are independent), and
    its trajectory matches the dense lowering of the same stack
    <= 1e-12."""
    import itertools

    base = _fedcet(problem)

    def build(order, spec):
        factories = {
            "topo": lambda a: with_topology(a, spec),
            "comp": lambda a: with_compression(a, compressor="shift:q8"),
            "part": lambda a: with_participation(a, 0.8, seed=3),
            "delay": lambda a: with_delay(a, "fixed:2", policy="last"),
        }
        algo = base
        for name in order:
            algo = factories[name](algo)
        return algo

    orders = list(itertools.permutations(("topo", "comp", "part", "delay")))
    sparse_algos = [build(o, "ring:sparse") for o in orders]
    assert all(a == sparse_algos[0] for a in sparse_algos[1:])
    ref = simulate_quadratic(build(orders[0], "ring"), problem, rounds=30)
    res = simulate_quadratic(sparse_algos[0], problem, rounds=30)
    np.testing.assert_allclose(np.asarray(res.errors),
                               np.asarray(ref.errors), **_TOL)
    _state_allclose(res.state, ref.state, **_TOL)


def test_sparse_resampled_er_matches_dense_roundwise(problem):
    """The per-round resampled graph: sparse neighbor tables rebuilt
    in-trace from the TopoState stream draw the SAME graph sequence as
    the dense matrix — round-by-round error agreement <= 1e-12."""
    rd = simulate_quadratic(with_topology(_fedcet(problem), "er:0.5:t",
                                          seed=11), problem, rounds=30)
    rs = simulate_quadratic(with_topology(_fedcet(problem),
                                          "er:0.5:t:sparse", seed=11),
                            problem, rounds=30)
    np.testing.assert_allclose(np.asarray(rs.errors), np.asarray(rd.errors),
                               **_TOL)
    _state_allclose(rs.state, rd.state, **_TOL)


def test_sparse_resampled_determinism_and_resume(problem, tmp_path):
    """The sparse resampled path is deterministic across independent
    runs, and restart-from-checkpoint MID-SWEEP continues bit-compatibly
    — the neighbor tables rebuild from the checkpointed TopoState round
    index alone."""
    from repro.checkpoint.ckpt import load_pytree, save_pytree

    algo = with_topology(_fedcet(problem), "er:0.6:t:sparse", seed=3)
    r1 = simulate_quadratic(algo, problem, rounds=20)
    r2 = simulate_quadratic(algo, problem, rounds=20)
    np.testing.assert_array_equal(np.asarray(r1.errors), np.asarray(r2.errors))

    gf = jax.grad(problem.client_loss)
    batches = problem.stacked_batches(TAU)
    init_b = jax.tree.map(lambda b: b[0], batches)
    x0 = jnp.zeros((problem.dim,), problem.b.dtype)
    state0 = algo.init(gf, x0, init_b)
    full, _ = run_rounds(algo, gf, state0, batches, rounds=8)
    half, _ = run_rounds(algo, gf, state0, batches, rounds=4)
    path = str(tmp_path / "mid_sparse.npz")
    save_pytree(path, half)
    resumed, _ = run_rounds(algo, gf, load_pytree(path, half), batches,
                            rounds=4)
    _state_allclose(resumed, full, **_TOL)


def test_sparse_wide_table_fallback_matches_dense():
    """Tables wider than the unroll threshold (resampled graphs capped at
    n-1 with n > 33) take the gather + segment_sum fallback — pinned
    against the dense matrix of the same TopoState draw."""
    from repro.core.topology import _UNROLL_SLOTS

    n = 40
    topo = Mixing.erdos_renyi(n, 0.3, resample=True)
    sparse = dataclasses.replace(topo, lowering="sparse")
    assert sparse._resampled_tables(
        TopoState(k=jnp.zeros((), jnp.int32)), n,
        jnp.float64)[0].shape[1] > _UNROLL_SLOTS
    tree = {"v": jax.random.normal(jax.random.key(5), (n, 17)),
            "s": jax.random.normal(jax.random.key(6), (n,))}
    w = jnp.ones((n,)).at[3].set(0.0).at[11].set(0.0)
    for k in (0, 1, 7):
        ts = TopoState(k=jnp.asarray(k, jnp.int32))
        ref = topo.reduce(tree, w, ts)
        out = sparse.reduce(tree, w, ts)
        for leaf in tree:
            np.testing.assert_allclose(np.asarray(out[leaf]),
                                       np.asarray(ref[leaf]), **_TOL)


def test_sparse_spec_grammar():
    from repro.core.compressors import ErrorFeedback, Shifted, StochasticQuant

    t = parse_topology("ring:sparse", N)
    assert isinstance(t, Mixing) and t.graph == "ring"
    assert t.lowering == "sparse"
    assert parse_topology("ring", N).lowering == "dense"
    assert parse_topology("torus:2x5:sparse", N).lowering == "sparse"
    t = parse_topology("er:0.4:sparse", N)
    assert t.lowering == "sparse" and not t.resample
    t = parse_topology("er:0.4:t:sparse", N)
    assert t.lowering == "sparse" and t.resample and t.stateful
    with pytest.raises(ValueError, match="sparse"):
        parse_topology("hier:g5:sparse", N)
    with pytest.raises(ValueError, match="tier_compression"):
        parse_topology("ring", N, tier_compression="q8")
    with pytest.raises(ValueError, match="tier_compression"):
        parse_topology("star", N, tier_compression="q8")
    # tier specs follow the engine's auto-EF policy: unbiased stays bare,
    # biased wraps, shift: passes through.
    h = parse_topology("hier:g5", N, tier_compression="q8")
    assert isinstance(h.tier_compression, StochasticQuant)
    assert isinstance(
        parse_topology("hier:g5", N, tier_compression="topk:0.3")
        .tier_compression, ErrorFeedback)
    assert isinstance(
        parse_topology("hier:g5", N, tier_compression="shift:q8")
        .tier_compression, Shifted)
    assert parse_topology("hier:g5", N, tier_compression="none") \
        == parse_topology("hier:g5", N)


def test_mixing_validation_gaps():
    """The grammar/validation gaps the lowering surfaced: torus shape/n
    mismatch, max-degree overflow on a dense Erdős–Rényi draw, resampled
    graphs rejecting any explicit degree cap, unknown lowering names."""
    with pytest.raises(ValueError, match="torus shape"):
        Mixing.torus(10, shape=(3, 4))
    assert Mixing.torus(12, shape=(3, 4)).n == 12  # consistent pair: fine
    dense_er = Mixing.erdos_renyi(10, 0.9, seed=1)
    with pytest.raises(ValueError, match="overflows"):
        dataclasses.replace(dense_er, lowering="sparse", max_degree=2)
    with pytest.raises(ValueError, match="cannot bound"):
        dataclasses.replace(Mixing.erdos_renyi(10, 0.5, resample=True),
                            max_degree=4)
    # a resampled cap ABOVE n-1 (one uniform cap across varying n) is
    # honored by clamping to the n-1 slots a node can actually have
    wide = dataclasses.replace(Mixing.erdos_renyi(10, 0.5, resample=True),
                               lowering="sparse", max_degree=15)
    tree = {"v": jnp.ones((10, 3))}
    out = wide.reduce(tree, jnp.ones((10,)),
                      TopoState(k=jnp.zeros((), jnp.int32)))
    np.testing.assert_allclose(np.asarray(out["v"]), 1.0, rtol=1e-12)
    with pytest.raises(ValueError, match="lowering"):
        dataclasses.replace(Mixing.ring(10), lowering="csr")
    # an explicit cap >= the actual degree is honored: wider pad tables
    ok = dataclasses.replace(Mixing.ring(10), lowering="sparse", max_degree=4)
    idx, wgt = ok._static_tables()
    assert idx.shape == (10, 5)
    assert (wgt[:, 3:] == 0).all()  # ring degree 2: the extra slots pad


def test_sparse_gossip_accounting_identical_to_dense(problem):
    """The lowering changes the EXECUTION, not the exchange: identical
    per-hop messages and bits for every family, including the expected
    edge count of the resampled graph."""
    n, dim = problem.n_clients, problem.dim
    for spec in ("ring", "torus", "er:0.5", "er:0.4:t"):
        d = with_topology(_fedcet(problem), spec)
        s = with_topology(_fedcet(problem), spec + ":sparse")
        assert comm_hops_per_round(s, dim, n) == comm_hops_per_round(d, dim, n)
        assert comm_bits_per_round(s, dim, n) == comm_bits_per_round(d, dim, n)
    ring_s = with_topology(_fedcet(problem), "ring:sparse")
    assert ring_s.topology.client_up_mult(n) == 2.0
    assert ring_s.topology.broadcast_mult(n) == 0.0


# -------------------------------------------------------- tier recompression
def test_tier_recompression_accounting(problem):
    """Compressed interior hops: with shift:q8 tiers the edge->root hop
    pays 8 bits/coord (instead of dense f32) so the FULL uplink is
    compressed end to end; the downward tier re-broadcast stays dense
    f32, and CommMeter agrees with comm_bits_per_round."""
    n, dim = problem.n_clients, problem.dim
    algo = with_topology(
        with_compression(_fedcet(problem), compressor="shift:q8"),
        "hier:g5", tier_compression="shift:q8")
    assert algo.topology.tier_bits_per_coord == 8.0
    hops = comm_hops_per_round(algo, dim, n)
    assert [h["hop"] for h in hops] == ["client", "tier1->root"]
    assert hops[0]["bits"] == dim * n * 8.0   # shift:q8 client uplink
    assert hops[1]["bits"] == dim * 5 * 8.0   # shift:q8 interior tier
    bits = comm_bits_per_round(algo, dim, n)
    assert bits["up_bits"] == dim * (n + 5) * 8.0
    assert bits["down_bits"] == dim * (n + 5) * 32.0  # downward stays dense
    params = {"w": jnp.zeros((dim,))}
    m = CommMeter.for_params(params, algo=algo, n_clients=n)
    m.tick_round(algo)
    assert m.bytes_up == int(bits["up_bits"] / 8)
    assert m.bytes_down == int(bits["down_bits"] / 8)
    # without tier compression the interior hop stays dense f32
    plain = with_topology(_fedcet(problem), "hier:g5")
    assert plain.topology.tier_bits_per_coord == 32.0
    assert comm_hops_per_round(plain, dim, n)[1]["bits"] == dim * 5 * 32.0


def test_tier_recompression_fedavg_exact_fedcet_floors(problem):
    """The measured convergence boundary of tier recompression: FedAvg's
    memoryless mean FORGIVES the interior-hop quantization (exact,
    ~1e-15, because the shifted quantizer's error shrinks with the
    round-to-round change of the partial means) — but FedCET's drift
    integrator does not: the tier hop's transmission error enters
    ``sum_i d_i`` un-redistributed (no wire-consistency at interior
    hops), the invariant drifts during the transient, and the trajectory
    converges to a PERMANENTLY OFFSET fixed point at ~quantizer
    resolution (~1.5e-3 at q8, seed-dependent; scales as 2^-bits)."""
    from repro.core import FedAvg

    fedavg = FedAvg(alpha=1.0 / (2 * TAU * problem.L), tau=TAU,
                    n_clients=problem.n_clients)
    res = simulate_quadratic(
        with_topology(fedavg, "hier:g5", tier_compression="shift:q8"),
        problem, rounds=1200)
    assert res.final_error < 1e-9, res.final_error

    res = simulate_quadratic(
        with_topology(_fedcet(problem), "hier:g5",
                      tier_compression="shift:q8"),
        problem, rounds=800)
    errs = np.asarray(res.errors)
    assert 1e-4 < errs[-1] < 1e-2, errs[-1]            # the frozen offset
    np.testing.assert_allclose(errs[-1], errs[400], rtol=0.5)  # frozen, not
    # a random walk: the drift invariant broke and STAYED broken.
    d_sum = np.linalg.norm(np.asarray(jnp.sum(res.state.inner.d, axis=0)))
    assert d_sum > 1e-3, d_sum


def test_tier_recompression_state_checkpoint_resume(problem, tmp_path):
    """Stateful tier compression rides TopoState: the per-tier shift
    memory (one [g, dim] tree per tier) sits in the extras slot just
    before DelayState, round-trips the npz checkpoint, and the resumed
    run continues bit-compatibly mid-sweep."""
    from repro.checkpoint.ckpt import load_pytree, save_pytree

    algo = with_delay(
        with_topology(_fedcet(problem), "hier:g5",
                      tier_compression="shift:q8"),
        "rr:2", policy="last")
    gf = jax.grad(problem.client_loss)
    batches = problem.stacked_batches(TAU)
    init_b = jax.tree.map(lambda b: b[0], batches)
    x0 = jnp.zeros((problem.dim,), problem.b.dtype)
    state0 = algo.init(gf, x0, init_b)
    tstate = state0.extras[-2]
    assert isinstance(tstate, TopoState) and int(tstate.k) == 1
    assert isinstance(tstate.tier, tuple) and len(tstate.tier) == 1
    assert jax.tree.leaves(tstate.tier)[0].shape == (5, problem.dim)
    assert isinstance(state0.extras[-1], DelayState)

    full, _ = run_rounds(algo, gf, state0, batches, rounds=8)
    half, _ = run_rounds(algo, gf, state0, batches, rounds=4)
    path = str(tmp_path / "tier.npz")
    save_pytree(path, half)
    back = load_pytree(path, half)
    for a, b in zip(jax.tree.leaves(half), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    resumed, _ = run_rounds(algo, gf, back, batches, rounds=4)
    _state_allclose(resumed, full, **_TOL)
    # a stateless-but-stochastic tier compressor carries only the round
    # index (tier=None) — and q8 tiers on a STATELESS hierarchy need no
    # TopoState at all when the compressor is deterministic.
    q8 = with_topology(_fedcet(problem), "hier:g5", tier_compression="q8")
    s0 = q8.init(gf, x0, init_b)
    assert isinstance(s0.extras[-1], TopoState)
    assert s0.extras[-1].tier is None
    # the "bf16" SPEC goes through the auto-EF policy (biased -> wrapped,
    # hence stateful); a deterministic stateless compressor attached
    # directly keeps the whole hierarchy stateless.
    from repro.core.compressors import Bf16, ErrorFeedback

    bf16 = with_topology(_fedcet(problem), "hier:g5", tier_compression="bf16")
    assert isinstance(bf16.topology.tier_compression, ErrorFeedback)
    assert bf16.topology.stateful is True
    assert Hierarchical((5,), tier_compression=Bf16()).stateful is False


def test_abstract_state_tier_compression_extras():
    """The AOT lowering path: abstract_state shapes the TopoState tier
    memory (per-tier [g, ...] trees) via the topology's own init_state
    under eval_shape, and state_shardings replicates it."""
    from repro.core.fedcet import FedCET
    from repro.launch.mesh import make_test_mesh
    from repro.launch.train import abstract_state, make_plan, state_shardings

    mesh = make_test_mesh((1, 1))  # single-host CPU mesh
    plan = make_plan("qwen3-1.7b", mesh)
    algo = with_topology(
        FedCET(alpha=1e-3, c=0.05, tau=2, n_clients=8),
        "hier:g4", tier_compression="shift:q8")
    plan = dataclasses.replace(plan, algo=algo, n_clients=8)
    shapes = abstract_state(plan)
    assert isinstance(shapes, EngineState)
    tstate = shapes.extras[-1]
    assert isinstance(tstate, TopoState) and tstate.k.shape == ()
    assert isinstance(tstate.tier, tuple) and len(tstate.tier) == 1
    x_leaves = jax.tree.leaves(shapes.inner.x)
    t_leaves = jax.tree.leaves(tstate.tier)
    assert len(t_leaves) == len(x_leaves)
    assert all(t.shape == (4,) + x.shape[1:]
               for t, x in zip(t_leaves, x_leaves))
    sh = state_shardings(plan, shapes)
    assert isinstance(sh.extras[-1], TopoState)
