"""Faithful-reproduction tests: FedCET on the paper's §IV problem.

These tests ARE the paper validation: linear convergence to the exact
optimum under heterogeneous data (Corollary 1), equivalence of the (d, x)
form with the literal Algorithm 2 (Lemma 1), fixed-point characterization
(Lemma 2), and the measured contraction factor against the theoretical rho.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FedCET, FedCETLiteral, max_weight_c
from repro.core.lr_search import contraction_factors, lr_search
from repro.core.simulate import simulate_quadratic
from repro.data.quadratic import make_quadratic_problem

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def problem():
    return make_quadratic_problem(0)


@pytest.fixture(scope="module")
def fedcet_algo(problem):
    tau = 2
    alpha = lr_search(problem.mu, problem.L, tau)
    return FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=tau,
                  n_clients=problem.n_clients)


def test_gradient_matches_closed_form(problem):
    """jax.grad of the client loss equals the closed-form gradient."""
    x = jax.random.normal(jax.random.key(1), (problem.dim,))
    for i in range(problem.n_clients):
        batch = {"b": problem.b[i], "m": problem.m[i]}
        g = jax.grad(problem.client_loss)(x, batch)
        np.testing.assert_allclose(g, problem.client_grad(x, batch),
                                   rtol=1e-5, atol=1e-5)


def test_x_star_is_stationary(problem):
    g = jax.grad(problem.global_loss)(problem.x_star)
    np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-10)


def test_exact_convergence_heterogeneous(problem, fedcet_algo):
    """Claim 1: FedCET converges to the EXACT optimum despite heterogeneity."""
    res = simulate_quadratic(fedcet_algo, problem, rounds=400)
    assert res.final_error < 1e-9, f"did not reach exact optimum: {res.final_error}"


def test_linear_rate_matches_theory(problem, fedcet_algo):
    """Measured per-round contraction <= theoretical rho of Corollary 1
    (the theory is an upper bound; measured should be no worse)."""
    cf = contraction_factors(fedcet_algo.alpha, problem.mu, problem.L,
                             fedcet_algo.tau, problem.n_clients)
    assert cf.converges, f"Algorithm-1 alpha must satisfy rho<1, got {cf}"
    res = simulate_quadratic(fedcet_algo, problem, rounds=200)
    errs = np.asarray(res.errors)
    # geometric-mean contraction over the mid-trajectory (avoids transients
    # and the floating-point floor).
    window = errs[10:100]
    measured = (window[-1] / window[0]) ** (1.0 / (len(window) - 1))
    # rho bounds the squared Lyapunov function; per-round error contraction
    # is ~sqrt(rho). Allow the loose direction only.
    assert measured < np.sqrt(cf.rho) + 1e-3, (measured, cf.rho)
    assert measured < 1.0


def test_dform_equals_literal_form(problem):
    """Lemma 1: the (d, x) production form and the printed 2-point form
    produce identical iterates at every communication round."""
    tau = 3
    alpha = lr_search(problem.mu, problem.L, tau)
    kw = dict(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=tau,
              n_clients=problem.n_clients)
    a = FedCET(**kw)
    b = FedCETLiteral(**kw)
    grad_fn = jax.grad(problem.client_loss)
    batches = problem.stacked_batches(tau)
    init_batch = jax.tree.map(lambda z: z[0], batches)
    x0 = jnp.zeros((problem.dim,))
    sa, sb = a.init(grad_fn, x0, init_batch), b.init(grad_fn, x0, init_batch)
    np.testing.assert_allclose(sa.x, sb.x_curr, rtol=1e-12, atol=1e-12)
    for _ in range(5):
        sa = a.round(grad_fn, sa, batches)
        sb = b.round(grad_fn, sb, batches)
        np.testing.assert_allclose(sa.x, sb.x_curr, rtol=1e-9, atol=1e-9)


def test_fixed_point_characterization(problem, fedcet_algo):
    """Lemma 2: at convergence d* = -grad_i(x*) per client and all clients
    hold the consensus x*."""
    res = simulate_quadratic(fedcet_algo, problem, rounds=600)
    x = np.asarray(res.state.x)      # [N, n]
    d = np.asarray(res.state.d)      # [N, n]
    x_star = np.asarray(problem.x_star)
    for i in range(problem.n_clients):
        np.testing.assert_allclose(x[i], x_star, atol=1e-7)
        batch = {"b": problem.b[i], "m": problem.m[i]}
        gi = np.asarray(problem.client_grad(jnp.asarray(x_star), batch))
        np.testing.assert_allclose(d[i], -gi, atol=1e-6)


def test_d_never_transmitted_one_vector_comm(fedcet_algo):
    """Remark 2: FedCET declares exactly one vector each way per round."""
    assert fedcet_algo.vectors_up == 1
    assert fedcet_algo.vectors_down == 1


@pytest.mark.parametrize("tau", [1, 2, 4, 8])
def test_convergence_across_tau(problem, tau):
    """Theory-prescribed alpha shrinks ~1/tau^2, so round counts scale with
    tau to reach the same error."""
    alpha = lr_search(problem.mu, problem.L, tau)
    algo = FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=tau,
                  n_clients=problem.n_clients)
    res = simulate_quadratic(algo, problem, rounds=200 * tau)
    assert res.final_error < 1e-6, (tau, res.final_error)


def test_exact_convergence_heterogeneous_hessians():
    """Stronger-than-paper validation: FedCET is exact even when client
    HESSIANS differ (the paper's experiment varies only the linear terms)."""
    from repro.data.quadratic import make_hetero_hessian_problem

    p = make_hetero_hessian_problem(7)
    tau = 2
    alpha = lr_search(p.mu, p.L, tau)
    algo = FedCET(alpha=alpha, c=max_weight_c(p.mu, alpha), tau=tau,
                  n_clients=p.n_clients)
    res = simulate_quadratic(algo, p, rounds=3000)
    assert res.final_error < 1e-9, res.final_error


def test_homogeneous_data_still_converges():
    """Sanity: with identical client datasets (IID limit) FedCET behaves like
    centralized gradient descent and still converges exactly."""
    p = make_quadratic_problem(3, n_clients=4)
    b_same = jnp.broadcast_to(p.b[:1], p.b.shape)
    p = type(p)(b=b_same, m=p.m)
    tau = 2
    alpha = lr_search(p.mu, p.L, tau)
    algo = FedCET(alpha=alpha, c=max_weight_c(p.mu, alpha), tau=tau,
                  n_clients=p.n_clients)
    res = simulate_quadratic(algo, p, rounds=300)
    assert res.final_error < 1e-10
