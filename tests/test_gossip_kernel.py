"""Pallas gossip segment-reduce kernel vs its ref.py oracle.

Separate from tests/test_kernels.py on purpose (same split as
tests/test_quantize_kernel.py): that module needs ``hypothesis`` (absent
in some environments, skipped by the conftest guard), while the gossip
segment reduce is on the sparse-exchange hot path and must stay covered
by the tier-1 suite everywhere — hypothesis-free, fixed-seed grids,
``interpret=True`` off-TPU, and only a handful of compiles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

#: (nodes, slots, dim) grids: uneven node blocks, lane-block boundaries
#: (128/1024 multiples and off-by-one), degenerate single-node case.
GRIDS = [(4, 3, 60), (8, 5, 128), (10, 3, 1025), (3, 7, 33), (1, 2, 4)]


@pytest.mark.parametrize("n,slots,dim", GRIDS)
def test_segment_reduce_matches_segment_sum(n, slots, dim):
    """Kernel == jax.ops.segment_sum over the fixed-slot segment ids, on
    fixed-seed value grids across node/lane padding regimes."""
    vals = jax.random.normal(jax.random.key(n * slots + dim),
                             (n * slots, dim), jnp.float32) * 3.0
    out = ops.gossip_reduce(vals, slots=slots)
    want = ref.segment_reduce(vals, slots)
    direct = jax.ops.segment_sum(
        vals, jnp.repeat(jnp.arange(n), slots), num_segments=n)
    np.testing.assert_allclose(np.asarray(want), np.asarray(direct),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    assert out.shape == (n, dim) and out.dtype == vals.dtype


def test_segment_reduce_zero_pad_slots_exact():
    """Zero rows (the sparse lowering's masked pad slots) contribute
    exactly 0 — the padded reduce equals the unpadded sum bit-for-bit
    when the pad slots hold zeros."""
    n, slots, dim = 6, 4, 96
    vals = jax.random.normal(jax.random.key(0), (n * slots, dim))
    mask = (jnp.arange(n * slots) % slots < 2)[:, None]  # 2 live slots/node
    masked = jnp.where(mask, vals, 0.0)
    out = ops.gossip_reduce(masked, slots=slots)
    live = vals.reshape(n, slots, dim)[:, :2, :]
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(live[:, 0] + live[:, 1]))


def test_mixing_use_kernel_path_matches_default():
    """Mixing(lowering="sparse", use_kernel=True) routes the reduce
    through the Pallas kernel and must match both the default (unrolled
    gather+fma) sparse path and the dense contraction — the flag can
    flip on TPU without changing semantics."""
    import dataclasses

    from repro.core.topology import Mixing

    topo = Mixing.torus(12, shape=(3, 4))
    tree = {"v": jax.random.normal(jax.random.key(1), (12, 37)),
            "s": jax.random.normal(jax.random.key(2), (12,))}
    w = jnp.asarray([1.0, 1, 0, 1, 1, 1, 1, 0, 1, 1, 1, 1])
    dense = topo.reduce(tree, w)
    sparse = dataclasses.replace(topo, lowering="sparse").reduce(tree, w)
    kern = dataclasses.replace(topo, lowering="sparse",
                               use_kernel=True).reduce(tree, w)
    for leaf in tree:
        np.testing.assert_allclose(np.asarray(sparse[leaf]),
                                   np.asarray(dense[leaf]),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(kern[leaf]),
                                   np.asarray(sparse[leaf]),
                                   rtol=1e-6, atol=1e-6)
