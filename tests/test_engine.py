"""Unified round engine: equivalence with the seed implementations.

The engine refactor (repro/core/engine.py) replaced seven hand-rolled round
bodies with one driver + slim per-algorithm specs. These tests pin the
refactor to the seed semantics:

* each migrated algorithm reproduces a reference implementation transcribed
  from the seed round bodies (python loops, no scan — so the tests also
  validate the engine's lax.scan lowering) to <= 1e-12 in float64. The
  residual is 1-2 ulp of XLA fusion rounding between jitted and op-by-op
  execution: running the engine against the JITTED seed implementation
  reproduces its floats exactly (verified during the migration; e.g. the
  compressed EF ablation numbers match the seed to the last bit);
* ``with_participation(rate=1.0)`` and ``with_compression(k_frac=1.0,
  quantize=False)`` are exact no-ops;
* the previously-impossible composition — compressed-uplink,
  partial-participation FedCET — converges to the exact optimum on the
  paper's quadratic problem;
* regression tests for the two participation bugs the refactor fixed
  (step counter advancing 2*tau-1 per round; shared PRNG key between the
  Bernoulli draw and the non-empty fallback).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FedAvg,
    FedCET,
    FedCETCompressed,
    FedCETPartial,
    FedLin,
    FedTrack,
    Scaffold,
    max_weight_c,
    participation_mask,
    with_compression,
    with_participation,
)
from repro.core.comm import topk_sparsify
from repro.core.lr_search import lr_search
from repro.core.simulate import simulate_quadratic
from repro.data.quadratic import make_quadratic_problem

jax.config.update("jax_enable_x64", True)

TAU = 2
ROUNDS = 25


@pytest.fixture(scope="module")
def problem():
    return make_quadratic_problem(0)


def _setup(problem, tau=TAU):
    """Shared pieces of every reference run: the vmapped gradient, the
    stacked full-batch rounds, and the replicated start point."""
    gf = jax.vmap(jax.grad(problem.client_loss), in_axes=(0, 0))
    batches = problem.stacked_batches(tau)
    init_b = jax.tree.map(lambda b: b[0], batches)
    x0 = jnp.zeros((problem.dim,), problem.b.dtype)
    x = jnp.broadcast_to(x0[None], (problem.n_clients, problem.dim))
    return gf, batches, init_b, x


def _errs(problem, traj):
    return np.asarray([float(jnp.linalg.norm(x.mean(0) - problem.x_star))
                       for x in traj])


# jitted-scan vs op-by-op reference: identical math, <= 2 ulp of fusion
# rounding (float32 tolerance — the acceptance bar — would be ~1e-7).
_TOL = dict(rtol=1e-12, atol=1e-12)


def _assert_same_run(problem, algo, ref_traj, ref_final_leaves, res):
    """Engine run == reference: error curve and final state."""
    np.testing.assert_allclose(np.asarray(res.errors),
                               _errs(problem, ref_traj), **_TOL)
    for got, want in zip(jax.tree.leaves(res.state), ref_final_leaves):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_TOL)


# ------------------------------------------------------------------- FedCET
def _ref_fedcet(problem, alpha, c, tau, rounds, *, k_frac=1.0, quantize=False):
    """Seed FedCET / FedCETCompressed round body, transcribed verbatim
    (k_frac=1.0, quantize=False reduces to the uncompressed seed path)."""
    gf, batches, init_b, x = _setup(problem, tau)
    compressing = k_frac < 1.0 or quantize

    def compress(a):
        out = a
        if k_frac < 1.0:
            out = topk_sparsify(out, k_frac)
        if quantize:
            out = out.astype(jnp.bfloat16).astype(a.dtype)
        return out

    def comm(x, d, e, batch):
        g = gf(x, batch)
        v = x - alpha * g - alpha * d
        if compressing:
            e = e + v
            v_tx = compress(e)
            e = e - v_tx
        else:
            v_tx = v
        v_bar = v_tx.mean(0, keepdims=True)
        d = d + c * (v_tx - v_bar)
        x = v - c * alpha * (v_tx - v_bar)
        return x, d, e

    g = gf(x, init_b)
    x = x - alpha * g
    d = jnp.zeros_like(x)
    e = jnp.zeros_like(x)
    x, d, e = comm(x, d, e, init_b)
    traj = [x]
    for _ in range(rounds):
        for s in range(tau - 1):
            b = jax.tree.map(lambda a, s=s: a[s], batches)
            g = gf(x, b)
            x = x - alpha * g - alpha * d
        b = jax.tree.map(lambda a: a[tau - 1], batches)
        x, d, e = comm(x, d, e, b)
        traj.append(x)
    return traj, (x, d, e)


def test_fedcet_matches_seed(problem):
    alpha = lr_search(problem.mu, problem.L, TAU)
    c = max_weight_c(problem.mu, alpha)
    algo = FedCET(alpha=alpha, c=c, tau=TAU, n_clients=problem.n_clients)
    traj, (x, d, _) = _ref_fedcet(problem, alpha, c, TAU, ROUNDS)
    res = simulate_quadratic(algo, problem, rounds=ROUNDS)
    # state leaves: (x, d, t)
    _assert_same_run(problem, algo, traj,
                     [x, d, jnp.asarray((ROUNDS + 1) * TAU - TAU)], res)


def test_fedcet_tau1_and_tau4(problem):
    """The local-scan boundary cases: no local steps (tau=1) and several."""
    for tau in (1, 4):
        alpha = lr_search(problem.mu, problem.L, tau)
        c = max_weight_c(problem.mu, alpha)
        algo = FedCET(alpha=alpha, c=c, tau=tau, n_clients=problem.n_clients)
        traj, _ = _ref_fedcet(problem, alpha, c, tau, 10)
        res = simulate_quadratic(algo, problem, rounds=10)
        np.testing.assert_allclose(np.asarray(res.errors),
                                   _errs(problem, traj), **_TOL)


def test_fedcet_compressed_matches_seed(problem):
    """Error-feedback top-k + bf16 — the full compressed seed recursion,
    including the transform state (feedback memory e) in EngineState."""
    alpha = lr_search(problem.mu, problem.L, TAU)
    c = max_weight_c(problem.mu, alpha)
    algo = FedCETCompressed(alpha=alpha, c=c, tau=TAU,
                            n_clients=problem.n_clients,
                            k_frac=0.3, quantize=True)
    traj, (x, d, e) = _ref_fedcet(problem, alpha, c, TAU, ROUNDS,
                                  k_frac=0.3, quantize=True)
    res = simulate_quadratic(algo, problem, rounds=ROUNDS)
    np.testing.assert_allclose(np.asarray(res.errors), _errs(problem, traj),
                               **_TOL)
    inner, extras = res.state
    np.testing.assert_allclose(np.asarray(inner.x), np.asarray(x), **_TOL)
    np.testing.assert_allclose(np.asarray(inner.d), np.asarray(d), **_TOL)
    np.testing.assert_allclose(np.asarray(extras[0]), np.asarray(e), **_TOL)


# ------------------------------------------------------------------- FedAvg
def test_fedavg_matches_seed(problem):
    alpha = 1.0 / (2 * TAU * problem.L)
    algo = FedAvg(alpha=alpha, tau=TAU, n_clients=problem.n_clients)
    gf, batches, _, x = _setup(problem)
    traj = [x]
    for _ in range(ROUNDS):
        for s in range(TAU):
            b = jax.tree.map(lambda a, s=s: a[s], batches)
            x = x - alpha * gf(x, b)
        x = jnp.broadcast_to(x.mean(0, keepdims=True), x.shape)
        traj.append(x)
    res = simulate_quadratic(algo, problem, rounds=ROUNDS)
    _assert_same_run(problem, algo, traj, [x, jnp.asarray(ROUNDS * TAU)], res)


# ----------------------------------------------------------------- SCAFFOLD
def test_scaffold_matches_seed(problem):
    a_l, a_g = 1.0 / (81 * TAU * problem.L), 1.0
    algo = Scaffold(alpha_l=a_l, alpha_g=a_g, tau=TAU,
                    n_clients=problem.n_clients)
    gf, batches, _, x = _setup(problem)
    ci = jnp.zeros_like(x)
    cc = jnp.zeros_like(x)
    traj = [x]
    for _ in range(ROUNDS):
        y = x
        for s in range(TAU):
            b = jax.tree.map(lambda a, s=s: a[s], batches)
            y = y - a_l * (gf(y, b) - ci + cc)
        ci_new = ci - cc + (x - y) / (TAU * a_l)
        x = x + a_g * (y - x).mean(0, keepdims=True)
        cc = cc + (ci_new - ci).mean(0, keepdims=True)
        ci = ci_new
        traj.append(x)
    res = simulate_quadratic(algo, problem, rounds=ROUNDS)
    _assert_same_run(problem, algo, traj,
                     [x, ci, cc, jnp.asarray(ROUNDS * TAU)], res)


# ----------------------------------------------------------- FedTrack/FedLin
def _ref_fedlin(problem, alpha, tau, rounds, k_frac):
    gf, batches, _, x = _setup(problem, tau)
    mem = jnp.zeros_like(x)
    traj = [x]
    for _ in range(rounds):
        b0 = jax.tree.map(lambda a: a[0], batches)
        g_i = gf(x, b0)
        if k_frac < 1.0:
            g_eff = g_i + mem
            g_i = topk_sparsify(g_eff, k_frac)
            mem = g_eff - g_i
        g_bar = g_i.mean(0, keepdims=True)
        y = x
        for s in range(tau):
            b = jax.tree.map(lambda a, s=s: a[s], batches)
            y = y - alpha * (gf(y, b) - g_i + g_bar)
        x = jnp.broadcast_to(y.mean(0, keepdims=True), y.shape)
        traj.append(x)
    return traj, (x, mem)


def test_fedtrack_matches_seed(problem):
    alpha = 1.0 / (18 * TAU * problem.L)
    algo = FedTrack(alpha=alpha, tau=TAU, n_clients=problem.n_clients)
    traj, (x, mem) = _ref_fedlin(problem, alpha, TAU, ROUNDS, 1.0)
    res = simulate_quadratic(algo, problem, rounds=ROUNDS)
    _assert_same_run(problem, algo, traj,
                     [x, mem, jnp.asarray(ROUNDS * TAU)], res)


def test_fedlin_topk_matches_seed(problem):
    alpha = 1.0 / (18 * TAU * problem.L)
    algo = FedLin(alpha=alpha, tau=TAU, n_clients=problem.n_clients,
                  k_frac=0.3)
    traj, (x, mem) = _ref_fedlin(problem, alpha, TAU, ROUNDS, 0.3)
    res = simulate_quadratic(algo, problem, rounds=ROUNDS)
    _assert_same_run(problem, algo, traj,
                     [x, mem, jnp.asarray(ROUNDS * TAU)], res)


# --------------------------------------------------------- transform no-ops
def test_identity_transforms_are_exact_noops(problem):
    alpha = lr_search(problem.mu, problem.L, TAU)
    base = FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=TAU,
                  n_clients=problem.n_clients)
    assert with_participation(base, 1.0) is base
    assert with_compression(base, k_frac=1.0, quantize=False) is base
    # ...and through the construction-sugar factories too
    part = FedCETPartial(alpha=base.alpha, c=base.c, tau=TAU,
                         n_clients=problem.n_clients, participation=1.0)
    comp = FedCETCompressed(alpha=base.alpha, c=base.c, tau=TAU,
                            n_clients=problem.n_clients, k_frac=1.0)
    r_base = simulate_quadratic(base, problem, rounds=20)
    for algo in (part, comp):
        r = simulate_quadratic(algo, problem, rounds=20)
        np.testing.assert_array_equal(np.asarray(r.errors),
                                      np.asarray(r_base.errors))


# --------------------------------------------------- composition (new-ability)
def test_composed_compression_participation_exact_convergence(problem):
    """The composed ``with_compression(with_participation(FedCET(...)))``
    expression converges to the EXACT optimum on the paper's quadratic
    problem (top-30%-sparsified single-vector uplink; measured ~1e-14)."""
    alpha = lr_search(problem.mu, problem.L, TAU)
    algo = with_compression(
        with_participation(
            FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=TAU,
                   n_clients=problem.n_clients),
            1.0, seed=3),
        k_frac=0.5)
    res = simulate_quadratic(algo, problem, rounds=4000)
    assert res.final_error < 1e-9, res.final_error


def test_composed_sampled_bf16_converges_to_quantization_floor(problem):
    """Beyond-paper finding (measured, not theory-claimed): with RANDOM
    client subsets, biased compression floors the error at the compressor's
    resolution — bf16 uplinks + 80% participation settle ~1e-5, the same
    order as full-participation compressed FedCET-C's bf16 floor (so
    sampling adds no systematic bias), and 5+ orders below FedAvg's drift
    floor. Top-k+EF behaves analogously with a larger (~3e-3) floor: the
    feedback limit cycle does not average out over random subsets."""
    alpha = lr_search(problem.mu, problem.L, TAU)
    algo = with_compression(
        with_participation(
            FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=TAU,
                   n_clients=problem.n_clients),
            0.8, seed=3),
        quantize=True)
    res = simulate_quadratic(algo, problem, rounds=3000)
    assert res.final_error < 2e-5, res.final_error


def test_unbiased_compressors_x_participation_no_error_floor(problem):
    """THE pinned upgrade over the biased-compressor caveat above: with the
    first-class UNBIASED compressors, compression x random participation
    converges to the exact optimum — no stochastic error floor.

    Measured (4000 rounds, 80% participation, seed 3): uncompressed
    ~2.9e-15; randk:0.5 ~3.0e-15; shift:q8 (DIANA-style shifted 8-bit
    dithered quantization) ~3.3e-15; shift:randk:0.5+q8 (4 bits/coord, an
    8x uplink cut) ~3.3e-15. All within 10x of the uncompressed run —
    i.e. at the float64 measurement floor, vs the 3e-3 (top-k+EF) and
    ~1e-5 (bf16) floors of the biased stacks."""
    alpha = lr_search(problem.mu, problem.L, TAU)
    base = with_participation(
        FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=TAU,
               n_clients=problem.n_clients), 0.8, seed=3)
    ref_err = simulate_quadratic(base, problem, rounds=4000).final_error
    assert ref_err < 1e-12  # participation alone: exact (pinned in PR 1)
    for spec in ("randk:0.5", "shift:q8", "shift:randk:0.5+q8"):
        algo = with_compression(base, compressor=spec)
        err = simulate_quadratic(algo, problem, rounds=4000).final_error
        assert err < 10 * ref_err, (spec, err, ref_err)


def test_plain_dithered_quant_floor_is_participation_induced(problem):
    """Documented-as-measured boundary of the result above: PLAIN (unshifted)
    dithered quantization is unbiased and converges exactly under FULL
    participation, but under random participation its fixed quantization
    step sustains a small re-excitation floor (~3e-5 ~ the kick scale
    c*alpha*step) — the shift wrapper quantizes the shrinking residual
    instead and removes it (previous test). Pinning both sides keeps the
    mechanism honest."""
    alpha = lr_search(problem.mu, problem.L, TAU)
    base = FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=TAU,
                  n_clients=problem.n_clients)
    full = with_compression(base, compressor="q8")
    assert simulate_quadratic(full, problem, rounds=4000).final_error < 1e-12
    part = with_compression(with_participation(base, 0.8, seed=3),
                            compressor="q8")
    err = simulate_quadratic(part, problem, rounds=3000).final_error
    assert 1e-8 < err < 5e-4, err  # the floor: present but small (meas 3e-5)


def test_composed_other_order_and_drift_invariant(problem):
    """Transforms compose in either order; sum_i d_i = 0 survives the
    composition (the Lemma 2 mean-zero invariant: drift updates use the
    client's own compressed message)."""
    alpha = lr_search(problem.mu, problem.L, TAU)
    algo = with_participation(
        with_compression(
            FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=TAU,
                   n_clients=problem.n_clients),
            k_frac=0.5),
        0.7, seed=11)
    res = simulate_quadratic(algo, problem, rounds=60)
    inner, _extras = res.state
    d_mean = np.asarray(jnp.mean(inner.d, axis=0))
    np.testing.assert_allclose(d_mean, 0.0, atol=1e-10)


def test_composed_up_frac_accounting(problem):
    """Uplink byte fractions under composition: FedLin's two up vectors
    compress independently (its own top-k on the round-start gradient, the
    engine transform on the endpoint message)."""
    n = problem.n_clients
    assert FedLin(alpha=0.01, tau=2, n_clients=n, k_frac=0.1).up_frac \
        == pytest.approx(0.6)  # (2*0.1 + 1)/2
    assert with_compression(FedTrack(alpha=0.01, tau=2, n_clients=n),
                            quantize=True).up_frac == pytest.approx(0.75)
    assert with_compression(
        FedCET(alpha=0.01, c=0.3, tau=2, n_clients=n),
        k_frac=0.3).up_frac == pytest.approx(0.6)


def test_stale_checkpoint_layout_fails_loudly(tmp_path, problem):
    """A checkpoint written with the pre-engine FedCETCompressed leaf order
    (x, d, e, t) must NOT silently restore transposed into the new
    EngineState layout (x, d, t, e) — same leaf count, different shapes."""
    from repro.checkpoint.ckpt import load_pytree, save_pytree

    alpha = lr_search(problem.mu, problem.L, TAU)
    algo = with_compression(
        FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=TAU,
               n_clients=problem.n_clients), quantize=True)
    res = simulate_quadratic(algo, problem, rounds=2)
    inner, (e,) = res.state
    old_layout = (inner.x, inner.d, e, inner.t)  # seed FedCETCState order
    path = str(tmp_path / "old.npz")
    save_pytree(path, old_layout)
    with pytest.raises(ValueError, match="incompatible"):
        load_pytree(path, res.state)


def test_composed_state_checkpoint_roundtrip(tmp_path, problem):
    """EngineState (inner + transform extras) survives checkpointing."""
    from repro.checkpoint.ckpt import load_pytree, save_pytree

    alpha = lr_search(problem.mu, problem.L, TAU)
    algo = with_compression(
        FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=TAU,
               n_clients=problem.n_clients), quantize=True)
    res = simulate_quadratic(algo, problem, rounds=3)
    path = str(tmp_path / "state.npz")
    save_pytree(path, res.state)
    back = load_pytree(path, res.state)
    for a, b in zip(jax.tree.leaves(res.state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------- participation bug fixes
def test_participation_step_counter_advances_tau_per_round(problem):
    """Regression (seed bug): FedCETPartial advanced t by 2*tau-1 per round
    (the local scan already bumped it tau-1 times, then t + tau was applied
    on top), skewing the per-round mask key schedule. The engine advances t
    by exactly tau regardless of sampling."""
    alpha = lr_search(problem.mu, problem.L, TAU)
    algo = FedCETPartial(alpha=alpha, c=max_weight_c(problem.mu, alpha),
                         tau=TAU, n_clients=problem.n_clients,
                         participation=0.6)
    res = simulate_quadratic(algo, problem, rounds=7)
    assert int(res.state.t) == 7 * TAU


def test_participation_mask_key_split():
    """Regression (seed bug): the Bernoulli draw and the non-empty fallback
    used the SAME key. With independent subkeys the forced client index is
    uniform: at rate=0 every client must be selected across enough seeds."""
    n = 10
    chosen = set()
    for s in range(300):
        m = participation_mask(jax.random.key(s), n, 0.0)
        idx = np.flatnonzero(np.asarray(m))
        assert idx.size == 1  # exactly the forced client
        chosen.add(int(idx[0]))
    assert chosen == set(range(n))


def test_participation_masks_deterministic_per_round(problem):
    """Same seed + same round counter => same mask (restart-stable)."""
    key = jax.random.fold_in(jax.random.key(5), 12)
    m1 = participation_mask(key, 8, 0.4)
    m2 = participation_mask(key, 8, 0.4)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
