"""Algorithm 1 (learning-rate search) and the Remark-1 conditions."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.lr_search import (
    alpha0_upper_bound,
    contraction_factors,
    lr_search,
    lr_search_validated,
    remark1_inequalities,
)


def test_paper_setting_values():
    """mu = L = 4, tau = 2 (the paper's experiment): check the bound
    arithmetic by hand. (1+2/tau)^(2tau-2) = 4; bound = min(1/16, 1/64,
    1/160) = 1/160."""
    b = alpha0_upper_bound(4.0, 4.0, 2)
    assert b == pytest.approx(1.0 / 160.0)
    alpha = lr_search(4.0, 4.0, 2)
    assert alpha > b  # the search grows past the conservative initial bound
    assert alpha < 2.0 / (2 * 4.0)  # and stays below 2/(tau L)


def test_search_output_satisfies_predicates():
    from repro.core.lr_search import _alg1_predicates

    for (mu, L, tau) in [(4.0, 4.0, 2), (1.0, 10.0, 4), (0.5, 2.0, 8), (2.0, 2.0, 1)]:
        alpha = lr_search(mu, L, tau)
        p1, p2 = _alg1_predicates(alpha, mu, L, tau)
        assert p1 > 0 and p2 > 0, (mu, L, tau, alpha, p1, p2)


def test_validated_search_satisfies_remark1():
    for (mu, L, tau) in [(4.0, 4.0, 2), (1.0, 10.0, 4), (0.5, 2.0, 8)]:
        alpha = lr_search_validated(mu, L, tau)
        d1, d2 = remark1_inequalities(alpha, mu, L, tau)
        assert d1 > 0 and d2 > 0, (mu, L, tau, alpha)
        cf = contraction_factors(alpha, mu, L, tau, n_clients=10)
        assert cf.converges, cf


@settings(max_examples=30, deadline=None)
@given(
    mu=st.floats(0.1, 5.0),
    kappa=st.floats(1.0, 20.0),
    tau=st.integers(1, 8),
)
def test_property_search_terminates_and_contracts(mu, kappa, tau):
    """Property (hypothesis): for any conditioning in range, Algorithm 1
    terminates with an alpha whose Corollary-1 factors contract."""
    L = mu * kappa
    alpha = lr_search(mu, L, tau, h_frac=1e-2)
    assert 0 < alpha < 2.0 / (tau * L)
    cf = contraction_factors(alpha, mu, L, tau, n_clients=5)
    assert 0.0 < cf.rho < 1.0, (mu, L, tau, alpha, cf)


def test_finer_grid_no_smaller_alpha():
    """Remark 1: a finer search step h can only find a larger (or equal)
    feasible learning rate."""
    coarse = lr_search(4.0, 4.0, 2, h_frac=1e-2)
    fine = lr_search(4.0, 4.0, 2, h_frac=1e-4)
    assert fine >= coarse - 1e-12
