"""The first-class compressor subsystem (repro/core/compressors.py).

Covers the accounting contract (bit-true bits_per_coord / up_frac /
omega), statistical unbiasedness of RandK / StochasticQuant, per-client
vs legacy cross-client top-k, the per-round PRNG key schedule threaded
through MessageCompression, the spec-string parser, the bit-true
CommMeter, and the FedScenario launch knob."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CommMeter, FedCET, with_compression
from repro.core.comm import bits_per_coord_of, comm_bits_per_round, topk_sparsify
from repro.core.compressors import (
    Bf16,
    Chain,
    ErrorFeedback,
    Identity,
    NaturalQuant,
    RandK,
    Shifted,
    StochasticQuant,
    TopK,
    as_compressor,
    from_spec,
)
from repro.core.engine import ErrorFeedbackCompression, MessageCompression

jax.config.update("jax_enable_x64", True)


def _leaf(key, clients=6, dim=40):
    return jax.random.normal(key, (clients, dim))


# ------------------------------------------------------------- unbiasedness
@pytest.mark.parametrize("comp,qbits", [
    (RandK(0.25), None), (RandK(0.5), None),
    (StochasticQuant(bits=4), 4), (StochasticQuant(bits=8), 8),
    (Chain((RandK(0.5), StochasticQuant(bits=8))), 8),
    (NaturalQuant(), None),
])
def test_statistical_unbiasedness(comp, qbits):
    """E[compress(v)] == v over the key distribution: the empirical mean
    over many keys matches v within ~5 standard errors per coordinate.

    The se envelope needs two terms: the empirical std (rand-k's 1/k
    inflation), plus the THEORETICAL dither-flip se ``s/(2 sqrt(n))`` for
    quantizers — at coordinates where v/s is nearly integer the flip
    probability is tiny, the empirical std collapses to ~0, and only the
    binomial bound is honest."""
    v = _leaf(jax.random.key(0))
    n_keys = 4000
    outs = jax.vmap(lambda k: comp.compress(k, v))(
        jax.random.split(jax.random.key(1), n_keys))
    mean = np.asarray(jnp.mean(outs, axis=0))
    se = np.asarray(jnp.std(outs, axis=0)) / np.sqrt(n_keys)
    if qbits is not None:
        step = float(jnp.max(jnp.abs(v))) / (2 ** (qbits - 1) - 1)
        se = se + step / (2.0 * np.sqrt(n_keys))
    np.testing.assert_array_less(np.abs(mean - np.asarray(v)), 5.0 * se + 1e-9)


@pytest.mark.parametrize("comp", [TopK(0.3), Bf16(),
                                  Chain((TopK(0.3), Bf16()))])
def test_biased_compressors_flagged(comp):
    assert not comp.unbiased
    assert not comp.requires_key


def test_unbiased_flags():
    assert RandK(0.3).unbiased and RandK(0.3).requires_key
    assert StochasticQuant(8).unbiased and StochasticQuant(8).requires_key
    assert Chain((RandK(0.5), StochasticQuant(8))).unbiased
    assert not Chain((TopK(0.5), StochasticQuant(8))).unbiased
    assert Shifted(StochasticQuant(8)).unbiased
    assert not ErrorFeedback(TopK(0.5)).unbiased


# ------------------------------------------------------------------- top-k
def test_topk_per_client_rows():
    """per_client=True keeps exactly ceil(k*dim) entries in EVERY client
    row; the legacy flatten lets clients compete (some rows get more, some
    fewer) — the seed artifact kept behind per_client=False."""
    v = _leaf(jax.random.key(2), clients=5, dim=50)
    k = 10  # 0.2 * 50
    per_row = np.count_nonzero(np.asarray(TopK(0.2).compress(None, v)), axis=1)
    np.testing.assert_array_equal(per_row, k)
    legacy = np.asarray(TopK(0.2, per_client=False).compress(None, v))
    np.testing.assert_array_equal(legacy, np.asarray(topk_sparsify(v, 0.2)))
    assert np.count_nonzero(legacy) == 50  # 0.2 * 250 total, NOT per row
    assert np.count_nonzero(legacy, axis=1).max() > k  # competition happened


def test_topk_kept_values_exact():
    v = _leaf(jax.random.key(3))
    out = np.asarray(TopK(0.4).compress(None, v))
    nz = out != 0
    np.testing.assert_array_equal(out[nz], np.asarray(v)[nz])


def test_randk_mask_shared_across_clients():
    """The rand-k mask is drawn once per round and shared by every client
    (seed-synchronized with the server: no index traffic, and identical
    messages at consensus — the fixed-point argument in compressors.py)."""
    v = _leaf(jax.random.key(4), clients=7, dim=30)
    out = np.asarray(RandK(0.3).compress(jax.random.key(5), v))
    support = out != 0
    for r in range(1, 7):
        np.testing.assert_array_equal(support[r], support[0])
    k = 9  # 0.3 * 30
    assert support[0].sum() == k
    nz = support[0]
    np.testing.assert_allclose(out[:, nz], np.asarray(v)[:, nz] * (30 / 9))


# --------------------------------------------------------------- accounting
def test_bits_per_coord_accounting():
    assert TopK(0.3).bits_per_coord == pytest.approx(0.3 * 64)   # val+idx
    assert RandK(0.25).bits_per_coord == pytest.approx(8.0)      # values only
    assert StochasticQuant(8).bits_per_coord == 8.0
    assert Bf16().bits_per_coord == 16.0
    # chain: bf16 halves VALUES only; int32 indices survive
    assert Chain((TopK(0.3), Bf16())).bits_per_coord == pytest.approx(
        0.3 * (16 + 32))
    assert Chain((RandK(0.5), StochasticQuant(8))).bits_per_coord == \
        pytest.approx(4.0)
    # wrappers are accounting-transparent
    assert ErrorFeedback(TopK(0.3)).bits_per_coord == pytest.approx(0.3 * 64)
    assert Shifted(StochasticQuant(4)).bits_per_coord == 4.0
    assert Identity().bits_per_coord == 32.0 and Identity().up_frac == 1.0


def test_chain_value_bits_first_narrowest_wins():
    """Regression: the old scan billed the LAST quantizer's width, so
    ``q8 + bf16`` (8-bit payloads re-encoded into a 16-bit container)
    over-billed 2x. Once a stage narrows the payload to b bits, a later
    wider stage cannot put information back on the wire."""
    assert Chain((StochasticQuant(8), Bf16())).value_bits == 8
    assert Chain((Bf16(), StochasticQuant(8))).value_bits == 8
    assert Chain((StochasticQuant(8), Bf16())).bits_per_coord == 8.0
    assert Chain((TopK(0.5), StochasticQuant(4), Bf16())).bits_per_coord \
        == pytest.approx(0.5 * (4 + 32))
    # wrappers and wire_bits agree with the narrowed width
    assert Shifted(Chain((StochasticQuant(6), Bf16()))).bits_per_coord == 6.0
    assert Chain((StochasticQuant(8), Bf16())).wire_bits(100) == 800.0


@pytest.mark.parametrize("stages", [
    (TopK(0.3),),
    (RandK(0.25),),
    (StochasticQuant(6),),
    (Bf16(),),
    (TopK(0.3), Bf16()),
    (RandK(0.5), StochasticQuant(8)),
    (RandK(0.5), TopK(0.5), StochasticQuant(4)),
    (StochasticQuant(8), Bf16()),
    (TopK(0.7), StochasticQuant(12), Bf16()),
])
@pytest.mark.parametrize("n", [1, 3, 7, 100, 12345])
def test_chain_wire_bits_is_per_stage_sum(stages, n):
    """``wire_bits(n)`` is the exact per-stage walk: every sparsifying
    stage bills its index bits at that stage's ACTUAL kept count
    ``max(1, round(frac * n))``, values go at the first-narrowest width —
    and the smooth ``bits_per_coord`` rate agrees up to per-stage
    rounding."""
    chain = Chain(stages)
    frac, kept, idx, value = 1.0, float(n), 0.0, None
    for s in stages:
        if s.keep_frac < 1.0:
            frac *= s.keep_frac
            kept = float(max(1, int(round(frac * n))))
        idx += kept * s.index_bits
        if s.value_bits is not None:
            value = (s.value_bits if value is None
                     else min(value, s.value_bits))
    expect = kept * (32.0 if value is None else value) + idx
    assert chain.wire_bits(n) == expect
    # rounding drift vs the smooth rate is bounded per sparsifying stage
    assert abs(chain.wire_bits(n) - n * chain.bits_per_coord) \
        <= 64.0 * (len(stages) + 1)


def test_omega_and_auto_beta():
    assert RandK(0.25).omega == pytest.approx(3.0)
    assert StochasticQuant(8).omega == 0.0
    assert Chain((RandK(0.5), RandK(0.5))).omega == pytest.approx(3.0)
    assert Shifted(RandK(0.5)).step == pytest.approx(0.5)   # 1/(1+omega)
    assert Shifted(StochasticQuant(8)).step == 1.0
    assert Shifted(RandK(0.5), beta=0.1).step == pytest.approx(0.1)


def test_legacy_wrapper_keeps_approx_up_frac_but_reports_true_bits():
    """The seed's up_frac formula ("bf16 halves whatever remains") is pinned
    for backward compat, while bits_per_coord is the bit-true cost the
    meter now uses — they legitimately differ for quantized top-k."""
    t = ErrorFeedbackCompression(k_frac=0.3, quantize=True)
    assert t.up_frac == pytest.approx(0.3)                  # legacy
    assert t.bits_per_coord == pytest.approx(0.3 * (16 + 32))  # bit-true
    algo = with_compression(FedCET(alpha=0.01, c=0.3, tau=2, n_clients=4),
                            k_frac=0.3, quantize=True)
    assert bits_per_coord_of(algo) == pytest.approx(14.4)


def test_engine_bits_per_coord_for_compressor_stacks():
    base = FedCET(alpha=0.01, c=0.3, tau=2, n_clients=4)
    assert base.bits_per_coord == 32.0
    assert with_compression(base, compressor="randk:0.25").bits_per_coord \
        == pytest.approx(8.0)
    b = comm_bits_per_round(with_compression(base, compressor="q8"),
                            n_params=1000, n_clients=4)
    assert b["up_bits"] == 1 * 1000 * 4 * 8
    assert b["down_bits"] == 1 * 1000 * 4 * 32


# ------------------------------------------------------------- key schedule
def test_per_round_keys_distinct_and_deterministic():
    """MessageCompression derives a fresh key per round from the step
    counter (regression for the PR 1 participation-key bug class): same
    step => identical output (restart-stable), different step => a
    different mask/dither."""
    t = MessageCompression(RandK(0.5), seed=0)
    msg = {"v": _leaf(jax.random.key(6))}
    out0a, _ = t.apply(msg, None, step=0)
    out0b, _ = t.apply(msg, None, step=0)
    out1, _ = t.apply(msg, None, step=jnp.asarray(2))
    np.testing.assert_array_equal(np.asarray(out0a["v"]), np.asarray(out0b["v"]))
    assert (np.asarray(out0a["v"]) != np.asarray(out1["v"])).any()


def test_key_schedule_domain_separated_from_participation():
    """Compression keys carry a domain-separation tag: at the default
    seed=0 the per-round compression key must NOT equal the per-round
    participation key ``fold_in(key(0), t)`` (which would correlate the
    rand-k mask with the client mask)."""
    t = MessageCompression(RandK(0.5), seed=0)
    v = _leaf(jax.random.key(7))
    for step in (0, 2, 4):
        out, _ = t.apply({"v": v}, None, step=step)
        naive_key = jax.random.fold_in(jax.random.key(0),
                                       jnp.asarray(step, jnp.int32))
        naive = RandK(0.5).compress(jax.random.fold_in(naive_key, 0), v)
        assert (np.asarray(out["v"]) != np.asarray(naive)).any()


def test_stochastic_quant_dither_shared_across_clients():
    """One dither per round, broadcast over clients: identical rows
    quantize identically (the consensus fixed-point requirement)."""
    row = jax.random.normal(jax.random.key(8), (25,))
    v = jnp.stack([row, row, row])
    out = np.asarray(StochasticQuant(8).compress(jax.random.key(9), v))
    np.testing.assert_array_equal(out[0], out[1])
    np.testing.assert_array_equal(out[0], out[2])


def test_scalar_parameter_leaves_stay_synchronized():
    """A (n_clients,) leaf is a STACKED SCALAR parameter — axis 0 is always
    the client axis, never a draw axis. Rand-k must keep it for every
    client (coordinate space is a single coordinate) and the quant dither
    must be shared, so clients at consensus still transmit identically."""
    v = jnp.full((6,), 1.7)
    out = RandK(0.5).compress(jax.random.key(0), v)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(v))
    q = np.asarray(StochasticQuant(8).compress(jax.random.key(1), v))
    assert len(set(q.tolist())) == 1  # shared dither: identical at consensus
    t = np.asarray(TopK(0.5).compress(None, v))
    np.testing.assert_array_equal(t, np.asarray(v))  # 1 coord/client: kept


def test_stateful_wrappers_cannot_nest():
    with pytest.raises(ValueError, match="nest stateful"):
        ErrorFeedback(Shifted(StochasticQuant(8)))
    with pytest.raises(ValueError, match="nest stateful"):
        Shifted(ErrorFeedback(TopK(0.3)))
    with pytest.raises(ValueError, match="AROUND a chain"):
        Chain((Shifted(StochasticQuant(8)), Bf16()))


def test_with_compression_guards():
    """Auto-EF must not wrap a stateful Shifted (it would clobber the shift
    memory slot), and mixing the legacy kwargs with compressor= raises
    instead of silently dropping them."""
    base = FedCET(alpha=0.01, c=0.3, tau=2, n_clients=4)
    algo = with_compression(base, compressor="shift:bf16")  # biased inner
    assert isinstance(algo.transforms[0].compressor, Shifted)
    with pytest.raises(ValueError, match="not both"):
        with_compression(base, k_frac=0.3, compressor="q8")
    with pytest.raises(ValueError, match="nest stateful"):
        with_compression(base, compressor="shift:q8", error_feedback=True)


def test_stacked_transforms_distinct_keys_and_chain_accounting():
    """Two transforms stacked at the SAME default seed must not replay each
    other's randomness (same mask twice would make rand-k biased: 4v on
    one subset), and stacked accounting composes like Chain stages — a
    later quantizer shrinks VALUE bits only, never the sparsifier's int32
    index bits."""
    base = FedCET(alpha=0.01, c=0.3, tau=2, n_clients=4)
    algo = with_compression(with_compression(base, compressor="randk:0.5"),
                            compressor="randk:0.5")
    t0, t1 = algo.transforms
    v = {"v": _leaf(jax.random.key(11))}
    s0 = np.asarray(t0.apply(v, None, step=0)[0]["v"]) != 0
    s1 = np.asarray(t1.apply(v, None, step=0)[0]["v"]) != 0
    assert (s0 != s1).any()
    stacked = with_compression(with_compression(base, compressor="topk:0.3"),
                               compressor="q8")
    assert stacked.bits_per_coord == pytest.approx(0.3 * (8 + 32))
    # ...identical to expressing the same stack as one Chain transform
    assert with_compression(base, compressor="topk:0.3+q8").bits_per_coord \
        == pytest.approx(0.3 * (8 + 32))


def test_empty_prefixed_spec_raises():
    for bad in ("ef:", "shift:", "ef: + "):
        with pytest.raises(ValueError, match="empty compressor spec"):
            from_spec(bad)


def test_comm_meter_bits_down_zero_is_honored():
    """bits_down=0.0 (a downlink-free scheme) must meter 0 down bytes, not
    silently fall back to dense 32 (the falsy-zero trap)."""
    m = CommMeter(n_params=10, n_clients=2, bits_up=32.0, bits_down=0.0)
    m.tick(1, 1)
    assert m.bytes_down == 0 and m.bytes_up == 10 * 2 * 4


# ------------------------------------------------------------------ parsing
def test_from_spec_round_trips():
    assert from_spec("none") is None and from_spec("") is None
    assert from_spec(None) is None
    assert from_spec("topk:0.3") == TopK(0.3, per_client=True)
    assert from_spec("topk_global:0.3") == TopK(0.3, per_client=False)
    assert from_spec("randk:0.25") == RandK(0.25)
    assert from_spec("q8") == StochasticQuant(bits=8)
    assert from_spec("quant:4") == StochasticQuant(bits=4)
    assert from_spec("bf16") == Bf16()
    assert from_spec("topk:0.3+bf16") == Chain((TopK(0.3), Bf16()))
    assert from_spec("ef:topk:0.3") == ErrorFeedback(TopK(0.3))
    assert from_spec("shift:q8") == Shifted(StochasticQuant(8))
    comp = RandK(0.5)
    assert from_spec(comp) is comp
    with pytest.raises(ValueError, match="unknown compressor"):
        from_spec("zstd:9")
    with pytest.raises(TypeError):
        as_compressor(None)


# ----------------------------------------------------------------- metering
def test_comm_meter_bit_true_mode():
    algo = with_compression(FedCET(alpha=0.01, c=0.3, tau=2, n_clients=3),
                            compressor="randk:0.25")
    params = {"w": jnp.zeros((100,))}
    m = CommMeter.for_params(params, algo=algo, n_clients=3)
    m.tick_round(algo)
    assert m.bytes_up == int(1 * 100 * 3 * 8 / 8)     # 8 bits/coord up
    assert m.bytes_down == int(1 * 100 * 3 * 32 / 8)  # dense f32 down
    with pytest.raises(ValueError, match="double-count"):
        m.tick(1, 1, up_frac=0.5)


def test_comm_meter_itemsize_removed():
    params = {"w": jnp.zeros((10,))}
    # the deprecated fixed-width kwarg now raises with a migration hint
    with pytest.raises(ValueError, match="algo=algo"):
        CommMeter.for_params(params, itemsize=2)
    # the direct constructor keeps the legacy fixed-width mode (and still
    # takes an explicit up_frac)
    m = CommMeter(n_params=10, itemsize=4, n_clients=2)
    m.tick(2, 1, up_frac=0.5)
    assert m.bytes_up == int(2 * 10 * 4 * 2 * 0.5)
    assert m.bytes_down == 10 * 4 * 2


# ------------------------------------------------------------- launch knob
def test_fed_scenario_apply():
    from repro.configs import FedScenario
    from repro.core.engine import EngineState

    base = FedCET(alpha=0.01, c=0.3, tau=2, n_clients=4)
    assert FedScenario().apply(base) is base          # identity is a no-op
    algo = FedScenario(compression="shift:q8", participation=0.5).apply(base)
    assert algo.sampling is not None and algo.sampling.rate == 0.5
    assert algo.bits_per_coord == 8.0
    assert isinstance(algo.transforms[0], MessageCompression)
    assert isinstance(algo.transforms[0].compressor, Shifted)
    # biased spec gets auto error feedback; unbiased stays bare
    ef_algo = FedScenario(compression="topk:0.3").apply(base)
    assert isinstance(ef_algo.transforms[0].compressor, ErrorFeedback)
    del EngineState  # imported for documentation parity


# -------------------------------------------------- per-client dither option
def test_per_client_dither_unbiased():
    """StochasticQuant(per_client_dither=True) — each client row gets an
    INDEPENDENT dither — remains unbiased: the empirical mean over many
    keys matches v within the binomial dither-flip envelope (the same
    bound as the shared-dither test above)."""
    comp = StochasticQuant(bits=8, per_client_dither=True)
    v = _leaf(jax.random.key(0))
    n_keys = 4000
    outs = jax.vmap(lambda k: comp.compress(k, v))(
        jax.random.split(jax.random.key(1), n_keys))
    mean = np.asarray(jnp.mean(outs, axis=0))
    se = np.asarray(jnp.std(outs, axis=0)) / np.sqrt(n_keys)
    step = float(jnp.max(jnp.abs(v))) / (2 ** 7 - 1)
    se = se + step / (2.0 * np.sqrt(n_keys))
    np.testing.assert_array_less(np.abs(mean - np.asarray(v)), 5.0 * se + 1e-9)


def test_per_client_dither_desynchronizes_clients():
    """Regression for the option's semantics: with identical rows, the
    shared dither quantizes every client identically (the synchronized-
    randomness invariant), while per_client_dither=True yields different
    wire messages per client — same wire bits, no seed synchronization."""
    row = jax.random.normal(jax.random.key(7), (40,))
    v = jnp.broadcast_to(row[None], (6, 40))
    key = jax.random.key(8)
    shared = np.asarray(StochasticQuant(bits=8).compress(key, v))
    for r in range(1, 6):
        np.testing.assert_array_equal(shared[r], shared[0])
    per_client = np.asarray(
        StochasticQuant(bits=8, per_client_dither=True).compress(key, v))
    assert any(not np.array_equal(per_client[r], per_client[0])
               for r in range(1, 6))
    # accounting is identical: the dither never rides the wire
    assert StochasticQuant(8, per_client_dither=True).bits_per_coord \
        == StochasticQuant(8).bits_per_coord == 8.0


def test_per_client_dither_spec():
    comp = from_spec("pq8")
    assert isinstance(comp, StochasticQuant) and comp.per_client_dither
    assert comp.bits == 8
    shifted = from_spec("shift:pq4")
    assert isinstance(shifted, Shifted) and shifted.inner.per_client_dither


# --------------------------------------------------- natural (exponent-only)
def test_natural_quant_outputs_signed_powers_of_two():
    """Every nonzero output is EXACTLY a signed power of two (only the
    exponent rides the wire — the kernel must use ldexp, not exp2, whose
    XLA lowering is off by an ulp), one of the two bracketing v."""
    v = _leaf(jax.random.key(12))
    out = np.asarray(NaturalQuant().compress(jax.random.key(13), v))
    nz = out[out != 0]
    exps = np.log2(np.abs(nz))
    np.testing.assert_array_equal(exps, np.round(exps))
    assert np.array_equal(np.sign(out), np.sign(np.asarray(v)))
    ratio = np.abs(nz) / np.abs(np.asarray(v)[out != 0])
    assert (ratio >= 0.5 - 1e-12).all() and (ratio <= 2.0 + 1e-12).all()
    # zeros stay zero
    z = jnp.zeros((3, 5))
    np.testing.assert_array_equal(
        np.asarray(NaturalQuant().compress(jax.random.key(0), z)), 0.0)


def test_natural_quant_accounting_and_spec():
    """Sign + 8-bit exponent = 9 wire bits/coordinate, omega = 1/8 (the
    Horvath et al. variance bound), parsed by the ``nat`` spec token and
    wrappable by shift:."""
    comp = NaturalQuant()
    assert comp.bits_per_coord == 9.0 and comp.value_bits == 9.0
    assert comp.omega == pytest.approx(1.0 / 8.0)
    assert comp.unbiased and comp.requires_key
    assert from_spec("nat") == NaturalQuant()
    shifted = from_spec("shift:nat")
    assert isinstance(shifted, Shifted) and shifted.inner == NaturalQuant()
    assert shifted.step == pytest.approx(1.0 / (1.0 + 0.125))
    assert Chain((RandK(0.5), NaturalQuant())).bits_per_coord \
        == pytest.approx(4.5)


def test_natural_quant_dither_shared_across_clients():
    """The rounding dither is one draw per coordinate per round,
    broadcast over the client axis: identical rows quantize identically
    (the synchronized-randomness/consensus invariant)."""
    row = jax.random.normal(jax.random.key(14), (30,))
    v = jnp.stack([row, row, row])
    out = np.asarray(NaturalQuant().compress(jax.random.key(15), v))
    np.testing.assert_array_equal(out[0], out[1])
    np.testing.assert_array_equal(out[0], out[2])
