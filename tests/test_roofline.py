"""Roofline machinery: HLO collective parsing (incl. loop multipliers) and
the analytic cost model."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.roofline.flops import cost_for, param_counts
from repro.roofline.hlo_parse import collective_summary, parse_collectives, shape_bytes


def test_shape_bytes():
    assert shape_bytes("f32[2,3]") == 24
    assert shape_bytes("bf16[8]{0}") == 16
    assert shape_bytes("(f32[4], bf16[4])") == 24
    assert shape_bytes("s32[]") == 4  # scalar: empty dims -> 1 element
    assert shape_bytes("pred[]") == 1


def test_parse_collectives_from_synthetic_hlo():
    hlo = """
HloModule test

%cond_comp (x: (s32[])) -> pred[] {
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body_comp (x: (s32[])) -> (s32[]) {
  %ar = f32[16,128]{1,0} all-reduce(%p), replica_groups={}
  ROOT %t = (s32[]) tuple(%i)
}

ENTRY %main (p: f32[16,128]) -> f32[16,128] {
  %w = (s32[]) while(%init), condition=%cond_comp, body=%body_comp
  %ag = f32[32,128]{1,0} all-gather(%p), dimensions={0}
  ROOT %r = f32[16,128] get-tuple-element(%w)
}
"""
    ops = parse_collectives(hlo)
    kinds = {o.kind: o for o in ops}
    assert kinds["all-gather"].multiplier == 1
    assert kinds["all-reduce"].multiplier == 7  # inside the while body
    s = collective_summary(hlo)
    assert s["bytes_by_kind"]["all-reduce"] == 7 * 16 * 128 * 4
    assert s["bytes_by_kind"]["all-gather"] == 32 * 128 * 4


def test_param_counts_dense_matches_manual():
    cfg = get_config("gemma-2b")
    total, active = param_counts(cfg)
    assert total == active
    # gemma-2b ~ 2.5B params (tied embeddings: one 256000 x 2048 table)
    assert 2.0e9 < total < 3.2e9, total


def test_param_counts_moe_active_fraction():
    cfg = get_config("llama4-scout-17b-a16e")
    total, active = param_counts(cfg)
    assert 90e9 < total < 120e9, total      # Scout ~109B total
    assert 14e9 < active < 25e9, active     # ~17B active (top-1 + shared)


def test_cost_model_orders_of_magnitude():
    cfg = get_config("internlm2-20b")
    c_train = cost_for(cfg, INPUT_SHAPES["train_4k"], n_devices=256)
    c_dec = cost_for(cfg, INPUT_SHAPES["decode_32k"], n_devices=256)
    # 6ND for 20B x 1M tokens x tau=2 ~ 2.5e17
    assert 1e17 < c_train.model_flops_total < 1e18
    # decode: 2*N*B ~ 2*20e9*128 ~ 5e12 global
    assert 1e12 < c_dec.model_flops_total < 1e13
    # decode has far lower arithmetic intensity than training
    train_int = c_train.flops_per_device / c_train.hbm_bytes_per_device
    dec_int = c_dec.flops_per_device / c_dec.hbm_bytes_per_device
    assert dec_int * 5 < train_int, (dec_int, train_int)


def test_ssm_decode_cost_has_no_kv_term():
    cfg = get_config("mamba2-130m")
    c = cost_for(cfg, INPUT_SHAPES["long_500k"], n_devices=256)
    # state cache is O(1): far below even 1 GB of reads
    assert c.detail["cache_read_bytes"] < 1e9
