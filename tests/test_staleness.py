"""The staleness subsystem (repro/core/staleness.py + engine with_delay).

Pins, in order:

* identity delays are EXACT no-ops (the factory returns the algorithm
  object unchanged, for every policy) and the attached machinery with an
  always-fresh schedule is trajectory-identical (<= 1e-12) to the
  synchronous engine for FedCET, FedAvg, SCAFFOLD and FedLin;
* composition with ``with_compression`` / ``with_participation`` in either
  order, including drop + always-fresh + sampling == sampling alone;
* determinism: same seed => identical delay schedule across runs, and
  resume-from-checkpoint reproduces the server buffer state exactly;
* measured convergence boundaries on the paper's quadratic (full sweep in
  benchmarks/staleness_sweep.py): FedCET stays EXACTLY convergent at
  delay 2 under ``drop`` and ``last`` (the buffered message is the
  absolute vector v, so reusing it is safe and uniform weighting keeps
  ``sum_i d_i = 0``), while ``poly:1`` staleness-discounted weights break
  the mean-zero drift structure (floor ~5e-2) and SCAFFOLD's
  delta-encoded message makes ``last`` re-apply stale control updates
  (error ~1e0);
* the uplink duty cycle in CommMeter / comm_bits_per_round: buffered
  rounds transmit zero uplink bits.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommMeter,
    DelayState,
    EngineState,
    FedAvg,
    FedCET,
    FedLin,
    Scaffold,
    StalenessConfig,
    max_weight_c,
    parse_policy,
    run_rounds,
    with_compression,
    with_delay,
    with_participation,
)
from repro.core.comm import comm_bits_per_round
from repro.core.lr_search import lr_search
from repro.core.simulate import simulate_quadratic
from repro.core.staleness import (
    FixedDelay,
    GeometricDelay,
    RoundRobinStraggler,
    parse_delay,
)
from repro.data.quadratic import make_quadratic_problem

jax.config.update("jax_enable_x64", True)

TAU = 2
_TOL = dict(rtol=1e-12, atol=1e-12)
POLICIES = ("drop", "last", "poly:1")


@pytest.fixture(scope="module")
def problem():
    return make_quadratic_problem(0)


def _fedcet(problem, tau=TAU):
    alpha = lr_search(problem.mu, problem.L, tau)
    return FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=tau,
                  n_clients=problem.n_clients)


def _all_algos(problem):
    n, L = problem.n_clients, problem.L
    return {
        "fedcet": _fedcet(problem),
        "fedavg": FedAvg(alpha=1.0 / (2 * TAU * L), tau=TAU, n_clients=n),
        "scaffold": Scaffold(alpha_l=1.0 / (81 * TAU * L), tau=TAU, n_clients=n),
        "fedlin": FedLin(alpha=1.0 / (18 * TAU * L), tau=TAU, n_clients=n,
                         k_frac=0.3),
    }


def _always_fresh(algo, policy):
    """Attach the FULL delay machinery (buffer, ages, weighted aggregation)
    with a schedule that never delays — bypassing the factory's identity
    shortcut."""
    cfg = StalenessConfig(GeometricDelay(1.0), policy=parse_policy(policy))
    return dataclasses.replace(algo, delay=cfg)


# ------------------------------------------------------------ exact no-ops
def test_identity_delay_specs_are_exact_noops(problem):
    """``with_delay(algo, <zero delay>)`` returns the SAME object for every
    policy — synchronous runs are bit-identical by construction, for every
    algorithm."""
    for algo in _all_algos(problem).values():
        for spec in ("none", "off", "fixed:0", "rr:0", "geom:1", None,
                     FixedDelay(0), RoundRobinStraggler(0)):
            for pol in POLICIES:
                assert with_delay(algo, spec, policy=pol) is algo


def test_always_fresh_machinery_is_noop_every_algorithm(problem):
    """With the buffer/weighting machinery ATTACHED but an always-fresh
    schedule, every policy reproduces the synchronous trajectory <= 1e-12
    on every algorithm (all policies degenerate to the uniform mean when
    every client is fresh)."""
    for name, algo in _all_algos(problem).items():
        ref = simulate_quadratic(algo, problem, rounds=12)
        for pol in POLICIES:
            res = simulate_quadratic(_always_fresh(algo, pol), problem,
                                     rounds=12)
            np.testing.assert_allclose(np.asarray(res.errors),
                                       np.asarray(ref.errors), **_TOL,
                                       err_msg=f"{name}/{pol}")


def test_parse_delay_grammar():
    assert parse_delay("fixed:2") == FixedDelay(2)
    assert parse_delay("rr:1") == RoundRobinStraggler(1)
    assert parse_delay("geom:0.5") == GeometricDelay(0.5)
    assert parse_delay("geom:1.0") is None
    assert parse_delay("") is None
    with pytest.raises(ValueError, match="unknown delay"):
        parse_delay("exp:3")
    with pytest.raises(ValueError, match="unknown stale policy"):
        parse_policy("oldest")


# ------------------------------------------------------------- composition
def test_delay_composes_with_transforms_in_either_order(problem):
    """Delay is an engine field applied at the aggregation seam after all
    message transforms, so factory order cannot change the algorithm —
    the two orders build EQUAL specs (and the composed run converges)."""
    base = _fedcet(problem)
    a = with_delay(with_compression(base, compressor="randk:0.5"),
                   "rr:2", policy="last")
    b = with_compression(with_delay(base, "rr:2", policy="last"),
                         compressor="randk:0.5")
    assert a == b
    res = simulate_quadratic(a, problem, rounds=1500)
    assert res.final_error < 1e-9, res.final_error


def test_drop_with_sampling_matches_participation_alone(problem):
    """drop + always-fresh + Bernoulli sampling IS partial participation:
    freshness is masked by presence, the drop weights reproduce the
    present-clients mean, and absent clients revert — trajectory-identical
    to ``with_participation`` alone (the server buffer just rides along)."""
    base = _fedcet(problem)
    ref = simulate_quadratic(with_participation(base, 0.6, seed=7), problem,
                             rounds=40)
    res = simulate_quadratic(
        _always_fresh(with_participation(base, 0.6, seed=7), "drop"),
        problem, rounds=40)
    np.testing.assert_allclose(np.asarray(res.errors),
                               np.asarray(ref.errors), **_TOL)


def test_stacked_delay_raises(problem):
    algo = with_delay(_fedcet(problem), "fixed:2")
    with pytest.raises(ValueError, match="already has a delay"):
        with_delay(algo, "rr:1")


# ------------------------------------------------------------- determinism
def test_delay_schedule_deterministic_across_runs(problem):
    """Same seed => identical stochastic arrival schedule => bit-equal
    error curves across independent runs (the schedule is keyed off the
    step counter, restart-stable)."""
    algo = with_delay(_fedcet(problem), "geom:0.5", policy="last", seed=11)
    r1 = simulate_quadratic(algo, problem, rounds=60)
    r2 = simulate_quadratic(algo, problem, rounds=60)
    np.testing.assert_array_equal(np.asarray(r1.errors), np.asarray(r2.errors))


def test_fresh_mask_restart_stable():
    cfg = StalenessConfig(GeometricDelay(0.4), policy=parse_policy("last"),
                          seed=5)
    m1 = cfg.fresh_mask(jnp.asarray(6), TAU, 8)
    m2 = cfg.fresh_mask(jnp.asarray(6), TAU, 8)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))
    # distinct rounds draw distinct masks (with overwhelming probability
    # over 20 consecutive rounds at p = 0.4)
    masks = [np.asarray(cfg.fresh_mask(jnp.asarray(s), TAU, 8))
             for s in range(0, 40, TAU)]
    assert any(not np.array_equal(masks[0], m) for m in masks[1:])


@pytest.mark.parametrize("spec", ["rr:2", "geom:0.5"])
def test_checkpoint_resume_reproduces_buffer(problem, spec, tmp_path):
    """Save/restore mid-run: the server buffer (last-known messages + ages)
    rides in EngineState, round-trips the npz checkpoint exactly, and the
    resumed run continues bit-compatibly with the uninterrupted one."""
    from repro.checkpoint.ckpt import load_pytree, save_pytree

    algo = with_delay(_fedcet(problem), spec, policy="last", seed=3)
    gf = jax.grad(problem.client_loss)
    batches = problem.stacked_batches(TAU)
    init_b = jax.tree.map(lambda b: b[0], batches)
    x0 = jnp.zeros((problem.dim,), problem.b.dtype)
    state0 = algo.init(gf, x0, init_b)
    assert isinstance(state0, EngineState)
    dstate = state0.extras[-1]
    assert isinstance(dstate, DelayState)
    np.testing.assert_array_equal(np.asarray(dstate.age),
                                  np.zeros(problem.n_clients, np.int32))

    full, _ = run_rounds(algo, gf, state0, batches, rounds=8)
    half, _ = run_rounds(algo, gf, state0, batches, rounds=4)
    path = str(tmp_path / "mid.npz")
    save_pytree(path, half)
    back = load_pytree(path, half)
    for a, b in zip(jax.tree.leaves(half), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    resumed, _ = run_rounds(algo, gf, back, batches, rounds=4)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **_TOL)


# ------------------------------------------- measured convergence boundaries
def test_fedcet_exact_under_delay_drop_and_last(problem):
    """THE pinned result (full sweep in benchmarks/staleness_sweep.py):
    FedCET keeps EXACT linear convergence at delay >= 2 under both ``drop``
    (fresh-only aggregation, stragglers continue locally) and ``last``
    (uniform last-known aggregation) — measured ~1e-14 at 800 rounds for
    fixed:2 and rr:2 alike. The buffered message is the ABSOLUTE vector v
    (not a delta), so the server reusing it is safe, and uniform weights
    keep the drift updates mean-zero."""
    base = _fedcet(problem)
    for spec in ("fixed:2", "rr:2"):
        for pol in ("drop", "last"):
            res = simulate_quadratic(with_delay(base, spec, policy=pol),
                                     problem, rounds=800)
            assert res.final_error < 1e-9, (spec, pol, res.final_error)


def test_poly_discount_breaks_fedcet_exactness(problem):
    """Measured boundary: staleness-discounted weights (poly:1 — the
    classic async-FL heuristic) make the aggregation a NON-uniform mean,
    the drift updates stop summing to zero, and FedCET floors (~4.7e-2
    under rr:2). Pinning the failure keeps the mechanism honest: it is the
    uniform weighting, not buffering per se, that preserves Lemma 2."""
    algo = with_delay(_fedcet(problem), "rr:2", policy="poly:1")
    res = simulate_quadratic(algo, problem, rounds=800)
    assert 1e-4 < res.final_error < 1.0, res.final_error
    # ...and the invariant itself measurably drifts
    inner = res.state.inner
    d_mean = float(jnp.linalg.norm(jnp.mean(inner.d, axis=0)))
    assert d_mean > 1e-6, d_mean


def test_fedcet_drift_invariant_survives_uniform_staleness(problem):
    """sum_i d_i = 0 survives stale messages under the uniform policies:
    drop aggregates fresh-only deviations (stragglers' d frozen), last
    aggregates buffer deviations from the buffer mean — both mean-zero."""
    base = _fedcet(problem)
    for pol in ("drop", "last"):
        res = simulate_quadratic(with_delay(base, "rr:2", policy=pol),
                                 problem, rounds=60)
        d_mean = np.asarray(jnp.mean(res.state.inner.d, axis=0))
        np.testing.assert_allclose(d_mean, 0.0, atol=1e-10, err_msg=pol)


def test_scaffold_delta_messages_not_stale_safe(problem):
    """Measured contrast pinned from the sweep: SCAFFOLD's message is a
    DELTA pair (dy, dc) — re-aggregating a buffered copy re-applies old
    control-variate updates, so ``last`` breaks outright (error ~1e0 at
    rr:2) where FedCET's absolute-vector message stays exact. ``drop``
    keeps SCAFFOLD convergent (merely slower)."""
    scaffold = _all_algos(problem)["scaffold"]
    res_last = simulate_quadratic(with_delay(scaffold, "rr:2", policy="last"),
                                  problem, rounds=800)
    assert res_last.final_error > 1e-1, res_last.final_error
    res_drop = simulate_quadratic(with_delay(scaffold, "rr:2", policy="drop"),
                                  problem, rounds=800)
    assert res_drop.final_error < 1e-2, res_drop.final_error


# -------------------------------------------------------- comm duty account
def test_comm_meter_delay_duty(problem):
    """Buffered rounds transmit zero uplink bits: expected uplink scales by
    the transmit duty (fixed:2 -> 1/3, rr:2 -> (N-2)/N, geom:p -> p);
    downlink broadcasts stay dense."""
    n = problem.n_clients
    base = _fedcet(problem)
    assert base.transmit_frac == 1.0
    assert with_delay(base, "fixed:2").transmit_frac == pytest.approx(1 / 3)
    assert with_delay(base, "rr:2").transmit_frac == pytest.approx((n - 2) / n)
    assert with_delay(base, "geom:0.25").transmit_frac == pytest.approx(0.25)

    params = {"w": jnp.zeros((problem.dim,))}
    sync = CommMeter.for_params(params, algo=base, n_clients=n)
    dly = CommMeter.for_params(params, algo=with_delay(base, "fixed:2"),
                               n_clients=n)
    sync.tick_round(base)
    dly.tick_round(base)
    # bytes are int-truncated per tick and the duty is 1/3: allow 1 byte
    assert abs(dly.bytes_up * 3 - sync.bytes_up) <= 3
    assert dly.bytes_down == sync.bytes_down

    bits = comm_bits_per_round(with_delay(base, "fixed:2"), problem.dim,
                               n_clients=n)
    bits_sync = comm_bits_per_round(base, problem.dim, n_clients=n)
    assert bits["up_bits"] * 3 == pytest.approx(bits_sync["up_bits"])
    assert bits["down_bits"] == bits_sync["down_bits"]

    # duty composes with compression: the wire width shrinks AND the duty
    # scales what remains.
    comp = with_delay(with_compression(base, compressor="shift:q8"), "fixed:2")
    assert comp.bits_per_coord == 8.0
    cbits = comm_bits_per_round(comp, problem.dim, n_clients=n)
    assert cbits["up_bits"] == pytest.approx(bits_sync["up_bits"] / 4 / 3)


# -------------------------------------------------------------- integration
def test_fed_trainer_runs_delayed_scenario(problem, tmp_path):
    """FedTrainer end-to-end with a delayed, compressed, sampled FedCET:
    the in-scan eval metric, the duty-cycled comm meter and checkpointing
    all handle the EngineState-with-buffer layout."""
    from repro.fed import FedTrainer, TrainerConfig

    algo = with_delay(
        with_compression(with_participation(_fedcet(problem), 0.8, seed=3),
                         compressor="randk:0.5"),
        "rr:2", policy="last")
    tc = TrainerConfig(rounds=6, eval_every=3, ckpt_every=3,
                       ckpt_dir=str(tmp_path / "ck"))
    trainer = FedTrainer(algo, problem.client_loss, tc)
    batches_for = lambda r: problem.stacked_batches(TAU)  # noqa: E731
    state = trainer.init_state(
        jnp.zeros((problem.dim,), problem.b.dtype),
        jax.tree.map(lambda b: b[0], batches_for(0)))
    state = trainer.fit(state, batches_for)
    assert trainer.history and all(
        np.isfinite(h["loss_global"]) for h in trainer.history)
    # metered bytes from first principles: randk:0.5 puts 16 bits/coord on
    # the wire, duty = participation 0.8 x rr:2's (N-2)/N; downlink is
    # dense f32 but PRESENT-ONLY — absent clients keep frozen replicas
    # and are not billed a broadcast, so down bytes scale by the 0.8 rate.
    n, dim, rounds = problem.n_clients, problem.dim, 6
    duty = 0.8 * (n - 2) / n
    per_round_up = int(dim * n * 16 * duty / 8)
    per_round_down = int(dim * n * 32 * 0.8 / 8)
    assert algo.transmit_frac == pytest.approx(duty)
    assert trainer.history[-1]["comm_bytes"] \
        == rounds * (per_round_up + per_round_down)
    # resume restores the buffer-bearing state
    restored, start = trainer.maybe_resume(state)
    assert start == 6
    assert isinstance(restored, EngineState)
    assert isinstance(restored.extras[-1], DelayState)
