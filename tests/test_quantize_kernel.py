"""Pallas stochastic-quantize kernel vs its ref.py oracle.

Separate from tests/test_kernels.py on purpose: that module needs
``hypothesis`` (absent in some environments, skipped by the conftest
guard), while the quantize kernel is on the compressed-uplink hot path and
must stay covered by the tier-1 suite everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(7,), (1024,), (1025,), (256, 1024), (3, 5, 17)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("bits", [4, 8])
def test_stochastic_quantize_sweep(shape, dtype, bits):
    """Kernel == oracle across shapes/dtypes/bit-widths (the dither and
    scale are kernel INPUTS, so both see identical randomness and must
    agree to fusion rounding)."""
    ka, ku = jax.random.split(jax.random.key(7))
    a = (jax.random.normal(ka, shape) * 3.0).astype(dtype)
    u = jax.random.uniform(ku, shape, dtype=jnp.float32).astype(dtype)
    levels = 2 ** (bits - 1) - 1
    scale = (jnp.max(jnp.abs(a.astype(jnp.float32))) / levels).astype(dtype)
    out = ops.stochastic_quantize(a, u, scale, bits)
    want = ref.stochastic_quantize(a, u, scale, bits)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert out.shape == shape and out.dtype == dtype


def test_stochastic_quantize_zero_scale_and_grid():
    """scale=0 (an all-zero leaf) maps to exactly 0 everywhere, and outputs
    land exactly on the quantization grid {q * scale, |q| <= levels}."""
    a = jax.random.normal(jax.random.key(1), (300,), dtype=jnp.float32)
    u = jax.random.uniform(jax.random.key(2), (300,), dtype=jnp.float32)
    zero = ops.stochastic_quantize(jnp.zeros_like(a), u, jnp.float32(0.0), 8)
    np.testing.assert_array_equal(np.asarray(zero), 0.0)
    scale = jnp.max(jnp.abs(a)) / 127.0
    out = np.asarray(ops.stochastic_quantize(a, u, scale, 8))
    q = out / float(scale)
    np.testing.assert_allclose(q, np.round(q), atol=1e-4)
    assert np.max(np.abs(np.round(q))) <= 127


def test_stochastic_quant_compressor_kernel_path():
    """StochasticQuant(use_kernel=True) == the pure-jnp compressor path
    (same key, same dither, same math — the kernel only changes the
    schedule), so the flag can flip on TPU without changing semantics."""
    from repro.core.compressors import StochasticQuant

    leaf = jax.random.normal(jax.random.key(3), (4, 257), dtype=jnp.float32)
    key = jax.random.key(9)
    out_j = StochasticQuant(bits=8).compress(key, leaf)
    out_k = StochasticQuant(bits=8, use_kernel=True).compress(key, leaf)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_j),
                               rtol=1e-6, atol=1e-6)
