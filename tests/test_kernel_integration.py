"""End-to-end kernel integration: models with use_pallas_* flags reproduce
the pure-XLA path (interpret mode on CPU)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.input_specs import make_batch
from repro.models import build_model


def test_pallas_attention_in_model_forward():
    """Dense model with the Pallas flash-attention kernel == XLA blockwise
    path (sequence long enough to take the non-naive branch)."""
    cfg = get_config("qwen3-1.7b").reduced()
    cfg = dataclasses.replace(cfg, window=2048)  # keep SWA non-trivial
    model_ref = build_model(cfg)
    cfg_k = dataclasses.replace(cfg, use_pallas_attention=True)
    model_k = build_model(cfg_k)
    params = model_ref.init(jax.random.key(0))
    batch = make_batch(cfg, 1, 1536, key=2)  # > 1024 -> blockwise/pallas
    ref_logits = model_ref.forward(params, batch)
    k_logits = model_k.forward(params, batch)
    np.testing.assert_allclose(np.asarray(k_logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)


def test_pallas_ssd_in_mamba_forward():
    cfg = get_config("mamba2-130m").reduced()
    # kernel tiles are per-chunk: use a seq that spans several chunks
    model_ref = build_model(cfg)
    cfg_k = dataclasses.replace(cfg, use_pallas_ssd=True)
    model_k = build_model(cfg_k)
    params = model_ref.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 256, key=3)
    ref_logits = model_ref.forward(params, batch)
    k_logits = model_k.forward(params, batch)
    np.testing.assert_allclose(np.asarray(k_logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)


def test_pallas_attention_mqa_long_seq():
    """MQA (kv=1) arch through the kernel path on a multi-block sequence;
    the kernel is the forward/serving path — training keeps the (already
    flash-structured) XLA blockwise path, whose backward is the remat'd
    scan. A custom backward kernel is the documented next step."""
    cfg = get_config("gemma-2b").reduced()
    model_ref = build_model(cfg)
    cfg_k = dataclasses.replace(cfg, use_pallas_attention=True)
    model_k = build_model(cfg_k)
    params = model_ref.init(jax.random.key(0))
    batch = make_batch(cfg, 1, 1280, key=4)
    ref_logits = model_ref.forward(params, batch)
    k_logits = model_k.forward(params, batch)
    np.testing.assert_allclose(np.asarray(k_logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)
