"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref


SHAPES = [(7,), (1024,), (1025,), (256, 1024), (3, 5, 17), (2048, 1024),
          (100_003,)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_fedcet_v_sweep(shape, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    x, g, d = (jax.random.normal(k, shape).astype(dtype) for k in ks)
    out = ops.fedcet_v(x, g, d, 0.0123)
    want = ref.fedcet_v(x, g, d, 0.0123)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
    assert out.shape == shape and out.dtype == dtype


@pytest.mark.parametrize("shape", SHAPES[:5])
@pytest.mark.parametrize("dtype", DTYPES)
def test_fedcet_comm_sweep(shape, dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    d, v, vb = (jax.random.normal(k, shape).astype(dtype) for k in ks)
    d_new, x_new = ops.fedcet_comm(d, v, vb, 0.31, 0.0123)
    d_want, x_want = ref.fedcet_comm(d, v, vb, 0.31, 0.0123)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(d_new, np.float32),
                               np.asarray(d_want, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(x_new, np.float32),
                               np.asarray(x_want, np.float32), rtol=tol, atol=tol)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 5000),
    alpha=st.floats(1e-5, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_fedcet_v_any_length(n, alpha, seed):
    ks = jax.random.split(jax.random.key(seed), 3)
    x, g, d = (jax.random.normal(k, (n,)) for k in ks)
    out = ops.fedcet_v(x, g, d, alpha)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.fedcet_v(x, g, d, alpha)),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("shape", [
    # (B, Nc, Lc, H, P, N)
    (1, 1, 8, 1, 4, 4),
    (2, 3, 16, 2, 8, 8),
    (1, 2, 128, 3, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_intra_kernel_sweep(shape, dtype):
    """Pallas SSD intra-chunk kernel vs jnp oracle across shapes/dtypes."""
    B, Nc, Lc, H, P, N = shape
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (B, Nc, Lc, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, Nc, Lc, H))).astype(dtype)
    a = -jax.nn.softplus(jax.random.normal(ks[2], (B, Nc, Lc, H)))
    a_cs = jnp.cumsum(a, axis=2).astype(dtype)
    Bm = jax.random.normal(ks[3], (B, Nc, Lc, N)).astype(dtype)
    Cm = jax.random.normal(ks[4], (B, Nc, Lc, N)).astype(dtype)
    out = ops.ssd_intra(x, dt, a_cs, Bm, Cm)
    want = ref.ssd_intra(x, dt, a_cs, Bm, Cm)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ssd_intra_matches_mamba_chunked_path():
    """The kernel's intra-chunk term equals the term inside
    models/mamba2.ssd_chunked (cross-module consistency)."""
    from repro.models.mamba2 import ssd_chunked, ssd_naive

    ks = jax.random.split(jax.random.key(5), 5)
    B, S, H, P, N, Lc = 1, 32, 2, 8, 8, 8
    x = jax.random.normal(ks[0], (B, S, H, P), dtype=jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    # kernel path: build chunked tensors exactly as ssd_chunked does
    Nc = S // Lc
    xf = x.reshape(B, Nc, Lc, H, P)
    dtf = dt.reshape(B, Nc, Lc, H)
    a_cs = jnp.cumsum(dtf * A, axis=2)
    Bf = Bm.reshape(B, Nc, Lc, N)
    Cf = Cm.reshape(B, Nc, Lc, N)
    y_kernel = ops.ssd_intra(xf, dtf, a_cs, Bf, Cf).reshape(B, S, H, P)
    # reference: full chunked minus inter-chunk contribution == intra term.
    y_full, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=Lc)
    # recompute inter term via naive state carried between chunks
    y_naive_first_chunk, _ = ssd_naive(x[:, :Lc], dt[:, :Lc], A,
                                       Bm[:, :Lc], Cm[:, :Lc])
    # for the FIRST chunk there is no inter-chunk term: kernel == full SSD
    np.testing.assert_allclose(np.asarray(y_kernel[:, :Lc]),
                               np.asarray(y_full[:, :Lc]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_kernel[:, :Lc]),
                               np.asarray(y_naive_first_chunk),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    S=st.integers(4, 80),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([4, 8]),
    blk=st.sampled_from([8, 16, 64]),
    kind=st.sampled_from(["causal", "sliding", "chunked", "bidirectional"]),
)
def test_property_flash_attention_matches_naive(seed, S, hkv, g, D, blk, kind):
    """Pallas flash kernel == naive attention for any shape/mask/blocking,
    including blocks that don't divide the sequence."""
    from repro.models import attention as A

    ks = jax.random.split(jax.random.key(seed), 3)
    Hq = hkv * g
    q = jax.random.normal(ks[0], (2, S, Hq, D), dtype=jnp.float32)
    k = jax.random.normal(ks[1], (2, S, hkv, D), dtype=jnp.float32)
    v = jax.random.normal(ks[2], (2, S, hkv, D), dtype=jnp.float32)
    kr, vr = (jnp.repeat(t, g, axis=2) for t in (k, v))
    ref_out = A.attend_naive(q, kr, vr, A.mask_fn(kind, window=5, chunk=7))
    out = ops.flash_attention(q, k, v, kind=kind, window=5, chunk=7,
                              q_blk=blk, kv_blk=blk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    from repro.models import attention as A

    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 8, 16)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 16)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 16)).astype(jnp.bfloat16)
    kr, vr = (jnp.repeat(t, 4, axis=2) for t in (k, v))
    ref_out = A.attend_naive(q.astype(jnp.float32), kr.astype(jnp.float32),
                             vr.astype(jnp.float32), A.mask_fn("causal"))
    out = ops.flash_attention(q, k, v, q_blk=32, kv_blk=32)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out), rtol=5e-2, atol=5e-2)
    assert out.dtype == jnp.bfloat16


def test_kernel_in_fedcet_algorithm():
    """FedCET with use_fused_kernel=True reproduces the pure-jnp trajectory
    on the paper's quadratic problem."""
    import dataclasses

    from repro.core import FedCET
    from repro.core.simulate import simulate_quadratic
    from repro.data.quadratic import make_quadratic_problem

    p = make_quadratic_problem(2, n_clients=4, dim=32)
    base = FedCET(alpha=0.01, c=0.3, tau=2, n_clients=4)
    fused = dataclasses.replace(base, use_fused_kernel=True)
    r_base = simulate_quadratic(base, p, rounds=5)
    r_fused = simulate_quadratic(fused, p, rounds=5)
    np.testing.assert_allclose(np.asarray(r_fused.errors),
                               np.asarray(r_base.errors), rtol=1e-6, atol=1e-9)
