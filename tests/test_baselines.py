"""Baseline algorithms: convergence properties + the Fig. 1 comparison."""

import jax
import numpy as np
import pytest

from repro.core import FedAvg, FedLin, FedTrack, Scaffold
from repro.core.simulate import paper_fig1_algorithms, simulate_quadratic
from repro.data.quadratic import make_hetero_hessian_problem, make_quadratic_problem

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def problem():
    return make_quadratic_problem(0)


def test_fedavg_drifts_under_heterogeneity():
    """The motivating failure: constant-lr FedAvg stalls at a nonzero error
    floor under client drift. NB: drift requires heterogeneous client
    HESSIANS — with the paper's M_i = I, periodic averaging of quadratics is
    exact (which is why Fig. 1 omits FedAvg) — so this test uses the
    heterogeneous-Hessian variant."""
    problem = make_hetero_hessian_problem(11)
    algo = FedAvg(alpha=1.0 / (2 * 2 * problem.L), tau=2,
                  n_clients=problem.n_clients)
    res = simulate_quadratic(algo, problem, rounds=800)
    errs = np.asarray(res.errors)
    floor = errs[-1]
    assert floor > 1e-4, f"expected drift floor, got {floor}"
    # it plateaus: last 100 rounds move by < 1% relative.
    assert abs(errs[-1] - errs[-100]) < 0.01 * floor + 1e-12


def test_fedcet_beats_fedavg_floor_same_bytes():
    """Same problem, same bytes per round: FedCET goes exact where FedAvg
    stalls."""
    from repro.core import FedCET, max_weight_c
    from repro.core.lr_search import lr_search

    problem = make_hetero_hessian_problem(11)
    tau = 2
    alpha = lr_search(problem.mu, problem.L, tau)
    fedcet = FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=tau,
                    n_clients=problem.n_clients)
    fedavg = FedAvg(alpha=1.0 / (2 * tau * problem.L), tau=tau,
                    n_clients=problem.n_clients)
    r_cet = simulate_quadratic(fedcet, problem, rounds=3000)
    r_avg = simulate_quadratic(fedavg, problem, rounds=3000)
    assert r_cet.bytes_per_round == r_avg.bytes_per_round
    assert r_cet.final_error < 1e-8 < r_avg.final_error


def test_fedtrack_converges_exactly(problem):
    algo = FedTrack(alpha=1.0 / (18 * 2 * problem.L), tau=2,
                    n_clients=problem.n_clients)
    res = simulate_quadratic(algo, problem, rounds=1500)
    assert res.final_error < 1e-8, res.final_error


def test_scaffold_converges_exactly(problem):
    algo = Scaffold(alpha_l=1.0 / (81 * 2 * problem.L), alpha_g=1.0, tau=2,
                    n_clients=problem.n_clients)
    res = simulate_quadratic(algo, problem, rounds=4000)
    assert res.final_error < 1e-6, res.final_error


def test_fedlin_sparsified_converges(problem):
    """FedLin with top-30% uplink sparsification + error feedback still
    converges exactly (more rounds, fewer bytes/round)."""
    algo = FedLin(alpha=1.0 / (18 * 2 * problem.L), tau=2,
                  n_clients=problem.n_clients, k_frac=0.3)
    res = simulate_quadratic(algo, problem, rounds=4000)
    assert res.final_error < 1e-6, res.final_error


def test_fig1_ordering(problem):
    """The paper's Fig. 1: at equal round counts FedCET's error is below
    FedTrack's, which is below SCAFFOLD's — with FedCET moving HALF the
    bytes per round of either."""
    algos = paper_fig1_algorithms(problem, tau=2)
    rounds = 300
    res = {k: simulate_quadratic(a, problem, rounds=rounds) for k, a in algos.items()}
    e = {k: float(r.errors[-1]) for k, r in res.items()}
    assert e["fedcet"] < e["fedtrack"] < e["scaffold"], e
    assert res["fedcet"].bytes_per_round * 2 == res["fedtrack"].bytes_per_round
    assert res["fedcet"].bytes_per_round * 2 == res["scaffold"].bytes_per_round


def test_error_vs_bytes_dominance(problem):
    """Communication-efficiency headline: at any transmitted-byte budget in
    the sampled range, FedCET's error is no worse than SCAFFOLD's/FedTrack's."""
    algos = paper_fig1_algorithms(problem, tau=2)
    rounds = 400
    res = {k: simulate_quadratic(a, problem, rounds=rounds) for k, a in algos.items()}
    # error of `name` after `n` bytes of total communication
    for budget_rounds in (50, 100, 200):
        bytes_budget = res["fedcet"].bytes_per_round * budget_rounds
        e_fedcet = float(res["fedcet"].errors[budget_rounds])
        for other in ("fedtrack", "scaffold"):
            k = bytes_budget // res[other].bytes_per_round
            e_other = float(res[other].errors[k])
            assert e_fedcet <= e_other, (budget_rounds, other, e_fedcet, e_other)


# ------------------------------------------------------------------ FedProx
def test_fedprox_mu0_is_fedavg(problem):
    """FedProx with mu_prox = 0 runs FedAvg's recursion exactly — the
    proximal term vanishes and both specs share the engine round body."""
    from repro.core import FedProx

    alpha = 1.0 / (2 * 2 * problem.L)
    avg = FedAvg(alpha=alpha, tau=2, n_clients=problem.n_clients)
    prox = FedProx(alpha=alpha, mu_prox=0.0, tau=2,
                   n_clients=problem.n_clients)
    r_avg = simulate_quadratic(avg, problem, rounds=100)
    r_prox = simulate_quadratic(prox, problem, rounds=100)
    np.testing.assert_allclose(np.asarray(r_prox.errors),
                               np.asarray(r_avg.errors),
                               rtol=1e-12, atol=1e-12)


def test_fedprox_converges_on_quadratic(problem):
    """On the paper's (homogeneous-Hessian) quadratic the proximal anchor
    does not bias the fixed point: FedProx converges to the exact optimum
    (measured ~6e-16 at mu_prox in {0.5, 2})."""
    from repro.core import FedProx

    for mu in (0.5, 2.0):
        algo = FedProx(alpha=1.0 / (2 * 2 * problem.L), mu_prox=mu, tau=2,
                       n_clients=problem.n_clients)
        res = simulate_quadratic(algo, problem, rounds=2000)
        assert res.final_error < 1e-9, (mu, res.final_error)


def test_fedprox_inherits_all_three_transforms(problem):
    """The point of the engine: a brand-new ~60-line spec composes with
    compression x participation x delay with NO algorithm-side code, and
    the composed run still converges exactly (measured 6.2e-16: shifted
    8-bit quantized uplink, 80% participation, rr:2 stragglers with
    last-known aggregation)."""
    from repro.core import (FedProx, with_compression, with_delay,
                            with_participation)

    base = FedProx(alpha=1.0 / (2 * 2 * problem.L), mu_prox=0.5, tau=2,
                   n_clients=problem.n_clients)
    algo = with_delay(
        with_compression(with_participation(base, 0.8, seed=3),
                         compressor="shift:q8"),
        "rr:2", policy="last")
    res = simulate_quadratic(algo, problem, rounds=2000)
    assert res.final_error < 1e-9, res.final_error


# ------------------------------------------------------------------- FedDyn
def _feddyn(problem, a_dyn=1.0, tau=2):
    from repro.core import FedDyn

    return FedDyn(alpha=1.0 / (2 * tau * (problem.L + a_dyn)), a_dyn=a_dyn,
                  tau=tau, n_clients=problem.n_clients)


def test_feddyn_exact_where_fedavg_floors():
    """FedDyn's dynamic regularizer absorbs gradient heterogeneity the
    way FedCET's drift variable does: on the heterogeneous-Hessian
    problem where constant-lr FedAvg provably stalls (see
    test_fedavg_drifts_under_heterogeneity), FedDyn converges EXACTLY
    (measured ~2e-14) at the same one-vector-each-way traffic."""
    problem = make_hetero_hessian_problem(11)
    for a_dyn in (0.5, 1.0, 2.0):
        res = simulate_quadratic(_feddyn(problem, a_dyn), problem, rounds=3000)
        assert res.final_error < 1e-9, (a_dyn, res.final_error)
    algo = _feddyn(problem)
    assert algo.vectors_up == 1 and algo.vectors_down == 1


def test_feddyn_dual_tracks_local_gradients():
    """At the fixed point lam_i -> grad f_i(x*): the duals absorb exactly
    the heterogeneity, and their mean tracks the server de-bias state h
    (the invariant the wire-consistent update preserves)."""
    import jax.numpy as jnp

    problem = make_hetero_hessian_problem(11)
    res = simulate_quadratic(_feddyn(problem), problem, rounds=3000)
    state = res.state
    x_star = np.asarray(problem.x_star)
    grads = np.stack([
        np.asarray(problem.client_grad(
            jnp.asarray(x_star), {"b": problem.b[i], "m": problem.m[i]}))
        for i in range(problem.n_clients)])
    np.testing.assert_allclose(np.asarray(state.lam), grads, atol=1e-8)
    np.testing.assert_allclose(np.asarray(jnp.mean(state.lam, axis=0)),
                               np.asarray(state.h)[0], atol=1e-10)


def test_feddyn_exact_under_compression_and_participation():
    """The satellite acceptance: FedDyn under the compression x
    participation stack stays exactly convergent (measured ~4e-15 for a
    shift:q8 8-bit uplink at 80% Bernoulli participation) BECAUSE the
    dual update uses the client's own transmitted message — the
    FedCET/Lemma-2 wire-consistency discipline; see feddyn.py."""
    from repro.core import with_compression, with_participation

    problem = make_hetero_hessian_problem(11)
    algo = with_compression(with_participation(_feddyn(problem), 0.8, seed=3),
                            compressor="shift:q8")
    res = simulate_quadratic(algo, problem, rounds=3000)
    assert res.final_error < 1e-9, res.final_error
