"""Cohort execution: the O(cohort) gathered round vs the dense path.

The gather lowering runs per-client work (begin_round, the local scan,
message) on the cohort's gathered ``[m, ...]`` rows only; the ``dense``
lowering runs it on all ``[N, ...]`` rows and gathers the results. All
cross-client work (transforms, delay buffering, the weighted reduce,
server_aggregate, the within-cohort participation freeze) is shared
between the two lowerings on cohort-sized arrays — so the lowerings must
agree EXACTLY (these tests run in f64 via conftest; everything here pins
<= 1e-12 and in practice lands bitwise)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    CohortSpec,
    FedAvg,
    FedTrack,
    Scaffold,
    parse_cohort,
    run_rounds,
    with_cohort,
    with_compression,
    with_delay,
    with_participation,
    with_topology,
)
from repro.core.baselines import FedLin
from repro.core.fedcet import FedCET
from repro.data.quadratic import make_hetero_hessian_problem

N, M, TAU, ROUNDS = 24, 7, 2, 6
TOL = 1e-12

PROB = make_hetero_hessian_problem(0, n_clients=N, dim=12, n_measurements=4)
GRAD = jax.grad(PROB.client_loss)
BATCHES = PROB.stacked_batches(TAU)
FIRST = jax.tree.map(lambda b: b[0], BATCHES)


def _algos():
    return {
        "fedcet": FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N),
        "fedavg": FedAvg(alpha=0.05, tau=TAU, n_clients=N),
        "scaffold": Scaffold(alpha_l=0.02, tau=TAU, n_clients=N),
        "fedlin": FedTrack(alpha=0.02, tau=TAU, n_clients=N),
    }


def _run(algo, rounds=ROUNDS, state=None):
    if state is None:
        state = algo.init(GRAD, jnp.zeros((PROB.dim,), PROB.b.dtype), FIRST)
    final, _ = run_rounds(algo, GRAD, state, BATCHES, rounds=rounds)
    return final


def _assert_close(a, b, tol=TOL):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert float(jnp.max(jnp.abs(x - y))) <= tol


def _composed(algo):
    """The full scenario stack of the issue: shift:q8 x 0.8 participation
    x fixed:2 delay (compose first, cohort wraps the whole spec)."""
    algo = with_participation(algo, 0.8, seed=3)
    algo = with_compression(algo, compressor="shift:q8", seed=5)
    return with_delay(algo, "fixed:2", policy="last", seed=7)


# ------------------------------------------------- gather == dense lowering
@pytest.mark.parametrize("name", list(_algos()))
def test_cohort_lowerings_agree_bare(name):
    algo = _algos()[name]
    g = with_cohort(algo, CohortSpec(size=M, lowering="gather"))
    d = with_cohort(algo, CohortSpec(size=M, lowering="dense"))
    _assert_close(_run(g), _run(d))


@pytest.mark.parametrize("name", list(_algos()))
def test_cohort_lowerings_agree_composed(name):
    algo = _composed(_algos()[name])
    g = with_cohort(algo, CohortSpec(size=M, lowering="gather"))
    d = with_cohort(algo, CohortSpec(size=M, lowering="dense"))
    _assert_close(_run(g), _run(d))


def test_cohort_lowerings_agree_drop_policy():
    """The drop policy's continuation step (local_step on the stale rows)
    also runs on cohort rows only — both lowerings must agree."""
    algo = with_delay(FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N),
                      "rr:2", policy="drop")
    g = with_cohort(algo, CohortSpec(size=M, lowering="gather"))
    d = with_cohort(algo, CohortSpec(size=M, lowering="dense"))
    _assert_close(_run(g), _run(d))


def test_cohort_lowerings_agree_hierarchical_tier_compression():
    """Hierarchical reduce over a cohort: first-tier segment ids are the
    full-population assignment gathered at the cohort ids, so stateful
    tier memory ([g, ...], full-N groups) advances identically."""
    algo = with_topology(FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N),
                         "hier:g4", tier_compression="shift:q8")
    g = with_cohort(algo, CohortSpec(size=M, lowering="gather"))
    d = with_cohort(algo, CohortSpec(size=M, lowering="dense"))
    _assert_close(_run(g), _run(d))


@pytest.mark.parametrize("selector", ["block", "rr", "uniform"])
def test_cohort_selectors_lowering_invariant(selector):
    algo = FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N)
    g = with_cohort(algo, CohortSpec(size=M, selector=selector,
                                     lowering="gather"))
    d = with_cohort(algo, CohortSpec(size=M, selector=selector,
                                     lowering="dense"))
    _assert_close(_run(g), _run(d))


def test_rr_selector_covers_population():
    """Round-robin blocks sweep every client id across ceil(N/m) rounds."""
    spec = CohortSpec(size=M, selector="rr")
    seen = set()
    for r in range(-(-N // M)):
        seen.update(int(i) for i in spec.indices(r * TAU, TAU, N))
    assert seen == set(range(N))


# ------------------------------------------------------- checkpoint/resume
def test_cohort_checkpoint_resume_mid_sweep(tmp_path):
    """Save after 4 rounds, reload, run 4 more — identical to 8 straight
    rounds: the cohort schedule keys off the state's step counter, and
    the relocated extras (shift memory, delay buffers) round-trip."""
    from repro.checkpoint.ckpt import load_pytree, save_pytree

    algo = with_cohort(_composed(FedCET(alpha=0.02, c=0.3, tau=TAU,
                                        n_clients=N)), M)
    straight = _run(algo, rounds=8)
    mid = _run(algo, rounds=4)
    path = str(tmp_path / "ckpt.npz")
    save_pytree(path, mid)
    resumed_state = load_pytree(path, mid)
    resumed = _run(algo, rounds=4, state=resumed_state)
    _assert_close(straight, resumed, tol=0.0)


# ----------------------------------------------------- factory + validation
def test_with_cohort_identity_cases():
    algo = FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N)
    for spec in (None, "none", "off", "full", 0, "0", "", N, str(N)):
        assert with_cohort(algo, spec) is algo
    with pytest.raises(ValueError):
        with_cohort(algo, N + 1)


def test_with_cohort_rejects_stacking():
    algo = with_cohort(FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N), M)
    with pytest.raises(ValueError):
        with_cohort(algo, M)


def test_with_cohort_rejects_mixing_both_orders():
    algo = FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N)
    gossip = with_topology(algo, "ring")
    with pytest.raises(ValueError):
        with_cohort(gossip, M)
    with pytest.raises(ValueError):
        with_topology(with_cohort(algo, M), "ring")


def test_with_cohort_rejects_fedlin_cross_client_topk():
    sparse = FedLin(alpha=0.02, tau=TAU, n_clients=N, k_frac=0.3)
    with pytest.raises(ValueError):
        with_cohort(sparse, M)
    # k_frac=1 (FedTrack) is dense — cohort-safe
    assert with_cohort(FedTrack(alpha=0.02, tau=TAU, n_clients=N),
                       M).cohort is not None


def test_parse_cohort_grammar():
    assert parse_cohort(None) is None
    assert parse_cohort("none") is None
    assert parse_cohort(256) == CohortSpec(size=256)
    assert parse_cohort("256") == CohortSpec(size=256)
    assert parse_cohort("block:256") == CohortSpec(size=256, selector="block")
    assert parse_cohort("rr:64:dense") == CohortSpec(
        size=64, selector="rr", lowering="dense")
    assert parse_cohort("1024:dense") == CohortSpec(size=1024,
                                                    lowering="dense")
    for bad in ("block", "block:", "nope:8", "8:nope", "block:8:gather:x"):
        with pytest.raises(ValueError):
            parse_cohort(bad)


def test_cohort_spec_validation():
    with pytest.raises(ValueError):
        CohortSpec(size=0)
    with pytest.raises(ValueError):
        CohortSpec(size=4, selector="nope")
    with pytest.raises(ValueError):
        CohortSpec(size=4, lowering="nope")


def test_cohort_scenario_applies_last():
    """FedScenario(cohort=...) wraps the fully-composed spec."""
    from repro.configs.base import FedScenario

    sc = FedScenario(compression="shift:q8", participation=0.8,
                     delay="fixed:2", cohort=f"block:{M}", seed=3)
    algo = sc.apply(FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N))
    assert algo.cohort == CohortSpec(size=M, selector="block", seed=3)
    ref = with_cohort(
        FedScenario(compression="shift:q8", participation=0.8,
                    delay="fixed:2", seed=3).apply(
            FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N)),
        CohortSpec(size=M, selector="block", seed=3))
    _assert_close(_run(algo), _run(ref), tol=0.0)


def test_cohort_converges_on_quadratic():
    """Sanity: the cohort path optimizes — FedCET with a rotating block
    cohort (every client visited each ceil(N/m) rounds) drives the
    paper's quadratic toward x*. Partial rounds contract slower than the
    synchronous rate, so this pins steady progress, not the paper's
    linear rate (which assumes full participation)."""
    from repro.data.quadratic import make_quadratic_problem

    prob = make_quadratic_problem(1, n_clients=N, dim=12, n_measurements=4)
    grad = jax.grad(prob.client_loss)
    batches = prob.stacked_batches(TAU)
    algo = with_cohort(FedCET(alpha=0.05, c=0.5, tau=TAU, n_clients=N),
                       CohortSpec(size=M, selector="rr"))
    state = algo.init(grad, jnp.zeros((prob.dim,), prob.b.dtype),
                      jax.tree.map(lambda b: b[0], batches))
    err0 = float(jnp.linalg.norm(
        algo.client_params(state)[0] - prob.x_star))
    final, _ = run_rounds(algo, grad, state, batches, rounds=400)
    err = float(jnp.linalg.norm(
        algo.client_params(final)[0] - prob.x_star))
    assert err < 0.2 * err0, (err0, err)
