"""Partial client participation (beyond-paper extension)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fedcet import FedCET, max_weight_c
from repro.core.lr_search import lr_search
from repro.core.participation import FedCETPartial, participation_mask
from repro.core.simulate import simulate_quadratic
from repro.data.quadratic import make_quadratic_problem

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def problem():
    return make_quadratic_problem(0)


def _algo(problem, rate, tau=2):
    alpha = lr_search(problem.mu, problem.L, tau)
    return FedCETPartial(alpha=alpha, c=max_weight_c(problem.mu, alpha),
                         tau=tau, n_clients=problem.n_clients,
                         participation=rate)


def test_mask_never_empty():
    for s in range(50):
        m = participation_mask(jax.random.key(s), 10, 0.05)
        assert bool(jnp.any(m))


def test_full_participation_matches_fedcet(problem):
    a = _algo(problem, 1.0)
    base = FedCET(alpha=a.alpha, c=a.c, tau=2, n_clients=problem.n_clients)
    r_a = simulate_quadratic(a, problem, rounds=40)
    r_b = simulate_quadratic(base, problem, rounds=40)
    np.testing.assert_allclose(np.asarray(r_a.errors), np.asarray(r_b.errors),
                               rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("rate", [0.8, 0.5])
def test_partial_participation_still_exact(problem, rate):
    """Measured (not theory-claimed): with >= 50% sampling the iterates
    still converge to the exact optimum, just in more rounds."""
    a = _algo(problem, rate)
    res = simulate_quadratic(a, problem, rounds=int(1200 / rate))
    assert res.final_error < 1e-8, (rate, res.final_error)


def test_drift_sum_invariant_under_sampling(problem):
    """sum_i d_i = 0 holds at every round even with random absences."""
    a = _algo(problem, 0.6)
    res = simulate_quadratic(a, problem, rounds=37)
    d_mean = np.asarray(jnp.mean(res.state.d, axis=0))
    np.testing.assert_allclose(d_mean, 0.0, atol=1e-10)


def test_lower_participation_is_slower_but_unbiased(problem):
    errs = {}
    for rate in (1.0, 0.5):
        res = simulate_quadratic(_algo(problem, rate), problem, rounds=250)
        errs[rate] = float(res.final_error)
    assert errs[1.0] < errs[0.5]          # sampling costs rounds...
    res_long = simulate_quadratic(_algo(problem, 0.5), problem, rounds=3000)
    assert float(res_long.final_error) < 1e-10   # ...but not exactness
