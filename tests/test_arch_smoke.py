"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
of the same family (2 layers, d_model<=256, <=4 experts), run

  * a forward pass (shape + finiteness),
  * one full FedCET communication round (tau=2, 2 heterogeneous clients) —
    the paper's technique applied to the real model pytree,
  * a prefill + decode step consistency check,

all on CPU. The FULL configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation) in src/repro/launch/dryrun.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core import FedCET, replicate
from repro.launch.input_specs import make_batch
from repro.models import build_model

ARCHS = list_archs()
B, S = 2, 16


def _finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ARCHS:
        cfg = get_config(name).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        out[name] = (cfg, model, params)
    return out


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finiteness(built, name):
    cfg, model, params = built[name]
    batch = make_batch(cfg, B, S, key=1)
    logits = model.forward(params, batch)
    extra = cfg.n_modal_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (B, S + extra, cfg.vocab_size), logits.shape
    assert _finite(logits), f"{name}: non-finite logits"
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"


@pytest.mark.parametrize("name", ARCHS)
def test_fedcet_round_on_arch(built, name):
    """One FedCET communication round on the real model pytree: params stay
    finite, shapes unchanged, and the drift variable d has moved."""
    cfg, model, params = built[name]
    tau, n_clients = 2, 2
    algo = FedCET(alpha=1e-2, c=0.1, tau=tau, n_clients=n_clients)
    # heterogeneous client batches: different random streams
    batches = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[jax.tree.map(lambda *ys: jnp.stack(ys),
                       *[make_batch(cfg, B, S, key=10 * t + c)
                         for c in range(n_clients)])
          for t in range(tau)],
    )
    grad_fn = jax.grad(model.loss)
    init_b = jax.tree.map(lambda b: b[0], batches)
    state = algo.init(grad_fn, params, init_b)
    state = algo.round(grad_fn, state, batches)
    assert _finite(state.x), f"{name}: non-finite params after round"
    assert _finite(state.d), f"{name}: non-finite drift state"
    ref_shapes = jax.tree.map(lambda a: (n_clients,) + a.shape, params)
    got_shapes = jax.tree.map(lambda a: a.shape, state.x)
    assert jax.tree.all(jax.tree.map(lambda a, b: a == b, ref_shapes, got_shapes))
    d_norm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(state.d))
    assert d_norm > 0.0, f"{name}: drift variable never updated"


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_decode_consistency(built, name):
    """prefill(tokens[:-1]) + decode(last token) == forward last logits."""
    cfg, model, params = built[name]
    batch = make_batch(cfg, B, S, key=3)
    full = model.forward(params, batch)          # [B, S(+modal), V]
    prefix = dict(batch)
    prefix["tokens"] = batch["tokens"][:, :-1]
    caches = model.init_caches(B, S + (cfg.n_modal_tokens if cfg.family == "vlm" else 0))
    logits_pre, caches = model.prefill(params, prefix, caches)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0]), np.asarray(full[:, -2]),
        rtol=2e-3, atol=2e-3)
    logits_dec, _ = model.decode_step(params, batch["tokens"][:, -1:], caches)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(full[:, -1]),
        rtol=2e-3, atol=2e-3)


def test_reduced_configs_meet_constraints():
    for name in ARCHS:
        cfg = get_config(name).reduced()
        assert cfg.n_layers <= 2
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4


def test_full_configs_match_assignment():
    """The exact assigned hyperparameters (regression guard)."""
    spec = {
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    for name, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(name)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == (L, d, h, kv, ff, v), (name, got)
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("mamba2-130m").ssm_state == 128
    assert get_config("llama4-scout-17b-a16e").n_experts == 16
    assert get_config("llama4-scout-17b-a16e").experts_per_token == 1
    assert get_config("granite-moe-3b-a800m").n_experts == 40
    assert get_config("granite-moe-3b-a800m").experts_per_token == 8
    assert get_config("gemma-2b").head_dim == 256
