"""Attention: blockwise-vs-naive oracle, masks, GQA, cache consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import attention as A


def _rand(key, *shape):
    return jax.random.normal(jax.random.key(key), shape, dtype=jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    S=st.integers(3, 96),
    H=st.sampled_from([1, 2, 4]),
    D=st.sampled_from([4, 8]),
    block=st.sampled_from([5, 16, 32]),
    kind=st.sampled_from(["causal", "sliding", "chunked", "bidirectional"]),
)
def test_property_blockwise_matches_naive(seed, S, H, D, block, kind):
    """Flash-style streaming softmax == materialized softmax, any mask/shape,
    including blocks that don't divide the sequence."""
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (2, S, H, D))
    k = jax.random.normal(ks[1], (2, S, H, D))
    v = jax.random.normal(ks[2], (2, S, H, D))
    allowed = A.mask_fn(kind, window=7, chunk=9)
    ref = A.attend_naive(q, k, v, allowed)
    out = A.attend_blockwise(q, k, v, allowed, block_size=block)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kind,window,chunk", [
    ("full", 0, 0), ("sliding", 8, 0), ("chunked", 0, 8)])
def test_decode_matches_full_forward(kind, window, chunk):
    """Token-by-token decode through the cache reproduces the full-sequence
    attention output at every position."""
    B, S, Hq, Hkv, D, d = 2, 24, 4, 2, 8, 32
    params = A.init_attention(jax.random.key(0), d, Hq, Hkv, D,
                              jnp.float32, qk_norm=True)
    x = _rand(1, B, S, d)
    full = A.attention(params, x, n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                       kind=kind, window=window, chunk=chunk,
                       force_naive=True)
    ring = kind == "sliding"
    cap = window if ring else S
    cache = A.init_cache(B, cap, Hkv, D, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = A.decode_attention(
            params, x[:, t:t + 1], cache, n_heads=Hq, n_kv_heads=Hkv,
            head_dim=D, kind=kind, window=window, chunk=chunk, ring=ring)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


def test_prefill_then_decode_matches_forward():
    """prefill(x[:P]) + decode steps == full attention on x."""
    B, S, P, Hq, Hkv, D, d = 1, 20, 12, 4, 4, 8, 32
    params = A.init_attention(jax.random.key(3), d, Hq, Hkv, D, jnp.float32)
    x = _rand(5, B, S, d)
    full = A.attention(params, x, n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                       kind="full", force_naive=True)
    cache = A.init_cache(B, S, Hkv, D, jnp.float32)
    pre, cache = A.prefill_attention(params, x[:, :P], cache=cache,
                                     n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                                     kind="full")
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :P]),
                               rtol=2e-4, atol=2e-4)
    for t in range(P, S):
        o, cache = A.decode_attention(params, x[:, t:t + 1], cache,
                                      n_heads=Hq, n_kv_heads=Hkv, head_dim=D)
        np.testing.assert_allclose(np.asarray(o[:, 0]),
                                   np.asarray(full[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_causality():
    """Perturbing a future token never changes past outputs."""
    B, S, Hq, Hkv, D, d = 1, 16, 2, 1, 8, 16
    params = A.init_attention(jax.random.key(7), d, Hq, Hkv, D, jnp.float32)
    x = _rand(8, B, S, d)
    kw = dict(n_heads=Hq, n_kv_heads=Hkv, head_dim=D, kind="full",
              force_naive=True)
    base = A.attention(params, x, **kw)
    x2 = x.at[:, 10].add(13.0)
    pert = A.attention(params, x2, **kw)
    np.testing.assert_allclose(np.asarray(pert[:, :10]),
                               np.asarray(base[:, :10]), rtol=1e-5, atol=1e-6)
    assert not np.allclose(np.asarray(pert[:, 10:]), np.asarray(base[:, 10:]))


def test_sliding_window_ignores_distant_past():
    """With window w, changing token t-w (or older) must not affect token t."""
    B, S, H, D, d, w = 1, 32, 2, 8, 16, 4
    params = A.init_attention(jax.random.key(9), d, H, H, D, jnp.float32)
    x = _rand(10, B, S, d)
    kw = dict(n_heads=H, n_kv_heads=H, head_dim=D, kind="sliding", window=w,
              force_naive=True)
    base = A.attention(params, x, **kw)
    x2 = x.at[:, 5].add(100.0)
    pert = A.attention(params, x2, **kw)
    # outputs at positions >= 5 + w see nothing of position 5
    np.testing.assert_allclose(np.asarray(pert[:, 5 + w:]),
                               np.asarray(base[:, 5 + w:]),
                               rtol=1e-5, atol=1e-6)


def test_gqa_equals_repeated_mha():
    """GQA with kv groups == MHA with explicitly repeated kv projections."""
    B, S, Hq, Hkv, D, d = 2, 8, 4, 2, 8, 16
    params = A.init_attention(jax.random.key(11), d, Hq, Hkv, D, jnp.float32)
    # build an MHA whose wk/wv are the GQA ones repeated per group
    G = Hq // Hkv
    wk = params["wk"].reshape(d, Hkv, D)
    mha = dict(params)
    mha["wk"] = jnp.repeat(wk, G, axis=1).reshape(d, Hq * D)
    mha["wv"] = jnp.repeat(params["wv"].reshape(d, Hkv, D), G, axis=1).reshape(d, Hq * D)
    x = _rand(12, B, S, d)
    out_gqa = A.attention(params, x, n_heads=Hq, n_kv_heads=Hkv, head_dim=D,
                          kind="full", force_naive=True)
    out_mha = A.attention(mha, x, n_heads=Hq, n_kv_heads=Hq, head_dim=D,
                          kind="full", force_naive=True)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               rtol=1e-5, atol=1e-5)
