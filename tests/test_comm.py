"""Communication accounting + compression operators (Remark 2 and beyond)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # guarded: the accounting pins below run without hypothesis, only
    from hypothesis import given, settings  # the property tests skip
    from hypothesis import strategies as st
except ImportError:
    def given(**kw):  # noqa: D103
        return lambda f: pytest.mark.skip("hypothesis not installed")(f)

    def settings(**kw):  # noqa: D103
        return lambda f: f

    class st:  # noqa: D101
        integers = floats = staticmethod(lambda *a, **k: None)

from repro.core import CommMeter, comm_bytes_per_round, quantize_bf16, topk_sparsify
from repro.core.baselines import FedAvg, FedTrack, Scaffold
from repro.core.fedcet import FedCET


def _mk(algo_cls, **kw):
    return algo_cls(**kw)


def test_remark2_half_communication():
    fedcet = FedCET(alpha=0.01, c=0.4, tau=2, n_clients=10)
    scaffold = Scaffold(alpha_l=0.001, tau=2, n_clients=10)
    fedtrack = FedTrack(alpha=0.001, tau=2, n_clients=10)
    n = 123_457
    b_cet = comm_bytes_per_round(fedcet, n, n_clients=10)
    for other in (scaffold, fedtrack):
        b = comm_bytes_per_round(other, n, n_clients=10)
        assert b["total"] == 2 * b_cet["total"]
    b_avg = comm_bytes_per_round(FedAvg(alpha=0.1, tau=2, n_clients=10), n, n_clients=10)
    assert b_avg["total"] == b_cet["total"]  # same traffic, but FedAvg drifts


def test_comm_meter_accumulates():
    m = CommMeter(n_params=100, itemsize=4, n_clients=3)
    m.tick(1, 1)
    m.tick(2, 2)
    assert m.rounds == 2
    assert m.bytes_up == (1 + 2) * 100 * 4 * 3
    assert m.bytes_down == (1 + 2) * 100 * 4 * 3


@settings(max_examples=25, deadline=None)
@given(
    size=st.integers(4, 300),
    k_frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_topk_sparsify(size, k_frac, seed):
    """Top-k keeps >= ceil(k*size) largest-magnitude entries, zeros others,
    and never changes a kept value."""
    a = jax.random.normal(jax.random.key(seed), (size,))
    out = np.asarray(topk_sparsify(a, k_frac))
    a = np.asarray(a)
    nz = np.nonzero(out)[0]
    k = max(1, int(round(k_frac * size)))
    assert len(nz) >= min(k, size - np.sum(a == 0))
    np.testing.assert_array_equal(out[nz], a[nz])
    if len(nz) < size:
        kept_min = np.min(np.abs(a[nz]))
        dropped = np.setdiff1d(np.arange(size), nz)
        assert np.all(np.abs(a[dropped]) <= kept_min + 1e-12)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), size=st.integers(1, 64))
def test_property_bf16_quantization_bounded(seed, size):
    a = jax.random.normal(jax.random.key(seed), (size,)) * 100.0
    q = np.asarray(quantize_bf16(a))
    a = np.asarray(a)
    # bf16 has 8 significand bits -> relative error < 2^-8.
    np.testing.assert_allclose(q, a, rtol=2**-8, atol=1e-30)


def test_topk_shape_and_dtype_preserved():
    a = jnp.ones((4, 5, 6), dtype=jnp.float32)
    out = topk_sparsify(a, 0.5)
    assert out.shape == a.shape and out.dtype == a.dtype


# ------------------------------------------------------ cohort duty cycle
def test_cohort_duty_cycle_fractions():
    """Cohort mode: unsampled clients transmit ZERO uplink bits and
    receive no broadcast (present-only downlink), so both duty cycles
    scale by size/N — and compose multiplicatively with participation."""
    from repro.core import with_cohort, with_participation

    base = FedCET(alpha=0.01, c=0.4, tau=2, n_clients=100)
    cohort = with_cohort(base, 25)
    assert cohort.transmit_frac == 0.25
    assert cohort.receive_frac == 0.25
    both = with_cohort(with_participation(base, 0.8), 25)
    np.testing.assert_allclose(both.transmit_frac, 0.25 * 0.8)
    np.testing.assert_allclose(both.receive_frac, 0.25 * 0.8)


def test_cohort_bits_per_round_scale():
    from repro.core import comm_bits_per_round, with_cohort

    base = FedCET(alpha=0.01, c=0.4, tau=2, n_clients=100)
    cohort = with_cohort(base, 25)
    n = 12_345
    dense = comm_bits_per_round(base, n, n_clients=100)
    coh = comm_bits_per_round(cohort, n, n_clients=100)
    assert coh["up_bits"] == 0.25 * dense["up_bits"]
    assert coh["down_bits"] == 0.25 * dense["down_bits"]


# ------------------------------------------- per-leaf wire-bit accounting
def test_meter_bills_actual_kept_counts_per_leaf():
    """Regression (wire-bit rounding drift): billing uses each leaf's
    ACTUAL kept count ``max(1, round(k_frac * n))`` — not the smooth
    ``k_frac * n`` — so a tiny leaf that keeps its floor coordinate is
    billed for it, and declared bits match what the compressor actually
    transmits to <= 1 coordinate per leaf."""
    from repro.core import with_compression
    from repro.core.comm import leaf_info_of, message_leaf_bits_of
    from repro.core.fedcet import FedCET as _FedCET

    params = {"a": jnp.zeros((3,)), "b": jnp.zeros((10,)),
              "c": jnp.zeros((100,))}
    algo = with_compression(_FedCET(alpha=0.01, c=0.4, tau=2, n_clients=4),
                            compressor="topk:0.3")
    info = leaf_info_of(params)
    lb = message_leaf_bits_of(algo, info)
    # actual kept coords: a: max(1, round(0.9)) = 1, b: 3, c: 30 — each at
    # 64 bits (f32 value + int32 index). The smooth rate would bill
    # 0.3 * 3 * 64 = 57.6 bits for 'a' and under-count the floor keep.
    assert lb == [1 * 64.0, 3 * 64.0, 30 * 64.0]
    m = CommMeter.for_params(params, algo=algo, n_clients=4)
    assert m.leaf_bits == tuple(lb)
    assert m.bits_up == pytest.approx(sum(lb) / 113)
    # declared vs actual: compress each leaf, count the survivors
    comp = algo.transforms[0].compressor.inner  # strip the auto-EF wrapper
    for i, (nm, n) in enumerate(info):
        leaf = jax.random.normal(jax.random.fold_in(jax.random.key(0), i),
                                 (1, n))
        actual = int(jnp.sum(comp.compress(None, leaf) != 0))
        assert abs(lb[i] / 64.0 - actual) <= 1, (nm, lb[i], actual)


def test_cohort_meter_bills_only_cohort():
    from repro.core import with_cohort

    base = FedCET(alpha=0.01, c=0.4, tau=2, n_clients=100)
    cohort = with_cohort(base, 25)
    params = {"w": jnp.zeros((64, 3))}
    md = CommMeter.for_params(params, algo=base, n_clients=100)
    mc = CommMeter.for_params(params, algo=cohort, n_clients=100)
    md.tick_round(base)
    mc.tick_round(cohort)
    assert md.bytes_up > 0
    assert mc.bytes_up * 4 == md.bytes_up
    assert mc.bytes_down * 4 == md.bytes_down
