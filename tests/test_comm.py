"""Communication accounting + compression operators (Remark 2 and beyond)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import CommMeter, comm_bytes_per_round, quantize_bf16, topk_sparsify
from repro.core.baselines import FedAvg, FedTrack, Scaffold
from repro.core.fedcet import FedCET


def _mk(algo_cls, **kw):
    return algo_cls(**kw)


def test_remark2_half_communication():
    fedcet = FedCET(alpha=0.01, c=0.4, tau=2, n_clients=10)
    scaffold = Scaffold(alpha_l=0.001, tau=2, n_clients=10)
    fedtrack = FedTrack(alpha=0.001, tau=2, n_clients=10)
    n = 123_457
    b_cet = comm_bytes_per_round(fedcet, n, n_clients=10)
    for other in (scaffold, fedtrack):
        b = comm_bytes_per_round(other, n, n_clients=10)
        assert b["total"] == 2 * b_cet["total"]
    b_avg = comm_bytes_per_round(FedAvg(alpha=0.1, tau=2, n_clients=10), n, n_clients=10)
    assert b_avg["total"] == b_cet["total"]  # same traffic, but FedAvg drifts


def test_comm_meter_accumulates():
    m = CommMeter(n_params=100, itemsize=4, n_clients=3)
    m.tick(1, 1)
    m.tick(2, 2)
    assert m.rounds == 2
    assert m.bytes_up == (1 + 2) * 100 * 4 * 3
    assert m.bytes_down == (1 + 2) * 100 * 4 * 3


@settings(max_examples=25, deadline=None)
@given(
    size=st.integers(4, 300),
    k_frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_topk_sparsify(size, k_frac, seed):
    """Top-k keeps >= ceil(k*size) largest-magnitude entries, zeros others,
    and never changes a kept value."""
    a = jax.random.normal(jax.random.key(seed), (size,))
    out = np.asarray(topk_sparsify(a, k_frac))
    a = np.asarray(a)
    nz = np.nonzero(out)[0]
    k = max(1, int(round(k_frac * size)))
    assert len(nz) >= min(k, size - np.sum(a == 0))
    np.testing.assert_array_equal(out[nz], a[nz])
    if len(nz) < size:
        kept_min = np.min(np.abs(a[nz]))
        dropped = np.setdiff1d(np.arange(size), nz)
        assert np.all(np.abs(a[dropped]) <= kept_min + 1e-12)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), size=st.integers(1, 64))
def test_property_bf16_quantization_bounded(seed, size):
    a = jax.random.normal(jax.random.key(seed), (size,)) * 100.0
    q = np.asarray(quantize_bf16(a))
    a = np.asarray(a)
    # bf16 has 8 significand bits -> relative error < 2^-8.
    np.testing.assert_allclose(q, a, rtol=2**-8, atol=1e-30)


def test_topk_shape_and_dtype_preserved():
    a = jnp.ones((4, 5, 6), dtype=jnp.float32)
    out = topk_sparsify(a, 0.5)
    assert out.shape == a.shape and out.dtype == a.dtype
