"""Substrate: data pipeline, optimizers, schedules, checkpointing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import restore
from repro.checkpoint.ckpt import all_steps, load_pytree, save, save_pytree
from repro.data.synthetic import make_hetero_lm_dataset
from repro.optim import Adam, Sgd, wsd


# ----------------------------------------------------------------- data
def test_hetero_lm_shapes_and_determinism():
    ds = make_hetero_lm_dataset(vocab_size=64, n_clients=3, seq_len=16,
                                batch_size=4, heterogeneity=0.7, seed=5)
    b1 = ds.sample_round(0, tau=2)
    b2 = ds.sample_round(0, tau=2)
    assert b1.shape == (2, 3, 4, 16) and b1.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))
    b3 = ds.sample_round(1, tau=2)
    assert not np.array_equal(np.asarray(b1), np.asarray(b3))
    assert int(b1.min()) >= 0 and int(b1.max()) < 64


def test_heterogeneity_monotone():
    """Higher heterogeneity => larger divergence between client unigrams."""
    div = []
    for h in (0.0, 0.5, 1.0):
        ds = make_hetero_lm_dataset(vocab_size=128, n_clients=4, seq_len=8,
                                    batch_size=2, heterogeneity=h, seed=1)
        div.append(float(ds.client_unigram_divergence()))
    assert div[0] < 1e-6
    assert div[0] < div[1] < div[2]


# ------------------------------------------------------------- optimizers
def test_sgd_and_adam_minimize_quadratic():
    target = jnp.asarray([1.0, -2.0, 3.0])
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for opt, lr, steps in ((Sgd(), 0.1, 200), (Sgd(momentum=0.9), 0.02, 200),
                           (Adam(), 0.05, 400)):
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        for _ in range(steps):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params, lr)
        assert float(loss(params)) < 1e-3, (opt, float(loss(params)))


def test_wsd_schedule_shape():
    f = wsd(1.0, 1000, warmup_frac=0.02, decay_frac=0.2)
    assert float(f(0)) == 0.0
    assert float(f(20)) == pytest.approx(1.0)       # end of warmup
    assert float(f(500)) == pytest.approx(1.0)      # stable plateau
    assert float(f(800)) == pytest.approx(1.0)      # decay starts after 800
    assert float(f(900)) < 0.2                      # mid-decay
    assert float(f(1000)) == pytest.approx(0.01, rel=1e-3)


# ------------------------------------------------------------ checkpointing
def test_pytree_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.int32), "c": [jnp.zeros(2), jnp.ones(1)]},
    }
    p = str(tmp_path / "ck.npz")
    save_pytree(p, tree)
    back = load_pytree(p, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_round_robin_retention(tmp_path):
    d = str(tmp_path / "ckpts")
    tree = {"w": jnp.zeros(2)}
    for s in range(6):
        save(d, s, tree, keep=3)
    assert all_steps(d) == [3, 4, 5]
    got, step = restore(d, tree)
    assert step == 5


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_fedcet_state_roundtrip(tmp_path_factory, seed):
    """Algorithm states (the thing a real run checkpoints) survive exactly."""
    from repro.core import FedCET
    from repro.core.simulate import simulate_quadratic
    from repro.data.quadratic import make_quadratic_problem

    p = make_quadratic_problem(seed, n_clients=3, dim=8)
    algo = FedCET(alpha=0.01, c=0.3, tau=2, n_clients=3)
    res = simulate_quadratic(algo, p, rounds=3)
    d = tmp_path_factory.mktemp("ck")
    path = str(d / "state.npz")
    save_pytree(path, res.state)
    back = load_pytree(path, res.state)
    for x, y in zip(jax.tree.leaves(res.state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
