"""In-trace telemetry subsystem (repro/core/telemetry.py).

The load-bearing contract: telemetry DISABLED is a BITWISE no-op (max
abs diff 0.0, not <=eps) on every composed scenario — the engine guards
every capture site on an active tape, so the disabled trace is the
identical jaxpr. Enabled, the stacked per-round series streams through
``drain`` into sinks behind a run manifest, and the declarative invariant
monitor reproduces the PR 3 staleness boundary live: silent where
``sum_i d_i = 0`` survives (bare, fixed:k + poly), WARN events naming the
offending axis where non-uniform ages break it (rr:2 + poly:1).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import restore, save
from repro.configs.base import FedScenario
from repro.core import (
    INVARIANT_MONITOR,
    FedAvg,
    FedCET,
    JsonlSink,
    MemorySink,
    Monitor,
    Scaffold,
    Telemetry,
    drain,
    max_weight_c,
    parse_sinks,
    parse_telemetry,
    resolve_monitors,
    run_manifest,
    split_metrics,
    with_delay,
    with_telemetry,
)
from repro.core.engine import run_rounds
from repro.core.lr_search import lr_search
from repro.core.simulate import simulate_quadratic
from repro.data.quadratic import make_quadratic_problem

jax.config.update("jax_enable_x64", True)

ROUNDS = 8


def _problem():
    return make_quadratic_problem(0, n_clients=8, dim=24)


def _algo(name, problem, tau=2):
    mu, L, n = problem.mu, problem.L, problem.n_clients
    alpha = lr_search(mu, L, tau)
    return {
        "fedcet": lambda: FedCET(alpha=alpha, c=max_weight_c(mu, alpha),
                                 tau=tau, n_clients=n),
        "fedavg": lambda: FedAvg(alpha=1.0 / (2 * tau * L), tau=tau,
                                 n_clients=n),
        "scaffold": lambda: Scaffold(alpha_l=1.0 / (81 * tau * L), tau=tau,
                                     n_clients=n),
    }[name]()


SCENARIOS = {
    "bare": dict(),
    # the full composition: compression x participation x delay x cohort
    # x arena — the exact stack the engine instruments.
    "composed": dict(compression="shift:q8", participation=0.8,
                     delay="fixed:2", stale_policy="poly:1",
                     cohort="block:4", arena=True),
    "hier": dict(compression="shift:q8", topology="hier:g4"),
}


def _assert_bitwise_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        assert x.dtype == y.dtype and x.shape == y.shape
        diff = np.abs(x.astype(np.float64) - y.astype(np.float64)).max() \
            if x.size else 0.0
        assert diff == 0.0, f"max abs diff {diff} != 0.0"


# --------------------------------------------------------- bitwise no-op
@pytest.mark.parametrize("algo_name", ["fedcet", "fedavg", "scaffold"])
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_disabled_vs_enabled_is_bitwise_identical(algo_name, scenario):
    """Telemetry ON observes; it must never perturb — final state and the
    metric series match the telemetry-off run at EXACTLY 0.0 divergence,
    for every algorithm x fully-composed scenario."""
    problem = _problem()
    kw = SCENARIOS[scenario]
    off = FedScenario(telemetry=False, **kw).apply(_algo(algo_name, problem))
    on = FedScenario(telemetry=True, **kw).apply(_algo(algo_name, problem))
    assert getattr(on, "telemetry", None) is not None
    res_off = simulate_quadratic(off, problem, rounds=ROUNDS)
    res_on = simulate_quadratic(on, problem, rounds=ROUNDS)
    assert res_off.telemetry is None
    assert res_on.telemetry is not None
    _assert_bitwise_equal(res_off.state, res_on.state)
    _assert_bitwise_equal(res_off.errors, res_on.errors)


def test_disabled_is_bitwise_noop_across_checkpoint_resume(tmp_path):
    """Telemetry adds NO state: a checkpoint written mid-run with
    telemetry ON restores into the telemetry-OFF algorithm (and vice
    versa) and continues bitwise identically to the uninterrupted run."""
    problem = _problem()
    kw = SCENARIOS["composed"]
    off = FedScenario(telemetry=False, **kw).apply(_algo("fedcet", problem))
    on = FedScenario(telemetry=True, **kw).apply(_algo("fedcet", problem))
    grad = jax.grad(problem.client_loss)
    batches = problem.stacked_batches(off.tau)
    x0 = jnp.zeros((problem.dim,), dtype=problem.b.dtype)
    init_b = jax.tree.map(lambda b: b[0], batches)
    state0 = off.init(grad, x0, init_b)
    _assert_bitwise_equal(state0, on.init(grad, x0, init_b))

    straight, _ = run_rounds(off, grad, state0, batches, rounds=ROUNDS)
    mid_on, _ = run_rounds(on, grad, state0, batches, rounds=ROUNDS // 2)
    save(str(tmp_path / "ck"), ROUNDS // 2, mid_on)
    restored, step = restore(str(tmp_path / "ck"), mid_on)
    assert step == ROUNDS // 2
    resumed_off, _ = run_rounds(off, grad, restored, batches,
                                rounds=ROUNDS - ROUNDS // 2)
    _assert_bitwise_equal(straight, resumed_off)


def test_with_telemetry_disabled_returns_same_object():
    algo = _algo("fedcet", _problem())
    for spec in (None, False, "none", "off", ""):
        assert with_telemetry(algo, spec) is algo
    on = with_telemetry(algo, True)
    assert on is not algo and isinstance(on.telemetry, Telemetry)
    # idempotent re-attach of an explicit spec
    assert with_telemetry(algo, Telemetry()).telemetry == Telemetry()


# ------------------------------------------------------- series content
def test_series_keys_and_shapes():
    problem = _problem()
    algo = FedScenario(telemetry=True, **SCENARIOS["composed"]).apply(
        _algo("fedcet", problem))
    res = simulate_quadratic(algo, problem, rounds=ROUNDS)
    series = res.telemetry
    for key in ("grad_norm", "msg_norm", "compress_err", "participating",
                "fresh_count", "age_min", "age_mean", "age_max",
                "invariant_residual", "consensus_err"):
        assert key in series, sorted(series)
        assert len(series[key]) == ROUNDS
    assert np.all(np.asarray(series["participating"]) <= 4)  # cohort size
    assert np.all(np.asarray(series["grad_norm"]) > 0)


def test_metric_subset_selection():
    problem = _problem()
    algo = with_telemetry(_algo("fedcet", problem),
                          Telemetry(metrics=("grad_norm", "msg_norm")))
    res = simulate_quadratic(algo, problem, rounds=3)
    assert sorted(res.telemetry) == ["grad_norm", "msg_norm"]


# --------------------------------------------------- monitors: boundary
def _residual_series(delay, policy, rounds=24):
    problem = _problem()
    algo = _algo("fedcet", problem)
    if delay != "none":
        algo = with_delay(algo, delay, policy=policy)
    res = simulate_quadratic(with_telemetry(algo, True), problem,
                             rounds=rounds)
    events = drain(res.telemetry, monitors=(INVARIANT_MONITOR,))
    warns = [e for e in events if e["event"] == "monitor"]
    residuals = [e["invariant_residual"] for e in events
                 if e["event"] == "round"]
    return residuals, warns


def test_invariant_monitor_silent_on_exact_scenarios():
    """sum_i d_i = 0 holds bare and under fixed:k + poly (uniform ages =>
    uniform weights): the residual sits at f64 noise, no WARNs."""
    for delay, policy in (("none", "last"), ("fixed:2", "poly:1")):
        residuals, warns = _residual_series(delay, policy)
        assert max(residuals) < 1e-9, (delay, policy, max(residuals))
        assert not warns, (delay, policy, warns[:1])


def test_invariant_monitor_fires_on_poly_staleness():
    """rr:2 + poly:1 has non-uniform ages => non-uniform weights => the
    Lemma 2 redistribution breaks; the monitor fires and names the axis."""
    residuals, warns = _residual_series("rr:2", "poly:1")
    assert max(residuals) > 1e-4
    assert warns, "monitor must fire"
    w = warns[0]
    assert w["level"] == "WARN" and w["metric"] == "invariant_residual"
    assert "stale_policy" in w["axis"]


def test_monitor_modes():
    assert Monitor("m", 2.0, "max").violated(3.0)
    assert not Monitor("m", 2.0, "max").violated(1.0)
    assert Monitor("m", 2.0, "min").violated(1.0)
    assert not Monitor("m", 2.0, "min").violated(3.0)


# ------------------------------------------------------- sinks / events
def test_jsonl_sink_round_trips_with_manifest(tmp_path):
    problem = _problem()
    algo = with_telemetry(with_delay(_algo("fedcet", problem), "rr:2",
                                     policy="poly:1"), True)
    res = simulate_quadratic(algo, problem, rounds=6)
    path = str(tmp_path / "run.jsonl")
    sink = JsonlSink(path)
    sink.emit(run_manifest(algo, n_params=problem.dim,
                           config={"rounds": 6},
                           monitors=resolve_monitors(algo.telemetry)))
    drain(res.telemetry, sinks=[sink],
          monitors=resolve_monitors(algo.telemetry),
          algo=algo, n_params=problem.dim)
    sink.close()
    events = [json.loads(line) for line in open(path)]
    man = events[0]
    assert man["event"] == "manifest" and man["schema"] == 1
    assert man["algo"] == "fedcet" and man["n_clients"] == problem.n_clients
    assert man["mesh"]["n_devices"] >= 1
    assert man["monitors"][0]["metric"] == "invariant_residual"
    assert man["bits_per_round"]["up_bits"] > 0
    assert man["hops"][0]["hop"] == "client"
    rounds = [e for e in events if e["event"] == "round"]
    assert [e["round"] for e in rounds] == list(range(6))
    assert all("invariant_residual" in e and "bits_up" in e for e in rounds)
    assert any(e["event"] == "monitor" for e in events)


def test_parse_sinks_grammar(tmp_path):
    sinks = parse_sinks(f"jsonl:{tmp_path}/a.jsonl,memory,stdout:5")
    kinds = [type(s).__name__ for s in sinks]
    assert kinds == ["JsonlSink", "MemorySink", "StdoutSink"]
    assert sinks[2].every == 5
    for s in sinks:
        s.close()
    assert parse_sinks(None) == []
    mem = MemorySink()
    assert parse_sinks([mem]) == [mem]
    with pytest.raises(ValueError):
        parse_sinks("carrier-pigeon:coop")


def test_parse_telemetry_spec():
    assert parse_telemetry(None) is None
    assert parse_telemetry("none") is None
    assert parse_telemetry(False) is None
    assert parse_telemetry(True) == Telemetry()
    assert parse_telemetry("jsonl:x.jsonl") == Telemetry()
    spec = Telemetry(metrics=("grad_norm",))
    assert parse_telemetry(spec) is spec


# ------------------------------------------------------------- trainer
def _lm_setup(telemetry, sinks, log_csv):
    from repro.configs import get_config
    from repro.data.synthetic import make_hetero_lm_dataset
    from repro.fed import FedTrainer, TrainerConfig
    from repro.models import build_model

    cfg = get_config("fedlm-100m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_clients, tau, B, S = 3, 2, 2, 32
    algo = FedCET(alpha=3e-3, c=0.05, tau=tau, n_clients=n_clients)
    algo = with_telemetry(algo, telemetry)
    ds = make_hetero_lm_dataset(cfg.vocab_size, n_clients, S, B, seed=1)
    batches_for = lambda r: {"tokens": ds.sample_round(r, tau)}  # noqa: E731
    tc = TrainerConfig(rounds=4, eval_every=2, log_csv=log_csv)
    trainer = FedTrainer(algo, model.loss, tc, sinks=sinks)
    state = trainer.init_state(params, jax.tree.map(lambda b: b[0],
                                                    batches_for(0)))
    return trainer, state, batches_for


def test_trainer_csv_bytes_identical_with_telemetry(tmp_path):
    """The trainer's CSV log must be identical whether or not telemetry +
    sinks ride the same fit — the observer cannot perturb the metrics
    pipeline either. (Every field is compared byte-for-byte except
    ``wall_s``, which differs between ANY two runs.)"""
    csv_off = str(tmp_path / "off.csv")
    csv_on = str(tmp_path / "on.csv")
    jsonl = str(tmp_path / "run.jsonl")
    trainer, state, batches_for = _lm_setup(False, None, csv_off)
    final_off = trainer.fit(state, batches_for)
    trainer2, state2, batches_for2 = _lm_setup(True, f"jsonl:{jsonl}", csv_on)
    final_on = trainer2.fit(state2, batches_for2)
    with open(csv_off) as a, open(csv_on) as b:
        rows_a, rows_b = a.read().splitlines(), b.read().splitlines()
    assert rows_a[0] == rows_b[0]          # identical header
    header = rows_a[0].split(",")
    wall = header.index("wall_s")          # the only nondeterministic field
    for ra, rb in zip(rows_a[1:], rows_b[1:]):
        ca, cb = ra.split(","), rb.split(",")
        ca[wall] = cb[wall] = ""
        assert ca == cb, (ra, rb)
    _assert_bitwise_equal(final_off, final_on)
    events = [json.loads(line) for line in open(jsonl)]
    assert events[0]["event"] == "manifest"
    assert sum(e["event"] == "round" for e in events) == 4


def test_run_training_per_round_stdout_lines(capsys, tmp_path):
    """launch.train emits a per-round summary (round, loss, bits_up,
    active_clients) gated by log_every, and drains telemetry into the
    requested sinks."""
    from repro.launch.train import run_training

    jsonl = str(tmp_path / "t.jsonl")
    hist = run_training("fedlm-100m", steps=3, n_clients=2, batch=2,
                        seq_len=16, log_every=1, telemetry=f"jsonl:{jsonl}")
    out = capsys.readouterr().out
    lines = [ln for ln in out.splitlines() if ln.startswith("round ")]
    assert len(lines) == 3
    for ln in lines:
        assert "loss" in ln and "bits_up" in ln and "active_clients" in ln
    assert len(hist["round"]) == 3
    events = [json.loads(line) for line in open(jsonl)]
    assert events[0]["event"] == "manifest"
    assert sum(e["event"] == "round" for e in events) == 3
