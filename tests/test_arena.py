"""Packed parameter arena: pack/unpack exactness, the arena round
lowering vs the per-leaf reference, and the fused round tail.

The arena (repro/core/arena.py) is pure data movement — reshape, zero
pad, concat — so pack/unpack must round-trip BITWISE, and an arena-run
round must match the per-leaf round <= 1e-12 (in f64 via conftest; in
practice most cells land bitwise) bare AND under the composed scenario
stack (shift:q8 x 0.8 participation x cohort), including a checkpoint
flipped between representations mid-sweep (``adapt_state``). The fused
tail (``FedCET(use_fused_kernel=True)`` + arena) replicates the generic
seam's PRNG schedule and masked-mean expressions, so it pins to the same
tolerance. Kernel parity: the Pallas kernels (interpret mode on CPU)
against their kernels/ref.py oracles.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Arena,
    ArenaLayout,
    CohortSpec,
    FedAvg,
    Scaffold,
    adapt_state,
    pack,
    run_rounds,
    unpack,
    with_arena,
    with_cohort,
    with_compression,
    with_participation,
)
from repro.core.fedcet import FedCET
from repro.data.quadratic import make_hetero_hessian_problem

N, M, TAU, ROUNDS = 24, 7, 2, 4
TOL = 1e-12

PROB = make_hetero_hessian_problem(0, n_clients=N, dim=12, n_measurements=4)
GRAD = jax.grad(PROB.client_loss)
BATCHES = PROB.stacked_batches(TAU)
FIRST = jax.tree.map(lambda b: b[0], BATCHES)


def _algos():
    return {
        "fedcet": FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N),
        "fedavg": FedAvg(alpha=0.05, tau=TAU, n_clients=N),
        "scaffold": Scaffold(alpha_l=0.02, tau=TAU, n_clients=N),
    }


def _composed(algo):
    """The issue's composed stack: shift:q8 x 0.8 participation x cohort."""
    algo = with_participation(algo, 0.8, seed=3)
    algo = with_compression(algo, compressor="shift:q8", seed=5)
    return with_cohort(algo, CohortSpec(size=M, selector="block"), seed=7)


def _run(algo, rounds=ROUNDS, state=None):
    if state is None:
        state = algo.init(GRAD, jnp.zeros((PROB.dim,), PROB.b.dtype), FIRST)
    final, _ = run_rounds(algo, GRAD, state, BATCHES, rounds=rounds)
    return final


def _assert_close(a, b, tol=TOL):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert float(jnp.max(jnp.abs(x - y))) <= tol


def _assert_equiv(arena_state, per_leaf_state, tol=TOL):
    """Adapt the arena-run state onto the per-leaf structure and compare."""
    _assert_close(adapt_state(arena_state, per_leaf_state),
                  per_leaf_state, tol=tol)


# --------------------------------------------------- pack/unpack round-trip
def _odd_tree(key, dtype=jnp.float64, lead=None):
    """Leaf sizes chosen to exercise lane padding: none divides 1024."""
    shapes = [("w", (3, 5)), ("b", (7,)), ("scalar", ()), ("big", (1030,)),
              ("nest_k", (2, 513))]
    ks = jax.random.split(key, len(shapes))
    mk = lambda k, s: jax.random.normal(  # noqa: E731
        k, ((lead,) + s if lead is not None else s), dtype)
    return {name: mk(k, s) for (name, s), k in zip(shapes, ks)}


def test_pack_unpack_roundtrip_bitwise():
    tree = _odd_tree(jax.random.key(0))
    lo = ArenaLayout.for_tree(tree)
    arena = pack(tree, lo)
    assert arena.data.shape == (lo.rows, 1024)
    back = unpack(arena)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert bool(jnp.all(a == b))


def test_pack_unpack_roundtrip_stacked():
    tree = _odd_tree(jax.random.key(1), lead=5)
    lo = ArenaLayout.for_tree(_odd_tree(jax.random.key(1)))
    arena = pack(tree, lo)
    assert arena.data.shape == (5, lo.rows, 1024)
    back = unpack(arena)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert bool(jnp.all(a == b))


def test_pack_pads_are_zero():
    tree = {"b": jnp.ones((7,), jnp.float64)}
    arena = pack(tree)
    assert float(jnp.sum(arena.data)) == 7.0  # everything past n is 0


def test_layout_row_segments():
    tree = _odd_tree(jax.random.key(2))
    lo = ArenaLayout.for_tree(tree)
    seg = lo.row_segments()
    assert seg.shape == (lo.rows,)
    counts = np.bincount(seg, minlength=len(lo.shapes))
    assert tuple(counts) == lo.rows_per_leaf
    assert lo.num_params == sum(int(np.prod(s)) for s in lo.shapes)


def test_layout_rejects_bad_trees():
    with pytest.raises(ValueError):  # mixed dtypes
        ArenaLayout.for_tree({"a": jnp.ones((2,), jnp.float32),
                              "b": jnp.ones((2,), jnp.float64)})
    with pytest.raises(ValueError):  # non-float
        ArenaLayout.for_tree({"a": jnp.ones((2,), jnp.int32)})
    lo = ArenaLayout.for_tree({"a": jnp.ones((3,))})
    with pytest.raises(ValueError):  # wrong leaf count
        pack({"a": jnp.ones((3,)), "b": jnp.ones((3,))}, lo)
    with pytest.raises(ValueError):  # neither model- nor stacked-shaped
        pack({"a": jnp.ones((4, 4))}, lo)


def test_arena_is_transparent_pytree():
    tree = _odd_tree(jax.random.key(3))
    a = pack(tree)
    b = jax.tree.map(lambda x: 2.0 * x, a)
    assert isinstance(b, Arena) and b.layout is a.layout
    assert bool(jnp.all(b.data == 2.0 * a.data))
    sds = jax.eval_shape(lambda x: x, a)
    assert jax.tree.leaves(sds)[0].shape == a.data.shape


# ------------------------------------- arena == per-leaf, quadratic (f64)
@pytest.mark.parametrize("name", list(_algos()))
def test_arena_equiv_bare(name):
    algo = _algos()[name]
    _assert_equiv(_run(with_arena(algo)), _run(algo))


@pytest.mark.parametrize("name", list(_algos()))
def test_arena_equiv_composed(name):
    algo = _composed(_algos()[name])
    _assert_equiv(_run(with_arena(algo)), _run(algo))


def test_fused_tail_equiv():
    """use_fused_kernel=True routes the arena round through the fused tail
    (FedCET._fused_tail -> ops.fedcet_round_tail); must match both the
    generic arena path and the per-leaf reference, bare and masked."""
    def mk(fused, participation=None):
        a = FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N,
                   use_fused_kernel=fused)
        a = with_compression(with_arena(a), compressor="shift:q8", seed=5)
        if participation is not None:
            a = with_participation(a, participation, seed=3)
        return a

    _assert_equiv(_run(mk(True)), _run(mk(False)))
    _assert_equiv(_run(mk(True, 0.8)), _run(mk(False, 0.8)))
    per_leaf = _run(with_compression(
        FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N),
        compressor="shift:q8", seed=5))
    _assert_equiv(_run(mk(True)), per_leaf)


def test_server_aggregate_fused_flag_per_leaf():
    """Satellite: the kernel-backed ``FedCET.server_aggregate`` (the
    ``fedcet_comm`` pair with the compressed-message ``v=`` carry) matches
    the tree.map expression on the plain per-leaf path too."""
    mk = lambda fused: with_compression(  # noqa: E731
        FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N,
               use_fused_kernel=fused), compressor="shift:q8", seed=5)
    _assert_close(_run(mk(True)), _run(mk(False)))


# --------------------------------------------- tiny transformer full round
def _tiny_lm():
    from repro.configs import get_config
    from repro.models import build_model

    cfg = dataclasses.replace(
        get_config("fedlm-100m").reduced(),
        d_model=32, n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
        vocab_size=96)
    return build_model(cfg), cfg


@pytest.mark.parametrize("compose", [False, True])
def test_arena_equiv_tiny_transformer(compose):
    """Full LM rounds on a tiny transformer (f32 model dtypes): arena vs
    per-leaf. The lowering is pure data movement around identical math, so
    the pin is far below f32 training noise."""
    from repro.data.synthetic import make_hetero_lm_dataset

    model, cfg = _tiny_lm()
    nc, tau, b, s = 5, 2, 2, 8
    params = model.init(jax.random.key(0))
    ds = make_hetero_lm_dataset(cfg.vocab_size, nc, s, b, seed=0)
    batches = {"tokens": ds.sample_round(0, tau)}
    grad_fn = jax.grad(model.loss)

    def run(algo, rounds=3):
        st = algo.init(grad_fn, params,
                       jax.tree.map(lambda x: x[0], batches))
        fin, _ = run_rounds(algo, grad_fn, st, batches, rounds=rounds)
        return fin

    algo = FedCET(alpha=3e-3, c=0.05, tau=tau, n_clients=nc)
    if compose:
        algo = with_participation(
            with_compression(algo, compressor="shift:q8", seed=5), 0.8,
            seed=3)
    pl_state = run(algo)
    ar_state = run(with_arena(algo))
    _assert_close(adapt_state(ar_state, pl_state), pl_state, tol=1e-5)


# ------------------------------------------------- checkpoint/resume flips
def test_checkpoint_flips_between_representations(tmp_path):
    """Save a per-leaf checkpoint mid-sweep, resume it as an ``--arena``
    run (and the reverse): both finish <= 1e-12 of the straight runs."""
    from repro.checkpoint.ckpt import load_pytree, save_pytree

    base = _composed(FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N))
    arena = with_arena(base)

    straight = _run(base, rounds=6)
    # per-leaf -> arena
    mid = _run(base, rounds=3)
    path = str(tmp_path / "per_leaf.npz")
    save_pytree(path, mid)
    like = arena.init(GRAD, jnp.zeros((PROB.dim,), PROB.b.dtype), FIRST)
    resumed = adapt_state(load_pytree(path, mid), like)
    final = _run(arena, rounds=3, state=resumed)
    _assert_equiv(final, straight)
    # arena -> per-leaf (also exercises checkpointing an Arena state)
    mid_a = _run(arena, rounds=3)
    path_a = str(tmp_path / "arena.npz")
    save_pytree(path_a, mid_a)
    resumed_pl = adapt_state(load_pytree(path_a, mid_a), mid)
    final_pl = _run(base, rounds=3, state=resumed_pl)
    _assert_close(final_pl, straight)


# --------------------------------------------------- kernel == ref parity
def test_fedcet_comm_kernel_matches_ref_with_v():
    from repro.kernels import ops as kops

    k = jax.random.split(jax.random.key(7), 4)
    shape = (1000,)  # odd: exercises the tile padding
    d, m, v = (jax.random.normal(k[i], shape) for i in range(3))
    mb = jax.random.normal(k[3], shape)
    for vv in (None, v):
        ker = kops.fedcet_comm(d, m, mb, 0.3, 0.02, v=vv, impl="kernel")
        ref = kops.fedcet_comm(d, m, mb, 0.3, 0.02, v=vv, impl="ref")
        _assert_close(ker, ref)


def test_round_tail_kernel_matches_ref():
    from repro.kernels import ops as kops

    c, rows = 3, 5
    ks = jax.random.split(jax.random.key(8), 5)
    v = jax.random.normal(ks[0], (c, rows, 1024))
    h = jax.random.normal(ks[1], (c, rows, 1024))
    d = jax.random.normal(ks[2], (c, rows, 1024))
    u = jax.random.uniform(ks[3], (rows, 1024))
    scale = jnp.max(jnp.abs(v - h), axis=(0, 2))[:, None] / 127.0
    scale = scale.at[2, 0].set(0.0)  # a zero-scale (constant-leaf) row
    w = jax.random.bernoulli(ks[4], 0.7, (c, 1)).astype(v.dtype)
    den = jnp.maximum(jnp.sum(w), 1.0).reshape(1, 1)
    args = dict(c=0.3, alpha=0.02, beta=0.5, bits=8)
    ref = kops.fedcet_round_tail(v, h, d, u, scale, w, den, impl="ref",
                                 **args)
    for impl in ("kernel", "auto"):
        got = kops.fedcet_round_tail(v, h, d, u, scale, w, den, impl=impl,
                                     **args)
        _assert_close(got, ref)


def test_stochastic_quantize_rows_matches_oracle():
    from repro.kernels import ops as kops

    rows = 9
    ks = jax.random.split(jax.random.key(9), 2)
    a = jax.random.normal(ks[0], (rows, 1024))
    u = jax.random.uniform(ks[1], (rows, 1024))
    scale = jnp.max(jnp.abs(a), axis=1, keepdims=True) / 127.0
    got = kops.stochastic_quantize_rows(a, u, scale, bits=8)
    inv = jnp.where(scale > 0, 1.0 / scale, 0.0)
    want = jnp.clip(jnp.floor(a * inv + u), -127, 127) * scale
    _assert_close(got, want)
