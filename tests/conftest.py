"""Test-suite configuration.

x64 is enabled process-wide: the convergence tests validate linear
convergence to the EXACT optimum (errors ~1e-10), which is below float32
resolution. Model code takes explicit dtypes from its configs, so enabling
x64 here does not change what the architecture smoke tests exercise.

NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — the
multi-pod dry-run runs in its own process (src/repro/launch/dryrun.py) so
tests and benchmarks see the single real CPU device.
"""

import jax

jax.config.update("jax_enable_x64", True)
