"""Test-suite configuration.

x64 is enabled process-wide: the convergence tests validate linear
convergence to the EXACT optimum (errors ~1e-10), which is below float32
resolution. Model code takes explicit dtypes from its configs, so enabling
x64 here does not change what the architecture smoke tests exercise.

Optional test dependencies: the property-based modules need ``hypothesis``
(pinned in pyproject.toml's ``test`` extra). When it is not installed,
``pytest_ignore_collect`` below skips exactly the modules that import it
UNGUARDED (top-level, column 0) so the tier-1 suite still collects and
runs green without optional deps; modules that guard the import behind
``try``/``except`` (tests/test_comm.py) stay collected — their
non-property tests run everywhere.

NOTE: XLA_FLAGS / host-device-count is deliberately NOT set here — the
multi-pod dry-run runs in its own process (src/repro/launch/dryrun.py) so
tests and benchmarks see the single real CPU device.
"""

import jax

jax.config.update("jax_enable_x64", True)

try:
    import hypothesis  # noqa: F401

    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False


def pytest_ignore_collect(collection_path, config):
    """Skip collecting modules that import hypothesis when it is absent."""
    import re

    if _HAVE_HYPOTHESIS or collection_path.suffix != ".py":
        return None
    try:
        text = collection_path.read_text(encoding="utf-8")
    except OSError:
        return None
    if re.search(r"^(from|import) hypothesis\b", text, re.M):
        return True
    return None
