"""Per-leaf compression plans (repro/core/compressors.py CompressionPlan).

The load-bearing contract: a plan mapping EVERY leaf to one spec is
BITWISE-identical to uniform ``with_compression`` with that spec — same
per-leaf key schedule (``fold_in(key, i)``), same wrapper math run
leaf-wise, same extras shapes (so checkpoints interchange between the
two). Pinned bare and under the composed scenario stack (shift:q8 x 0.8
participation x block cohort x arena), for FedCET and FedAvg.

Plus: the ``parse_plan`` grammar (including its error paths), first-
match-wins / digit-index resolution, the greedy bit-budget allocator's
invariants (budget respected, monotone in sensitivity, below-floor
rand-k fallback), and the telemetry-driven ``AdaptivePlan`` schedule.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CohortSpec,
    FedAvg,
    run_rounds,
    with_arena,
    with_cohort,
    with_compression,
    with_participation,
)
from repro.core.compressors import (
    AdaptivePlan,
    Bf16,
    Chain,
    CompressionPlan,
    ErrorFeedback,
    RandK,
    Shifted,
    StochasticQuant,
    TopK,
    parse_plan,
)
from repro.core.fedcet import FedCET
from repro.data.quadratic import make_hetero_hessian_problem

N, M, TAU, ROUNDS = 24, 7, 2, 4

PROB = make_hetero_hessian_problem(0, n_clients=N, dim=12, n_measurements=4)
SPLIT = 5  # params live as a 2-leaf dict so per-leaf rules mean something


def _loss(params, batch):
    return PROB.client_loss(
        jnp.concatenate([params["head"], params["tail"]]), batch)


GRAD = jax.grad(_loss)
BATCHES = PROB.stacked_batches(TAU)
FIRST = jax.tree.map(lambda b: b[0], BATCHES)
PARAMS0 = {"head": jnp.zeros((SPLIT,), PROB.b.dtype),
           "tail": jnp.zeros((PROB.dim - SPLIT,), PROB.b.dtype)}


def _algos():
    return {
        "fedcet": FedCET(alpha=0.02, c=0.3, tau=TAU, n_clients=N),
        "fedavg": FedAvg(alpha=0.05, tau=TAU, n_clients=N),
    }


def _composed(algo, compressor):
    """The composed scenario stack around either compressor flavor."""
    algo = with_participation(algo, 0.8, seed=3)
    algo = with_compression(algo, compressor=compressor, seed=5)
    return with_cohort(algo, CohortSpec(size=M, selector="block"), seed=7)


def _run(algo, rounds=ROUNDS, state=None):
    if state is None:
        state = algo.init(GRAD, PARAMS0, FIRST)
    final, _ = run_rounds(algo, GRAD, state, BATCHES, rounds=rounds)
    return final


def _assert_bitwise(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------ parse grammar
def test_parse_plan_grammar():
    p = parse_plan("embed*:q12,ln*:bf16,*:shift:q6")
    assert isinstance(p, CompressionPlan) and len(p.rules) == 3
    pat0, c0 = p.rules[0]
    assert pat0 == "embed*" and c0 == StochasticQuant(12)  # unbiased: bare
    pat1, c1 = p.rules[1]
    assert pat1 == "ln*" and isinstance(c1, ErrorFeedback)  # biased: auto-EF
    assert isinstance(c1.inner, Bf16)
    pat2, c2 = p.rules[2]
    assert pat2 == "*" and isinstance(c2, Shifted)
    assert c2.inner == StochasticQuant(6)


def test_parse_plan_none_and_passthrough():
    for spec in (None, "", "none", "off", "  NONE  "):
        assert parse_plan(spec) is None
    p = CompressionPlan(rules=(("*", StochasticQuant(8)),))
    assert parse_plan(p) is p
    # 'pattern:none' pins dense passthrough for matched leaves
    q = parse_plan("ln*:none,*:q8")
    assert q.rules[0] == ("ln*", None)
    # error_feedback=False turns the auto-EF policy off per rule
    bare = parse_plan("*:topk:0.3", error_feedback=False)
    assert bare.rules[0][1] == TopK(0.3)


def test_parse_plan_rejects_bad_rules():
    with pytest.raises(ValueError, match="bad plan rule"):
        parse_plan("justapattern")
    with pytest.raises(ValueError, match="bad plan rule"):
        parse_plan("embed*:")
    with pytest.raises(ValueError):
        parse_plan("*:bogus")
    with pytest.raises(TypeError, match="not a compression plan"):
        parse_plan(123)


# --------------------------------------------------------------- resolution
def test_resolution_first_match_wins_and_digit_index():
    plan = CompressionPlan(rules=(("0", TopK(0.5)),
                                  ("w*", StochasticQuant(8)),
                                  ("*", StochasticQuant(4))),
                           default=Bf16())
    # digit rule names the flatten-order leaf index, whatever its path
    assert plan.resolve(0, "zzz") == TopK(0.5)
    # first-match-wins: 'w*' shadows the '*' catch-all
    assert plan.resolve(1, "weight") == StochasticQuant(8)
    # glob also matches any single path component
    assert plan.resolve(2, "layers/0/wq") == StochasticQuant(8)
    assert plan.resolve(3, "bias") == StochasticQuant(4)
    # no catch-all: unmatched leaves fall to default
    short = CompressionPlan(rules=(("w*", StochasticQuant(8)),),
                            default=Bf16())
    assert isinstance(short.resolve(0, "bias"), Bf16)
    assert CompressionPlan(rules=(("w*", TopK(0.5)),)).resolve(0, "b") is None


def test_plans_cannot_nest_and_default_must_be_stateless():
    inner = CompressionPlan(rules=(("*", StochasticQuant(8)),))
    with pytest.raises(ValueError, match="nest"):
        CompressionPlan(rules=(("*", inner),))
    with pytest.raises(ValueError, match="default"):
        CompressionPlan(default=Shifted(StochasticQuant(8)))


# --------------------------------------- bitwise equivalence vs uniform path
@pytest.mark.parametrize("name", list(_algos()))
@pytest.mark.parametrize("spec", ["shift:q8", "q8", "topk:0.3",
                                  "randk:0.5+q8", "ef:topk:0.3+bf16"])
def test_uniform_plan_bitwise_equiv_bare(name, spec):
    """A '*:<spec>' plan IS uniform with_compression(<spec>): identical
    key schedule, identical wrapper math, identical extras — bitwise."""
    uni = with_compression(_algos()[name], compressor=spec, seed=5)
    pln = with_compression(_algos()[name], compressor=parse_plan(f"*:{spec}"),
                           seed=5)
    _assert_bitwise(_run(pln), _run(uni))


@pytest.mark.parametrize("name", list(_algos()))
def test_uniform_plan_bitwise_equiv_composed(name):
    """Same, under the full composed stack (participation x cohort), per-
    leaf AND arena-packed lowering."""
    uni = _composed(_algos()[name], "shift:q8")
    pln = _composed(_algos()[name], parse_plan("*:shift:q8"))
    _assert_bitwise(_run(pln), _run(uni))
    _assert_bitwise(_run(with_arena(pln)), _run(with_arena(uni)))


def test_checkpoint_interchange_plan_uniform(tmp_path):
    """Stateful extras are message-shaped zero trees on BOTH paths, so a
    mid-run checkpoint written by the uniform stack restores into the
    plan stack (and vice versa) and continues bitwise-identically."""
    from repro.checkpoint.ckpt import load_pytree, save_pytree

    uni = with_compression(_algos()["fedcet"], compressor="shift:q8", seed=5)
    pln = with_compression(_algos()["fedcet"],
                           compressor=parse_plan("*:shift:q8"), seed=5)
    mid_u = _run(uni, rounds=2)
    path = str(tmp_path / "mid.npz")
    save_pytree(path, mid_u)
    mid_p = load_pytree(path, _run(pln, rounds=2))  # plan-run structure
    _assert_bitwise(mid_p, mid_u)
    _assert_bitwise(_run(pln, state=mid_p, rounds=2),
                    _run(uni, state=mid_u, rounds=2))


def test_mixed_plan_runs_and_bills_per_leaf():
    """A genuinely per-leaf plan (different specs per leaf) runs through
    the engine and bills each leaf at its own wire width."""
    from repro.core.comm import CommMeter, leaf_info_of

    plan = parse_plan("head:shift:q4,*:shift:q8")
    algo = with_compression(_algos()["fedcet"], compressor=plan, seed=5)
    final = _run(algo)
    assert all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree.leaves(final))
    info = leaf_info_of(PARAMS0)
    assert [plan.leaf_wire_bits(i, nm, n) for i, (nm, n) in enumerate(info)] \
        == [SPLIT * 4.0, (PROB.dim - SPLIT) * 8.0]
    meter = CommMeter.for_params(PARAMS0, algo=algo, n_clients=N)
    assert meter.leaf_bits == (SPLIT * 4.0, (PROB.dim - SPLIT) * 8.0)
    assert meter.bits_up == pytest.approx(
        (SPLIT * 4.0 + (PROB.dim - SPLIT) * 8.0) / PROB.dim)


def test_scenario_knob_and_conflict():
    from repro.configs.base import FedScenario

    sc = FedScenario(compression_plan="head:q4,*:shift:q8")
    algo = sc.apply(_algos()["fedcet"])
    assert all(bool(jnp.all(jnp.isfinite(l)))
               for l in jax.tree.leaves(_run(algo)))
    with pytest.raises(ValueError, match="not both"):
        FedScenario(compression="q8",
                    compression_plan="*:q4").apply(_algos()["fedcet"])


# ---------------------------------------------------------------- allocator
def _toy_params(key):
    ks = jax.random.split(key, 3)
    return {"big": jax.random.normal(ks[0], (4096,)) * 0.02,
            "hot": jax.random.normal(ks[1], (256,)) * 2.0,
            "cold": jax.random.normal(ks[2], (256,)) * 0.001}


def test_allocator_respects_budget_and_weights_sensitivity():
    from repro.core.comm import leaf_info_of

    params = _toy_params(jax.random.key(0))
    info = leaf_info_of(params)
    n_total = sum(n for _, n in info)
    budget = 3.0 * n_total
    plan = CompressionPlan().allocate(budget, leaves=params,
                                      sensitivity="rms", wrap="shift",
                                      max_bits=16)
    bits = {nm: plan.leaf_wire_bits(i, nm, n) / n
            for i, (nm, n) in enumerate(info)}
    assert sum(plan.tree_wire_bits(info)) <= budget + 1e-9
    # monotone in sensitivity at EQUAL leaf size: hot/cold are both 256
    # coords, 2000x apart in RMS — hot must get the strictly wider grid.
    # (Across different sizes the water-fill trades value-per-BIT, so a
    # big low-sensitivity leaf can legitimately sit below a small one.)
    assert bits["hot"] > bits["cold"]
    assert bits["hot"] > bits["big"]
    # bound plan: the scalar rate is exact and within budget
    assert plan.leaves == tuple(info)
    assert plan.bits_per_coord <= 3.0 + 1e-12
    # absmax weighting orders the same way on this geometry
    pa = CompressionPlan().allocate(budget, leaves=params,
                                    sensitivity="absmax", wrap="shift",
                                    max_bits=16)
    ba = {nm: pa.leaf_wire_bits(i, nm, n) / n
          for i, (nm, n) in enumerate(info)}
    assert ba["hot"] > ba["cold"]


def test_allocator_below_floor_falls_back_to_randk():
    params = _toy_params(jax.random.key(1))
    from repro.core.comm import leaf_info_of

    info = leaf_info_of(params)
    n_total = sum(n for _, n in info)
    plan = CompressionPlan().allocate(0.5 * n_total, leaves=params,
                                      sensitivity=None, wrap=None)
    # one rule per leaf, all the same shared-k_frac rand-k + min_bits quant
    assert len(plan.rules) == len(info)
    ks = set()
    for (pat, comp), (nm, _) in zip(plan.rules, info):
        assert pat == nm and isinstance(comp, Chain)
        assert isinstance(comp.stages[0], RandK)
        assert isinstance(comp.stages[1], StochasticQuant)
        ks.add(comp.stages[0].k_frac)
    assert len(ks) == 1  # the k_frac is shared, not per-leaf
    assert sum(plan.tree_wire_bits(info)) <= 0.5 * n_total * 1.001


def test_allocator_validates_inputs():
    params = _toy_params(jax.random.key(2))
    with pytest.raises(ValueError, match="sensitivity"):
        CompressionPlan().allocate(1e4, leaves=params, sensitivity="bogus")
    with pytest.raises(ValueError, match="entries"):
        CompressionPlan().allocate(1e4, leaves=params,
                                   sensitivity=[1.0, 2.0])
    with pytest.raises(ValueError, match="rms"):
        CompressionPlan().allocate(1e4, leaves=[("a", 100)],
                                   sensitivity="rms")


# ------------------------------------------------------------ adaptive plan
def test_tightened_preserves_wrappers_and_floors():
    plan = CompressionPlan(rules=(
        ("a", Shifted(StochasticQuant(8))),
        ("b", ErrorFeedback(TopK(0.5))),
        ("c", Chain((RandK(0.5), StochasticQuant(2))))))
    t = plan.tightened()
    a, b, c = (c for _, c in t.rules)
    assert isinstance(a, Shifted) and a.inner == StochasticQuant(7)
    assert isinstance(b, ErrorFeedback) and b.inner == TopK(0.25)
    assert c.stages[0] == RandK(0.25)
    assert c.stages[1] == StochasticQuant(2)  # already at the floor
    # extras shapes preserved: still stateful with the same leaf layout
    assert t.stateful == plan.stateful


def test_adaptive_plan_tightens_on_residual_shrink():
    plan = CompressionPlan(rules=(("*", Shifted(StochasticQuant(8))),))
    sched = AdaptivePlan(plan=plan, factor=10.0)
    assert sched.update(1.0) is None        # first call sets the reference
    assert sched.update(0.5) is None        # only 2x down: no tighten
    new = sched.update(0.05)                # 20x down: tighten one step
    assert new is not None
    assert new.rules[0][1].inner == StochasticQuant(7)
    assert sched.update(float("nan")) is None
    assert sched.update(0.0) is None
