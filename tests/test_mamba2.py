"""Mamba2 SSD: chunked dual form vs literal recurrence; decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models import mamba2 as M


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    S=st.integers(2, 80),
    H=st.sampled_from([1, 2, 4]),
    P=st.sampled_from([4, 8]),
    N=st.sampled_from([4, 16]),
    chunk=st.sampled_from([4, 16, 128]),
)
def test_property_ssd_chunked_matches_naive(seed, S, H, P, N, chunk):
    """SSD chunked dual form == literal recurrence for any chunking,
    including chunks that don't divide S."""
    ks = jax.random.split(jax.random.key(seed), 5)
    B = 2
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_ref, h_ref = M.ssd_naive(x, dt, A, Bm, Cm)
    y, h = M.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_with_initial_state():
    """Carried initial state h0 behaves as a continuation of a longer seq."""
    ks = jax.random.split(jax.random.key(0), 5)
    B, S, H, P, N = 1, 32, 2, 4, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_full, h_full = M.ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    cut = 20
    y1, h1 = M.ssd_chunked(x[:, :cut], dt[:, :cut], A, Bm[:, :cut],
                           Cm[:, :cut], chunk=8)
    y2, h2 = M.ssd_chunked(x[:, cut:], dt[:, cut:], A, Bm[:, cut:],
                           Cm[:, cut:], chunk=8, h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)


def _tiny_cfg():
    return get_config("mamba2-130m").reduced()


def test_block_full_vs_naive_path():
    cfg = _tiny_cfg()
    p = M.init_mamba_block(jax.random.key(0), cfg)
    u = jax.random.normal(jax.random.key(1), (2, 24, cfg.d_model),
                          dtype=jnp.float32)
    out_c = M.apply_mamba_block(p, u, cfg)
    out_n = M.apply_mamba_block(p, u, cfg, naive=True)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_n),
                               rtol=2e-4, atol=2e-4)


def test_prefill_plus_decode_matches_full():
    """prefill(x[:P]) then token-by-token decode == full-sequence block."""
    cfg = _tiny_cfg()
    p = M.init_mamba_block(jax.random.key(0), cfg)
    B, S, P_cut = 2, 16, 9
    u = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model),
                          dtype=jnp.float32)
    full = M.apply_mamba_block(p, u, cfg)
    cache = M.init_ssm_cache(B, cfg, jnp.float32)
    out_pre, cache = M.apply_mamba_block_prefill(p, u[:, :P_cut], cache, cfg)
    np.testing.assert_allclose(np.asarray(out_pre), np.asarray(full[:, :P_cut]),
                               rtol=2e-4, atol=2e-4)
    for t in range(P_cut, S):
        o, cache = M.apply_mamba_block_decode(p, u[:, t:t + 1], cache, cfg)
        np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_decode_state_is_constant_size():
    """The long_500k enabler: SSM cache size is independent of seq len."""
    cfg = _tiny_cfg()
    c1 = M.init_ssm_cache(1, cfg, jnp.float32)
    sizes = [a.size for a in jax.tree.leaves(c1)]
    assert sum(sizes) < 100_000  # tiny, O(1) in sequence length
