"""FedCET-C (beyond-paper): compressed single-vector uplink + error feedback."""

import jax
import numpy as np
import pytest

from repro.core.fedcet_compressed import FedCETCompressed
from repro.core.lr_search import lr_search
from repro.core.fedcet import FedCET, max_weight_c
from repro.core.simulate import simulate_quadratic
from repro.data.quadratic import make_hetero_hessian_problem, make_quadratic_problem

jax.config.update("jax_enable_x64", True)


@pytest.fixture(scope="module")
def problem():
    return make_quadratic_problem(0)


def _algo(problem, tau=2, **kw):
    alpha = lr_search(problem.mu, problem.L, tau)
    return FedCETCompressed(alpha=alpha, c=max_weight_c(problem.mu, alpha),
                            tau=tau, n_clients=problem.n_clients, **kw)


def test_dense_variant_matches_fedcet(problem):
    """k_frac=1, no quantization == plain FedCET exactly."""
    a = _algo(problem)
    alpha = a.alpha
    base = FedCET(alpha=alpha, c=a.c, tau=2, n_clients=problem.n_clients)
    r_c = simulate_quadratic(a, problem, rounds=50)
    r_b = simulate_quadratic(base, problem, rounds=50)
    np.testing.assert_allclose(np.asarray(r_c.errors), np.asarray(r_b.errors),
                               rtol=1e-10, atol=1e-12)


def test_bf16_quantized_uplink_converges(problem):
    """bf16-compressed single vector + error feedback: still converges to a
    near-exact solution (bf16 floor), at half the uplink bytes."""
    a = _algo(problem, quantize=True)
    res = simulate_quadratic(a, problem, rounds=600)
    assert res.final_error < 1e-5, res.final_error
    assert a.up_frac == 0.5


def test_topk_sparsified_uplink_converges(problem):
    a = _algo(problem, k_frac=0.3)
    res = simulate_quadratic(a, problem, rounds=2000)
    assert res.final_error < 1e-6, res.final_error
    assert a.up_frac == pytest.approx(0.6)


def test_topk_hetero_hessians_neighborhood():
    """Beyond-paper finding: under Hessian heterogeneity, top-k+EF FedCET
    converges to a SMALL NEIGHBORHOOD of x* (~1e-4 here) rather than
    exactly — the compression noise interacts with the drift correction.
    Still ~500x below the no-feedback bias floor (next test)."""
    p = make_hetero_hessian_problem(7)
    a = _algo(p, k_frac=0.5)
    res = simulate_quadratic(a, p, rounds=3000)
    assert res.final_error < 1e-3, res.final_error


def test_error_feedback_required():
    """Ablation: WITHOUT error feedback, top-k FedCET stalls at a hard bias
    floor (~0.05); WITH feedback it reaches ~1e-4 on the same problem."""
    problem = make_hetero_hessian_problem(7)
    a = _algo(problem, k_frac=0.5)

    # sever the feedback: compress v directly, discard the remainder
    no_ef = _algo(problem, k_frac=0.5, error_feedback=False)
    r_ef = simulate_quadratic(a, problem, rounds=3000)
    r_no = simulate_quadratic(no_ef, problem, rounds=3000)
    assert r_ef.final_error < 1e-3
    # without feedback the sparsification bias leaves a hard floor
    # (measured: ~0.035 vs ~3.8e-4 with feedback, a ~90x gap)
    assert r_no.final_error > 50 * r_ef.final_error
