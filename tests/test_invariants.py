"""System-level invariants of the FedCET implementation (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import FedCET, max_weight_c
from repro.core.simulate import simulate_quadratic
from repro.data.quadratic import make_hetero_hessian_problem, make_quadratic_problem

jax.config.update("jax_enable_x64", True)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    tau=st.integers(1, 4),
    rounds=st.integers(1, 30),
    n_clients=st.integers(2, 8),
)
def test_property_drift_variable_is_mean_zero(seed, tau, rounds, n_clients):
    """Invariant (from d(t+1) = d(t) + c(I - 11^T/N)(...)): the drift
    variable d sums to zero over clients at EVERY round — the correction is
    purely redistributive, which is why it never needs transmitting."""
    p = make_quadratic_problem(seed, n_clients=n_clients, dim=12)
    algo = FedCET(alpha=0.01, c=0.3, tau=tau, n_clients=n_clients)
    res = simulate_quadratic(algo, p, rounds=rounds)
    d_mean = np.asarray(jnp.mean(res.state.d, axis=0))
    np.testing.assert_allclose(d_mean, 0.0, atol=1e-10)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), rounds=st.integers(5, 50))
def test_property_consensus_error_bounded_by_state(seed, rounds):
    """Clients stay in a bounded neighborhood of their mean (no divergence
    of the consensus error even mid-training)."""
    p = make_hetero_hessian_problem(seed)
    from repro.core.lr_search import lr_search

    alpha = lr_search(p.mu, p.L, 2)
    algo = FedCET(alpha=alpha, c=max_weight_c(p.mu, alpha), tau=2,
                  n_clients=p.n_clients)
    res = simulate_quadratic(algo, p, rounds=rounds)
    x = np.asarray(res.state.x)
    spread = np.linalg.norm(x - x.mean(0, keepdims=True))
    assert np.isfinite(spread)
    assert spread < 10.0 * (1.0 + np.linalg.norm(x.mean(0)))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(0.1, 10.0))
def test_property_translation_equivariance(seed, scale):
    """Shifting every measurement by a constant shifts x* and the whole
    FedCET trajectory by the matching amount (affine equivariance of the
    update rule) — e(k) curves are identical."""
    import dataclasses

    p1 = make_quadratic_problem(seed, n_clients=4, dim=8)
    shift = scale * jnp.ones((8,), p1.b.dtype)
    p2 = dataclasses.replace(p1, b=p1.b + 2.0 * shift[None, None, :])
    algo = FedCET(alpha=0.02, c=0.3, tau=2, n_clients=4)
    r1 = simulate_quadratic(algo, p1, rounds=30)
    r2 = simulate_quadratic(algo, p2, rounds=30,
                            x0=jnp.zeros((8,), p1.b.dtype) + shift)
    np.testing.assert_allclose(np.asarray(r1.errors), np.asarray(r2.errors),
                               rtol=1e-8, atol=1e-9)
