"""Partitioning rules + a small-mesh end-to-end lowering test.

The big-mesh dry-run lives in its own process (it forces 512 host devices);
here we check the PartitionSpec rule table directly, and run one miniature
lowering on a 4-device subprocess mesh to catch rule/shape regressions
inside the normal pytest run.
"""

import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.partition import _base_spec, param_pspec


class L:  # tiny ShapeDtypeStruct stand-in
    def __init__(self, *shape):
        self.shape = shape
        self.ndim = len(shape)


TP = 16


def test_attention_projection_rules():
    assert _base_spec(("layers", "attn", "wq"), (6144, 6144), TP) == (None, "model")
    assert _base_spec(("layers", "attn", "wo"), (6144, 6144), TP) == ("model", None)
    assert _base_spec(("layers", "attn", "wk"), (2048, 256), TP) == (None, "model")


def test_moe_rules_divisible_vs_not():
    # llama4: 16 experts over a 16-way model axis -> expert parallel
    assert _base_spec(("layers", "moe", "up"), (16, 5120, 8192), TP) == ("model", None, None)
    # granite: 40 experts don't divide 16 -> shard the ffn dim instead
    assert _base_spec(("layers", "moe", "up"), (40, 1536, 512), TP) == (None, None, "model")
    assert _base_spec(("layers", "moe", "down"), (40, 512, 1536), TP) == (None, "model", None)
    # shared expert inside the moe dict follows dense rules
    assert _base_spec(("layers", "moe", "shared", "up"), (5120, 8192), TP) == (None, "model")


def test_embed_vocab_sharding_and_odd_vocab():
    assert _base_spec(("embed",), (92544, 6144), TP) == ("model", None)
    # odd vocab (49155) is not sharded
    assert _base_spec(("embed",), (49155, 1536), TP) == (None, None)


def test_norms_replicated():
    assert _base_spec(("layers", "ln1", "weight"), (6144,), TP) == ()


def test_stacked_and_client_axes_padding():
    # federated state leaf: [clients, L, d_in, d_out]
    spec = param_pspec(("layers", "attn", "wq"), L(16, 48, 6144, 6144), TP,
                       client_axes=("pod", "data"))
    assert spec == P(("pod", "data"), None, None, "model")
    spec = param_pspec(("layers", "mlp", "up"), L(48, 2048, 6144), TP)
    assert spec == P(None, None, "model")


def test_fsdp_extra_axis():
    spec = param_pspec(("layers", "attn", "wq"), L(4, 48, 5120, 5120), TP,
                       client_axes=("data",), extra_axis="fsdp", extra_size=4)
    assert spec == P(("data",), None, "fsdp", "model")
    # 1-d leaves unaffected
    spec = param_pspec(("layers", "ln1", "weight"), L(48, 5120), TP,
                       extra_axis="fsdp", extra_size=4)
    assert spec == P(None, None)


SMALL_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.launch.mesh import make_test_mesh
from repro.launch.train import make_plan, lower_train_step, TrainPlan
from repro.launch import serve
import dataclasses
from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES, ShapeConfig

mesh = make_test_mesh((2, 4), ("data", "model"))

# miniature shapes so the 8-device CPU compile is fast
INPUT_SHAPES["train_4k"] = ShapeConfig("train_4k", 64, 4, "train")
INPUT_SHAPES["decode_32k"] = ShapeConfig("decode_32k", 64, 4, "decode")

from repro.configs import registry
import repro.configs as C
cfg = get_config("qwen3-1.7b").reduced()
reg = registry()
reg["qwen3-1.7b"] = dataclasses.replace(cfg, name="qwen3-1.7b")

plan = make_plan("qwen3-1.7b", mesh)
compiled = lower_train_step(plan).compile()
assert compiled.memory_analysis().temp_size_in_bytes > 0
print("TRAIN_OK")

lowered = serve.lower_decode("qwen3-1.7b", mesh, shape_name="decode_32k")
lowered.compile()
print("DECODE_OK")
"""


def test_small_mesh_lowering_subprocess():
    """End-to-end pjit lowering on a 2x4 fake-device mesh (own process so the
    device-count flag doesn't leak into this test session)."""
    res = subprocess.run(
        [sys.executable, "-c", SMALL_MESH_SCRIPT],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             # skip the 60s TPU-backend probe; this is a fake-device CPU test
             "JAX_PLATFORMS": "cpu"},
        cwd=__file__.rsplit("/tests/", 1)[0],
    )
    assert "TRAIN_OK" in res.stdout, res.stderr[-2000:]
    assert "DECODE_OK" in res.stdout, res.stderr[-2000:]
