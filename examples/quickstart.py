"""Quickstart: reproduce the paper's numerical evaluation (Section IV).

Solves the heterogeneous distributed-estimation problem with FedCET and the
paper's comparison baselines, printing the convergence error e(k) at sampled
communication rounds and the transmitted bytes — the console version of
Fig. 1. Runs in seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)  # errors reach 1e-12: need f64

from repro.core.lr_search import contraction_factors, lr_search
from repro.core.simulate import paper_fig1_algorithms, simulate_quadratic
from repro.data.quadratic import make_quadratic_problem


def main():
    problem = make_quadratic_problem(0)  # N=10 clients, n=60, b~U[-10,10]
    print(f"problem: N={problem.n_clients} clients, n={problem.dim}, "
          f"mu={problem.mu}, L={problem.L}")
    alpha = lr_search(problem.mu, problem.L, tau=2)
    cf = contraction_factors(alpha, problem.mu, problem.L, 2, problem.n_clients)
    print(f"Algorithm 1 learning rate: alpha={alpha:.6f} "
          f"(rho1={cf.rho1:.4f}, rho2={cf.rho2:.6f})\n")

    rounds = 300
    algos = paper_fig1_algorithms(problem, tau=2)
    results = {k: simulate_quadratic(a, problem, rounds=rounds)
               for k, a in algos.items()}

    header = f"{'round':>6} " + " ".join(f"{k:>14}" for k in results)
    print(header)
    for k in (0, 10, 25, 50, 100, 200, 300):
        row = f"{k:>6} " + " ".join(
            f"{float(r.errors[k]):>14.3e}" for r in results.values())
        print(row)
    print("\nbytes per communication round (all clients, up+down):")
    for name, r in results.items():
        print(f"  {name:>9}: {r.bytes_per_round:>8d} B"
              + ("   <- ONE vector each way (Remark 2)" if name == "fedcet" else ""))
    assert results["fedcet"].final_error < 1e-9, "FedCET must reach exact x*"
    print("\nFedCET reached the exact optimum with half the communication. OK")


if __name__ == "__main__":
    main()
