"""Batched serving example: prefill a prompt batch, then KV-cached decode.

Uses the same model zoo + serve_step code path that the multi-pod dry-run
lowers for the decode shapes. Reduced configs by default (CPU-friendly);
works for every assigned architecture, including the SSM/hybrid families
(O(1)-state decode) and the VLM/audio stub frontends.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma-2b --gen-len 16
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m
    PYTHONPATH=src python examples/serve_lm.py --arch whisper-small
"""

import argparse
import time

from repro.launch.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    t0 = time.time()
    out = generate(args.arch, prompt_len=args.prompt_len,
                   gen_len=args.gen_len, batch=args.batch,
                   reduced=not args.full, greedy=not args.sample)
    dt = time.time() - t0
    print(f"arch={args.arch} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen_len}  ({dt:.1f}s incl. compile)")
    for i, row in enumerate(out):
        print(f"  request {i}: {[int(t) for t in row]}")


if __name__ == "__main__":
    main()
