"""End-to-end federated LM training with FedCET (the paper's technique as a
first-class training feature).

Trains a decoder-only LM on synthetic heterogeneous client corpora (per-
client Markov statistics; non-IID by construction) for a few hundred
communication rounds, logging loss and cumulative communication. Defaults to
the reduced fedlm config so it runs on one CPU in a few minutes; pass --full
for the ~100M-parameter config (sized for real hardware; same code path as
the pjit production launcher).

    PYTHONPATH=src python examples/fed_train_lm.py --rounds 200
    PYTHONPATH=src python examples/fed_train_lm.py --arch qwen3-1.7b   # reduced qwen3
"""

import argparse

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fedlm-100m")
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--alpha", type=float, default=3e-3)
    ap.add_argument("--heterogeneity", type=float, default=0.8)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (use on real hardware)")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    hist = run_training(
        args.arch, steps=args.rounds, tau=args.tau, n_clients=args.clients,
        batch=args.batch, seq_len=args.seq_len, alpha=args.alpha,
        heterogeneity=args.heterogeneity, reduced=not args.full,
        ckpt_dir=args.ckpt_dir,
        callback=lambda r, l, b: print(
            f"round {r:5d}  loss {l:8.4f}  comm {b / 1e6:9.2f} MB"))
    first, last = hist["loss"][0], hist["loss"][-1]
    print(f"\nloss {first:.4f} -> {last:.4f} over {args.rounds} rounds "
          f"({hist['comm_bytes'][-1] / 1e6:.1f} MB transmitted)")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
