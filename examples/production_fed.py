"""Production-style federated run: FedTrainer + compressed FedCET +
partial participation + checkpoint/resume — the engine's message
transforms composed onto one algorithm (previously impossible: the seed
had separate FedCETCompressed and FedCETPartial forks that could not be
combined).

    PYTHONPATH=src python examples/production_fed.py --rounds 60
"""

import argparse

import jax

from repro.configs import get_config
from repro.core import FedCET, with_compression, with_participation
from repro.data.synthetic import make_hetero_lm_dataset
from repro.fed import FedTrainer, TrainerConfig
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fedlm-100m")
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--participation", type=float, default=0.75,
                    help="per-round client sampling rate (1.0 = everyone)")
    ap.add_argument("--ckpt-dir", default="results/prod_fed_ckpt")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 4, 64
    ds = make_hetero_lm_dataset(cfg.vocab_size, args.clients, S, B,
                                heterogeneity=0.8, seed=0)
    batches_for = lambda r: {"tokens": ds.sample_round(r, args.tau)}
    eval_b = batches_for(999_999)

    # bf16-compressed uplink x sampled clients, composed onto plain FedCET;
    # the trainer meters bit-true bytes from the compressor stack's
    # bits_per_coord (16 bits/coordinate up here, dense f32 down).
    algo = with_participation(
        with_compression(FedCET(alpha=3e-3, c=0.05, tau=args.tau,
                                n_clients=args.clients), quantize=True),
        args.participation)
    trainer = FedTrainer(algo, model.loss, TrainerConfig(
        rounds=args.rounds, eval_every=10, ckpt_every=20,
        ckpt_dir=args.ckpt_dir, log_csv="results/prod_fed_metrics.csv"))

    state = trainer.init_state(params, jax.tree.map(lambda b: b[0],
                                                    batches_for(0)))
    state, start = trainer.maybe_resume(state)
    if start:
        print(f"resumed from round {start}")
    trainer.fit(state, batches_for, eval_batch_for=lambda r: eval_b,
                start_round=start,
                callback=lambda row: print(
                    f"round {row['round']:4d}  global {row['loss_global']:7.4f}  "
                    f"gap {row['heterogeneity_gap']:+.4f}  "
                    f"comm {row['comm_bytes'] / 1e6:8.2f} MB"))
    first, last = trainer.history[0], trainer.history[-1]
    print(f"\nglobal loss {first['loss_global']:.4f} -> {last['loss_global']:.4f}"
          f"  ({last['comm_bytes'] / 1e6:.1f} MB total, bf16 uplink, "
          f"{args.participation:.0%} participation)")


if __name__ == "__main__":
    main()
