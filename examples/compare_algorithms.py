"""Error-vs-communication comparison across federated algorithms.

Extends the paper's Fig. 1 with FedAvg (drift floor, shown on the
heterogeneous-Hessian variant where drift is provable) and sparsified FedLin,
reporting error as a function of TRANSMITTED BYTES — the paper's actual
headline metric. Writes a CSV for plotting.

    PYTHONPATH=src python examples/compare_algorithms.py --out results/compare.csv
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

from repro.core import (FedAvg, FedCET, FedLin, FedTrack, Scaffold,
                        max_weight_c, with_compression)
from repro.core.lr_search import lr_search
from repro.core.simulate import simulate_quadratic
from repro.data.quadratic import make_hetero_hessian_problem


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=2000)
    ap.add_argument("--out", default="results/compare.csv")
    args = ap.parse_args()

    p = make_hetero_hessian_problem(11)
    tau, n = 2, p.n_clients
    alpha = lr_search(p.mu, p.L, tau)
    fedcet = FedCET(alpha=alpha, c=max_weight_c(p.mu, alpha), tau=tau,
                    n_clients=n)
    algos = {
        "fedcet": fedcet,
        "fedavg": FedAvg(alpha=1.0 / (2 * tau * p.L), tau=tau, n_clients=n),
        "fedtrack": FedTrack(alpha=1.0 / (18 * tau * p.L), tau=tau,
                             n_clients=n),
        "scaffold": Scaffold(alpha_l=1.0 / (81 * tau * p.L), tau=tau,
                             n_clients=n),
        "fedlin_k0.3": FedLin(alpha=1.0 / (18 * tau * p.L), tau=tau,
                              n_clients=n, k_frac=0.3),
        # beyond-paper: the generic engine transform on FedCET's single vector
        "fedcet_c_top30": with_compression(fedcet, k_frac=0.3),
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write("algo,round,bytes,error\n")
        for name, algo in algos.items():
            res = simulate_quadratic(algo, p, rounds=args.rounds)
            # up_frac is declared by the algorithm (engine transforms and
            # FedLin's own sparsifier both report through it)
            per_round = int(p.dim * 8 * n
                            * (algo.vectors_up * algo.up_frac + algo.vectors_down))
            for k in range(0, args.rounds + 1, max(1, args.rounds // 100)):
                f.write(f"{name},{k},{k * per_round},"
                        f"{float(res.errors[k]):.6e}\n")
            print(f"{name:>12}: final err {float(res.errors[-1]):.3e}, "
                  f"{per_round} B/round")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
