"""Benchmark: Remark-2 communication table — bytes per round per algorithm
for each assigned architecture's parameter count (the paper's headline:
FedCET transmits HALF of SCAFFOLD/FedTrack/FedLin at equal round counts)."""

from __future__ import annotations

from repro.configs import ASSIGNED, get_config
from repro.core import FedAvg, FedCET, FedLin, FedTrack, Scaffold, comm_bytes_per_round
from repro.roofline.flops import param_counts


def run(csv_rows=None, n_clients: int = 16):
    from repro.core import FedCETCompressed, with_compression

    algos = {
        "fedcet": FedCET(alpha=1e-3, c=0.05, tau=2, n_clients=n_clients),
        "fedavg": FedAvg(alpha=1e-3, tau=2, n_clients=n_clients),
        "scaffold": Scaffold(alpha_l=1e-3, tau=2, n_clients=n_clients),
        "fedtrack": FedTrack(alpha=1e-3, tau=2, n_clients=n_clients),
        "fedlin_k0.1": FedLin(alpha=1e-3, tau=2, n_clients=n_clients, k_frac=0.1),
        # beyond-paper: compressed single-vector uplink with error feedback
        "fedcet_c_bf16": FedCETCompressed(alpha=1e-3, c=0.05, tau=2,
                                          n_clients=n_clients, quantize=True),
        # the generic engine transform composes onto any algorithm
        "fedcet_c_top30": with_compression(
            FedCET(alpha=1e-3, c=0.05, tau=2, n_clients=n_clients), k_frac=0.3),
    }
    out = {}
    for arch in ASSIGNED:
        n, _ = param_counts(get_config(arch))
        for name, algo in algos.items():
            b = comm_bytes_per_round(algo, n, itemsize=2, n_clients=n_clients)
            # uplink compression fraction, declared by the algorithm itself
            total = int(b["up"] * algo.up_frac + b["down"])
            out[(arch, name)] = total
            if csv_rows is not None:
                csv_rows.append((f"comm/{arch}/{name}", 0.0,
                                 f"bytes_per_round={total}"))
        assert out[(arch, "fedcet")] * 2 == out[(arch, "scaffold")]
        assert out[(arch, "fedcet")] == out[(arch, "fedavg")]
    return out


if __name__ == "__main__":
    rows = []
    run(csv_rows=rows)
    for r in rows:
        print(",".join(map(str, r)))
