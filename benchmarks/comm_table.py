"""Benchmark: Remark-2 communication table — bytes per round per algorithm
for each assigned architecture's parameter count (the paper's headline:
FedCET transmits HALF of SCAFFOLD/FedTrack/FedLin at equal round counts),
plus BIT-TRUE bits/round for every compressor stack (the compressor
subsystem's accounting contract: sparsifiers pay index bits, quantizers
shrink value bits, seed-synchronized rand-k pays values only).

Per-leaf plan billing (``_plan_leaf_billing``) additionally pins the
lowering-invariance contract: a :class:`CompressionPlan`'s per-leaf bits
are identical (not merely close) whether the leaf sizes come from the
unpacked pytree (``leaf_info_of``) or the packed parameter arena
(``ArenaLayout.leaf_sizes``), and the DECLARED per-leaf wire bits agree
with the ACTUALLY kept coordinate counts to <= 1 coordinate per leaf
(the ``max(1, round(k_frac * n))`` rounding fix)."""

from __future__ import annotations

import math

from repro.configs import ASSIGNED, get_config
from repro.core import (
    FedAvg,
    FedCET,
    FedLin,
    FedTrack,
    Scaffold,
    comm_bits_per_round,
)
from repro.roofline.flops import param_counts


def _algos(n_clients: int) -> dict:
    from repro.core import (FedCETCompressed, with_cohort, with_compression,
                            with_delay, with_topology)

    fedcet = lambda: FedCET(alpha=1e-3, c=0.05, tau=2, n_clients=n_clients)  # noqa: E731
    return {
        "fedcet": fedcet(),
        "fedavg": FedAvg(alpha=1e-3, tau=2, n_clients=n_clients),
        "scaffold": Scaffold(alpha_l=1e-3, tau=2, n_clients=n_clients),
        "fedtrack": FedTrack(alpha=1e-3, tau=2, n_clients=n_clients),
        "fedlin_k0.1": FedLin(alpha=1e-3, tau=2, n_clients=n_clients, k_frac=0.1),
        # beyond-paper: compressed single-vector uplink with error feedback
        "fedcet_c_bf16": FedCETCompressed(alpha=1e-3, c=0.05, tau=2,
                                          n_clients=n_clients, quantize=True),
        # the generic engine transform composes onto any algorithm
        "fedcet_c_top30": with_compression(fedcet(), k_frac=0.3),
        # first-class compressor stacks (core/compressors.py): per-client
        # top-k, unbiased rand-k / dithered quantization, DIANA-style shift
        "fedcet_topk30_pc": with_compression(fedcet(), compressor="topk:0.3"),
        "fedcet_randk25": with_compression(fedcet(), compressor="randk:0.25"),
        "fedcet_q8": with_compression(fedcet(), compressor="q8"),
        "fedcet_shift_q8": with_compression(fedcet(), compressor="shift:q8"),
        "fedcet_randk50_q8": with_compression(fedcet(),
                                              compressor="randk:0.5+q8"),
        # asynchronous rounds (core/staleness.py): buffered rounds transmit
        # ZERO uplink bits — expected uplink scales by the transmit duty
        # (fixed:2 = every 3rd round lands -> 1/3; rr:2 = 2 of n_clients
        # stragglers per round -> (n-2)/n), and stacks with compression.
        "fedcet_delay_fixed2": with_delay(fedcet(), "fixed:2", policy="last"),
        "fedcet_delay_rr2": with_delay(fedcet(), "rr:2", policy="drop"),
        "fedcet_shiftq8_rr2": with_delay(
            with_compression(fedcet(), compressor="shift:q8"), "rr:2"),
        # natural (exponent-only) quantization: 9 bits/coord, no shared
        # scale, unbiased with omega = 1/8.
        "fedcet_nat": with_compression(fedcet(), compressor="nat"),
        # aggregation topologies (core/topology.py): the hierarchy's root
        # ingests 4 messages instead of n_clients (aggregator tiers billed
        # dense f32 per hop, client tier pays the compressed width); ring
        # gossip bills one message per directed edge and NO broadcast.
        "fedcet_hier4": with_topology(fedcet(), "hier:g4"),
        "fedcet_hier4_shiftq8": with_topology(
            with_compression(fedcet(), compressor="shift:q8"), "hier:g4"),
        "fedcet_ring": with_topology(fedcet(), "ring"),
        # the sparse neighbor-exchange lowering exchanges the SAME directed
        # edges as the dense contraction — accounting must be identical.
        "fedcet_ring_sparse": with_topology(fedcet(), "ring:sparse"),
        # tier recompression: the interior edge->root hop carries 8-bit
        # shifted-quantized partial means instead of dense f32, so the
        # FULL uplink is compressed end to end (downward tier
        # re-broadcasts stay dense f32).
        "fedcet_hier4_tierq8": with_topology(
            with_compression(fedcet(), compressor="shift:q8"), "hier:g4",
            tier_compression="shift:q8"),
        # cohort execution (core/engine.py): only the sampled size/N slice
        # of clients computes, transmits OR receives — BOTH duty cycles
        # scale by the cohort fraction, and stack with compression.
        "fedcet_cohort4": with_cohort(fedcet(), "block:4"),
        "fedcet_cohort4_shiftq8": with_cohort(
            with_compression(fedcet(), compressor="shift:q8"), "block:4"),
    }


def _plan_leaf_billing(csv_rows=None, n_clients: int = 16) -> None:
    """Per-leaf plan billing on the reduced LM geometry: identical bits
    from the packed-arena layout and the unpacked pytree (<= 1e-12), and
    declared-vs-actual kept coordinates within 1 per leaf."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import (leaf_info_of, message_leaf_bits_of, parse_plan,
                            with_compression)
    from repro.core.arena import ArenaLayout
    from repro.core.comm import CommMeter
    from repro.core.compressors import (ErrorFeedback, Shifted, _k_of,
                                        _wire_stages)
    from repro.models import build_model

    cfg = get_config("fedlm-100m").reduced()
    model = build_model(cfg)
    shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    params = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)
    info = leaf_info_of(params)

    plan = parse_plan("embed*:topk:0.3,ln*:q4,lm_head*:randk:0.5+q8,"
                      "*:shift:q6")
    algo = with_compression(
        FedCET(alpha=1e-3, c=0.05, tau=2, n_clients=n_clients),
        compressor=plan, seed=0)

    # (1) lowering invariance: billing never inspects how the message is
    # packed — per-leaf sizes from ArenaLayout (layout order == flatten
    # order) and from leaf_info_of produce IDENTICAL per-leaf bits.
    layout = ArenaLayout.for_tree(params)
    arena_info = list(zip((nm for nm, _ in info), layout.leaf_sizes()))
    pytree_bits = message_leaf_bits_of(algo, info)
    arena_bits = message_leaf_bits_of(algo, arena_info)
    assert pytree_bits and arena_bits and len(pytree_bits) == len(info)
    for a, b in zip(pytree_bits, arena_bits):
        assert abs(a - b) <= 1e-12, (a, b)
    meter = CommMeter.for_params(params, algo=algo, n_clients=n_clients)
    assert meter.leaf_bits == tuple(pytree_bits)
    assert abs(meter.bits_up - sum(pytree_bits) / meter.n_params) <= 1e-12

    # (2) declared vs actual: compress a random leaf through each resolved
    # stack and count surviving coordinates — the declared kept count
    # (max(1, round(k_frac * n)), the rounding fix) matches to <= 1.
    key = jax.random.key(3)
    for i, (nm, n) in enumerate(info):
        comp = plan.resolve(i, nm)
        stages = _wire_stages(comp)
        frac, declared = 1.0, float(n)
        for s in stages:
            if s.keep_frac < 1.0:
                frac *= s.keep_frac
                declared = float(_k_of(frac, n))
        if frac >= 1.0:
            continue  # dense stack: every coordinate survives
        # count survivors after the SPARSIFYING stages only — a trailing
        # quantizer legitimately rounds small kept values to zero, but
        # those coordinates are still transmitted (and billed).
        q = jax.random.normal(jax.random.fold_in(key, i), (1, n))
        for j, s in enumerate(stages):
            if s.keep_frac < 1.0:
                sub = jax.random.fold_in(jax.random.fold_in(key, i), j)
                q = s.compress(sub if s.requires_key else None, q)
        actual = int(jnp.sum(q != 0))
        assert abs(declared - actual) <= 1, (nm, declared, actual)
        if csv_rows is not None:
            csv_rows.append((f"comm/plan_leaf/{nm}", 0.0,
                             f"declared_kept={declared:g}"
                             f";actual_kept={actual}"
                             f";bits={pytree_bits[i]:g}"))


def run(csv_rows=None, n_clients: int = 16):
    algos = _algos(n_clients)
    out = {}
    for arch in ASSIGNED:
        n, _ = param_counts(get_config(arch))
        for name, algo in algos.items():
            # ONE source of truth per row: the bit-true accounting — bytes
            # are bits/8 (the old itemsize=2 x up_frac bytes column mixed a
            # 16-bit dense baseline with fractions relative to f32 and
            # disagreed with the bits column by 2x for compressed stacks).
            bits = comm_bits_per_round(algo, n, n_clients=n_clients)
            total = int(bits["total_bits"] / 8)
            out[(arch, name)] = total
            if csv_rows is not None:
                csv_rows.append((
                    f"comm/{arch}/{name}", 0.0,
                    f"bytes_per_round={total}"
                    f";bits_per_round={int(bits['total_bits'])}"
                    f";up_bits_per_coord={algo.bits_per_coord:g}"
                    f";up_duty={getattr(algo, 'transmit_frac', 1.0):g}"
                    f";down_duty={getattr(algo, 'receive_frac', 1.0):g}"))
        assert out[(arch, "fedcet")] * 2 == out[(arch, "scaffold")]
        assert out[(arch, "fedcet")] == out[(arch, "fedavg")]
        # bit-true sanity: seed-synchronized rand-k pays no index traffic,
        # so the 25% rand-k uplink is exactly 8 bits/coordinate...
        assert algos["fedcet_randk25"].bits_per_coord == 8.0
        # ...while per-client top-k at 30% pays values + int32 indices.
        assert algos["fedcet_topk30_pc"].bits_per_coord == 0.3 * 64.0
        # delay duty: fixed:2 lands every 3rd round (expected uplink /3,
        # downlink broadcast stays dense), rr:2 idles 2 of n_clients.
        # isclose, not ==: a * (1/3) * 3 is not exact for every int a.
        sync_up = comm_bits_per_round(algos["fedcet"], n,
                                      n_clients=n_clients)["up_bits"]
        dly = comm_bits_per_round(algos["fedcet_delay_fixed2"], n,
                                  n_clients=n_clients)
        assert math.isclose(dly["up_bits"] * 3, sync_up, rel_tol=1e-12)
        assert dly["down_bits"] == sync_up
        assert algos["fedcet_delay_rr2"].transmit_frac \
            == (n_clients - 2) / n_clients
        # duty composes with compression: shift:q8 is 8 bits/coord BEFORE
        # the duty scaling.
        assert algos["fedcet_shiftq8_rr2"].bits_per_coord == 8.0
        # natural compression: sign + 8-bit exponent.
        assert algos["fedcet_nat"].bits_per_coord == 9.0
        # per-hop topology accounting: the 2-level hierarchy adds 4 dense
        # f32 tier messages each way on top of the client tier (which
        # still pays the compressed width)...
        from repro.core import comm_hops_per_round
        hops = comm_hops_per_round(algos["fedcet_hier4_shiftq8"], n,
                                   n_clients=n_clients)
        assert [h["messages"] for h in hops] == [n_clients, 4]
        assert hops[0]["bits"] == n * n_clients * 8.0   # shift:q8 clients
        assert hops[1]["bits"] == n * 4 * 32.0          # dense tier->root
        # ...while ring gossip transmits to 2 neighbors and broadcasts
        # nothing (vectors_down bits are billed zero).
        ring_bits = comm_bits_per_round(algos["fedcet_ring"], n,
                                        n_clients=n_clients)
        assert ring_bits["up_bits"] == n * n_clients * 2 * 32.0
        assert ring_bits["down_bits"] == 0.0
        # the sparse lowering changes the EXECUTION, not the exchange:
        # identical hops, messages and bits to the dense path.
        assert comm_bits_per_round(algos["fedcet_ring_sparse"], n,
                                   n_clients=n_clients) == ring_bits
        assert comm_hops_per_round(algos["fedcet_ring_sparse"], n,
                                   n_clients=n_clients) \
            == comm_hops_per_round(algos["fedcet_ring"], n,
                                   n_clients=n_clients)
        # tier recompression: the interior hop drops from dense f32 to the
        # tier compressor's 8 bits/coord; the downward tier re-broadcast
        # stays dense f32 (uplink-only mechanism).
        thops = comm_hops_per_round(algos["fedcet_hier4_tierq8"], n,
                                    n_clients=n_clients)
        assert thops[0]["bits"] == n * n_clients * 8.0  # shift:q8 clients
        assert thops[1]["bits"] == n * 4 * 8.0          # shift:q8 tiers
        tbits = comm_bits_per_round(algos["fedcet_hier4_tierq8"], n,
                                    n_clients=n_clients)
        assert tbits["down_bits"] == n * (n_clients + 4) * 32.0
        # cohort duty: a block:4 cohort of 16 clients scales BOTH the
        # uplink and the (present-only) downlink by 4/16 — non-sampled
        # clients neither transmit nor receive.
        frac = 4 / n_clients
        assert algos["fedcet_cohort4"].transmit_frac == frac
        assert algos["fedcet_cohort4"].receive_frac == frac
        cbits = comm_bits_per_round(algos["fedcet_cohort4"], n,
                                    n_clients=n_clients)
        assert math.isclose(cbits["up_bits"], sync_up * frac, rel_tol=1e-12)
        assert math.isclose(cbits["down_bits"], sync_up * frac,
                            rel_tol=1e-12)
        # ...and composes with the compressed wire width (8 bits/coord
        # before the duty scaling).
        ccbits = comm_bits_per_round(algos["fedcet_cohort4_shiftq8"], n,
                                     n_clients=n_clients)
        assert math.isclose(ccbits["up_bits"], sync_up * frac * 8.0 / 32.0,
                            rel_tol=1e-12)
    _plan_leaf_billing(csv_rows, n_clients)
    return out


if __name__ == "__main__":
    rows = []
    run(csv_rows=rows)
    for r in rows:
        print(",".join(map(str, r)))
