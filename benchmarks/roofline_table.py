"""Benchmark: render the roofline table from the dry-run results JSON
(produced by `python -m repro.launch.dryrun --all`). One row per
(arch x shape x mesh): the three terms, dominant bottleneck, MODEL_FLOPS
ratio, per-device memory."""

from __future__ import annotations

import json
import os

RESULTS = os.environ.get("DRYRUN_RESULTS", "results/dryrun.json")


def load(path: str = RESULTS) -> dict:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def rows(data: dict):
    for key in sorted(data):
        r = data[key]
        if r.get("status") != "ok":
            yield (r["arch"], r["shape"], r["mesh"], r.get("status"),
                   r.get("reason", r.get("error", ""))[:60], "", "", "", "")
            continue
        rf = r["roofline"]
        mem_gb = (r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"]) / 1e9
        yield (r["arch"], r["shape"], r["mesh"], "ok",
               f"{rf['compute_s'] * 1e3:.2f}",
               f"{rf['memory_s'] * 1e3:.2f}",
               f"{rf['collective_s'] * 1e3:.2f}",
               rf["bottleneck"],
               f"{rf['flops_ratio']:.3f}|{mem_gb:.1f}GB")


def run(csv_rows=None, path: str = RESULTS):
    data = load(path)
    for row in rows(data):
        if csv_rows is not None:
            csv_rows.append((
                f"roofline/{row[0]}/{row[1]}/{row[2]}", 0.0,
                f"status={row[3]};compute_ms={row[4]};memory_ms={row[5]};"
                f"collective_ms={row[6]};bottleneck={row[7]};extra={row[8]}"))
    return data


def markdown(path: str = RESULTS) -> str:
    data = load(path)
    out = ["| arch | shape | mesh | status | compute ms | memory ms | "
           "collective ms | bottleneck | MF-ratio / mem |",
           "|---|---|---|---|---|---|---|---|---|"]
    for row in rows(data):
        out.append("| " + " | ".join(str(c) for c in row) + " |")
    return "\n".join(out)


if __name__ == "__main__":
    print(markdown())
