"""Benchmark: staleness sweep — error floors under asynchronous rounds.

FedCET vs FedAvg vs SCAFFOLD on the paper's quadratic (Section IV), across
delay model x stale-aggregation policy x compression stack. Emits one CSV
row per cell with the final error at ``ROUNDS`` rounds plus the uplink duty
cycle, and asserts the PINNED MEASURED FINDINGS (committed table in
results/staleness_sweep.csv; recorded in ARCHITECTURE.md):

1. FedCET keeps EXACT convergence at delay >= 2 under ``drop`` AND
   ``last`` — final error ~1e-14 for fixed:2 / rr:2 / geom:0.5, with or
   without a shift:q8 compressed uplink (8 bits/coord). Its single
   transmitted vector v is ABSOLUTE, so the server reusing a buffered
   copy is safe, and uniform aggregation weights keep the drift updates
   mean-zero (Lemma 2 survives staleness).
2. SCAFFOLD's two-vector message is a DELTA pair (dy, dc): ``last``
   re-applies buffered control updates every stale round and the error
   explodes to ~1e0-4e0; only ``drop`` keeps it convergent. FedAvg
   (absolute model message) tolerates both policies on this problem (its
   drift floor needs heterogeneous Hessians — see tests/test_baselines).
3. ``poly:1`` staleness-discounted weights — the classic async-FL
   heuristic — BREAK FedCET's exactness whenever ages are non-uniform
   (floor ~5e-2 under rr:2, ~3e-1 under geom:0.5): non-uniform weights
   destroy the mean-zero drift structure. Under fixed:k all ages are
   equal, weights stay uniform, and exactness survives.

Run directly (``python benchmarks/staleness_sweep.py``) or via
benchmarks/run.py; ``--quick`` shrinks the grid/rounds for CI smoke.
"""

from __future__ import annotations

import time

ROUNDS = 1500
DELAYS = ("none", "fixed:2", "rr:2", "geom:0.5")
POLICIES = ("drop", "last", "poly:1")
COMPRESSIONS = ("none", "shift:q8")


def _algos(problem, tau=2):
    from repro.core import FedAvg, FedCET, Scaffold, max_weight_c
    from repro.core.lr_search import lr_search

    mu, L, n = problem.mu, problem.L, problem.n_clients
    alpha = lr_search(mu, L, tau)
    return {
        "fedcet": FedCET(alpha=alpha, c=max_weight_c(mu, alpha), tau=tau,
                         n_clients=n),
        "fedavg": FedAvg(alpha=1.0 / (2 * tau * L), tau=tau, n_clients=n),
        "scaffold": Scaffold(alpha_l=1.0 / (81 * tau * L), tau=tau,
                             n_clients=n),
    }


def run(csv_rows=None, rounds: int = ROUNDS, quick: bool = False):
    import jax

    jax.config.update("jax_enable_x64", True)  # floors sit below f32 eps

    from repro.core import with_compression, with_delay
    from repro.core.simulate import simulate_quadratic
    from repro.data.quadratic import make_quadratic_problem

    if quick:
        rounds = min(rounds, 400)
    problem = make_quadratic_problem(0)
    algos = _algos(problem)
    delays = DELAYS if not quick else ("none", "rr:2")
    comps = COMPRESSIONS if not quick else ("none",)

    err = {}
    for aname, base in algos.items():
        for comp in comps:
            algo0 = base if comp == "none" else with_compression(
                base, compressor=comp)
            for dspec in delays:
                for pol in POLICIES if dspec != "none" else ("sync",):
                    algo = algo0 if dspec == "none" else with_delay(
                        algo0, dspec, policy=pol)
                    t0 = time.perf_counter()
                    res = simulate_quadratic(algo, problem, rounds=rounds)
                    dt = (time.perf_counter() - t0) * 1e6 / rounds
                    e = res.final_error
                    err[(aname, comp, dspec, pol)] = e
                    if csv_rows is not None:
                        csv_rows.append((
                            f"staleness/{aname}/{comp}/{dspec}/{pol}", dt,
                            f"final_err={e:.3e}"
                            f";rounds={rounds}"
                            f";up_duty={algo.transmit_frac:g}"
                            f";up_bits_per_coord={algo.bits_per_coord:g}"))

    # ---- pinned measured findings (full grid only; see module docstring)
    if not quick:
        for dspec in ("fixed:2", "rr:2", "geom:0.5"):
            for pol in ("drop", "last"):
                for comp in comps:
                    e = err[("fedcet", comp, dspec, pol)]
                    assert e < 1e-9, ("fedcet stays exact", comp, dspec, pol, e)
        assert err[("scaffold", "none", "rr:2", "last")] > 1e-1
        assert err[("scaffold", "none", "rr:2", "drop")] < 1e-2
        assert err[("fedcet", "none", "rr:2", "poly:1")] > 1e-4
        assert err[("fedcet", "none", "geom:0.5", "poly:1")] > 1e-4
        # fixed:k ages are uniform -> poly weights uniform -> still exact
        assert err[("fedcet", "none", "fixed:2", "poly:1")] < 1e-9
    return err


if __name__ == "__main__":
    import sys

    rows = []
    run(csv_rows=rows, quick="--quick" in sys.argv)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(map(str, r)))
