"""Benchmark: in-trace telemetry overhead + live monitor boundaries.

Three claims, all asserted:

1. **Overhead**: attaching ``with_telemetry`` to a composed FedCET round
   (shift:q8 compression x fixed:2 delay) costs <= 10% wall-clock on the
   paper's quadratic — the captures are a handful of fused reductions
   riding the existing scan, with zero host syncs inside a segment. The
   compiled footprint (optimized-HLO instruction count of the K-round
   runner, off vs on) and the host-side drain cost are reported alongside.
   With the FULL distribution-sketch stack on top (per-client norm
   log-histograms + quantiles + top-k over every source, one O(N) pass
   per round), the pin loosens to <= 1.15x — and the sketch-on state
   stays a bitwise no-op.

2. **Live invariant boundary**: the invariant monitor reproduces the
   PR 3 pinned staleness boundary FROM A SINGLE RUN'S JSONL — no offline
   re-simulation: ``fixed:2`` + ``poly:1`` keeps uniform ages, so the
   streamed ``invariant_residual`` series stays at f64 noise and the
   monitor is SILENT; ``rr:2`` + ``poly:1`` makes ages non-uniform, the
   residual drifts above the 1e-6 bound, and the monitor emits WARN
   events naming the offending axis (stale_policy).

3. **Live rate boundary**: the online linear-rate estimator
   (``RateMonitor``, windowed log-residual regression over the streamed
   ``err`` series) detects the SAME boundary as a rate break: ``fixed:2``
   + ``poly:1`` contracts linearly every round (rho_hat < 1, silent);
   ``rr:2`` + ``poly:1`` floors, the windowed rho_hat crosses 1 after
   linear convergence was established, and the monitor WARNs naming the
   suspect axis — verified both live at drain time and by re-running
   ``replay_jsonl`` over the finished file alone.

Emits ``results/BENCH_telemetry.json``. Runs via benchmarks/run.py (late:
it enables x64 for the f64 residual floor) or directly.
"""

from __future__ import annotations

import json
import os
import tempfile

from benchmarks._timing import min_of_batches, results_dir, write_bench_json

ROUNDS_PER_CALL = 32
BOUNDARY_ROUNDS = 60
N_CLIENTS = 32
DIM = 512
#: measurements per client — sets the local-step compute the captures
#: amortize against (the paper's 10 makes the round so small that the
#: handful of capture reductions shows up as >10%; any realistic local
#: workload drowns them).
N_MEAS = 64
MAX_OVERHEAD = 1.10
#: full sketch stack (hist + quantiles + top-k over every source) — one
#: O(N) pass over the whole client store per round rides on top.
MAX_SKETCH_OVERHEAD = 1.15


def _fedcet(problem, tau=2):
    from repro.core import FedCET, max_weight_c
    from repro.core.lr_search import lr_search

    alpha = lr_search(problem.mu, problem.L, tau)
    return FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=tau,
                  n_clients=problem.n_clients)


def _runner_and_state(algo, problem):
    import jax
    import jax.numpy as jnp

    from repro.core.engine import make_round_runner

    grad_fn = jax.grad(problem.client_loss)
    batches = problem.stacked_batches(algo.tau)
    x0 = jnp.zeros((problem.dim,), dtype=problem.b.dtype)
    state = algo.init(grad_fn, x0, jax.tree.map(lambda b: b[0], batches))
    return make_round_runner(algo, grad_fn, repeat=True), state, batches


def _time_round(algo, problem) -> tuple[float, object]:
    import jax

    runner, state, batches = _runner_and_state(algo, problem)
    best, out = min_of_batches(
        lambda: runner(state, batches, ROUNDS_PER_CALL), reps=3, batches=5)
    jax.block_until_ready(out)
    return best / ROUNDS_PER_CALL, out


def _instr_count(algo, problem) -> int:
    from repro.core.telemetry import instruction_count

    # the runner is already a jitted callable (rounds static) — lower it
    # directly rather than re-wrapping in jit.
    runner, state, batches = _runner_and_state(algo, problem)
    return instruction_count(runner.lower(state, batches, ROUNDS_PER_CALL))


def _jsonl_boundary(base, problem, delay_spec: str, path: str):
    """One LIVE run: simulate with telemetry attached, drain the stacked
    series (plus the distance-to-optimum ``err`` series the rate
    estimator watches) into a JSONL sink, then read the FILE back and
    return the parsed residual series + WARN events split by monitor kind
    (what a dashboard would see)."""
    import time

    import numpy as np

    from repro.core import (INVARIANT_MONITOR, JsonlSink, RateMonitor, drain,
                            rate_axis, run_manifest, with_delay,
                            with_telemetry)
    from repro.core.simulate import simulate_quadratic

    algo = with_telemetry(
        with_delay(base, delay_spec, policy="poly:1"), True)
    monitors = (INVARIANT_MONITOR, RateMonitor(axis=rate_axis(algo)))
    t0 = time.perf_counter()
    res = simulate_quadratic(algo, problem, rounds=BOUNDARY_ROUNDS)
    sink = JsonlSink(path)
    sink.emit(run_manifest(algo, n_params=problem.dim,
                           config={"delay": delay_spec, "policy": "poly:1"},
                           monitors=monitors))
    # errors[0] is the pre-round state; round r's event carries errors[r+1]
    drain({**res.telemetry, "err": np.asarray(res.errors)[1:]},
          sinks=[sink], monitors=monitors, algo=algo, n_params=problem.dim)
    sink.close()
    drain_us = (time.perf_counter() - t0) * 1e6 / BOUNDARY_ROUNDS
    with open(path) as f:
        events = [json.loads(line) for line in f]
    assert events[0]["event"] == "manifest", events[0]
    residuals = [e["invariant_residual"] for e in events
                 if e["event"] == "round"]
    warns = [e for e in events
             if e["event"] == "monitor" and e.get("level") == "WARN"]
    inv_warns = [w for w in warns if w.get("kind") != "rate_break"]
    rate_warns = [w for w in warns if w.get("kind") == "rate_break"]
    assert len(residuals) == BOUNDARY_ROUNDS
    return residuals, inv_warns, rate_warns, drain_us


def run(csv_rows=None, quick: bool = False):
    import jax

    jax.config.update("jax_enable_x64", True)  # residual floor is f64 noise

    from repro.core import with_compression, with_delay, with_telemetry
    from repro.data.quadratic import make_quadratic_problem

    problem = make_quadratic_problem(0, n_clients=N_CLIENTS, dim=DIM,
                                     n_measurements=N_MEAS)
    base = _fedcet(problem)
    composed = with_delay(
        with_compression(base, compressor="shift:q8"), "fixed:2",
        policy="last")

    # ---- 1. wall-clock overhead of the in-trace captures -----------------
    off_us, out_off = _time_round(composed, problem)
    on_us, out_on = _time_round(with_telemetry(composed, True), problem)
    ratio = on_us / off_us
    # telemetry must also be a bitwise no-op on the state it observed
    s_off, s_on = out_off[0], out_on[0]
    diffs = jax.tree.map(lambda a, b: float(abs(a - b).max()),
                         jax.tree.leaves(s_off), jax.tree.leaves(s_on))
    assert max(diffs) == 0.0, diffs
    assert ratio <= MAX_OVERHEAD, (
        f"telemetry overhead {ratio:.3f}x exceeds {MAX_OVERHEAD}x "
        f"({off_us:.1f}us -> {on_us:.1f}us per round)")

    # full distribution-sketch stack on top: hist + quantiles + top-k per
    # source, one O(N) pass over the whole client store each round.
    from repro.core import Telemetry

    sketch_spec = Telemetry(sketches="auto", topk=4)
    sketch_us, out_sk = _time_round(with_telemetry(composed, sketch_spec),
                                    problem)
    sketch_ratio = sketch_us / off_us
    s_sk = out_sk[0]
    diffs = jax.tree.map(lambda a, b: float(abs(a - b).max()),
                         jax.tree.leaves(s_off), jax.tree.leaves(s_sk))
    assert max(diffs) == 0.0, diffs
    assert sketch_ratio <= MAX_SKETCH_OVERHEAD, (
        f"sketch overhead {sketch_ratio:.3f}x exceeds {MAX_SKETCH_OVERHEAD}x "
        f"({off_us:.1f}us -> {sketch_us:.1f}us per round)")

    instr_off = _instr_count(composed, problem)
    instr_on = _instr_count(with_telemetry(composed, True), problem)
    instr_sk = _instr_count(with_telemetry(composed, sketch_spec), problem)

    # ---- 2+3. the PR 3 staleness boundary, live from one run's JSONL -----
    tmp = tempfile.mkdtemp(prefix="telemetry_bench_")
    exact_path = os.path.join(tmp, "fixed2_poly1.jsonl")
    drift_path = os.path.join(tmp, "rr2_poly1.jsonl")
    exact, exact_warns, exact_rate, drain_exact_us = _jsonl_boundary(
        base, problem, "fixed:2", exact_path)
    drift, drift_warns, drift_rate, drain_drift_us = _jsonl_boundary(
        base, problem, "rr:2", drift_path)
    # fixed:k -> uniform ages -> poly weights uniform -> exact: the monitor
    # stays silent and the streamed residual series sits at f64 noise.
    assert max(exact) < 1e-9, max(exact)
    assert not exact_warns, exact_warns[:2]
    # rr:2 -> non-uniform ages -> poly weights non-uniform -> Lemma 2
    # breaks: the residual drifts above the bound and the monitor fires,
    # naming the offending axis.
    assert max(drift) > 1e-4, max(drift)
    assert drift_warns, "monitor failed to fire on rr:2 + poly:1"
    assert "stale_policy" in drift_warns[0]["axis"]
    # the rate estimator sees the same boundary: fixed:2 contracts
    # linearly to the end (no break); rr:2 floors and the windowed
    # rho_hat crossing 1 fires a rate break naming the suspect axis.
    assert not exact_rate, exact_rate[:2]
    assert drift_rate, "rate monitor failed to fire on rr:2 + poly:1"
    assert "stale_policy" in drift_rate[0]["axis"]
    assert drift_rate[0]["rho_hat"] >= 0.99, drift_rate[0]
    # ... and reproduces POST HOC from the finished file alone.
    from repro.core import RateMonitor, replay_jsonl

    replayed = [w for w in replay_jsonl(drift_path, (RateMonitor(),))
                if w.get("kind") == "rate_break"]
    assert replayed, "replay_jsonl missed the rr:2 rate break"
    assert replayed[0]["round"] == drift_rate[0]["round"], (
        replayed[0], drift_rate[0])

    timings = {
        "round_telemetry_off": off_us,
        "round_telemetry_on": on_us,
        "round_sketch_on": sketch_us,
        "drain_per_round_exact": drain_exact_us,
        "drain_per_round_drift": drain_drift_us,
    }
    write_bench_json(
        "telemetry",
        config={"n_clients": N_CLIENTS, "dim": DIM,
                "n_measurements": N_MEAS,
                "rounds_per_call": ROUNDS_PER_CALL,
                "boundary_rounds": BOUNDARY_ROUNDS,
                "scenario": "shift:q8 + fixed:2/last",
                "max_overhead": MAX_OVERHEAD,
                "max_sketch_overhead": MAX_SKETCH_OVERHEAD},
        timings=timings,
        extra={"overhead_ratio": round(ratio, 4),
               "sketch_overhead_ratio": round(sketch_ratio, 4),
               "hlo_instructions": {"off": instr_off, "on": instr_on,
                                    "sketches": instr_sk},
               "boundary": {
                   "fixed2_poly1_max_residual": max(exact),
                   "rr2_poly1_max_residual": max(drift),
                   "rr2_poly1_warns": len(drift_warns),
                   "fixed2_poly1_rate_breaks": len(exact_rate),
                   "rr2_poly1_rate_breaks": len(drift_rate),
                   "rr2_poly1_break_round": drift_rate[0]["round"],
                   "rr2_poly1_break_rho_hat": drift_rate[0]["rho_hat"]}},
        out_dir=results_dir())
    if csv_rows is not None:
        csv_rows.append((
            "telemetry/overhead", on_us,
            f"off_us={off_us:.1f};ratio={ratio:.3f}"
            f";sketch_ratio={sketch_ratio:.3f}"
            f";hlo_off={instr_off};hlo_on={instr_on};hlo_sk={instr_sk}"))
        csv_rows.append((
            "telemetry/boundary", 0.0,
            f"fixed2_poly1_max_res={max(exact):.3e}"
            f";rr2_poly1_max_res={max(drift):.3e}"
            f";warns={len(drift_warns)}"
            f";rate_breaks={len(drift_rate)}"))
    return {"ratio": ratio, "sketch_ratio": sketch_ratio,
            "exact": max(exact), "drift": max(drift),
            "rate_breaks": len(drift_rate)}


if __name__ == "__main__":
    rows = []
    run(csv_rows=rows)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(map(str, r)))
