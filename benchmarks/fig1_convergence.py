"""Benchmark: the paper's Fig. 1 — FedCET vs FedTrack vs SCAFFOLD (+FedAvg)
on the quadratic estimation problem. Emits error-per-round and
error-per-transmitted-byte CSV rows."""

from __future__ import annotations

import time

from repro.core.simulate import paper_fig1_algorithms, simulate_quadratic
from repro.data.quadratic import make_quadratic_problem


def run(rounds: int = 300, csv_rows=None):
    problem = make_quadratic_problem(0)
    algos = paper_fig1_algorithms(problem, tau=2)
    results = {}
    for name, algo in algos.items():
        t0 = time.perf_counter()
        res = simulate_quadratic(algo, problem, rounds=rounds)
        dt = (time.perf_counter() - t0) * 1e6 / rounds
        results[name] = res
        final = float(res.errors[-1])
        if csv_rows is not None:
            csv_rows.append((f"fig1/{name}", dt, f"final_err={final:.3e}"))
        # sampled trajectory for the experiment log
        for k in (0, 50, 100, 200, rounds):
            if csv_rows is not None and k < len(res.errors):
                csv_rows.append((
                    f"fig1/{name}/round_{k}", 0.0,
                    f"err={float(res.errors[k]):.6e};"
                    f"bytes={k * res.bytes_per_round}"))
    # validation assertions mirrored from tests
    e = {k: float(r.errors[-1]) for k, r in results.items()}
    assert e["fedcet"] < e["fedtrack"] < e["scaffold"], e
    return results


if __name__ == "__main__":
    rows = []
    run(csv_rows=rows)
    for r in rows:
        print(",".join(map(str, r)))
