"""Benchmark: topology sweep — aggregation geometry vs exactness and rate.

FedCET and NIDS on the paper's quadratic (Section IV) across aggregation
topologies (star / 2- and 3-level hierarchical / ring / torus /
Erdős–Rényi gossip), with and without a shift:q8 compressed client
uplink. Because the doubly-stochastic mixing keeps the CLIENT MEAN on the
centralized trajectory regardless of topology, the sweep measures the
consensus-aware error ``max_i ||x_i - x*||`` (the mean error is blind to
gossip disagreement), emits one CSV row per cell with the final error,
rounds-to-1e-6, spectral gap and per-hop uplink accounting, and asserts
the PINNED MEASURED FINDINGS (committed table in
results/topology_sweep.csv; recorded in ARCHITECTURE.md):

1. FedCET stays EXACT under 2-level (and 3-level) HIERARCHICAL
   aggregation — final ~4.5e-15 at 2000 rounds, with or without a
   shift:q8 8-bit client uplink — and its rounds-to-1e-6 (180) are
   IDENTICAL to star: the tree is an exact regrouping of the weighted
   mean, so Lemma 2 never notices the extra hop, while the root ingress
   drops from N=10 messages to g=5 (the scaling story).
2. NIDS proper (the decentralized optimizer FedCET descends from, run as
   the ~70-line engine spec + a mixing matrix) converges EXACTLY on every
   CONNECTED gossip graph, at a rate ordered by the spectral gap of W:
   er:0.7 (gap .47) 57 rounds < torus 2x5 (gap .35) 79 < er:0.5
   (gap .17) 170 < ring (gap .13) 229 — and the answer to "when does
   ring-NIDS match star-FedCET's 180 rounds?" is gap ~0.17: the er:0.5
   graph already matches (170 <= 180), the N=10 ring (gap 0.13) needs
   ~1.3x. FedCET's own aggregating step over the ring stays exact too
   (~2e-14) but needs 840 rounds — its c-damped correction mixes slower
   than NIDS's lazy (I+W)/2 step.
3. The spectral gap is the WHOLE story: the seed-0 G(10, 0.3) draw is
   disconnected (gap = 0, two isolated nodes) and NIDS stalls at the
   initial disagreement (~7.3) — while the MEAN error still reads ~9e-15,
   which is why this sweep pins the per-client metric.

Run directly (``python benchmarks/topology_sweep.py``) or via
benchmarks/run.py; ``--quick`` shrinks the grid/rounds for CI smoke.
"""

from __future__ import annotations

import time

ROUNDS = 2000
TOL = 1e-6

#: (label, topology spec) cells for each algorithm family.
FEDCET_TOPOS = ("star", "hier:g5", "hier:4x2", "ring")
NIDS_TOPOS = ("star", "ring", "torus", "er:0.7", "er:0.5", "er:0.3")
COMPRESSIONS = ("none", "shift:q8")


def _client_errors(algo, problem, rounds):
    """Per-round consensus-aware error max_i ||x_i - x*|| (the mean error
    is topology-blind under doubly-stochastic mixing)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import run_rounds

    gf = jax.grad(problem.client_loss)
    batches = problem.stacked_batches(algo.tau)
    init_b = jax.tree.map(lambda b: b[0], batches)
    state0 = algo.init(gf, jnp.zeros((problem.dim,), problem.b.dtype), init_b)

    def metric(s):
        return jnp.max(jnp.linalg.norm(
            algo.client_params(s) - problem.x_star, axis=-1))

    _, errs = run_rounds(algo, gf, state0, batches, rounds=rounds,
                         metric_fn=metric)
    return np.asarray(errs)


def _rounds_to(errs, tol=TOL) -> int:
    import numpy as np

    hit = np.nonzero(errs < tol)[0]
    return int(hit[0]) + 1 if hit.size else -1


def run(csv_rows=None, rounds: int = ROUNDS, quick: bool = False):
    import jax

    jax.config.update("jax_enable_x64", True)  # floors sit below f32 eps

    from repro.core import (NIDS, FedCET, comm_hops_per_round, max_weight_c,
                            with_compression, with_topology)
    from repro.core.lr_search import lr_search
    from repro.data.quadratic import make_quadratic_problem

    if quick:
        rounds = min(rounds, 500)
    problem = make_quadratic_problem(0)
    n = problem.n_clients
    alpha = lr_search(problem.mu, problem.L, 2)
    fedcet = FedCET(alpha=alpha, c=max_weight_c(problem.mu, alpha), tau=2,
                    n_clients=n)
    nids = NIDS(alpha=1.0 / problem.L, n_clients=n)
    comps = COMPRESSIONS if not quick else ("none",)
    nids_topos = NIDS_TOPOS if not quick else ("star", "ring")

    out = {}

    def cell(name, algo):
        t0 = time.perf_counter()
        errs = _client_errors(algo, problem, rounds)
        dt = (time.perf_counter() - t0) * 1e6 / rounds
        final, r_to = float(errs[-1]), _rounds_to(errs)
        out[name] = (final, r_to)
        if csv_rows is not None:
            topo = algo.topology
            gap = getattr(topo, "spectral_gap", None) if topo else None
            hops = comm_hops_per_round(algo, problem.dim, n)
            root = hops[-1]["messages"] if len(hops) > 1 else hops[0]["messages"]
            csv_rows.append((
                f"topology/{name}", dt,
                f"final_err={final:.3e}"
                f";rounds_to_1e6={r_to}"
                f";spectral_gap={'' if gap is None else f'{gap:.4f}'}"
                f";root_ingress_msgs={root:g}"
                f";up_bits_hop0={hops[0]['bits']:g}"))
        return final, r_to

    for comp in comps:
        for spec in FEDCET_TOPOS:
            algo = fedcet if spec == "star" else with_topology(fedcet, spec)
            if comp != "none":
                algo = with_compression(algo, compressor=comp)
            cell(f"fedcet/{comp}/{spec}", algo)
    for spec in nids_topos:
        algo = nids if spec == "star" else with_topology(nids, spec)
        cell(f"nids/none/{spec}", algo)

    # ---- pinned measured findings (full grid only; see module docstring)
    if not quick:
        # 1. hierarchical aggregation keeps FedCET exact, same round count
        #    as star, with or without the 8-bit client uplink.
        star_rounds = out["fedcet/none/star"][1]
        for comp in comps:
            for spec in ("hier:g5", "hier:4x2"):
                final, r_to = out[f"fedcet/{comp}/{spec}"]
                assert final < 1e-9, ("fedcet stays exact", comp, spec, final)
                assert r_to == out[f"fedcet/{comp}/star"][1], (comp, spec)
        assert star_rounds == 180, star_rounds
        # 2. NIDS exact on every connected graph; rounds ordered by the
        #    spectral gap; er:0.5 (gap .17) already matches star-FedCET.
        for spec in ("star", "ring", "torus", "er:0.7", "er:0.5"):
            assert out[f"nids/none/{spec}"][0] < 1e-9, spec
        r = {s: out[f"nids/none/{s}"][1] for s in NIDS_TOPOS}
        assert r["er:0.7"] < r["torus"] < r["er:0.5"] < r["ring"], r
        assert r["er:0.5"] <= star_rounds < r["ring"], (r, star_rounds)
        # FedCET's own step over the ring: exact but ~4.7x slower.
        assert out["fedcet/none/ring"][0] < 1e-9
        assert out["fedcet/none/ring"][1] > 4 * star_rounds
        # 3. the disconnected G(10, 0.3) draw (gap 0) never reaches
        #    consensus — the per-client metric sees what the mean hides.
        assert out["nids/none/er:0.3"][0] > 1.0, out["nids/none/er:0.3"]
    return out


if __name__ == "__main__":
    import sys

    rows = []
    run(csv_rows=rows, quick="--quick" in sys.argv)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(map(str, r)))
