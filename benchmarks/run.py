"""Benchmark harness: one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows. Modules:
  fig1_convergence — the paper's Fig. 1 (FedCET vs FedTrack vs SCAFFOLD)
  comm_table       — Remark 2: bytes/round per algorithm x architecture
  lr_search_bench  — Algorithm 1 output/timing across regimes
  fed_lm_bench     — federated LM round throughput + bytes-to-target-error
  comp_plan_bench  — per-leaf compression plans: budget-matched allocated
                     plan vs uniform shift:q8 on the LM track (plan must
                     win at equal-or-fewer measured bits/round)
  kernel_bench     — Pallas fedcet-update kernels (interpret mode)
  roofline_table   — (arch x shape x mesh) roofline terms from the dry-run
                     results JSON, when present
  gossip_scaling   — sparse neighbor-exchange lowering O(E) vs the dense
                     N^2 gossip contraction at N in {64, 256, 1024}
  cohort_scaling   — O(cohort) gathered round vs the dense O(N) vmap path
                     at N = 1e3..1e6 (runs late: it enables x64)
  staleness_sweep  — error floors under asynchronous rounds: delay model x
                     stale policy x compression (runs LAST: it enables x64)
  topology_sweep   — aggregation geometry: hierarchical exactness, NIDS
                     gossip rate vs spectral gap (also x64: keep last)
  telemetry_bench  — in-trace telemetry overhead (<=10% asserted; full
                     sketch stack <=1.15x) + the invariant- and rate-
                     monitor staleness boundaries replayed live from one
                     run's JSONL (also x64: keep last)

After the module loop every ``results/BENCH_*.json`` merges into
``results/BENCH_trajectory.json`` — the one-file perf trajectory.

Flags:
  ``--only mod1,mod2``      run a subset of the modules above
  ``--check-drift``         after the loop, diff freshly emitted
                            ``results/BENCH_*.json`` timings against the
                            committed copies (``git show HEAD:...``) and
                            print ``# drift:`` WARN lines on regressions
                            past ``--drift-threshold`` (default 1.5x).
                            Never exits nonzero — a non-gating CI step.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time


def check_drift(threshold: float = 1.5) -> list[str]:
    """Compare every working-tree ``results/BENCH_<name>.json`` timing
    against the committed copy (``git show HEAD:<path>``): a fresh timing
    more than ``threshold``x the committed one is flagged as a WARN line
    (``# drift: ...``). New benches / new timing keys are noted, never
    flagged. Returns the WARN lines (also printed to stderr); advisory
    only — wall-clock on shared CI runners is noisy, so this gates
    nothing."""
    from benchmarks._timing import results_dir

    import glob
    import os

    warns: list[str] = []
    for path in sorted(glob.glob(os.path.join(results_dir(),
                                              "BENCH_*.json"))):
        name = os.path.basename(path)
        if name == "BENCH_trajectory.json":
            continue
        rel = os.path.relpath(path, os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))))
        try:
            committed = json.loads(subprocess.run(
                ["git", "show", f"HEAD:{rel}"], capture_output=True,
                text=True, check=True,
                cwd=os.path.dirname(os.path.abspath(__file__))).stdout)
        except (subprocess.CalledProcessError, json.JSONDecodeError):
            # freshly added bench (not in HEAD yet — e.g. the file this
            # very run just emitted): new, skip. NOT a failure.
            print(f"# drift: new {name}: no committed baseline, skipping",
                  file=sys.stderr)
            continue
        try:
            fresh = json.loads(open(path).read())
        except (OSError, json.JSONDecodeError) as e:
            print(f"# drift: WARN {name}: unreadable working-tree file "
                  f"({e})", file=sys.stderr)
            continue
        base_t = committed.get("timings_us", {})
        for k, v in fresh.get("timings_us", {}).items():
            b = base_t.get(k)
            if b is None:
                print(f"# drift: {name}:{k}: new timing key",
                      file=sys.stderr)
                continue
            if not (isinstance(b, (int, float)) and b > 0
                    and isinstance(v, (int, float))):
                continue
            if v > b * threshold:
                w = (f"# drift: WARN {name}:{k} regressed "
                     f"{v / b:.2f}x ({b:.1f} -> {v:.1f} us, "
                     f"threshold {threshold}x)")
                warns.append(w)
                print(w, file=sys.stderr)
    if not warns:
        print(f"# drift: no regressions past {threshold}x", file=sys.stderr)
    return warns


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of bench modules to run "
                         "(e.g. 'kernel_bench,telemetry_bench')")
    ap.add_argument("--check-drift", action="store_true",
                    help="after the loop, WARN on fresh-vs-committed "
                         "BENCH_*.json timing regressions (non-gating)")
    ap.add_argument("--drift-threshold", type=float, default=1.5,
                    help="drift WARN threshold as a fresh/committed ratio")
    args = ap.parse_args(argv)

    from benchmarks import (
        cohort_scaling,
        comm_table,
        comp_plan_bench,
        fed_lm_bench,
        fig1_convergence,
        gossip_scaling,
        kernel_bench,
        lr_search_bench,
        roofline_table,
        staleness_sweep,
        telemetry_bench,
        topology_sweep,
    )
    from benchmarks._timing import aggregate_trajectory

    modules = [
        ("fig1_convergence", fig1_convergence),
        ("comm_table", comm_table),
        ("lr_search_bench", lr_search_bench),
        ("fed_lm_bench", fed_lm_bench),
        ("comp_plan_bench", comp_plan_bench),
        ("kernel_bench", kernel_bench),
        ("roofline_table", roofline_table),
        ("gossip_scaling", gossip_scaling),
        ("cohort_scaling", cohort_scaling),    # enables x64: keep last
        ("staleness_sweep", staleness_sweep),  # also x64
        ("topology_sweep", topology_sweep),    # also x64
        ("telemetry_bench", telemetry_bench),  # also x64
    ]
    if args.only:
        keep = {m.strip() for m in args.only.split(",") if m.strip()}
        unknown = keep - {n for n, _ in modules}
        if unknown:
            ap.error(f"unknown bench module(s): {sorted(unknown)}")
        modules = [(n, m) for n, m in modules if n in keep]

    rows: list[tuple] = []
    t0 = time.time()
    for name, mod in modules:
        t = time.time()
        try:
            mod.run(csv_rows=rows)
            print(f"# {name}: ok ({time.time() - t:.1f}s)", file=sys.stderr)
        except Exception as e:  # keep the harness going; report at the end
            rows.append((f"{name}/FAILED", 0.0, repr(e)[:120]))
            print(f"# {name}: FAILED {e!r}", file=sys.stderr)
    traj = aggregate_trajectory()
    if traj:
        print(f"# trajectory: {traj}", file=sys.stderr)
    if args.check_drift:
        check_drift(args.drift_threshold)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(c) for c in r))
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
