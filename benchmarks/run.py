"""Benchmark harness: one module per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows. Modules:
  fig1_convergence — the paper's Fig. 1 (FedCET vs FedTrack vs SCAFFOLD)
  comm_table       — Remark 2: bytes/round per algorithm x architecture
  lr_search_bench  — Algorithm 1 output/timing across regimes
  fed_lm_bench     — federated LM round throughput + bytes-to-target-error
  kernel_bench     — Pallas fedcet-update kernels (interpret mode)
  roofline_table   — (arch x shape x mesh) roofline terms from the dry-run
                     results JSON, when present
  gossip_scaling   — sparse neighbor-exchange lowering O(E) vs the dense
                     N^2 gossip contraction at N in {64, 256, 1024}
  cohort_scaling   — O(cohort) gathered round vs the dense O(N) vmap path
                     at N = 1e3..1e6 (runs late: it enables x64)
  staleness_sweep  — error floors under asynchronous rounds: delay model x
                     stale policy x compression (runs LAST: it enables x64)
  topology_sweep   — aggregation geometry: hierarchical exactness, NIDS
                     gossip rate vs spectral gap (also x64: keep last)
  telemetry_bench  — in-trace telemetry overhead (<=10% asserted) + the
                     invariant-monitor staleness boundary replayed live
                     from one run's JSONL (also x64: keep last)

After the module loop every ``results/BENCH_*.json`` merges into
``results/BENCH_trajectory.json`` — the one-file perf trajectory.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        cohort_scaling,
        comm_table,
        fed_lm_bench,
        fig1_convergence,
        gossip_scaling,
        kernel_bench,
        lr_search_bench,
        roofline_table,
        staleness_sweep,
        telemetry_bench,
        topology_sweep,
    )
    from benchmarks._timing import aggregate_trajectory

    rows: list[tuple] = []
    t0 = time.time()
    for name, mod in [
        ("fig1_convergence", fig1_convergence),
        ("comm_table", comm_table),
        ("lr_search_bench", lr_search_bench),
        ("fed_lm_bench", fed_lm_bench),
        ("kernel_bench", kernel_bench),
        ("roofline_table", roofline_table),
        ("gossip_scaling", gossip_scaling),
        ("cohort_scaling", cohort_scaling),    # enables x64: keep last
        ("staleness_sweep", staleness_sweep),  # also x64
        ("topology_sweep", topology_sweep),    # also x64
        ("telemetry_bench", telemetry_bench),  # also x64
    ]:
        t = time.time()
        try:
            mod.run(csv_rows=rows)
            print(f"# {name}: ok ({time.time() - t:.1f}s)", file=sys.stderr)
        except Exception as e:  # keep the harness going; report at the end
            rows.append((f"{name}/FAILED", 0.0, repr(e)[:120]))
            print(f"# {name}: FAILED {e!r}", file=sys.stderr)
    traj = aggregate_trajectory()
    if traj:
        print(f"# trajectory: {traj}", file=sys.stderr)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(str(c) for c in r))
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
