"""Benchmark: gossip aggregation cost scaling — sparse O(E) vs dense O(N^2).

The dense ``Mixing`` path materializes the full doubly-stochastic matrix
and pays an ``N^2 x D`` contraction per leaf per aggregation; the sparse
neighbor-exchange lowering (``lowering="sparse"``, repro/core/topology.py)
gathers each node's ``S = max_degree + 1`` padded neighbor rows and
segment-sums them — ``O((E + N) x D)``. On bounded-degree production
graphs (ring degree 2, torus degree 4, sparse Erdős–Rényi with expected
degree 8 independent of N) the edge count grows LINEARLY in N, so the
sparse per-round aggregation cost grows with E while the dense cost grows
with N^2.

This script times one jitted ``reduce`` per (family x lowering x N) cell
at N in {64, 256, 1024} with a [N, 4096] payload, checks the two
lowerings agree numerically, emits one CSV row per cell (time, directed
edge count, slot width, the modeled gather/contract element counts) and
asserts the PINNED SCALING FINDINGS (committed table in
results/gossip_scaling.csv; recorded in ARCHITECTURE.md):

1. the sparse lowering beats the dense contraction at N=1024 on every
   bounded-degree family (measured ~10-1000x, machine-dependent — the
   assertion keeps a 3x margin);
2. sparse cost grows with the EDGE count, not N^2: stepping N 256 -> 1024
   (4x nodes, 4x edges, 16x dense work) grows the sparse time by < 8x
   while the dense time grows by > 8x.

Run directly (``python benchmarks/gossip_scaling.py``) or via
benchmarks/run.py; ``--quick`` shrinks the grid for CI smoke (the
scaling assertions need the full grid and are skipped).
"""

from __future__ import annotations

import dataclasses

try:
    from benchmarks._timing import min_of_batches, results_dir, \
        write_bench_json
except ImportError:  # run directly as a script: benchmarks/ is sys.path[0]
    from _timing import min_of_batches, results_dir, write_bench_json

NS = (64, 256, 1024)
DIM = 4096
REPS = 3
BATCHES = 5  # report min-of-batches (noise-robust on shared machines)
#: G(n, p) with p = EXPECTED_ER_DEGREE / (n - 1): expected node degree 8
#: independent of N — the bounded-degree random mesh.
EXPECTED_ER_DEGREE = 8


def _families(n: int) -> dict:
    from repro.core.topology import Mixing

    return {
        "ring": Mixing.ring(n),
        "torus": Mixing.torus(n),
        "er8": Mixing.erdos_renyi(n, EXPECTED_ER_DEGREE / (n - 1), seed=1),
    }


def _time_reduce(topo, n: int, dim: int, reps: int = REPS,
                 batches: int = BATCHES) -> tuple:
    import jax
    import jax.numpy as jnp

    tree = {"v": jax.random.normal(jax.random.key(0), (n, dim), jnp.float32)}
    w = jnp.ones((n,), jnp.float32)
    fn = jax.jit(lambda t: topo.reduce(t, w))
    return min_of_batches(lambda: fn(tree), reps=reps, batches=batches)


def run(csv_rows=None, quick: bool = False):
    import numpy as np

    ns = NS[:-1] if quick else NS
    times = {}
    for n in ns:
        for family, dense in _families(n).items():
            sparse = dataclasses.replace(dense, lowering="sparse")
            edges = int(dense._directed_edges(n))
            slots = sparse._static_tables()[0].shape[1]
            t_d, out_d = _time_reduce(dense, n, DIM)
            t_s, out_s = _time_reduce(sparse, n, DIM)
            # the lowering is the same aggregation (f32 here; the <=1e-12
            # trajectory harness runs in f64 in tests/test_topology.py)
            np.testing.assert_allclose(np.asarray(out_s["v"]),
                                       np.asarray(out_d["v"]),
                                       rtol=1e-4, atol=1e-5)
            for lowering, t in (("dense", t_d), ("sparse", t_s)):
                times[(family, lowering, n)] = t
                # modeled per-leaf element visits: the dense contraction
                # touches N^2 matrix entries per lane; the sparse exchange
                # touches one gathered row per slot (pads included).
                work = n * n * DIM if lowering == "dense" \
                    else n * slots * DIM
                if csv_rows is not None:
                    csv_rows.append((
                        f"gossip_scaling/{family}/{lowering}/n{n}", t,
                        f"directed_edges={edges}"
                        f";slots={slots}"
                        f";model_elems={work}"
                        f";dim={DIM}"))

    write_bench_json(
        "gossip_scaling",
        config={"ns": list(ns), "dim": DIM, "reps": REPS, "batches": BATCHES,
                "er_degree": EXPECTED_ER_DEGREE, "quick": quick},
        timings={f"{family}/{lowering}/n{n}": t
                 for (family, lowering, n), t in times.items()},
        out_dir=results_dir())

    # ---- pinned measured findings (full grid only; see module docstring)
    if not quick:
        for family in ("ring", "torus", "er8"):
            t_s1k = times[(family, "sparse", 1024)]
            t_d1k = times[(family, "dense", 1024)]
            assert t_s1k * 3 < t_d1k, (
                "sparse must beat dense at N=1024", family, t_s1k, t_d1k)
            grow_s = times[(family, "sparse", 1024)] / \
                times[(family, "sparse", 256)]
            grow_d = times[(family, "dense", 1024)] / \
                times[(family, "dense", 256)]
            # 4x nodes: edge-linear sparse ~4x, quadratic dense ~16x;
            # the relative comparison (with a 2x noise margin) is the
            # O(E)-vs-O(N^2) pin — cost grows with edges, not N^2.
            assert 2.0 * grow_s < grow_d, (
                "sparse grows with edges, dense with N^2",
                family, grow_s, grow_d)
    return times


if __name__ == "__main__":
    import sys

    rows = []
    run(csv_rows=rows, quick="--quick" in sys.argv)
    print("name,us_per_call,derived")
    for r in rows:
        print(",".join(map(str, r)))
