"""Shared benchmark timing + machine-readable result emission.

Every benchmark in this directory times jitted callables the same way:
warm once (compile), then report the MIN over a few batches of ``reps``
back-to-back calls — noise-robust on shared machines. ``min_of_batches``
is that loop, factored out of benchmarks/gossip_scaling.py.

``write_bench_json`` persists one ``BENCH_<name>.json`` per benchmark
(config, git commit, timings) so the perf trajectory is first-class and
diffable across commits instead of scattered CSVs; CI uploads these as
artifacts alongside the sweep CSVs.
"""

from __future__ import annotations

import json
import os
import subprocess
import time


def min_of_batches(run_once, *, reps: int = 3, batches: int = 5):
    """Time ``run_once`` (a nullary returning a JAX value): warm once to
    compile, then return ``(best_us, out)`` — the minimum per-call
    microseconds over ``batches`` batches of ``reps`` synchronous calls."""
    import jax

    out = run_once()  # compile + warm
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(batches):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = run_once()
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) * 1e6 / reps)
    return best, out


def results_dir() -> str:
    """The repo's committed ``results/`` directory when present (benchmarks
    live one level below the repo root), else the current directory."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = os.path.join(repo, "results")
    return out if os.path.isdir(out) else "."


def git_commit() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def write_bench_json(name: str, *, config: dict, timings: dict,
                     extra: dict | None = None, out_dir: str = ".") -> str:
    """Emit ``BENCH_<name>.json``: benchmark name, commit, the config the
    numbers were measured under, and a flat ``{cell: us_per_call}`` timing
    map. Returns the written path."""
    doc = {
        "benchmark": name,
        "commit": git_commit(),
        "config": config,
        "timings_us": {k: round(float(v), 3) for k, v in timings.items()},
    }
    if extra:
        doc.update(extra)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def aggregate_trajectory(out_dir: str | None = None) -> str | None:
    """Merge every ``BENCH_<name>.json`` in ``out_dir`` (default: the
    repo's ``results/``) into one ``BENCH_trajectory.json`` mapping
    benchmark name -> {commit, config, timings_us, ...} — the single file
    a perf dashboard (or a human diff) reads instead of N scattered
    per-bench documents. Idempotent; skips itself and unparseable files.
    Returns the written path, or None when no bench documents exist."""
    out_dir = out_dir or results_dir()
    merged: dict[str, dict] = {}
    for fn in sorted(os.listdir(out_dir)):
        if (not fn.startswith("BENCH_") or not fn.endswith(".json")
                or fn == "BENCH_trajectory.json"):
            continue
        try:
            with open(os.path.join(out_dir, fn)) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        merged[doc.get("benchmark", fn[len("BENCH_"):-len(".json")])] = doc
    if not merged:
        return None
    path = os.path.join(out_dir, "BENCH_trajectory.json")
    with open(path, "w") as f:
        json.dump({"schema": 1, "commit": git_commit(),
                   "benchmarks": merged}, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
